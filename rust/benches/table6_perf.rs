//! EXP-T6 — regenerates paper Table VI: peak performance (latency, TOPS,
//! GOPS/AIE) and energy efficiency (W, GOPS/W) of the three accelerators.

use cat::experiments::table6_rows;
use cat::report::table6;
use cat::util::bench::bench;

fn main() {
    println!("=== Table VI: peak performance and energy efficiency ===\n");
    let rows = table6_rows().expect("simulation failed");
    println!("{}", table6(&rows));

    // paper values: (latency ms, TOPS, GOPS/AIE, W, GOPS/W)
    let paper = [
        ("BERT-Base", 0.118, 35.194, 99.983, 67.555, 520.968),
        ("ViT-Base", 0.129, 30.279, 86.020, 61.464, 492.629),
        ("BERT-Base (Limited AIE)", 0.398, 9.598, 149.968, 16.168, 593.642),
    ];
    println!("paper-vs-measured (System/EDPU rows):");
    for (s, (name, p_lat, p_tops, p_gpa, p_w, p_gpw)) in rows.iter().zip(paper) {
        println!("  {name}:");
        for (what, pv, mv) in [
            ("latency ms", p_lat, s.sys_latency_ms),
            ("TOPS", p_tops, s.sys_tops),
            ("GOPS/AIE", p_gpa, s.sys_gops_per_aie),
            ("Power W", p_w, s.power_w),
            ("GOPS/W", p_gpw, s.gops_per_w),
        ] {
            println!(
                "    {what:<11} paper {pv:>9.3}  measured {mv:>9.3}  ({:+.0}%)",
                (mv - pv) / pv * 100.0
            );
        }
    }

    bench("table6/simulate_all_three_batch16", 1, 5, || {
        // reset so every iteration simulates instead of hitting the
        // stage-sim cache (keeps rows comparable with the seed trajectory)
        cat::sched::reset_stage_cache();
        let _ = table6_rows().unwrap();
    });
}
