//! EXP-T2 — regenerates paper Table II: the EDPU-organization ablation
//! (independent linear x ATB pipeline mode x ATB parallelism) on the
//! ViT-Base configuration.
//!
//! Paper speedups: 1.0x / 3.8x / 5.3x / 14.6x / 20.1x.  Our simulator
//! preserves the strict ordering; magnitudes are compressed because the
//! simulated Lab 1 baseline is less pathological than the measured one
//! (see EXPERIMENTS.md).

use cat::experiments::table2_rows;
use cat::report::table2;
use cat::util::bench::bench;

fn main() {
    println!("=== Table II: EDPU organization ablation ===\n");
    let rows = table2_rows().expect("ablation failed");
    println!("{}", table2(&rows));
    let paper = [1.0, 3.8, 5.3, 14.6, 20.1];
    let base = rows[0].makespan_ns;
    println!("paper-vs-measured speedup ratios:");
    for (r, p) in rows.iter().zip(paper) {
        println!(
            "  {}: paper {p:>5.1}x  measured {:>5.2}x  (simulated MHA makespan {:.1} µs)",
            r.lab,
            base / r.makespan_ns,
            r.makespan_ns / 1e3
        );
    }
    // timing of the experiment itself (simulator throughput)
    bench("table2/full_ablation", 1, 5, || {
        // reset so every iteration simulates instead of hitting the
        // stage-sim cache (keeps rows comparable with the seed trajectory)
        cat::sched::reset_stage_cache();
        let _ = table2_rows().unwrap();
    });
}
