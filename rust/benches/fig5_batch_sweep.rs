//! EXP-F5 — regenerates paper Figure 5: throughput of the three
//! accelerators vs batch size (1..32).  Paper observations to reproduce:
//! all three stabilize by batch 16; BERT/ViT stay above 22 TOPS even at
//! small batch; system TOPS lies between MHA and FFN.

use cat::experiments::{fig5_series, three_accelerators};
use cat::report::fig5;
use cat::util::bench::bench;

fn main() {
    println!("=== Figure 5: throughput vs batch size ===\n");
    for (label, m, hw) in three_accelerators() {
        let pts = fig5_series(&m, &hw).expect("sweep failed");
        println!("{}", fig5(label, &pts));
        let b16 = pts.iter().find(|p| p.batch == 16).unwrap();
        let b32 = pts.iter().find(|p| p.batch == 32).unwrap();
        println!(
            "  saturation by batch 16: {:.1} -> {:.1} TOPS ({:+.1}%)  [paper: stable at 16]\n",
            b16.sys_tops,
            b32.sys_tops,
            (b32.sys_tops / b16.sys_tops - 1.0) * 100.0
        );
    }

    let (_, bert, hw) = &three_accelerators()[0];
    bench("fig5/bert_sweep_6_batches", 1, 5, || {
        // reset so every iteration simulates instead of hitting the
        // stage-sim cache (keeps rows comparable with the seed trajectory)
        cat::sched::reset_stage_cache();
        let _ = fig5_series(bert, hw).unwrap();
    });
}
