//! EXP-T5 — regenerates paper Table V: hardware resource utilization
//! (LUT/FF/BRAM/URAM + AIE deployment / effective-utilization rates) of
//! the three accelerators derived by the CAT customization engine.

use cat::experiments::table5_plans;
use cat::report::table5;
use cat::util::bench::bench;

fn main() {
    println!("=== Table V: hardware resource utilization ===\n");
    let plans = table5_plans().expect("customization failed");
    let refs: Vec<(&str, &cat::arch::AcceleratorPlan)> =
        plans.iter().map(|(n, p)| (*n, p)).collect();
    println!("{}", table5(&refs));

    println!("paper-vs-estimated (BERT-Base):");
    let bert = &plans[0].1;
    for (what, paper, got) in [
        ("MHA LUT", 162_900.0, bert.res_mha.luts as f64),
        ("MHA FF", 213_600.0, bert.res_mha.ffs as f64),
        ("MHA BRAM", 588.0, bert.res_mha.brams as f64),
        ("MHA URAM", 220.0, bert.res_mha.urams as f64),
        ("FFN LUT", 71_700.0, bert.res_ffn.luts as f64),
        ("FFN BRAM", 482.0, bert.res_ffn.brams as f64),
        ("FFN URAM", 276.0, bert.res_ffn.urams as f64),
        ("Overall LUT", 232_300.0, bert.res_overall.luts as f64),
    ] {
        println!(
            "  {what:<12} paper {paper:>9.0}  estimated {got:>9.0}  ({:+.0}%)",
            (got - paper) / paper * 100.0
        );
    }
    println!(
        "\ndeployment rates: BERT {:.0}%, ViT {:.0}%, Limited {:.0}% (paper: 88/88/100)",
        plans[0].1.deployment_rate() * 100.0,
        plans[1].1.deployment_rate() * 100.0,
        plans[2].1.deployment_rate() * 100.0
    );

    bench("table5/customize_all_three", 1, 20, || {
        let _ = table5_plans().unwrap();
    });
}
