//! EXP-O1 — regenerates paper Observation 1: organizing the AIE's
//! send/compute/receive phases serially vs pipelined on the PL side.
//! Paper: serial 1.10x baseline, pipelined 0.71x, i.e. 1.41x speedup.

use cat::experiments::obs1_times;
use cat::util::bench::bench;

fn main() {
    println!("=== Observation 1: PL-side phase organization ===\n");
    let (serial, pipe) = obs1_times().expect("sim failed");
    println!("  serial    : {serial:>10.1} ns   (paper: 1.10x baseline)");
    println!("  pipelined : {pipe:>10.1} ns   (paper: 0.71x)");
    println!("  speedup   : {:.2}x          (paper: 1.41x)", serial / pipe);

    bench("obs1/both_sims", 1, 20, || {
        let _ = obs1_times().unwrap();
    });
}
