//! §Perf — microbenchmarks of the L3 hot paths (used by the performance
//! pass; before/after numbers recorded in EXPERIMENTS.md §Perf):
//!
//! * DES engine throughput (events/s) on the BERT MHA scenario,
//! * full EDPU simulation latency at several batch sizes,
//! * customization engine latency,
//! * PJRT runtime: encoder-layer execution + literal marshalling
//!   (skipped when artifacts are absent).

use cat::config::{HardwareConfig, ModelConfig};
use cat::customize::{customize, CustomizeOptions};
use cat::sched::{run_edpu, run_stage, Stage};
use cat::util::bench::{bench, black_box};

fn main() {
    let model = ModelConfig::bert_base();
    let hw = HardwareConfig::vck5000();
    let plan = customize(&model, &hw, &CustomizeOptions::default()).unwrap();

    println!("=== hot-path microbenchmarks ===\n");

    bench("customize/bert_on_vck5000", 10, 100, || {
        black_box(customize(&model, &hw, &CustomizeOptions::default()).unwrap());
    });

    let r = run_stage(&plan, Stage::Mha, 8).unwrap();
    println!(
        "  (MHA batch-8 scenario: {} events, {:.1} µs simulated)",
        r.sim.events,
        r.makespan_ns / 1e3
    );
    bench("sim/mha_stage_batch8", 3, 30, || {
        black_box(run_stage(&plan, Stage::Mha, 8).unwrap());
    });
    bench("sim/edpu_batch1", 3, 30, || {
        black_box(run_edpu(&plan, 1).unwrap());
    });
    bench("sim/edpu_batch16", 3, 20, || {
        black_box(run_edpu(&plan, 16).unwrap());
    });
    bench("sim/edpu_batch64", 1, 5, || {
        black_box(run_edpu(&plan, 64).unwrap());
    });

    // PJRT hot path (needs artifacts)
    if std::path::Path::new("artifacts/manifest.json").exists() {
        use cat::coordinator::synthetic_request;
        use cat::runtime::{EncoderWeights, Runtime};
        let mut rt = Runtime::open("artifacts").unwrap();
        rt.compile("encoder_layer_fused").unwrap();
        let req = synthetic_request(&model, 64, 0, 1);
        let w = EncoderWeights::synthetic(&model, 7);
        bench("pjrt/encoder_layer_fused", 1, 5, || {
            black_box(
                rt.encoder_layer("encoder_layer_fused", &req.x_q, req.x_scale, &w)
                    .unwrap(),
            );
        });
        let tile_a = cat::runtime::Tensor::I8 { data: vec![1; 64 * 64], shape: vec![64, 64] };
        let tile_b = tile_a.clone();
        rt.compile("mm_tile").unwrap();
        bench("pjrt/mm_tile_64", 3, 50, || {
            black_box(rt.run("mm_tile", &[tile_a.clone(), tile_b.clone()]).unwrap());
        });
    } else {
        println!("  (artifacts/ missing — run `make artifacts` for PJRT benches)");
    }
}
