//! §Perf — microbenchmarks of the L3 hot paths:
//!
//! * DES engine throughput on the BERT MHA scenario, fast vs exact
//!   (`engine/*` rows isolate the simulator fast path from the caches),
//! * full EDPU simulation latency at several batch sizes (stage-sim cache
//!   reset inside the timed closure, so the engine is what's measured),
//! * the stage-sim cache hit path,
//! * customization engine latency,
//! * PJRT runtime: encoder-layer execution + literal marshalling
//!   (skipped when artifacts are absent).
//!
//! Modes:
//!   `cargo bench --bench hotpath -- --json BENCH_hotpath.json`
//!       also writes the machine-readable trajectory record;
//!   `CAT_BENCH_SMOKE=1` shrinks iteration counts for CI smoke runs.
//!
//! The run *asserts* fast-vs-exact engine parity (≤0.1% makespan
//! deviation, equal bytes moved) before timing anything, so a fast-path
//! correctness regression fails the bench — and CI — loudly.

use std::collections::BTreeMap;

use cat::config::{HardwareConfig, ModelConfig};
use cat::customize::{customize, CustomizeOptions};
use cat::dse::{ExploreConfig, SpaceSpec};
use cat::sched::{build_mha_pipelined, reset_stage_cache, run_edpu, run_stage, Stage};
use cat::sim;
use cat::util::bench::{bench, bench_doc, black_box, write_json, Stats};
use cat::util::cli;
use cat::util::json::Json;
use cat::workload::layer_workload;

fn main() {
    let args = cli::parse(std::env::args().skip(1), &["json"]);
    let smoke = std::env::var("CAT_BENCH_SMOKE").map(|v| v != "0").unwrap_or(false);

    let model = ModelConfig::bert_base();
    let hw = HardwareConfig::vck5000();
    let plan = customize(&model, &hw, &CustomizeOptions::default()).unwrap();
    let wl = layer_workload(&plan.model, plan.mmsz, plan.independent_linear);

    println!("=== hot-path microbenchmarks ({}) ===\n", if smoke { "smoke" } else { "full" });

    // --- correctness gate: the fast engine must match the exact engine ---
    let sc64 = build_mha_pipelined(&plan, &wl, 64, true).unwrap();
    let fast = sim::run(&sc64).unwrap();
    let exact = sim::run_exact(&sc64).unwrap();
    let parity = (fast.makespan_ns - exact.makespan_ns).abs() / exact.makespan_ns.max(1e-9);
    assert!(
        parity <= 1e-3,
        "fast path deviates from exact DES: {} vs {} ({parity:.2e} rel)",
        fast.makespan_ns,
        exact.makespan_ns
    );
    assert_eq!(fast.bytes_moved, exact.bytes_moved, "fast path lost bytes");
    println!(
        "  parity gate: batch-64 MHA makespan fast {:.1} µs vs exact {:.1} µs \
         (rel dev {parity:.2e}); {} / {} invocations fast-forwarded\n",
        fast.makespan_ns / 1e3,
        exact.makespan_ns / 1e3,
        fast.fast_forwarded,
        sc64.total_invocations(),
    );

    // One helper owns the (warmup, iters) smoke-shrink, the timing, and
    // the row recording, so a row name can't diverge from its record.
    let mut rows: Vec<(String, Stats)> = Vec::new();
    let mut run_row = |name: &str, warmup: u32, iters: u32, f: &mut dyn FnMut()| -> Stats {
        let (w, i) = if smoke { (0, iters.min(2)) } else { (warmup, iters) };
        let s = bench(name, w, i, f);
        rows.push((name.to_string(), s));
        s
    };

    run_row("customize/bert_on_vck5000", 10, 100, &mut || {
        black_box(customize(&model, &hw, &CustomizeOptions::default()).unwrap());
    });

    // --- engine rows: the same scenario object, fast vs exact ---
    let fast_med = run_row("engine/mha_scenario_batch64_fast", 2, 10, &mut || {
        black_box(sim::run(&sc64).unwrap());
    })
    .median_ns();
    let exact_med = run_row("engine/mha_scenario_batch64_exact", 1, 5, &mut || {
        black_box(sim::run_exact(&sc64).unwrap());
    })
    .median_ns();

    // --- scheduler rows: cache reset inside the closure so every
    //     iteration pays the real simulation, not a lookup ---
    reset_stage_cache();
    let r = run_stage(&plan, Stage::Mha, 8).unwrap();
    println!(
        "  (MHA batch-8 scenario: {} events, {} fast-forwarded, {:.1} µs simulated)",
        r.sim.events,
        r.sim.fast_forwarded,
        r.makespan_ns / 1e3
    );
    run_row("sim/mha_stage_batch8", 3, 30, &mut || {
        reset_stage_cache();
        black_box(run_stage(&plan, Stage::Mha, 8).unwrap());
    });
    run_row("sim/edpu_batch1", 3, 30, &mut || {
        reset_stage_cache();
        black_box(run_edpu(&plan, 1).unwrap());
    });
    run_row("sim/edpu_batch16", 3, 20, &mut || {
        reset_stage_cache();
        black_box(run_edpu(&plan, 16).unwrap());
    });
    run_row("sim/edpu_batch64", 1, 5, &mut || {
        reset_stage_cache();
        black_box(run_edpu(&plan, 64).unwrap());
    });

    // --- cache row: identical call, warm cache ---
    reset_stage_cache();
    let _ = run_edpu(&plan, 16).unwrap(); // warm
    run_row("cache/edpu_batch16_hit", 3, 30, &mut || {
        black_box(run_edpu(&plan, 16).unwrap());
    });

    // --- dse row: a compact exhaustive exploration (enumerate -> prune
    //     -> simulate -> frontier), cache reset inside the closure so
    //     every iteration pays the real design-point simulations ---
    let mut dse_cfg = ExploreConfig::new(model.clone(), hw.clone());
    dse_cfg.sample_budget = None;
    dse_cfg.space = SpaceSpec::compact_9pt();
    let mut dse_points = 0usize;
    let dse_med = run_row("dse/explore_9pt_space", 1, 5, &mut || {
        reset_stage_cache();
        let r = cat::dse::explore(&dse_cfg).unwrap();
        dse_points = r.stats.evaluated;
        black_box(r);
    })
    .median_ns();
    let dse_points_per_sec = dse_points as f64 / (dse_med / 1e9).max(1e-12);
    println!(
        "\n  dse: {dse_points} design points evaluated per pass \
         ({dse_points_per_sec:.1} points/s cold-cache)"
    );

    // --- serve row: SLO-aware fleet routing over a pinned 2-backend
    //     family (service profiles pre-simulated once; the timed loop is
    //     pure virtual-clock routing/admission — the serving hot path) ---
    let explored = cat::dse::explore(&dse_cfg).unwrap();
    let mut serve_cfg = cat::serve::FleetConfig::new(model.clone(), hw.clone());
    serve_cfg.rps = 2000.0;
    serve_cfg.slo_ms = 50.0;
    serve_cfg.n_requests = if smoke { 512 } else { 4096 };
    serve_cfg.max_batch = 8;
    serve_cfg.seed = 7;
    let serve_fleet =
        cat::serve::Fleet::select(&model, &hw, &explored, 2, serve_cfg.max_batch).unwrap();
    let mut serve_shed_rate = 0.0;
    let serve_med = run_row("serve/fleet_2backend_route", 2, 20, &mut || {
        let r = cat::serve::serve_fleet_on(&serve_cfg, &serve_fleet).unwrap();
        serve_shed_rate = r.admission.shed_rate();
        black_box(r);
    })
    .median_ns();
    let serve_reqs_per_sec = serve_cfg.n_requests as f64 / (serve_med / 1e9).max(1e-12);
    println!(
        "  serve: {} requests routed per pass across {} backends \
         ({serve_reqs_per_sec:.0} req/s driver throughput, shed rate {serve_shed_rate:.3})",
        serve_cfg.n_requests,
        serve_fleet.len(),
    );

    // --- partitioned-serve row: the same virtual-clock routing loop, but
    //     the family co-resides on ONE board (Σ cores ≤ Total_AIE, joint
    //     PL pools) with every member re-derived under its share.  Link
    //     model off here, so the row isolates the routing path itself ---
    let part_fleet = cat::serve::Fleet::select_partitioned(
        &model,
        &hw,
        &explored,
        2,
        serve_cfg.max_batch,
        Some(serve_cfg.slo_ms),
        None,
    )
    .unwrap();
    let mut part_p50 = std::time::Duration::ZERO;
    let part_med = run_row("serve/partitioned_2backend_route", 2, 20, &mut || {
        let r = cat::serve::serve_fleet_on(&serve_cfg, &part_fleet).unwrap();
        part_p50 = r.fleet_stats.percentile(0.50);
        black_box(r);
    })
    .median_ns();
    let part_reqs_per_sec = serve_cfg.n_requests as f64 / (part_med / 1e9).max(1e-12);
    let part_budget = part_fleet.budget.as_ref().expect("partitioned fleet carries its budget");
    println!(
        "  serve (partitioned): {} co-resident backends on {}/{} AIE \
         ({} residual; {part_reqs_per_sec:.0} req/s driver throughput)",
        part_fleet.len(),
        part_budget.aie_used,
        part_budget.aie_total,
        part_budget.aie_residual(),
    );

    // --- contended partitioned row: the identical partition, but the
    //     shared DRAM/PCIe pools are deliberately tiny so the members
    //     oversubscribe the memory path and serve on throttled slices.
    //     The derived `serve_contention_overhead` (contended p50 /
    //     uncontended p50 modeled latency, virtual clock — fully
    //     deterministic) gates the contention model's trajectory ---
    let tight = cat::config::SharedLinkModel { dram_gbps: 30.0, pcie_gbps: 8.0 };
    let cont_fleet = cat::serve::Fleet::select_partitioned(
        &model,
        &hw,
        &explored,
        2,
        serve_cfg.max_batch,
        Some(serve_cfg.slo_ms),
        Some(&tight),
    )
    .unwrap();
    let cont_ledger = cont_fleet
        .budget
        .as_ref()
        .and_then(|b| b.links.as_ref())
        .expect("link model was enabled");
    assert!(cont_ledger.throttled(), "bench pools must oversubscribe the partition");
    let mut cont_p50 = std::time::Duration::ZERO;
    let cont_med = run_row("serve/partitioned_contended_route", 2, 20, &mut || {
        let r = cat::serve::serve_fleet_on(&serve_cfg, &cont_fleet).unwrap();
        cont_p50 = r.fleet_stats.percentile(0.50);
        black_box(r);
    })
    .median_ns();
    let cont_reqs_per_sec = serve_cfg.n_requests as f64 / (cont_med / 1e9).max(1e-12);
    let serve_contention_overhead = if part_p50.as_nanos() > 0 {
        cont_p50.as_secs_f64() / part_p50.as_secs_f64()
    } else {
        1.0
    };
    println!(
        "  serve (contended): DRAM {:.1}/{:.1} GB/s demanded, worst stretch {:.2}x \
         ({cont_reqs_per_sec:.0} req/s driver throughput; modeled p50 overhead \
         {serve_contention_overhead:.3}x vs uncontended partition)",
        cont_ledger.demanded().dram_gbps,
        tight.dram_gbps,
        cont_ledger.members.iter().map(|m| m.stretch).fold(0.0f64, f64::max),
    );

    // --- fixed-point contended row: the identical oversubscribed
    //     partition renegotiated to the clamped fixed point
    //     (`--links-fixed-point`), so slices serve on the relaxed
    //     throttles.  The derived `serve_contention_pessimism`
    //     (single-pass contended p50 / fixed-point contended p50,
    //     virtual clock, >= 1 by construction) gates how much modeled
    //     latency the conservative bound gives away ---
    let fp_fleet = cat::serve::Fleet::select_partitioned_in(
        &model,
        &hw,
        &explored,
        2,
        serve_cfg.max_batch,
        Some(serve_cfg.slo_ms),
        Some(&tight),
        cat::serve::NegotiationMode::FixedPoint,
    )
    .unwrap();
    let fp_ledger = fp_fleet
        .budget
        .as_ref()
        .and_then(|b| b.links.as_ref())
        .expect("link model was enabled");
    assert!(fp_ledger.throttled(), "fixed point must stay throttled on the bench pools");
    assert!(
        fp_ledger
            .members
            .iter()
            .zip(&cont_ledger.members)
            .all(|(f, s)| f.stretch <= s.stretch + 1e-12),
        "fixed-point stretch must never exceed the single-pass bound"
    );
    let mut fp_cfg = serve_cfg.clone();
    fp_cfg.links_fixed_point = true;
    let mut fp_p50 = std::time::Duration::ZERO;
    let fp_med = run_row("serve/fixedpoint_contended_route", 2, 20, &mut || {
        let r = cat::serve::serve_fleet_on(&fp_cfg, &fp_fleet).unwrap();
        fp_p50 = r.fleet_stats.percentile(0.50);
        black_box(r);
    })
    .median_ns();
    let fp_reqs_per_sec = fp_cfg.n_requests as f64 / (fp_med / 1e9).max(1e-12);
    let serve_contention_pessimism = if fp_p50.as_nanos() > 0 {
        cont_p50.as_secs_f64() / fp_p50.as_secs_f64()
    } else {
        1.0
    };
    println!(
        "  serve (fixed point): ledger pessimism {:.3}x, modeled p50 {:.3}x vs \
         single-pass contended ({fp_reqs_per_sec:.0} req/s driver throughput)",
        fp_ledger.pessimism(),
        serve_contention_pessimism,
    );

    // --- failover row: the same 2-backend fleet, but the cheapest
    //     member crashes 50 ms into the stream and recovers 100 ms
    //     later (virtual clock — inside the arrival span in both smoke
    //     and full mode).  Times the fault-era routing path: orphan
    //     drain, survivor re-admission, recovery rejoin ---
    let mut fail_cfg = serve_cfg.clone();
    fail_cfg.faults = Some(cat::serve::FaultPolicy::Schedule(cat::serve::FaultSchedule {
        events: vec![cat::serve::FaultEvent {
            at_ns: 50_000_000,
            kind: cat::serve::FaultKind::Crash { backend: 0, down_ns: 100_000_000 },
        }],
    }));
    let mut fail_requeued = 0usize;
    let fail_med = run_row("serve/failover_route", 2, 20, &mut || {
        let r = cat::serve::serve_fleet_on(&fail_cfg, &serve_fleet).unwrap();
        fail_requeued = r.faults.as_ref().map_or(0, |f| f.requeued);
        black_box(r);
    })
    .median_ns();
    let failover_reqs_per_sec = fail_cfg.n_requests as f64 / (fail_med / 1e9).max(1e-12);
    println!(
        "  serve (failover): mid-stream crash + recovery, {fail_requeued} rider(s) \
         requeued ({failover_reqs_per_sec:.0} req/s driver throughput)"
    );

    // --- cluster row: the same virtual-clock admission plane, but the
    //     family spreads across a 2-board VCK5000 + Limited-AIE rack
    //     behind shared NIC/switch pools (`--cluster`).  Deployment
    //     (per-board explore, placement, net negotiation) happens once
    //     outside the timed loop, so the row isolates the cluster-era
    //     routing path itself ---
    let cl_spec = cat::cluster::ClusterSpec {
        boards: vec![hw.clone(), HardwareConfig::vck5000_limited(64)],
        net: cat::config::SharedLinkModel { dram_gbps: 25.0, pcie_gbps: 12.5 },
    };
    let mut cl_cfg = serve_cfg.clone();
    cl_cfg.max_backends = 2;
    // headroom for the Limited-AIE board's worst-case service bound, so
    // the mixed rack always fields a member per board
    cl_cfg.slo_ms = 100.0;
    cl_cfg.explore_budget = Some(24);
    cl_cfg.cluster = Some(cl_spec.clone());
    let cl_fleet = cat::cluster::build_fleet(&cl_cfg, &cl_spec).unwrap();
    let cl_boards = cl_fleet.cluster.as_ref().expect("cluster fleet carries its ledger");
    let mut cl_completed = 0usize;
    let cl_med = run_row("serve/cluster_2board_route", 2, 20, &mut || {
        let r = cat::serve::serve_fleet_on(&cl_cfg, &cl_fleet).unwrap();
        cl_completed = r.admission.completed;
        black_box(r);
    })
    .median_ns();
    let cluster_reqs_per_sec = cl_cfg.n_requests as f64 / (cl_med / 1e9).max(1e-12);
    println!(
        "  serve (cluster): {} member(s) across {} board(s), {cl_completed} completed \
         per pass ({cluster_reqs_per_sec:.0} req/s driver throughput)",
        cl_fleet.len(),
        cl_boards.boards.len(),
    );

    // --- traced-serve row: the identical routing loop with the full
    //     observability layer attached (trace sink + metrics registry).
    //     The derived `serve_trace_overhead` (traced/untraced host-time
    //     median ratio, lower-is-better) gates the instrumentation cost:
    //     growth means the "zero-cost-when-off, cheap-when-on" contract
    //     is eroding ---
    let mut traced_events = 0usize;
    let traced_med = run_row("serve/traced_route", 2, 20, &mut || {
        let mut obs = cat::obs::Obs::new(true, true);
        let r = cat::serve::serve_fleet_on_obs(&serve_cfg, &serve_fleet, &mut obs).unwrap();
        traced_events = obs.trace.as_ref().map_or(0, |t| t.len());
        black_box(r);
    })
    .median_ns();
    let serve_trace_overhead = traced_med / serve_med.max(1.0);
    println!(
        "  serve (traced): {traced_events} trace event(s) per pass \
         ({serve_trace_overhead:.3}x host-time overhead vs untraced routing)"
    );

    // --- indexed-route rows: the admission plane alone (no batcher, no
    //     responses — a synthetic fleet driven straight through
    //     AdmissionIndex::route with its event upkeep).  The 2- vs
    //     64-backend pair prices how per-request routing cost scales
    //     with fleet width: the derived `serve_router_scaling`
    //     (64-backend ÷ 2-backend per-pass median over the SAME request
    //     count, lower-is-better) gates the index's whole reason to
    //     exist — cached event-driven bounds must keep wide fleets from
    //     paying a full per-arrival rescan ---
    let ir_requests = if smoke { 2_048 } else { 65_536 };
    let mut ir2_admitted = 0usize;
    let ir2_med = run_row("serve/indexed_route_2backend", 2, 20, &mut || {
        ir2_admitted = black_box(indexed_route_pass(2, ir_requests));
    })
    .median_ns();
    let mut ir64_admitted = 0usize;
    let ir64_med = run_row("serve/indexed_route_64backend", 2, 20, &mut || {
        ir64_admitted = black_box(indexed_route_pass(64, ir_requests));
    })
    .median_ns();
    let serve_router_scaling = ir64_med / ir2_med.max(1.0);
    println!(
        "  serve (indexed): {ir_requests} pure-routing arrivals per pass, {ir2_admitted} \
         admitted on 2 backends / {ir64_admitted} on 64 ({serve_router_scaling:.2}x \
         per-request cost at 32x the fleet width)"
    );

    // PJRT hot path (needs artifacts)
    if std::path::Path::new("artifacts/manifest.json").exists() {
        use cat::coordinator::synthetic_request;
        use cat::runtime::{EncoderWeights, Runtime};
        let mut rt = Runtime::open("artifacts").unwrap();
        rt.compile("encoder_layer_fused").unwrap();
        let req = synthetic_request(&model, 64, 0, 1);
        let wts = EncoderWeights::synthetic(&model, 7);
        run_row("pjrt/encoder_layer_fused", 1, 5, &mut || {
            black_box(
                rt.encoder_layer("encoder_layer_fused", &req.x_q, req.x_scale, &wts)
                    .unwrap(),
            );
        });
        let tile_a = cat::runtime::Tensor::I8 { data: vec![1; 64 * 64], shape: vec![64, 64] };
        let tile_b = tile_a.clone();
        rt.compile("mm_tile").unwrap();
        run_row("pjrt/mm_tile_64", 3, 50, &mut || {
            black_box(rt.run("mm_tile", &[tile_a.clone(), tile_b.clone()]).unwrap());
        });
    } else {
        println!("  (artifacts/ missing — run `make artifacts` for PJRT benches)");
    }

    let engine_speedup = exact_med / fast_med.max(1.0);
    println!("\n  engine fast-path speedup on batch-64 MHA: {engine_speedup:.2}x (exact/fast)");

    if let Some(path) = args.opt("json") {
        let mut derived = BTreeMap::new();
        derived.insert(
            "engine_speedup_mha_batch64".to_string(),
            Json::Num((engine_speedup * 100.0).round() / 100.0),
        );
        derived.insert("parity_rel_dev_mha_batch64".to_string(), Json::Num(parity));
        derived.insert(
            "fast_forwarded_mha_batch64".to_string(),
            Json::Num(fast.fast_forwarded as f64),
        );
        derived.insert(
            "dse_points_per_sec".to_string(),
            Json::Num((dse_points_per_sec * 10.0).round() / 10.0),
        );
        derived.insert("dse_points_evaluated".to_string(), Json::Num(dse_points as f64));
        derived.insert(
            "serve_router_reqs_per_sec".to_string(),
            Json::Num(serve_reqs_per_sec.round()),
        );
        derived.insert("serve_shed_rate".to_string(), Json::Num(serve_shed_rate));
        derived.insert(
            "serve_partitioned_reqs_per_sec".to_string(),
            Json::Num(part_reqs_per_sec.round()),
        );
        derived.insert(
            "serve_partitioned_backends".to_string(),
            Json::Num(part_fleet.len() as f64),
        );
        derived.insert(
            "serve_partitioned_aie_used".to_string(),
            Json::Num(part_budget.aie_used as f64),
        );
        derived.insert(
            "serve_contention_overhead".to_string(),
            Json::Num((serve_contention_overhead * 1000.0).round() / 1000.0),
        );
        derived.insert(
            "serve_contended_reqs_per_sec".to_string(),
            Json::Num(cont_reqs_per_sec.round()),
        );
        derived.insert(
            "serve_contention_pessimism".to_string(),
            Json::Num((serve_contention_pessimism * 1000.0).round() / 1000.0),
        );
        derived.insert(
            "serve_fixedpoint_reqs_per_sec".to_string(),
            Json::Num(fp_reqs_per_sec.round()),
        );
        derived.insert(
            "serve_failover_reqs_per_sec".to_string(),
            Json::Num(failover_reqs_per_sec.round()),
        );
        derived.insert(
            "serve_cluster_reqs_per_sec".to_string(),
            Json::Num(cluster_reqs_per_sec.round()),
        );
        derived.insert(
            "serve_cluster_boards".to_string(),
            Json::Num(cl_boards.boards.len() as f64),
        );
        derived.insert(
            "serve_trace_overhead".to_string(),
            Json::Num((serve_trace_overhead * 1000.0).round() / 1000.0),
        );
        derived.insert("serve_trace_events".to_string(), Json::Num(traced_events as f64));
        derived.insert(
            "serve_router_scaling".to_string(),
            Json::Num((serve_router_scaling * 1000.0).round() / 1000.0),
        );
        derived.insert(
            "serve_indexed_admitted_64backend".to_string(),
            Json::Num(ir64_admitted as f64),
        );
        derived.insert("smoke".to_string(), Json::Bool(smoke));
        // the record's own regenerate command reproduces the mode it was
        // measured in, so a refreshed baseline stays gate-comparable
        let regen = if smoke {
            "CAT_BENCH_SMOKE=1 cargo bench --bench hotpath -- --json BENCH_hotpath.json"
        } else {
            "cargo bench --bench hotpath -- --json BENCH_hotpath.json"
        };
        derived.insert("regenerate".to_string(), Json::Str(regen.into()));
        let doc = bench_doc("hotpath", &rows, derived);
        write_json(path, &doc).expect("writing bench json");
        println!("  wrote {path}");
    }
}

/// One pure-routing pass over a synthetic `n`-backend fleet: arrivals in
/// 4-deep same-timestamp bursts (the index's batch-admit fast path),
/// admit → immediate single-rider dispatch → retirement when the virtual
/// clock passes the bound.  Offered load far exceeds the cheap end's
/// capacity, so probes walk deep into the cost order on wide fleets —
/// exactly the regime the index exists for.  No batcher, no riders, no
/// responses: the timed loop is `AdmissionIndex::route` plus its event
/// upkeep and nothing else, fully deterministic (u64 virtual clock).
fn indexed_route_pass(n: usize, requests: usize) -> usize {
    use std::cmp::Reverse;
    use std::collections::BinaryHeap;
    // cheapest first, with enough spread that the cheap end saturates
    let services: Vec<u64> = (0..n).map(|b| 1_000_000 + 20_000 * b as u64).collect();
    let mut ix = cat::serve::AdmissionIndex::new(&services, 200_000);
    let (cap, slo) = (4usize, 2_500_000u64);
    let mut outstanding: BinaryHeap<Reverse<(u64, usize)>> = BinaryHeap::new();
    let mut now = 0u64;
    let mut admitted = 0usize;
    for i in 0..requests {
        if i % 4 == 0 {
            now += 60_000;
            while let Some(&Reverse((done, b))) = outstanding.peek() {
                if done > now {
                    break;
                }
                ix.note_retired(b, 1);
                outstanding.pop();
            }
        }
        if let Ok(d) = ix.route(now, now + slo, cap) {
            ix.note_admitted(d.backend);
            ix.set_busy_until(d.backend, d.completion_bound_ns);
            outstanding.push(Reverse((d.completion_bound_ns, d.backend)));
            admitted += 1;
        }
    }
    admitted
}
