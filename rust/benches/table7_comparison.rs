//! EXP-T7 — regenerates paper Table VII: cross-platform performance and
//! energy-efficiency comparison (peak / ViT / BERT groups), including the
//! CHARM-style and SSR-style scheduling baselines simulated on the same
//! VCK5000 substrate.

use cat::experiments::table7_data;
use cat::report::table7_group;
use cat::util::bench::bench;

fn main() {
    println!("=== Table VII: cross-platform comparison ===\n");
    let d = table7_data().expect("comparison failed");
    println!(
        "{}",
        table7_group(
            "peak",
            &d.cat_peak,
            &[
                ("CHARM-style (sim)", d.charm_style),
                ("SSR-style (sim)", d.ssr_style)
            ]
        )
    );
    println!("{}", table7_group("vit", &d.cat_vit, &[]));
    println!("{}", table7_group("bert", &d.cat_bert, &[]));

    println!("headline claims, paper vs measured:");
    let ssr_pub = 26.7;
    let ssr_pub_eff = 453.32;
    println!(
        "  CAT vs SSR (SOTA) throughput: paper 1.31x, measured {:.2}x",
        d.cat_peak.tops / ssr_pub
    );
    println!(
        "  CAT vs SSR energy efficiency: paper 1.15x, measured {:.2}x",
        d.cat_peak.gops_per_w / ssr_pub_eff
    );
    println!(
        "  CAT vs A10G throughput: paper 2.41x, measured {:.2}x",
        d.cat_peak.tops / 14.63
    );
    println!(
        "  CAT vs A10G energy efficiency: paper 7.80x, measured {:.2}x",
        d.cat_peak.gops_per_w / 66.79
    );
    println!(
        "  like-for-like on our substrate: CAT {:.1} > SSR-style {:.1} > CHARM-style {:.1} TOPS",
        d.cat_peak.tops, d.ssr_style.tops, d.charm_style.tops
    );

    bench("table7/full_comparison", 1, 5, || {
        // reset so every iteration simulates instead of hitting the
        // stage-sim cache (keeps rows comparable with the seed trajectory)
        cat::sched::reset_stage_cache();
        let _ = table7_data().unwrap();
    });
}
