//! Property tests for fault-tolerant fleet serving (`--faults`,
//! `--mtbf-s`/`--mttr-s`):
//!
//! * **conservation with faults** — `submitted == completed + shed_slo +
//!   shed_capacity + shed_fault + shed_retry`, every id answered exactly
//!   once or attributed to exactly one shed reason;
//! * **SLO compliance survives failover** — every *completed* request
//!   still meets its original deadline, even when its first backend died
//!   and it was re-admitted on a survivor;
//! * **determinism** — a fixed seed reproduces a fault run's JSON byte
//!   for byte, scripted or random;
//! * **availability accounting** — per-backend downtime is clamped to
//!   the wall and availability stays in [0, 1];
//! * **graceful degradation** — on a partitioned fleet, a member crash
//!   re-negotiates the shared links over the survivors and their
//!   contention stretch can only relax.

use std::collections::BTreeSet;

use cat::config::{HardwareConfig, ModelConfig, SharedLinkModel};
use cat::dse::{explore, ExploreConfig, SpaceSpec};
use cat::serve::{
    serve_fleet_stream, FaultEvent, FaultKind, FaultPolicy, FaultSchedule, Fleet, FleetConfig,
    FleetReport,
};

const MS: u64 = 1_000_000;

/// Same compact exhaustive space as `serve_properties.rs`.
fn compact_fleet(model: &ModelConfig, hw: &HardwareConfig, max_backends: usize) -> Fleet {
    let mut cfg = ExploreConfig::new(model.clone(), hw.clone());
    cfg.sample_budget = None;
    cfg.space = SpaceSpec::compact_9pt();
    let explored = explore(&cfg).unwrap();
    Fleet::select(model, hw, &explored, max_backends, 8).unwrap()
}

/// The fault-era conservation and SLO invariants.
fn check_fault_invariants(r: &FleetReport, cfg: &FleetConfig, n: usize, label: &str) {
    let a = &r.admission;
    assert_eq!(a.submitted, n, "{label}: submitted");
    assert!(a.accounted(), "{label}: stats leak requests: {a:?}");
    assert_eq!(
        a.submitted,
        a.completed + a.shed_slo + a.shed_capacity + a.shed_fault + a.shed_retry,
        "{label}: five-term conservation: {a:?}"
    );
    assert_eq!(r.responses.len(), a.completed, "{label}: responses vs stats");
    assert_eq!(r.shed.len(), a.shed(), "{label}: shed records vs stats");
    let mut seen = BTreeSet::new();
    for resp in &r.responses {
        assert!(seen.insert(resp.id), "{label}: duplicate response id {}", resp.id);
    }
    for s in &r.shed {
        assert!(seen.insert(s.id), "{label}: id {} both served and shed", s.id);
    }
    assert_eq!(seen.len(), n, "{label}: lost request ids");

    // every COMPLETED request meets its original deadline — failover must
    // never serve a request late, only shed it
    let slo_ns = cfg.slo_ns();
    for resp in &r.responses {
        assert!(
            resp.latency_ns() <= slo_ns,
            "{label}: req {} violated the SLO after failover: {} ns > {slo_ns} ns",
            resp.id,
            resp.latency_ns()
        );
    }
    assert_eq!(r.slo_violations, 0, "{label}: report disagrees on violations");

    // per-backend admitted == served holds WITH faults too: orphaning
    // decrements the source's admitted count, re-admission increments the
    // survivor's
    for (i, b) in r.backends.iter().enumerate() {
        let served = r.responses.iter().filter(|x| x.backend == i).count();
        assert_eq!(b.admitted, served, "{label}: backend {i} admitted==served");
    }

    let f = r.faults.as_ref().unwrap_or_else(|| panic!("{label}: fault run without faults block"));
    // requeue/retry accounting: a rider re-admits at most max_retries
    // times, and every requeued rider is either re-admitted or shed
    assert!(f.retried <= f.requeued, "{label}: retried > requeued");
    assert_eq!(
        f.requeued,
        r.backends.iter().zip(&f.backends).map(|(_, fb)| fb.requeued).sum::<usize>(),
        "{label}: per-backend requeues don't sum"
    );
    // availability: downtime clamped to the wall, availability in [0, 1]
    for (i, fb) in f.backends.iter().enumerate() {
        assert!(fb.down_ns <= r.wall_ns, "{label}: backend {i} down longer than the wall");
        let avail = if r.wall_ns == 0 {
            1.0
        } else {
            (r.wall_ns - fb.down_ns) as f64 / r.wall_ns as f64
        };
        assert!((0.0..=1.0).contains(&avail), "{label}: availability {avail}");
    }
}

/// Scripted mid-run crash of the cheapest backend: its in-flight work
/// fails over to the survivors, nothing completes late, everything is
/// attributed, and the run is byte-for-byte reproducible.
#[test]
fn scripted_crash_of_cheapest_backend_fails_over_to_survivors() {
    let model = ModelConfig::bert_base();
    let hw = HardwareConfig::vck5000();
    let fleet = compact_fleet(&model, &hw, 3);
    assert!(fleet.len() >= 2, "need survivors, got {} backend(s)", fleet.len());

    let mut cfg = FleetConfig::new(model, hw);
    cfg.rps = 1000.0; // label only — the stream below is explicit
    cfg.slo_ms = 80.0;
    cfg.seed = 5;
    // warmup, a queue-filling burst 0.5 ms before the crash, arrivals
    // through the down window, and a tail after the recovery
    let mut arrivals: Vec<u64> = (0..10).map(|i| i * 3 * MS / 2).collect();
    arrivals.extend(std::iter::repeat(19 * MS).take(20));
    arrivals.extend((0..20).map(|i| (25 + i) * MS));
    arrivals.extend((0..10).map(|i| (60 + i) * MS));
    cfg.n_requests = arrivals.len();
    let crash_at = 19 * MS + MS / 2;
    let recovery_at = crash_at + 30 * MS;
    cfg.faults = Some(FaultPolicy::Schedule(FaultSchedule {
        events: vec![FaultEvent {
            at_ns: crash_at,
            kind: FaultKind::Crash { backend: 0, down_ns: 30 * MS },
        }],
    }));

    let r = serve_fleet_stream(&cfg, &fleet, &arrivals).unwrap();
    check_fault_invariants(&r, &cfg, arrivals.len(), "scripted-crash");
    assert!(r.to_json().to_string().contains("\"schema\":\"cat-serve-v4\""));

    let f = r.faults.as_ref().unwrap();
    assert_eq!(f.timeline.len(), 1);
    assert!(f.timeline[0].1, "the crash must actually be applied");
    assert_eq!(f.backends[0].downs, 1);
    assert_eq!(f.backends[0].down_ns, 30 * MS, "downtime is the scheduled window");
    // the burst guarantees backend 0 holds forming/in-flight work at the
    // crash: it must be drained for re-admission, and with live survivors
    // some of it must actually land on them
    assert!(f.backends[0].requeued > 0, "crash caught no in-flight work");
    assert!(f.retried > 0, "no orphan was re-admitted on a survivor");
    // during the down window nothing routes to backend 0 ...
    assert!(
        !r.responses
            .iter()
            .any(|x| x.backend == 0 && x.completion_ns > crash_at && x.completion_ns < recovery_at),
        "a response completed on the crashed backend inside its down window"
    );
    // ... and after recovery the cheapest backend rejoins the rotation
    assert!(
        r.responses.iter().any(|x| x.backend == 0 && x.completion_ns >= recovery_at),
        "backend 0 never rejoined after recovery"
    );

    // byte-for-byte deterministic
    let again = serve_fleet_stream(&cfg, &fleet, &arrivals).unwrap();
    assert_eq!(r.to_json().to_string(), again.to_json().to_string());
}

/// A permanent crash of a single-backend fleet: orphans have no
/// survivors (shed as fault / retry-exhausted depending on the retry
/// budget) and arrivals during the total outage are attributed exactly.
#[test]
fn total_outage_attributes_every_request() {
    let model = ModelConfig::bert_base();
    let hw = HardwareConfig::vck5000();
    let fleet = compact_fleet(&model, &hw, 1);
    assert_eq!(fleet.len(), 1);

    // generous SLO so the whole pre-crash burst is admitted; the crash
    // then orphans everything still queued or in flight
    let mut arrivals: Vec<u64> = (0..10).map(|i| i * 3 * MS / 2).collect();
    arrivals.extend(std::iter::repeat(28 * MS).take(20));
    arrivals.extend(std::iter::repeat(40 * MS).take(10));
    let schedule = FaultSchedule {
        events: vec![FaultEvent {
            at_ns: 28 * MS + MS / 2,
            kind: FaultKind::Crash { backend: 0, down_ns: u64::MAX / 4 },
        }],
    };

    let mut cfg = FleetConfig::new(model, hw);
    cfg.rps = 1000.0;
    cfg.slo_ms = 500.0;
    cfg.seed = 6;
    cfg.n_requests = arrivals.len();
    cfg.faults = Some(FaultPolicy::Schedule(schedule.clone()));

    let r = serve_fleet_stream(&cfg, &fleet, &arrivals).unwrap();
    check_fault_invariants(&r, &cfg, arrivals.len(), "total-outage");
    let a = &r.admission;
    // the 20-burst leaves well over 4 riders queued/in-flight at the
    // crash, and all 10 post-crash arrivals face a total outage
    assert!(a.shed_fault >= 10, "outage arrivals must shed as fault: {a:?}");
    assert!(a.requeued >= 4, "the crash must orphan the queued burst: {a:?}");
    assert_eq!(a.retried, 0, "no survivors — nothing can be re-admitted");

    // with a zero retry budget the same orphans are attributed to
    // retry-exhaustion instead of survivor-less re-admission
    cfg.max_retries = 0;
    let r0 = serve_fleet_stream(&cfg, &fleet, &arrivals).unwrap();
    check_fault_invariants(&r0, &cfg, arrivals.len(), "total-outage-retry0");
    assert!(r0.admission.shed_retry >= 4, "orphans must exhaust a zero retry budget");
    assert_eq!(
        r0.admission.requeued, r.admission.requeued,
        "the retry budget changes attribution, not what the crash orphans"
    );
}

/// Seeded random fault schedules (the `--mtbf-s/--mttr-s` path): the
/// invariants hold across seeds, and each run reproduces byte-for-byte.
#[test]
fn random_fault_schedules_conserve_across_seeds() {
    let model = ModelConfig::bert_base();
    let hw = HardwareConfig::vck5000();
    let fleet = compact_fleet(&model, &hw, 3);

    let mut any_fault_applied = false;
    for seed in [1u64, 2, 3, 4] {
        let mut cfg = FleetConfig::new(model.clone(), hw.clone());
        cfg.rps = 2500.0;
        cfg.slo_ms = 90.0;
        cfg.n_requests = 400;
        cfg.seed = seed;
        // the arrival span is ~0.16 virtual seconds: a 40 ms MTBF lands a
        // handful of faults inside it, 8 ms MTTR keeps windows survivable
        cfg.faults = Some(FaultPolicy::Random { mtbf_s: 0.04, mttr_s: 0.008 });
        let arrivals = cat::serve::TrafficGen::poisson(seed, cfg.rps, cfg.n_requests);
        let r = serve_fleet_stream(&cfg, &fleet, &arrivals).unwrap();
        check_fault_invariants(&r, &cfg, cfg.n_requests, &format!("random-{seed}"));
        let f = r.faults.as_ref().unwrap();
        any_fault_applied |= f.timeline.iter().any(|(_, applied)| *applied);

        let again = serve_fleet_stream(&cfg, &fleet, &arrivals).unwrap();
        assert_eq!(
            r.to_json().to_string(),
            again.to_json().to_string(),
            "random fault run must be deterministic for seed {seed}"
        );
    }
    assert!(any_fault_applied, "no seed ever injected a fault — the test is vacuous");

    // different seeds draw different schedules (via seed ^ 0xFA17)
    let a = FaultSchedule::random(1 ^ 0xFA17, 0.04, 0.008, 3, 160_000_000);
    let b = FaultSchedule::random(2 ^ 0xFA17, 0.04, 0.008, 3, 160_000_000);
    assert_ne!(a, b, "fault schedules must vary with the seed");
}

/// Stalls and slowdowns: deadline-violating work is orphaned (stall) or
/// re-priced at admission (slowdown) — completed requests never miss.
#[test]
fn stalls_and_slowdowns_never_serve_late() {
    let model = ModelConfig::bert_base();
    let hw = HardwareConfig::vck5000();
    let fleet = compact_fleet(&model, &hw, 3);
    assert!(fleet.len() >= 2);

    let mut arrivals: Vec<u64> = (0..60).map(|i| i * MS).collect();
    arrivals.extend(std::iter::repeat(20 * MS).take(16));
    arrivals.sort_unstable();
    let mut cfg = FleetConfig::new(model, hw);
    cfg.rps = 1000.0;
    cfg.slo_ms = 60.0;
    cfg.seed = 7;
    cfg.n_requests = arrivals.len();
    cfg.faults = Some(FaultPolicy::Schedule(FaultSchedule {
        events: vec![
            FaultEvent {
                at_ns: 21 * MS,
                kind: FaultKind::Stall { backend: 0, down_ns: 25 * MS },
            },
            FaultEvent {
                at_ns: 35 * MS,
                kind: FaultKind::Slowdown { backend: 1, down_ns: 20 * MS, factor: 1.8 },
            },
        ],
    }));
    let r = serve_fleet_stream(&cfg, &fleet, &arrivals).unwrap();
    check_fault_invariants(&r, &cfg, arrivals.len(), "stall-slowdown");
    let f = r.faults.as_ref().unwrap();
    assert_eq!(f.timeline.len(), 2);
    assert!(f.timeline.iter().all(|(_, applied)| *applied));
    assert_eq!(f.backends[0].downs, 1, "the stall is a down window");
    assert_eq!(f.backends[1].downs, 0, "a slowdown keeps the backend up");
}

/// Graceful degradation on a partitioned fleet: when a co-resident
/// member dies, the shared DRAM/PCIe pools are re-negotiated over the
/// survivors — freed bandwidth can only RELAX their contention stretch.
#[test]
fn partitioned_crash_relaxes_survivor_link_stretch() {
    let model = ModelConfig::bert_base();
    let hw = HardwareConfig::vck5000();
    // mirror the hotpath bench's contended configuration exactly: the
    // compact exhaustive space, a 2-member co-resident partition, and
    // pools tight enough that the members are throttled pre-crash
    let mut ecfg = ExploreConfig::new(model.clone(), hw.clone());
    ecfg.sample_budget = None;
    ecfg.space = SpaceSpec::compact_9pt();
    let explored = explore(&ecfg).unwrap();
    let tight = SharedLinkModel { dram_gbps: 30.0, pcie_gbps: 8.0 };
    let fleet =
        Fleet::select_partitioned(&model, &hw, &explored, 2, 8, Some(50.0), Some(&tight)).unwrap();

    let mut cfg = FleetConfig::new(model, hw);
    cfg.rps = 2000.0;
    cfg.slo_ms = 50.0;
    cfg.n_requests = 300;
    cfg.seed = 11;
    cfg.faults = Some(FaultPolicy::Schedule(FaultSchedule {
        events: vec![FaultEvent {
            at_ns: 50 * MS,
            kind: FaultKind::Crash { backend: 0, down_ns: u64::MAX / 4 },
        }],
    }));
    let arrivals = cat::serve::TrafficGen::poisson(cfg.seed, cfg.rps, cfg.n_requests);

    let r = serve_fleet_stream(&cfg, &fleet, &arrivals).unwrap();
    check_fault_invariants(&r, &cfg, cfg.n_requests, "part-crash");
    assert!(r.to_json().to_string().contains("\"schema\":\"cat-serve-v4\""));
    let board = r.board.as_ref().expect("partitioned run carries the board ledger");
    let ledger = board.links.as_ref().expect("link model enabled");
    assert!(r.n_backends >= 2, "need co-resident survivors, got {}", r.n_backends);
    assert!(ledger.throttled(), "pools must be oversubscribed pre-crash for a meaningful test");

    let f = r.faults.as_ref().unwrap();
    assert_eq!(f.renegotiations.len(), 1, "one crash, one renegotiation");
    let (at_ns, stretches) = &f.renegotiations[0];
    assert_eq!(*at_ns, 50 * MS);
    assert!(stretches[0].is_none(), "the dead member holds no grant");
    let mut any_relaxed = false;
    for (i, s) in stretches.iter().enumerate().skip(1) {
        let pre = ledger.members[i].stretch;
        let post = s.expect("survivors keep a grant");
        assert!(
            post <= pre + 1e-9,
            "survivor {i} stretch must relax after the crash: {post} > {pre}"
        );
        any_relaxed |= post < pre - 1e-9;
    }
    assert!(
        any_relaxed,
        "freeing an oversubscribed member's demand must strictly relax some survivor"
    );
}
