//! Property tests for the whole fleet-serving path (`cat serve --rps`):
//! for randomized seeded arrival patterns,
//!
//! * **conservation** — every submitted request is answered exactly once
//!   or counted shed: no loss, no duplication, and the admission stats
//!   account for every id;
//! * **service lower bound** — a request's latency is at least the
//!   simulated service time of the batch it rode in (it cannot finish
//!   before its own batch does);
//! * **SLO compliance** — whenever the shed rate is 0, fleet p99 ≤ SLO;
//!   stronger, *every admitted* request meets the SLO even under
//!   overload, because admission bounds completion before accepting;
//! * **determinism** — a fixed `--seed` reproduces the report (JSON)
//!   byte for byte.
//!
//! Scenarios include an overload case where load-shedding engages and
//! one where it must not.

use std::collections::BTreeSet;

use cat::config::{HardwareConfig, ModelConfig};
use cat::dse::{explore, ExploreConfig, SpaceSpec};
use cat::serve::{
    serve_fleet_on, serve_fleet_stream, FaultPolicy, FaultSchedule, Fleet, FleetConfig,
    FleetReport, TrafficGen,
};

/// The shared compact exhaustive space ([`SpaceSpec::compact_9pt`], the
/// same fixture the hotpath bench sweeps): three EDPU sizes × up to
/// three parallel instances — enough for a frontier with genuinely
/// different cost/latency members, cheap enough to sweep in a test.
fn compact_fleet(model: &ModelConfig, hw: &HardwareConfig, max_batch: usize) -> Fleet {
    let mut cfg = ExploreConfig::new(model.clone(), hw.clone());
    cfg.sample_budget = None;
    cfg.space = SpaceSpec::compact_9pt();
    let explored = explore(&cfg).unwrap();
    Fleet::select(model, hw, &explored, 3, max_batch).unwrap()
}

fn check_invariants(r: &FleetReport, cfg: &FleetConfig, label: &str) {
    // -- conservation: completed + shed == submitted, ids unique, no loss
    let a = &r.admission;
    assert_eq!(a.submitted, cfg.n_requests, "{label}: submitted");
    assert!(a.accounted(), "{label}: stats leak requests: {a:?}");
    assert_eq!(r.responses.len(), a.completed, "{label}: responses vs stats");
    assert_eq!(r.shed.len(), a.shed(), "{label}: shed records vs stats");
    let mut seen = BTreeSet::new();
    for resp in &r.responses {
        assert!(seen.insert(resp.id), "{label}: duplicate response id {}", resp.id);
    }
    for s in &r.shed {
        assert!(seen.insert(s.id), "{label}: id {} both served and shed", s.id);
    }
    assert_eq!(seen.len(), cfg.n_requests, "{label}: lost request ids");
    assert_eq!(
        seen.iter().copied().max().map(|m| m as usize + 1).unwrap_or(0),
        cfg.n_requests,
        "{label}: unexpected id range"
    );

    // -- per-backend accounting agrees with the flat response list
    for (i, b) in r.backends.iter().enumerate() {
        assert_eq!(b.id, i, "{label}: backend ids are fleet positions");
        let served = r.responses.iter().filter(|x| x.backend == i).count();
        assert_eq!(b.stats.completed, served, "{label}: backend {i} completed");
        assert_eq!(b.admitted, served, "{label}: backend {i} admitted==served");
    }
    assert_eq!(
        r.backends.iter().map(|b| b.stats.completed).sum::<usize>(),
        r.responses.len(),
        "{label}: per-backend completions don't cover the stream"
    );

    let slo_ns = cfg.slo_ns();
    for resp in &r.responses {
        // -- latency ≥ the simulated service time of its own batch
        assert!(
            resp.latency_ns() >= resp.batch_service_ns,
            "{label}: req {} finished ({} ns) before its batch's service time ({} ns)",
            resp.id,
            resp.latency_ns(),
            resp.batch_service_ns
        );
        // -- batch sizes stay within the serving cap
        assert!(
            (1..=cfg.max_batch).contains(&resp.batch_size),
            "{label}: batch size {} out of range",
            resp.batch_size
        );
        // -- admission-bounded completion: every *admitted* request meets
        //    the SLO, shed or no shed
        assert!(
            resp.latency_ns() <= slo_ns,
            "{label}: req {} violated the SLO: {} ns > {slo_ns} ns",
            resp.id,
            resp.latency_ns()
        );
    }
    assert_eq!(r.slo_violations, 0, "{label}: report disagrees on violations");

    // -- the headline property: zero shed ⇒ fleet p99 within SLO
    if a.shed() == 0 {
        let p99 = r.fleet_stats.percentile(0.99).as_nanos() as u64;
        assert!(p99 <= slo_ns, "{label}: p99 {p99} ns > SLO {slo_ns} ns with no shedding");
    }
}

#[test]
fn randomized_traffic_conserves_requests_and_meets_slo() {
    let model = ModelConfig::bert_base();
    let hw = HardwareConfig::vck5000();
    let fleet = compact_fleet(&model, &hw, 8);
    assert!(fleet.len() >= 2, "need a 2+-backend family, got {}", fleet.len());

    // (label, seed, rps, slo_ms, n_requests, queue_cap)
    let scenarios: &[(&str, u64, f64, f64, usize, usize)] = &[
        ("relaxed", 11, 100.0, 1000.0, 200, 64),
        ("steady", 22, 1200.0, 120.0, 400, 64),
        ("tight-slo", 33, 800.0, 30.0, 300, 64),
        ("overload", 44, 150_000.0, 40.0, 500, 12),
    ];
    let mut any_shed_free = false;
    let mut any_overloaded = false;
    for &(label, seed, rps, slo_ms, n, cap) in scenarios {
        let mut cfg = FleetConfig::new(model.clone(), hw.clone());
        cfg.rps = rps;
        cfg.slo_ms = slo_ms;
        cfg.n_requests = n;
        cfg.queue_cap = cap;
        cfg.seed = seed;
        let r = serve_fleet_on(&cfg, &fleet).unwrap();
        check_invariants(&r, &cfg, label);
        any_shed_free |= r.admission.shed() == 0;
        any_overloaded |= r.admission.shed() > 0;
        if label == "overload" {
            // the overload scenario must actually engage load shedding —
            // and still account for every request (checked above)
            assert!(r.admission.shed() > 0, "overload scenario shed nothing");
        }
        if label == "relaxed" {
            assert_eq!(r.admission.shed(), 0, "relaxed scenario shed requests");
        }
    }
    assert!(any_shed_free && any_overloaded, "scenarios must cover both regimes");
}

#[test]
fn bursty_traffic_with_equal_timestamps_keeps_every_invariant() {
    // bursts deliver `burst` arrivals at the SAME virtual timestamp —
    // the adversarial case for queue caps and flush deadlines; the same
    // conservation/SLO invariants must hold through the identical path
    let model = ModelConfig::bert_base();
    let hw = HardwareConfig::vck5000();
    let fleet = compact_fleet(&model, &hw, 8);
    for (seed, burst) in [(5u64, 8usize), (6, 32)] {
        let mut cfg = FleetConfig::new(model.clone(), hw.clone());
        cfg.rps = 2000.0;
        cfg.slo_ms = 100.0;
        cfg.n_requests = 320;
        cfg.queue_cap = 24;
        cfg.seed = seed;
        let arrivals = TrafficGen::bursty(seed, cfg.rps, cfg.n_requests, burst);
        assert_eq!(arrivals.len(), cfg.n_requests);
        let r = serve_fleet_stream(&cfg, &fleet, &arrivals).unwrap();
        check_invariants(&r, &cfg, &format!("bursty-{burst}"));
    }
}

#[test]
fn fleet_serving_is_deterministic_for_a_fixed_seed() {
    let model = ModelConfig::bert_base();
    let hw = HardwareConfig::vck5000();
    let fleet = compact_fleet(&model, &hw, 4);
    let mut cfg = FleetConfig::new(model, hw);
    cfg.max_batch = 4;
    cfg.rps = 5000.0;
    cfg.slo_ms = 60.0;
    cfg.n_requests = 250;
    cfg.seed = 0xFEED;
    let a = serve_fleet_on(&cfg, &fleet).unwrap();
    let b = serve_fleet_on(&cfg, &fleet).unwrap();
    assert_eq!(a.to_json().to_string(), b.to_json().to_string());
    // a different seed produces a different stream (sanity that the JSON
    // comparison above is not vacuous)
    cfg.seed = 0xBEEF;
    let c = serve_fleet_on(&cfg, &fleet).unwrap();
    assert_ne!(a.to_json().to_string(), c.to_json().to_string());
}

#[test]
fn partitioned_fleet_keeps_every_serving_invariant() {
    // the identical conservation/latency/SLO invariants must hold when
    // the family co-resides on ONE board (`--partition`): routing and
    // admission are unchanged, only the deployments are share-constrained
    let model = ModelConfig::bert_base();
    let hw = HardwareConfig::vck5000();
    let scenarios: &[(&str, u64, f64, f64, usize, usize)] = &[
        ("part-steady", 52, 1500.0, 100.0, 300, 64),
        ("part-overload", 53, 140_000.0, 40.0, 400, 12),
    ];
    for &(label, seed, rps, slo_ms, n, cap) in scenarios {
        let mut cfg = FleetConfig::new(model.clone(), hw.clone());
        cfg.rps = rps;
        cfg.slo_ms = slo_ms;
        cfg.n_requests = n;
        cfg.queue_cap = cap;
        cfg.seed = seed;
        cfg.explore_budget = Some(64);
        cfg.partition = true;
        let r = cat::experiments::serve_fleet(&cfg).unwrap();
        check_invariants(&r, &cfg, label);
        let b = r.board.as_ref().expect("partitioned run carries the board ledger");
        assert!(b.aie_used <= b.aie_total, "{label}: board overcommitted");
        assert!(
            r.to_json().to_string().contains("\"schema\":\"cat-serve-v3\""),
            "{label}: partitioned runs with the (default) link model report schema v3"
        );
    }
}

#[test]
fn fault_free_reports_pin_the_pre_fault_schema() {
    // the fault subsystem must be invisible unless enabled: schema stays
    // v1, no faults block, no fault-era admission keys — and enabling an
    // EMPTY schedule flips to v4 without changing any serving outcome
    let model = ModelConfig::bert_base();
    let hw = HardwareConfig::vck5000();
    let fleet = compact_fleet(&model, &hw, 8);
    let mut cfg = FleetConfig::new(model, hw);
    cfg.rps = 3000.0;
    cfg.slo_ms = 80.0;
    cfg.n_requests = 200;
    cfg.seed = 77;
    let base = serve_fleet_on(&cfg, &fleet).unwrap();
    check_invariants(&base, &cfg, "fault-free");
    let j = base.to_json();
    let js = j.to_string();
    assert!(js.contains("\"schema\":\"cat-serve-v1\""), "fault-free stays v1");
    assert!(!js.contains("\"faults\""), "no faults block without fault injection");
    assert!(!js.contains("shed_fault") && !js.contains("shed_retry"));
    assert!(!js.contains("\"requeued\"") && !js.contains("\"retried\""));
    // the admission block carries exactly the six pre-fault keys
    let adm = j.get("admission").and_then(|a| a.as_obj()).expect("admission block");
    let keys: Vec<&str> = adm.keys().map(String::as_str).collect();
    assert_eq!(
        keys,
        ["admitted", "completed", "shed_capacity", "shed_rate", "shed_slo", "submitted"],
        "fault-free admission keys are pinned"
    );

    // empty schedule: v4 schema + faults block, byte-equal serving outcome
    let mut fcfg = cfg.clone();
    fcfg.faults = Some(FaultPolicy::Schedule(FaultSchedule::default()));
    let v4 = serve_fleet_on(&fcfg, &fleet).unwrap();
    let v4s = v4.to_json().to_string();
    assert!(v4s.contains("\"schema\":\"cat-serve-v4\""), "empty schedule still reports v4");
    assert!(v4s.contains("\"faults\""));
    assert_eq!(v4.responses.len(), base.responses.len());
    for (x, y) in base.responses.iter().zip(&v4.responses) {
        assert_eq!((x.id, x.backend, x.completion_ns), (y.id, y.backend, y.completion_ns));
    }
    let f = v4.faults.as_ref().expect("faults accounting present");
    assert!(f.timeline.is_empty() && f.requeued == 0 && f.retried == 0);
}

#[test]
fn end_to_end_serve_fleet_derives_a_multi_backend_family() {
    // the acceptance path: BERT-Base/VCK5000 through the in-process
    // exploration (sampled), a 2+-backend fleet, deterministic given seed
    let mut cfg = FleetConfig::new(ModelConfig::bert_base(), HardwareConfig::vck5000());
    cfg.rps = 2000.0;
    cfg.slo_ms = 80.0;
    cfg.n_requests = 128;
    cfg.max_backends = 3;
    cfg.explore_budget = Some(64);
    cfg.seed = 9;
    let a = cat::experiments::serve_fleet(&cfg).unwrap();
    assert!(a.n_backends >= 2, "expected a 2+-backend frontier, got {}", a.n_backends);
    check_invariants(&a, &cfg, "e2e");
    let b = cat::experiments::serve_fleet(&cfg).unwrap();
    assert_eq!(a.to_json().to_string(), b.to_json().to_string());
}
