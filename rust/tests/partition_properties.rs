//! Property tests for single-board partitioned fleets
//! (`cat serve --partition`):
//!
//! * **board feasibility** — every selected subset satisfies
//!   `Σ total_cores ≤ Total_AIE` and the Table V PL pool bounds, for
//!   every requested fleet size and across randomized explore samples;
//! * **degeneracy** — a 1-member partition behaves exactly like a PR 3
//!   single-backend fleet of the same design point (identical service
//!   profiles, byte-identical serving outcome);
//! * **degradation** — an infeasible `--backends k` degrades to the
//!   largest feasible subset, with the drop recorded in the board
//!   ledger rather than silently clamped;
//! * **serving invariants** — conservation, per-request service lower
//!   bounds, SLO compliance, and fixed-seed determinism all carry over
//!   to partitioned deployments (schema `cat-serve-v3` with the link
//!   model, `cat-serve-v2` without);
//! * **selection = admission** — the partitioner's SLO gate is the
//!   router's own worst-case service bound, not the explore-time
//!   latency (the PR 4 `proxy_tops` mismatch, pinned in both
//!   directions below).

use cat::config::{HardwareConfig, ModelConfig};
use cat::customize::CustomizeOptions;
use cat::dse::{
    explore, partition_frontier, Candidate, DesignPoint, ExploreConfig, ExploreResult,
    PartitionConfig, SpaceSpec,
};
use cat::sched::MultiEdpuMode;
use cat::serve::{serve_fleet_on, Backend, Fleet, FleetBudget, FleetConfig};
use cat::util::json::Json;

fn compact_explored(model: &ModelConfig, hw: &HardwareConfig) -> ExploreResult {
    let mut cfg = ExploreConfig::new(model.clone(), hw.clone());
    cfg.sample_budget = None;
    cfg.space = SpaceSpec::compact_9pt();
    explore(&cfg).unwrap()
}

/// Board-level feasibility and accounting checks shared by every test.
/// Returns the fleet's own budget so callers can make further claims.
fn check_budget<'a>(fleet: &'a Fleet, hw: &HardwareConfig, label: &str) -> &'a FleetBudget {
    let budget = fleet.budget.as_ref().expect("partitioned fleet carries its budget");
    assert_eq!(budget.aie_total, hw.total_aie, "{label}: board cap");
    assert!(
        budget.aie_used <= budget.aie_total,
        "{label}: {} AIE used exceeds the {}-core board",
        budget.aie_used,
        budget.aie_total
    );
    assert_eq!(
        budget.aie_used,
        fleet.backends.iter().map(|b| b.point.total_cores).sum::<usize>(),
        "{label}: ledger disagrees with the deployed members"
    );
    assert!(budget.pl_used.luts <= budget.pl_total.luts, "{label}: LUT pool");
    assert!(budget.pl_used.ffs <= budget.pl_total.ffs, "{label}: FF pool");
    assert!(budget.pl_used.brams <= budget.pl_total.brams, "{label}: BRAM pool");
    assert!(budget.pl_used.urams <= budget.pl_total.urams, "{label}: URAM pool");
    assert_eq!(fleet.len(), budget.shares.len(), "{label}: one share per member");
    for (b, s) in fleet.backends.iter().zip(&budget.shares) {
        assert_eq!(s.aie, b.point.total_cores, "{label}: share at the designed footprint");
        assert_eq!(s.pl.luts, b.point.pl_luts, "{label}: PL share LUTs");
        assert_eq!(s.pl.ffs, b.point.pl_ffs, "{label}: PL share FFs");
    }
    let st = &budget.stats;
    assert_eq!(st.selected, fleet.len(), "{label}: stats.selected");
    assert!(st.selected <= st.requested.min(st.candidates), "{label}: selection bounds");
    assert_eq!(
        st.subsets_considered,
        st.aie_infeasible + st.pl_infeasible + st.feasible,
        "{label}: subset accounting leaks: {st:?}"
    );
    assert!(st.feasible > 0, "{label}: a selected partition implies a feasible subset");
    budget
}

#[test]
fn every_selected_subset_fits_one_board() {
    let model = ModelConfig::bert_base();
    let hw = HardwareConfig::vck5000();
    let ex = compact_explored(&model, &hw);
    for k in 1..=4 {
        for slo_ms in [None, Some(80.0), Some(5.0)] {
            let fleet =
                Fleet::select_partitioned(&model, &hw, &ex, k, 4, slo_ms, Some(&hw.links()))
                    .unwrap();
            check_budget(&fleet, &hw, &format!("k={k} slo={slo_ms:?}"));
        }
    }
}

#[test]
fn randomized_frontiers_always_partition_within_budget() {
    // sampled explorations of the full joint space give varied frontiers;
    // the partition must fit the board for every one of them
    let model = ModelConfig::bert_base();
    let hw = HardwareConfig::vck5000();
    for seed in [1u64, 7, 42, 0xCA7] {
        let mut cfg = ExploreConfig::new(model.clone(), hw.clone());
        cfg.sample_budget = Some(64);
        cfg.seed = seed;
        cfg.slo_ms = Some(80.0);
        let ex = explore(&cfg).unwrap();
        let fleet =
            Fleet::select_partitioned(&model, &hw, &ex, 3, 4, Some(80.0), Some(&hw.links()))
                .unwrap();
        check_budget(&fleet, &hw, &format!("seed={seed}"));
    }
}

#[test]
fn one_member_partition_degenerates_to_pr3_single_backend() {
    let model = ModelConfig::bert_base();
    let hw = HardwareConfig::vck5000();
    let ex = compact_explored(&model, &hw);
    let max_batch = 6;
    // link model ON: a 1-member partition owns the whole memory path, so
    // its negotiated stretch is exactly 1 and nothing changes
    let part_fleet =
        Fleet::select_partitioned(&model, &hw, &ex, 1, max_batch, Some(80.0), Some(&hw.links()))
            .unwrap();
    assert_eq!(part_fleet.len(), 1);
    check_budget(&part_fleet, &hw, "solo");
    let ledger = part_fleet.budget.as_ref().unwrap().links.as_ref().unwrap();
    assert_eq!(ledger.members[0].stretch, 1.0, "a lone member never throttles");
    assert!(!ledger.throttled());

    // redeploy the SAME design point the PR 3 way (whole board) — the
    // share was allocated at the designed footprint, so the
    // budget-constrained re-derivation must reproduce the identical
    // service profile
    let point = part_fleet.backends[0].point.clone();
    let plain = Backend::deploy(&model, &hw, &point, max_batch).unwrap();
    let shared = &part_fleet.backends[0];
    for k in 1..=max_batch {
        assert_eq!(shared.service_ns(k), plain.service_ns(k), "batch-{k} service time");
        assert_eq!(shared.ops(k), plain.ops(k), "batch-{k} ops");
    }
    assert_eq!(shared.max_service_ns(), plain.max_service_ns());

    // and the full serving run is byte-identical through both fleets
    let mut cfg = FleetConfig::new(model, hw);
    cfg.rps = 1500.0;
    cfg.slo_ms = 80.0;
    cfg.n_requests = 200;
    cfg.max_batch = max_batch;
    cfg.seed = 0xD06;
    let pr3_fleet = Fleet { backends: vec![plain], budget: None, cluster: None };
    let a = serve_fleet_on(&cfg, &part_fleet).unwrap();
    let b = serve_fleet_on(&cfg, &pr3_fleet).unwrap();
    // identical serving behavior; the partitioned report additionally
    // carries the board ledger and the v2 schema tag, and its
    // fleet.gops_per_w charges the shared board's static power over the
    // wall instead of per busy member (documented divergence) — compare
    // every other byte of the two documents
    let strip = |j: Json| match j {
        Json::Obj(mut m) => {
            m.remove("board");
            m.remove("schema");
            if let Some(Json::Obj(fl)) = m.get_mut("fleet") {
                fl.remove("gops_per_w");
            }
            Json::Obj(m)
        }
        other => other,
    };
    assert_eq!(strip(a.to_json()).to_string(), strip(b.to_json()).to_string());
}

#[test]
fn infeasible_backend_request_degrades_and_records_the_drop() {
    let model = ModelConfig::bert_base();
    let hw = HardwareConfig::vck5000();
    let ex = compact_explored(&model, &hw);
    // fixture precondition: even after the fleet's (cores, latency)
    // dedup, the whole frontier's joint footprint exceeds the board, so
    // a request for "all of it" must drop members.  (Bit-exact latency
    // keys mirror the dedup's exact f64 equality.)
    let mut pairs: Vec<(usize, u64)> =
        ex.frontier_points().map(|p| (p.total_cores, p.latency_ms.to_bits())).collect();
    pairs.sort_unstable();
    pairs.dedup();
    let dedup_cores: usize = pairs.iter().map(|&(c, _)| c).sum();
    assert!(pairs.len() >= 2, "fixture drifted: frontier too small");
    assert!(
        dedup_cores > hw.total_aie,
        "fixture drifted: the whole frontier fits one board ({dedup_cores} cores)"
    );

    let links = hw.links();
    let fleet = Fleet::select_partitioned(&model, &hw, &ex, 64, 4, None, Some(&links)).unwrap();
    let st = check_budget(&fleet, &hw, "k=64").stats;
    assert_eq!(st.requested, 64);
    assert!(
        st.selected < st.candidates,
        "the whole frontier ({} candidates) cannot fit one board",
        st.candidates
    );
    // asking for exactly the candidate count records the same drop
    let fleet2 =
        Fleet::select_partitioned(&model, &hw, &ex, st.candidates, 4, None, Some(&links))
            .unwrap();
    let budget2 = check_budget(&fleet2, &hw, "k=candidates");
    assert_eq!(budget2.stats.requested, st.candidates);
    assert!(budget2.stats.selected < budget2.stats.requested, "drop not recorded");
    // degradation is stable: re-requesting the achieved size reproduces it
    let fleet3 = Fleet::select_partitioned(
        &model,
        &hw,
        &ex,
        budget2.stats.selected,
        4,
        None,
        Some(&links),
    )
    .unwrap();
    let budget3 = check_budget(&fleet3, &hw, "k=selected");
    assert_eq!(fleet3.len(), fleet2.len());
    assert_eq!(budget3.aie_used, budget2.aie_used);
}

#[test]
fn partitioned_serving_keeps_conservation_and_slo_invariants() {
    let model = ModelConfig::bert_base();
    let hw = HardwareConfig::vck5000();
    // (label, seed, rps, slo_ms, n, queue_cap, backends)
    let scenarios: &[(&str, u64, f64, f64, usize, usize, usize)] = &[
        ("steady", 21, 1200.0, 120.0, 300, 64, 2),
        ("tight", 33, 900.0, 30.0, 250, 64, 3),
        ("overload", 44, 120_000.0, 40.0, 400, 10, 2),
    ];
    for &(label, seed, rps, slo_ms, n, cap, backends) in scenarios {
        let mut cfg = FleetConfig::new(model.clone(), hw.clone());
        cfg.rps = rps;
        cfg.slo_ms = slo_ms;
        cfg.n_requests = n;
        cfg.queue_cap = cap;
        cfg.max_backends = backends;
        cfg.seed = seed;
        cfg.explore_budget = Some(64);
        cfg.partition = true;
        let r = cat::experiments::serve_fleet(&cfg).unwrap();

        // the board ledger rode along and fits the physical part
        let budget = r.board.as_ref().expect("partitioned run must carry the board ledger");
        assert!(budget.aie_used <= budget.aie_total, "{label}: board overcommitted");
        assert_eq!(budget.stats.requested, backends, "{label}: requested recorded");

        // conservation: every submitted request completes or is shed
        let a = &r.admission;
        assert_eq!(a.submitted, n, "{label}: submitted");
        assert!(a.accounted(), "{label}: stats leak requests: {a:?}");
        assert_eq!(r.responses.len(), a.completed, "{label}: responses vs stats");
        assert_eq!(r.shed.len(), a.shed(), "{label}: shed records vs stats");

        // every admitted request meets the SLO and pays its batch's time
        let slo_ns = cfg.slo_ns();
        for resp in &r.responses {
            assert!(resp.latency_ns() >= resp.batch_service_ns, "{label}: req {}", resp.id);
            assert!(resp.latency_ns() <= slo_ns, "{label}: req {} broke SLO", resp.id);
        }
        assert_eq!(r.slo_violations, 0, "{label}: violations must be zero");

        // determinism: the partitioned path replays byte-identically
        let again = cat::experiments::serve_fleet(&cfg).unwrap();
        assert_eq!(r.to_json().to_string(), again.to_json().to_string(), "{label}");
    }
}

#[test]
fn serve_json_schema_v3_with_links_v2_without_v1_unpartitioned() {
    let model = ModelConfig::bert_base();
    let hw = HardwareConfig::vck5000();
    let mut cfg = FleetConfig::new(model, hw);
    cfg.rps = 1000.0;
    cfg.slo_ms = 80.0;
    cfg.n_requests = 64;
    cfg.explore_budget = Some(64);
    cfg.seed = 7;

    // default: partitioned WITH the link model -> cat-serve-v3 + board.links
    cfg.partition = true;
    assert!(cfg.links.is_some(), "the link model defaults on");
    let v3 = cat::experiments::serve_fleet(&cfg).unwrap().to_json().to_string();
    assert!(v3.contains("\"schema\":\"cat-serve-v3\""), "partitioned schema tag");
    let doc = Json::parse(&v3).unwrap();
    let board = doc.get("board").expect("v3 carries the board block");
    let used = board.get("aie_used").unwrap().as_usize().unwrap();
    let total = board.get("aie_total").unwrap().as_usize().unwrap();
    assert!(used <= total, "board.aie_used must fit board.aie_total");
    assert_eq!(
        board.get("aie_residual").unwrap().as_usize().unwrap(),
        total - used,
        "residual accounting"
    );
    assert!(!board.get("shares").unwrap().as_arr().unwrap().is_empty());
    let links = board.get("links").expect("v3 carries the board.links block");
    for pool in ["dram", "pcie"] {
        let p = links.get(pool).unwrap();
        assert!(p.get("pool_gbps").unwrap().as_f64().unwrap() > 0.0, "{pool} pool");
        assert!(p.get("demanded_gbps").unwrap().as_f64().unwrap() > 0.0, "{pool} demand");
        assert!(
            p.get("granted_gbps").unwrap().as_f64().unwrap()
                <= p.get("pool_gbps").unwrap().as_f64().unwrap() + 1e-9,
            "{pool} grants never exceed the pool"
        );
    }
    let members = links.get("members").unwrap().as_arr().unwrap();
    assert_eq!(members.len(), board.get("shares").unwrap().as_arr().unwrap().len());
    for m in members {
        let stretch = m.get("stretch").unwrap().as_f64().unwrap();
        let throttle = m.get("throttle").unwrap().as_f64().unwrap();
        assert!(stretch >= 1.0);
        assert!((throttle * stretch - 1.0).abs() < 1e-9);
    }

    // link model disabled -> the PR 4 cat-serve-v2 document, no links block
    cfg.links = None;
    let v2 = cat::experiments::serve_fleet(&cfg).unwrap().to_json().to_string();
    assert!(v2.contains("\"schema\":\"cat-serve-v2\""), "v2 retained when links disabled");
    let doc2 = Json::parse(&v2).unwrap();
    assert!(doc2.get("board").is_some(), "v2 keeps the board block");
    assert!(doc2.get("board").unwrap().get("links").is_none(), "v2 has no links block");

    // unpartitioned -> v1, no board block at all
    cfg.partition = false;
    let v1 = cat::experiments::serve_fleet(&cfg).unwrap().to_json().to_string();
    assert!(v1.contains("\"schema\":\"cat-serve-v1\""), "v1 retained without --partition");
    assert!(!v1.contains("\"board\""), "v1 must not grow a board block");
}

/// Synthetic design point with a chosen footprint, throughput, and
/// explore-time latency (the partitioner only reads those fields).
fn synth_point(index: usize, cores: usize, tops: f64, latency_ms: f64) -> DesignPoint {
    DesignPoint {
        cand: Candidate {
            index,
            opts: CustomizeOptions::default(),
            batch: 4,
            edpu_budget: cores,
            n_edpu: 1,
            multi_mode: MultiEdpuMode::Parallel,
        },
        mmsz: 64,
        plio_aie: 8,
        independent_linear: true,
        p_atb: 4,
        mha_mode: cat::arch::ParallelMode::Serial,
        ffn_mode: cat::arch::ParallelMode::Serial,
        cores_per_edpu: cores,
        total_cores: cores,
        pl_luts: 1000,
        pl_ffs: 1000,
        pl_brams: 10,
        pl_urams: 0,
        tops,
        latency_ms,
        gops_per_aie: 1.0,
        power_w: 10.0,
        gops_per_w: 1.0,
    }
}

#[test]
fn regression_selection_gate_is_the_admission_bound_not_explore_latency() {
    // Pins the PR 4 `proxy_tops` mismatch in BOTH directions.  The
    // pre-fix partitioner gated the SLO objective on the explore-time
    // per-item latency at the candidate's own batch; the router admits
    // on the post-deployment worst-case service bound at the serving
    // batch cap.  Construct a frontier where the two disagree both ways:
    //
    //   A: explore latency 1 ms (passes a 50 ms SLO) but a 200 ms
    //      worst-case serving bound — the router would NEVER admit a
    //      request to it;
    //   B: explore latency 90 ms (fails the SLO at explore time — e.g. a
    //      large own-batch) but a 5 ms serving bound — it serves fine.
    //
    // The pre-fix partitioner scores A=9, B=0 and deploys A: a fleet
    // that sheds 100% of traffic.  The fixed partitioner must invert
    // that — this test fails on the pre-fix code by construction.
    let hw = HardwareConfig::vck5000();
    let pts = [
        synth_point(0, 100, 9.0, 1.0),  // A: explore-fast, admission-hopeless
        synth_point(1, 100, 4.0, 90.0), // B: explore-slow, admission-fine
    ];
    let refs: Vec<&DesignPoint> = pts.iter().collect();
    let bounds: Vec<u64> = vec![(200.0 * 1e6) as u64, (5.0 * 1e6) as u64];
    let mut cfg = PartitionConfig::new(1);
    cfg.slo_ms = Some(50.0);
    let part = partition_frontier(&refs, &bounds, &hw, &cfg).unwrap();
    assert_eq!(part.members, vec![1], "must select the member that actually admits traffic");
    assert!(
        (part.objective_tops - 4.0).abs() < 1e-12,
        "objective counts only admission-feasible TOPS, got {}",
        part.objective_tops
    );
}

#[test]
fn partition_objective_matches_deployed_admission_bounds() {
    // End to end on a real frontier: with the link model off (so the
    // deployed profiles are exactly the scoring profiles), the achieved
    // objective must equal the Σ TOPS of deployed members whose
    // worst-case service bound fits the SLO — i.e. selection scored on
    // precisely what the deployment admits with.
    let model = ModelConfig::bert_base();
    let hw = HardwareConfig::vck5000();
    let ex = compact_explored(&model, &hw);
    for slo_ms in [5.0f64, 40.0, 80.0] {
        let fleet =
            Fleet::select_partitioned(&model, &hw, &ex, 2, 4, Some(slo_ms), None).unwrap();
        let budget = fleet.budget.as_ref().unwrap();
        let slo_ns = slo_ms * 1e6;
        let admitted_tops: f64 = fleet
            .backends
            .iter()
            .filter(|b| (b.max_service_ns() as f64) <= slo_ns)
            .map(|b| b.point.tops)
            .sum();
        assert!(
            (budget.objective_tops - admitted_tops).abs() < 1e-6,
            "slo={slo_ms}: objective {} vs deployed admission-feasible TOPS {admitted_tops}",
            budget.objective_tops
        );
    }
}
