//! Integration: customization engine -> EDPU scheduler -> simulator ->
//! metrics, for all three paper accelerators, with calibration checks
//! against the paper's Tables V/VI.

use cat::arch::ParallelMode;
use cat::config::{HardwareConfig, ModelConfig};
use cat::customize::{customize, CustomizeOptions};
use cat::metrics::summarize;
use cat::sched::{run_edpu, run_stage, Stage};

fn rel_err(got: f64, want: f64) -> f64 {
    (got - want).abs() / want
}

#[test]
fn bert_base_full_pipeline_vs_paper() {
    let plan = customize(
        &ModelConfig::bert_base(),
        &HardwareConfig::vck5000(),
        &CustomizeOptions::default(),
    )
    .unwrap();
    let r = run_edpu(&plan, 16).unwrap();
    let s = summarize(&plan, &r);

    // Table VI BERT-Base System row: 0.118 ms, 35.194 TOPS, 99.98 GOPS/AIE,
    // 67.56 W, 520.97 GOPS/W. Simulator tolerance: 40%.
    assert!(rel_err(s.sys_latency_ms, 0.118) < 0.40, "latency {}", s.sys_latency_ms);
    assert!(rel_err(s.sys_tops, 35.194) < 0.40, "tops {}", s.sys_tops);
    assert!(rel_err(s.sys_gops_per_aie, 99.983) < 0.40, "gops/aie {}", s.sys_gops_per_aie);
    assert!(rel_err(s.power_w, 67.555) < 0.40, "power {}", s.power_w);
    assert!(rel_err(s.gops_per_w, 520.968) < 0.50, "gops/w {}", s.gops_per_w);
    // structure exactly as the paper derives
    assert_eq!(plan.cores_deployed(), 352);
    assert!((s.mha_eff_util - 1.0).abs() < 1e-9);
    assert!((s.ffn_eff_util - 256.0 / 352.0).abs() < 1e-9);
}

#[test]
fn vit_base_full_pipeline_vs_paper() {
    let plan = customize(
        &ModelConfig::vit_base(),
        &HardwareConfig::vck5000(),
        &CustomizeOptions::default(),
    )
    .unwrap();
    let r = run_edpu(&plan, 16).unwrap();
    let s = summarize(&plan, &r);
    // Table VI ViT-Base: 0.129 ms, 30.279 TOPS, 492.6 GOPS/W
    assert!(rel_err(s.sys_tops, 30.279) < 0.40, "tops {}", s.sys_tops);
    assert!(rel_err(s.gops_per_w, 492.629) < 0.50, "gops/w {}", s.gops_per_w);
}

#[test]
fn limited_aie_full_pipeline_vs_paper() {
    let plan = customize(
        &ModelConfig::bert_base(),
        &HardwareConfig::vck5000_limited(64),
        &CustomizeOptions::default(),
    )
    .unwrap();
    let r = run_edpu(&plan, 16).unwrap();
    let s = summarize(&plan, &r);
    // Table VI Limited: 0.398 ms, 9.598 TOPS, 149.97 GOPS/AIE, 16.17 W
    assert!(rel_err(s.sys_latency_ms, 0.398) < 0.40, "latency {}", s.sys_latency_ms);
    assert!(rel_err(s.sys_tops, 9.598) < 0.40, "tops {}", s.sys_tops);
    assert!(rel_err(s.sys_gops_per_aie, 149.968) < 0.40, "gops/aie {}", s.sys_gops_per_aie);
    assert!(rel_err(s.power_w, 16.168) < 0.40, "power {}", s.power_w);
    // and the serial design's signature: 100% deployment + utilization
    assert!((s.deployment_rate - 1.0).abs() < 1e-9);
    assert!((s.avg_eff_util - 1.0).abs() < 1e-9);
}

#[test]
fn system_latency_is_sum_of_stages() {
    // Algorithm 1: MHA and FFN execute serially -> EDPU latency adds.
    let plan = customize(
        &ModelConfig::bert_base(),
        &HardwareConfig::vck5000(),
        &CustomizeOptions::default(),
    )
    .unwrap();
    let mha = run_stage(&plan, Stage::Mha, 4).unwrap();
    let ffn = run_stage(&plan, Stage::Ffn, 4).unwrap();
    let edpu = run_edpu(&plan, 4).unwrap();
    let sum = mha.makespan_ns + ffn.makespan_ns;
    assert!((edpu.makespan_ns() - sum).abs() / sum < 1e-9);
}

#[test]
fn system_tops_between_stage_tops() {
    // paper Fig. 5: "the overall system performance is mostly between
    // MHA Stage and FFN Stage"
    let plan = customize(
        &ModelConfig::bert_base(),
        &HardwareConfig::vck5000(),
        &CustomizeOptions::default(),
    )
    .unwrap();
    let r = run_edpu(&plan, 16).unwrap();
    let lo = r.mha.tops().min(r.ffn.tops());
    let hi = r.mha.tops().max(r.ffn.tops());
    assert!(r.tops() >= lo * 0.95 && r.tops() <= hi * 1.05,
            "sys {} not between {} and {}", r.tops(), lo, hi);
}

#[test]
fn serial_hybrid_mode_runs_end_to_end() {
    let opts = CustomizeOptions {
        force_mha_mode: Some(ParallelMode::SerialHybrid),
        ..Default::default()
    };
    let plan = customize(&ModelConfig::bert_base(), &HardwareConfig::vck5000(), &opts).unwrap();
    let r = run_edpu(&plan, 2).unwrap();
    assert!(r.makespan_ns() > 0.0);
    assert!(r.tops() > 1.0);
}

#[test]
fn plan_json_roundtrips_key_fields() {
    let plan = customize(
        &ModelConfig::bert_base(),
        &HardwareConfig::vck5000(),
        &CustomizeOptions::default(),
    )
    .unwrap();
    let j = plan.to_json();
    let text = j.to_string();
    let parsed = cat::util::json::Json::parse(&text).unwrap();
    assert_eq!(parsed.get("mmsz").unwrap().as_usize(), Some(64));
    assert_eq!(parsed.get("p_atb").unwrap().as_usize(), Some(4));
    assert_eq!(
        parsed.path(&["model", "name"]).unwrap().as_str(),
        Some("bert-base")
    );
}

#[test]
fn twelve_layer_model_scales_linearly() {
    // one EDPU iteration = one layer; a 12-layer model is 12 iterations
    let plan = customize(
        &ModelConfig::bert_base(),
        &HardwareConfig::vck5000(),
        &CustomizeOptions::default(),
    )
    .unwrap();
    let r1 = run_edpu(&plan, 1).unwrap();
    let full_model_ns = r1.makespan_ns() * 12.0;
    // BERT-Base full inference: ~12 * 0.118ms at peak (we're at batch 1,
    // so slower) — just check the scaling arithmetic holds
    assert!(full_model_ns > 12.0 * r1.mha.makespan_ns);
}
