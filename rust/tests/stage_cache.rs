//! Integration: the scheduler's stage-simulation cache.
//!
//! `run_edpu` called twice with the same plan/batch must hit the
//! [`StageSimCache`](cat::sched::cache) on the second call — one hit per
//! stage — and the cached report must be indistinguishable from a fresh
//! simulation (the engine is deterministic).  Kept to a single `#[test]`
//! because the hit/miss counters are process-global and the libtest
//! harness runs sibling tests concurrently.

use cat::config::{HardwareConfig, ModelConfig};
use cat::customize::{customize, CustomizeOptions};
use cat::sched::{reset_stage_cache, run_edpu, stage_cache_len, stage_cache_stats};

#[test]
fn run_edpu_memoizes_stage_simulations() {
    if std::env::var("CAT_SIM_CACHE").as_deref() == Ok("0") {
        eprintln!("skipping: CAT_SIM_CACHE=0");
        return;
    }
    let plan = customize(
        &ModelConfig::bert_base(),
        &HardwareConfig::vck5000(),
        &CustomizeOptions::default(),
    )
    .unwrap();

    reset_stage_cache();
    let first = run_edpu(&plan, 4).unwrap();
    let (h0, m0) = stage_cache_stats();
    assert_eq!(h0, 0, "cold cache cannot hit");
    assert_eq!(m0, 2, "MHA + FFN should each miss once");
    assert_eq!(stage_cache_len(), 2);

    let second = run_edpu(&plan, 4).unwrap();
    let (h1, m1) = stage_cache_stats();
    assert_eq!(h1, 2, "repeat run must hit once per stage");
    assert_eq!(m1, 2, "repeat run must not miss");

    // cached report == fresh report, bit for bit where it matters
    assert_eq!(first.makespan_ns(), second.makespan_ns());
    assert_eq!(first.ops(), second.ops());
    assert_eq!(first.mha.sim.events, second.mha.sim.events);
    assert_eq!(first.ffn.sim.bytes_moved, second.ffn.sim.bytes_moved);

    // a different batch is a different key
    let _ = run_edpu(&plan, 8).unwrap();
    let (h2, m2) = stage_cache_stats();
    assert_eq!(h2, 2);
    assert_eq!(m2, 4);

    // a different plan is a different fingerprint, even at equal batch
    let limited = customize(
        &ModelConfig::bert_base(),
        &HardwareConfig::vck5000_limited(64),
        &CustomizeOptions::default(),
    )
    .unwrap();
    let _ = run_edpu(&limited, 4).unwrap();
    let (h3, m3) = stage_cache_stats();
    assert_eq!(h3, 2, "limited-AIE plan must not hit the full plan's entries");
    assert_eq!(m3, 6);
}
