//! End-to-end PJRT runtime tests (need `make artifacts`; each test skips
//! gracefully when the artifact directory is absent).
//!
//! These are the repo's ground-truth numerics checks: the EDPU-tiled
//! (Pallas/AIE-MM-PU schedule) encoder must be bit-identical on the int8
//! path to the fused encoder, and the two-stage decomposition must
//! compose exactly.

use cat::config::ModelConfig;
use cat::coordinator::{synthetic_request, Host, HostConfig};
use cat::runtime::{EncoderWeights, Runtime, Tensor};

fn artifacts() -> Option<&'static str> {
    if std::path::Path::new("artifacts/manifest.json").exists() {
        Some("artifacts")
    } else {
        eprintln!("skipping: run `make artifacts` first");
        None
    }
}

#[test]
fn pallas_tiling_is_arithmetically_invisible() {
    let Some(dir) = artifacts() else { return };
    let model = ModelConfig::bert_base();
    let mut rt = Runtime::open(dir).unwrap();
    let req = synthetic_request(&model, 64, 0, 11);
    let w = EncoderWeights::synthetic(&model, 5);
    let (f_fused, q_fused, s_fused) = rt
        .encoder_layer("encoder_layer_fused", &req.x_q, req.x_scale, &w)
        .unwrap();
    let (f_pal, q_pal, s_pal) = rt
        .encoder_layer("encoder_layer_pallas", &req.x_q, req.x_scale, &w)
        .unwrap();
    assert_eq!(q_fused.as_i8().unwrap(), q_pal.as_i8().unwrap());
    assert!((s_fused - s_pal).abs() < 1e-7);
    let max = f_fused
        .as_f32()
        .unwrap()
        .iter()
        .zip(f_pal.as_f32().unwrap())
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max < 1e-4, "max diff {max}");
}

#[test]
fn stage_decomposition_composes_exactly() {
    let Some(dir) = artifacts() else { return };
    let model = ModelConfig::bert_base();
    let mut rt = Runtime::open(dir).unwrap();
    let req = synthetic_request(&model, 64, 1, 23);
    let w = EncoderWeights::synthetic(&model, 9);

    let (full, _, _) = rt
        .encoder_layer("encoder_layer_pallas", &req.x_q, req.x_scale, &w)
        .unwrap();

    let mut mha_in = vec![req.x_q.clone(), Tensor::scalar_f32(req.x_scale)];
    mha_in.extend([
        w.wqkv.clone(),
        Tensor::scalar_f32(w.sqkv),
        w.bqkv.clone(),
        w.wproj.clone(),
        Tensor::scalar_f32(w.sproj),
        w.bproj.clone(),
        w.ln1_g.clone(),
        w.ln1_b.clone(),
    ]);
    let h1 = rt.run("mha_stage", &mha_in).unwrap().remove(0);
    let mut ffn_in = vec![h1];
    ffn_in.extend([
        w.w1.clone(),
        Tensor::scalar_f32(w.s1),
        w.b1.clone(),
        w.w2.clone(),
        Tensor::scalar_f32(w.s2),
        w.b2.clone(),
        w.ln2_g.clone(),
        w.ln2_b.clone(),
    ]);
    let composed = rt.run("ffn_stage", &ffn_in).unwrap().remove(0);

    let max = full
        .as_f32()
        .unwrap()
        .iter()
        .zip(composed.as_f32().unwrap())
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max < 1e-4, "stage composition diverged: {max}");
}

#[test]
fn pu_artifacts_compute_identity_matmuls() {
    let Some(dir) = artifacts() else { return };
    let mut rt = Runtime::open(dir).unwrap();
    for (name, m, k) in [
        ("mm_pu_large", 256usize, 256usize),
        ("mm_pu_standard", 128, 256),
        ("mm_pu_small", 64, 256),
    ] {
        // a = [I | 0] so a @ b = top k-rows of b's first m columns...
        // simpler: a = identity-padded, b = ramp; check row 0.
        let info = rt.manifest().artifact(name).unwrap().clone();
        let (ma, ka) = (info.params[0].shape[0], info.params[0].shape[1]);
        let (kb, nb) = (info.params[1].shape[0], info.params[1].shape[1]);
        assert_eq!((ma, ka), (m, k));
        let mut a = vec![0i8; ma * ka];
        for i in 0..ma.min(ka) {
            a[i * ka + i] = 1;
        }
        let b: Vec<i8> = (0..kb * nb).map(|i| (i % 125) as i8 - 62).collect();
        let out = rt
            .run(
                name,
                &[
                    Tensor::I8 { data: a, shape: vec![ma, ka] },
                    Tensor::I8 { data: b.clone(), shape: vec![kb, nb] },
                ],
            )
            .unwrap();
        let got = match &out[0] {
            Tensor::I32 { data, .. } => data.clone(),
            other => panic!("{name}: unexpected {other:?}"),
        };
        // with a = I (padded), out rows 0..min(m,k) == b rows 0..min
        for r in 0..ma.min(ka).min(4) {
            for c in 0..nb {
                assert_eq!(got[r * nb + c], b[r * nb + c] as i32, "{name} at ({r},{c})");
            }
        }
    }
}

#[test]
fn pl_operator_artifacts_behave() {
    let Some(dir) = artifacts() else { return };
    let mut rt = Runtime::open(dir).unwrap();
    // softmax rows sum to one
    let x = Tensor::F32 { data: vec![0.5; 256 * 256], shape: vec![256, 256] };
    let out = rt.run("softmax_row", &[x]).unwrap().remove(0);
    let v = out.as_f32().unwrap();
    for r in 0..4 {
        let s: f32 = v[r * 256..(r + 1) * 256].iter().sum();
        assert!((s - 1.0).abs() < 1e-4, "row {r} sums to {s}");
    }
    // layernorm of constant rows is beta
    let x = Tensor::F32 { data: vec![3.0; 256 * 768], shape: vec![256, 768] };
    let g = Tensor::F32 { data: vec![2.0; 768], shape: vec![768] };
    let b = Tensor::F32 { data: vec![0.25; 768], shape: vec![768] };
    let out = rt.run("layernorm", &[x, g, b]).unwrap().remove(0);
    let v = out.as_f32().unwrap();
    assert!(v.iter().take(768).all(|x| (x - 0.25).abs() < 1e-3));
    // gelu(0) == 0
    let x = Tensor::F32 { data: vec![0.0; 256 * 3072], shape: vec![256, 3072] };
    let out = rt.run("gelu", &[x]).unwrap().remove(0);
    assert!(out.as_f32().unwrap().iter().all(|v| v.abs() < 1e-7));
}

#[test]
fn multi_layer_chaining_is_stable() {
    let Some(dir) = artifacts() else { return };
    let model = ModelConfig::bert_base();
    let mut rt = Runtime::open(dir).unwrap();
    let req = synthetic_request(&model, 64, 2, 31);
    let ws: Vec<EncoderWeights> =
        (0..3).map(|i| EncoderWeights::synthetic(&model, 100 + i)).collect();
    let out = rt
        .encoder_forward("encoder_layer_fused", req.x_q, req.x_scale, &ws)
        .unwrap();
    let v = out.as_f32().unwrap();
    assert!(v.iter().all(|x| x.is_finite()));
    // LayerNorm-ed output: per-row mean ~0
    let mean: f32 = v[..768].iter().sum::<f32>() / 768.0;
    assert!(mean.abs() < 1e-2, "mean {mean}");
}

#[test]
fn host_serves_batches_end_to_end() {
    let Some(dir) = artifacts() else { return };
    let model = ModelConfig::bert_base();
    let mut cfg = HostConfig::new(model.clone());
    cfg.artifact_dir = dir.to_string();
    cfg.layers = 1;
    cfg.workers = 2;
    cfg.max_batch = 3;
    let mut host = Host::start(cfg).unwrap();
    let n = 7;
    for i in 0..n {
        host.submit(synthetic_request(&model, 64, i, 900 + i));
    }
    let (responses, stats) = host.drain().unwrap();
    assert_eq!(responses.len(), n as usize);
    assert_eq!(stats.completed, n as usize);
    // ids preserved and sorted
    for (i, r) in responses.iter().enumerate() {
        assert_eq!(r.id, i as u64);
        assert_eq!(r.output.shape(), &[256, 768]);
    }
    // identical inputs must give identical outputs across workers
    let mut cfg2 = HostConfig::new(model.clone());
    cfg2.artifact_dir = dir.to_string();
    cfg2.layers = 1;
    cfg2.workers = 1;
    cfg2.max_batch = 1;
    let mut host2 = Host::start(cfg2).unwrap();
    host2.submit(synthetic_request(&model, 64, 0, 900));
    let (r2, _) = host2.drain().unwrap();
    assert_eq!(
        responses[0].output.as_f32().unwrap(),
        r2[0].output.as_f32().unwrap()
    );
}

#[test]
fn host_reports_worker_errors() {
    let Some(_) = artifacts() else { return };
    let model = ModelConfig::bert_base();
    let mut cfg = HostConfig::new(model.clone());
    cfg.artifact_dir = "nonexistent-dir".into();
    let mut host = Host::start(cfg).unwrap();
    host.submit(synthetic_request(&model, 64, 0, 1));
    assert!(host.drain().is_err());
}
