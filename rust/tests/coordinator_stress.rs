//! Deterministic stress test for the coordinator's condvar/stop-flag
//! path (the PR 1 `drain()` rework had no dedicated test): N producer
//! threads × M pool workers, repeated across shapes, asserting clean
//! shutdown with every job completed and no missed-wakeup hang.
//!
//! The whole scenario runs under a watchdog: if the pool ever hangs
//! (e.g. a stop notify slipping between a worker's flag check and its
//! condvar wait), the test fails in bounded time instead of wedging CI.

use std::sync::mpsc;
use std::sync::Arc;
use std::time::Duration;

use cat::coordinator::{Executor, ExecutorFactory, WorkerPool};

/// Run `f` on its own thread; panic if it does not finish within
/// `timeout` (the hang is reported, the wedged thread is abandoned).
fn with_watchdog<T: Send + 'static>(
    timeout: Duration,
    label: &str,
    f: impl FnOnce() -> T + Send + 'static,
) -> T {
    let (tx, rx) = mpsc::channel();
    std::thread::spawn(move || {
        let _ = tx.send(f());
    });
    match rx.recv_timeout(timeout) {
        Ok(v) => v,
        Err(_) => panic!("{label}: worker pool hung past {timeout:?} (missed wakeup?)"),
    }
}

/// An executor that does a little deterministic spinning so workers
/// genuinely interleave with producers, then echoes the job id.
fn spin_factory(spin: u32) -> ExecutorFactory<u64, u64> {
    Arc::new(move |_wid| {
        Ok(Box::new(move |job: u64| {
            let mut acc = job;
            for _ in 0..spin {
                acc = acc.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            }
            // keep the mix, return the id so completeness is checkable
            std::hint::black_box(acc);
            Ok(vec![job])
        }) as Executor<u64, u64>)
    })
}

#[test]
fn producers_times_workers_shut_down_cleanly() {
    // sweep pool shapes: more producers than workers, more workers than
    // producers, single worker, single producer
    for &(producers, workers) in &[(4usize, 2usize), (2, 6), (8, 1), (1, 4)] {
        let jobs_per_producer = 200u64;
        let total = producers as u64 * jobs_per_producer;
        let mut out = with_watchdog(
            Duration::from_secs(60),
            "producers_times_workers",
            move || {
                let pool = WorkerPool::start("stress", workers, spin_factory(64)).unwrap();
                std::thread::scope(|s| {
                    for p in 0..producers {
                        let pool = &pool;
                        s.spawn(move || {
                            for j in 0..jobs_per_producer {
                                pool.submit(p as u64 * jobs_per_producer + j);
                            }
                        });
                    }
                });
                pool.wait_for_results(total as usize);
                pool.shutdown().unwrap()
            },
        );
        assert_eq!(out.len(), total as usize, "{producers}x{workers}: lost results");
        out.sort_unstable();
        let expect: Vec<u64> = (0..total).collect();
        assert_eq!(out, expect, "{producers}x{workers}: duplicated or mangled jobs");
    }
}

#[test]
fn immediate_shutdown_still_completes_queued_jobs() {
    // stop is honored only once the queue is drained — submit a burst and
    // shut down with no wait at all, repeatedly, to shake the race window
    for trial in 0..20u64 {
        let out = with_watchdog(Duration::from_secs(60), "immediate_shutdown", move || {
            let pool = WorkerPool::start("stress", 3, spin_factory(16)).unwrap();
            for j in 0..100u64 {
                pool.submit(j.wrapping_add(trial));
            }
            pool.shutdown().unwrap()
        });
        assert_eq!(out.len(), 100, "trial {trial}: queued jobs dropped at shutdown");
    }
}

#[test]
fn idle_pool_shutdown_is_prompt_under_contention() {
    // start/stop churn with zero jobs: a missed stop wakeup would park a
    // worker for its full 500 ms backstop (or forever without one) — 40
    // pools × 4 workers inside one 60 s watchdog catches that regression
    with_watchdog(Duration::from_secs(60), "idle_churn", || {
        for _ in 0..40 {
            let pool = WorkerPool::<u64, u64>::start("stress", 4, spin_factory(1)).unwrap();
            assert!(pool.shutdown().unwrap().is_empty());
        }
    });
}

#[test]
fn error_during_stress_surfaces_not_hangs() {
    // one poisoned job among many: the pool must report the error from
    // shutdown (not hang in wait_for_results) and join every worker
    let factory: ExecutorFactory<u64, u64> = Arc::new(|_wid| {
        Ok(Box::new(|job: u64| {
            if job == 137 {
                Err(anyhow::anyhow!("poisoned job {job}"))
            } else {
                Ok(vec![job])
            }
        }) as Executor<u64, u64>)
    });
    let err = with_watchdog(Duration::from_secs(60), "poisoned_job", move || {
        let pool = WorkerPool::start("stress", 2, factory).unwrap();
        for j in 0..300u64 {
            pool.submit(j);
        }
        pool.wait_for_results(300); // must return early on the error
        pool.shutdown().unwrap_err()
    });
    let msg = format!("{err}");
    assert!(msg.contains("worker error") && msg.contains("poisoned job 137"), "{msg}");
}
