//! Property-based tests over the coordinator/customization invariants
//! (in-repo harness `cat::util::check`; proptest is not vendored).

use cat::arch::ParallelMode;
use cat::config::{HardwareConfig, ModelConfig};
use cat::customize::{customize, eq3_mmsz, CustomizeOptions};
use cat::sched::{run_edpu, run_stage, Stage};
use cat::sim::scenario::{EdgeSpec, NodeSpec, PortSpec, PuTiming, Scenario};
use cat::util::check::{close, property};
use cat::util::prng::Prng;
use cat::workload::layer_workload;

fn random_model(rng: &mut Prng) -> ModelConfig {
    let heads = *rng.choose(&[1usize, 2, 4, 8, 12, 16]);
    let head_dim = *rng.choose(&[32usize, 64, 128]);
    let embed = heads * head_dim;
    ModelConfig {
        name: "random".into(),
        heads,
        embed_dim: embed,
        dff: embed * *rng.choose(&[2usize, 4]),
        seq_len: rng.range(16, 1024),
        layers: rng.range(1, 24),
        bits: 8,
    }
}

fn random_hw(rng: &mut Prng) -> HardwareConfig {
    let mut hw = HardwareConfig::vck5000();
    hw.total_aie = *rng.choose(&[4usize, 16, 64, 128, 256, 400, 800]);
    hw.window_bytes = *rng.choose(&[8usize, 16, 32, 64]) * 1024;
    hw
}

#[test]
fn customization_always_feasible() {
    // For ANY model x hardware combination the engine must produce a plan
    // that fits the AIE budget and the padded shapes.
    property("customize/feasible", 200, |rng| {
        let model = random_model(rng);
        let hw = random_hw(rng);
        let plan = customize(&model, &hw, &CustomizeOptions::default())
            .map_err(|e| format!("customize failed: {e}"))?;
        if plan.cores_deployed() > hw.total_aie {
            return Err(format!(
                "deployed {} > budget {}",
                plan.cores_deployed(),
                hw.total_aie
            ));
        }
        if plan.mmsz == 0 || !plan.mmsz.is_power_of_two() {
            return Err(format!("bad mmsz {}", plan.mmsz));
        }
        if plan.p_atb < 1 || plan.p_atb > model.heads {
            return Err(format!("bad p_atb {}", plan.p_atb));
        }
        Ok(())
    });
}

#[test]
fn eq3_respects_window_quarter() {
    property("eq3/window_quarter", 200, |rng| {
        let mut hw = HardwareConfig::vck5000();
        hw.window_bytes = rng.range(64, 1 << 20);
        let bytes = *rng.choose(&[1usize, 2, 4]);
        let mmsz = eq3_mmsz(&hw, bytes);
        if mmsz * mmsz * bytes > hw.window_bytes / 4 && mmsz > 1 {
            return Err(format!(
                "mmsz {mmsz} x {bytes}B exceeds quarter window {}",
                hw.window_bytes / 4
            ));
        }
        // maximality: doubling must overflow
        if (2 * mmsz) * (2 * mmsz) * bytes <= hw.window_bytes / 4 {
            return Err(format!("mmsz {mmsz} not maximal"));
        }
        Ok(())
    });
}

#[test]
fn workload_ops_independent_of_linear_mode() {
    // Merging QKV reorganizes but never *adds* compute; per-head linears
    // additionally pad head_dim up to the tile edge, so they can only be
    // >= the merged count, with equality when head_dim is tile-aligned.
    property("workload/ops_conserved", 100, |rng| {
        let model = random_model(rng);
        let merged = layer_workload(&model, 64, true).total_ops();
        let per_head = layer_workload(&model, 64, false).total_ops();
        if merged > per_head {
            return Err(format!("merged {merged} > per-head {per_head}"));
        }
        if model.head_dim() % 64 == 0 && merged != per_head {
            return Err(format!("aligned dims but {merged} != {per_head}"));
        }
        Ok(())
    });
}

#[test]
fn more_aies_never_slower() {
    // monotonicity: growing the AIE budget must not increase latency
    property("sched/monotone_in_aies", 12, |rng| {
        let model = ModelConfig::bert_base();
        let budgets = [64usize, 128, 400];
        let batch = rng.range(1, 4);
        let mut last = f64::INFINITY;
        for b in budgets {
            let hw = HardwareConfig::vck5000_limited(b);
            let plan = customize(&model, &hw, &CustomizeOptions::default())
                .map_err(|e| e.to_string())?;
            let r = run_edpu(&plan, batch).map_err(|e| e.to_string())?;
            if r.makespan_ns() > last * 1.02 {
                return Err(format!("{b} AIEs slower: {} > {last}", r.makespan_ns()));
            }
            last = r.makespan_ns();
        }
        Ok(())
    });
}

#[test]
fn batch_throughput_monotone() {
    property("sched/batch_monotone", 6, |rng| {
        let model = if rng.bool() {
            ModelConfig::bert_base()
        } else {
            ModelConfig::vit_base()
        };
        let plan = customize(&model, &HardwareConfig::vck5000(), &CustomizeOptions::default())
            .map_err(|e| e.to_string())?;
        let mut last = 0.0;
        for batch in [1usize, 4, 16] {
            let r = run_edpu(&plan, batch).map_err(|e| e.to_string())?;
            let tops = r.tops();
            if tops < last * 0.98 {
                return Err(format!("batch {batch}: {tops} < {last}"));
            }
            last = tops;
        }
        Ok(())
    });
}

#[test]
fn simulator_flow_conservation_random_pipelines() {
    // random 2-4 node chains: the engine must complete them (no deadlock)
    // and makespan must be >= the slowest node's lower bound.
    property("sim/random_chains", 150, |rng| {
        let n_nodes = rng.range(2, 4);
        let mut sc = Scenario::default();
        let mut prev: Option<(usize, usize)> = None; // (node, n_inv)
        for i in 0..n_nodes {
            let n_inv = rng.range(1, 12);
            let t = PuTiming {
                t_send_ns: rng.range(0, 5) as f64,
                t_calc_ns: rng.range(1, 20) as f64,
                t_recv_ns: rng.range(0, 5) as f64,
            };
            let node = sc.add_node(NodeSpec {
                name: format!("n{i}"),
                pus: vec![t; rng.range(1, 3)],
                pipelined: rng.bool(),
                n_inv,
                cores: 1,
                inputs: vec![],
                outputs: vec![],
            });
            if let Some((p, p_inv)) = prev {
                // conserve flow exactly: total = lcm-ish product unit
                let unit = rng.range(1, 64) as u64;
                let total = unit * p_inv as u64 * n_inv as u64;
                let e = sc.add_edge(EdgeSpec::wire(total.max(1)));
                sc.nodes[p].outputs.push(PortSpec {
                    edge: e,
                    bytes_per_inv: total / p_inv as u64,
                });
                sc.nodes[node].inputs.push(PortSpec {
                    edge: e,
                    bytes_per_inv: total / n_inv as u64,
                });
            }
            prev = Some((node, n_inv));
        }
        let r = cat::sim::run(&sc).map_err(|e| format!("sim: {e}"))?;
        // lower bound: any node's serial work / its PU count
        for (i, n) in sc.nodes.iter().enumerate() {
            let beat = n.pus[0].beat_ns(n.pipelined);
            let lower = beat * (n.n_inv as f64 / n.pus.len() as f64).floor();
            if r.makespan_ns + 1e-6 < lower {
                return Err(format!("node {i}: makespan {} < bound {lower}", r.makespan_ns));
            }
        }
        // determinism
        let r2 = cat::sim::run(&sc).map_err(|e| format!("sim: {e}"))?;
        if (r.makespan_ns - r2.makespan_ns).abs() > 1e-12 {
            return Err("non-deterministic".into());
        }
        Ok(())
    });
}

#[test]
fn stage_ops_conserved_across_modes() {
    // the same workload must report the same op count whatever the mode
    property("sched/ops_mode_invariant", 8, |_rng| {
        let model = ModelConfig::bert_base();
        let hw = HardwareConfig::vck5000();
        let mut plans = Vec::new();
        for mode in [ParallelMode::FullyPipelined, ParallelMode::SerialHybrid] {
            let opts = CustomizeOptions {
                force_mha_mode: Some(mode),
                ..Default::default()
            };
            plans.push(customize(&model, &hw, &opts).map_err(|e| e.to_string())?);
        }
        let ops: Vec<u64> = plans
            .iter()
            .map(|p| run_stage(p, Stage::Mha, 2).map(|r| r.ops))
            .collect::<Result<_, _>>()
            .map_err(|e| e.to_string())?;
        if ops.windows(2).any(|w| w[0] != w[1]) {
            return Err(format!("{ops:?}"));
        }
        Ok(())
    });
}

/// Fast-vs-exact parity on randomized edge-less nodes: the isolated-node
/// analytic schedule must reproduce the event-driven reference bit for
/// bit on makespan (both are integer picoseconds underneath) and to
/// float-accumulation noise on busy time.
#[test]
fn sim_isolated_fast_path_matches_exact() {
    property("sim/fast_vs_exact_isolated", 40, |rng| {
        let p = rng.range(1, 5);
        let uniform = rng.bool();
        let mk = |rng: &mut Prng| PuTiming {
            t_send_ns: rng.range(0, 4) as f64 * 0.5,
            t_calc_ns: rng.range(1, 12) as f64,
            t_recv_ns: rng.range(0, 4) as f64 * 0.5,
        };
        let base = mk(rng);
        let pus: Vec<PuTiming> =
            (0..p).map(|_| if uniform { base } else { mk(rng) }).collect();
        let mut sc = Scenario::default();
        sc.add_node(NodeSpec {
            name: "solo".into(),
            pus,
            pipelined: rng.bool(),
            n_inv: rng.range(1, 3000),
            cores: 1,
            inputs: vec![],
            outputs: vec![],
        });
        let fast = cat::sim::run(&sc).map_err(|e| format!("fast: {e}"))?;
        let exact = cat::sim::run_exact(&sc).map_err(|e| format!("exact: {e}"))?;
        if fast.makespan_ns != exact.makespan_ns {
            return Err(format!(
                "makespan {} != exact {}",
                fast.makespan_ns, exact.makespan_ns
            ));
        }
        if fast.fast_forwarded != sc.nodes[0].n_inv as u64 {
            return Err(format!(
                "isolated fast path did not engage: ff {}",
                fast.fast_forwarded
            ));
        }
        let (f, x) = (&fast.nodes[0], &exact.nodes[0]);
        close(f.busy_ns, x.busy_ns, 1e-9)?;
        if f.finish_ns != x.finish_ns {
            return Err(format!("finish {} != {}", f.finish_ns, x.finish_ns));
        }
        if f.first_start_ns != x.first_start_ns {
            return Err(format!("first_start {} != {}", f.first_start_ns, x.first_start_ns));
        }
        Ok(())
    });
}

/// Fast-vs-exact parity on randomized pipelines, including tight buffers
/// (binding backpressure), PL latency, and finite-bandwidth edges — the
/// regimes the steady-state cycle fast-forward must survive.  The
/// acceptance tolerance for the fast path is 0.1% on makespan; the
/// implementation is exact by construction, so we assert far tighter,
/// plus identical `bytes_moved` and per-node invocation counts.
#[test]
fn sim_fast_path_matches_exact_des() {
    property("sim/fast_vs_exact_chains", 18, |rng| {
        let n_nodes = rng.range(2, 4);
        let mut sc = Scenario::default();
        let mut prev: Option<(usize, usize)> = None;
        for i in 0..n_nodes {
            let n_inv = rng.range(300, 1200);
            let t = PuTiming {
                t_send_ns: rng.range(0, 3) as f64,
                t_calc_ns: rng.range(1, 9) as f64,
                t_recv_ns: rng.range(0, 3) as f64,
            };
            let node = sc.add_node(NodeSpec {
                name: format!("n{i}"),
                pus: vec![t; rng.range(1, 3)],
                pipelined: rng.bool(),
                n_inv,
                cores: 1,
                inputs: vec![],
                outputs: vec![],
            });
            if let Some((p, p_inv)) = prev {
                let unit = rng.range(1, 16) as u64;
                let total = unit * p_inv as u64 * n_inv as u64;
                let prod_grain = total / p_inv as u64;
                let cons_grain = total / n_inv as u64;
                // capacity >= prod + cons grains is the deadlock-freedom
                // floor (residue argument in sched::connect); small
                // multiples keep backpressure binding, large ones leave
                // the producer free-running.
                let cap = (prod_grain + cons_grain) * rng.range(1, 5) as u64;
                let edge = if rng.bool() {
                    EdgeSpec::wire(cap)
                } else {
                    EdgeSpec {
                        capacity_bytes: cap,
                        latency_ns: rng.range(0, 20) as f64,
                        bw_bytes_per_ns: if rng.bool() {
                            f64::INFINITY
                        } else {
                            rng.range(1, 50) as f64
                        },
                    }
                };
                let e = sc.add_edge(edge);
                sc.nodes[p].outputs.push(PortSpec { edge: e, bytes_per_inv: prod_grain });
                sc.nodes[node].inputs.push(PortSpec { edge: e, bytes_per_inv: cons_grain });
            }
            prev = Some((node, n_inv));
        }
        let fast = cat::sim::run(&sc).map_err(|e| format!("fast: {e}"))?;
        let exact = cat::sim::run_exact(&sc).map_err(|e| format!("exact: {e}"))?;
        close(fast.makespan_ns, exact.makespan_ns, 1e-9)
            .map_err(|e| format!("makespan: {e}"))?;
        if fast.bytes_moved != exact.bytes_moved {
            return Err(format!(
                "bytes_moved {} != exact {}",
                fast.bytes_moved, exact.bytes_moved
            ));
        }
        for (f, x) in fast.nodes.iter().zip(&exact.nodes) {
            if f.n_inv != x.n_inv {
                return Err(format!("{}: n_inv {} != {}", f.name, f.n_inv, x.n_inv));
            }
            close(f.busy_ns, x.busy_ns, 1e-6).map_err(|e| format!("{} busy: {e}", f.name))?;
            close(f.finish_ns, x.finish_ns, 1e-9)
                .map_err(|e| format!("{} finish: {e}", f.name))?;
        }
        Ok(())
    });
}

/// Fast-vs-exact parity on **throttled board slices**: a partitioned
/// member whose shared DRAM/PCIe grant stretches its stream phases
/// (`hw.mem_throttle < 1`) must simulate identically through the fast
/// engine and the fast-path-free reference — the throttle only rescales
/// PU send/receive times before the scenario is built, so every engine
/// mechanism (isolated-node closed form, cycle fast-forward) must stay
/// exact under it.  Also asserts the contention direction: a throttled
/// slice is never faster than the uncontended plan.
#[test]
fn sim_fast_path_matches_exact_under_throttled_slices() {
    property("sim/fast_vs_exact_throttled", 10, |rng| {
        let model = ModelConfig::bert_base();
        let mut hw = HardwareConfig::vck5000();
        let baseline = {
            let plan = customize(&model, &hw, &CustomizeOptions::default())
                .map_err(|e| e.to_string())?;
            let wl = layer_workload(&plan.model, plan.mmsz, plan.independent_linear);
            let sc = cat::sched::build_mha_pipelined(&plan, &wl, 4, true)
                .map_err(|e| e.to_string())?;
            cat::sim::run(&sc).map_err(|e| format!("baseline: {e}"))?.makespan_ns
        };
        hw.mem_throttle = *rng.choose(&[0.8, 0.5, 0.25, 0.1]);
        let plan =
            customize(&model, &hw, &CustomizeOptions::default()).map_err(|e| e.to_string())?;
        let wl = layer_workload(&plan.model, plan.mmsz, plan.independent_linear);
        let sc = cat::sched::build_mha_pipelined(&plan, &wl, 4, true)
            .map_err(|e| e.to_string())?;
        let fast = cat::sim::run(&sc).map_err(|e| format!("fast: {e}"))?;
        let exact = cat::sim::run_exact(&sc).map_err(|e| format!("exact: {e}"))?;
        close(fast.makespan_ns, exact.makespan_ns, 1e-9)
            .map_err(|e| format!("throttle {}: makespan {e}", hw.mem_throttle))?;
        if fast.bytes_moved != exact.bytes_moved {
            return Err(format!(
                "bytes_moved {} != exact {}",
                fast.bytes_moved, exact.bytes_moved
            ));
        }
        for (f, x) in fast.nodes.iter().zip(&exact.nodes) {
            if f.n_inv != x.n_inv {
                return Err(format!("{}: n_inv {} != {}", f.name, f.n_inv, x.n_inv));
            }
        }
        if fast.makespan_ns < baseline {
            return Err(format!(
                "throttle {} made the slice FASTER: {} < uncontended {baseline}",
                hw.mem_throttle, fast.makespan_ns
            ));
        }
        Ok(())
    });
}

#[test]
fn useful_ops_never_exceed_padded_peak() {
    property("metrics/tops_below_peak", 30, |rng| {
        let model = random_model(rng);
        let hw = HardwareConfig::vck5000();
        let plan = customize(&model, &hw, &CustomizeOptions::default())
            .map_err(|e| e.to_string())?;
        let r = run_edpu(&plan, 4).map_err(|e| e.to_string())?;
        // no accelerator can beat the array's sustained-MM peak
        if r.tops() > hw.peak_tops() {
            return Err(format!("{} TOPS > peak {}", r.tops(), hw.peak_tops()));
        }
        Ok(())
    });
}
