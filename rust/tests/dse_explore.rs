//! Integration tests for the `cat explore` design-space exploration
//! subsystem (ISSUE 2 acceptance criteria):
//!
//! * the BERT-Base/VCK5000 frontier is non-empty, mutually
//!   non-dominated, within board budgets, and contains (or dominates)
//!   the plan the Eq. 3–8 `customize` strategy derives on its own;
//! * a `--max-cores 64` constrained query reproduces the paper's
//!   Limited-AIE scenario (serial mode, 64 cores, ~150 GOPS/AIE);
//! * the seeded sampler is deterministic and its frontier is a subset of
//!   the exhaustive frontier on a small space.

use cat::arch::ParallelMode;
use cat::config::{HardwareConfig, ModelConfig};
use cat::customize::{customize, CustomizeOptions};
use cat::dse::{dominates, explore, ExploreConfig, SpaceSpec};
use cat::sched::MultiEdpuMode;
use cat::util::json::Json;

/// Single-EDPU sweep of the §IV knobs on the full board — small enough
/// to run exhaustively in a test.
fn knob_space() -> SpaceSpec {
    SpaceSpec {
        independent_linear: vec![true, false],
        mha_modes: vec![
            None,
            Some(ParallelMode::FullyPipelined),
            Some(ParallelMode::SerialHybrid),
            Some(ParallelMode::Serial),
        ],
        ffn_modes: vec![None, Some(ParallelMode::Serial)],
        p_atb: vec![1, 2, 4],
        batches: vec![8],
        edpu_budgets: vec![400],
        deployments: vec![(1, MultiEdpuMode::Parallel)],
    }
}

/// Multi-EDPU family space: replicate the compact 64-core serial EDPU.
/// Cores strictly grow and the largest batch share strictly shrinks with
/// `n_edpu`, so every feasible point is Pareto-optimal by construction —
/// which makes the exhaustive frontier the whole set.
fn family_space() -> SpaceSpec {
    SpaceSpec {
        independent_linear: vec![true],
        mha_modes: vec![None],
        ffn_modes: vec![None],
        p_atb: vec![4],
        batches: vec![8],
        edpu_budgets: vec![64],
        deployments: vec![
            (1, MultiEdpuMode::Parallel),
            (2, MultiEdpuMode::Parallel),
            (3, MultiEdpuMode::Parallel),
        ],
    }
}

#[test]
fn bert_frontier_is_sound_and_covers_the_customize_plan() {
    let model = ModelConfig::bert_base();
    let hw = HardwareConfig::vck5000();
    let mut cfg = ExploreConfig::new(model.clone(), hw.clone());
    cfg.sample_budget = None; // exhaustive on the reduced space
    cfg.space = knob_space();
    let res = explore(&cfg).unwrap();

    assert!(!res.frontier.is_empty(), "frontier must be non-empty");
    assert!(res.stats.evaluated > 0);
    assert_eq!(
        res.stats.sampled,
        res.stats.customize_rejected
            + res.stats.aie_rejected
            + res.stats.pl_rejected
            + res.stats.sim_failed
            + res.stats.evaluated,
        "every considered point must be accounted for: {:?}",
        res.stats
    );

    // no frontier point dominates another
    for &i in &res.frontier {
        for &j in &res.frontier {
            if i != j {
                assert!(
                    !dominates(
                        &res.points[i].objectives(),
                        &res.points[j].objectives()
                    ),
                    "frontier points {i} and {j} are not mutually non-dominated"
                );
            }
        }
    }

    // every evaluated point satisfies the board budgets
    for p in &res.points {
        assert!(p.total_cores <= hw.total_aie, "{p:?}");
        assert!(p.pl_luts <= hw.pl_luts, "{p:?}");
        assert!(p.pl_brams <= hw.pl_brams, "{p:?}");
        assert!(p.pl_urams <= hw.pl_urams, "{p:?}");
        assert!(p.tops > 0.0 && p.latency_ms > 0.0 && p.power_w > 0.0);
    }

    // The point whose overrides reproduce the Eq. 3–8 defaults must be in
    // the evaluated set, and the frontier must contain it or a point that
    // dominates it — i.e. systematic exploration never loses to the
    // paper's hand-derived design.
    let reference = customize(&model, &hw, &CustomizeOptions::default()).unwrap();
    let ref_pt = res
        .points
        .iter()
        .find(|p| {
            let o = &p.cand.opts;
            o.independent_linear == Some(true)
                && o.force_mha_mode.is_none()
                && o.force_ffn_mode.is_none()
                && o.p_atb == Some(reference.p_atb)
        })
        .expect("the default-equivalent candidate must survive pruning");
    assert_eq!(ref_pt.cores_per_edpu, reference.cores_deployed());
    assert_eq!(ref_pt.mha_mode, reference.mha.mode);
    assert_eq!(ref_pt.ffn_mode, reference.ffn.mode);
    let ro = ref_pt.objectives();
    assert!(
        res.frontier.iter().any(|&i| {
            let o = res.points[i].objectives();
            o == ro || dominates(&o, &ro)
        }),
        "the Eq. 3-8 plan must be on (or dominated by a point on) the frontier"
    );
}

#[test]
fn explore_json_emits_a_non_empty_budget_clean_frontier() {
    // what `cat explore --model bert-base --hw vck5000 --json` prints
    let mut cfg = ExploreConfig::new(ModelConfig::bert_base(), HardwareConfig::vck5000());
    cfg.sample_budget = None;
    cfg.space = family_space();
    let res = explore(&cfg).unwrap();
    let doc = Json::parse(&res.to_json().to_string()).unwrap();
    assert_eq!(doc.get("schema").unwrap().as_str(), Some("cat-dse-v1"));
    let frontier = doc.get("frontier").unwrap().as_arr().unwrap();
    assert!(!frontier.is_empty());
    for p in frontier {
        let cores = p.get("total_cores").unwrap().as_usize().unwrap();
        assert!(cores <= 400);
        assert!(p.get("tops").unwrap().as_f64().unwrap() > 0.0);
        assert!(p.get("latency_ms").unwrap().as_f64().unwrap() > 0.0);
        assert!(p.get("gops_per_w").unwrap().as_f64().unwrap() > 0.0);
    }
    assert!(doc.get("best_constrained").unwrap().get("tops").is_some());
}

#[test]
fn limited_aie_constrained_query_reproduces_the_paper_scenario() {
    // `cat explore --model bert-base --hw vck5000 --max-cores 64`:
    // the board-level cap must reproduce the Table V/VI/VII Limited-AIE
    // design — Eq. 5 falls back to serial, all 64 cores deploy, and the
    // per-AIE efficiency lands in the paper's ~150 GOPS/AIE band.
    let mut cfg = ExploreConfig::new(ModelConfig::bert_base(), HardwareConfig::vck5000());
    cfg.max_cores = Some(64);
    cfg.sample_budget = None;
    cfg.space = SpaceSpec {
        independent_linear: vec![true],
        mha_modes: vec![None],
        ffn_modes: vec![None],
        p_atb: vec![4],
        batches: vec![1],
        // both budgets clamp to the 64-core board and collapse into one
        // candidate (no duplicate evaluations under --max-cores)
        edpu_budgets: vec![400, 64],
        deployments: vec![(1, MultiEdpuMode::Parallel)],
    };
    let res = explore(&cfg).unwrap();
    assert_eq!(res.space_size, 1);
    assert_eq!(res.points.len(), 1);
    let p = &res.points[0];
    assert_eq!(p.mha_mode, ParallelMode::Serial);
    assert_eq!(p.cores_per_edpu, 64);
    assert_eq!(p.total_cores, 64);
    assert_eq!(p.pl_urams, 0); // Table V row 3: serial design uses no URAM
    // same window the scheduler's Limited-AIE test calibrates against
    assert!(
        p.gops_per_aie > 100.0 && p.gops_per_aie < 170.0,
        "{} GOPS/AIE",
        p.gops_per_aie
    );
    // whole-model per-item latency: 12 layers x the paper's 0.2-0.8 ms
    assert!(
        p.latency_ms > 0.2 * 12.0 && p.latency_ms < 0.8 * 12.0,
        "{} ms",
        p.latency_ms
    );
    assert_eq!(res.frontier, vec![0]);
    assert_eq!(res.best_constrained, Some(0));
}

#[test]
fn experiments_explore_driver_smoke_on_the_default_space() {
    // the `cat explore` CLI path: default joint space, seeded sample
    let res = cat::experiments::explore(
        &ModelConfig::bert_base(),
        &HardwareConfig::vck5000(),
        Some(8),
        5,
        None,
        Some(5.0),
    )
    .unwrap();
    // 2 IL x 4 MHA x 3 FFN x 6 P_ATB x 5 batches x 4 budgets x 7 deployments
    assert_eq!(res.space_size, 2 * 4 * 3 * 6 * 5 * 4 * 7);
    assert!(res.sampled);
    let s = &res.stats;
    assert_eq!(s.sampled, 8);
    assert_eq!(
        s.sampled,
        s.customize_rejected + s.aie_rejected + s.pl_rejected + s.sim_failed + s.evaluated,
        "{s:?}"
    );
    for &i in &res.frontier {
        assert!(i < res.points.len());
    }
    if let Some(i) = res.best_constrained {
        assert!(res.points[i].latency_ms <= 5.0);
    }
}

#[test]
fn sampler_is_deterministic_and_its_frontier_is_a_subset_of_exhaustive() {
    let model = ModelConfig::bert_base();
    let hw = HardwareConfig::vck5000();
    let run = |budget: Option<usize>, seed: u64| {
        let mut cfg = ExploreConfig::new(model.clone(), hw.clone());
        cfg.space = family_space();
        cfg.sample_budget = budget;
        cfg.seed = seed;
        explore(&cfg).unwrap()
    };

    let full = run(None, 1);
    assert!(!full.sampled);
    assert_eq!(full.points.len(), 3, "{:?}", full.stats);
    // the family is a real trade-off: each extra EDPU buys throughput
    // with cores, so nothing dominates anything
    for w in full.points.windows(2) {
        assert!(w[1].tops > w[0].tops, "{} !> {}", w[1].tops, w[0].tops);
        assert!(w[1].total_cores > w[0].total_cores);
    }
    assert_eq!(full.frontier.len(), 3);

    let s1 = run(Some(2), 42);
    let s2 = run(Some(2), 42);
    assert!(s1.sampled);
    assert_eq!(s1.points.len(), 2);
    // deterministic: same seed, same sample, bit-identical evaluation
    assert_eq!(s1.points.len(), s2.points.len());
    for (a, b) in s1.points.iter().zip(&s2.points) {
        assert_eq!(a.cand.index, b.cand.index);
        assert_eq!(a.objectives(), b.objectives());
    }
    assert_eq!(s1.frontier, s2.frontier);
    assert_eq!(s1.dominated, s2.dominated);

    // the sampled frontier is a subset of the exhaustive frontier
    let full_ids: Vec<usize> = full
        .frontier
        .iter()
        .map(|&i| full.points[i].cand.index)
        .collect();
    for &i in &s1.frontier {
        assert!(
            full_ids.contains(&s1.points[i].cand.index),
            "sampled frontier point {} is not on the exhaustive frontier",
            s1.points[i].cand.index
        );
    }
}
