//! Equivalence oracle for the indexed admission plane.
//!
//! The serving loop routes through [`AdmissionIndex`] (event-maintained
//! cached bounds, cheapest-first probe order); `router::route` is
//! retained as the linear-scan reference.  Two layers of proof here:
//!
//! * **randomized event scripts** — a seeded generator drives an
//!   [`AdmissionIndex`] and a plain mirror state through thousands of
//!   admission/dispatch/retire/crash/stall/slowdown/recovery/redeploy
//!   events, probing both routers after every step (including repeated
//!   probes at one virtual timestamp, the burst fast path) and asserting
//!   identical decisions, bounds, scan counts, and shed reasons;
//! * **whole-loop replays** — faulted, partitioned, and cluster serve
//!   runs per seed.  Under `cargo test` (debug assertions on) the loop
//!   itself cross-checks EVERY admission against the oracle and every
//!   flush-deadline read against the batcher clock, so these runs are
//!   per-arrival equivalence proofs; the tests additionally pin byte
//!   determinism of the run JSON and the admission invariants, so the
//!   indexed plane provably changes no observable output.

use std::collections::BTreeSet;

use cat::config::{HardwareConfig, ModelConfig};
use cat::serve::{
    route, serve_fleet, AdmissionIndex, BackendLoad, FaultPolicy, FleetConfig, FleetReport,
    ShedReason,
};

const MS: u64 = 1_000_000;

/// Tiny deterministic generator (xorshift64*) — no external deps, fixed
/// streams per seed.
struct Rng(u64);

impl Rng {
    fn new(seed: u64) -> Rng {
        Rng(seed | 1)
    }

    fn next_u64(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.0 = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    fn below(&mut self, n: u64) -> u64 {
        self.next_u64() % n
    }
}

/// Plain mirror of one backend's admission state — the "recompute
/// everything" representation the oracle snapshots from.
#[derive(Clone)]
struct Mirror {
    busy: u64,
    flush: Option<u64>,
    in_flight: usize,
    up: bool,
    base_service: u64,
    slow_until: u64,
    slow_factor: f64,
}

fn snapshot(mirrors: &[Mirror], now: u64, wait: u64) -> Vec<BackendLoad> {
    mirrors
        .iter()
        .map(|m| BackendLoad {
            busy_until_ns: m.busy,
            pending: 0,
            flush_deadline_ns: m.flush.unwrap_or_else(|| now.saturating_add(wait)),
            in_flight: m.in_flight,
            up: m.up,
            max_service_ns: if now < m.slow_until {
                (m.base_service as f64 * m.slow_factor).ceil() as u64
            } else {
                m.base_service
            },
        })
        .collect()
}

fn assert_agree(
    ix: &mut AdmissionIndex,
    mirrors: &[Mirror],
    now: u64,
    deadline: u64,
    cap: usize,
    wait: u64,
    label: &str,
) {
    let loads = snapshot(mirrors, now, wait);
    let oracle = route(&loads, now, deadline, cap);
    let indexed = ix.route(now, deadline, cap);
    match (oracle, indexed) {
        (Ok(o), Ok(i)) => assert_eq!(
            (o.backend, o.completion_bound_ns, o.scanned),
            (i.backend, i.completion_bound_ns, i.scanned),
            "{label}: decision diverged at now={now} deadline={deadline}"
        ),
        (Err(o), Err(i)) => {
            assert_eq!(o, i, "{label}: shed reason diverged at now={now} deadline={deadline}")
        }
        (o, i) => panic!("{label}: oracle {o:?} vs indexed {i:?} at now={now}"),
    }
}

/// Fire every pending flush whose deadline passed on an up backend —
/// the serving loop's pump guarantee that routing never sees a stale
/// forming batch.  Down backends keep theirs (deferral to recovery).
fn pump(ix: &mut AdmissionIndex, mirrors: &mut [Mirror], now: u64, rng: &mut Rng) {
    for (b, m) in mirrors.iter_mut().enumerate() {
        if m.up {
            if let Some(f) = m.flush {
                if f < now {
                    let service = 1 + rng.below(3 * MS);
                    m.busy = m.busy.max(f).saturating_add(service);
                    m.flush = None;
                    ix.set_busy_until(b, m.busy);
                    ix.set_flush_deadline(b, None);
                }
            }
        }
    }
}

#[test]
fn randomized_event_scripts_agree_with_the_linear_scan_oracle() {
    for seed in [3, 11, 0xFEED] {
        let mut rng = Rng::new(seed);
        let n = 2 + rng.below(7) as usize; // 2..=8 backends
        let wait = (1 + rng.below(10)) * MS / 10;
        let services: Vec<u64> = (0..n).map(|_| (5 + rng.below(40)) * MS / 10).collect();
        let mut ix = AdmissionIndex::new(&services, wait);
        let mut mirrors: Vec<Mirror> = services
            .iter()
            .map(|&s| Mirror {
                busy: 0,
                flush: None,
                in_flight: 0,
                up: true,
                base_service: s,
                slow_until: 0,
                slow_factor: 1.0,
            })
            .collect();
        let cap = 2 + rng.below(6) as usize;
        let mut now = 0u64;
        for step in 0..600 {
            // ~1 step in 4 keeps the timestamp (same-burst fast path)
            if rng.below(4) != 0 {
                now += rng.below(2 * wait + 1);
            }
            pump(&mut ix, &mut mirrors, now, &mut rng);
            let b = rng.below(n as u64) as usize;
            let m = &mut mirrors[b];
            match rng.below(9) {
                0 => {
                    // admission: queue room + (maybe) opening a batch
                    m.in_flight += 1;
                    ix.note_admitted(b);
                    if m.up && m.flush.is_none() {
                        m.flush = Some(now.saturating_add(wait));
                        ix.set_flush_deadline(b, Some(now.saturating_add(wait)));
                    }
                }
                1 => {
                    // dispatch: busy moves, forming batch clears
                    let service = 1 + rng.below(4 * MS);
                    m.busy = m.busy.max(now).saturating_add(service);
                    m.flush = None;
                    ix.set_busy_until(b, m.busy);
                    ix.set_flush_deadline(b, None);
                }
                2 => {
                    // retirement frees room without touching the bound
                    if m.in_flight > 0 {
                        let k = 1 + rng.below(m.in_flight as u64) as usize;
                        m.in_flight -= k;
                        ix.note_retired(b, k);
                    }
                }
                3 => {
                    // crash: lose everything, leave the rotation
                    let orphans = m.in_flight;
                    m.in_flight = 0;
                    m.busy = now;
                    m.flush = None;
                    m.slow_until = 0;
                    m.slow_factor = 1.0;
                    m.up = false;
                    ix.note_orphaned(b, orphans);
                    ix.set_busy_until(b, now);
                    ix.set_flush_deadline(b, None);
                    ix.clear_slowdown(b);
                    ix.set_down(b);
                }
                4 => {
                    // stall: horizon shifts, forming batch freezes
                    if m.busy > now {
                        m.busy = m.busy.saturating_add(rng.below(5 * MS));
                        ix.set_busy_until(b, m.busy);
                    }
                    m.up = false;
                    ix.set_down(b);
                }
                5 => {
                    // recovery: rejoin at the old position; a frozen
                    // batch whose deadline passed flushes AT recovery
                    if !m.up {
                        m.up = true;
                        ix.set_up(b);
                        if m.flush.is_some_and(|f| f < now) {
                            let service = 1 + rng.below(3 * MS);
                            m.busy = m.busy.max(now).saturating_add(service);
                            m.flush = None;
                            ix.set_busy_until(b, m.busy);
                            ix.set_flush_deadline(b, None);
                        }
                    }
                }
                6 => {
                    // slowdown window (merged, harsher factor wins)
                    let end = now + rng.below(20 * MS);
                    let factor = 1.0 + rng.below(30) as f64 / 10.0;
                    if now < m.slow_until {
                        m.slow_factor = m.slow_factor.max(factor);
                        m.slow_until = m.slow_until.max(end);
                    } else {
                        m.slow_factor = factor;
                        m.slow_until = end;
                    }
                    ix.set_slowdown(b, m.slow_until, m.slow_factor);
                }
                7 => {
                    // renegotiation redeploy repriced the worst case
                    m.base_service = (5 + rng.below(40)) * MS / 10;
                    ix.set_max_service(b, m.base_service);
                }
                _ => {} // quiet step: probe-only
            }
            // getter mirrors stay exact
            assert_eq!(ix.in_flight(b), mirrors[b].in_flight, "in_flight mirror (seed {seed})");
            assert_eq!(ix.is_up(b), mirrors[b].up, "up mirror (seed {seed})");
            assert_eq!(ix.busy_until_ns(b), mirrors[b].busy, "busy mirror (seed {seed})");
            assert_eq!(ix.flush_deadline(b), mirrors[b].flush, "flush mirror (seed {seed})");
            // probe repeatedly at the same instant: bursts must reuse the
            // cached bounds and still agree with the recomputing oracle
            let label = format!("seed {seed} step {step}");
            for _ in 0..3 {
                let deadline = now + rng.below(40 * MS);
                assert_agree(&mut ix, &mirrors, now, deadline, cap, wait, &label);
            }
        }
    }
}

/// Conservation + SLO + unique-id accounting shared by the replay tests
/// (the same contract the serve/fault/cluster property suites pin).
fn check_replay(r: &FleetReport, cfg: &FleetConfig, label: &str) {
    let a = &r.admission;
    assert_eq!(a.submitted, cfg.n_requests, "{label}: submitted");
    assert!(a.accounted(), "{label}: stats leak requests: {a:?}");
    let mut seen = BTreeSet::new();
    for resp in &r.responses {
        assert!(seen.insert(resp.id), "{label}: duplicate response id {}", resp.id);
    }
    for s in &r.shed {
        assert!(seen.insert(s.id), "{label}: id {} both served and shed", s.id);
    }
    assert_eq!(seen.len(), cfg.n_requests, "{label}: lost request ids");
    let slo_ns = cfg.slo_ns();
    for resp in &r.responses {
        assert!(resp.latency_ns() <= slo_ns, "{label}: req {} violated the SLO", resp.id);
    }
}

/// Run one config twice; in debug builds every arrival inside is an
/// indexed-vs-oracle assertion, and the two runs must serialize byte
/// for byte.
fn replay(mut cfg: FleetConfig, seed: u64, label: &str) {
    cfg.seed = seed;
    let r = serve_fleet(&cfg).unwrap();
    check_replay(&r, &cfg, label);
    let again = serve_fleet(&cfg).unwrap();
    assert_eq!(
        r.to_json().to_string(),
        again.to_json().to_string(),
        "{label}: serve JSON must be byte-identical per seed"
    );
}

fn base_cfg() -> FleetConfig {
    let mut cfg = FleetConfig::new(ModelConfig::bert_base(), HardwareConfig::vck5000());
    cfg.rps = 1200.0;
    cfg.slo_ms = 80.0;
    cfg.n_requests = 160;
    cfg.max_backends = 3;
    cfg.explore_budget = Some(64);
    cfg
}

#[test]
fn faulted_replays_route_identically_per_seed() {
    for seed in [1, 42] {
        let mut cfg = base_cfg();
        // random crash/stall/slowdown pressure straddling the run
        cfg.faults = Some(FaultPolicy::Random { mtbf_s: 0.04, mttr_s: 0.02 });
        replay(cfg, seed, "faulted");
    }
}

#[test]
fn partitioned_replays_route_identically_per_seed() {
    for seed in [2, 99] {
        let mut cfg = base_cfg();
        cfg.partition = true;
        replay(cfg, seed, "partitioned");
    }
    // and partitioned + faults: renegotiation redeploys hit the index
    let mut cfg = base_cfg();
    cfg.partition = true;
    cfg.faults = Some(FaultPolicy::Random { mtbf_s: 0.04, mttr_s: 0.02 });
    replay(cfg, 7, "partitioned+faults");
}

#[test]
fn cluster_replays_route_identically_per_seed() {
    use cat::cluster::ClusterSpec;
    use cat::util::json::Json;
    let src = r#"{"boards": ["vck5000", "vck5000-limited-64"]}"#;
    let spec = ClusterSpec::from_json(&Json::parse(src).unwrap()).unwrap();
    for seed in [5, 23] {
        let mut cfg = FleetConfig::new(ModelConfig::bert_base(), spec.boards[0].clone());
        cfg.rps = 1000.0;
        cfg.slo_ms = 80.0;
        cfg.n_requests = 160;
        cfg.max_backends = 3;
        cfg.explore_budget = Some(64);
        cfg.cluster = Some(spec.clone());
        replay(cfg, seed, "cluster");
    }
}

/// The indexed path never admits a request the oracle would shed (and
/// vice versa) even at a saturating deadline boundary: sweep deadlines
/// across the admission edge on a half-degraded index.
#[test]
fn deadline_boundary_sweep_agrees() {
    let services = [2 * MS, 3 * MS, 5 * MS];
    let wait = MS / 2;
    let mut ix = AdmissionIndex::new(&services, wait);
    let mut mirrors: Vec<Mirror> = services
        .iter()
        .map(|&s| Mirror {
            busy: 0,
            flush: None,
            in_flight: 0,
            up: true,
            base_service: s,
            slow_until: 0,
            slow_factor: 1.0,
        })
        .collect();
    // degrade: 0 busy deep, 1 slowed, 2 idle
    mirrors[0].busy = 10 * MS;
    ix.set_busy_until(0, 10 * MS);
    mirrors[1].slow_until = 20 * MS;
    mirrors[1].slow_factor = 2.0;
    ix.set_slowdown(1, 20 * MS, 2.0);
    let now = 4 * MS;
    for deadline in (0..30).map(|k| now + k * MS / 2) {
        assert_agree(&mut ix, &mirrors, now, deadline, 4, wait, "boundary sweep");
    }
    assert_eq!(
        ix.route(now, now, 4).unwrap_err(),
        ShedReason::Slo,
        "room exists but nothing fits a zero-slack deadline"
    );
}
