//! Properties of the observability layer (`--trace` / `--metrics`):
//!
//! * **zero perturbation** — attaching a trace sink and a metrics
//!   registry leaves every serve/explore report byte-identical to the
//!   plain entry points, across plain (v1), partitioned (v2/v3), and
//!   faulted (v4) runs;
//! * **reproducibility** — the exported Chrome trace-event document is
//!   byte-identical across runs for a fixed seed (virtual clock, no
//!   wall-time anywhere);
//! * **well-formedness** — the export parses, every event carries
//!   name/ph/pid/tid, per-track timestamps are monotone in file order,
//!   and complete-spans have non-negative durations;
//! * **agreement** — the `cat-obs-v1` counters restate the report's own
//!   admission accounting, and the latency histogram covers exactly the
//!   completed requests.

use std::collections::BTreeMap;

use cat::config::{HardwareConfig, ModelConfig};
use cat::dse::{explore, explore_obs, ExploreConfig, SpaceSpec};
use cat::obs::Obs;
use cat::serve::{
    serve_fleet_on, serve_fleet_on_obs, serve_fleet_stream, serve_fleet_stream_obs, FaultEvent,
    FaultKind, FaultPolicy, FaultSchedule, Fleet, FleetConfig,
};
use cat::util::json::Json;

const MS: u64 = 1_000_000;

/// Same compact exhaustive space as `serve_properties.rs`.
fn compact_fleet(model: &ModelConfig, hw: &HardwareConfig, max_batch: usize) -> Fleet {
    let mut cfg = ExploreConfig::new(model.clone(), hw.clone());
    cfg.sample_budget = None;
    cfg.space = SpaceSpec::compact_9pt();
    let explored = explore(&cfg).unwrap();
    Fleet::select(model, hw, &explored, 3, max_batch).unwrap()
}

fn trace_string(obs: &Obs) -> String {
    obs.trace.as_ref().expect("trace side enabled").to_json().to_string()
}

/// Walk an exported trace document: parse, check the Chrome trace-event
/// shape, and return `(event_count, names)` for content assertions.
fn check_trace_well_formed(doc: &str, label: &str) -> (usize, Vec<String>) {
    let j = Json::parse(doc).unwrap_or_else(|e| panic!("{label}: trace does not parse: {e}"));
    let events = j
        .get("traceEvents")
        .and_then(|e| e.as_arr())
        .unwrap_or_else(|| panic!("{label}: no traceEvents array"));
    let mut last_ts: BTreeMap<(u64, u64), f64> = BTreeMap::new();
    let mut names = Vec::new();
    for (i, ev) in events.iter().enumerate() {
        let name = ev
            .get("name")
            .and_then(|n| n.as_str())
            .unwrap_or_else(|| panic!("{label}: event {i} has no name"));
        names.push(name.to_string());
        let ph = ev
            .get("ph")
            .and_then(|p| p.as_str())
            .unwrap_or_else(|| panic!("{label}: event {i} has no ph"));
        let pid = ev.get("pid").and_then(|p| p.as_u64());
        let tid = ev.get("tid").and_then(|t| t.as_u64());
        assert!(pid.is_some() && tid.is_some(), "{label}: event {i} lacks pid/tid");
        if ph == "M" {
            assert!(ev.get("ts").is_none(), "{label}: metadata event {i} carries a ts");
            continue;
        }
        let ts = ev
            .get("ts")
            .and_then(|t| t.as_f64())
            .unwrap_or_else(|| panic!("{label}: event {i} ({name}) has no numeric ts"));
        let track = (pid.unwrap(), tid.unwrap());
        if let Some(prev) = last_ts.get(&track) {
            assert!(
                ts >= *prev,
                "{label}: track {track:?} goes backwards at event {i} ({name}): {ts} < {prev}"
            );
        }
        last_ts.insert(track, ts);
        if ph == "X" {
            let dur = ev
                .get("dur")
                .and_then(|d| d.as_f64())
                .unwrap_or_else(|| panic!("{label}: X event {i} ({name}) has no dur"));
            assert!(dur >= 0.0, "{label}: negative span duration at event {i}");
        }
    }
    (events.len(), names)
}

#[test]
fn serve_reports_are_byte_identical_with_observability_attached() {
    let model = ModelConfig::bert_base();
    let hw = HardwareConfig::vck5000();
    let fleet = compact_fleet(&model, &hw, 4);
    // (label, seed, rps, slo_ms, n_requests, queue_cap) — the v1
    // determinism scenario plus an overload one where shedding engages
    let scenarios: &[(&str, u64, f64, f64, usize, usize)] = &[
        ("steady", 0xFEED, 5000.0, 60.0, 250, 64),
        ("overload", 44, 150_000.0, 40.0, 300, 12),
    ];
    for &(label, seed, rps, slo_ms, n, cap) in scenarios {
        let mut cfg = FleetConfig::new(model.clone(), hw.clone());
        cfg.max_batch = 4;
        cfg.rps = rps;
        cfg.slo_ms = slo_ms;
        cfg.n_requests = n;
        cfg.queue_cap = cap;
        cfg.seed = seed;
        let plain = serve_fleet_on(&cfg, &fleet).unwrap();
        let mut obs = Obs::new(true, true);
        let traced = serve_fleet_on_obs(&cfg, &fleet, &mut obs).unwrap();
        assert_eq!(
            plain.to_json().to_string(),
            traced.to_json().to_string(),
            "{label}: attaching observability changed the report"
        );
        // trace reproducibility: a second traced run exports byte-equal
        let mut obs2 = Obs::new(true, true);
        serve_fleet_on_obs(&cfg, &fleet, &mut obs2).unwrap();
        assert_eq!(trace_string(&obs), trace_string(&obs2), "{label}: trace not reproducible");
        let (count, names) = check_trace_well_formed(&trace_string(&obs), label);
        assert!(count > 0, "{label}: empty trace");
        for expected in ["submit", "admit", "complete", "dispatch", "batch"] {
            assert!(
                names.iter().any(|n| n == expected),
                "{label}: no '{expected}' event in the trace"
            );
        }
        if label == "overload" {
            assert!(names.iter().any(|n| n == "shed"), "overload trace records no sheds");
        }
    }
}

#[test]
fn metrics_agree_with_the_report() {
    let model = ModelConfig::bert_base();
    let hw = HardwareConfig::vck5000();
    let fleet = compact_fleet(&model, &hw, 4);
    let mut cfg = FleetConfig::new(model, hw);
    cfg.max_batch = 4;
    cfg.rps = 5000.0;
    cfg.slo_ms = 60.0;
    cfg.n_requests = 250;
    cfg.seed = 0xFEED;
    let mut obs = Obs::new(false, true);
    let r = serve_fleet_on_obs(&cfg, &fleet, &mut obs).unwrap();
    assert!(obs.trace.is_none(), "metrics-only run must not allocate a trace");
    let m = obs.metrics.as_ref().unwrap();
    assert_eq!(m.counter("serve.submitted"), r.admission.submitted as u64);
    assert_eq!(m.counter("serve.admitted"), r.admission.admitted as u64);
    assert_eq!(m.counter("serve.completed"), r.admission.completed as u64);
    assert_eq!(m.counter("serve.shed_slo"), r.admission.shed_slo as u64);
    assert_eq!(m.counter("serve.shed_capacity"), r.admission.shed_capacity as u64);
    let lat = m.histogram("serve.latency_ns").expect("latency histogram");
    assert_eq!(lat.count(), r.admission.completed as u64, "one latency sample per completion");
    let depth = m.histogram("serve.queue_depth").expect("queue-depth histogram");
    assert_eq!(depth.count(), r.admission.admitted as u64, "one depth sample per admission");
    // the document carries the schema tag
    assert!(m.to_json().to_string().contains("\"schema\":\"cat-obs-v1\""));
}

#[test]
fn fault_runs_stay_byte_identical_and_faults_land_in_the_trace() {
    let model = ModelConfig::bert_base();
    let hw = HardwareConfig::vck5000();
    let fleet = compact_fleet(&model, &hw, 3);
    assert!(fleet.len() >= 2, "need survivors, got {}", fleet.len());
    let mut cfg = FleetConfig::new(model, hw);
    cfg.rps = 1000.0; // label only — the stream below is explicit
    cfg.slo_ms = 80.0;
    cfg.seed = 5;
    let mut arrivals: Vec<u64> = (0..10).map(|i| i * 3 * MS / 2).collect();
    arrivals.extend(std::iter::repeat(19 * MS).take(20));
    arrivals.extend((0..20).map(|i| (25 + i) * MS));
    arrivals.extend((0..10).map(|i| (60 + i) * MS));
    cfg.n_requests = arrivals.len();
    cfg.faults = Some(FaultPolicy::Schedule(FaultSchedule {
        events: vec![FaultEvent {
            at_ns: 19 * MS + MS / 2,
            kind: FaultKind::Crash { backend: 0, down_ns: 30 * MS },
        }],
    }));

    let plain = serve_fleet_stream(&cfg, &fleet, &arrivals).unwrap();
    let mut obs = Obs::new(true, true);
    let traced = serve_fleet_stream_obs(&cfg, &fleet, &arrivals, Some(&mut obs)).unwrap();
    assert_eq!(
        plain.to_json().to_string(),
        traced.to_json().to_string(),
        "observability changed a faulted (v4) report"
    );
    let doc = trace_string(&obs);
    let (_, names) = check_trace_well_formed(&doc, "faulted");
    for expected in ["crash", "down", "up", "retry"] {
        assert!(names.iter().any(|n| n == expected), "no '{expected}' event in fault trace");
    }
    let m = obs.metrics.as_ref().unwrap();
    assert_eq!(m.counter("serve.faults.crash"), 1);
    // reproducible with faults too
    let mut obs2 = Obs::new(true, false);
    serve_fleet_stream_obs(&cfg, &fleet, &arrivals, Some(&mut obs2)).unwrap();
    assert_eq!(doc, trace_string(&obs2), "fault trace not reproducible");
}

#[test]
fn partitioned_runs_stay_byte_identical_under_observability() {
    // v3 (partition + link model) and v2 (partition, --no-links)
    let model = ModelConfig::bert_base();
    let hw = HardwareConfig::vck5000();
    for (label, links) in [("v3-linked", true), ("v2-no-links", false)] {
        let mut cfg = FleetConfig::new(model.clone(), hw.clone());
        cfg.rps = 1500.0;
        cfg.slo_ms = 100.0;
        cfg.n_requests = 200;
        cfg.seed = 52;
        cfg.explore_budget = Some(64);
        cfg.partition = true;
        if !links {
            cfg.links = None;
        }
        let plain = cat::experiments::serve_fleet(&cfg).unwrap();
        let mut obs = Obs::new(true, true);
        let traced = cat::experiments::serve_fleet_obs(&cfg, &mut obs).unwrap();
        assert_eq!(
            plain.to_json().to_string(),
            traced.to_json().to_string(),
            "{label}: observability changed a partitioned report"
        );
        check_trace_well_formed(&trace_string(&obs), label);
    }
}

#[test]
fn explore_trace_and_metrics_are_reproducible_and_cover_the_space() {
    let mut cfg = ExploreConfig::new(ModelConfig::bert_base(), HardwareConfig::vck5000());
    cfg.sample_budget = None;
    cfg.space = SpaceSpec::compact_9pt();
    let plain = explore(&cfg).unwrap();
    let mut obs = Obs::new(true, true);
    let traced = explore_obs(&cfg, Some(&mut obs)).unwrap();
    assert_eq!(
        plain.to_json().to_string(),
        traced.to_json().to_string(),
        "observability changed the explore result"
    );
    let doc = trace_string(&obs);
    let (count, names) = check_trace_well_formed(&doc, "explore");
    assert!(count > 0, "empty DSE trace");
    assert!(names.iter().any(|n| n == "customize+prune"), "no prune phase span");
    assert!(names.iter().any(|n| n == "pareto+query"), "no pareto phase span");
    let evals = names.iter().filter(|n| n.starts_with("eval#")).count();
    assert_eq!(evals, traced.points.len(), "one evaluate span per surviving point");
    let m = obs.metrics.as_ref().unwrap();
    assert_eq!(m.counter("dse.evaluated"), traced.points.len() as u64);
    let lat = m.histogram("dse.point_latency_ns").expect("point latency histogram");
    assert_eq!(lat.count(), traced.points.len() as u64);
    // byte-reproducible
    let mut obs2 = Obs::new(true, false);
    explore_obs(&cfg, Some(&mut obs2)).unwrap();
    assert_eq!(doc, trace_string(&obs2), "DSE trace not reproducible");
}
