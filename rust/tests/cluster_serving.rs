//! Cluster-serving invariants (`--cluster`, schema `cat-serve-v5`):
//!
//! * **conservation/SLO/determinism on a heterogeneous rack** — a
//!   2-board VCK5000 + Limited-AIE cluster keeps the five-term admission
//!   conservation, serves every completed request inside its SLO, and
//!   reproduces its JSON byte for byte from a fixed seed;
//! * **whole-board crash → survivors absorb** — a scripted `board_crash`
//!   sheds at most the dead board's in-flight share while the surviving
//!   board keeps admitting, and per-board availability records the
//!   outage;
//! * **rack link_degrade → every board rethrottles** — a fault spec
//!   written in rack vocabulary (`nic_scale`/`switch_scale`) shrinks the
//!   cluster NIC pool mid-run; the loop renegotiates once at the event
//!   instant, no member's stretch relaxes, at least one tightens, and
//!   availability stays 1.0 (link faults down nobody);
//! * **1-board cluster ≡ --partition** — a cluster of one board behind
//!   uncontended network pools serves byte-identically to the same
//!   config run with `--partition` (modulo the schema tag and the
//!   cluster/board ledgers themselves).

use std::collections::BTreeSet;

use cat::cluster::{build_fleet, ClusterSpec};
use cat::config::ModelConfig;
use cat::serve::{
    run, serve_fleet, serve_fleet_on, FaultEvent, FaultKind, FaultPolicy, FaultSchedule,
    FleetConfig, FleetReport, Session,
};
use cat::util::json::Json;

const MS: u64 = 1_000_000;

fn spec_of(src: &str) -> ClusterSpec {
    ClusterSpec::from_json(&Json::parse(src).unwrap()).unwrap()
}

fn two_board_cfg() -> FleetConfig {
    let spec = spec_of(r#"{"boards": ["vck5000", "vck5000-limited-64"]}"#);
    let mut cfg = FleetConfig::new(ModelConfig::bert_base(), spec.boards[0].clone());
    cfg.rps = 1000.0;
    cfg.slo_ms = 80.0;
    cfg.n_requests = 160;
    cfg.max_backends = 3;
    cfg.explore_budget = Some(64);
    cfg.seed = 7;
    cfg.cluster = Some(spec);
    cfg
}

/// Five-term conservation + SLO compliance + id accounting, the same
/// contract single-board fault runs honor.
fn check_invariants(r: &FleetReport, cfg: &FleetConfig, label: &str) {
    let a = &r.admission;
    assert_eq!(a.submitted, cfg.n_requests, "{label}: submitted");
    assert!(a.accounted(), "{label}: stats leak requests: {a:?}");
    assert_eq!(
        a.submitted,
        a.completed + a.shed_slo + a.shed_capacity + a.shed_fault + a.shed_retry,
        "{label}: five-term conservation: {a:?}"
    );
    let mut seen = BTreeSet::new();
    for resp in &r.responses {
        assert!(seen.insert(resp.id), "{label}: duplicate response id {}", resp.id);
    }
    for s in &r.shed {
        assert!(seen.insert(s.id), "{label}: id {} both served and shed", s.id);
    }
    assert_eq!(seen.len(), cfg.n_requests, "{label}: lost request ids");
    let slo_ns = cfg.slo_ns();
    for resp in &r.responses {
        assert!(
            resp.latency_ns() <= slo_ns,
            "{label}: req {} violated the SLO: {} ns > {slo_ns} ns",
            resp.id,
            resp.latency_ns()
        );
    }
    assert_eq!(r.slo_violations, 0, "{label}: report disagrees on violations");
}

#[test]
fn heterogeneous_cluster_conserves_meets_slo_and_reproduces() {
    let cfg = two_board_cfg();
    assert_eq!(cfg.schema(), "cat-serve-v5");
    let r = serve_fleet(&cfg).unwrap();
    check_invariants(&r, &cfg, "2-board");
    assert!(r.admission.completed > 0, "a 3-member rack must serve something");

    // the ledger names both SKUs and places every member on exactly one
    let cb = r.cluster.as_ref().expect("cluster runs carry the ledger");
    assert_eq!(cb.boards.len(), 2);
    assert_eq!(r.hw, "vck5000+vck5000-limited-64");
    assert_eq!(cb.members.len(), r.n_backends);
    assert_eq!(cb.boards.iter().map(|b| b.members.len()).sum::<usize>(), r.n_backends);
    let usage = cb.board_usage(&r);
    for (j, u) in usage.iter().enumerate() {
        assert!((0.0..=1.0).contains(&u.utilization), "board {j} utilization");
        assert_eq!(u.availability, 1.0, "board {j}: fault-free run must be fully available");
        assert!(u.energy_j > 0.0, "board {j} burns at least its static floor");
    }
    assert_eq!(usage.iter().map(|u| u.admitted).sum::<usize>(), r.admission.completed);

    // schema gate + byte determinism, through both the consolidated
    // entry point and the wrapper it feeds
    let json = r.to_json().to_string();
    assert!(json.contains(r#""schema":"cat-serve-v5""#), "schema tag");
    assert!(json.contains(r#""cluster":{"#), "cluster block");
    let again = run(&cfg, Session::new()).unwrap();
    assert_eq!(json, again.to_json().to_string(), "same seed, same bytes");
}

#[test]
fn board_crash_sheds_only_its_share_and_survivors_keep_admitting() {
    let mut cfg = two_board_cfg();
    let crash_at = 40 * MS;
    cfg.faults = Some(FaultPolicy::Schedule(FaultSchedule {
        events: vec![FaultEvent {
            at_ns: crash_at,
            kind: FaultKind::BoardCrash { board: 0, down_ns: 10_000 * MS },
        }],
    }));
    let fleet = build_fleet(&cfg, cfg.cluster.as_ref().unwrap()).unwrap();
    let cb = fleet.cluster.clone().unwrap();
    let r = serve_fleet_on(&cfg, &fleet).unwrap();
    check_invariants(&r, &cfg, "board-crash");

    // the dead board can only orphan what it had in flight: admission
    // bounds every member at queue_cap, so the fault-shed total is
    // capped by the crashed board's share
    let a = &r.admission;
    let crashed = cb.boards[0].members.len();
    assert!(
        a.shed_fault + a.shed_retry <= crashed * cfg.queue_cap,
        "shed {}+{} exceeds board 0's in-flight bound ({crashed} × {})",
        a.shed_fault,
        a.shed_retry,
        cfg.queue_cap
    );

    // survivors keep admitting after the crash — completions with
    // arrivals past the instant, all served by board-1 members
    let survivors: BTreeSet<usize> = cb.boards[1].members.iter().copied().collect();
    let after: Vec<_> = r.responses.iter().filter(|x| x.arrival_ns > crash_at).collect();
    assert!(!after.is_empty(), "the surviving board must keep completing work");
    for resp in &after {
        assert!(
            survivors.contains(&resp.backend),
            "req {} served by dead board member {}",
            resp.id,
            resp.backend
        );
    }

    // the outage lands in the per-board availability rollup
    let usage = r.cluster.as_ref().unwrap().board_usage(&r);
    assert!(usage[0].availability < 1.0, "board 0 was down");
    assert_eq!(usage[1].availability, 1.0, "board 1 never faulted");
    let f = r.faults.as_ref().expect("fault runs carry the faults block");
    assert_eq!(f.timeline.len(), crashed, "one expanded crash per board-0 member");
    for (e, applied) in &f.timeline {
        assert!(*applied, "the crash fires inside the horizon");
        assert_eq!(e.at_ns, crash_at);
        assert!(matches!(e.kind, FaultKind::Crash { .. }), "expanded to member crashes");
    }
}

#[test]
fn rack_link_degrade_renegotiates_the_net_pools_and_rethrottles_members() {
    // size the NIC pool from the boards' actual host-I/O appetite so the
    // baseline is mildly contended and shrinking the pool must bite
    let base = two_board_cfg();
    let probe = build_fleet(&base, base.cluster.as_ref().unwrap()).unwrap();
    let host_gbps: f64 = probe
        .cluster
        .as_ref()
        .unwrap()
        .boards
        .iter()
        .flat_map(|bl| bl.budget.links.as_ref().unwrap().members.iter())
        .map(|m| m.demand.pcie_gbps)
        .sum();
    assert!(host_gbps > 0.0, "members must demand host I/O");
    let mut cfg = base;
    cfg.cluster = Some(spec_of(&format!(
        r#"{{"boards": ["vck5000", "vck5000-limited-64"], "nic_gbps": {}, "switch_gbps": 1000}}"#,
        0.6 * host_gbps
    )));
    // the fault spec speaks rack vocabulary: nic_scale/switch_scale are
    // the cluster aliases for the two shared link-pool slots
    cfg.faults = Some(FaultPolicy::Schedule(
        FaultSchedule::from_json(
            &Json::parse(
                r#"[{"at_ms": 30, "kind": "link_degrade", "nic_scale": 0.5, "switch_scale": 1}]"#,
            )
            .unwrap(),
        )
        .unwrap(),
    ));
    let fleet = build_fleet(&cfg, cfg.cluster.as_ref().unwrap()).unwrap();
    let cb = fleet.cluster.clone().unwrap();
    let r = serve_fleet_on(&cfg, &fleet).unwrap();
    check_invariants(&r, &cfg, "rack-degrade");
    assert!(r.admission.completed > 0, "a degraded rack still serves");

    // exactly one renegotiation, at the fault instant, with every member
    // still up: a halved NIC pool can only tighten stretches, and at
    // least one board's grant must actually shrink
    let f = r.faults.as_ref().expect("fault runs carry the faults block");
    assert_eq!(f.timeline.len(), 1, "one link event in the schedule");
    assert!(f.timeline[0].1, "the degrade fires inside the horizon");
    assert!(matches!(f.timeline[0].0.kind, FaultKind::LinkDegrade { .. }));
    assert_eq!(f.renegotiations.len(), 1, "one link event, one renegotiation");
    let (at, stretches) = &f.renegotiations[0];
    assert_eq!(*at, 30 * MS);
    assert_eq!(stretches.len(), r.n_backends);
    let mut tightened = 0;
    for (g, s) in stretches.iter().enumerate() {
        let s = s.expect("no member is down during a pure link fault");
        let deployed = 1.0 / cb.members[g].throttle;
        assert!(
            s >= deployed - 1e-9,
            "member {g}: renegotiated stretch {s} relaxed below deployed {deployed}"
        );
        if s > deployed + 1e-9 {
            tightened += 1;
        }
    }
    assert!(tightened >= 1, "halving an oversubscribed NIC pool must throttle someone");

    // nobody went down, and the degraded era reproduces byte for byte
    let usage = r.cluster.as_ref().unwrap().board_usage(&r);
    for (j, u) in usage.iter().enumerate() {
        assert_eq!(u.availability, 1.0, "board {j}: link faults down no members");
    }
    let again = serve_fleet_on(&cfg, &fleet).unwrap();
    assert_eq!(r.to_json().to_string(), again.to_json().to_string(), "same seed, same bytes");
}

#[test]
fn one_board_cluster_is_byte_identical_to_the_partition_run() {
    let model = ModelConfig::bert_base();
    // network pools far wider than any board's appetite: the net stretch
    // is exactly 1, so members deploy identically to --partition
    let spec = spec_of(r#"{"boards": ["vck5000"], "nic_gbps": 1000, "switch_gbps": 1000}"#);
    let mut part = FleetConfig::new(model, spec.boards[0].clone());
    part.rps = 1200.0;
    part.slo_ms = 80.0;
    part.n_requests = 160;
    part.max_backends = 2;
    part.explore_budget = Some(64);
    part.seed = 11;
    part.partition = true;
    let mut clus = part.clone();
    clus.partition = false;
    clus.cluster = Some(spec);
    assert_eq!(part.schema(), "cat-serve-v3");
    assert_eq!(clus.schema(), "cat-serve-v5");

    let a = serve_fleet(&part).unwrap();
    let b = serve_fleet(&clus).unwrap();
    // identical serving: the reports differ only in the schema tag and
    // in which ledger they carry (board vs cluster)
    let strip = |j: Json| match j {
        Json::Obj(mut m) => {
            m.remove("schema");
            m.remove("board");
            m.remove("cluster");
            Json::Obj(m)
        }
        other => other,
    };
    assert_eq!(
        strip(a.to_json()).to_string(),
        strip(b.to_json()).to_string(),
        "a 1-board cluster must degenerate to the partition run"
    );
    // and the net ledger shows the degenerate single-member negotiation
    let cb = b.cluster.as_ref().unwrap();
    assert_eq!(cb.net.members[0].stretch, 1.0, "uncontended pools never throttle");
    for ms in &cb.members {
        assert_eq!(ms.board, 0);
    }
}
