//! Property tests for the shared memory-path contention model
//! (`serve::links` + throttled board slices):
//!
//! * **direction** — a partition that oversubscribes the DRAM pool
//!   serves every batch size no faster, and strictly slower where the
//!   stream phases matter, than the same partition with the link model
//!   disabled;
//! * **monotonicity** — shrinking the pools (deeper over-subscription)
//!   never speeds a member up;
//! * **degeneracy** — a 1-member partition is bit-identical with the
//!   link model on and off (a lone member owns the whole path), so PR 4
//!   behavior is preserved exactly;
//! * **schema** — `cat-serve-v3` with links vs `cat-serve-v2` without
//!   round-trips with identical serving content.

use cat::config::{HardwareConfig, ModelConfig, SharedLinkModel};
use cat::dse::{explore, ExploreConfig, ExploreResult, SpaceSpec};
use cat::serve::{serve_fleet_on, Fleet, FleetConfig};
use cat::util::json::Json;

fn compact_explored(model: &ModelConfig, hw: &HardwareConfig) -> ExploreResult {
    let mut cfg = ExploreConfig::new(model.clone(), hw.clone());
    cfg.sample_budget = None;
    cfg.space = SpaceSpec::compact_9pt();
    explore(&cfg).unwrap()
}

/// A 2-member partitioned fleet under the given link pools (`None` =
/// contention model off).
fn two_member_fleet(
    model: &ModelConfig,
    hw: &HardwareConfig,
    ex: &ExploreResult,
    links: Option<&SharedLinkModel>,
) -> Fleet {
    let fleet = Fleet::select_partitioned(model, hw, ex, 2, 4, Some(200.0), links).unwrap();
    assert!(fleet.len() >= 2, "fixture drifted: no 2-member partition on the compact frontier");
    fleet
}

/// Pools tight enough that any real member pair oversubscribes DRAM.
fn tight_pools() -> SharedLinkModel {
    SharedLinkModel { dram_gbps: 4.0, pcie_gbps: 1.0 }
}

#[test]
fn oversubscribed_partition_is_strictly_slower_than_free_links() {
    let model = ModelConfig::bert_base();
    let hw = HardwareConfig::vck5000();
    let ex = compact_explored(&model, &hw);
    let free = two_member_fleet(&model, &hw, &ex, None);
    let tight = tight_pools();
    let contended = two_member_fleet(&model, &hw, &ex, Some(&tight));
    assert_eq!(free.len(), contended.len(), "link pools must not change the selection");

    let ledger = contended.budget.as_ref().unwrap().links.as_ref().unwrap();
    assert!(ledger.throttled(), "fixture drifted: 4 GB/s DRAM pool not oversubscribed");
    let demanded = ledger.demanded();
    assert!(demanded.dram_gbps > tight.dram_gbps, "Σ demand must exceed the pool");
    // grants saturate but never exceed the pool
    let granted = ledger.granted();
    assert!(granted.dram_gbps <= tight.dram_gbps + 1e-9);
    assert!((granted.dram_gbps - tight.dram_gbps).abs() < 1e-6, "grants saturate the pool");

    for (f, c) in free.backends.iter().zip(&contended.backends) {
        assert_eq!(f.point.cand.index, c.point.cand.index, "same members, same order");
        for k in 1..=f.max_batch() {
            assert!(
                c.service_ns(k) >= f.service_ns(k),
                "batch {k}: contended {} < uncontended {}",
                c.service_ns(k),
                f.service_ns(k)
            );
            assert_eq!(c.ops(k), f.ops(k), "contention must not change the work done");
        }
        // the stream phases are on the critical path of every real plan,
        // so deep throttling shows up strictly, not just weakly
        assert!(
            c.max_service_ns() > f.max_service_ns(),
            "worst-case bound must strictly grow under a {}x stretch",
            ledger.members[0].stretch
        );
    }
}

#[test]
fn deeper_oversubscription_is_monotonically_slower() {
    let model = ModelConfig::bert_base();
    let hw = HardwareConfig::vck5000();
    let ex = compact_explored(&model, &hw);
    // shrinking pools: uncontended -> 2x -> 8x -> 30x oversubscribed
    let pools = [
        SharedLinkModel { dram_gbps: 1e6, pcie_gbps: 1e6 },
        SharedLinkModel { dram_gbps: 60.0, pcie_gbps: 16.0 },
        SharedLinkModel { dram_gbps: 15.0, pcie_gbps: 4.0 },
        SharedLinkModel { dram_gbps: 4.0, pcie_gbps: 1.0 },
    ];
    let mut last: Option<(Vec<u64>, f64)> = None;
    for p in &pools {
        let fleet = two_member_fleet(&model, &hw, &ex, Some(p));
        let ledger = fleet.budget.as_ref().unwrap().links.as_ref().unwrap();
        let worst: Vec<u64> = fleet.backends.iter().map(|b| b.max_service_ns()).collect();
        let stretch = ledger.members.iter().map(|m| m.stretch).fold(0.0f64, f64::max);
        if let Some((prev_worst, prev_stretch)) = &last {
            assert!(
                stretch >= *prev_stretch,
                "stretch must grow with over-subscription: {stretch} < {prev_stretch}"
            );
            for (w, pw) in worst.iter().zip(prev_worst) {
                assert!(w >= pw, "service bound shrank under a tighter pool: {w} < {pw}");
            }
        }
        last = Some((worst, stretch));
    }
    // the extremes differ strictly (the chain is not vacuous)
    let loose = two_member_fleet(&model, &hw, &ex, Some(&pools[0]));
    let tight = two_member_fleet(&model, &hw, &ex, Some(&pools[3]));
    assert!(tight.backends[0].max_service_ns() > loose.backends[0].max_service_ns());
}

#[test]
fn one_member_partition_identical_with_and_without_links() {
    // PR 3/PR 4 degeneracy preserved: a lone member owns the whole
    // memory path, so the link model must be a bit-exact no-op — same
    // profiles, and the serve JSON identical apart from the schema tag
    // and the board.links block itself.
    let model = ModelConfig::bert_base();
    let hw = HardwareConfig::vck5000();
    let ex = compact_explored(&model, &hw);
    let with =
        Fleet::select_partitioned(&model, &hw, &ex, 1, 6, Some(80.0), Some(&hw.links())).unwrap();
    let without = Fleet::select_partitioned(&model, &hw, &ex, 1, 6, Some(80.0), None).unwrap();
    assert_eq!(with.len(), 1);
    assert_eq!(without.len(), 1);
    let (a, b) = (&with.backends[0], &without.backends[0]);
    assert_eq!(a.point.cand.index, b.point.cand.index);
    for k in 1..=6 {
        assert_eq!(a.service_ns(k), b.service_ns(k), "batch-{k} service time");
        assert_eq!(a.ops(k), b.ops(k), "batch-{k} ops");
    }
    let ledger = with.budget.as_ref().unwrap().links.as_ref().unwrap();
    assert_eq!(ledger.members[0].stretch, 1.0);

    let mut cfg = FleetConfig::new(model, hw);
    cfg.rps = 1500.0;
    cfg.slo_ms = 80.0;
    cfg.n_requests = 200;
    cfg.max_batch = 6;
    cfg.seed = 0xD07;
    let ra = serve_fleet_on(&cfg, &with).unwrap();
    let rb = serve_fleet_on(&cfg, &without).unwrap();
    assert!(ra.to_json().to_string().contains("\"schema\":\"cat-serve-v3\""));
    assert!(rb.to_json().to_string().contains("\"schema\":\"cat-serve-v2\""));
    let strip = |j: Json| match j {
        Json::Obj(mut m) => {
            m.remove("schema");
            if let Some(board) = m.get_mut("board") {
                if let Json::Obj(bm) = board {
                    bm.remove("links");
                }
            }
            Json::Obj(m)
        }
        other => other,
    };
    assert_eq!(
        strip(ra.to_json()).to_string(),
        strip(rb.to_json()).to_string(),
        "link model must be a no-op for a lone member"
    );
}

#[test]
fn contended_serving_keeps_every_invariant_and_prices_contention() {
    // Full serving runs through an oversubscribed partition: admitted
    // requests still meet the SLO (the router admits on the contended
    // profiles), conservation holds, and the run is deterministic.
    let model = ModelConfig::bert_base();
    let hw = HardwareConfig::vck5000();
    let mut cfg = FleetConfig::new(model, hw);
    cfg.rps = 1200.0;
    cfg.slo_ms = 150.0;
    cfg.n_requests = 300;
    cfg.explore_budget = Some(64);
    cfg.seed = 61;
    cfg.partition = true;
    cfg.links = Some(tight_pools());
    let r = cat::experiments::serve_fleet(&cfg).unwrap();
    let ledger = r.board.as_ref().unwrap().links.as_ref().unwrap();
    assert!(ledger.throttled(), "fixture drifted: partition not contended");

    let a = &r.admission;
    assert_eq!(a.submitted, cfg.n_requests);
    assert!(a.accounted(), "stats leak requests: {a:?}");
    let slo_ns = cfg.slo_ns();
    for resp in &r.responses {
        assert!(resp.latency_ns() >= resp.batch_service_ns, "req {}", resp.id);
        assert!(resp.latency_ns() <= slo_ns, "req {} broke SLO under contention", resp.id);
    }
    assert_eq!(r.slo_violations, 0);
    assert!(!r.responses.is_empty(), "a 150 ms SLO admits contended traffic (non-vacuous)");
    let again = cat::experiments::serve_fleet(&cfg).unwrap();
    assert_eq!(r.to_json().to_string(), again.to_json().to_string());
}
