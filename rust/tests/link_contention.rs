//! Property tests for the shared memory-path contention model
//! (`serve::links` + throttled board slices):
//!
//! * **direction** — a partition that oversubscribes the DRAM pool
//!   serves every batch size no faster, and strictly slower where the
//!   stream phases matter, than the same partition with the link model
//!   disabled;
//! * **monotonicity** — shrinking the pools (deeper over-subscription)
//!   never speeds a member up;
//! * **degeneracy** — a 1-member partition is bit-identical with the
//!   link model on and off (a lone member owns the whole path), so PR 4
//!   behavior is preserved exactly;
//! * **schema** — `cat-serve-v3` with links vs `cat-serve-v2` without
//!   round-trips with identical serving content.

use cat::config::{HardwareConfig, ModelConfig, SharedLinkModel};
use cat::dse::{explore, ExploreConfig, ExploreResult, SpaceSpec};
use cat::serve::links::{negotiate, negotiate_fixed_point, LinkDemand};
use cat::serve::{serve_fleet_on, Fleet, FleetConfig, NegotiationMode};
use cat::util::json::Json;

fn compact_explored(model: &ModelConfig, hw: &HardwareConfig) -> ExploreResult {
    let mut cfg = ExploreConfig::new(model.clone(), hw.clone());
    cfg.sample_budget = None;
    cfg.space = SpaceSpec::compact_9pt();
    explore(&cfg).unwrap()
}

/// A 2-member partitioned fleet under the given link pools (`None` =
/// contention model off).
fn two_member_fleet(
    model: &ModelConfig,
    hw: &HardwareConfig,
    ex: &ExploreResult,
    links: Option<&SharedLinkModel>,
) -> Fleet {
    let fleet = Fleet::select_partitioned(model, hw, ex, 2, 4, Some(200.0), links).unwrap();
    assert!(fleet.len() >= 2, "fixture drifted: no 2-member partition on the compact frontier");
    fleet
}

/// Pools tight enough that any real member pair oversubscribes DRAM.
fn tight_pools() -> SharedLinkModel {
    SharedLinkModel { dram_gbps: 4.0, pcie_gbps: 1.0 }
}

#[test]
fn oversubscribed_partition_is_strictly_slower_than_free_links() {
    let model = ModelConfig::bert_base();
    let hw = HardwareConfig::vck5000();
    let ex = compact_explored(&model, &hw);
    let free = two_member_fleet(&model, &hw, &ex, None);
    let tight = tight_pools();
    let contended = two_member_fleet(&model, &hw, &ex, Some(&tight));
    assert_eq!(free.len(), contended.len(), "link pools must not change the selection");

    let ledger = contended.budget.as_ref().unwrap().links.as_ref().unwrap();
    assert!(ledger.throttled(), "fixture drifted: 4 GB/s DRAM pool not oversubscribed");
    let demanded = ledger.demanded();
    assert!(demanded.dram_gbps > tight.dram_gbps, "Σ demand must exceed the pool");
    // grants saturate but never exceed the pool
    let granted = ledger.granted();
    assert!(granted.dram_gbps <= tight.dram_gbps + 1e-9);
    assert!((granted.dram_gbps - tight.dram_gbps).abs() < 1e-6, "grants saturate the pool");

    for (f, c) in free.backends.iter().zip(&contended.backends) {
        assert_eq!(f.point.cand.index, c.point.cand.index, "same members, same order");
        for k in 1..=f.max_batch() {
            assert!(
                c.service_ns(k) >= f.service_ns(k),
                "batch {k}: contended {} < uncontended {}",
                c.service_ns(k),
                f.service_ns(k)
            );
            assert_eq!(c.ops(k), f.ops(k), "contention must not change the work done");
        }
        // the stream phases are on the critical path of every real plan,
        // so deep throttling shows up strictly, not just weakly
        assert!(
            c.max_service_ns() > f.max_service_ns(),
            "worst-case bound must strictly grow under a {}x stretch",
            ledger.members[0].stretch
        );
    }
}

#[test]
fn deeper_oversubscription_is_monotonically_slower() {
    let model = ModelConfig::bert_base();
    let hw = HardwareConfig::vck5000();
    let ex = compact_explored(&model, &hw);
    // shrinking pools: uncontended -> 2x -> 8x -> 30x oversubscribed
    let pools = [
        SharedLinkModel { dram_gbps: 1e6, pcie_gbps: 1e6 },
        SharedLinkModel { dram_gbps: 60.0, pcie_gbps: 16.0 },
        SharedLinkModel { dram_gbps: 15.0, pcie_gbps: 4.0 },
        SharedLinkModel { dram_gbps: 4.0, pcie_gbps: 1.0 },
    ];
    let mut last: Option<(Vec<u64>, f64)> = None;
    for p in &pools {
        let fleet = two_member_fleet(&model, &hw, &ex, Some(p));
        let ledger = fleet.budget.as_ref().unwrap().links.as_ref().unwrap();
        let worst: Vec<u64> = fleet.backends.iter().map(|b| b.max_service_ns()).collect();
        let stretch = ledger.members.iter().map(|m| m.stretch).fold(0.0f64, f64::max);
        if let Some((prev_worst, prev_stretch)) = &last {
            assert!(
                stretch >= *prev_stretch,
                "stretch must grow with over-subscription: {stretch} < {prev_stretch}"
            );
            for (w, pw) in worst.iter().zip(prev_worst) {
                assert!(w >= pw, "service bound shrank under a tighter pool: {w} < {pw}");
            }
        }
        last = Some((worst, stretch));
    }
    // the extremes differ strictly (the chain is not vacuous)
    let loose = two_member_fleet(&model, &hw, &ex, Some(&pools[0]));
    let tight = two_member_fleet(&model, &hw, &ex, Some(&pools[3]));
    assert!(tight.backends[0].max_service_ns() > loose.backends[0].max_service_ns());
}

#[test]
fn one_member_partition_identical_with_and_without_links() {
    // PR 3/PR 4 degeneracy preserved: a lone member owns the whole
    // memory path, so the link model must be a bit-exact no-op — same
    // profiles, and the serve JSON identical apart from the schema tag
    // and the board.links block itself.
    let model = ModelConfig::bert_base();
    let hw = HardwareConfig::vck5000();
    let ex = compact_explored(&model, &hw);
    let with =
        Fleet::select_partitioned(&model, &hw, &ex, 1, 6, Some(80.0), Some(&hw.links())).unwrap();
    let without = Fleet::select_partitioned(&model, &hw, &ex, 1, 6, Some(80.0), None).unwrap();
    assert_eq!(with.len(), 1);
    assert_eq!(without.len(), 1);
    let (a, b) = (&with.backends[0], &without.backends[0]);
    assert_eq!(a.point.cand.index, b.point.cand.index);
    for k in 1..=6 {
        assert_eq!(a.service_ns(k), b.service_ns(k), "batch-{k} service time");
        assert_eq!(a.ops(k), b.ops(k), "batch-{k} ops");
    }
    let ledger = with.budget.as_ref().unwrap().links.as_ref().unwrap();
    assert_eq!(ledger.members[0].stretch, 1.0);

    let mut cfg = FleetConfig::new(model, hw);
    cfg.rps = 1500.0;
    cfg.slo_ms = 80.0;
    cfg.n_requests = 200;
    cfg.max_batch = 6;
    cfg.seed = 0xD07;
    let ra = serve_fleet_on(&cfg, &with).unwrap();
    let rb = serve_fleet_on(&cfg, &without).unwrap();
    assert!(ra.to_json().to_string().contains("\"schema\":\"cat-serve-v3\""));
    assert!(rb.to_json().to_string().contains("\"schema\":\"cat-serve-v2\""));
    let strip = |j: Json| match j {
        Json::Obj(mut m) => {
            m.remove("schema");
            if let Some(board) = m.get_mut("board") {
                if let Json::Obj(bm) = board {
                    bm.remove("links");
                }
            }
            Json::Obj(m)
        }
        other => other,
    };
    assert_eq!(
        strip(ra.to_json()).to_string(),
        strip(rb.to_json()).to_string(),
        "link model must be a no-op for a lone member"
    );
}

#[test]
fn contended_serving_keeps_every_invariant_and_prices_contention() {
    // Full serving runs through an oversubscribed partition: admitted
    // requests still meet the SLO (the router admits on the contended
    // profiles), conservation holds, and the run is deterministic.
    let model = ModelConfig::bert_base();
    let hw = HardwareConfig::vck5000();
    let mut cfg = FleetConfig::new(model, hw);
    cfg.rps = 1200.0;
    cfg.slo_ms = 150.0;
    cfg.n_requests = 300;
    cfg.explore_budget = Some(64);
    cfg.seed = 61;
    cfg.partition = true;
    cfg.links = Some(tight_pools());
    let r = cat::experiments::serve_fleet(&cfg).unwrap();
    let ledger = r.board.as_ref().unwrap().links.as_ref().unwrap();
    assert!(ledger.throttled(), "fixture drifted: partition not contended");

    let a = &r.admission;
    assert_eq!(a.submitted, cfg.n_requests);
    assert!(a.accounted(), "stats leak requests: {a:?}");
    let slo_ns = cfg.slo_ns();
    for resp in &r.responses {
        assert!(resp.latency_ns() >= resp.batch_service_ns, "req {}", resp.id);
        assert!(resp.latency_ns() <= slo_ns, "req {} broke SLO under contention", resp.id);
    }
    assert_eq!(r.slo_violations, 0);
    assert!(!r.responses.is_empty(), "a 150 ms SLO admits contended traffic (non-vacuous)");
    let again = cat::experiments::serve_fleet(&cfg).unwrap();
    assert_eq!(r.to_json().to_string(), again.to_json().to_string());
}

#[test]
fn fixed_point_stretch_never_exceeds_single_pass_on_a_real_partition() {
    // The pessimism fix, member-wise on a real contended fleet: the
    // fixed-point stretch is never below 1 and never above the
    // single-pass bound, grants stay the single-pass split, and the
    // relaxed slices serve every batch no slower than the conservative
    // ones.
    let model = ModelConfig::bert_base();
    let hw = HardwareConfig::vck5000();
    let ex = compact_explored(&model, &hw);
    let tight = tight_pools();
    let sp = two_member_fleet(&model, &hw, &ex, Some(&tight));
    let fp = Fleet::select_partitioned_in(
        &model,
        &hw,
        &ex,
        2,
        4,
        Some(200.0),
        Some(&tight),
        NegotiationMode::FixedPoint,
    )
    .unwrap();
    assert_eq!(sp.len(), fp.len(), "the mode must not change the selection");
    let lsp = sp.budget.as_ref().unwrap().links.as_ref().unwrap();
    let lfp = fp.budget.as_ref().unwrap().links.as_ref().unwrap();
    assert!(lsp.throttled() && lfp.throttled());
    for (a, b) in lfp.members.iter().zip(&lsp.members) {
        assert!(a.stretch >= 1.0);
        assert!(a.stretch <= b.stretch + 1e-12, "fp {} > sp {}", a.stretch, b.stretch);
        assert_eq!(a.stretch_single_pass, b.stretch, "sp bound must be carried verbatim");
        assert_eq!(a.granted, b.granted, "grants stay the feasible single-pass split");
    }
    assert!(lfp.pessimism() >= 1.0);
    for (f, c) in fp.backends.iter().zip(&sp.backends) {
        assert_eq!(f.point.cand.index, c.point.cand.index);
        for k in 1..=f.max_batch().min(c.max_batch()) {
            assert!(
                f.service_ns(k) <= c.service_ns(k),
                "batch {k}: fixed-point slice slower than single-pass"
            );
            assert_eq!(f.ops(k), c.ops(k));
        }
    }
}

#[test]
fn fixed_point_strictly_improves_a_constructed_oversubscribed_partition() {
    // Constructed 2-member cross-pool coupling: A is PCIe-bound beyond
    // its DRAM share, B is DRAM-heavy — each member's excess stretch
    // frees appetite the other's binding pool re-grants, so BOTH
    // bounds relax strictly.
    let pools = SharedLinkModel { dram_gbps: 100.0, pcie_gbps: 4.0 };
    let demands = [
        LinkDemand { dram_gbps: 40.0, pcie_gbps: 6.0 },
        LinkDemand { dram_gbps: 80.0, pcie_gbps: 1.0 },
    ];
    let sp = negotiate(&pools, &demands);
    let fp = negotiate_fixed_point(&pools, &demands);
    assert!(sp.throttled());
    for (a, b) in fp.members.iter().zip(&sp.members) {
        assert!(b.stretch > 1.0, "fixture drifted: member not throttled");
        assert!(
            a.stretch < b.stretch - 1e-6,
            "expected strict relaxation: fp {} vs sp {}",
            a.stretch,
            b.stretch
        );
        assert!(a.stretch >= 1.0);
    }
    assert!(fp.pessimism() > 1.0 + 1e-6);
}

#[test]
fn one_member_partition_bit_identical_across_negotiation_modes() {
    // No contender means nothing to relax: the fixed point IS the
    // single pass for a lone member, end to end through the serve JSON
    // (modulo the links block's own mode annotation).
    let model = ModelConfig::bert_base();
    let hw = HardwareConfig::vck5000();
    let ex = compact_explored(&model, &hw);
    let sp =
        Fleet::select_partitioned(&model, &hw, &ex, 1, 6, Some(80.0), Some(&hw.links())).unwrap();
    let fp = Fleet::select_partitioned_in(
        &model,
        &hw,
        &ex,
        1,
        6,
        Some(80.0),
        Some(&hw.links()),
        NegotiationMode::FixedPoint,
    )
    .unwrap();
    assert_eq!(sp.len(), 1);
    assert_eq!(fp.len(), 1);
    let (a, b) = (&sp.backends[0], &fp.backends[0]);
    assert_eq!(a.point.cand.index, b.point.cand.index);
    for k in 1..=6 {
        assert_eq!(a.service_ns(k), b.service_ns(k), "batch-{k} service time");
        assert_eq!(a.ops(k), b.ops(k));
    }
    let lfp = fp.budget.as_ref().unwrap().links.as_ref().unwrap();
    assert_eq!(lfp.members[0].stretch, 1.0);
    assert_eq!(lfp.members[0].stretch_single_pass, 1.0);

    let mut cfg = FleetConfig::new(model, hw);
    cfg.rps = 1500.0;
    cfg.slo_ms = 80.0;
    cfg.n_requests = 200;
    cfg.max_batch = 6;
    cfg.seed = 0xD07;
    let ra = serve_fleet_on(&cfg, &sp).unwrap();
    cfg.links_fixed_point = true;
    let rb = serve_fleet_on(&cfg, &fp).unwrap();
    let strip = |j: Json| match j {
        Json::Obj(mut m) => {
            if let Some(Json::Obj(bm)) = m.get_mut("board") {
                bm.remove("links");
            }
            Json::Obj(m)
        }
        other => other,
    };
    assert_eq!(
        strip(ra.to_json()).to_string(),
        strip(rb.to_json()).to_string(),
        "negotiation mode must be a serving no-op for a lone member"
    );
}

#[test]
fn contended_serving_under_fixed_point_keeps_every_invariant() {
    // Full serving through the same oversubscribed partition with
    // --links-fixed-point: conservation, SLO compliance, and
    // determinism all hold, the report carries both bounds, and the
    // whole document stays valid JSON.
    let model = ModelConfig::bert_base();
    let hw = HardwareConfig::vck5000();
    let mut cfg = FleetConfig::new(model, hw);
    cfg.rps = 1200.0;
    cfg.slo_ms = 150.0;
    cfg.n_requests = 300;
    cfg.explore_budget = Some(64);
    cfg.seed = 61;
    cfg.partition = true;
    cfg.links = Some(tight_pools());
    cfg.links_fixed_point = true;
    let r = cat::experiments::serve_fleet(&cfg).unwrap();
    let ledger = r.board.as_ref().unwrap().links.as_ref().unwrap();
    assert!(ledger.throttled(), "fixture drifted: partition not contended");
    assert_eq!(ledger.mode, NegotiationMode::FixedPoint);
    for m in &ledger.members {
        assert!(m.stretch >= 1.0 && m.stretch <= m.stretch_single_pass + 1e-12);
    }

    let a = &r.admission;
    assert_eq!(a.submitted, cfg.n_requests);
    assert!(a.accounted(), "stats leak requests: {a:?}");
    let slo_ns = cfg.slo_ns();
    for resp in &r.responses {
        assert!(resp.latency_ns() >= resp.batch_service_ns, "req {}", resp.id);
        assert!(resp.latency_ns() <= slo_ns, "req {} broke SLO under contention", resp.id);
    }
    assert_eq!(r.slo_violations, 0);
    assert!(!r.responses.is_empty());
    let s = r.to_json().to_string();
    assert!(s.contains("\"schema\":\"cat-serve-v3\""));
    assert!(s.contains("\"stretch_single_pass\""));
    assert!(s.contains("\"stretch_fixed_point\""));
    assert!(s.contains("\"pessimism\""));
    assert!(s.contains("\"mode\":\"fixed_point\""));
    Json::parse(&s).expect("fixed-point serve report must stay valid JSON");
    let again = cat::experiments::serve_fleet(&cfg).unwrap();
    assert_eq!(s, again.to_json().to_string());
}

#[test]
fn non_finite_ledger_values_serialize_as_null_through_the_serve_path() {
    // A demanded zero-width pool negotiates to infinite stretch; the
    // selection path refuses such pools, but the ledger API can still
    // carry one (e.g. external callers building their own budget).
    // The full serve report must degrade those to null — bare `inf`
    // would poison the whole cat-serve-v3 document.
    let model = ModelConfig::bert_base();
    let hw = HardwareConfig::vck5000();
    let ex = compact_explored(&model, &hw);
    let mut fleet = two_member_fleet(&model, &hw, &ex, Some(&tight_pools()));
    let demands: Vec<LinkDemand> = fleet
        .budget
        .as_ref()
        .unwrap()
        .links
        .as_ref()
        .unwrap()
        .members
        .iter()
        .map(|m| m.demand)
        .collect();
    let zero = SharedLinkModel { dram_gbps: 0.0, pcie_gbps: 1.0 };
    fleet.budget.as_mut().unwrap().links = Some(negotiate(&zero, &demands));

    let mut cfg = FleetConfig::new(model, hw);
    cfg.rps = 1200.0;
    cfg.slo_ms = 150.0;
    cfg.n_requests = 50;
    cfg.seed = 61;
    cfg.partition = true;
    let r = serve_fleet_on(&cfg, &fleet).unwrap();
    let s = r.to_json().to_string();
    assert!(s.contains("\"schema\":\"cat-serve-v3\""));
    assert!(s.contains("\"stretch\":null"), "infinite stretch must serialize as null: {s}");
    assert!(s.contains("\"oversubscription\":null"), "zero-pool oversubscription: {s}");
    // a bare non-finite literal would surface as `:inf`/`:NaN` (the
    // board's `aie_infeasible` key makes a plain "inf" search useless)
    assert!(!s.contains(":inf") && !s.contains(":NaN"), "bare non-finite is invalid JSON");
    let parsed = Json::parse(&s).expect("report with non-finite ledger values must parse");
    let members = parsed
        .get("board")
        .unwrap()
        .get("links")
        .unwrap()
        .get("members")
        .unwrap()
        .as_arr()
        .unwrap();
    assert!(members.iter().any(|m| m.get("stretch") == Some(&Json::Null)));
}
