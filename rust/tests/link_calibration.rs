//! Calibration of the two contention bounds against a beat-level
//! arbitration reference.
//!
//! The serve-layer link model is deliberately fluid: single-pass
//! proportional grants (conservative) and the clamped fixed point
//! (optimistic).  This suite replays a small **request/response-beat
//! arbitration trace** — shaped like the AXI read/write-beat and DRAM
//! channel models of cycle-accurate emulation engines — through a
//! weighted round-robin arbiter and checks that the measured per-member
//! stretch lands **between the two bounds**:
//!
//! `stretch_fixed_point  ≤  reference  ≤  stretch_single_pass`
//!
//! (up to the trace's beat-quantization tolerance).  The arbiter is
//! intentionally independent arithmetic: members issue per-work-unit
//! DRAM and PCIe beats, each channel grants one beat at a time
//! round-robin among *eligible* members (beat bytes are proportional
//! to demand, so equal beats per round ≈ the proportional split), and
//! a bounded window couples the channels — a member stalled on one
//! link stops issuing beats on the other, which is exactly the freed
//! bandwidth the fixed point re-grants and the single pass ignores.
//!
//! `tools/link_calibration.py` is the same replay in independent
//! Python, used to refresh these constants when the link model
//! changes (see ROADMAP).

use cat::config::SharedLinkModel;
use cat::serve::links::{negotiate, negotiate_fixed_point, LinkDemand};

/// Work units each member must complete before the snapshot window.
const UNITS: usize = 400;
/// Beats per work unit per channel: beat bytes = demand / BEATS, so a
/// round-robin round moves bytes proportional to demand.
const BEATS: usize = 16;
/// How many units a member may run ahead of its fully-completed
/// frontier — the request/response window that couples the channels.
const WINDOW: usize = 4;
/// Beat-quantization tolerance on the bracket (relative).
const TOL: f64 = 0.03;

/// One grant in the replayed trace: (channel, member, completion ns).
type Grant = (usize, usize, f64);

/// Replay the beat trace for `demands` against `pools`; returns each
/// member's achieved work rate (units per ns) over the fully-contended
/// interval (up to the first member's completion) plus the grant trace.
fn replay(pools: &SharedLinkModel, demands: &[LinkDemand]) -> (Vec<f64>, Vec<Grant>) {
    let n = demands.len();
    let pool = [pools.dram_gbps, pools.pcie_gbps];
    // bytes per beat, per channel per member (0 = no traffic there)
    let beat: Vec<[f64; 2]> = demands
        .iter()
        .map(|d| [d.dram_gbps / BEATS as f64, d.pcie_gbps / BEATS as f64])
        .collect();
    let mut served = vec![[0usize; 2]; n]; // beats completed
    let mut free_at = [0.0f64; 2];
    let mut cursor = [0usize; 2]; // round-robin position per channel
    let mut trace = Vec::new();
    let mut now = 0.0f64;
    // a member's completed units = its slowest channel's frontier;
    // channels with zero demand are always complete
    let units_done = |served: &Vec<[usize; 2]>, m: usize| -> f64 {
        (0..2)
            .filter(|&c| beat[m][c] > 0.0)
            .map(|c| served[m][c] as f64 / BEATS as f64)
            .fold(UNITS as f64, f64::min)
    };
    // unit `u` is *released* at `u` ns (demands are bytes per unit per
    // ns, so release rate 1/ns makes the demand a byte rate); a beat is
    // eligible once its unit is released AND within the completion
    // window — the latter is what couples the two channels
    let eligible = |served: &Vec<[usize; 2]>, m: usize, c: usize, now: f64| -> bool {
        if beat[m][c] <= 0.0 || served[m][c] >= UNITS * BEATS {
            return false;
        }
        if (served[m][c] / BEATS) as f64 > now {
            return false; // unit not yet released
        }
        let done = (0..2)
            .filter(|&k| beat[m][k] > 0.0)
            .map(|k| served[m][k] / BEATS)
            .min()
            .unwrap_or(UNITS);
        served[m][c] < (done + WINDOW) * BEATS
    };
    let mut steps = 0usize;
    loop {
        steps += 1;
        assert!(steps < 10_000_000, "arbitration replay failed to terminate");
        if (0..n).any(|m| units_done(&served, m) >= UNITS as f64) {
            break;
        }
        let mut progressed = false;
        for c in 0..2 {
            if free_at[c] > now {
                continue;
            }
            // round-robin: next eligible member after the cursor
            let pick =
                (0..n).map(|k| (cursor[c] + k) % n).find(|&m| eligible(&served, m, c, now));
            if let Some(m) = pick {
                let dur = beat[m][c] / pool[c];
                free_at[c] = now + dur;
                served[m][c] += 1;
                cursor[c] = (m + 1) % n;
                trace.push((c, m, free_at[c]));
                progressed = true;
            }
        }
        if !progressed {
            // channels busy or blocked: advance to the next event —
            // a beat completion or a unit release (eligibility only
            // changes at those instants)
            let mut next =
                free_at.iter().copied().filter(|t| *t > now).fold(f64::INFINITY, f64::min);
            for (m, s) in served.iter().enumerate() {
                for c in 0..2 {
                    if beat[m][c] > 0.0 && s[c] < UNITS * BEATS {
                        let release = (s[c] / BEATS) as f64;
                        if release > now {
                            next = next.min(release);
                        }
                    }
                }
            }
            assert!(next.is_finite(), "deadlocked replay: no event to advance to");
            now = next;
        }
    }
    let horizon = now.max(free_at[0]).max(free_at[1]);
    let rates = (0..n).map(|m| units_done(&served, m) / horizon).collect();
    (rates, trace)
}

/// A member's solo work rate (units per ns): alone it owns every pool,
/// so each channel moves `min(demand, pool)` bytes per ns.
fn solo_rate(pools: &SharedLinkModel, d: &LinkDemand) -> f64 {
    let per = |dem: f64, pool: f64| if dem <= 0.0 { f64::INFINITY } else { dem.min(pool) / dem };
    per(d.dram_gbps, pools.dram_gbps).min(per(d.pcie_gbps, pools.pcie_gbps))
}

fn check_bracket(
    pools: &SharedLinkModel,
    demands: &[LinkDemand],
) -> (Vec<f64>, Vec<f64>, Vec<f64>) {
    let sp = negotiate(pools, demands);
    let fp = negotiate_fixed_point(pools, demands);
    let (rates, trace) = replay(pools, demands);
    assert!(!trace.is_empty());
    let mut sps = Vec::new();
    let mut fps = Vec::new();
    let mut refs = Vec::new();
    for (m, d) in demands.iter().enumerate() {
        let reference = solo_rate(pools, d) / rates[m];
        let (s, f) = (sp.members[m].stretch, fp.members[m].stretch);
        assert!(
            reference >= 1.0 - TOL,
            "member {m}: reference stretch {reference} below 1 — broken replay"
        );
        assert!(
            f <= reference * (1.0 + TOL),
            "member {m}: fixed-point bound {f} above the reference {reference} — the \
             optimistic bound stopped being a lower bracket"
        );
        assert!(
            reference <= s * (1.0 + TOL),
            "member {m}: reference {reference} above the single-pass bound {s} — the \
             conservative bound stopped being an upper bracket"
        );
        sps.push(s);
        fps.push(f);
        refs.push(reference);
    }
    (sps, fps, refs)
}

#[test]
fn bounds_bracket_the_cross_pool_coupled_reference() {
    // the ledger-level strict-relaxation scenario: A is PCIe-bound
    // beyond its DRAM share, B DRAM-heavy — the arbitration reference
    // must land between the relaxed and the conservative bound
    let pools = SharedLinkModel { dram_gbps: 100.0, pcie_gbps: 4.0 };
    let demands = [
        LinkDemand { dram_gbps: 40.0, pcie_gbps: 6.0 },
        LinkDemand { dram_gbps: 80.0, pcie_gbps: 1.0 },
    ];
    let (sps, fps, _) = check_bracket(&pools, &demands);
    // the bracket is non-degenerate here: the bounds genuinely differ
    for (s, f) in sps.iter().zip(&fps) {
        assert!(f < s, "fixture drifted: bounds collapsed, nothing to calibrate");
    }
}

#[test]
fn bounds_bracket_a_single_pool_reference_where_they_coincide() {
    // pure DRAM contention, no cross-pool coupling: both bounds equal
    // Σdemand/pool and the arbitration replay must land on them
    let pools = SharedLinkModel { dram_gbps: 100.0, pcie_gbps: 1e6 };
    let demands = [
        LinkDemand { dram_gbps: 80.0, pcie_gbps: 0.5 },
        LinkDemand { dram_gbps: 40.0, pcie_gbps: 0.5 },
    ];
    let (sps, fps, refs) = check_bracket(&pools, &demands);
    for ((s, f), r) in sps.iter().zip(&fps).zip(&refs) {
        assert!((s - f).abs() < 1e-9, "no coupling, the bounds must coincide");
        assert!((r - s).abs() <= s * TOL, "reference {r} off the coincident bound {s}");
    }
}

#[test]
fn uncontended_replay_matches_both_bounds_at_stretch_one() {
    let pools = SharedLinkModel { dram_gbps: 200.0, pcie_gbps: 32.0 };
    let demands = [
        LinkDemand { dram_gbps: 40.0, pcie_gbps: 4.0 },
        LinkDemand { dram_gbps: 50.0, pcie_gbps: 6.0 },
    ];
    let (sps, fps, refs) = check_bracket(&pools, &demands);
    for ((s, f), r) in sps.iter().zip(&fps).zip(&refs) {
        assert_eq!(*s, 1.0);
        assert_eq!(*f, 1.0);
        assert!((r - 1.0).abs() <= TOL, "idle links must not stretch the replay: {r}");
    }
}

#[test]
fn replayed_trace_is_deterministic() {
    let pools = SharedLinkModel { dram_gbps: 100.0, pcie_gbps: 4.0 };
    let demands = [
        LinkDemand { dram_gbps: 40.0, pcie_gbps: 6.0 },
        LinkDemand { dram_gbps: 80.0, pcie_gbps: 1.0 },
    ];
    let (r1, t1) = replay(&pools, &demands);
    let (r2, t2) = replay(&pools, &demands);
    assert_eq!(r1, r2);
    assert_eq!(t1.len(), t2.len());
    assert!(t1.iter().zip(&t2).all(|(a, b)| a.0 == b.0 && a.1 == b.1 && a.2 == b.2));
}
