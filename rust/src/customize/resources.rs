//! PL resource estimator (Table V substitute for Vivado synthesis).
//!
//! Coefficients are calibrated against the paper's reported utilization
//! (Table V) for the three accelerators.  The estimator is *structural*:
//! it prices sender/receiver stream logic per PLIO channel, each PL
//! operator module, stage control, and maps buffers to BRAM (stream/
//! activation) and URAM (weight cache, only in pipelined mode — the
//! Limited-AIE serial design streams weights and reports 0 URAM).

use crate::arch::{PlResources, PuSpec, StagePlan};
use crate::workload::{PlSite, Workload};

/// Which stage is being estimated (they price different PL operators).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StageKind {
    Mha,
    Ffn,
}

// --- calibrated coefficients (see tests + EXPERIMENTS.md) ---

/// LUT / FF per PLIO stream channel (sender or receiver data mover).
const LUT_PER_CHANNEL: usize = 1_150;
const FF_PER_CHANNEL: usize = 1_450;
/// BRAM per channel (stream FIFO, double buffered).
const BRAM_PER_CHANNEL: usize = 4;

/// Per PL operator module instance.
const LUT_SOFTMAX: usize = 7_500;
const FF_SOFTMAX: usize = 9_000;
const LUT_TRANSPOSE: usize = 1_800;
const FF_TRANSPOSE: usize = 2_200;
const LUT_GELU: usize = 6_000;
const FF_GELU: usize = 7_000;
const LUT_LAYERNORM: usize = 5_500;
const FF_LAYERNORM: usize = 6_500;

/// Stage controller (MHA Controller / FFN Controller in Fig. 2).
const LUT_CONTROL: usize = 9_000;
const FF_CONTROL: usize = 12_000;

/// One BRAM36 holds 4 KiB usable here; one URAM 32 KiB.
const BRAM_BYTES: usize = 4 * 1024;
const URAM_BYTES: usize = 32 * 1024;
/// Weight/activation caches are double-buffered in URAM.
const URAM_DOUBLE_BUFFER: usize = 2;

/// Estimate one stage's PL resources from its plan + workload.
pub fn estimate_stage_resources(
    kind: StageKind,
    stage: &StagePlan,
    wl: &Workload,
    p_atb: usize,
) -> PlResources {
    let mmsz = wl.mmsz;
    let l = wl.model.padded_seq_len(mmsz);
    let e = wl.model.embed_dim;
    let d = wl.model.dff;
    let dh = wl.model.head_dim();

    // --- stream channels: every PU instance carries its own sender +
    // receiver (paper: "we equip each AIE MM PU with a special Sender and
    // Receiver at the PL side") ---
    let mut channels = 0usize;
    for prg in &stage.prgs {
        for (class, n) in &prg.pus {
            let spec = PuSpec::by_class(*class);
            channels += n * (spec.in_plio + spec.out_plio);
        }
    }
    // serial modes share one set of movers across PRGs (hardware reuse):
    let shared = !matches!(stage.mode, crate::arch::ParallelMode::FullyPipelined);
    if shared {
        let max_prg_channels = stage
            .prgs
            .iter()
            .map(|p| {
                p.pus
                    .iter()
                    .map(|(c, n)| {
                        let s = PuSpec::by_class(*c);
                        n * (s.in_plio + s.out_plio)
                    })
                    .sum::<usize>()
            })
            .max()
            .unwrap_or(0);
        channels = max_prg_channels;
    }

    let mut luts = channels * LUT_PER_CHANNEL + LUT_CONTROL;
    let mut ffs = channels * FF_PER_CHANNEL + FF_CONTROL;
    let mut brams = channels * BRAM_PER_CHANNEL;
    let mut urams = 0usize;

    // --- PL operator modules on the dataflow branches ---
    match kind {
        StageKind::Mha => {
            // one softmax + one transpose per parallel ATB; one LN+add
            let n = if shared { 1 } else { p_atb };
            luts += n * (LUT_SOFTMAX + LUT_TRANSPOSE) + LUT_LAYERNORM;
            ffs += n * (FF_SOFTMAX + FF_TRANSPOSE) + FF_LAYERNORM;
        }
        StageKind::Ffn => {
            luts += LUT_GELU + LUT_LAYERNORM;
            ffs += FF_GELU + FF_LAYERNORM;
        }
    }

    // --- buffers ---
    let _ = wl.pls.iter().find(|p| p.site == PlSite::Softmax);
    if shared {
        // serial: only working tiles stay on chip; weights stream from
        // DRAM. Activation double buffers in BRAM.
        let act_bytes = 2 * l * (e.max(d)) / 2; // half-matrix double buffer
        brams += act_bytes / BRAM_BYTES;
    } else {
        match kind {
            StageKind::Mha => {
                // the §V.B accounting (int8 activations, int32 scores)
                let chunk = 4 * mmsz;
                let act = l * chunk * 3          // QKV out cache
                    + l * dh * 4 * p_atb          // ATB I/O
                    + p_atb * l * l / 2           // attention cache
                    + l * e + l * chunk; // Proj I/O
                brams += act / BRAM_BYTES;
                // weight cache for QKV + Proj (4*E^2), URAM, double buffered
                urams += 4 * e * e * URAM_DOUBLE_BUFFER / URAM_BYTES;
            }
            StageKind::Ffn => {
                let act = l * d + 2 * l * e;
                brams += act / BRAM_BYTES;
                urams += 2 * e * d * URAM_DOUBLE_BUFFER / URAM_BYTES;
            }
        }
    }

    // FFN shares the MHA stage's movers in the paper design; its own LUT
    // count is therefore just movers for its Large PUs + GELU/LN. Nothing
    // extra to do: channels above already reflect the FFN plan's own PUs.
    let has_atb = stage.prgs.iter().any(|p| p.kind.is_atb());
    debug_assert!(matches!(kind, StageKind::Mha) == has_atb || shared);

    PlResources { luts, ffs, brams, urams }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::ParallelMode;
    use crate::config::{HardwareConfig, ModelConfig};
    use crate::customize::{customize, CustomizeOptions};
    use crate::workload::layer_workload;

    fn close_pct(got: usize, want: usize, pct: f64) -> bool {
        (got as f64 - want as f64).abs() / want as f64 <= pct
    }

    #[test]
    fn bert_mha_near_table_v() {
        let plan = customize(
            &ModelConfig::bert_base(),
            &HardwareConfig::vck5000(),
            &CustomizeOptions::default(),
        )
        .unwrap();
        // paper Table V MHA: 162.9K LUT, 213.6K FF, 588 BRAM, 220 URAM
        let r = plan.res_mha;
        assert!(close_pct(r.luts, 162_900, 0.25), "LUT {}", r.luts);
        assert!(close_pct(r.ffs, 213_600, 0.25), "FF {}", r.ffs);
        assert!(close_pct(r.brams, 588, 0.35), "BRAM {}", r.brams);
        assert!(close_pct(r.urams, 220, 0.45), "URAM {}", r.urams);
    }

    #[test]
    fn bert_ffn_near_table_v() {
        let plan = customize(
            &ModelConfig::bert_base(),
            &HardwareConfig::vck5000(),
            &CustomizeOptions::default(),
        )
        .unwrap();
        // paper Table V FFN: 71.7K LUT, 85K FF, 482 BRAM, 276 URAM
        let r = plan.res_ffn;
        assert!(close_pct(r.luts, 71_700, 0.30), "LUT {}", r.luts);
        assert!(close_pct(r.ffs, 85_000, 0.35), "FF {}", r.ffs);
        assert!(close_pct(r.brams, 482, 0.45), "BRAM {}", r.brams);
        assert!(close_pct(r.urams, 276, 0.45), "URAM {}", r.urams);
    }

    #[test]
    fn overall_less_than_sum_of_stages() {
        let plan = customize(
            &ModelConfig::bert_base(),
            &HardwareConfig::vck5000(),
            &CustomizeOptions::default(),
        )
        .unwrap();
        let sum = plan.res_mha.add(&plan.res_ffn);
        assert!(plan.res_overall.luts < sum.luts);
        assert!(plan.res_overall.luts >= plan.res_mha.luts);
        assert!(plan.res_overall.brams < sum.brams);
    }

    #[test]
    fn limited_serial_has_no_uram_and_small_lut() {
        let plan = customize(
            &ModelConfig::bert_base(),
            &HardwareConfig::vck5000_limited(64),
            &CustomizeOptions::default(),
        )
        .unwrap();
        // paper Table V row 3: ~46-48K LUT, 320 BRAM, 0 URAM
        assert_eq!(plan.res_mha.urams, 0);
        assert!(close_pct(plan.res_mha.luts, 46_600, 0.35), "LUT {}", plan.res_mha.luts);
        assert!(close_pct(plan.res_mha.brams, 320, 0.50), "BRAM {}", plan.res_mha.brams);
    }

    #[test]
    fn serial_mode_shares_movers() {
        let m = ModelConfig::bert_base();
        let wl = layer_workload(&m, 64, true);
        let plan = customize(&m, &HardwareConfig::vck5000(), &CustomizeOptions::default())
            .unwrap();
        let mut serial_stage = plan.mha.clone();
        serial_stage.mode = ParallelMode::Serial;
        let r_serial = estimate_stage_resources(StageKind::Mha, &serial_stage, &wl, 4);
        let r_pipe = estimate_stage_resources(StageKind::Mha, &plan.mha, &wl, 4);
        assert!(r_serial.luts < r_pipe.luts);
    }
}
