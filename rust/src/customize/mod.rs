//! The CAT customization strategy (paper §IV): decide the three
//! customizable attributes — AIE MM PU scale (Eq. 3–4), stage parallel
//! modes (Eq. 5–6), ATB parallelism (Eq. 7–8) — from the model
//! configuration and the board's intrinsic parameters, then allocate PUs
//! to PRGs (§V.C) and estimate PL resources (Table V).

mod resources;

pub use resources::{estimate_stage_resources, StageKind};

use crate::arch::{
    AcceleratorPlan, ParallelMode, Prg, PrgKind, PuClass, PuSpec, StagePlan,
};
use crate::config::{HardwareConfig, ModelConfig};
use crate::workload::{layer_workload, MmSite};
use anyhow::{anyhow, Result};

/// Enumeration-friendly domains of the customizable attributes for one
/// model/board pair — what the [`dse`](crate::dse) subsystem sweeps.
///
/// A `None` entry in a mode domain means "let Eq. 5/6 decide"; the forced
/// entries explore the Table II-style overrides.  `p_atb` covers every
/// divisor of the head count (the shapes a head-partitioned ATB array can
/// take) plus the Eq. 7/8-derived value, so the plan `customize` would
/// pick on its own is always a point of the enumerated space.
#[derive(Debug, Clone)]
pub struct KnobDomains {
    pub independent_linear: Vec<bool>,
    pub mha_modes: Vec<Option<ParallelMode>>,
    pub ffn_modes: Vec<Option<ParallelMode>>,
    pub p_atb: Vec<usize>,
}

/// The joint customization domains for `model` on `hw` (see [`KnobDomains`]).
pub fn knob_domains(model: &ModelConfig, hw: &HardwareConfig) -> KnobDomains {
    let mut p_atb: Vec<usize> = (1..=model.heads)
        .filter(|p| model.heads % p == 0)
        .collect();
    let bytes = model.bytes_per_elem();
    let mmsz = eq3_mmsz(hw, bytes);
    let plio = eq4_plio_aie(hw, mmsz, bytes);
    let derived = derived_p_atb(model, hw, mmsz, plio);
    if !p_atb.contains(&derived) {
        p_atb.push(derived);
        p_atb.sort_unstable();
    }
    KnobDomains {
        independent_linear: vec![true, false],
        mha_modes: vec![
            None,
            Some(ParallelMode::FullyPipelined),
            Some(ParallelMode::SerialHybrid),
            Some(ParallelMode::Serial),
        ],
        ffn_modes: vec![
            None,
            Some(ParallelMode::FullyPipelined),
            Some(ParallelMode::Serial),
        ],
        p_atb,
    }
}

/// Ablation / override knobs (Table II toggles these; normal use leaves
/// everything `None` and lets Eq. 3–8 decide).
#[derive(Debug, Clone, Copy, Default)]
pub struct CustomizeOptions {
    /// Force the independent-linear (merged QKV) organization on/off.
    pub independent_linear: Option<bool>,
    /// Force the MHA stage parallel mode.
    pub force_mha_mode: Option<ParallelMode>,
    /// Force the FFN stage parallel mode.
    pub force_ffn_mode: Option<ParallelMode>,
    /// Force `P_ATB`.
    pub p_atb: Option<usize>,
}

/// Eq. 3: largest power-of-two tile edge whose square int8 tile fits in a
/// quarter of the AIE window (two operands x double buffering).
pub fn eq3_mmsz(hw: &HardwareConfig, bytes_per_elem: usize) -> usize {
    let budget = hw.window_bytes / 4;
    let mut mmsz = 1usize;
    while (2 * mmsz) * (2 * mmsz) * bytes_per_elem <= budget {
        mmsz *= 2;
    }
    mmsz
}

/// Eq. 4: how many cores one PLIO can feed in packet-switch mode without
/// stalling compute: `floor(T_Calc / T_Window)`.
///
/// A 5% tolerance is applied before the floor: with double buffering the
/// next window's tail can overlap the current iteration, so a ~4% shortfall
/// (exactly what the VCK5000 numbers give: 3276.8 ns / 853.3 ns = 3.84)
/// still sustains `T_PU ~= T_Calc` — and the paper indeed reaches
/// `PLIO_AIE = 4` on this board.
pub fn eq4_plio_aie(hw: &HardwareConfig, mmsz: usize, bytes_per_elem: usize) -> usize {
    let t_calc = hw.t_calc_ns(mmsz);
    let t_window = hw.t_window_ns(mmsz, bytes_per_elem);
    let ratio = t_calc / t_window * 1.05;
    (ratio.floor() as usize).max(1)
}

/// Eq. 5 Factor1 for the MHA stage: LB MM scale demanded by the model vs
/// the MM scale the whole computing engine can take in one shot.
pub fn factor1_mha(model: &ModelConfig, hw: &HardwareConfig, mmsz: usize, plio: usize) -> f64 {
    let l = model.padded_seq_len(mmsz) as f64;
    let e = model.embed_dim as f64;
    let engine = engine_capacity(hw, mmsz, plio);
    // 4 LB matmuls of L x E x E (merged QKV counts as 3 + projection)
    4.0 * l * e * e / engine
}

/// Eq. 6 Factor1 for the FFN stage.
pub fn factor1_ffn(model: &ModelConfig, hw: &HardwareConfig, mmsz: usize, plio: usize) -> f64 {
    let l = model.padded_seq_len(mmsz) as f64;
    let e = model.embed_dim as f64;
    let d = model.dff as f64;
    2.0 * l * e * d / engine_capacity(hw, mmsz, plio)
}

/// `floor(Total_AIE / PLIO_AIE^2) * (PLIO_AIE * MMSZ)^3` — the denominator
/// of Eq. 5/6.
fn engine_capacity(hw: &HardwareConfig, mmsz: usize, plio: usize) -> f64 {
    let groups = (hw.total_aie / (plio * plio)) as f64;
    let edge = (plio * mmsz) as f64;
    groups * edge * edge * edge
}

/// Eq. 5 Factor2: PL on-chip bytes the MHA stage needs when fully
/// pipeline-unrolled (the §V.B accounting: QKV-out + ATB I/O + attention
/// cache + Proj I/O + weight cache = 7.5625 MiB for BERT-Base).
pub fn factor2_mha_bytes(
    model: &ModelConfig,
    mmsz: usize,
    plio: usize,
    p_atb: usize,
) -> u64 {
    let l = model.padded_seq_len(mmsz) as u64;
    let e = model.embed_dim as u64;
    let d = model.dff as u64;
    let dh = model.head_dim() as u64;
    let chunk = (plio * mmsz) as u64; // Large-PU output width
    let qkv_out = l * chunk * 3;
    let atb_io = l * dh * 4 * p_atb as u64;
    let attn_cache = p_atb as u64 * l * l / 2;
    let proj_io = l * e + l * chunk;
    // weight cache holds ALL layer weights (shared by both stages):
    // 4*E^2 (QKV merged + Proj) + 2*E*Dff
    let weights = 4 * e * e + 2 * e * d;
    qkv_out + atb_io + attn_cache + proj_io + weights
}

/// Eq. 6 Factor2: FFN1/FFN2 buffers under full pipelining.
pub fn factor2_ffn_bytes(model: &ModelConfig, mmsz: usize) -> u64 {
    let l = model.padded_seq_len(mmsz) as u64;
    let e = model.embed_dim as u64;
    let d = model.dff as u64;
    // FFN weights + the inter-LB activation (L x Dff int8) + in/out rows
    let weights = 2 * e * d;
    weights + l * d + 2 * l * e
}

/// Eq. 5/6 decision rule.
pub fn decide_mode(factor1: f64, factor2_bytes: u64, hw: &HardwareConfig) -> ParallelMode {
    if factor1 >= hw.prg_max_pipeline_depth as f64
        || factor2_bytes > hw.onchip_sram_bytes as u64
    {
        ParallelMode::SerialHybrid
    } else {
        ParallelMode::FullyPipelined
    }
}

/// Eq. 7: integer head-ratio between what the QKV LB emits per execution
/// and what one ATB consumes.
pub fn eq7_p_atb(model: &ModelConfig, mmsz: usize, plio: usize) -> Option<usize> {
    let lb_out_cols = plio * mmsz; // Large-PU output tile width
    let dh = model.head_dim();
    if lb_out_cols % dh == 0 {
        Some(lb_out_cols / dh)
    } else {
        None
    }
}

/// The `P_ATB` value the strategy derives when none is forced: Eq. 7's
/// integer head-ratio, falling back to Eq. 8's throughput ratio, clamped
/// to the head count.  Shared by [`customize`] and [`knob_domains`] so
/// the derived plan is always a point of the enumerated space.
pub fn derived_p_atb(
    model: &ModelConfig,
    hw: &HardwareConfig,
    mmsz: usize,
    plio: usize,
) -> usize {
    eq7_p_atb(model, mmsz, plio)
        .unwrap_or_else(|| eq8_p_atb(model, hw, mmsz, plio))
        .clamp(1, model.heads)
}

/// Eq. 8 fallback: throughput ratio.
pub fn eq8_p_atb(model: &ModelConfig, hw: &HardwareConfig, mmsz: usize, plio: usize) -> usize {
    // QKV LB throughput on one Large PU vs one ATB chain's throughput on
    // (2 Small + 1 Standard); both are t_calc-bound, so the ratio reduces
    // to an ops ratio per beat.
    let large = PuSpec::by_class(PuClass::Large);
    let small = PuSpec::by_class(PuClass::Small);
    let std_ = PuSpec::by_class(PuClass::Standard);
    let lb_ops = large.ops(mmsz) as f64;
    let atb_ops = (2 * small.ops(mmsz) + std_.ops(mmsz)) as f64;
    let _ = hw;
    let _ = model;
    let _ = plio;
    ((lb_ops / atb_ops).round() as usize).max(1)
}

/// §V.C PU allocation for the fully-pipelined MHA stage: one Large per LB
/// PRG, and per ATB a (2 Small + 1 Standard) pre/post pair.
fn mha_pipelined_prgs(independent_linear: bool, p_atb: usize) -> Vec<Prg> {
    let mut prgs = Vec::new();
    if independent_linear {
        // merged QKV computed as 3 Large-PU LB PRGs + Proj
        for kind in [PrgKind::QLb, PrgKind::KLb, PrgKind::VLb] {
            prgs.push(Prg { kind, atb_index: 0, pus: vec![(PuClass::Large, 1)] });
        }
    } else {
        for kind in [PrgKind::QLb, PrgKind::KLb, PrgKind::VLb] {
            prgs.push(Prg { kind, atb_index: 0, pus: vec![(PuClass::Large, 1)] });
        }
    }
    for i in 0..p_atb {
        prgs.push(Prg {
            kind: PrgKind::AtbPre,
            atb_index: i,
            pus: vec![(PuClass::Small, 2)],
        });
        prgs.push(Prg {
            kind: PrgKind::AtbPost,
            atb_index: i,
            pus: vec![(PuClass::Standard, 1)],
        });
    }
    prgs.push(Prg { kind: PrgKind::ProjLb, atb_index: 0, pus: vec![(PuClass::Large, 1)] });
    prgs
}

/// FFN stage reuses the MHA stage's Large PUs (two per LB) — the paper's
/// two-stage hardware sharing.
fn ffn_pipelined_prgs(n_large: usize) -> Vec<Prg> {
    let per_lb = (n_large / 2).max(1);
    vec![
        Prg { kind: PrgKind::Ffn1Lb, atb_index: 0, pus: vec![(PuClass::Large, per_lb)] },
        Prg { kind: PrgKind::Ffn2Lb, atb_index: 0, pus: vec![(PuClass::Large, per_lb)] },
    ]
}

/// Serial allocation (Limited-AIE): one shared PU pool, every PRG uses it
/// in turn.
fn serial_prgs(pool: &[(PuClass, usize)], independent_linear: bool, mha: bool) -> Vec<Prg> {
    let mut prgs = Vec::new();
    if mha {
        let lb_kinds: Vec<PrgKind> = if independent_linear {
            vec![PrgKind::QkvLb]
        } else {
            vec![PrgKind::QLb, PrgKind::KLb, PrgKind::VLb]
        };
        for kind in lb_kinds {
            prgs.push(Prg { kind, atb_index: 0, pus: pool.to_vec() });
        }
        prgs.push(Prg { kind: PrgKind::AtbPre, atb_index: 0, pus: pool.to_vec() });
        prgs.push(Prg { kind: PrgKind::AtbPost, atb_index: 0, pus: pool.to_vec() });
        prgs.push(Prg { kind: PrgKind::ProjLb, atb_index: 0, pus: pool.to_vec() });
    } else {
        prgs.push(Prg { kind: PrgKind::Ffn1Lb, atb_index: 0, pus: pool.to_vec() });
        prgs.push(Prg { kind: PrgKind::Ffn2Lb, atb_index: 0, pus: pool.to_vec() });
    }
    prgs
}

/// Largest PU mix that fits a core budget (used by serial mode).
fn best_pool_for(budget: usize) -> Vec<(PuClass, usize)> {
    for class in [PuClass::Large, PuClass::Standard, PuClass::Small] {
        let cores = PuSpec::by_class(class).cores();
        if budget >= cores {
            return vec![(class, budget / cores)];
        }
    }
    vec![(PuClass::Small, 1)]
}

/// Top-level: derive a customized accelerator (the "top-down" strategy).
pub fn customize(
    model: &ModelConfig,
    hw: &HardwareConfig,
    opts: &CustomizeOptions,
) -> Result<AcceleratorPlan> {
    model.validate()?;
    let bytes = model.bytes_per_elem();

    // --- Eq. 3 / Eq. 4: PU scale attributes ---
    let mmsz = eq3_mmsz(hw, bytes);
    let plio = eq4_plio_aie(hw, mmsz, bytes);
    if mmsz < 2 {
        return Err(anyhow!("window memory too small for any tile"));
    }

    let independent_linear = opts.independent_linear.unwrap_or(true);

    // --- Eq. 7 / Eq. 8: ATB parallelism ---
    let p_atb = match opts.p_atb {
        Some(p) => p.clamp(1, model.heads),
        None => derived_p_atb(model, hw, mmsz, plio),
    };

    // --- Eq. 5 / Eq. 6: parallel modes ---
    let f1_mha = factor1_mha(model, hw, mmsz, plio);
    let f2_mha = factor2_mha_bytes(model, mmsz, plio, p_atb);
    let f1_ffn = factor1_ffn(model, hw, mmsz, plio);
    let f2_ffn = factor2_ffn_bytes(model, mmsz);

    let mut mha_mode = opts
        .force_mha_mode
        .unwrap_or_else(|| decide_mode(f1_mha, f2_mha, hw));
    let mut ffn_mode = opts
        .force_ffn_mode
        .unwrap_or_else(|| decide_mode(f1_ffn, f2_ffn, hw));

    // The pipelined allocation needs 4 Large + p_atb*(2 Small + 1 Standard)
    // cores; if the board cannot host it, fall back to serial (this is
    // exactly what the Limited-AIE configuration exercises).
    let pipelined_cores = 4 * PuSpec::by_class(PuClass::Large).cores()
        + p_atb
            * (2 * PuSpec::by_class(PuClass::Small).cores()
                + PuSpec::by_class(PuClass::Standard).cores());
    if hw.total_aie < pipelined_cores && opts.force_mha_mode.is_none() {
        mha_mode = ParallelMode::Serial;
    }
    if hw.total_aie < 4 * PuSpec::by_class(PuClass::Large).cores()
        && opts.force_ffn_mode.is_none()
    {
        ffn_mode = ParallelMode::Serial;
    }

    // --- PRG construction + PU allocation ---
    let mha = match mha_mode {
        ParallelMode::FullyPipelined => StagePlan {
            mode: mha_mode,
            prgs: mha_pipelined_prgs(independent_linear, p_atb),
        },
        ParallelMode::SerialHybrid => {
            // LBs serial with the whole pool; ATBs split the pool p_atb ways
            let pool = best_pool_for(hw.total_aie);
            let mut prgs = serial_prgs(&pool, independent_linear, true);
            // mark ATB PRGs as parallel instances
            let per_atb = best_pool_for(hw.total_aie / p_atb.max(1));
            prgs.retain(|p| !p.kind.is_atb());
            for i in 0..p_atb {
                prgs.push(Prg { kind: PrgKind::AtbPre, atb_index: i, pus: per_atb.clone() });
                prgs.push(Prg { kind: PrgKind::AtbPost, atb_index: i, pus: per_atb.clone() });
            }
            StagePlan { mode: mha_mode, prgs }
        }
        ParallelMode::Serial => StagePlan {
            mode: mha_mode,
            prgs: serial_prgs(&best_pool_for(hw.total_aie), independent_linear, true),
        },
    };

    let n_large_mha = mha
        .prgs
        .iter()
        .flat_map(|p| p.pus.iter())
        .filter(|(c, _)| *c == PuClass::Large)
        .map(|(_, n)| n)
        .sum::<usize>()
        .max(1);

    let ffn = match ffn_mode {
        ParallelMode::FullyPipelined | ParallelMode::SerialHybrid => StagePlan {
            mode: ParallelMode::FullyPipelined,
            prgs: ffn_pipelined_prgs(n_large_mha.min(4)),
        },
        ParallelMode::Serial => StagePlan {
            mode: ffn_mode,
            prgs: serial_prgs(&best_pool_for(hw.total_aie), independent_linear, false),
        },
    };

    // --- Table V resource estimate ---
    let wl = layer_workload(model, mmsz, independent_linear);
    let res_mha = resources::estimate_stage_resources(StageKind::Mha, &mha, &wl, p_atb);
    let res_ffn = resources::estimate_stage_resources(StageKind::Ffn, &ffn, &wl, p_atb);
    // Stages share hardware; shared fraction calibrated to Table V's
    // "overall < sum of stages".
    let res_overall = res_mha.union_shared(&res_ffn, 0.70);

    let plan = AcceleratorPlan {
        model: model.clone(),
        hw: hw.clone(),
        mmsz,
        plio_aie: plio,
        independent_linear,
        p_atb,
        mha,
        ffn,
        factor1_mha: f1_mha,
        factor2_mha_bytes: f2_mha,
        factor1_ffn: f1_ffn,
        factor2_ffn_bytes: f2_ffn,
        res_mha,
        res_ffn,
        res_overall,
    };

    // Feasibility invariants
    if plan.cores_deployed() > hw.total_aie {
        return Err(anyhow!(
            "allocation exceeds AIE budget: {} > {}",
            plan.cores_deployed(),
            hw.total_aie
        ));
    }
    let _ = wl.mms_at(MmSite::AtbPre);
    Ok(plan)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bert() -> ModelConfig {
        ModelConfig::bert_base()
    }

    fn vck() -> HardwareConfig {
        HardwareConfig::vck5000()
    }

    #[test]
    fn eq3_gives_64_on_vck5000() {
        // 64^2 * 1B = 4 KiB <= 32 KiB / 4 = 8 KiB; 128^2 = 16 KiB > 8 KiB.
        assert_eq!(eq3_mmsz(&vck(), 1), 64);
    }

    #[test]
    fn eq3_scales_with_window() {
        let mut hw = vck();
        hw.window_bytes = 8 * 1024; // budget 2 KiB -> 32x32 int8
        assert_eq!(eq3_mmsz(&hw, 1), 32);
        // int16 exactly fills the quarter window at 64 (64^2*2 = 8 KiB):
        assert_eq!(eq3_mmsz(&vck(), 2), 64);
        assert_eq!(eq3_mmsz(&vck(), 4), 32); // fp32 halves the edge
    }

    #[test]
    fn eq4_gives_4_on_vck5000() {
        assert_eq!(eq4_plio_aie(&vck(), 64, 1), 4);
    }

    #[test]
    fn design_case_factor1() {
        // §V.B: Factor1 = 1.5 (paper, 1 dp); exact arithmetic gives
        // 4*256*768^2 / (25 * 256^3) = 1.44.
        let f1 = factor1_mha(&bert(), &vck(), 64, 4);
        assert!((f1 - 1.44).abs() < 0.01, "{f1}");
        assert!(f1 < 4.0); // < PRG_MAX_Pipeline_Depth -> fully pipelined
    }

    #[test]
    fn design_case_factor2_is_7_5625_mib() {
        let f2 = factor2_mha_bytes(&bert(), 64, 4, 4);
        assert_eq!(f2, 7_929_856); // = 7.5625 MiB, the paper's number
        assert!((f2 as f64 / (1024.0 * 1024.0) - 7.5625).abs() < 1e-9);
    }

    #[test]
    fn design_case_p_atb_4() {
        assert_eq!(eq7_p_atb(&bert(), 64, 4), Some(4));
    }

    #[test]
    fn design_case_full_plan() {
        // The §V.B walk-through end to end.
        let plan = customize(&bert(), &vck(), &CustomizeOptions::default()).unwrap();
        assert_eq!(plan.mmsz, 64);
        assert_eq!(plan.plio_aie, 4);
        assert_eq!(plan.p_atb, 4);
        assert_eq!(plan.mha.mode, ParallelMode::FullyPipelined);
        assert_eq!(plan.mha.cores_deployed(), 352); // §V.C
        assert!((plan.deployment_rate() - 0.88).abs() < 1e-9);
        // FFN reuses 4 Large PUs = 256 cores
        assert_eq!(plan.ffn.cores_deployed(), 256);
    }

    #[test]
    fn vit_plan_matches_bert_structure() {
        let plan = customize(&ModelConfig::vit_base(), &vck(), &CustomizeOptions::default())
            .unwrap();
        assert_eq!(plan.mha.cores_deployed(), 352);
        assert_eq!(plan.p_atb, 4);
        assert_eq!(plan.mha.mode, ParallelMode::FullyPipelined);
    }

    #[test]
    fn limited_aie_goes_serial() {
        let hw = HardwareConfig::vck5000_limited(64);
        let plan = customize(&bert(), &hw, &CustomizeOptions::default()).unwrap();
        assert_eq!(plan.mha.mode, ParallelMode::Serial);
        assert_eq!(plan.cores_deployed(), 64);
        assert!((plan.deployment_rate() - 1.0).abs() < 1e-9); // Table V: 100%
        // serial mode keeps buffers small: no URAM (Table V row 3)
        assert_eq!(plan.res_overall.urams, 0);
    }

    #[test]
    fn tiny_budget_still_feasible() {
        let hw = HardwareConfig::vck5000_limited(4);
        let plan = customize(&bert(), &hw, &CustomizeOptions::default()).unwrap();
        assert!(plan.cores_deployed() <= 4);
    }

    #[test]
    fn huge_model_forces_serial_hybrid() {
        let mut m = bert();
        m.seq_len = 4096;
        m.embed_dim = 4096;
        m.dff = 16384;
        m.heads = 64;
        let plan = customize(&m, &vck(), &CustomizeOptions::default()).unwrap();
        assert_ne!(plan.mha.mode, ParallelMode::FullyPipelined);
    }

    #[test]
    fn overrides_respected() {
        let opts = CustomizeOptions {
            independent_linear: Some(false),
            p_atb: Some(1),
            force_mha_mode: Some(ParallelMode::SerialHybrid),
            force_ffn_mode: None,
        };
        let plan = customize(&bert(), &vck(), &opts).unwrap();
        assert!(!plan.independent_linear);
        assert_eq!(plan.p_atb, 1);
        assert_eq!(plan.mha.mode, ParallelMode::SerialHybrid);
    }

    #[test]
    fn knob_domains_cover_the_derived_plan() {
        let d = knob_domains(&bert(), &vck());
        // head divisors of 12 (the Eq. 7 value 4 is one of them)
        assert_eq!(d.p_atb, vec![1, 2, 3, 4, 6, 12]);
        assert!(d.independent_linear.contains(&true));
        assert!(d.mha_modes.contains(&None));
        assert!(d.ffn_modes.contains(&None));
        // a model whose Eq. 8 fallback is not a head divisor still appears
        let mut m = bert();
        m.heads = 11;
        m.embed_dim = 704; // head_dim 64
        let d2 = knob_domains(&m, &vck());
        assert!(d2.p_atb.contains(&4), "{:?}", d2.p_atb); // 256/64 via Eq. 7
        assert!(d2.p_atb.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn plan_json_exports() {
        let plan = customize(&bert(), &vck(), &CustomizeOptions::default()).unwrap();
        let j = plan.to_json();
        assert_eq!(j.get("p_atb").unwrap().as_usize(), Some(4));
        assert_eq!(j.get("aie_deployed").unwrap().as_usize(), Some(352));
    }
}
