//! Observability: virtual-clock tracing + deterministic metrics.
//!
//! The layer is zero-cost when off: the serving loop and DSE carry an
//! `Option<&mut Obs>` and every emission site is gated on it, so the
//! flag-off path allocates nothing and the emitted reports stay
//! byte-identical to the uninstrumented build (pinned by
//! `rust/tests/obs_properties.rs`).
//!
//! * [`trace::TraceSink`] — structured events in integer-ns virtual
//!   time, exported as Chrome trace-event JSON (`--trace out.json`,
//!   loadable in Perfetto).
//! * [`metrics::MetricsRegistry`] — counters/gauges + fixed log2
//!   histograms, emitted as the `cat-obs-v1` document
//!   (`--metrics out.json`).
//!
//! Metric names are contracts across implementation swaps: the
//! `serve.route_scanned` histogram means "admission candidates
//! considered in cost order, counting skipped-down positions" whether
//! the linear-scan oracle (`serve::router::route`) or the event-driven
//! `serve::AdmissionIndex` hot path produced the decision — both count
//! probes identically, so recorded distributions stay comparable across
//! versions.
//!
//! A few subsystems (stage-sim cache, DES fast-forward coverage,
//! `par_map` occupancy) count globally because they run under worker
//! threads with no `Obs` in reach; [`Snapshot`] brackets a traced
//! region so the registry reports deltas, not process lifetime totals.

pub mod metrics;
pub mod trace;

use std::sync::atomic::{AtomicU64, Ordering};

pub use metrics::{LogHistogram, MetricsRegistry, HIST_BUCKETS};
pub use trace::{TraceSink, PID_DSE, PID_SERVE};

// Stage-run coverage: every `sched::run_stage` records how many DES
// invocations the engine fast-forwarded (SimReport.fast_forwarded),
// including cache-hit returns (the cached report keeps its counts).
static STAGE_RUNS: AtomicU64 = AtomicU64::new(0);
static FAST_FORWARDED: AtomicU64 = AtomicU64::new(0);

/// Called by the scheduler on every stage report (computed or cached).
pub fn record_stage_run(fast_forwarded: u64) {
    STAGE_RUNS.fetch_add(1, Ordering::Relaxed);
    FAST_FORWARDED.fetch_add(fast_forwarded, Ordering::Relaxed);
}

/// `(stage runs, fast-forwarded invocations)` since process start.
pub fn stage_run_totals() -> (u64, u64) {
    (STAGE_RUNS.load(Ordering::Relaxed), FAST_FORWARDED.load(Ordering::Relaxed))
}

/// Test hook: zero the stage-run totals.
pub fn reset_stage_run_totals() {
    STAGE_RUNS.store(0, Ordering::Relaxed);
    FAST_FORWARDED.store(0, Ordering::Relaxed);
}

/// Point-in-time copy of the process-global observability counters.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Snapshot {
    pub stage_cache_hits: u64,
    pub stage_cache_misses: u64,
    pub stage_runs: u64,
    pub fast_forwarded: u64,
    pub par_calls: u64,
    pub par_items: u64,
    pub par_worker_launches: u64,
}

/// Snapshot the global counters now.
pub fn snapshot() -> Snapshot {
    let (hits, misses) = crate::sched::stage_cache_stats();
    let (runs, ff) = stage_run_totals();
    let (calls, items, workers) = crate::util::par::par_stats();
    Snapshot {
        stage_cache_hits: hits,
        stage_cache_misses: misses,
        stage_runs: runs,
        fast_forwarded: ff,
        par_calls: calls,
        par_items: items,
        par_worker_launches: workers,
    }
}

/// Handle threaded through serve/DSE entry points: either side can be
/// on independently (`--trace` vs `--metrics`).
#[derive(Debug, Default)]
pub struct Obs {
    pub trace: Option<TraceSink>,
    pub metrics: Option<MetricsRegistry>,
    baseline: Option<Snapshot>,
}

impl Obs {
    /// Build a handle with the requested sides enabled.  Captures a
    /// baseline [`Snapshot`] so the filled registry reports counter
    /// deltas over the observed region.
    pub fn new(trace: bool, metrics: bool) -> Obs {
        Obs {
            trace: trace.then(TraceSink::new),
            metrics: metrics.then(MetricsRegistry::new),
            baseline: Some(snapshot()),
        }
    }

    pub fn tracing(&self) -> bool {
        self.trace.is_some()
    }

    pub fn metering(&self) -> bool {
        self.metrics.is_some()
    }

    /// Record the global-counter deltas since `Obs::new` into the
    /// registry (stage-cache traffic, fast-forward coverage, par_map
    /// occupancy).  Saturating: a concurrent `reset_stage_cache` in
    /// another thread clamps to zero instead of wrapping.
    pub fn record_global_deltas(&mut self) {
        let Some(m) = self.metrics.as_mut() else { return };
        let base = self.baseline.unwrap_or(Snapshot {
            stage_cache_hits: 0,
            stage_cache_misses: 0,
            stage_runs: 0,
            fast_forwarded: 0,
            par_calls: 0,
            par_items: 0,
            par_worker_launches: 0,
        });
        let now = snapshot();
        m.add("sched.stage_cache_hits", now.stage_cache_hits.saturating_sub(base.stage_cache_hits));
        m.add(
            "sched.stage_cache_misses",
            now.stage_cache_misses.saturating_sub(base.stage_cache_misses),
        );
        m.add("sched.stage_runs", now.stage_runs.saturating_sub(base.stage_runs));
        m.add("sim.fast_forwarded", now.fast_forwarded.saturating_sub(base.fast_forwarded));
        m.add("par.calls", now.par_calls.saturating_sub(base.par_calls));
        m.add("par.items", now.par_items.saturating_sub(base.par_items));
        m.add(
            "par.worker_launches",
            now.par_worker_launches.saturating_sub(base.par_worker_launches),
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn obs_sides_toggle_independently() {
        let o = Obs::new(true, false);
        assert!(o.tracing() && !o.metering());
        let o = Obs::new(false, true);
        assert!(!o.tracing() && o.metering());
        let o = Obs::new(false, false);
        assert!(!o.tracing() && !o.metering());
    }

    #[test]
    fn global_deltas_land_in_the_registry() {
        let mut o = Obs::new(false, true);
        // other tests run in parallel, so only assert the keys exist
        // and are deltas (>= what this thread contributes: nothing).
        record_stage_run(3);
        o.record_global_deltas();
        let m = o.metrics.as_ref().unwrap();
        assert!(m.counter("sched.stage_runs") >= 1);
        assert!(m.counter("sim.fast_forwarded") >= 3);
        // counters exist even when zero
        let doc = m.to_json().to_string();
        assert!(doc.contains("\"par.calls\""), "{doc}");
        assert!(doc.contains("\"sched.stage_cache_hits\""), "{doc}");
    }
}
