//! Deterministic metrics primitives: counters, gauges, and fixed
//! log2-bucket histograms.
//!
//! Everything here is driven off the virtual clock or plain event
//! counts — no wall-clock reads, and no floating point in bucket
//! boundaries — so a registry filled by a seeded run is byte-stable
//! across hosts and thread counts (modulo process-global counters the
//! caller snapshots; see `obs::Snapshot`).  The registry serializes as
//! the `cat-obs-v1` JSON document consumed by `--metrics <path>`.

use std::collections::BTreeMap;

use crate::util::json::Json;

/// Number of histogram buckets: one for zero plus one per power of
/// two up to `u64::MAX` (bucket `i >= 1` covers `[2^(i-1), 2^i - 1]`).
pub const HIST_BUCKETS: usize = 65;

/// Fixed log2-bucket histogram over `u64` samples (virtual-clock
/// nanoseconds, queue depths, batch sizes...).  Bucket boundaries are
/// integers known at compile time, so two histograms fed the same
/// samples are bit-identical regardless of insertion order, and merge
/// is plain element-wise addition (associative and commutative).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LogHistogram {
    counts: [u64; HIST_BUCKETS],
    sum: u64,
}

impl Default for LogHistogram {
    // [u64; 65] has no derived Default (arrays stop at 32); spell it out.
    fn default() -> LogHistogram {
        LogHistogram { counts: [0; HIST_BUCKETS], sum: 0 }
    }
}

impl LogHistogram {
    pub fn new() -> LogHistogram {
        LogHistogram::default()
    }

    /// Bucket index for a sample: 0 for 0, else `floor(log2(v)) + 1`.
    pub fn bucket_of(v: u64) -> usize {
        (64 - v.leading_zeros()) as usize
    }

    /// Inclusive lower bound of bucket `i`.
    pub fn bucket_lo(i: usize) -> u64 {
        if i == 0 {
            0
        } else {
            1u64 << (i - 1)
        }
    }

    /// Inclusive upper bound of bucket `i`.
    pub fn bucket_hi(i: usize) -> u64 {
        if i == 0 {
            0
        } else if i >= 64 {
            u64::MAX
        } else {
            (1u64 << i) - 1
        }
    }

    pub fn record(&mut self, v: u64) {
        self.counts[Self::bucket_of(v)] += 1;
        self.sum = self.sum.saturating_add(v);
    }

    /// Element-wise addition; `(a ⊕ b) ⊕ c == a ⊕ (b ⊕ c)`.
    pub fn merge(&mut self, other: &LogHistogram) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += *b;
        }
        self.sum = self.sum.saturating_add(other.sum);
    }

    /// Total number of recorded samples.
    pub fn count(&self) -> u64 {
        self.counts.iter().sum()
    }

    /// Saturating sum of recorded samples.
    pub fn sum(&self) -> u64 {
        self.sum
    }

    pub fn bucket_count(&self, i: usize) -> u64 {
        self.counts[i]
    }

    /// `{"count":N,"sum":S,"buckets":[[lo,hi,count],...]}` with empty
    /// buckets omitted (the document stays small for sparse data).
    pub fn to_json(&self) -> Json {
        let buckets: Vec<Json> = self
            .counts
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .map(|(i, &c)| {
                Json::Arr(vec![
                    Json::Num(Self::bucket_lo(i) as f64),
                    Json::Num(Self::bucket_hi(i) as f64),
                    Json::Num(c as f64),
                ])
            })
            .collect();
        let mut o = BTreeMap::new();
        o.insert("count".into(), Json::Num(self.count() as f64));
        o.insert("sum".into(), Json::Num(self.sum as f64));
        o.insert("buckets".into(), Json::Arr(buckets));
        Json::Obj(o)
    }
}

/// Named counters, gauges, and histograms; serializes as `cat-obs-v1`.
/// BTreeMap keys give a stable field order in the emitted document.
#[derive(Debug, Clone, Default)]
pub struct MetricsRegistry {
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, f64>,
    histograms: BTreeMap<String, LogHistogram>,
}

impl MetricsRegistry {
    pub fn new() -> MetricsRegistry {
        MetricsRegistry::default()
    }

    /// Add `delta` to a named counter (created at zero on first use).
    pub fn add(&mut self, name: &str, delta: u64) {
        *self.counters.entry(name.to_string()).or_insert(0) += delta;
    }

    /// Current counter value (0 when never touched).
    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    /// Set a named gauge (last write wins).
    pub fn set_gauge(&mut self, name: &str, v: f64) {
        self.gauges.insert(name.to_string(), v);
    }

    pub fn gauge(&self, name: &str) -> Option<f64> {
        self.gauges.get(name).copied()
    }

    /// Record one sample into a named histogram.
    pub fn record(&mut self, name: &str, v: u64) {
        self.histograms.entry(name.to_string()).or_default().record(v);
    }

    pub fn histogram(&self, name: &str) -> Option<&LogHistogram> {
        self.histograms.get(name)
    }

    /// Fold another registry in: counters add, gauges last-write-wins,
    /// histograms merge.
    pub fn merge(&mut self, other: &MetricsRegistry) {
        for (k, v) in &other.counters {
            *self.counters.entry(k.clone()).or_insert(0) += v;
        }
        for (k, v) in &other.gauges {
            self.gauges.insert(k.clone(), *v);
        }
        for (k, h) in &other.histograms {
            self.histograms.entry(k.clone()).or_default().merge(h);
        }
    }

    /// The `cat-obs-v1` document.
    pub fn to_json(&self) -> Json {
        let counters: BTreeMap<String, Json> =
            self.counters.iter().map(|(k, &v)| (k.clone(), Json::Num(v as f64))).collect();
        let gauges: BTreeMap<String, Json> =
            self.gauges.iter().map(|(k, &v)| (k.clone(), Json::Num(v))).collect();
        let hists: BTreeMap<String, Json> =
            self.histograms.iter().map(|(k, h)| (k.clone(), h.to_json())).collect();
        let mut o = BTreeMap::new();
        o.insert("schema".into(), Json::Str("cat-obs-v1".into()));
        o.insert("counters".into(), Json::Obj(counters));
        o.insert("gauges".into(), Json::Obj(gauges));
        o.insert("histograms".into(), Json::Obj(hists));
        Json::Obj(o)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_edges() {
        assert_eq!(LogHistogram::bucket_of(0), 0);
        assert_eq!(LogHistogram::bucket_of(1), 1);
        assert_eq!(LogHistogram::bucket_of(2), 2);
        assert_eq!(LogHistogram::bucket_of(3), 2);
        assert_eq!(LogHistogram::bucket_of(4), 3);
        assert_eq!(LogHistogram::bucket_of(u64::MAX), 64);
        // zero lands in its own bucket
        assert_eq!(LogHistogram::bucket_lo(0), 0);
        assert_eq!(LogHistogram::bucket_hi(0), 0);
        // the top bucket reaches u64::MAX
        assert_eq!(LogHistogram::bucket_hi(64), u64::MAX);
    }

    #[test]
    fn bucket_boundaries_are_monotone_and_contiguous() {
        for i in 1..HIST_BUCKETS {
            assert_eq!(
                LogHistogram::bucket_lo(i),
                LogHistogram::bucket_hi(i - 1).wrapping_add(1),
                "bucket {i} lower bound must follow bucket {} upper bound",
                i - 1
            );
            assert!(LogHistogram::bucket_hi(i) >= LogHistogram::bucket_lo(i));
        }
        // every sample lands inside its bucket's bounds
        for v in [0u64, 1, 2, 3, 7, 8, 1023, 1024, u64::MAX - 1, u64::MAX] {
            let i = LogHistogram::bucket_of(v);
            assert!(v >= LogHistogram::bucket_lo(i) && v <= LogHistogram::bucket_hi(i));
        }
    }

    #[test]
    fn record_counts_and_saturating_sum() {
        let mut h = LogHistogram::new();
        h.record(0);
        h.record(1);
        h.record(u64::MAX);
        h.record(u64::MAX); // sum saturates instead of wrapping
        assert_eq!(h.count(), 4);
        assert_eq!(h.sum(), u64::MAX);
        assert_eq!(h.bucket_count(0), 1);
        assert_eq!(h.bucket_count(1), 1);
        assert_eq!(h.bucket_count(64), 2);
    }

    #[test]
    fn merge_is_associative() {
        let fill = |vals: &[u64]| {
            let mut h = LogHistogram::new();
            for &v in vals {
                h.record(v);
            }
            h
        };
        let a = fill(&[0, 5, 17, 1 << 40]);
        let b = fill(&[3, 3, 900]);
        let c = fill(&[u64::MAX, 1]);
        let mut left = a.clone();
        left.merge(&b);
        left.merge(&c);
        let mut bc = b.clone();
        bc.merge(&c);
        let mut right = a.clone();
        right.merge(&bc);
        assert_eq!(left, right);
        assert_eq!(left.count(), a.count() + b.count() + c.count());
    }

    #[test]
    fn registry_document_shape() {
        let mut m = MetricsRegistry::new();
        m.add("serve.submitted", 10);
        m.add("serve.submitted", 5);
        m.set_gauge("serve.shed_rate", 0.25);
        m.record("serve.latency_ns", 1500);
        m.record("serve.latency_ns", 0);
        assert_eq!(m.counter("serve.submitted"), 15);
        assert_eq!(m.counter("never.touched"), 0);
        let doc = m.to_json().to_string();
        assert!(doc.contains("\"schema\":\"cat-obs-v1\""), "{doc}");
        assert!(doc.contains("\"serve.submitted\":15"), "{doc}");
        assert!(doc.contains("\"serve.shed_rate\":0.25"), "{doc}");
        assert!(doc.contains("\"serve.latency_ns\""), "{doc}");
        // only non-empty buckets are emitted: zero-bucket + [1024,2047]
        let parsed = Json::parse(&doc).unwrap();
        let buckets =
            parsed.path(&["histograms", "serve.latency_ns", "buckets"]).and_then(Json::as_arr);
        assert_eq!(buckets.map(<[Json]>::len), Some(2));
    }

    #[test]
    fn registry_merge_folds_counters_and_histograms() {
        let mut a = MetricsRegistry::new();
        a.add("c", 2);
        a.record("h", 10);
        let mut b = MetricsRegistry::new();
        b.add("c", 3);
        b.record("h", 20);
        b.set_gauge("g", 1.5);
        a.merge(&b);
        assert_eq!(a.counter("c"), 5);
        assert_eq!(a.histogram("h").unwrap().count(), 2);
        assert_eq!(a.gauge("g"), Some(1.5));
    }
}
