//! Chrome trace-event sink on the virtual clock.
//!
//! `TraceSink` buffers structured events stamped in integer virtual
//! nanoseconds and exports the Chrome trace-event JSON format
//! (`{"traceEvents":[...]}`) that Perfetto and `chrome://tracing`
//! load directly.  Timestamps convert to microseconds only at export
//! (the format's unit); the division by 1000 is exact for the `.5`/
//! `.25` fractions the integer clock can produce, so the emitted text
//! is byte-reproducible per seed.
//!
//! Export sorts events by `(virtual time, emission order)` with
//! metadata first, so per-track timestamps are monotone in file order
//! no matter when the simulator learned about an interval (e.g. batch
//! service spans are only known at retirement).

use std::collections::BTreeMap;

use crate::util::json::Json;

/// Trace process id for the serving loop's tracks.
pub const PID_SERVE: u32 = 1;
/// Trace process id for the DSE synthetic timeline.
pub const PID_DSE: u32 = 2;

#[derive(Debug, Clone)]
struct TraceEvent {
    name: String,
    cat: &'static str,
    ph: char,
    ts_ns: u64,
    dur_ns: Option<u64>,
    pid: u32,
    tid: u32,
    args: Vec<(String, Json)>,
}

/// Buffer of virtual-clock trace events.
#[derive(Debug, Clone, Default)]
pub struct TraceSink {
    events: Vec<TraceEvent>,
}

impl TraceSink {
    pub fn new() -> TraceSink {
        TraceSink::default()
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Complete event (`ph:"X"`): an interval `[ts, ts+dur)` on one track.
    pub fn complete(
        &mut self,
        name: &str,
        cat: &'static str,
        pid: u32,
        tid: u32,
        ts_ns: u64,
        dur_ns: u64,
        args: Vec<(String, Json)>,
    ) {
        self.events.push(TraceEvent {
            name: name.to_string(),
            cat,
            ph: 'X',
            ts_ns,
            dur_ns: Some(dur_ns),
            pid,
            tid,
            args,
        });
    }

    /// Instant event (`ph:"i"`, thread scope): a point on one track.
    pub fn instant(
        &mut self,
        name: &str,
        cat: &'static str,
        pid: u32,
        tid: u32,
        ts_ns: u64,
        args: Vec<(String, Json)>,
    ) {
        self.events.push(TraceEvent {
            name: name.to_string(),
            cat,
            ph: 'i',
            ts_ns,
            dur_ns: None,
            pid,
            tid,
            args,
        });
    }

    /// Counter event (`ph:"C"`): every arg is a numeric series sample.
    pub fn counter(
        &mut self,
        name: &str,
        cat: &'static str,
        pid: u32,
        tid: u32,
        ts_ns: u64,
        args: Vec<(String, Json)>,
    ) {
        self.events.push(TraceEvent {
            name: name.to_string(),
            cat,
            ph: 'C',
            ts_ns,
            dur_ns: None,
            pid,
            tid,
            args,
        });
    }

    /// `process_name` metadata: labels a pid in the Perfetto UI.
    pub fn process_name(&mut self, pid: u32, name: &str) {
        self.metadata("process_name", pid, 0, name);
    }

    /// `thread_name` metadata: labels a (pid, tid) track.
    pub fn thread_name(&mut self, pid: u32, tid: u32, name: &str) {
        self.metadata("thread_name", pid, tid, name);
    }

    fn metadata(&mut self, kind: &str, pid: u32, tid: u32, name: &str) {
        self.events.push(TraceEvent {
            name: kind.to_string(),
            cat: "__metadata",
            ph: 'M',
            ts_ns: 0,
            dur_ns: None,
            pid,
            tid,
            args: vec![("name".to_string(), Json::Str(name.to_string()))],
        });
    }

    /// Export as `{"traceEvents":[...]}`.  Events are ordered by
    /// `(ts, emission order)` with metadata first; `ts`/`dur` are in
    /// microseconds per the trace-event spec (exact division of the
    /// integer-ns clock, so the text is deterministic).
    pub fn to_json(&self) -> Json {
        let mut order: Vec<usize> = (0..self.events.len()).collect();
        order.sort_by_key(|&i| {
            let e = &self.events[i];
            (e.ph != 'M', e.ts_ns, i)
        });
        let events: Vec<Json> = order.iter().map(|&i| event_json(&self.events[i])).collect();
        let mut doc = BTreeMap::new();
        doc.insert("traceEvents".to_string(), Json::Arr(events));
        Json::Obj(doc)
    }
}

fn event_json(e: &TraceEvent) -> Json {
    let mut o = BTreeMap::new();
    o.insert("name".to_string(), Json::Str(e.name.clone()));
    o.insert("cat".to_string(), Json::Str(e.cat.to_string()));
    o.insert("ph".to_string(), Json::Str(e.ph.to_string()));
    o.insert("pid".to_string(), Json::Num(f64::from(e.pid)));
    o.insert("tid".to_string(), Json::Num(f64::from(e.tid)));
    if e.ph != 'M' {
        o.insert("ts".to_string(), Json::Num(e.ts_ns as f64 / 1000.0));
    }
    if let Some(d) = e.dur_ns {
        o.insert("dur".to_string(), Json::Num(d as f64 / 1000.0));
    }
    if e.ph == 'i' {
        // thread-scoped instant: renders as a tick on its own track
        o.insert("s".to_string(), Json::Str("t".to_string()));
    }
    if !e.args.is_empty() {
        let args: BTreeMap<String, Json> = e.args.iter().cloned().collect();
        o.insert("args".to_string(), Json::Obj(args));
    }
    Json::Obj(o)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn export_sorts_by_virtual_time_with_metadata_first() {
        let mut t = TraceSink::new();
        t.complete("late", "serve", PID_SERVE, 1, 5_000, 2_000, vec![]);
        t.instant("early", "serve", PID_SERVE, 0, 1_000, vec![]);
        t.process_name(PID_SERVE, "cat serve");
        let doc = t.to_json();
        let evs = doc.get("traceEvents").and_then(Json::as_arr).unwrap();
        assert_eq!(evs.len(), 3);
        assert_eq!(evs[0].get("ph").and_then(Json::as_str), Some("M"));
        assert_eq!(evs[1].get("name").and_then(Json::as_str), Some("early"));
        assert_eq!(evs[2].get("name").and_then(Json::as_str), Some("late"));
    }

    #[test]
    fn timestamps_export_as_exact_microseconds() {
        let mut t = TraceSink::new();
        t.instant("p", "serve", PID_SERVE, 0, 1_500, vec![]);
        let doc = t.to_json().to_string();
        // 1500 ns = 1.5 µs, printed exactly
        assert!(doc.contains("\"ts\":1.5"), "{doc}");
        // whole microseconds print as integers (Json::Num i64 fast path)
        let mut t2 = TraceSink::new();
        t2.complete("q", "serve", PID_SERVE, 0, 2_000, 1_000, vec![]);
        let doc2 = t2.to_json().to_string();
        assert!(doc2.contains("\"ts\":2"), "{doc2}");
        assert!(doc2.contains("\"dur\":1"), "{doc2}");
    }

    #[test]
    fn instant_and_counter_shapes() {
        let mut t = TraceSink::new();
        t.instant(
            "shed",
            "serve",
            PID_SERVE,
            0,
            10,
            vec![("reason".to_string(), Json::Str("slo".to_string()))],
        );
        let depth = vec![("in_flight".to_string(), Json::Num(3.0))];
        t.counter("queue", "serve", PID_SERVE, 1, 20, depth);
        let doc = t.to_json().to_string();
        assert!(doc.contains("\"ph\":\"i\""), "{doc}");
        assert!(doc.contains("\"s\":\"t\""), "{doc}");
        assert!(doc.contains("\"ph\":\"C\""), "{doc}");
        assert!(doc.contains("\"in_flight\":3"), "{doc}");
        assert!(!t.is_empty());
        assert_eq!(t.len(), 2);
    }
}
