//! Comparator accelerators for Table VII.
//!
//! Two kinds:
//!
//! * **Published numbers** — the rows the paper itself compares against
//!   (A10G/TensorRT, ViA, Auto-ViT-Acc, SSR, NPE).  The paper's Table VII
//!   compares *its* measurement to *their* published throughput/energy;
//!   we reproduce the table the same way, substituting our simulated CAT
//!   numbers.
//! * **Scheduling-style baselines on our own substrate** — CHARM-style
//!   (one generic MM accelerator called per operator, DRAM round-trips
//!   between calls) and SSR-style (uniform PU array, spatial-sequential,
//!   no per-model customization), so the "customization wins" claim can
//!   be tested like-for-like on the same simulated board.

use crate::config::{HardwareConfig, ModelConfig};
use crate::workload::{layer_workload, Workload};

/// One published comparator row (Table VII).
#[derive(Debug, Clone)]
pub struct PublishedAccel {
    pub name: &'static str,
    pub platform: &'static str,
    pub design: &'static str,
    pub frequency: &'static str,
    pub precision: &'static str,
    pub tops: f64,
    pub gops_per_w: f64,
    /// Which comparison groups this row belongs to.
    pub groups: &'static [&'static str],
}

/// The paper's Table VII comparator set (published numbers).
pub fn published() -> Vec<PublishedAccel> {
    vec![
        PublishedAccel {
            name: "TensorRT",
            platform: "NVIDIA A10G",
            design: "TensorRT [16]",
            frequency: "1.71GHz",
            precision: "FP32",
            tops: 14.630,
            gops_per_w: 66.79,
            groups: &["peak"],
        },
        PublishedAccel {
            name: "ViA",
            platform: "Alveo U50",
            design: "ViA [25]",
            frequency: "300MHz",
            precision: "FP16",
            tops: 0.309,
            gops_per_w: 7.92,
            groups: &["peak", "vit"],
        },
        PublishedAccel {
            name: "Auto-ViT-Acc",
            platform: "ZCU102",
            design: "Auto-ViT-Acc [19]",
            frequency: "150MHz",
            precision: "FIX8",
            tops: 0.711,
            gops_per_w: 84.10,
            groups: &["peak", "vit"],
        },
        PublishedAccel {
            name: "SSR",
            platform: "VCK190",
            design: "SSR [14] (FPGA'24)",
            frequency: "AIE:1GHz PL:230MHz",
            precision: "INT8",
            tops: 26.700,
            gops_per_w: 453.32,
            groups: &["peak"],
        },
        PublishedAccel {
            name: "SSR-ViT",
            platform: "VCK190",
            design: "SSR [14] (FPGA'24)",
            frequency: "AIE:1GHz PL:230MHz",
            precision: "INT8",
            tops: 22.030,
            gops_per_w: 360.04,
            groups: &["vit"],
        },
        PublishedAccel {
            name: "NPE",
            platform: "Zynq Z-7100",
            design: "NPE [38]",
            frequency: "200MHz",
            precision: "16-bit",
            tops: 0.208,
            gops_per_w: 10.40,
            groups: &["peak", "bert"],
        },
    ]
}

/// Result of a scheduling-style baseline evaluation.
#[derive(Debug, Clone, Copy)]
pub struct BaselineResult {
    /// End-to-end time for one encoder layer (ns).
    pub layer_ns: f64,
    pub tops: f64,
    /// Estimated average power (W).
    pub power_w: f64,
    pub gops_per_w: f64,
}

/// CHARM-style execution: one monolithic MM accelerator (all AIEs) called
/// once per MM operator, with every operand/result round-tripping DRAM —
/// the paper's critique: "the communication overhead and power waste
/// caused by multiple calls to the operator are very obvious".
pub fn charm_style(model: &ModelConfig, hw: &HardwareConfig) -> BaselineResult {
    let mmsz = 64.min(crate::customize::eq3_mmsz(hw, model.bytes_per_elem()));
    let wl = layer_workload(model, mmsz, false); // no operator fusion
    let t_calc = hw.t_calc_ns(mmsz);
    let dram = hw.dram_bw_gbps; // bytes/ns
    let mut total_ns = 0.0;
    let mut dram_bytes = 0u64;
    for mm in &wl.mms {
        for _ in 0..mm.count {
            let tiles = mm.m.div_ceil(mmsz) * mm.n.div_ceil(mmsz) * mm.k.div_ceil(mmsz);
            let compute = (tiles as f64 / hw.total_aie as f64).ceil() * t_calc;
            // A, B in; C out — int8 in, int8 out (int32 for scores)
            let bytes = (mm.m * mm.k + mm.k * mm.n + mm.m * mm.n) as u64;
            let io = bytes as f64 / dram;
            // per-call overhead: kernel launch + descriptor setup via host
            let launch = 2_000.0;
            total_ns += compute.max(io) + launch;
            dram_bytes += bytes;
        }
    }
    // nonlinear operators execute on PL between calls (serial)
    for pl in &wl.pls {
        let bytes = pl.bytes();
        total_ns += bytes as f64 / (hw.plio_bits as f64 / 8.0 * hw.pl_freq_mhz * 1e-3 * 8.0);
        dram_bytes += bytes;
    }
    finish(model, hw, &wl, total_ns, dram_bytes, 1.0)
}

/// SSR-style execution: a uniform array of Standard PUs, spatial-sequential
/// scheduling, on-chip between ops, but *no* per-model customization —
/// each operator group pays its own pipeline fill.
pub fn ssr_style(model: &ModelConfig, hw: &HardwareConfig) -> BaselineResult {
    let mmsz = 64.min(crate::customize::eq3_mmsz(hw, model.bytes_per_elem()));
    let wl = layer_workload(model, mmsz, true);
    let t_calc = hw.t_calc_ns(mmsz);
    let beat = t_calc.max(hw.t_window_ns(mmsz, 1) * 4.0);
    let fill = 3.0 * beat;
    let mut total_ns = 0.0;
    for mm in &wl.mms {
        let tiles = mm.count * mm.m.div_ceil(mmsz) * mm.n.div_ceil(mmsz) * mm.k.div_ceil(mmsz);
        let beats = (tiles as f64 / hw.total_aie as f64).ceil();
        total_ns += beats * beat + fill;
    }
    let dram_bytes = 2 * (model.padded_seq_len(mmsz) * model.embed_dim) as u64;
    finish(model, hw, &wl, total_ns, dram_bytes, 0.9)
}

fn finish(
    model: &ModelConfig,
    hw: &HardwareConfig,
    wl: &Workload,
    total_ns: f64,
    dram_bytes: u64,
    running_frac: f64,
) -> BaselineResult {
    let ops = (wl.total_ops() as f64 * model.useful_fraction(wl.mmsz)) as u64;
    let tops = ops as f64 / total_ns / 1e3;
    let pw = crate::sim::power::power(
        hw,
        &crate::sim::power::PowerBreakdownInput {
            aie_deployed: hw.total_aie,
            aie_running_avg: hw.total_aie as f64 * running_frac,
            pl: crate::arch::PlResources { luts: 120_000, ffs: 150_000, brams: 500, urams: 100 },
            dram_gbps: (dram_bytes as f64 / total_ns).min(hw.dram_bw_gbps),
        },
    )
    .total_w();
    BaselineResult {
        layer_ns: total_ns,
        tops,
        power_w: pw,
        gops_per_w: ops as f64 / total_ns / pw,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::customize::{customize, CustomizeOptions};
    use crate::sched::run_edpu;

    #[test]
    fn published_table_complete() {
        let p = published();
        assert_eq!(p.len(), 6);
        let peak: Vec<_> = p.iter().filter(|a| a.groups.contains(&"peak")).collect();
        assert_eq!(peak.len(), 5);
        // SSR is the pre-CAT SOTA
        let ssr = p.iter().find(|a| a.name == "SSR").unwrap();
        assert!((ssr.tops - 26.7).abs() < 1e-9);
    }

    #[test]
    fn cat_beats_charm_and_ssr_styles() {
        // The paper's core claim, like-for-like on our substrate:
        // customized CAT > generic SSR-style > operator-call CHARM-style.
        let m = ModelConfig::bert_base();
        let hw = HardwareConfig::vck5000();
        let charm = charm_style(&m, &hw);
        let ssr = ssr_style(&m, &hw);
        let plan = customize(&m, &hw, &CustomizeOptions::default()).unwrap();
        let cat = run_edpu(&plan, 16).unwrap().tops();
        assert!(cat > ssr.tops, "CAT {cat} <= SSR-style {}", ssr.tops);
        assert!(ssr.tops > charm.tops, "SSR-style {} <= CHARM-style {}", ssr.tops, charm.tops);
    }

    #[test]
    fn charm_is_dram_bound() {
        // CHARM-style should land far below the array's sustained peak.
        let r = charm_style(&ModelConfig::bert_base(), &HardwareConfig::vck5000());
        assert!(r.tops < 30.0, "{}", r.tops);
        assert!(r.tops > 3.0, "{}", r.tops);
    }

    #[test]
    fn ssr_style_near_published_ssr() {
        // SSR-style on VCK190 parameters should land near SSR's published
        // 26.7 TOPS (order-of-magnitude calibration).
        let r = ssr_style(&ModelConfig::bert_base(), &HardwareConfig::vck190());
        assert!(r.tops > 13.0 && r.tops < 45.0, "{}", r.tops);
    }

    #[test]
    fn baseline_power_positive() {
        let r = ssr_style(&ModelConfig::vit_base(), &HardwareConfig::vck5000());
        assert!(r.power_w > 10.0 && r.power_w < 150.0);
        assert!(r.gops_per_w > 0.0);
    }
}
