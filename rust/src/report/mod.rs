//! Renderers that print the paper's tables and figure series from
//! measured (simulated) data.  Each bench target calls one of these; the
//! same functions back the `cat table ...` CLI subcommands.

use crate::arch::AcceleratorPlan;
use crate::baselines::{published, BaselineResult};
use crate::metrics::PerfSummary;
use crate::util::table::{fmt_f, fmt_ratio, Table};

/// Table II row: one ablation lab.
#[derive(Debug, Clone)]
pub struct AblationRow {
    pub lab: &'static str,
    pub independent_linear: bool,
    pub atb_parallel_mode: &'static str,
    pub atb_parallelism: usize,
    pub makespan_ns: f64,
}

/// Render Table II (architecture ablation, speedups vs Lab 1).
pub fn table2(rows: &[AblationRow]) -> String {
    let base = rows
        .first()
        .map(|r| r.makespan_ns)
        .unwrap_or(1.0);
    let mut t = Table::new(
        "Table II — operation efficiency of different EDPU organizations (ViT-Base cfg)",
        &["ID", "Independent Linear", "ATB Parallel Mode", "ATB Parallelism", "Speedup Ratio"],
    );
    for r in rows {
        t.row(&[
            r.lab.to_string(),
            if r.independent_linear { "yes" } else { "no" }.into(),
            r.atb_parallel_mode.into(),
            r.atb_parallelism.to_string(),
            fmt_ratio(base / r.makespan_ns),
        ]);
    }
    t.render()
}

/// Render Table V (hardware resource utilization) for a set of plans.
pub fn table5(plans: &[(&str, &AcceleratorPlan)]) -> String {
    let mut t = Table::new(
        "Table V — hardware resource utilization",
        &["Model", "Module", "LUT", "FF", "BRAM", "URAM", "AIE dep.rate", "AIE eff.util"],
    );
    for (name, plan) in plans {
        let dep = format!(
            "{:.0}% ({} AIEs)",
            plan.deployment_rate() * 100.0,
            plan.cores_deployed()
        );
        let mha_cores = plan.mha.cores_deployed();
        let ffn_cores = plan.ffn.cores_deployed();
        let deployed = plan.cores_deployed().max(1);
        let rows = [
            ("MHA Stage", plan.res_mha, mha_cores),
            ("FFN Stage", plan.res_ffn, ffn_cores),
            ("Overall", plan.res_overall, usize::MAX),
        ];
        for (module, r, running) in rows {
            let eff = if running == usize::MAX {
                let avg = (mha_cores as f64 / deployed as f64
                    + ffn_cores as f64 / deployed as f64)
                    / 2.0;
                format!("{:.0}% (Avg)", avg * 100.0)
            } else {
                format!("{:.0}% ({} AIEs)", running as f64 / deployed as f64 * 100.0, running)
            };
            t.row(&[
                name.to_string(),
                module.into(),
                format!("{:.1}K", r.luts as f64 / 1e3),
                format!("{:.1}K", r.ffs as f64 / 1e3),
                r.brams.to_string(),
                r.urams.to_string(),
                dep.clone(),
                eff,
            ]);
        }
    }
    t.render()
}

/// Render Table VI (peak performance and energy efficiency).
pub fn table6(rows: &[PerfSummary]) -> String {
    let mut t = Table::new(
        "Table VI — peak performance and energy efficiency (batch at saturation)",
        &["Model", "Module", "Latency(ms)", "TOPS", "GOPS/AIE", "Power(W)", "GOPS/W"],
    );
    for s in rows {
        t.row(&[
            s.model.clone(),
            "MHA Stage".into(),
            fmt_f(s.mha_latency_ms, 3),
            fmt_f(s.mha_tops, 3),
            fmt_f(s.mha_gops_per_aie, 1),
            "N/A".into(),
            "N/A".into(),
        ]);
        t.row(&[
            s.model.clone(),
            "FFN Stage".into(),
            fmt_f(s.ffn_latency_ms, 3),
            fmt_f(s.ffn_tops, 3),
            fmt_f(s.ffn_gops_per_aie, 1),
            "N/A".into(),
            "N/A".into(),
        ]);
        t.row(&[
            s.model.clone(),
            "System (EDPU)".into(),
            fmt_f(s.sys_latency_ms, 3),
            fmt_f(s.sys_tops, 3),
            fmt_f(s.sys_gops_per_aie, 1),
            fmt_f(s.power_w, 2),
            fmt_f(s.gops_per_w, 1),
        ]);
    }
    t.render()
}

/// One measured CAT row for Table VII.
#[derive(Debug, Clone)]
pub struct CatRow {
    pub tops: f64,
    pub gops_per_w: f64,
}

/// Render one group of Table VII (peak / ViT / BERT), ratios vs the
/// group's reference row (the paper uses ViA for peak+ViT, NPE for BERT).
pub fn table7_group(group: &str, cat: &CatRow, extra_styles: &[(&str, BaselineResult)]) -> String {
    let rows: Vec<_> = published()
        .into_iter()
        .filter(|a| a.groups.contains(&group))
        .collect();
    let reference = rows
        .iter()
        .find(|a| a.name == if group == "bert" { "NPE" } else { "ViA" })
        .map(|a| (a.tops, a.gops_per_w))
        .unwrap_or((1.0, 1.0));
    let mut t = Table::new(
        &format!("Table VII ({group}) — performance and energy-efficiency comparison"),
        &["Platform", "Design", "Freq", "Prec", "TOPS", "GOPS/W", "Speedup", "EnergyEff Up"],
    );
    for a in &rows {
        t.row(&[
            a.platform.into(),
            a.design.into(),
            a.frequency.into(),
            a.precision.into(),
            fmt_f(a.tops, 3),
            fmt_f(a.gops_per_w, 2),
            fmt_ratio(a.tops / reference.0),
            fmt_ratio(a.gops_per_w / reference.1),
        ]);
    }
    for (name, r) in extra_styles {
        t.row(&[
            "VCK5000 (sim)".into(),
            (*name).into(),
            "AIE:1.25GHz PL:300MHz".into(),
            "INT8".into(),
            fmt_f(r.tops, 3),
            fmt_f(r.gops_per_w, 2),
            fmt_ratio(r.tops / reference.0),
            fmt_ratio(r.gops_per_w / reference.1),
        ]);
    }
    t.row(&[
        "VCK5000 (sim)".into(),
        "CAT (ours)".into(),
        "AIE:1.25GHz PL:300MHz".into(),
        "INT8".into(),
        fmt_f(cat.tops, 3),
        fmt_f(cat.gops_per_w, 2),
        fmt_ratio(cat.tops / reference.0),
        fmt_ratio(cat.gops_per_w / reference.1),
    ]);
    t.render()
}

/// Render a design-space exploration result: the Pareto frontier table
/// plus the accounting line (dominated/duplicate/pruned counts) and the
/// scalarized best-under-constraint pick.
pub fn explore(r: &crate::dse::ExploreResult) -> String {
    let s = &r.stats;
    let title = format!(
        "CAT design-space exploration — Pareto frontier ({} of {} evaluated points; \
         space {}{}, pruned: {} customize / {} AIE / {} PL, {} sim failure(s))",
        r.frontier.len(),
        s.evaluated,
        r.space_size,
        if r.sampled {
            format!(", sampled {}", s.sampled)
        } else {
            String::new()
        },
        s.customize_rejected,
        s.aie_rejected,
        s.pl_rejected,
        s.sim_failed,
    );
    let mut t = Table::new(
        &title,
        &[
            "IL", "MHA mode", "FFN mode", "P_ATB", "batch", "EDPUs", "cores", "PL LUT",
            "TOPS", "lat(ms)", "GOPS/W", "GOPS/AIE",
        ],
    );
    for p in r.frontier_points() {
        t.row(&[
            if p.independent_linear { "yes" } else { "no" }.into(),
            p.mha_mode.to_string(),
            p.ffn_mode.to_string(),
            p.p_atb.to_string(),
            p.cand.batch.to_string(),
            format!("{}x{:?}", p.cand.n_edpu, p.cand.multi_mode),
            p.total_cores.to_string(),
            format!("{:.1}K", p.pl_luts as f64 / 1e3),
            fmt_f(p.tops, 3),
            fmt_f(p.latency_ms, 3),
            fmt_f(p.gops_per_w, 1),
            fmt_f(p.gops_per_aie, 1),
        ]);
    }
    let mut out = t.render();
    out.push_str(&format!(
        "  {} dominated point(s), {} duplicate(s) behind the frontier\n",
        r.dominated, r.duplicates
    ));
    if let Some(i) = r.best_constrained {
        let p = &r.points[i];
        let label = match r.slo_ms {
            Some(x) => format!("best under latency SLO {x} ms"),
            None => "peak-TOPS point".to_string(),
        };
        out.push_str(&format!(
            "  {label}: {:.3} TOPS at {:.3} ms/item, {} cores ({}x{:?}, batch {})\n",
            p.tops, p.latency_ms, p.total_cores, p.cand.n_edpu, p.cand.multi_mode,
            p.cand.batch
        ));
    }
    out
}

/// Render a fleet-serving report: one row per backend, then the
/// fleet-level accounting (tail latencies, shed split, energy-weighted
/// efficiency).
pub fn serve_fleet(r: &crate::serve::FleetReport) -> String {
    let ms = |d: std::time::Duration| d.as_secs_f64() * 1e3;
    let a = &r.admission;
    let title = format!(
        "CAT fleet serving — {} on {}: {} backend(s), {:.0} req/s offered, SLO {} ms, seed {}",
        r.model, r.hw, r.n_backends, r.rps, r.slo_ms, r.seed,
    );
    let mut t = Table::new(
        &title,
        &[
            "BE", "EDPUs", "cores", "power(W)", "GOPS/W", "admitted", "batches", "mean b",
            "util%", "p50(ms)", "p99(ms)",
        ],
    );
    for b in &r.backends {
        t.row(&[
            b.id.to_string(),
            format!("{}x{:?}", b.point.cand.n_edpu, b.point.cand.multi_mode),
            b.point.total_cores.to_string(),
            fmt_f(b.point.power_w, 1),
            fmt_f(b.point.gops_per_w, 1),
            b.admitted.to_string(),
            b.stats.batches.to_string(),
            fmt_f(b.stats.mean_batch(), 2),
            fmt_f(b.utilization(r.wall_ns) * 100.0, 1),
            fmt_f(ms(b.stats.percentile(0.50)), 3),
            fmt_f(ms(b.stats.percentile(0.99)), 3),
        ]);
    }
    let mut out = t.render();
    if r.faults.is_some() {
        out.push_str(&format!(
            "  {} submitted: {} completed, {} shed ({} SLO / {} capacity / {} fault / \
             {} retry-exhausted, rate {:.1}%)\n",
            a.submitted,
            a.completed,
            a.shed(),
            a.shed_slo,
            a.shed_capacity,
            a.shed_fault,
            a.shed_retry,
            a.shed_rate() * 100.0,
        ));
    } else {
        out.push_str(&format!(
            "  {} submitted: {} completed, {} shed ({} SLO / {} capacity, rate {:.1}%)\n",
            a.submitted,
            a.completed,
            a.shed(),
            a.shed_slo,
            a.shed_capacity,
            a.shed_rate() * 100.0,
        ));
    }
    let s = &r.fleet_stats;
    out.push_str(&format!(
        "  fleet p50/p95/p99: {:.3} / {:.3} / {:.3} ms (SLO {} ms, {} violation(s)); \
         {:.0} req/s served over {:.1} ms; {:.1} GOPS/W energy-weighted\n",
        ms(s.percentile(0.50)),
        ms(s.percentile(0.95)),
        ms(s.percentile(0.99)),
        r.slo_ms,
        r.slo_violations,
        s.throughput_rps(),
        r.wall_ns as f64 / 1e6,
        r.fleet_gops_per_w,
    ));
    if let Some(b) = &r.board {
        let pct = |used: usize, total: usize| {
            if total == 0 {
                0.0
            } else {
                used as f64 / total as f64 * 100.0
            }
        };
        let st = &b.stats;
        out.push_str(&format!(
            "  board {}: {}/{} AIE used ({} residual); PL LUT {:.1}% / FF {:.1}% / \
             BRAM {:.1}% / URAM {:.1}%\n",
            b.board,
            b.aie_used,
            b.aie_total,
            b.aie_residual(),
            pct(b.pl_used.luts, b.pl_total.luts),
            pct(b.pl_used.ffs, b.pl_total.ffs),
            pct(b.pl_used.brams, b.pl_total.brams),
            pct(b.pl_used.urams, b.pl_total.urams),
        ));
        out.push_str(&format!(
            "  partition: {} requested -> {} selected of {} candidates \
             ({} subsets: {} AIE-infeasible, {} PL-infeasible, {} feasible{}); \
             objective {:.3} SLO-feasible TOPS\n",
            st.requested,
            st.selected,
            st.candidates,
            st.subsets_considered,
            st.aie_infeasible,
            st.pl_infeasible,
            st.feasible,
            if st.greedy { ", greedy" } else { "" },
            b.objective_tops,
        ));
        if let Some(l) = &b.links {
            let dem = l.demanded();
            let sub = |d: f64, pool: f64| if pool > 0.0 { d / pool * 100.0 } else { 0.0 };
            out.push_str(&format!(
                "  links: DRAM {:.1}/{:.1} GB/s demanded ({:.0}% of pool), \
                 PCIe {:.2}/{:.1} GB/s ({:.0}%){}\n",
                dem.dram_gbps,
                l.pools.dram_gbps,
                sub(dem.dram_gbps, l.pools.dram_gbps),
                dem.pcie_gbps,
                l.pools.pcie_gbps,
                sub(dem.pcie_gbps, l.pools.pcie_gbps),
                if l.throttled() { " — oversubscribed, slices throttled" } else { "" },
            ));
            if l.throttled() {
                let factors: Vec<String> = l
                    .members
                    .iter()
                    .enumerate()
                    .map(|(i, m)| format!("BE{i} x{:.2}", m.stretch))
                    .collect();
                out.push_str(&format!(
                    "  contention stretch per member: {}\n",
                    factors.join(", ")
                ));
            }
            // dual-bound lines only in fixed-point mode, so the
            // default report text stays byte-identical
            if l.mode == crate::serve::NegotiationMode::FixedPoint {
                let bounds: Vec<String> = l
                    .members
                    .iter()
                    .enumerate()
                    .map(|(i, m)| {
                        format!("BE{i} x{:.2}->x{:.2}", m.stretch_single_pass, m.stretch)
                    })
                    .collect();
                out.push_str(&format!(
                    "  fixed-point bounds (single-pass -> fixed-point): {}; \
                     pessimism x{:.3}\n",
                    bounds.join(", "),
                    l.pessimism(),
                ));
            }
        }
    }
    if let Some(c) = &r.cluster {
        let usage = c.board_usage(r);
        let total_j: f64 = usage.iter().map(|u| u.energy_j).sum();
        out.push_str(&format!(
            "  cluster {}: {} board(s), {} member(s), {:.1} J over the run\n",
            c.name,
            c.boards.len(),
            c.members.len(),
            total_j,
        ));
        for (j, (bl, u)) in c.boards.iter().zip(&usage).enumerate() {
            out.push_str(&format!(
                "  board {j} ({}): members {:?}, {} admitted, {} completed, util {:.1}%, \
                 availability {:.2}%, {:.1} J, net stretch x{:.2}\n",
                bl.hw.name,
                bl.members,
                u.admitted,
                u.completed,
                u.utilization * 100.0,
                u.availability * 100.0,
                u.energy_j,
                c.net.members[j].stretch,
            ));
        }
        let dem = c.net.demanded();
        let sub = |d: f64, pool: f64| if pool > 0.0 { d / pool * 100.0 } else { 0.0 };
        out.push_str(&format!(
            "  net: switch {:.1}/{:.1} GB/s demanded ({:.0}% of pool), NIC {:.2}/{:.1} GB/s \
             ({:.0}%){}\n",
            dem.dram_gbps,
            c.net.pools.dram_gbps,
            sub(dem.dram_gbps, c.net.pools.dram_gbps),
            dem.pcie_gbps,
            c.net.pools.pcie_gbps,
            sub(dem.pcie_gbps, c.net.pools.pcie_gbps),
            if c.net.throttled() { " — oversubscribed, boards throttled" } else { "" },
        ));
    }
    if let Some(f) = &r.faults {
        let injected = f.timeline.iter().filter(|(_, applied)| *applied).count();
        out.push_str(&format!(
            "  faults: {} injected of {} scheduled; {} rider(s) requeued, {} re-admitted; \
             degraded-window p99 {:.3} ms\n",
            injected,
            f.timeline.len(),
            f.requeued,
            f.retried,
            f.degraded_p99_ms,
        ));
        for (i, b) in f.backends.iter().enumerate() {
            if b.downs == 0 && b.requeued == 0 {
                continue;
            }
            let avail = if r.wall_ns == 0 {
                1.0
            } else {
                (r.wall_ns - b.down_ns) as f64 / r.wall_ns as f64
            };
            out.push_str(&format!(
                "  BE{i}: {} down window(s), {:.3} ms down (availability {:.2}%), \
                 {} requeued\n",
                b.downs,
                b.down_ns as f64 / 1e6,
                avail * 100.0,
                b.requeued,
            ));
        }
        if !f.renegotiations.is_empty() {
            out.push_str(&format!(
                "  link renegotiations: {} (freed bandwidth relaxes survivor throttles)\n",
                f.renegotiations.len(),
            ));
        }
    }
    out
}

/// Observability footer for the human-readable `explore`/`serve` reports
/// (printed only when `--metrics` is given): the process-global engine
/// counters the run bracketed — stage-sim cache effectiveness, fast-forward
/// reuse inside the simulator, and `par_map` occupancy.
pub fn obs_footer(m: &crate::obs::MetricsRegistry) -> String {
    let hits = m.counter("sched.stage_cache_hits");
    let misses = m.counter("sched.stage_cache_misses");
    let runs = m.counter("sched.stage_runs");
    let ff = m.counter("sim.fast_forwarded");
    let calls = m.counter("par.calls");
    let items = m.counter("par.items");
    let launches = m.counter("par.worker_launches");
    let pct = |n: u64, d: u64| if d == 0 { 0.0 } else { n as f64 / d as f64 * 100.0 };
    let mut out = String::new();
    out.push_str("  -- observability (cat-obs-v1) --\n");
    out.push_str(&format!(
        "  stage-sim cache: {hits} hit(s), {misses} miss(es) ({:.1}% hit rate) \
         over {runs} stage run(s)\n",
        pct(hits, hits + misses),
    ));
    out.push_str(&format!(
        "  simulator fast-forward: {ff} invocation(s) reused a computed period\n"
    ));
    out.push_str(&format!(
        "  par_map: {calls} call(s), {items} item(s), {launches} worker launch(es) \
         ({:.1} workers/call)\n",
        if calls == 0 { 0.0 } else { launches as f64 / calls as f64 },
    ));
    out
}

/// Figure 5 series: throughput vs batch size for MHA / FFN / System.
#[derive(Debug, Clone)]
pub struct BatchPoint {
    pub batch: usize,
    pub mha_tops: f64,
    pub ffn_tops: f64,
    pub sys_tops: f64,
}

/// Render the Figure 5 series for one accelerator as a table + ASCII plot.
pub fn fig5(model: &str, points: &[BatchPoint]) -> String {
    let mut t = Table::new(
        &format!("Figure 5 — {model}: throughput vs batch size"),
        &["batch", "MHA TOPS", "FFN TOPS", "System TOPS"],
    );
    for p in points {
        t.row(&[
            p.batch.to_string(),
            fmt_f(p.mha_tops, 2),
            fmt_f(p.ffn_tops, 2),
            fmt_f(p.sys_tops, 2),
        ]);
    }
    let mut out = t.render();
    // ASCII sparkline of system TOPS
    let max = points.iter().map(|p| p.sys_tops).fold(1e-9, f64::max);
    out.push_str("  sys TOPS |");
    for p in points {
        let h = (p.sys_tops / max * 8.0).round() as usize;
        out.push(['.', '1', '2', '3', '4', '5', '6', '7', '8'][h.min(8)]);
    }
    out.push_str("| (normalized)\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{HardwareConfig, ModelConfig};
    use crate::customize::{customize, CustomizeOptions};

    #[test]
    fn table2_ratios_relative_to_first() {
        let rows = vec![
            AblationRow {
                lab: "Lab 1",
                independent_linear: false,
                atb_parallel_mode: "N/A",
                atb_parallelism: 1,
                makespan_ns: 100.0,
            },
            AblationRow {
                lab: "Lab 2",
                independent_linear: false,
                atb_parallel_mode: "Pipeline",
                atb_parallelism: 1,
                makespan_ns: 25.0,
            },
        ];
        let s = table2(&rows);
        assert!(s.contains("1.00x"));
        assert!(s.contains("4.00x"));
    }

    #[test]
    fn table5_renders_three_modules_per_model() {
        let plan = customize(
            &ModelConfig::bert_base(),
            &HardwareConfig::vck5000(),
            &CustomizeOptions::default(),
        )
        .unwrap();
        let s = table5(&[("BERT-Base", &plan)]);
        assert!(s.contains("MHA Stage") && s.contains("FFN Stage") && s.contains("Overall"));
        assert!(s.contains("88% (352 AIEs)"));
    }

    #[test]
    fn table7_has_reference_rows() {
        let cat = CatRow { tops: 35.194, gops_per_w: 520.97 };
        let s = table7_group("peak", &cat, &[]);
        assert!(s.contains("ViA"));
        assert!(s.contains("CAT (ours)"));
        assert!(s.contains("SSR"));
        // CAT vs ViA speedup ~113.9x
        assert!(s.contains("113.9") || s.contains("113.90"), "{s}");
    }

    #[test]
    fn fig5_sparkline() {
        let pts = vec![
            BatchPoint { batch: 1, mha_tops: 10.0, ffn_tops: 12.0, sys_tops: 11.0 },
            BatchPoint { batch: 16, mha_tops: 38.0, ffn_tops: 30.0, sys_tops: 33.0 },
        ];
        let s = fig5("bert-base", &pts);
        assert!(s.contains("batch"));
        assert!(s.contains("sys TOPS"));
    }
}
