//! SLO-aware dispatch: pick the **cheapest** healthy backend whose
//! worst-case completion bound fits the request's deadline.
//!
//! The bound is constructed so that admission implies compliance:
//!
//! ```text
//! completion ≤ max(busy_until, flush_deadline) + max_service
//! ```
//!
//! * the request joins the backend's forming batch, which flushes no
//!   later than `flush_deadline` (staleness) — filling up early only
//!   dispatches it sooner;
//! * batches dispatch in order per backend, so nothing overtakes the
//!   forming batch: its start is bounded by
//!   `max(busy_until, flush_deadline)` where `busy_until` covers every
//!   batch already dispatched;
//! * the batch serves in at most `max_service_ns` — the snapshot carries
//!   the backend's *effective* worst case, so an active slowdown window
//!   is priced into admission.
//!
//! Every term is an upper bound, so every *admitted* request completes
//! within its deadline — load shedding, not queue collapse, is how
//! overload manifests (the property tests assert exactly this).
//!
//! **Backend health** is part of the snapshot: a crashed or stalled
//! backend reports `up: false` and is skipped entirely — it neither
//! admits nor counts as queue room, and when *no* backend is up the shed
//! reason is [`ShedReason::Fault`] rather than `Capacity`.  Recovery is
//! event-driven: the serving loop flips the flag back at the scheduled
//! recovery time and the backend simply reappears at its old position in
//! the cheapest-first order — no polling, no re-sorting.
//!
//! **Deadlines, not SLO offsets:** the router compares against an
//! absolute `deadline_ns`.  For fresh arrivals the caller passes
//! `arrival + SLO`, so the check is identical to the historical
//! `completion - now ≤ slo`; for *re-admissions* after a fault the
//! original arrival keeps anchoring the deadline — a rider does not get
//! a fresh SLO budget just because its first backend died.
//!
//! **Partitioned fleets** change nothing in the admission logic, but the
//! bound's ingredients are re-derived per member: each backend's service
//! profile is re-simulated against its budget-constrained deployment
//! ([`Backend::deploy_in_share`](super::Backend::deploy_in_share)), so
//! `max_service_ns` already reflects the member's board share and the
//! `admission ⇒ compliance` argument carries over unchanged to
//! co-resident backends.

use super::admission::ShedReason;

/// One backend's queue snapshot at routing time (virtual ns).
#[derive(Debug, Clone, Copy)]
pub struct BackendLoad {
    /// When every batch already dispatched to this backend completes.
    pub busy_until_ns: u64,
    /// Requests in the forming batch (not yet dispatched).
    pub pending: usize,
    /// Latest virtual time the forming batch will flush (now + staleness
    /// budget when the batcher is empty).
    pub flush_deadline_ns: u64,
    /// Requests admitted but not yet completed — the forming batch
    /// (`pending`) plus dispatched-but-unfinished batches.  This is the
    /// quantity the bounded queue caps.
    pub in_flight: usize,
    /// Health: `false` while the backend is inside a crash/stall window.
    /// Down backends are excluded from admission entirely.
    pub up: bool,
    /// The backend's *effective* worst-case service time — the profile
    /// maximum, stretched when a slowdown window is active.
    pub max_service_ns: u64,
}

/// A routing decision: which backend (as a **position** in the slice
/// passed to [`route`], not `Backend::id` — the two coincide only for
/// [`Fleet::select`](super::Fleet::select)-built fleets), and the
/// completion bound the admission promised (for diagnostics/tests).
#[derive(Debug, Clone, Copy)]
pub struct RouteDecision {
    pub backend: usize,
    pub completion_bound_ns: u64,
    /// How many backends the scan considered before this one admitted
    /// (1 = first choice took it).  Routing effort, surfaced as the
    /// `serve.route_scanned` histogram by the observability layer.
    pub scanned: usize,
}

/// Route one arrival (or re-admission).  `loads` must be in cost order
/// (cheapest first — [`Fleet::select`](super::Fleet::select) guarantees
/// it); the first healthy, SLO-feasible backend with queue room wins.
/// `Err` is the shed reason: `Fault` when every backend is down,
/// `Capacity` when every *up* queue was full, `Slo` when room existed
/// but no completion bound fit `deadline_ns`.
pub fn route(
    loads: &[BackendLoad],
    now_ns: u64,
    deadline_ns: u64,
    queue_cap: usize,
) -> Result<RouteDecision, ShedReason> {
    let mut any_up = false;
    let mut any_room = false;
    for (i, l) in loads.iter().enumerate() {
        if !l.up {
            continue;
        }
        any_up = true;
        if l.in_flight >= queue_cap {
            continue;
        }
        any_room = true;
        debug_assert!(l.flush_deadline_ns >= now_ns, "stale batch not flushed before routing");
        let start_bound = l.busy_until_ns.max(l.flush_deadline_ns);
        let completion_bound = start_bound.saturating_add(l.max_service_ns);
        if completion_bound <= deadline_ns {
            return Ok(RouteDecision {
                backend: i,
                completion_bound_ns: completion_bound,
                scanned: i + 1,
            });
        }
    }
    Err(if !any_up {
        ShedReason::Fault
    } else if any_room {
        ShedReason::Slo
    } else {
        ShedReason::Capacity
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn load(busy: u64, in_flight: usize, up: bool, max_service: u64) -> BackendLoad {
        BackendLoad {
            busy_until_ns: busy,
            pending: 0,
            flush_deadline_ns: busy.max(100),
            in_flight,
            up,
            max_service_ns: max_service,
        }
    }

    #[test]
    fn cheapest_feasible_backend_wins() {
        let loads = [load(0, 0, true, 50), load(0, 0, true, 10)];
        let d = route(&loads, 0, 1_000, 8).unwrap();
        assert_eq!(d.backend, 0, "cost order, not service time, breaks ties");
    }

    #[test]
    fn down_backends_are_skipped() {
        let loads = [load(0, 0, false, 50), load(0, 0, true, 10)];
        let d = route(&loads, 0, 1_000, 8).unwrap();
        assert_eq!(d.backend, 1);
        // the skipped down backend still counts toward scan effort
        assert_eq!(d.scanned, 2);
    }

    #[test]
    fn total_outage_sheds_with_fault() {
        let loads = [load(0, 0, false, 50), load(0, 0, false, 10)];
        assert_eq!(route(&loads, 0, 1_000, 8).unwrap_err(), ShedReason::Fault);
    }

    #[test]
    fn full_up_queues_shed_capacity_and_deadline_misses_shed_slo() {
        // up-but-full dominates down: the fleet is alive, just saturated
        let full = [load(0, 8, true, 50), load(0, 0, false, 10)];
        assert_eq!(route(&full, 0, 1_000, 8).unwrap_err(), ShedReason::Capacity);
        // room exists but no bound fits the deadline
        let slow = [load(5_000, 0, true, 50)];
        assert_eq!(route(&slow, 0, 1_000, 8).unwrap_err(), ShedReason::Slo);
    }

    #[test]
    fn deadline_is_absolute() {
        // busy_until 900 + service 90 = 990 ≤ deadline 1000 admits even
        // though now is 950 (the old now-relative check would too: the
        // equivalence `completion - now ≤ slo ⇔ completion ≤ arrival+slo`
        // holds only when deadline anchors at arrival — which re-admission
        // exploits by NOT refreshing it)
        let loads = [load(900, 0, true, 90)];
        assert!(route(&loads, 950, 1_000, 8).is_ok());
        assert_eq!(route(&loads, 950, 989, 8).unwrap_err(), ShedReason::Slo);
    }
}
