//! SLO-aware dispatch: pick the **cheapest** healthy backend whose
//! worst-case completion bound fits the request's deadline.
//!
//! The bound is constructed so that admission implies compliance:
//!
//! ```text
//! completion ≤ max(busy_until, flush_deadline) + max_service
//! ```
//!
//! * the request joins the backend's forming batch, which flushes no
//!   later than `flush_deadline` (staleness) — filling up early only
//!   dispatches it sooner;
//! * batches dispatch in order per backend, so nothing overtakes the
//!   forming batch: its start is bounded by
//!   `max(busy_until, flush_deadline)` where `busy_until` covers every
//!   batch already dispatched;
//! * the batch serves in at most `max_service_ns` — the snapshot carries
//!   the backend's *effective* worst case, so an active slowdown window
//!   is priced into admission.
//!
//! Every term is an upper bound, so every *admitted* request completes
//! within its deadline — load shedding, not queue collapse, is how
//! overload manifests (the property tests assert exactly this).
//!
//! **Backend health** is part of the snapshot: a crashed or stalled
//! backend reports `up: false` and is skipped entirely — it neither
//! admits nor counts as queue room, and when *no* backend is up the shed
//! reason is [`ShedReason::Fault`] rather than `Capacity`.  Recovery is
//! event-driven: the serving loop flips the flag back at the scheduled
//! recovery time and the backend simply reappears at its old position in
//! the cheapest-first order — no polling, no re-sorting.
//!
//! **Deadlines, not SLO offsets:** the router compares against an
//! absolute `deadline_ns`.  For fresh arrivals the caller passes
//! `arrival + SLO`, so the check is identical to the historical
//! `completion - now ≤ slo`; for *re-admissions* after a fault the
//! original arrival keeps anchoring the deadline — a rider does not get
//! a fresh SLO budget just because its first backend died.
//!
//! **Partitioned fleets** change nothing in the admission logic, but the
//! bound's ingredients are re-derived per member: each backend's service
//! profile is re-simulated against its budget-constrained deployment
//! ([`Backend::deploy_in_share`](super::Backend::deploy_in_share)), so
//! `max_service_ns` already reflects the member's board share and the
//! `admission ⇒ compliance` argument carries over unchanged to
//! co-resident backends.
//!
//! # Two implementations, one contract
//!
//! [`route`] is the **linear-scan oracle**: it rebuilds nothing, trusts a
//! caller-assembled [`BackendLoad`] snapshot, and scans every backend per
//! request.  [`AdmissionIndex`] is the **event-driven hot path** the
//! serving loop actually runs: per-backend admission bounds are *cached*
//! and invalidated only by the events that change their ingredients
//! (batch dispatch, staleness flush, fault down/up/slowdown transitions,
//! link-renegotiation redeploys), up-backends are kept in a
//! cheapest-first probe list, and arrivals landing at the same virtual
//! timestamp reuse one bound refresh.  The two must agree decision for
//! decision — in debug builds the serving loop cross-checks every
//! admission against the oracle, and a cached bound that disagrees with
//! its recomputed ingredients panics (`rust/tests/router_index.rs`
//! replays randomized faulted/partitioned/cluster traffic through both).
//! `RouteDecision::scanned` keeps its meaning on both paths:
//! candidates considered in cost order, counting skipped-down positions,
//! exactly what the `serve.route_scanned` histogram has always reported.

use super::admission::ShedReason;

/// One backend's queue snapshot at routing time (virtual ns).
#[derive(Debug, Clone, Copy)]
pub struct BackendLoad {
    /// When every batch already dispatched to this backend completes.
    pub busy_until_ns: u64,
    /// Requests in the forming batch (not yet dispatched).
    pub pending: usize,
    /// Latest virtual time the forming batch will flush (now + staleness
    /// budget when the batcher is empty).
    pub flush_deadline_ns: u64,
    /// Requests admitted but not yet completed — the forming batch
    /// (`pending`) plus dispatched-but-unfinished batches.  This is the
    /// quantity the bounded queue caps.
    pub in_flight: usize,
    /// Health: `false` while the backend is inside a crash/stall window.
    /// Down backends are excluded from admission entirely.
    pub up: bool,
    /// The backend's *effective* worst-case service time — the profile
    /// maximum, stretched when a slowdown window is active.
    pub max_service_ns: u64,
}

/// A routing decision: which backend (as a **position** in the slice
/// passed to [`route`], not `Backend::id` — the two coincide only for
/// [`Fleet::select`](super::Fleet::select)-built fleets), and the
/// completion bound the admission promised (for diagnostics/tests).
#[derive(Debug, Clone, Copy)]
pub struct RouteDecision {
    pub backend: usize,
    pub completion_bound_ns: u64,
    /// How many backends the scan considered before this one admitted
    /// (1 = first choice took it).  Routing effort, surfaced as the
    /// `serve.route_scanned` histogram by the observability layer; the
    /// indexed path counts identically (candidates in cost order,
    /// including skipped-down positions), so the histogram keeps meaning
    /// probes-considered regardless of which implementation routed.
    pub scanned: usize,
}

/// Route one arrival (or re-admission).  `loads` must be in cost order
/// (cheapest first — [`Fleet::select`](super::Fleet::select) guarantees
/// it); the first healthy, SLO-feasible backend with queue room wins.
/// `Err` is the shed reason: `Fault` when every backend is down,
/// `Capacity` when every *up* queue was full, `Slo` when room existed
/// but no completion bound fit `deadline_ns`.
///
/// This is the reference implementation — the serving loop routes
/// through [`AdmissionIndex::route`] and (in debug builds) asserts it
/// agrees with this scan on every arrival.
pub fn route(
    loads: &[BackendLoad],
    now_ns: u64,
    deadline_ns: u64,
    queue_cap: usize,
) -> Result<RouteDecision, ShedReason> {
    let mut any_up = false;
    let mut any_room = false;
    for (i, l) in loads.iter().enumerate() {
        if !l.up {
            continue;
        }
        any_up = true;
        if l.in_flight >= queue_cap {
            continue;
        }
        any_room = true;
        debug_assert!(l.flush_deadline_ns >= now_ns, "stale batch not flushed before routing");
        let start_bound = l.busy_until_ns.max(l.flush_deadline_ns);
        let completion_bound = start_bound.saturating_add(l.max_service_ns);
        if completion_bound <= deadline_ns {
            return Ok(RouteDecision {
                backend: i,
                completion_bound_ns: completion_bound,
                scanned: i + 1,
            });
        }
    }
    Err(if !any_up {
        ShedReason::Fault
    } else if any_room {
        ShedReason::Slo
    } else {
        ShedReason::Capacity
    })
}

/// One backend's event-maintained admission state inside the
/// [`AdmissionIndex`].  The cached bound is the routing-time
/// `max(busy_until, flush_deadline) + effective_max_service` — valid
/// until an event dirties an ingredient, the probe timestamp moves while
/// the batcher is empty (an empty batcher's flush deadline tracks `now`),
/// or the probe crosses the slowdown-window edge (the stretch expires
/// passively, without an event).
#[derive(Debug, Clone)]
struct IndexEntry {
    busy_until_ns: u64,
    /// Natural staleness deadline of the forming batch
    /// (`first_enqueue + batch_wait`); `None` while the batcher is empty.
    /// Down-time deferral is the serving loop's read-side concern — down
    /// backends are never probed here.
    flush_deadline_ns: Option<u64>,
    in_flight: usize,
    up: bool,
    /// Base (unstretched) worst case of the *live* deployment — updated
    /// when a link renegotiation redeploys the member.
    max_service_ns: u64,
    slow_until_ns: u64,
    slow_factor: f64,
    cached_bound_ns: u64,
    cached_at_ns: u64,
    cache_valid: bool,
}

impl IndexEntry {
    /// Recompute the admission bound from the ingredients at `now_ns` —
    /// term for term the expression [`route`] evaluates.
    fn bound_at(&self, wait_ns: u64, now_ns: u64) -> u64 {
        let flush = self.flush_deadline_ns.unwrap_or_else(|| now_ns.saturating_add(wait_ns));
        let ms = if now_ns < self.slow_until_ns {
            (self.max_service_ns as f64 * self.slow_factor).ceil() as u64
        } else {
            self.max_service_ns
        };
        self.busy_until_ns.max(flush).saturating_add(ms)
    }

    /// Whether the cached bound is still exact at `now_ns`: nothing
    /// dirtied it, and either the probe timestamp is unchanged (the
    /// same-burst reuse) or every ingredient is time-invariant — a
    /// forming batch pins the flush term, and `now` sits on the same
    /// side of the slowdown edge as when the bound was computed.
    fn cache_usable(&self, now_ns: u64) -> bool {
        self.cache_valid
            && (self.cached_at_ns == now_ns
                || (self.flush_deadline_ns.is_some()
                    && (self.cached_at_ns < self.slow_until_ns) == (now_ns < self.slow_until_ns)))
    }

    fn invalidate(&mut self) {
        self.cache_valid = false;
    }
}

/// Event-driven admission plane: the indexed replacement for rebuilding
/// a [`BackendLoad`] snapshot per arrival.
///
/// * **Cheapest-first structure** — fleet positions *are* the cost order
///   ([`Fleet::ranked`](super::Fleet) sorts by power at build time, and a
///   recovering backend rejoins at its old position), so the index keeps
///   the up-backends as a sorted position list and probes it in order.
/// * **Cached bounds** — each entry caches its admission bound and the
///   instant it was computed; only the events that change an ingredient
///   invalidate it (dispatch moves `busy_until`, a push/flush moves the
///   flush deadline, fault transitions and renegotiation redeploys move
///   health/stretch/service).  Batch completion only frees queue room,
///   so retirement deliberately does *not* invalidate.
/// * **Burst batching** — arrivals at the same virtual timestamp hit the
///   `cached_at == now` fast path: one bound refresh per backend per
///   timestamp, however deep the burst.
///
/// The owner must mirror every state mutation through the event methods;
/// in debug builds a cache hit re-derives the bound and asserts equality,
/// so a *missed* invalidation is unrepresentable rather than silently
/// conservative (see `stale_cache_trips_the_debug_invariant`).
pub struct AdmissionIndex {
    entries: Vec<IndexEntry>,
    /// Up backends, ascending position == ascending cost.
    up_list: Vec<usize>,
    wait_ns: u64,
}

impl AdmissionIndex {
    /// One entry per backend, in fleet (cost) order, all up and idle.
    /// `max_services[b]` is member `b`'s worst-case service bound;
    /// `wait_ns` is the resolved staleness budget (an empty batcher
    /// flushes no later than `now + wait_ns`).
    pub fn new(max_services: &[u64], wait_ns: u64) -> AdmissionIndex {
        AdmissionIndex {
            entries: max_services
                .iter()
                .map(|&ms| IndexEntry {
                    busy_until_ns: 0,
                    flush_deadline_ns: None,
                    in_flight: 0,
                    up: true,
                    max_service_ns: ms,
                    slow_until_ns: 0,
                    slow_factor: 1.0,
                    cached_bound_ns: 0,
                    cached_at_ns: 0,
                    cache_valid: false,
                })
                .collect(),
            up_list: (0..max_services.len()).collect(),
            wait_ns,
        }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// The event-maintained natural flush deadline (`None` = empty
    /// batcher).  The serving loop's event pump reads this instead of
    /// re-deriving staleness from the batcher's clock.
    pub fn flush_deadline(&self, b: usize) -> Option<u64> {
        self.entries[b].flush_deadline_ns
    }

    pub fn in_flight(&self, b: usize) -> usize {
        self.entries[b].in_flight
    }

    pub fn is_up(&self, b: usize) -> bool {
        self.entries[b].up
    }

    pub fn busy_until_ns(&self, b: usize) -> u64 {
        self.entries[b].busy_until_ns
    }

    /// Route one arrival against the cached bounds: probe the up-list in
    /// cost order, admit the first backend with queue room whose bound
    /// fits `deadline_ns`.  Decisions, shed reasons, bounds, and
    /// `scanned` are identical to [`route`] over an equivalent snapshot.
    pub fn route(
        &mut self,
        now_ns: u64,
        deadline_ns: u64,
        queue_cap: usize,
    ) -> Result<RouteDecision, ShedReason> {
        let mut any_room = false;
        for &b in &self.up_list {
            let e = &mut self.entries[b];
            if e.in_flight >= queue_cap {
                continue;
            }
            any_room = true;
            debug_assert!(
                e.flush_deadline_ns.map_or(true, |f| f >= now_ns),
                "stale batch not flushed before routing"
            );
            let bound = if e.cache_usable(now_ns) {
                // a stale cached bound must be unrepresentable, not
                // silently conservative: every debug-mode cache hit is
                // re-derived and compared
                debug_assert_eq!(
                    e.cached_bound_ns,
                    e.bound_at(self.wait_ns, now_ns),
                    "cached admission bound diverged from its ingredients (missed invalidation?)"
                );
                e.cached_bound_ns
            } else {
                let fresh = e.bound_at(self.wait_ns, now_ns);
                e.cached_bound_ns = fresh;
                e.cached_at_ns = now_ns;
                e.cache_valid = true;
                fresh
            };
            if bound <= deadline_ns {
                return Ok(RouteDecision { backend: b, completion_bound_ns: bound, scanned: b + 1 });
            }
        }
        Err(if self.up_list.is_empty() {
            ShedReason::Fault
        } else if any_room {
            ShedReason::Slo
        } else {
            ShedReason::Capacity
        })
    }

    /// An admitted rider joined backend `b`'s forming batch.  Queue room
    /// only — the flush-deadline move is reported separately by
    /// [`AdmissionIndex::set_flush_deadline`].
    pub fn note_admitted(&mut self, b: usize) {
        self.entries[b].in_flight += 1;
    }

    /// `k` riders retired off backend `b` (batch completion).  Frees
    /// queue room; the bound's ingredients are untouched, so the cache
    /// deliberately survives.
    pub fn note_retired(&mut self, b: usize, k: usize) {
        self.entries[b].in_flight -= k;
    }

    /// `k` riders orphaned off backend `b` (crash drain, stall
    /// late-batch drop, fault-mode dispatch orphaning).  Frees queue
    /// room like retirement.
    pub fn note_orphaned(&mut self, b: usize, k: usize) {
        self.entries[b].in_flight -= k;
    }

    /// Batch dispatch (or a crash/stall rewriting the busy horizon).
    pub fn set_busy_until(&mut self, b: usize, busy_until_ns: u64) {
        let e = &mut self.entries[b];
        e.busy_until_ns = busy_until_ns;
        e.invalidate();
    }

    /// The forming batch's natural staleness deadline moved: `Some` when
    /// a rider started a fresh batch, `None` when a flush (staleness,
    /// full batch, crash drain) emptied the batcher.
    pub fn set_flush_deadline(&mut self, b: usize, deadline_ns: Option<u64>) {
        let e = &mut self.entries[b];
        e.flush_deadline_ns = deadline_ns;
        e.invalidate();
    }

    /// Crash/stall transition: backend `b` leaves the admission order.
    pub fn set_down(&mut self, b: usize) {
        let e = &mut self.entries[b];
        e.up = false;
        e.invalidate();
        if let Ok(i) = self.up_list.binary_search(&b) {
            self.up_list.remove(i);
        }
    }

    /// Recovery: backend `b` rejoins the cheapest-first order at its old
    /// position.
    pub fn set_up(&mut self, b: usize) {
        let e = &mut self.entries[b];
        e.up = true;
        e.invalidate();
        if let Err(i) = self.up_list.binary_search(&b) {
            self.up_list.insert(i, b);
        }
    }

    /// Slowdown window transition (the serving loop reports the merged
    /// window, harsher-factor-wins semantics included).  The passive
    /// *expiry* of the window needs no event: the cache is timestamp-
    /// aware and recomputes when a probe crosses `slow_until_ns`.
    pub fn set_slowdown(&mut self, b: usize, slow_until_ns: u64, slow_factor: f64) {
        let e = &mut self.entries[b];
        e.slow_until_ns = slow_until_ns;
        e.slow_factor = slow_factor;
        e.invalidate();
    }

    /// A crash cleared the slowdown window with the rest of the state.
    pub fn clear_slowdown(&mut self, b: usize) {
        self.set_slowdown(b, 0, 1.0);
    }

    /// A link renegotiation redeployed member `b`: its worst-case
    /// service bound now reflects the new throttle.
    pub fn set_max_service(&mut self, b: usize, max_service_ns: u64) {
        let e = &mut self.entries[b];
        e.max_service_ns = max_service_ns;
        e.invalidate();
    }

    /// Test-only back door: rewrite `b`'s busy horizon WITHOUT
    /// invalidating the cached bound — simulates a missed invalidation
    /// event so tests can prove the debug invariant makes a stale cache
    /// unrepresentable.  Never call outside tests.
    #[doc(hidden)]
    pub fn corrupt_busy_until_for_test(&mut self, b: usize, busy_until_ns: u64) {
        self.entries[b].busy_until_ns = busy_until_ns;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn load(busy: u64, in_flight: usize, up: bool, max_service: u64) -> BackendLoad {
        BackendLoad {
            busy_until_ns: busy,
            pending: 0,
            flush_deadline_ns: busy.max(100),
            in_flight,
            up,
            max_service_ns: max_service,
        }
    }

    #[test]
    fn cheapest_feasible_backend_wins() {
        let loads = [load(0, 0, true, 50), load(0, 0, true, 10)];
        let d = route(&loads, 0, 1_000, 8).unwrap();
        assert_eq!(d.backend, 0, "cost order, not service time, breaks ties");
    }

    #[test]
    fn down_backends_are_skipped() {
        let loads = [load(0, 0, false, 50), load(0, 0, true, 10)];
        let d = route(&loads, 0, 1_000, 8).unwrap();
        assert_eq!(d.backend, 1);
        // the skipped down backend still counts toward scan effort
        assert_eq!(d.scanned, 2);
    }

    #[test]
    fn total_outage_sheds_with_fault() {
        let loads = [load(0, 0, false, 50), load(0, 0, false, 10)];
        assert_eq!(route(&loads, 0, 1_000, 8).unwrap_err(), ShedReason::Fault);
    }

    #[test]
    fn full_up_queues_shed_capacity_and_deadline_misses_shed_slo() {
        // up-but-full dominates down: the fleet is alive, just saturated
        let full = [load(0, 8, true, 50), load(0, 0, false, 10)];
        assert_eq!(route(&full, 0, 1_000, 8).unwrap_err(), ShedReason::Capacity);
        // room exists but no bound fits the deadline
        let slow = [load(5_000, 0, true, 50)];
        assert_eq!(route(&slow, 0, 1_000, 8).unwrap_err(), ShedReason::Slo);
    }

    #[test]
    fn deadline_is_absolute() {
        // busy_until 900 + service 90 = 990 ≤ deadline 1000 admits even
        // though now is 950 (the old now-relative check would too: the
        // equivalence `completion - now ≤ slo ⇔ completion ≤ arrival+slo`
        // holds only when deadline anchors at arrival — which re-admission
        // exploits by NOT refreshing it)
        let loads = [load(900, 0, true, 90)];
        assert!(route(&loads, 950, 1_000, 8).is_ok());
        assert_eq!(route(&loads, 950, 989, 8).unwrap_err(), ShedReason::Slo);
    }

    // --- the indexed path against the oracle ---

    /// Mirror an index state as the oracle's snapshot at `now`.
    fn snapshot(ix: &AdmissionIndex, now: u64, wait: u64) -> Vec<BackendLoad> {
        (0..ix.len())
            .map(|b| BackendLoad {
                busy_until_ns: ix.busy_until_ns(b),
                pending: 0,
                flush_deadline_ns: ix
                    .flush_deadline(b)
                    .unwrap_or_else(|| now.saturating_add(wait)),
                in_flight: ix.in_flight(b),
                up: ix.is_up(b),
                max_service_ns: ix.entries[b].bound_effective_service(now),
            })
            .collect()
    }

    impl IndexEntry {
        /// Effective (slowdown-stretched) service, for test snapshots.
        fn bound_effective_service(&self, now_ns: u64) -> u64 {
            if now_ns < self.slow_until_ns {
                (self.max_service_ns as f64 * self.slow_factor).ceil() as u64
            } else {
                self.max_service_ns
            }
        }
    }

    fn assert_agree(ix: &mut AdmissionIndex, now: u64, deadline: u64, cap: usize) {
        let loads = snapshot(ix, now, ix.wait_ns);
        let oracle = route(&loads, now, deadline, cap);
        match (oracle, ix.route(now, deadline, cap)) {
            (Ok(o), Ok(i)) => assert_eq!(
                (o.backend, o.completion_bound_ns, o.scanned),
                (i.backend, i.completion_bound_ns, i.scanned),
                "indexed decision diverged at now={now}"
            ),
            (Err(o), Err(i)) => assert_eq!(o, i, "shed reason diverged at now={now}"),
            (o, i) => panic!("oracle {o:?} vs indexed {i:?} at now={now}"),
        }
    }

    #[test]
    fn index_agrees_with_oracle_through_an_event_script() {
        let mut ix = AdmissionIndex::new(&[40, 90, 250], 100);
        let cap = 4;
        // idle fleet: cheapest wins, burst reuses the cached bound
        assert_agree(&mut ix, 0, 1_000, cap);
        assert_agree(&mut ix, 0, 1_000, cap);
        // admit onto 0 and open a forming batch; the cached bound now
        // survives across timestamps (flush term pinned)
        ix.note_admitted(0);
        ix.set_flush_deadline(0, Some(100));
        assert_agree(&mut ix, 10, 150, cap);
        assert_agree(&mut ix, 40, 180, cap);
        // dispatch: busy moves, flush clears
        ix.set_busy_until(0, 140);
        ix.set_flush_deadline(0, None);
        assert_agree(&mut ix, 100, 260, cap);
        // crash 0, stall-shift 1's horizon, probe mid-outage
        ix.note_orphaned(0, 1);
        ix.set_busy_until(0, 100);
        ix.set_down(0);
        ix.set_busy_until(1, 400);
        assert_agree(&mut ix, 110, 600, cap);
        // slowdown on 2 with a forming batch pinning its flush term: the
        // stretched bound caches across timestamps, and a probe that
        // crosses the slowdown edge recomputes at base service even
        // though the expiry fires no event
        ix.set_slowdown(2, 300, 2.5);
        ix.note_admitted(2);
        ix.set_flush_deadline(2, Some(400));
        assert_agree(&mut ix, 120, 480, cap); // 1 infeasible -> probes 2 stretched -> Slo
        assert_agree(&mut ix, 120, 480, cap); // same-timestamp reuse of the cached bound
        assert_agree(&mut ix, 350, 530, cap); // crossed the slow edge -> recompute at base
        // staleness pump fires 2's forming batch at its deadline
        ix.set_busy_until(2, 650);
        ix.set_flush_deadline(2, None);
        // recovery rejoins at the old (cheapest) position
        ix.set_up(0);
        assert_agree(&mut ix, 400, 800, cap);
        // renegotiation redeploy moves the service bound
        ix.set_max_service(1, 55);
        assert_agree(&mut ix, 420, 800, cap);
        // saturate everything: capacity vs slo vs fault reasons
        for b in 0..3 {
            for _ in 0..cap {
                ix.note_admitted(b);
            }
        }
        assert_agree(&mut ix, 500, 10_000, cap); // all full -> Capacity
        ix.note_retired(2, cap);
        assert_agree(&mut ix, 500, 1, cap); // room, hopeless deadline -> Slo
        ix.set_down(0);
        ix.set_down(1);
        ix.set_down(2);
        assert_agree(&mut ix, 600, 10_000, cap); // everyone down -> Fault
    }

    #[test]
    fn index_scanned_counts_skipped_down_positions() {
        let mut ix = AdmissionIndex::new(&[10, 10, 10], 50);
        ix.set_down(0);
        let d = ix.route(0, 1_000, 8).unwrap();
        assert_eq!(d.backend, 1);
        assert_eq!(d.scanned, 2, "scanned keeps meaning probes-considered in cost order");
    }

    #[test]
    fn burst_at_one_timestamp_refreshes_each_bound_once() {
        let mut ix = AdmissionIndex::new(&[10, 20], 50);
        let first = ix.route(100, 1_000, 8).unwrap();
        assert!(ix.entries[0].cache_valid && ix.entries[0].cached_at_ns == 100);
        // the rest of the burst reuses the cached bound verbatim
        for _ in 0..4 {
            let again = ix.route(100, 1_000, 8).unwrap();
            assert_eq!(again.completion_bound_ns, first.completion_bound_ns);
        }
        // an empty batcher's bound tracks now: a later probe recomputes
        let later = ix.route(200, 1_000, 8).unwrap();
        assert_eq!(later.completion_bound_ns, first.completion_bound_ns + 100);
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "missed invalidation")]
    fn stale_cache_trips_the_debug_invariant() {
        let mut ix = AdmissionIndex::new(&[10], 50);
        // open a forming batch so the cached bound survives across
        // timestamps, then mutate an ingredient behind the cache's back
        ix.note_admitted(0);
        ix.set_flush_deadline(0, Some(120));
        ix.route(100, 1_000, 8).unwrap();
        ix.corrupt_busy_until_for_test(0, 5_000);
        // the cache still claims validity — the debug recompute must trip
        let _ = ix.route(110, 10_000, 8);
    }
}
