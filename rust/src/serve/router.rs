//! SLO-aware dispatch: pick the **cheapest** backend whose worst-case
//! completion bound fits the request's SLO.
//!
//! The bound is constructed so that admission implies compliance:
//!
//! ```text
//! completion ≤ max(busy_until, flush_deadline) + max_service
//! ```
//!
//! * the request joins the backend's forming batch, which flushes no
//!   later than `flush_deadline` (staleness) — filling up early only
//!   dispatches it sooner;
//! * batches dispatch in order per backend, so nothing overtakes the
//!   forming batch: its start is bounded by
//!   `max(busy_until, flush_deadline)` where `busy_until` covers every
//!   batch already dispatched;
//! * the batch serves in at most [`max_service_ns`] (the profile's
//!   worst case over every emittable batch size).
//!
//! Every term is an upper bound, so every *admitted* request completes
//! within its SLO — load shedding, not queue collapse, is how overload
//! manifests (the property tests assert exactly this).
//!
//! **Partitioned fleets** change nothing in the admission logic, but the
//! bound's ingredients are re-derived per member: each backend's service
//! profile is re-simulated against its budget-constrained deployment
//! ([`Backend::deploy_in_share`](super::Backend::deploy_in_share)), so
//! [`max_service_ns`] already reflects the member's board share and the
//! `admission ⇒ compliance` argument carries over unchanged to
//! co-resident backends.
//!
//! [`max_service_ns`]: super::Backend::max_service_ns

use super::admission::ShedReason;
use super::fleet::Backend;

/// One backend's queue snapshot at routing time (virtual ns).
#[derive(Debug, Clone, Copy)]
pub struct BackendLoad {
    /// When every batch already dispatched to this backend completes.
    pub busy_until_ns: u64,
    /// Requests in the forming batch (not yet dispatched).
    pub pending: usize,
    /// Latest virtual time the forming batch will flush (now + staleness
    /// budget when the batcher is empty).
    pub flush_deadline_ns: u64,
    /// Requests admitted but not yet completed — the forming batch
    /// (`pending`) plus dispatched-but-unfinished batches.  This is the
    /// quantity the bounded queue caps.
    pub in_flight: usize,
}

/// A routing decision: which backend (as a **position** in the slices
/// passed to [`route`], not `Backend::id` — the two coincide only for
/// [`Fleet::select`](super::Fleet::select)-built fleets), and the
/// completion bound the admission promised (for diagnostics/tests).
#[derive(Debug, Clone, Copy)]
pub struct RouteDecision {
    pub backend: usize,
    pub completion_bound_ns: u64,
}

/// Route one arrival.  `backends` must be in cost order (cheapest first —
/// [`Fleet::select`](super::Fleet::select) guarantees it); the first
/// SLO-feasible backend with queue room wins.  `Err` is the shed reason:
/// `Capacity` when every queue was full, `Slo` when room existed but no
/// bound fit.
pub fn route(
    backends: &[Backend],
    loads: &[BackendLoad],
    now_ns: u64,
    slo_ns: u64,
    queue_cap: usize,
) -> Result<RouteDecision, ShedReason> {
    debug_assert_eq!(backends.len(), loads.len());
    let mut any_room = false;
    for (i, (b, l)) in backends.iter().zip(loads).enumerate() {
        if l.in_flight >= queue_cap {
            continue;
        }
        any_room = true;
        debug_assert!(l.flush_deadline_ns >= now_ns, "stale batch not flushed before routing");
        let start_bound = l.busy_until_ns.max(l.flush_deadline_ns);
        let completion_bound = start_bound + b.max_service_ns();
        if completion_bound.saturating_sub(now_ns) <= slo_ns {
            return Ok(RouteDecision { backend: i, completion_bound_ns: completion_bound });
        }
    }
    Err(if any_room { ShedReason::Slo } else { ShedReason::Capacity })
}
