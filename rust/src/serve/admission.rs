//! Admission control and synthetic open-loop traffic.
//!
//! Admission is all-or-nothing at arrival time: a request is either
//! routed to a backend whose worst-case completion bound fits the SLO
//! (see [`router`](super::router)) or shed immediately, with the reason
//! recorded.  Bounded per-backend queues keep the fleet from building
//! unserviceable backlog under overload — shedding is the overload
//! valve, and [`AdmissionStats`] accounts for every submitted request
//! (the conservation invariant the property tests assert).

use crate::util::prng::Prng;

/// Why a request was shed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShedReason {
    /// Some backend had queue room, but none could bound completion
    /// within the SLO.
    Slo,
    /// Every backend's bounded queue was full.
    Capacity,
}

impl ShedReason {
    pub fn as_str(&self) -> &'static str {
        match self {
            ShedReason::Slo => "slo",
            ShedReason::Capacity => "capacity",
        }
    }
}

/// Fleet-level request accounting.  Conservation:
/// `submitted == completed + shed_slo + shed_capacity` and
/// `admitted == completed` once the stream has drained (everything
/// admitted completes — admission is the only drop point).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct AdmissionStats {
    pub submitted: usize,
    pub admitted: usize,
    pub completed: usize,
    pub shed_slo: usize,
    pub shed_capacity: usize,
}

impl AdmissionStats {
    pub fn shed(&self) -> usize {
        self.shed_slo + self.shed_capacity
    }

    pub fn shed_rate(&self) -> f64 {
        if self.submitted == 0 {
            return 0.0;
        }
        self.shed() as f64 / self.submitted as f64
    }

    /// The conservation invariant (valid after the stream has drained).
    pub fn accounted(&self) -> bool {
        self.completed + self.shed() == self.submitted && self.admitted == self.completed
    }

    pub fn record_shed(&mut self, reason: ShedReason) {
        match reason {
            ShedReason::Slo => self.shed_slo += 1,
            ShedReason::Capacity => self.shed_capacity += 1,
        }
    }
}

/// Seeded synthetic traffic (virtual-clock timestamps, ns from stream
/// start) for closed-form-checkable serving experiments.
pub struct TrafficGen;

impl TrafficGen {
    /// Open-loop Poisson arrivals: `n` timestamps with exponential
    /// inter-arrival times at `rps` requests/second.  Deterministic for a
    /// fixed seed.
    pub fn poisson(seed: u64, rps: f64, n: usize) -> Vec<u64> {
        assert!(rps > 0.0, "rps must be positive");
        let mut rng = Prng::new(seed);
        let mut t_ns = 0.0f64;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            // inverse-CDF exponential; 1-u is in (0, 1] so ln() is finite
            let gap_s = -(1.0 - rng.f64()).ln() / rps;
            t_ns += gap_s * 1e9;
            out.push(t_ns as u64);
        }
        out
    }

    /// Bursty arrivals: Poisson burst epochs at `rps / burst` bursts per
    /// second, each delivering `burst` back-to-back requests — same mean
    /// rate as [`TrafficGen::poisson`], much spikier tails.
    pub fn bursty(seed: u64, rps: f64, n: usize, burst: usize) -> Vec<u64> {
        let burst = burst.max(1);
        let epochs = TrafficGen::poisson(seed, rps / burst as f64, n.div_ceil(burst));
        let mut out = Vec::with_capacity(n);
        for e in epochs {
            for _ in 0..burst {
                if out.len() == n {
                    return out;
                }
                out.push(e);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_is_sorted_deterministic_and_near_rate() {
        let a = TrafficGen::poisson(7, 1000.0, 2000);
        let b = TrafficGen::poisson(7, 1000.0, 2000);
        assert_eq!(a, b);
        assert_ne!(a, TrafficGen::poisson(8, 1000.0, 2000));
        assert!(a.windows(2).all(|w| w[0] <= w[1]));
        // mean rate within 10% over 2000 draws
        let span_s = *a.last().unwrap() as f64 / 1e9;
        let rate = a.len() as f64 / span_s;
        assert!((rate - 1000.0).abs() < 100.0, "rate {rate}");
    }

    #[test]
    fn bursty_groups_arrivals() {
        let a = TrafficGen::bursty(3, 1000.0, 100, 10);
        assert_eq!(a.len(), 100);
        assert!(a.windows(2).all(|w| w[0] <= w[1]));
        // each epoch repeats 10x
        assert_eq!(a[0], a[9]);
        assert!(a[10] > a[9]);
    }

    #[test]
    fn stats_conserve() {
        let mut s =
            AdmissionStats { submitted: 10, admitted: 7, completed: 7, ..Default::default() };
        s.record_shed(ShedReason::Slo);
        s.record_shed(ShedReason::Slo);
        s.record_shed(ShedReason::Capacity);
        assert_eq!(s.shed(), 3);
        assert!(s.accounted());
        assert!((s.shed_rate() - 0.3).abs() < 1e-12);
        assert_eq!(ShedReason::Capacity.as_str(), "capacity");
    }
}
