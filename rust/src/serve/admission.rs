//! Admission control and synthetic open-loop traffic.
//!
//! Admission is all-or-nothing at arrival time: a request is either
//! routed to a backend whose worst-case completion bound fits the SLO
//! (see [`router`](super::router)) or shed immediately, with the reason
//! recorded.  Bounded per-backend queues keep the fleet from building
//! unserviceable backlog under overload — shedding is the overload
//! valve, and [`AdmissionStats`] accounts for every submitted request
//! (the conservation invariant the property tests assert).

use crate::util::prng::Prng;

/// Why a request was shed.
///
/// `as_str` is matched without a wildcard arm on purpose: adding a
/// variant without naming its JSON string is a compile error, not a
/// silent `"unknown"` in reports.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShedReason {
    /// Some backend had queue room, but none could bound completion
    /// within the SLO.
    Slo,
    /// Every backend's bounded queue was full.
    Capacity,
    /// Orphaned by a backend fault and no surviving backend could still
    /// bound completion within the SLO (or all survivors were full/down).
    Fault,
    /// Orphaned and re-admitted, but bounced more than `max_retries`
    /// times before any backend could retire it.
    RetryExhausted,
}

impl ShedReason {
    pub fn as_str(&self) -> &'static str {
        match self {
            ShedReason::Slo => "slo",
            ShedReason::Capacity => "capacity",
            ShedReason::Fault => "fault",
            ShedReason::RetryExhausted => "retry_exhausted",
        }
    }
}

/// Fleet-level request accounting.  Conservation once the stream has
/// drained: `submitted == admitted + shed_slo + shed_capacity` (the
/// arrival-time split) and `admitted == completed + shed_fault +
/// shed_retry` (everything admitted either completes or is attributed
/// to a fault).  Fault-free both collapse to the original invariant
/// `submitted == completed + shed` with `admitted == completed`.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct AdmissionStats {
    pub submitted: usize,
    pub admitted: usize,
    pub completed: usize,
    pub shed_slo: usize,
    pub shed_capacity: usize,
    /// Orphaned by a fault, unservable on the survivors within the SLO.
    pub shed_fault: usize,
    /// Orphaned, re-admitted, and bounced past the retry budget.
    pub shed_retry: usize,
    /// Riders drained off a faulted backend (each may retry or shed).
    pub requeued: usize,
    /// Requeued riders successfully re-admitted on a survivor.
    pub retried: usize,
}

impl AdmissionStats {
    pub fn shed(&self) -> usize {
        self.shed_slo + self.shed_capacity + self.shed_fault + self.shed_retry
    }

    pub fn shed_rate(&self) -> f64 {
        if self.submitted == 0 {
            return 0.0;
        }
        self.shed() as f64 / self.submitted as f64
    }

    /// The conservation invariant (valid after the stream has drained).
    /// `admitted` counts *distinct requests* that ever entered a queue —
    /// a requeued rider's re-admission does not re-increment it.
    pub fn accounted(&self) -> bool {
        self.admitted + self.shed_slo + self.shed_capacity == self.submitted
            && self.completed + self.shed_fault + self.shed_retry == self.admitted
    }

    pub fn record_shed(&mut self, reason: ShedReason) {
        match reason {
            ShedReason::Slo => self.shed_slo += 1,
            ShedReason::Capacity => self.shed_capacity += 1,
            ShedReason::Fault => self.shed_fault += 1,
            ShedReason::RetryExhausted => self.shed_retry += 1,
        }
    }

    /// Export the admission split as `serve.*` counters in the
    /// `cat-obs-v1` registry (one counter per field, same names).
    pub fn export_metrics(&self, m: &mut crate::obs::MetricsRegistry) {
        m.add("serve.submitted", self.submitted as u64);
        m.add("serve.admitted", self.admitted as u64);
        m.add("serve.completed", self.completed as u64);
        m.add("serve.shed_slo", self.shed_slo as u64);
        m.add("serve.shed_capacity", self.shed_capacity as u64);
        m.add("serve.shed_fault", self.shed_fault as u64);
        m.add("serve.shed_retry", self.shed_retry as u64);
        m.add("serve.requeued", self.requeued as u64);
        m.add("serve.retried", self.retried as u64);
    }
}

/// Seeded synthetic traffic (virtual-clock timestamps, ns from stream
/// start) for closed-form-checkable serving experiments.
pub struct TrafficGen;

impl TrafficGen {
    /// Open-loop Poisson arrivals: `n` timestamps with exponential
    /// inter-arrival times at `rps` requests/second.  Deterministic for a
    /// fixed seed.
    pub fn poisson(seed: u64, rps: f64, n: usize) -> Vec<u64> {
        assert!(rps > 0.0, "rps must be positive");
        let mut rng = Prng::new(seed);
        let mut t_ns = 0.0f64;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            // inverse-CDF exponential; 1-u is in (0, 1] so ln() is finite
            let gap_s = -(1.0 - rng.f64()).ln() / rps;
            t_ns += gap_s * 1e9;
            out.push(t_ns as u64);
        }
        out
    }

    /// Bursty arrivals: Poisson burst epochs at `rps / burst` bursts per
    /// second, each delivering `burst` back-to-back requests — same mean
    /// rate as [`TrafficGen::poisson`], much spikier tails.
    pub fn bursty(seed: u64, rps: f64, n: usize, burst: usize) -> Vec<u64> {
        let burst = burst.max(1);
        let epochs = TrafficGen::poisson(seed, rps / burst as f64, n.div_ceil(burst));
        let mut out = Vec::with_capacity(n);
        for e in epochs {
            for _ in 0..burst {
                if out.len() == n {
                    return out;
                }
                out.push(e);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_is_sorted_deterministic_and_near_rate() {
        let a = TrafficGen::poisson(7, 1000.0, 2000);
        let b = TrafficGen::poisson(7, 1000.0, 2000);
        assert_eq!(a, b);
        assert_ne!(a, TrafficGen::poisson(8, 1000.0, 2000));
        assert!(a.windows(2).all(|w| w[0] <= w[1]));
        // mean rate within 10% over 2000 draws
        let span_s = *a.last().unwrap() as f64 / 1e9;
        let rate = a.len() as f64 / span_s;
        assert!((rate - 1000.0).abs() < 100.0, "rate {rate}");
    }

    #[test]
    fn bursty_groups_arrivals() {
        let a = TrafficGen::bursty(3, 1000.0, 100, 10);
        assert_eq!(a.len(), 100);
        assert!(a.windows(2).all(|w| w[0] <= w[1]));
        // each epoch repeats 10x
        assert_eq!(a[0], a[9]);
        assert!(a[10] > a[9]);
    }

    #[test]
    fn stats_conserve() {
        let mut s =
            AdmissionStats { submitted: 10, admitted: 7, completed: 7, ..Default::default() };
        s.record_shed(ShedReason::Slo);
        s.record_shed(ShedReason::Slo);
        s.record_shed(ShedReason::Capacity);
        assert_eq!(s.shed(), 3);
        assert!(s.accounted());
        assert!((s.shed_rate() - 0.3).abs() < 1e-12);
        assert_eq!(ShedReason::Capacity.as_str(), "capacity");
    }

    #[test]
    fn stats_conserve_with_fault_sheds() {
        // 12 submitted: 2 shed at arrival, 10 admitted; of those, 7
        // completed, 2 shed to a fault, 1 exhausted its retries
        let mut s =
            AdmissionStats { submitted: 12, admitted: 10, completed: 7, ..Default::default() };
        s.record_shed(ShedReason::Slo);
        s.record_shed(ShedReason::Capacity);
        s.record_shed(ShedReason::Fault);
        s.record_shed(ShedReason::Fault);
        s.record_shed(ShedReason::RetryExhausted);
        assert_eq!(s.shed(), 5);
        assert!(s.accounted());
        // losing a fault shed breaks conservation
        s.shed_fault -= 1;
        assert!(!s.accounted());
    }

    #[test]
    fn shed_reason_strings_are_pinned() {
        // the JSON schema strings — changing one is a report break
        assert_eq!(ShedReason::Slo.as_str(), "slo");
        assert_eq!(ShedReason::Capacity.as_str(), "capacity");
        assert_eq!(ShedReason::Fault.as_str(), "fault");
        assert_eq!(ShedReason::RetryExhausted.as_str(), "retry_exhausted");
    }
}
