//! The accelerator fleet: deployable backends derived from an explore
//! frontier.
//!
//! Each [`Backend`] is one frontier [`DesignPoint`] turned back into an
//! executable deployment via [`deploy_plan`] — the same plan the
//! explorer simulated — plus a pre-simulated **service profile**: the
//! batch-completion time and useful-op count for every batch size the
//! serving batcher can emit (1..=`max_batch`), obtained from
//! [`run_multi_edpu`] riding the stage-sim cache.  The router then makes
//! per-request decisions by table lookup; no DES runs on the serving
//! hot path.

use std::collections::BTreeMap;

use super::links::{demand_at, negotiate_in, LinkLedger, NegotiationMode};
use crate::arch::{AcceleratorPlan, PlResources};
use crate::config::{HardwareConfig, ModelConfig, SharedLinkModel};
use crate::dse::{
    deploy_plan, deploy_plan_in_share, partition_frontier, DesignPoint, ExploreResult,
    PartitionConfig, PartitionStats, Share,
};
use crate::sched::run_multi_edpu;
use crate::util::json::Json;
use anyhow::{anyhow, Result};

/// One deployed member of the accelerator family.  The re-derived plan
/// is consumed at deploy time to build the service profile; serving
/// itself only ever consults the profile and the design point.
pub struct Backend {
    /// Position in the fleet (cost order: cheapest first).
    pub id: usize,
    /// The frontier design point this backend deploys.
    pub point: DesignPoint,
    /// `profile[k-1]` = (service time ns, useful ops) for a batch of `k`.
    profile: Vec<(u64, u64)>,
}

impl Backend {
    /// Deploy one frontier point: re-derive its plan and pre-simulate the
    /// service profile for batches `1..=max_batch`.
    pub fn deploy(
        model: &ModelConfig,
        board: &HardwareConfig,
        point: &DesignPoint,
        max_batch: usize,
    ) -> Result<Backend> {
        let plan = deploy_plan(model, board, &point.cand)?;
        Backend::from_plan(&plan, point, max_batch)
    }

    /// Deploy one frontier point **inside a board share** (partitioned
    /// fleet): the plan is re-derived under the member's granted
    /// AIE/PL slice via [`deploy_plan_in_share`], so the service profile
    /// — and therefore the router's worst-case admission bound — is
    /// re-simulated against the budget-constrained deployment, not the
    /// whole board.  `mem_throttle` is the slice's negotiated share of
    /// the board's DRAM/PCIe pools (`1.0` = uncontended; see
    /// [`super::links`]): a throttled slice streams slower, so the
    /// re-simulated profile prices the shared-memory contention.
    pub fn deploy_in_share(
        model: &ModelConfig,
        board: &HardwareConfig,
        point: &DesignPoint,
        max_batch: usize,
        share: &Share,
        mem_throttle: f64,
    ) -> Result<Backend> {
        let plan = deploy_plan_in_share(model, board, &point.cand, share, mem_throttle)?;
        Backend::from_plan(&plan, point, max_batch)
    }

    /// Pre-simulate the service profile for batches `1..=max_batch` of an
    /// already-derived plan (shared tail of both deploy paths).
    fn from_plan(
        plan: &AcceleratorPlan,
        point: &DesignPoint,
        max_batch: usize,
    ) -> Result<Backend> {
        assert!(max_batch > 0, "max_batch must be positive");
        let mut profile = Vec::with_capacity(max_batch);
        for k in 1..=max_batch {
            let r = run_multi_edpu(plan, point.cand.n_edpu, k, point.cand.multi_mode)?;
            profile.push((r.service_ns().ceil() as u64, r.ops));
        }
        Ok(Backend { id: 0, point: point.clone(), profile })
    }

    /// Simulated completion time for a batch of `k` (1 ≤ k ≤ max_batch).
    pub fn service_ns(&self, k: usize) -> u64 {
        self.profile[k - 1].0
    }

    /// Useful MM ops a batch of `k` executes.
    pub fn ops(&self, k: usize) -> u64 {
        self.profile[k - 1].1
    }

    /// Worst-case service time over every batch size the batcher can
    /// emit — the router's admission bound uses this so the bound holds
    /// however the forming batch fills up.
    pub fn max_service_ns(&self) -> u64 {
        self.profile.iter().map(|p| p.0).max().unwrap_or(0)
    }

    /// Largest batch this backend's profile covers.
    pub fn max_batch(&self) -> usize {
        self.profile.len()
    }

    /// The full pre-simulated service profile: `profile()[k-1]` is
    /// `(service ns, useful ops)` for a batch of `k`.  Read-only — the
    /// observability layer exports it so a trace viewer can relate
    /// observed batch spans back to the simulated table.
    pub fn profile(&self) -> &[(u64, u64)] {
        &self.profile
    }

    /// Routing cost: board power of this deployment (W) — "cheapest
    /// backend that fits the SLO" minimizes energy, the Table VI currency.
    pub fn power_w(&self) -> f64 {
        self.point.power_w
    }
}

/// The one-board resource ledger of a **partitioned** fleet: how much of
/// the physical `Total_AIE` array and the Table V PL pools the deployed
/// members jointly consume, which [`Share`] each fleet position was
/// granted, and the partition-search accounting.  [`Fleet::select_partitioned`]
/// threads the shares into every member's deployment and this ledger into
/// the `cat-serve-v2` report's `board` block.
#[derive(Debug, Clone)]
pub struct FleetBudget {
    /// Board the fleet co-resides on.
    pub board: String,
    pub aie_total: usize,
    pub aie_used: usize,
    pub pl_total: PlResources,
    pub pl_used: PlResources,
    /// `shares[i]` belongs to fleet position `i` (cost order).
    pub shares: Vec<Share>,
    /// Σ SLO-feasible member TOPS the partitioner maximized.
    pub objective_tops: f64,
    pub stats: PartitionStats,
    /// Shared memory-path ledger (DRAM + PCIe pools, per-member grants
    /// and throttle factors).  `Some` when the fleet was built with a
    /// [`SharedLinkModel`] — the report then carries schema
    /// `cat-serve-v3` with a `board.links` block; `None` keeps the PR 4
    /// `cat-serve-v2` semantics (members draw the pools for free).
    pub links: Option<LinkLedger>,
}

impl FleetBudget {
    /// AIE cores left unallocated on the board.
    pub fn aie_residual(&self) -> usize {
        self.aie_total - self.aie_used
    }

    /// The `board` block of the `cat-serve-v2`/`cat-serve-v3` schemas
    /// (v3 adds the `links` sub-block when the link model is on).
    pub fn to_json(&self) -> Json {
        let pool = |used: usize, total: usize| {
            let mut p = BTreeMap::new();
            p.insert("used".into(), Json::Num(used as f64));
            p.insert("total".into(), Json::Num(total as f64));
            p.insert(
                "utilization".into(),
                Json::Num(if total == 0 { 0.0 } else { used as f64 / total as f64 }),
            );
            Json::Obj(p)
        };
        let mut m = BTreeMap::new();
        m.insert("hw".into(), Json::Str(self.board.clone()));
        m.insert("aie_total".into(), Json::Num(self.aie_total as f64));
        m.insert("aie_used".into(), Json::Num(self.aie_used as f64));
        m.insert("aie_residual".into(), Json::Num(self.aie_residual() as f64));
        let mut pl = BTreeMap::new();
        pl.insert("luts".into(), pool(self.pl_used.luts, self.pl_total.luts));
        pl.insert("ffs".into(), pool(self.pl_used.ffs, self.pl_total.ffs));
        pl.insert("brams".into(), pool(self.pl_used.brams, self.pl_total.brams));
        pl.insert("urams".into(), pool(self.pl_used.urams, self.pl_total.urams));
        m.insert("pl".into(), Json::Obj(pl));
        let s = &self.stats;
        m.insert("backends_requested".into(), Json::Num(s.requested as f64));
        m.insert("backends_selected".into(), Json::Num(s.selected as f64));
        let mut part = BTreeMap::new();
        part.insert("candidates".into(), Json::Num(s.candidates as f64));
        part.insert("subsets_considered".into(), Json::Num(s.subsets_considered as f64));
        part.insert("aie_infeasible".into(), Json::Num(s.aie_infeasible as f64));
        part.insert("pl_infeasible".into(), Json::Num(s.pl_infeasible as f64));
        part.insert("feasible".into(), Json::Num(s.feasible as f64));
        part.insert("greedy".into(), Json::Bool(s.greedy));
        part.insert("objective_tops".into(), Json::Num(self.objective_tops));
        m.insert("partition".into(), Json::Obj(part));
        if let Some(links) = &self.links {
            m.insert("links".into(), links.to_json());
        }
        m.insert(
            "shares".into(),
            Json::Arr(
                self.shares
                    .iter()
                    .enumerate()
                    .map(|(i, sh)| {
                        let mut sm = BTreeMap::new();
                        sm.insert("backend".into(), Json::Num(i as f64));
                        sm.insert("aie".into(), Json::Num(sh.aie as f64));
                        sm.insert("pl_luts".into(), Json::Num(sh.pl.luts as f64));
                        sm.insert("pl_ffs".into(), Json::Num(sh.pl.ffs as f64));
                        sm.insert("pl_brams".into(), Json::Num(sh.pl.brams as f64));
                        sm.insert("pl_urams".into(), Json::Num(sh.pl.urams as f64));
                        Json::Obj(sm)
                    })
                    .collect(),
            ),
        );
        Json::Obj(m)
    }
}

/// The deployed family, sorted by [`Backend::power_w`] ascending so the
/// router's first SLO-feasible hit is the cheapest one.
pub struct Fleet {
    pub backends: Vec<Backend>,
    /// One-board ledger when this fleet was built by
    /// [`Fleet::select_partitioned`]; `None` = PR 3 semantics, every
    /// member owns a whole board.  The deployment mode travels WITH the
    /// fleet, so the serving loop's energy accounting and the report
    /// schema can never disagree with how the backends were actually
    /// deployed.
    pub budget: Option<FleetBudget>,
    /// Cluster ledger when this fleet was spread across a multi-board
    /// spec by [`crate::cluster::build_fleet`]; `None` = a single-board
    /// (or one-board-per-member) fleet.  Like [`Fleet::budget`], it
    /// travels with the fleet so serving, energy accounting, and the
    /// report schema always agree with the deployment.
    pub cluster: Option<crate::cluster::ClusterBudget>,
}

/// The shared frontier ranking both selection modes start from: power
/// ascending (ties broken by candidate index), exact (cores, latency)
/// duplicates collapsed.
fn ranked(explored: &ExploreResult) -> Result<Vec<&DesignPoint>> {
    let mut pts: Vec<&DesignPoint> = explored.frontier_points().collect();
    if pts.is_empty() {
        return Err(anyhow!("exploration produced an empty frontier — nothing to deploy"));
    }
    pts.sort_by(|a, b| a.power_w.total_cmp(&b.power_w).then(a.cand.index.cmp(&b.cand.index)));
    pts.dedup_by(|a, b| a.total_cores == b.total_cores && a.latency_ms == b.latency_ms);
    Ok(pts)
}

impl Fleet {
    /// Select up to `k` diverse members of the explore frontier and
    /// deploy them.
    ///
    /// Selection is deterministic: frontier points are ranked
    /// ([`ranked`]) and `k ≥ 2` evenly spaced picks keep both extremes —
    /// the frugal end serves relaxed requests cheaply, the powerful end
    /// absorbs tight SLOs and bursts.  A fleet of one deploys the
    /// **most powerful** member: a lone backend's first job is meeting
    /// the SLO at all, not meeting it cheaply.  Every member is assumed
    /// to own a whole board; [`Fleet::select_partitioned`] is the
    /// one-board co-residency variant.
    pub fn select(
        model: &ModelConfig,
        board: &HardwareConfig,
        explored: &ExploreResult,
        k: usize,
        max_batch: usize,
    ) -> Result<Fleet> {
        let pts = ranked(explored)?;
        let k = k.clamp(1, pts.len());
        let picks: Vec<usize> = if k == pts.len() {
            (0..k).collect()
        } else if k == 1 {
            vec![pts.len() - 1]
        } else {
            // evenly spaced over the sorted list, endpoints included;
            // strictly increasing because k <= pts.len()
            (0..k).map(|j| j * (pts.len() - 1) / (k - 1)).collect()
        };
        let mut backends = Vec::with_capacity(k);
        for (id, &pi) in picks.iter().enumerate() {
            let mut b = Backend::deploy(model, board, pts[pi], max_batch)?;
            b.id = id;
            backends.push(b);
        }
        Ok(Fleet { backends, budget: None, cluster: None })
    }

    /// Select the best frontier subset that **co-resides on one board**
    /// and deploy it: the members' joint footprint satisfies
    /// `Σ total_cores ≤ Total_AIE` and the Table V PL pool bounds (the
    /// same checks `dse::prune` applies per point), chosen to maximize
    /// Σ TOPS over members whose **worst-case service bound** fits the
    /// SLO — every candidate's profile is pre-simulated at the serving
    /// batch cap (cheap through the stage-sim cache), so the partitioner
    /// scores on the *same* inequality the router's admission enforces
    /// ([`partition_frontier`]).  Each member is then re-derived under
    /// its granted [`Share`] via [`Backend::deploy_in_share`], so every
    /// service profile — and the router's per-member worst-case bound —
    /// reflects the budget-constrained deployment.  An infeasible `k`
    /// degrades to the largest feasible subset; the drop is visible in
    /// the returned [`FleetBudget::stats`].
    ///
    /// `links` enables the **shared memory-path model** ([`super::links`]):
    /// the selected members' DRAM/PCIe demands are negotiated against the
    /// pools, and any member of an oversubscribed pool redeploys on a
    /// throttled slice whose re-simulated profile prices the contention.
    /// `None` keeps the PR 4 free-pool behavior (schema `cat-serve-v2`).
    /// Selection gates on the *uncontended* bounds (contention depends on
    /// who is selected, so it cannot gate its own selection); the router
    /// still admits against each member's post-throttle profile, so SLO
    /// compliance is never at risk — a throttled member that can no
    /// longer bound a request under the SLO simply sheds it.
    ///
    /// Members inherit the ranking's power order, so the returned fleet
    /// keeps the router's cheapest-first contract.  The returned fleet
    /// carries its [`FleetBudget`] (see [`Fleet::budget`]), which the
    /// serving loop consults for shared-board energy accounting and the
    /// `cat-serve-v2`/`cat-serve-v3` board block.
    pub fn select_partitioned(
        model: &ModelConfig,
        board: &HardwareConfig,
        explored: &ExploreResult,
        k: usize,
        max_batch: usize,
        slo_ms: Option<f64>,
        links: Option<&SharedLinkModel>,
    ) -> Result<Fleet> {
        Self::select_partitioned_in(
            model,
            board,
            explored,
            k,
            max_batch,
            slo_ms,
            links,
            NegotiationMode::SinglePass,
        )
    }

    /// [`Fleet::select_partitioned`] with an explicit [`NegotiationMode`].
    /// In fixed-point mode each member's slice carries the *relaxed*
    /// share (`mem_throttle = 1 / stretch_fixed_point`), so the
    /// re-simulated contended profile — and with it the router's
    /// admission bound — sheds the single-pass pessimism.
    #[allow(clippy::too_many_arguments)]
    pub fn select_partitioned_in(
        model: &ModelConfig,
        board: &HardwareConfig,
        explored: &ExploreResult,
        k: usize,
        max_batch: usize,
        slo_ms: Option<f64>,
        links: Option<&SharedLinkModel>,
        mode: NegotiationMode,
    ) -> Result<Fleet> {
        if let Some(pools) = links {
            if !pools.is_positive_finite() {
                return Err(anyhow!(
                    "shared link pools must be positive and finite, got DRAM {} GB/s / \
                     PCIe {} GB/s (disable the link model with links=None instead of \
                     zeroing a pool)",
                    pools.dram_gbps,
                    pools.pcie_gbps
                ));
            }
        }
        let pts = ranked(explored)?;
        // Admission-bound pass: pre-simulate every candidate's service
        // profile (shares are allocated at the designed footprint, so
        // the whole-board profile equals the in-share one — the PR 4
        // degeneracy property) and hand the partitioner the router's
        // worst-case bound per candidate.  Without an SLO the objective
        // never reads the bounds, so the whole-frontier pass is skipped
        // (the zeros below are placeholders the partitioner ignores).
        let bounds: Vec<u64> = if slo_ms.is_some() {
            pts.iter()
                .map(|p| Backend::deploy(model, board, p, max_batch).map(|b| b.max_service_ns()))
                .collect::<Result<_>>()?
        } else {
            vec![0; pts.len()]
        };
        let mut pcfg = PartitionConfig::new(k);
        pcfg.slo_ms = slo_ms;
        let part = partition_frontier(&pts, &bounds, board, &pcfg)?;
        // Link negotiation over the *selected* members' uncontended
        // demands at the serving batch cap.  Only the selected members
        // are deployed here; when the bounds pass already simulated
        // them, the stage-sim cache makes these re-derivations lookups.
        let ledger = match links {
            None => None,
            Some(pools) => {
                let mut demands = Vec::with_capacity(part.members.len());
                for &pi in &part.members {
                    let be = Backend::deploy(model, board, pts[pi], max_batch)?;
                    demands.push(demand_at(model, be.service_ns(be.max_batch()), be.max_batch()));
                }
                Some(negotiate_in(pools, &demands, mode))
            }
        };
        let budget = FleetBudget {
            board: board.name.clone(),
            aie_total: board.total_aie,
            aie_used: part.aie_used,
            pl_total: PlResources::pools_of(board),
            pl_used: part.pl_used,
            shares: part.shares,
            objective_tops: part.objective_tops,
            stats: part.stats,
            links: ledger,
        };
        let mut backends = Vec::with_capacity(part.members.len());
        for (id, (&pi, share)) in part.members.iter().zip(&budget.shares).enumerate() {
            let throttle = budget
                .links
                .as_ref()
                .map(|l| 1.0 / l.members[id].stretch)
                .unwrap_or(1.0);
            let mut b =
                Backend::deploy_in_share(model, board, pts[pi], max_batch, share, throttle)?;
            b.id = id;
            backends.push(b);
        }
        Ok(Fleet { backends, budget: Some(budget), cluster: None })
    }

    pub fn len(&self) -> usize {
        self.backends.len()
    }

    pub fn is_empty(&self) -> bool {
        self.backends.is_empty()
    }

    /// Seed the serving loop's event-driven admission plane from this
    /// fleet: one [`AdmissionIndex`](super::AdmissionIndex) entry per
    /// member, in fleet order.  Fleet order IS cost order — `select`
    /// ranks the frontier cheapest-first, partitioned fleets deploy the
    /// ranked picks in place, and cluster fleets arrive flat re-ranked
    /// power-ascending across boards ([`crate::cluster::build_fleet`]) —
    /// so the index's in-order probe reproduces the cheapest-first scan
    /// for every fleet shape.  `wait_ns` is the resolved staleness
    /// budget; each member contributes its worst-case service bound
    /// (renegotiation redeploys update it through
    /// [`AdmissionIndex::set_max_service`](super::AdmissionIndex::set_max_service)).
    pub fn admission_seed(&self, wait_ns: u64) -> super::AdmissionIndex {
        let max_services: Vec<u64> = self.backends.iter().map(|b| b.max_service_ns()).collect();
        super::AdmissionIndex::new(&max_services, wait_ns)
    }

    /// Largest batch every member's service profile covers — the serving
    /// loop clamps its batch cap to this, so profile lookups can't go out
    /// of range however the fleet was built.
    pub fn max_batch(&self) -> usize {
        self.backends.iter().map(Backend::max_batch).min().unwrap_or(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::customize::CustomizeOptions;
    use crate::dse::{Candidate, SpaceSpec};
    use crate::sched::MultiEdpuMode;

    fn explored() -> ExploreResult {
        let model = ModelConfig::bert_base();
        let hw = HardwareConfig::vck5000();
        let mut cfg = crate::dse::ExploreConfig::new(model, hw);
        cfg.sample_budget = None;
        cfg.space = SpaceSpec::compact_9pt();
        crate::dse::explore(&cfg).unwrap()
    }

    #[test]
    fn backend_profile_is_monotone_and_bounded_by_max() {
        let model = ModelConfig::bert_base();
        let hw = HardwareConfig::vck5000();
        let cand = Candidate {
            index: 0,
            opts: CustomizeOptions::default(),
            batch: 4,
            edpu_budget: hw.total_aie,
            n_edpu: 1,
            multi_mode: MultiEdpuMode::Parallel,
        };
        let plan = deploy_plan(&model, &hw, &cand).unwrap();
        let r = run_multi_edpu(&plan, 1, 4, MultiEdpuMode::Parallel).unwrap();
        let point = crate::dse::evaluate(&plan, &cand).unwrap();
        let be = Backend::deploy(&model, &hw, &point, 6).unwrap();
        assert_eq!(be.max_batch(), 6);
        // profile matches the underlying simulation at the probed batch
        assert_eq!(be.service_ns(4), r.service_ns().ceil() as u64);
        assert_eq!(be.ops(4), r.ops);
        // service time grows with batch size; max covers every entry
        for k in 2..=6 {
            assert!(be.service_ns(k) >= be.service_ns(k - 1), "batch {k} shrank");
        }
        assert_eq!(be.max_service_ns(), be.service_ns(6));
        assert!(be.power_w() > 0.0);
    }

    #[test]
    fn select_orders_by_power_and_keeps_extremes() {
        let model = ModelConfig::bert_base();
        let hw = HardwareConfig::vck5000();
        let ex = explored();
        assert!(ex.frontier.len() >= 2, "compact space frontier too small");
        let fleet = Fleet::select(&model, &hw, &ex, 2, 4).unwrap();
        assert_eq!(fleet.len(), 2);
        assert!(fleet.backends[0].power_w() <= fleet.backends[1].power_w());
        // ids are fleet positions
        for (i, b) in fleet.backends.iter().enumerate() {
            assert_eq!(b.id, i);
        }
        // asking for more backends than frontier points clamps
        let big = Fleet::select(&model, &hw, &ex, 64, 4).unwrap();
        assert!(big.len() <= ex.frontier.len());
        assert!(!big.is_empty());
        // a fleet of one deploys the most powerful member, not the
        // cheapest — a lone backend must be able to meet tight SLOs
        let solo = Fleet::select(&model, &hw, &ex, 1, 4).unwrap();
        assert_eq!(solo.len(), 1);
        for b in &big.backends {
            assert!(solo.backends[0].power_w() >= b.power_w());
        }
    }

    #[test]
    fn select_partitioned_fits_one_board_and_threads_shares() {
        let model = ModelConfig::bert_base();
        let hw = HardwareConfig::vck5000();
        let ex = explored();
        let fleet =
            Fleet::select_partitioned(&model, &hw, &ex, 2, 4, Some(80.0), Some(&hw.links()))
                .unwrap();
        let budget = fleet.budget.as_ref().expect("partitioned fleet carries its budget");
        assert_eq!(fleet.len(), budget.shares.len());
        assert_eq!(budget.aie_total, hw.total_aie);
        assert!(budget.aie_used <= budget.aie_total);
        assert_eq!(
            budget.aie_used,
            fleet.backends.iter().map(|b| b.point.total_cores).sum::<usize>()
        );
        assert!(budget.pl_used.luts <= budget.pl_total.luts);
        assert!(budget.pl_used.brams <= budget.pl_total.brams);
        // shares are the members' designed footprints, in fleet order
        for (b, s) in fleet.backends.iter().zip(&budget.shares) {
            assert_eq!(s.aie, b.point.total_cores);
            assert_eq!(s.pl.luts, b.point.pl_luts);
        }
        // the ranking's cost order survives partitioning
        for w in fleet.backends.windows(2) {
            assert!(w[0].power_w() <= w[1].power_w());
        }
        // the board JSON block is self-consistent
        let j = budget.to_json();
        let used = j.get("aie_used").unwrap().as_usize().unwrap();
        let total = j.get("aie_total").unwrap().as_usize().unwrap();
        assert!(used <= total);
        assert_eq!(j.get("aie_residual").unwrap().as_usize().unwrap(), total - used);
        // link model on: the ledger rode along, one entry per member,
        // pools = the board's, and the JSON gained the links block
        let ledger = budget.links.as_ref().expect("link model was enabled");
        assert_eq!(ledger.members.len(), fleet.len());
        assert_eq!(ledger.pools, hw.links());
        for m in &ledger.members {
            assert!(m.stretch >= 1.0);
            assert!(m.demand.dram_gbps > 0.0 && m.demand.pcie_gbps > 0.0);
        }
        assert!(j.get("links").is_some(), "board block carries the links ledger");

        // link model off: no ledger, no links block (PR 4 semantics)
        let v2 = Fleet::select_partitioned(&model, &hw, &ex, 2, 4, Some(80.0), None).unwrap();
        let v2_budget = v2.budget.as_ref().unwrap();
        assert!(v2_budget.links.is_none());
        assert!(v2_budget.to_json().get("links").is_none());
    }
}
