//! The accelerator fleet: deployable backends derived from an explore
//! frontier.
//!
//! Each [`Backend`] is one frontier [`DesignPoint`] turned back into an
//! executable deployment via [`deploy_plan`] — the same plan the
//! explorer simulated — plus a pre-simulated **service profile**: the
//! batch-completion time and useful-op count for every batch size the
//! serving batcher can emit (1..=`max_batch`), obtained from
//! [`run_multi_edpu`] riding the stage-sim cache.  The router then makes
//! per-request decisions by table lookup; no DES runs on the serving
//! hot path.

use crate::config::{HardwareConfig, ModelConfig};
use crate::dse::{deploy_plan, DesignPoint, ExploreResult};
use crate::sched::run_multi_edpu;
use anyhow::{anyhow, Result};

/// One deployed member of the accelerator family.  The re-derived plan
/// is consumed at deploy time to build the service profile; serving
/// itself only ever consults the profile and the design point.
pub struct Backend {
    /// Position in the fleet (cost order: cheapest first).
    pub id: usize,
    /// The frontier design point this backend deploys.
    pub point: DesignPoint,
    /// `profile[k-1]` = (service time ns, useful ops) for a batch of `k`.
    profile: Vec<(u64, u64)>,
}

impl Backend {
    /// Deploy one frontier point: re-derive its plan and pre-simulate the
    /// service profile for batches `1..=max_batch`.
    pub fn deploy(
        model: &ModelConfig,
        board: &HardwareConfig,
        point: &DesignPoint,
        max_batch: usize,
    ) -> Result<Backend> {
        assert!(max_batch > 0, "max_batch must be positive");
        let plan = deploy_plan(model, board, &point.cand)?;
        let mut profile = Vec::with_capacity(max_batch);
        for k in 1..=max_batch {
            let r = run_multi_edpu(&plan, point.cand.n_edpu, k, point.cand.multi_mode)?;
            profile.push((r.service_ns().ceil() as u64, r.ops));
        }
        Ok(Backend { id: 0, point: point.clone(), profile })
    }

    /// Simulated completion time for a batch of `k` (1 ≤ k ≤ max_batch).
    pub fn service_ns(&self, k: usize) -> u64 {
        self.profile[k - 1].0
    }

    /// Useful MM ops a batch of `k` executes.
    pub fn ops(&self, k: usize) -> u64 {
        self.profile[k - 1].1
    }

    /// Worst-case service time over every batch size the batcher can
    /// emit — the router's admission bound uses this so the bound holds
    /// however the forming batch fills up.
    pub fn max_service_ns(&self) -> u64 {
        self.profile.iter().map(|p| p.0).max().unwrap_or(0)
    }

    /// Largest batch this backend's profile covers.
    pub fn max_batch(&self) -> usize {
        self.profile.len()
    }

    /// Routing cost: board power of this deployment (W) — "cheapest
    /// backend that fits the SLO" minimizes energy, the Table VI currency.
    pub fn power_w(&self) -> f64 {
        self.point.power_w
    }
}

/// The deployed family, sorted by [`Backend::power_w`] ascending so the
/// router's first SLO-feasible hit is the cheapest one.
pub struct Fleet {
    pub backends: Vec<Backend>,
}

impl Fleet {
    /// Select up to `k` diverse members of the explore frontier and
    /// deploy them.
    ///
    /// Selection is deterministic: frontier points are sorted by power
    /// ascending (ties broken by candidate index), exact duplicates by
    /// (cores, latency) collapse, and `k ≥ 2` evenly spaced picks keep
    /// both extremes — the frugal end serves relaxed requests cheaply,
    /// the powerful end absorbs tight SLOs and bursts.  A fleet of one
    /// deploys the **most powerful** member: a lone backend's first job
    /// is meeting the SLO at all, not meeting it cheaply.
    pub fn select(
        model: &ModelConfig,
        board: &HardwareConfig,
        explored: &ExploreResult,
        k: usize,
        max_batch: usize,
    ) -> Result<Fleet> {
        let mut pts: Vec<&DesignPoint> = explored.frontier_points().collect();
        if pts.is_empty() {
            return Err(anyhow!("exploration produced an empty frontier — nothing to deploy"));
        }
        pts.sort_by(|a, b| {
            a.power_w.total_cmp(&b.power_w).then(a.cand.index.cmp(&b.cand.index))
        });
        pts.dedup_by(|a, b| a.total_cores == b.total_cores && a.latency_ms == b.latency_ms);
        let k = k.clamp(1, pts.len());
        let picks: Vec<usize> = if k == pts.len() {
            (0..k).collect()
        } else if k == 1 {
            vec![pts.len() - 1]
        } else {
            // evenly spaced over the sorted list, endpoints included;
            // strictly increasing because k <= pts.len()
            (0..k).map(|j| j * (pts.len() - 1) / (k - 1)).collect()
        };
        let mut backends = Vec::with_capacity(k);
        for (id, &pi) in picks.iter().enumerate() {
            let mut b = Backend::deploy(model, board, pts[pi], max_batch)?;
            b.id = id;
            backends.push(b);
        }
        Ok(Fleet { backends })
    }

    pub fn len(&self) -> usize {
        self.backends.len()
    }

    pub fn is_empty(&self) -> bool {
        self.backends.is_empty()
    }

    /// Largest batch every member's service profile covers — the serving
    /// loop clamps its batch cap to this, so profile lookups can't go out
    /// of range however the fleet was built.
    pub fn max_batch(&self) -> usize {
        self.backends.iter().map(Backend::max_batch).min().unwrap_or(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::customize::CustomizeOptions;
    use crate::dse::{Candidate, SpaceSpec};
    use crate::sched::MultiEdpuMode;

    fn explored() -> ExploreResult {
        let model = ModelConfig::bert_base();
        let hw = HardwareConfig::vck5000();
        let mut cfg = crate::dse::ExploreConfig::new(model, hw);
        cfg.sample_budget = None;
        cfg.space = SpaceSpec::compact_9pt();
        crate::dse::explore(&cfg).unwrap()
    }

    #[test]
    fn backend_profile_is_monotone_and_bounded_by_max() {
        let model = ModelConfig::bert_base();
        let hw = HardwareConfig::vck5000();
        let cand = Candidate {
            index: 0,
            opts: CustomizeOptions::default(),
            batch: 4,
            edpu_budget: hw.total_aie,
            n_edpu: 1,
            multi_mode: MultiEdpuMode::Parallel,
        };
        let plan = deploy_plan(&model, &hw, &cand).unwrap();
        let r = run_multi_edpu(&plan, 1, 4, MultiEdpuMode::Parallel).unwrap();
        let point = crate::dse::evaluate(&plan, &cand).unwrap();
        let be = Backend::deploy(&model, &hw, &point, 6).unwrap();
        assert_eq!(be.max_batch(), 6);
        // profile matches the underlying simulation at the probed batch
        assert_eq!(be.service_ns(4), r.service_ns().ceil() as u64);
        assert_eq!(be.ops(4), r.ops);
        // service time grows with batch size; max covers every entry
        for k in 2..=6 {
            assert!(be.service_ns(k) >= be.service_ns(k - 1), "batch {k} shrank");
        }
        assert_eq!(be.max_service_ns(), be.service_ns(6));
        assert!(be.power_w() > 0.0);
    }

    #[test]
    fn select_orders_by_power_and_keeps_extremes() {
        let model = ModelConfig::bert_base();
        let hw = HardwareConfig::vck5000();
        let ex = explored();
        assert!(ex.frontier.len() >= 2, "compact space frontier too small");
        let fleet = Fleet::select(&model, &hw, &ex, 2, 4).unwrap();
        assert_eq!(fleet.len(), 2);
        assert!(fleet.backends[0].power_w() <= fleet.backends[1].power_w());
        // ids are fleet positions
        for (i, b) in fleet.backends.iter().enumerate() {
            assert_eq!(b.id, i);
        }
        // asking for more backends than frontier points clamps
        let big = Fleet::select(&model, &hw, &ex, 64, 4).unwrap();
        assert!(big.len() <= ex.frontier.len());
        assert!(!big.is_empty());
        // a fleet of one deploys the most powerful member, not the
        // cheapest — a lone backend must be able to meet tight SLOs
        let solo = Fleet::select(&model, &hw, &ex, 1, 4).unwrap();
        assert_eq!(solo.len(), 1);
        for b in &big.backends {
            assert!(solo.backends[0].power_w() >= b.power_w());
        }
    }
}
