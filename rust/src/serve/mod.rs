//! SLO-aware fleet serving (`cat serve --rps ...`): route a live request
//! stream across an explore-derived accelerator family.
//!
//! The paper derives a *family* of customized accelerators (§IV, Table
//! VI); this module puts the family to work at runtime.  A fleet of
//! logical backends — one per selected [`dse`](crate::dse) frontier point,
//! re-derived via [`dse::deploy_plan`](crate::dse::deploy_plan) and
//! pre-simulated into a per-batch-size service profile ([`fleet`]) — is
//! driven by a **virtual-clock** serving loop:
//!
//! * a seeded open-loop Poisson generator ([`admission::TrafficGen`])
//!   produces arrivals at `--rps`;
//! * each arrival is routed ([`router`]) to the **cheapest** healthy
//!   backend whose worst-case completion bound fits `--slo-ms`, or shed
//!   ([`admission`]) when no bounded queue can make the deadline;
//! * per-backend continuous batching reuses the coordinator's
//!   [`Batcher`] (staleness flushes fire at their exact virtual
//!   deadlines, not on a polling grid);
//! * batch service times come from the explorer's own
//!   [`run_multi_edpu`](crate::sched::run_multi_edpu) machinery via the
//!   stage-sim cache, so the serving loop itself never runs the DES.
//!
//! Everything is integer virtual nanoseconds from a fixed epoch — the
//! loop is deterministic for a fixed seed and closed-form checkable
//! (`rust/tests/serve_properties.rs` asserts request conservation,
//! per-request latency lower bounds, and SLO compliance).
//!
//! **Partitioned mode** (`--partition`): instead of granting every
//! member its own board, [`Fleet::select_partitioned`] picks the best
//! frontier subset that **co-resides on one physical board** — joint
//! `Σ cores ≤ Total_AIE` and Table V PL pool bounds, the Vis-TOP-style
//! overlay scenario — scored on each candidate's pre-simulated
//! worst-case service bound (the router's own admission inequality),
//! and re-derives every member under its granted [`FleetBudget`] share.
//! The **shared memory path** is modeled too ([`links`]): members'
//! DRAM/PCIe demands are negotiated against the board's pools and
//! oversubscribed slices are throttled proportionally, re-simulating
//! their profiles under contention.  The routing/admission path is
//! identical; only the deployments (and hence each member's re-simulated
//! worst-case service bound) change, and the report carries the board
//! ledger under schema `cat-serve-v3` (`cat-serve-v2` when the link
//! model is disabled).
//!
//! **Fault injection** ([`faults`], `--faults`/`--mtbf-s`/`--mttr-s`):
//! a seeded virtual-clock schedule of crashes, stalls, slowdowns, and
//! link degradations is threaded through the same event pump that fires
//! staleness flushes, so fault application is exactly ordered against
//! every other virtual event.  A failed backend drops out of admission;
//! its forming and in-flight batches are drained and **re-admitted**
//! against each rider's *original* deadline on the survivors (bounded
//! retries — unsalvageable riders shed with [`ShedReason::Fault`] /
//! [`ShedReason::RetryExhausted`] so conservation balances exactly).
//! Recovery is event-driven: the backend rejoins the cheapest-first
//! order at its scheduled recovery instant.  On partitioned fleets every
//! down/up transition re-runs the link negotiation over the survivors
//! ([`links::negotiate_masked`]) and redeploys changed members through
//! [`Backend::deploy_in_share`] + the stage-sim cache, so freed
//! bandwidth measurably speeds the survivors up.  Fault runs report
//! schema `cat-serve-v4` with a `faults` block; fault-free runs stay
//! byte-identical `cat-serve-v3`/`v2`/`v1`.

mod admission;
pub mod faults;
mod fleet;
pub mod links;
mod router;

pub use admission::{AdmissionStats, ShedReason, TrafficGen};
pub use faults::{
    BackendFaultStats, FaultEvent, FaultKind, FaultPolicy, FaultSchedule, FaultsReport,
};
pub use fleet::{Backend, Fleet, FleetBudget};
pub use links::{LinkDemand, LinkLedger, MemberLink, NegotiationMode};
pub use router::{route, AdmissionIndex, BackendLoad, RouteDecision};

use std::collections::{BTreeMap, VecDeque};
use std::time::{Duration, Instant};

use crate::config::{HardwareConfig, ModelConfig, SharedLinkModel};
use crate::coordinator::{Batcher, BatcherConfig, ServeStats};
use crate::dse;
use crate::obs::{Obs, PID_SERVE};
use crate::util::json::Json;
use anyhow::{anyhow, Result};

/// One fleet-serving experiment.
#[derive(Debug, Clone)]
pub struct FleetConfig {
    pub model: ModelConfig,
    pub hw: HardwareConfig,
    /// Offered open-loop load (requests/second).
    pub rps: f64,
    /// Per-request completion SLO, arrival → response (ms).
    pub slo_ms: f64,
    /// Synthetic requests to generate.
    pub n_requests: usize,
    /// Fleet size cap (fewer deploy when the frontier is small).
    pub max_backends: usize,
    /// Per-backend serving batch cap.
    pub max_batch: usize,
    /// Admission bound: requests admitted but not yet completed, per
    /// backend (forming batch + dispatched backlog).
    pub queue_cap: usize,
    /// How long a forming batch may wait for more requests before the
    /// staleness flush dispatches it (`None` = SLO/8).
    pub batch_wait: Option<Duration>,
    /// Seed for the Poisson arrivals (and the in-process exploration;
    /// `--mtbf-s` random fault schedules derive from it too).
    pub seed: u64,
    /// `cat explore` sampling budget for the in-process frontier
    /// derivation (`None` = exhaustive).
    pub explore_budget: Option<usize>,
    /// Deploy the fleet as **co-resident partitions of one board**
    /// (`Σ cores ≤ Total_AIE`, joint Table V PL estimate within the
    /// pools) instead of one board per member; the report gains the
    /// `board` ledger and switches to schema `cat-serve-v3`
    /// (`cat-serve-v2` when [`FleetConfig::links`] is `None`).
    pub partition: bool,
    /// Shared memory-path pools for partitioned deployments (`--partition`):
    /// the board's DRAM bandwidth and PCIe link that co-resident members
    /// negotiate over ([`links`]).  Defaults to the board's own pools;
    /// `None` disables the contention model (PR 4 free-pool semantics,
    /// schema `cat-serve-v2`).  Ignored without `partition` — a
    /// one-board-per-member fleet owns its links outright.
    pub links: Option<SharedLinkModel>,
    /// Negotiate link stretches to the fixed point
    /// (`--links-fixed-point`, [`links::NegotiationMode::FixedPoint`])
    /// instead of the conservative single pass.  The selected fleet's
    /// slices carry the relaxed `mem_throttle`, every fault-path
    /// renegotiation uses the same mode, and the links block grows the
    /// dual-bound fields; off (the default) keeps `cat-serve-v3`/`v4`
    /// output byte-identical.  Ignored without `partition`+`links`.
    pub links_fixed_point: bool,
    /// Fault injection ([`faults`]): an explicit schedule or seeded
    /// random faults.  `Some` switches the report to `cat-serve-v4`
    /// with a `faults` block (even when the schedule is empty); `None`
    /// keeps the fault-free path byte-identical to earlier schemas.
    pub faults: Option<FaultPolicy>,
    /// How many times an orphaned rider may be re-admitted after a
    /// fault before it is shed with [`ShedReason::RetryExhausted`].
    pub max_retries: usize,
    /// Multi-board cluster serving (`--cluster <boards.json>`): deploy
    /// the family across every board in the spec — each internally
    /// partitioned — behind one admission plane, with the inter-board
    /// NIC/switch pools negotiated like the on-board links
    /// ([`crate::cluster`]).  `Some` switches the report to schema
    /// `cat-serve-v5` with a `cluster` ledger; `None` keeps every
    /// single-board path byte-identical.  Supersedes `partition`,
    /// `links`, and `hw` (board SKUs come from the spec).
    pub cluster: Option<crate::cluster::ClusterSpec>,
}

impl FleetConfig {
    pub fn new(model: ModelConfig, hw: HardwareConfig) -> FleetConfig {
        let links = Some(hw.links());
        FleetConfig {
            model,
            hw,
            rps: 1000.0,
            slo_ms: 50.0,
            n_requests: 512,
            max_backends: 3,
            max_batch: 8,
            queue_cap: 64,
            batch_wait: None,
            seed: 0xCA7,
            explore_budget: Some(128),
            partition: false,
            links,
            links_fixed_point: false,
            faults: None,
            max_retries: 3,
            cluster: None,
        }
    }

    /// The report schema this config produces — THE flag→schema
    /// decision, in precedence order (pinned by a table test below):
    /// `--cluster` ⇒ v5 (faults/links ride inside it), else faults ⇒
    /// v4, else partition+links ⇒ v3, else partition ⇒ v2, else v1.
    pub fn schema(&self) -> &'static str {
        if self.cluster.is_some() {
            "cat-serve-v5"
        } else if self.faults.is_some() {
            "cat-serve-v4"
        } else if self.partition && self.links.is_some() {
            "cat-serve-v3"
        } else if self.partition {
            "cat-serve-v2"
        } else {
            "cat-serve-v1"
        }
    }

    /// Staleness budget for forming batches: explicit, or SLO/8 so
    /// batching consumes a bounded slice of the deadline.  A
    /// non-positive/NaN SLO degrades to a zero wait (every batch
    /// dispatches immediately) instead of panicking in `Duration`.
    pub fn resolved_batch_wait(&self) -> Duration {
        self.batch_wait.unwrap_or_else(|| {
            let w = self.slo_ms / 8.0 / 1e3;
            Duration::from_secs_f64(if w.is_finite() && w > 0.0 { w } else { 0.0 })
        })
    }

    pub fn slo_ns(&self) -> u64 {
        (self.slo_ms * 1e6).round() as u64
    }

    /// The link negotiation mode this config serves under — threaded
    /// through fleet selection and every fault-path renegotiation so
    /// both always agree.
    pub fn link_mode(&self) -> links::NegotiationMode {
        if self.links_fixed_point {
            links::NegotiationMode::FixedPoint
        } else {
            links::NegotiationMode::SinglePass
        }
    }

    /// THE `cat serve --rps` flag surface → config conversion: every
    /// flag-dependency rule (`--dram-gbps`/`--pcie-gbps`/`--no-links`
    /// require `--partition`, `--links-fixed-point` needs a link model,
    /// `--faults` vs `--mtbf-s`/`--mttr-s` exclusivity, the `--cluster`
    /// conflicts) lives here, not strewn through `main.rs` — so the CLI
    /// and tests validate identically.  Raw strings in, typed config or
    /// the first offending flag's error out.
    pub fn from_args(args: &ServeArgs) -> Result<FleetConfig> {
        let parse_f64 = |flag: &str, s: &str| -> Result<f64> {
            s.parse::<f64>().map_err(|_| anyhow!("--{flag} expects a number, got '{s}'"))
        };
        let parse_usize = |flag: &str, s: &str| -> Result<usize> {
            s.parse::<usize>().map_err(|_| anyhow!("--{flag} expects an integer, got '{s}'"))
        };
        let model = ModelConfig::resolve(args.model.as_deref().unwrap_or("bert-base"))?;
        // --cluster conflicts are checked before the spec file is even
        // read: a contradictory command line should not depend on disk
        let cluster = match args.cluster.as_deref() {
            None => None,
            Some(path) => {
                if args.hw.is_some() {
                    return Err(anyhow!(
                        "--hw conflicts with --cluster (the board SKUs come from the cluster \
                         spec)"
                    ));
                }
                if args.partition {
                    return Err(anyhow!(
                        "--cluster conflicts with --partition: every cluster board is \
                         partitioned internally, and the cluster spec already names the boards"
                    ));
                }
                if args.no_links || args.dram_gbps.is_some() || args.pcie_gbps.is_some() {
                    return Err(anyhow!(
                        "--dram-gbps/--pcie-gbps/--no-links conflict with --cluster: each \
                         board brings its own DRAM/PCIe pools, and the cluster spec sets the \
                         NIC/switch pools"
                    ));
                }
                let src = std::fs::read_to_string(path)
                    .map_err(|e| anyhow!("reading cluster spec '{path}': {e}"))?;
                let j = Json::parse(&src)
                    .map_err(|e| anyhow!("parsing cluster spec '{path}': {e}"))?;
                Some(crate::cluster::ClusterSpec::from_json(&j)?)
            }
        };
        let hw = match &cluster {
            // board 0 stands in for the config-level `hw` (labels,
            // batch-wait defaults); deployment reads the spec per board
            Some(spec) => spec.boards[0].clone(),
            None => HardwareConfig::resolve(args.hw.as_deref().unwrap_or("vck5000"))?,
        };
        let mut cfg = FleetConfig::new(model, hw);
        if let Some(s) = &args.rps {
            cfg.rps = parse_f64("rps", s)?;
        }
        if cfg.rps <= 0.0 || cfg.rps.is_nan() {
            return Err(anyhow!("--rps must be positive, got {}", cfg.rps));
        }
        if let Some(s) = &args.slo_ms {
            cfg.slo_ms = parse_f64("slo-ms", s)?;
        }
        if cfg.slo_ms <= 0.0 || cfg.slo_ms.is_nan() {
            return Err(anyhow!("--slo-ms must be positive, got {}", cfg.slo_ms));
        }
        if let Some(s) = &args.requests {
            cfg.n_requests = parse_usize("requests", s)?;
        }
        if let Some(s) = &args.backends {
            cfg.max_backends = parse_usize("backends", s)?;
        }
        if cfg.max_backends == 0 {
            return Err(anyhow!("--backends must be positive"));
        }
        if let Some(s) = &args.batch {
            cfg.max_batch = parse_usize("batch", s)?;
        }
        if cfg.max_batch == 0 {
            return Err(anyhow!("--batch must be positive"));
        }
        if let Some(s) = &args.queue_cap {
            cfg.queue_cap = parse_usize("queue-cap", s)?;
        }
        if cfg.queue_cap == 0 {
            return Err(anyhow!("--queue-cap must be positive (0 would shed everything)"));
        }
        cfg.partition = args.partition;
        let link_flags = args.no_links
            || args.links_fixed_point
            || args.dram_gbps.is_some()
            || args.pcie_gbps.is_some();
        if link_flags && !cfg.partition && cluster.is_none() {
            return Err(anyhow!(
                "--dram-gbps/--pcie-gbps/--no-links/--links-fixed-point require --partition: \
                 the shared link pools only exist when backends co-reside on one board (a \
                 one-board-per-member fleet owns its links outright)"
            ));
        }
        if args.no_links {
            cfg.links = None;
        }
        if args.links_fixed_point {
            if cfg.links.is_none() {
                return Err(anyhow!(
                    "--links-fixed-point conflicts with --no-links (no contention model to \
                     refine)"
                ));
            }
            cfg.links_fixed_point = true;
        }
        let pool_override = |flag: &str, s: &Option<String>| -> Result<Option<f64>> {
            match s.as_deref() {
                None => Ok(None),
                Some(s) => s
                    .parse::<f64>()
                    .ok()
                    .filter(|v| v.is_finite() && *v > 0.0)
                    .map(Some)
                    .ok_or_else(|| anyhow!("--{flag} expects a positive number, got '{s}'")),
            }
        };
        let dram = pool_override("dram-gbps", &args.dram_gbps)?;
        let pcie = pool_override("pcie-gbps", &args.pcie_gbps)?;
        if dram.is_some() || pcie.is_some() {
            let links = cfg.links.as_mut().ok_or_else(|| {
                anyhow!("--dram-gbps/--pcie-gbps conflict with --no-links (no pools to override)")
            })?;
            if let Some(v) = dram {
                links.dram_gbps = v;
            }
            if let Some(v) = pcie {
                links.pcie_gbps = v;
            }
        }
        if let Some(s) = &args.seed {
            cfg.seed = s.parse().map_err(|_| anyhow!("--seed expects an integer, got '{s}'"))?;
        }
        if let Some(s) = &args.budget {
            cfg.explore_budget = if s == "all" {
                None
            } else {
                match s.parse() {
                    Ok(k) if k > 0 => Some(k),
                    _ => {
                        return Err(anyhow!(
                            "--budget expects a positive integer or 'all', got '{s}'"
                        ))
                    }
                }
            };
        }
        if let Some(path) = args.faults.as_deref() {
            if args.mtbf_s.is_some() || args.mttr_s.is_some() {
                return Err(anyhow!(
                    "--faults (scripted schedule) and --mtbf-s/--mttr-s (random faults) are \
                     mutually exclusive"
                ));
            }
            let src = std::fs::read_to_string(path)
                .map_err(|e| anyhow!("reading fault spec '{path}': {e}"))?;
            let j = Json::parse(&src).map_err(|e| anyhow!("parsing fault spec '{path}': {e}"))?;
            cfg.faults = Some(FaultPolicy::Schedule(FaultSchedule::from_json(&j)?));
        } else {
            match (&args.mtbf_s, &args.mttr_s) {
                (None, None) => {}
                (Some(b), Some(r)) => {
                    let parse_s = |flag: &str, s: &str| -> Result<f64> {
                        s.parse::<f64>().ok().filter(|v| v.is_finite() && *v > 0.0).ok_or_else(
                            || anyhow!("--{flag} expects a positive number of seconds, got '{s}'"),
                        )
                    };
                    cfg.faults = Some(FaultPolicy::Random {
                        mtbf_s: parse_s("mtbf-s", b)?,
                        mttr_s: parse_s("mttr-s", r)?,
                    });
                }
                _ => return Err(anyhow!("--mtbf-s and --mttr-s must be given together")),
            }
        }
        if let Some(s) = &args.max_retries {
            cfg.max_retries =
                s.parse().map_err(|_| anyhow!("--max-retries expects an integer, got '{s}'"))?;
        }
        cfg.cluster = cluster;
        Ok(cfg)
    }
}

/// The raw `cat serve --rps` flag surface, exactly as parsed — every
/// field a string so [`FleetConfig::from_args`] owns ALL parsing and
/// cross-flag validation (and tests can drive it without a process).
/// `None`/`false` means the flag was absent.
#[derive(Debug, Clone, Default)]
pub struct ServeArgs {
    pub model: Option<String>,
    pub hw: Option<String>,
    pub rps: Option<String>,
    pub slo_ms: Option<String>,
    pub requests: Option<String>,
    pub backends: Option<String>,
    pub batch: Option<String>,
    pub queue_cap: Option<String>,
    pub seed: Option<String>,
    pub budget: Option<String>,
    pub partition: bool,
    pub no_links: bool,
    pub links_fixed_point: bool,
    pub dram_gbps: Option<String>,
    pub pcie_gbps: Option<String>,
    pub cluster: Option<String>,
    pub faults: Option<String>,
    pub mtbf_s: Option<String>,
    pub mttr_s: Option<String>,
    pub max_retries: Option<String>,
}

/// One completed request (virtual-clock record).
#[derive(Debug, Clone, Copy)]
pub struct FleetResponse {
    pub id: u64,
    /// Fleet position of the backend that served it.
    pub backend: usize,
    pub arrival_ns: u64,
    pub completion_ns: u64,
    /// Size of the batch this request rode in.
    pub batch_size: usize,
    /// Simulated service time of that batch on its backend.
    pub batch_service_ns: u64,
}

impl FleetResponse {
    pub fn latency_ns(&self) -> u64 {
        self.completion_ns - self.arrival_ns
    }
}

/// One shed request.
#[derive(Debug, Clone, Copy)]
pub struct ShedRecord {
    pub id: u64,
    pub arrival_ns: u64,
    pub reason: ShedReason,
}

/// Per-backend serving summary.
#[derive(Debug, Clone)]
pub struct BackendSummary {
    pub id: usize,
    pub point: dse::DesignPoint,
    pub admitted: usize,
    pub busy_ns: u64,
    /// Useful MM ops executed across every batch served.
    pub ops: u64,
    /// Completed/batches/latency percentiles (virtual durations).
    pub stats: ServeStats,
}

impl BackendSummary {
    /// Fraction of the experiment wall the backend spent serving.
    pub fn utilization(&self, wall_ns: u64) -> f64 {
        if wall_ns == 0 {
            return 0.0;
        }
        self.busy_ns as f64 / wall_ns as f64
    }
}

/// The fleet-serving experiment outcome (schema `cat-serve-v1`;
/// `cat-serve-v2` when a partitioned deployment carries its board
/// ledger; `cat-serve-v3` when the board ledger additionally carries
/// the shared memory-path `links` block; `cat-serve-v4` whenever fault
/// injection was enabled — the `faults` block rides on top of whichever
/// board/links blocks the deployment produced; `cat-serve-v5` for
/// cluster deployments, whose `cluster` ledger subsumes the board
/// block and under which the `faults` block rides unchanged).  The
/// state-derived tag here always matches [`FleetConfig::schema`] for
/// fleets built from the same config.
#[derive(Debug, Clone)]
pub struct FleetReport {
    pub model: String,
    pub hw: String,
    pub rps: f64,
    pub slo_ms: f64,
    pub seed: u64,
    pub n_backends: usize,
    pub admission: AdmissionStats,
    pub responses: Vec<FleetResponse>,
    pub shed: Vec<ShedRecord>,
    pub backends: Vec<BackendSummary>,
    /// Fleet-wide latency stats (virtual durations; wall = stream span).
    pub fleet_stats: ServeStats,
    /// Virtual end of the experiment (last completion or arrival).
    pub wall_ns: u64,
    /// Energy-weighted fleet efficiency: total useful ops over total
    /// energy (Σ power·busy), i.e. busy-time-weighted GOPS/W.
    pub fleet_gops_per_w: f64,
    /// Completed requests whose latency exceeded the SLO — zero by
    /// construction (admission bounds completion, and a batch a fault
    /// pushed past a rider's deadline is re-admitted, never executed
    /// late; see [`router`]).
    pub slo_violations: usize,
    /// One-board resource ledger when the fleet was deployed with
    /// `--partition` (`None` = PR 3 semantics, one board per member).
    pub board: Option<FleetBudget>,
    /// Fault-injection accounting when [`FleetConfig::faults`] was set
    /// (`None` on the byte-identical fault-free path).
    pub faults: Option<FaultsReport>,
    /// Cluster ledger when the fleet was deployed with `--cluster`
    /// (`None` on every single-board path).
    pub cluster: Option<crate::cluster::ClusterBudget>,
}

impl FleetReport {
    pub fn to_json(&self) -> Json {
        let ms = |d: Duration| d.as_secs_f64() * 1e3;
        let mut m = BTreeMap::new();
        let schema = if self.cluster.is_some() {
            "cat-serve-v5"
        } else if self.faults.is_some() {
            "cat-serve-v4"
        } else {
            match &self.board {
                Some(b) if b.links.is_some() => "cat-serve-v3",
                Some(_) => "cat-serve-v2",
                None => "cat-serve-v1",
            }
        };
        m.insert("schema".into(), Json::Str(schema.into()));
        if let Some(b) = &self.board {
            m.insert("board".into(), b.to_json());
        }
        if let Some(c) = &self.cluster {
            m.insert("cluster".into(), c.to_json(self));
        }
        m.insert("model".into(), Json::Str(self.model.clone()));
        m.insert("hw".into(), Json::Str(self.hw.clone()));
        m.insert("rps".into(), Json::Num(self.rps));
        m.insert("slo_ms".into(), Json::Num(self.slo_ms));
        m.insert("seed".into(), Json::Num(self.seed as f64));

        let a = &self.admission;
        let mut adm = BTreeMap::new();
        adm.insert("submitted".into(), Json::Num(a.submitted as f64));
        adm.insert("admitted".into(), Json::Num(a.admitted as f64));
        adm.insert("completed".into(), Json::Num(a.completed as f64));
        adm.insert("shed_slo".into(), Json::Num(a.shed_slo as f64));
        adm.insert("shed_capacity".into(), Json::Num(a.shed_capacity as f64));
        if self.faults.is_some() {
            adm.insert("shed_fault".into(), Json::Num(a.shed_fault as f64));
            adm.insert("shed_retry".into(), Json::Num(a.shed_retry as f64));
            adm.insert("requeued".into(), Json::Num(a.requeued as f64));
            adm.insert("retried".into(), Json::Num(a.retried as f64));
        }
        adm.insert("shed_rate".into(), Json::Num(a.shed_rate()));
        m.insert("admission".into(), Json::Obj(adm));

        let s = &self.fleet_stats;
        let mut fl = BTreeMap::new();
        fl.insert("backends".into(), Json::Num(self.n_backends as f64));
        fl.insert("p50_ms".into(), Json::Num(ms(s.percentile(0.50))));
        fl.insert("p95_ms".into(), Json::Num(ms(s.percentile(0.95))));
        fl.insert("p99_ms".into(), Json::Num(ms(s.percentile(0.99))));
        fl.insert("throughput_rps".into(), Json::Num(s.throughput_rps()));
        fl.insert("wall_ms".into(), Json::Num(self.wall_ns as f64 / 1e6));
        fl.insert("gops_per_w".into(), Json::Num(self.fleet_gops_per_w));
        fl.insert("slo_violations".into(), Json::Num(self.slo_violations as f64));
        m.insert("fleet".into(), Json::Obj(fl));

        let wall_ns = self.wall_ns;
        m.insert(
            "backends".into(),
            Json::Arr(
                self.backends
                    .iter()
                    .map(|b| {
                        let mut bm = BTreeMap::new();
                        bm.insert("id".into(), Json::Num(b.id as f64));
                        bm.insert("design".into(), b.point.to_json());
                        bm.insert("admitted".into(), Json::Num(b.admitted as f64));
                        bm.insert("completed".into(), Json::Num(b.stats.completed as f64));
                        bm.insert("batches".into(), Json::Num(b.stats.batches as f64));
                        bm.insert("mean_batch".into(), Json::Num(b.stats.mean_batch()));
                        bm.insert("utilization".into(), Json::Num(b.utilization(wall_ns)));
                        bm.insert("busy_ms".into(), Json::Num(b.busy_ns as f64 / 1e6));
                        bm.insert("p50_ms".into(), Json::Num(ms(b.stats.percentile(0.50))));
                        bm.insert("p99_ms".into(), Json::Num(ms(b.stats.percentile(0.99))));
                        Json::Obj(bm)
                    })
                    .collect(),
            ),
        );
        if let Some(f) = &self.faults {
            m.insert("faults".into(), f.to_json(self.wall_ns));
        }
        Json::Obj(m)
    }
}

/// One request riding through the serving loop.  Carries its own
/// arrival time so the deadline survives re-admission (an orphaned
/// rider keeps its ORIGINAL SLO budget — the batcher's enqueue instant
/// only drives staleness), and its retry count so fault-time bouncing
/// is bounded.
#[derive(Debug, Clone, Copy)]
struct Rider {
    id: u64,
    arrival_ns: u64,
    retries: u32,
}

/// One dispatched-but-unretired batch.  Responses are emitted at
/// *retirement*, not dispatch, so a fault can still orphan the riders
/// of a batch whose virtual completion hasn't passed.
struct InFlightBatch {
    completion_ns: u64,
    service_ns: u64,
    ops: u64,
    riders: Vec<Rider>,
}

/// Per-backend mutable serving state (virtual clock).
struct BackendState {
    batcher: Batcher<Rider>,
    /// Completion time of everything dispatched so far.
    busy_until_ns: u64,
    /// Dispatched batches not yet past their completion time.
    outstanding: VecDeque<InFlightBatch>,
    in_flight: usize,
    admitted: usize,
    batches: usize,
    busy_ns: u64,
    ops: u64,
    latencies: Vec<Duration>,
    /// `Some(end)` while inside a crash/stall window — excluded from
    /// admission until the recovery event at `end` clears it.
    down_until_ns: Option<u64>,
    /// Batches dispatched before this instant serve `slow_factor`×
    /// slower (slowdown fault window).
    slow_until_ns: u64,
    slow_factor: f64,
    /// Riders orphaned off this backend by faults.
    requeued: usize,
    /// Crash/stall windows that hit this backend (merged for downtime).
    down_windows: Vec<(u64, u64)>,
    downs: usize,
}

/// Merge possibly-overlapping `(start, end)` windows, clamped to
/// `wall_ns`, into disjoint sorted intervals.
fn merge_windows(mut windows: Vec<(u64, u64)>, wall_ns: u64) -> Vec<(u64, u64)> {
    for w in &mut windows {
        w.0 = w.0.min(wall_ns);
        w.1 = w.1.min(wall_ns);
    }
    windows.retain(|&(s, e)| e > s);
    windows.sort_unstable();
    let mut out: Vec<(u64, u64)> = Vec::with_capacity(windows.len());
    for (s, e) in windows {
        match out.last_mut() {
            Some(last) if s <= last.1 => last.1 = last.1.max(e),
            _ => out.push((s, e)),
        }
    }
    out
}

/// Event classes of the virtual-clock pump, in tie-break order at equal
/// timestamps: a recovering backend rejoins *before* a co-timed fault
/// or flush sees the fleet, and faults apply before flushes so a flush
/// never dispatches into a backend that is crashing at the same instant.
const CLASS_RECOVER: u8 = 0;
const CLASS_FAULT: u8 = 1;
const CLASS_FLUSH: u8 = 2;

/// Trace track ids inside the serve trace (pid [`PID_SERVE`]): tid 0
/// carries the request lifecycle, tid `1 + b` backend `b`, and the tid
/// after the last backend the fault timeline.
const TID_REQUESTS: u32 = 0;

/// The virtual-clock serving loop over an already-built fleet.
struct ServeLoop<'a> {
    cfg: &'a FleetConfig,
    fleet: &'a Fleet,
    /// Fixed epoch mapping virtual ns ↔ the `Instant`s [`Batcher`] wants.
    epoch: Instant,
    wait_ns: u64,
    /// Last processed virtual time — pending flush deadlines are always
    /// in the future relative to it, so staleness math never saturates.
    cursor_ns: u64,
    states: Vec<BackendState>,
    /// Event-driven admission plane: cached per-backend bounds probed in
    /// cost order ([`AdmissionIndex`]).  Every mutation of a bound
    /// ingredient below (`busy_until`, the forming batch's flush
    /// deadline, down/up/slowdown transitions, renegotiation redeploys)
    /// is mirrored into it; debug builds cross-check every routing
    /// decision against the linear-scan [`route`] oracle.
    index: AdmissionIndex,
    responses: Vec<FleetResponse>,
    stats: AdmissionStats,
    shed: Vec<ShedRecord>,
    /// Resolved fault timeline (sorted) and the application cursor.
    schedule: Vec<FaultEvent>,
    fault_cursor: usize,
    applied: Vec<bool>,
    /// Gates every fault-only code path so the fault-free loop is
    /// *provably* byte-identical to the pre-fault implementation.
    faults_enabled: bool,
    /// Renegotiated redeployments (partitioned fleets): `Some` shadows
    /// the fleet's original backend at that position.
    overrides: Vec<Option<Backend>>,
    /// Last deployed `mem_throttle` per member (1/stretch).
    cur_throttle: Vec<f64>,
    /// Cumulative link-degradation scales (products of event scales).
    dram_scale: f64,
    pcie_scale: f64,
    renegotiations: Vec<(u64, Vec<Option<f64>>)>,
    /// Crash/stall/slowdown windows, for the degraded-window p99.
    degraded_windows: Vec<(u64, u64)>,
    /// Observability sink — `None` on the zero-cost flag-off path.
    /// Every emission site gates on it, so `None` changes nothing
    /// (pinned byte-for-byte by `obs_properties.rs`).
    obs: Option<&'a mut Obs>,
}

impl<'a> ServeLoop<'a> {
    fn new(
        cfg: &'a FleetConfig,
        fleet: &'a Fleet,
        schedule: Vec<FaultEvent>,
        faults_enabled: bool,
        obs: Option<&'a mut Obs>,
    ) -> ServeLoop<'a> {
        let wait = cfg.resolved_batch_wait();
        // never emit a batch the service profiles can't price
        let max_batch = cfg.max_batch.clamp(1, fleet.max_batch());
        let states = fleet
            .backends
            .iter()
            .map(|_| BackendState {
                batcher: Batcher::new(BatcherConfig { max_batch, timeout: wait }),
                busy_until_ns: 0,
                outstanding: VecDeque::new(),
                in_flight: 0,
                admitted: 0,
                batches: 0,
                busy_ns: 0,
                ops: 0,
                latencies: Vec::new(),
                down_until_ns: None,
                slow_until_ns: 0,
                slow_factor: 1.0,
                requeued: 0,
                down_windows: Vec::new(),
                downs: 0,
            })
            .collect();
        let cur_throttle = if let Some(cb) = fleet.cluster.as_ref() {
            cb.members.iter().map(|m| m.throttle).collect()
        } else {
            match fleet.budget.as_ref().and_then(|b| b.links.as_ref()) {
                Some(l) => l.members.iter().map(|m| 1.0 / m.stretch).collect(),
                None => vec![1.0; fleet.backends.len()],
            }
        };
        let applied = vec![false; schedule.len()];
        let wait_ns = wait.as_nanos() as u64;
        ServeLoop {
            cfg,
            fleet,
            epoch: Instant::now(),
            wait_ns,
            cursor_ns: 0,
            states,
            index: fleet.admission_seed(wait_ns),
            responses: Vec::new(),
            stats: AdmissionStats::default(),
            shed: Vec::new(),
            schedule,
            fault_cursor: 0,
            applied,
            faults_enabled,
            overrides: fleet.backends.iter().map(|_| None).collect(),
            cur_throttle,
            dram_scale: 1.0,
            pcie_scale: 1.0,
            renegotiations: Vec::new(),
            degraded_windows: Vec::new(),
            obs,
        }
    }

    fn at(&self, ns: u64) -> Instant {
        self.epoch + Duration::from_nanos(ns)
    }

    /// The live deployment at fleet position `b`: the renegotiated
    /// override when a fault redeployed it, the original otherwise.
    fn backend(&self, b: usize) -> &Backend {
        self.overrides[b].as_ref().unwrap_or(&self.fleet.backends[b])
    }

    fn tid_backend(b: usize) -> u32 {
        b as u32 + 1
    }

    fn tid_faults(&self) -> u32 {
        self.fleet.len() as u32 + 1
    }

    /// `true` when a trace sink is attached.  Emission sites gate arg
    /// construction on this, so the flag-off path allocates nothing.
    fn tracing(&self) -> bool {
        self.obs.as_ref().is_some_and(|o| o.tracing())
    }

    /// `true` when a metrics registry is attached.
    fn metering(&self) -> bool {
        self.obs.as_ref().is_some_and(|o| o.metering())
    }

    fn trace_instant(&mut self, name: &str, tid: u32, ts_ns: u64, args: Vec<(String, Json)>) {
        if let Some(t) = self.obs.as_deref_mut().and_then(|o| o.trace.as_mut()) {
            t.instant(name, "serve", PID_SERVE, tid, ts_ns, args);
        }
    }

    fn trace_complete(
        &mut self,
        name: &str,
        tid: u32,
        ts_ns: u64,
        dur_ns: u64,
        args: Vec<(String, Json)>,
    ) {
        if let Some(t) = self.obs.as_deref_mut().and_then(|o| o.trace.as_mut()) {
            t.complete(name, "serve", PID_SERVE, tid, ts_ns, dur_ns, args);
        }
    }

    fn trace_counter(&mut self, name: &str, tid: u32, ts_ns: u64, args: Vec<(String, Json)>) {
        if let Some(t) = self.obs.as_deref_mut().and_then(|o| o.trace.as_mut()) {
            t.counter(name, "serve", PID_SERVE, tid, ts_ns, args);
        }
    }

    fn metric_record(&mut self, name: &str, v: u64) {
        if let Some(m) = self.obs.as_deref_mut().and_then(|o| o.metrics.as_mut()) {
            m.record(name, v);
        }
    }

    fn metric_add(&mut self, name: &str, delta: u64) {
        if let Some(m) = self.obs.as_deref_mut().and_then(|o| o.metrics.as_mut()) {
            m.add(name, delta);
        }
    }

    /// Effective service time of a batch of `k` dispatched at `at_ns`:
    /// the live profile, stretched while a slowdown window is active.
    fn service_ns_at(&self, b: usize, k: usize, at_ns: u64) -> u64 {
        let base = self.backend(b).service_ns(k);
        let st = &self.states[b];
        if at_ns < st.slow_until_ns {
            (base as f64 * st.slow_factor).ceil() as u64
        } else {
            base
        }
    }

    /// Effective worst-case service time at `at_ns` — what admission
    /// prices, so a request admitted during a slowdown window is bounded
    /// against the stretched profile.
    fn max_service_at(&self, b: usize, at_ns: u64) -> u64 {
        let base = self.backend(b).max_service_ns();
        let st = &self.states[b];
        if at_ns < st.slow_until_ns {
            (base as f64 * st.slow_factor).ceil() as u64
        } else {
            base
        }
    }

    /// Absolute flush deadline of backend `b`'s forming batch (`None`
    /// when empty).  Evaluated at the cursor, where deadlines are exact.
    /// A down backend defers its flush to the recovery instant (a stall
    /// freezes the forming batch; a crash leaves the batcher empty).
    ///
    /// Event-maintained: the index carries the batch's *natural*
    /// deadline (`first_enqueue + batch_wait`, updated when a rider
    /// opens a batch and when a dispatch/flush/crash-drain empties it);
    /// clamping to the cursor reproduces the batcher's saturating
    /// staleness math exactly (a post-recovery stale batch flushes *at*
    /// the cursor, never behind it).  Debug builds re-derive every read
    /// from the batcher's clock and assert agreement.
    fn flush_deadline(&self, b: usize) -> Option<u64> {
        let deadline = self.index.flush_deadline(b).map(|natural| {
            let natural = natural.max(self.cursor_ns);
            match self.states[b].down_until_ns {
                Some(end) => natural.max(end),
                None => natural,
            }
        });
        debug_assert_eq!(
            deadline,
            self.flush_deadline_from_batcher(b),
            "event-maintained flush deadline diverged from the batcher clock (backend {b})"
        );
        deadline
    }

    /// The batcher-clock reference implementation of
    /// [`ServeLoop::flush_deadline`] — the pre-index derivation, kept so
    /// debug builds can assert the event-maintained deadline never
    /// diverges from it.
    fn flush_deadline_from_batcher(&self, b: usize) -> Option<u64> {
        let natural = self.states[b]
            .batcher
            .time_until_stale(self.at(self.cursor_ns))
            .map(|d| self.cursor_ns.saturating_add(d.as_nanos() as u64))?;
        Some(match self.states[b].down_until_ns {
            Some(end) => natural.max(end),
            None => natural,
        })
    }

    /// The next virtual event at or before `limit_ns`: recoveries,
    /// scheduled faults, and staleness flushes, ordered by
    /// `(time, class, position)` so ties are deterministic.
    fn next_event(&self, limit_ns: u64) -> Option<(u64, u8, usize)> {
        let recoveries = self
            .states
            .iter()
            .enumerate()
            .filter_map(|(b, st)| st.down_until_ns.map(|d| (d, CLASS_RECOVER, b)));
        let fault = self
            .schedule
            .get(self.fault_cursor)
            .map(|e| (e.at_ns.max(self.cursor_ns), CLASS_FAULT, self.fault_cursor));
        let flushes = (0..self.states.len())
            .filter_map(|b| self.flush_deadline(b).map(|d| (d, CLASS_FLUSH, b)));
        recoveries.chain(fault).chain(flushes).min().filter(|&(when, _, _)| when <= limit_ns)
    }

    /// Drive the virtual clock to `t_ns`: retire completed batches and
    /// fire every recovery, fault, and staleness flush due on the way,
    /// each at its own virtual timestamp in deterministic order.  With
    /// no faults scheduled this degenerates to the historical
    /// flush-then-advance loop (recoveries and faults never fire).
    fn process_until(&mut self, t_ns: u64) -> Result<()> {
        while let Some((when, class, idx)) = self.next_event(t_ns) {
            self.advance(when);
            self.cursor_ns = self.cursor_ns.max(when);
            match class {
                CLASS_RECOVER => {
                    self.states[idx].down_until_ns = None;
                    self.index.set_up(idx);
                    if self.tracing() {
                        self.trace_instant("up", Self::tid_backend(idx), when, Vec::new());
                    }
                    self.renegotiate(when)?;
                }
                CLASS_FAULT => {
                    let ev = self.schedule[idx];
                    self.fault_cursor += 1;
                    self.applied[idx] = true;
                    self.apply_fault(ev, when)?;
                }
                _ => {
                    if let Some(batch) = self.states[idx].batcher.flush() {
                        self.index.set_flush_deadline(idx, None);
                        if self.tracing() {
                            let args = vec![("batch".to_string(), Json::Num(batch.len() as f64))];
                            self.trace_instant("flush", Self::tid_backend(idx), when, args);
                        }
                        self.dispatch(idx, batch, when);
                    }
                }
            }
        }
        self.advance(t_ns);
        self.cursor_ns = self.cursor_ns.max(t_ns.min(u64::MAX / 2));
        Ok(())
    }

    /// Apply one scheduled fault at `now_ns` (== the event's timestamp,
    /// clamped forward to the cursor).
    fn apply_fault(&mut self, ev: FaultEvent, now_ns: u64) -> Result<()> {
        if self.tracing() {
            let tid = self.tid_faults();
            self.trace_instant(ev.kind.name(), tid, now_ns, ev.kind.trace_args());
        }
        if self.metering() {
            self.metric_add(&format!("serve.faults.{}", ev.kind.name()), 1);
        }
        match ev.kind {
            FaultKind::Crash { backend: b, down_ns } => {
                let end = now_ns.saturating_add(down_ns).min(faults::DOWN_CAP_NS);
                let st = &mut self.states[b];
                // the crash loses everything on the backend: the forming
                // batch and every dispatched-but-unretired batch
                let mut orphans: Vec<Rider> = st
                    .batcher
                    .flush()
                    .map(|batch| batch.into_iter().map(|(r, _)| r).collect())
                    .unwrap_or_default();
                for ifb in st.outstanding.drain(..) {
                    orphans.extend(ifb.riders);
                }
                debug_assert_eq!(st.in_flight, orphans.len(), "in-flight ≠ orphaned riders");
                st.in_flight = 0;
                st.admitted -= orphans.len();
                st.busy_until_ns = now_ns;
                st.slow_until_ns = 0;
                st.slow_factor = 1.0;
                st.down_until_ns = Some(st.down_until_ns.unwrap_or(0).max(end));
                st.downs += 1;
                st.down_windows.push((now_ns, end));
                self.degraded_windows.push((now_ns, end));
                // the crash rewrote every bound ingredient at once
                self.index.note_orphaned(b, orphans.len());
                self.index.set_busy_until(b, now_ns);
                self.index.set_flush_deadline(b, None);
                self.index.clear_slowdown(b);
                self.index.set_down(b);
                if self.tracing() {
                    let args = vec![("until_ms".to_string(), Json::Num(end as f64 / 1e6))];
                    self.trace_instant("down", Self::tid_backend(b), now_ns, args);
                }
                self.renegotiate(now_ns)?;
                self.requeue(b, orphans, now_ns);
            }
            FaultKind::Stall { backend: b, down_ns } => {
                let end = now_ns.saturating_add(down_ns).min(faults::DOWN_CAP_NS);
                let slo_ns = self.cfg.slo_ns();
                let st = &mut self.states[b];
                // nothing is lost, but every queued completion shifts by
                // the window; batches whose riders can no longer meet
                // their deadlines are orphaned instead of served late
                if st.busy_until_ns > now_ns {
                    st.busy_until_ns =
                        st.busy_until_ns.saturating_add(down_ns).min(faults::DOWN_CAP_NS);
                }
                let mut orphans = Vec::new();
                let mut kept = VecDeque::with_capacity(st.outstanding.len());
                for mut ifb in st.outstanding.drain(..) {
                    ifb.completion_ns =
                        ifb.completion_ns.saturating_add(down_ns).min(faults::DOWN_CAP_NS);
                    let late = ifb
                        .riders
                        .iter()
                        .any(|r| ifb.completion_ns > r.arrival_ns.saturating_add(slo_ns));
                    if late {
                        orphans.extend(ifb.riders);
                    } else {
                        kept.push_back(ifb);
                    }
                }
                st.outstanding = kept;
                st.in_flight -= orphans.len();
                st.admitted -= orphans.len();
                st.down_until_ns = Some(st.down_until_ns.unwrap_or(0).max(end));
                st.downs += 1;
                st.down_windows.push((now_ns, end));
                let busy = st.busy_until_ns;
                self.degraded_windows.push((now_ns, end));
                // the stall shifted the busy horizon and dropped the
                // late batches; the frozen forming batch keeps its
                // natural deadline (deferral to recovery is read-side)
                self.index.note_orphaned(b, orphans.len());
                self.index.set_busy_until(b, busy);
                self.index.set_down(b);
                if self.tracing() {
                    let args = vec![("until_ms".to_string(), Json::Num(end as f64 / 1e6))];
                    self.trace_instant("down", Self::tid_backend(b), now_ns, args);
                }
                self.renegotiate(now_ns)?;
                self.requeue(b, orphans, now_ns);
            }
            FaultKind::Slowdown { backend: b, down_ns, factor } => {
                let end = now_ns.saturating_add(down_ns).min(faults::DOWN_CAP_NS);
                let st = &mut self.states[b];
                if now_ns < st.slow_until_ns {
                    // overlapping windows: the harsher factor wins, the
                    // window extends to the later end
                    st.slow_factor = st.slow_factor.max(factor);
                    st.slow_until_ns = st.slow_until_ns.max(end);
                } else {
                    st.slow_factor = factor;
                    st.slow_until_ns = end;
                }
                let (slow_until, slow_factor) = (st.slow_until_ns, st.slow_factor);
                self.degraded_windows.push((now_ns, end));
                // report the *merged* window (harsher-factor-wins)
                self.index.set_slowdown(b, slow_until, slow_factor);
                if self.tracing() {
                    let args = vec![
                        ("factor".to_string(), Json::Num(factor)),
                        ("until_ms".to_string(), Json::Num(end as f64 / 1e6)),
                    ];
                    self.trace_instant("slow", Self::tid_backend(b), now_ns, args);
                }
            }
            FaultKind::LinkDegrade { dram_scale, pcie_scale } => {
                self.dram_scale *= dram_scale;
                self.pcie_scale *= pcie_scale;
                self.renegotiate(now_ns)?;
            }
            FaultKind::BoardCrash { .. } => {
                unreachable!("board crashes are expanded to member crashes before the loop")
            }
        }
        Ok(())
    }

    /// Re-run the shared-link negotiation over the *up* members against
    /// the (possibly degraded) pools and redeploy every member whose
    /// throttle changed — the graceful-degradation step: a dead member
    /// stops demanding bandwidth, so survivors' grants grow, their
    /// stretch drops, and their re-simulated profiles speed up.
    /// No-op for unpartitioned fleets or with the link model off.
    fn renegotiate(&mut self, now_ns: u64) -> Result<()> {
        if !self.faults_enabled {
            return Ok(());
        }
        if self.fleet.cluster.is_some() {
            return self.renegotiate_cluster(now_ns);
        }
        let cfg = self.cfg;
        let fleet = self.fleet;
        let Some(budget) = fleet.budget.as_ref() else { return Ok(()) };
        let Some(ledger0) = budget.links.as_ref() else { return Ok(()) };
        let pools = ledger0.pools.scaled(self.dram_scale, self.pcie_scale);
        let demands: Vec<LinkDemand> = ledger0.members.iter().map(|m| m.demand).collect();
        let up: Vec<bool> = self.states.iter().map(|st| st.down_until_ns.is_none()).collect();
        let grants = links::negotiate_masked(&pools, &demands, &up, cfg.link_mode());
        let mut stretches = Vec::with_capacity(grants.len());
        for (b, grant) in grants.iter().enumerate() {
            let Some(ml) = grant else {
                stretches.push(None);
                continue;
            };
            stretches.push(Some(ml.stretch));
            let throttle = 1.0 / ml.stretch;
            if (throttle - self.cur_throttle[b]).abs() <= 1e-12 {
                continue;
            }
            let base = &fleet.backends[b];
            let mut nb = Backend::deploy_in_share(
                &cfg.model,
                &cfg.hw,
                &base.point,
                base.max_batch(),
                &budget.shares[b],
                throttle,
            )
            .map_err(|e| {
                anyhow!("re-deploying backend {b} at throttle {throttle:.4} after a fault: {e}")
            })?;
            nb.id = base.id;
            let max_service = nb.max_service_ns();
            self.overrides[b] = Some(nb);
            self.cur_throttle[b] = throttle;
            // the redeploy repriced the member's worst case
            self.index.set_max_service(b, max_service);
        }
        if self.tracing() {
            let members_up = stretches.iter().filter(|s| s.is_some()).count();
            let tid = self.tid_faults();
            let args = vec![
                ("members_up".to_string(), Json::Num(members_up as f64)),
                ("mode".to_string(), Json::Str(cfg.link_mode().wire_name().into())),
            ];
            self.trace_instant("renegotiate", tid, now_ns, args);
        }
        self.renegotiations.push((now_ns, stretches));
        Ok(())
    }

    /// Cluster variant of [`ServeLoop::renegotiate`]: each board re-runs
    /// its *own* masked intra-board negotiation over its live members,
    /// then the boards renegotiate the cluster NIC/switch pools (which
    /// is where a `link_degrade` fault bites in cluster mode) with each
    /// board demanding only its live members' host I/O.  A member's new
    /// throttle folds both levels; only changed members redeploy.
    fn renegotiate_cluster(&mut self, now_ns: u64) -> Result<()> {
        let cfg = self.cfg;
        let fleet = self.fleet;
        let cb = fleet.cluster.as_ref().expect("cluster renegotiation without a cluster");
        let up: Vec<bool> = self.states.iter().map(|st| st.down_until_ns.is_none()).collect();
        let mut intra: Vec<Option<f64>> = vec![None; fleet.len()];
        let mut board_demands = Vec::with_capacity(cb.boards.len());
        for bl in &cb.boards {
            let ledger0 = bl.budget.links.as_ref().expect("cluster boards carry link ledgers");
            let demands: Vec<LinkDemand> = ledger0.members.iter().map(|m| m.demand).collect();
            let mut b_up = vec![false; demands.len()];
            for &g in &bl.members {
                b_up[cb.members[g].slot] = up[g];
            }
            let grants = links::negotiate_masked(&ledger0.pools, &demands, &b_up, cfg.link_mode());
            for &g in &bl.members {
                intra[g] = grants[cb.members[g].slot].map(|ml| ml.stretch);
            }
            // the board's residual net demand: its live members' host I/O
            // (a fully-down board demands nothing and stops stretching
            // the survivors' NIC/switch grants)
            let host: f64 = ledger0
                .members
                .iter()
                .zip(&b_up)
                .filter(|(_, live)| **live)
                .map(|(m, _)| m.demand.pcie_gbps)
                .sum();
            board_demands.push(LinkDemand { dram_gbps: host, pcie_gbps: host });
        }
        let net_pools = cb.net.pools.scaled(self.dram_scale, self.pcie_scale);
        let net = links::negotiate_in(&net_pools, &board_demands, cfg.link_mode());
        let mut stretches = Vec::with_capacity(fleet.len());
        for b in 0..fleet.len() {
            let Some(s_intra) = intra[b] else {
                stretches.push(None);
                continue;
            };
            let ms = cb.members[b];
            let stretch = s_intra * net.members[ms.board].stretch;
            stretches.push(Some(stretch));
            let throttle = 1.0 / stretch;
            if (throttle - self.cur_throttle[b]).abs() <= 1e-12 {
                continue;
            }
            let bl = &cb.boards[ms.board];
            let base = &fleet.backends[b];
            let mut nb = Backend::deploy_in_share(
                &cfg.model,
                &bl.hw,
                &base.point,
                base.max_batch(),
                &bl.budget.shares[ms.slot],
                throttle,
            )
            .map_err(|e| {
                anyhow!("re-deploying backend {b} at throttle {throttle:.4} after a fault: {e}")
            })?;
            nb.id = base.id;
            let max_service = nb.max_service_ns();
            self.overrides[b] = Some(nb);
            self.cur_throttle[b] = throttle;
            // the redeploy repriced the member's worst case
            self.index.set_max_service(b, max_service);
        }
        if self.tracing() {
            let members_up = stretches.iter().filter(|s| s.is_some()).count();
            let tid = self.tid_faults();
            let args = vec![
                ("members_up".to_string(), Json::Num(members_up as f64)),
                ("mode".to_string(), Json::Str(cfg.link_mode().wire_name().into())),
            ];
            self.trace_instant("renegotiate", tid, now_ns, args);
        }
        self.renegotiations.push((now_ns, stretches));
        Ok(())
    }

    /// Commit one batch to backend `b` at virtual time `now_ns`.
    /// Responses are deferred to retirement ([`ServeLoop::advance`]).
    fn dispatch(&mut self, b: usize, batch: Vec<(Rider, Instant)>, now_ns: u64) {
        let size = batch.len();
        let service = self.service_ns_at(b, size, now_ns);
        let ops = self.backend(b).ops(size);
        let start = self.states[b].busy_until_ns.max(now_ns);
        let completion = start.saturating_add(service);
        if self.faults_enabled {
            // a fault between admission and flush (slowdown repricing, a
            // stall's deferred backlog) can push this batch past a
            // rider's deadline; executing it would break the "every
            // completed request meets the SLO" guarantee, so the whole
            // batch is orphaned for re-admission instead.  Fault-free
            // this can never fire: the admission bound majorizes the
            // dispatch arithmetic term by term.
            let slo_ns = self.cfg.slo_ns();
            if batch.iter().any(|(r, _)| completion > r.arrival_ns.saturating_add(slo_ns)) {
                let riders: Vec<Rider> = batch.into_iter().map(|(r, _)| r).collect();
                let st = &mut self.states[b];
                st.admitted -= riders.len();
                st.in_flight -= riders.len();
                self.index.note_orphaned(b, riders.len());
                self.requeue(b, riders, now_ns);
                return;
            }
        }
        let st = &mut self.states[b];
        st.busy_until_ns = completion;
        st.outstanding.push_back(InFlightBatch {
            completion_ns: completion,
            service_ns: service,
            ops,
            riders: batch.into_iter().map(|(r, _)| r).collect(),
        });
        self.index.set_busy_until(b, completion);
        if self.tracing() {
            let args = vec![
                ("batch".to_string(), Json::Num(size as f64)),
                ("start_ms".to_string(), Json::Num(start as f64 / 1e6)),
                ("service_ms".to_string(), Json::Num(service as f64 / 1e6)),
            ];
            self.trace_instant("dispatch", Self::tid_backend(b), now_ns, args);
        }
    }

    /// Retire batches whose completion time has passed: emit their
    /// responses, credit the backend, and free queue room.
    fn advance(&mut self, now_ns: u64) {
        for b in 0..self.states.len() {
            while self.states[b]
                .outstanding
                .front()
                .is_some_and(|f| f.completion_ns <= now_ns)
            {
                let batch = self.states[b].outstanding.pop_front().unwrap();
                let size = batch.riders.len();
                // retirement frees queue room but moves no bound
                // ingredient — the index cache survives it
                self.index.note_retired(b, size);
                let st = &mut self.states[b];
                st.in_flight -= size;
                st.batches += 1;
                st.busy_ns += batch.service_ns;
                st.ops += batch.ops;
                for r in &batch.riders {
                    st.latencies.push(Duration::from_nanos(batch.completion_ns - r.arrival_ns));
                    self.responses.push(FleetResponse {
                        id: r.id,
                        backend: b,
                        arrival_ns: r.arrival_ns,
                        completion_ns: batch.completion_ns,
                        batch_size: size,
                        batch_service_ns: batch.service_ns,
                    });
                }
                if self.obs.is_some() {
                    self.retire_obs(b, &batch);
                }
            }
        }
    }

    /// Observability for one retired batch: the service-window span on
    /// the backend track plus a completion instant and latency sample
    /// per rider.  Spans are emitted at *retirement*, where the final
    /// window is known — a stall shifts completions after dispatch, and
    /// a crash resets `busy_until`, so dispatch-time emission could
    /// produce non-monotone track timestamps (orphaned batches never
    /// ran, so they get no span at all).
    fn retire_obs(&mut self, b: usize, batch: &InFlightBatch) {
        let start = batch.completion_ns.saturating_sub(batch.service_ns);
        let size = batch.riders.len();
        self.metric_record("serve.batch_size", size as u64);
        if self.tracing() {
            let args = vec![
                ("batch".to_string(), Json::Num(size as f64)),
                ("ops".to_string(), Json::Num(batch.ops as f64)),
            ];
            self.trace_complete("batch", Self::tid_backend(b), start, batch.service_ns, args);
        }
        for r in &batch.riders {
            let latency_ns = batch.completion_ns - r.arrival_ns;
            self.metric_record("serve.latency_ns", latency_ns);
            if self.tracing() {
                let args = vec![
                    ("id".to_string(), Json::Num(r.id as f64)),
                    ("backend".to_string(), Json::Num(b as f64)),
                    ("latency_ms".to_string(), Json::Num(latency_ns as f64 / 1e6)),
                ];
                self.trace_instant("complete", TID_REQUESTS, batch.completion_ns, args);
            }
        }
    }

    /// Try to admit one rider at `now_ns` (fresh arrival or fault-time
    /// re-admission).  Routes against the rider's ORIGINAL deadline —
    /// an orphan gets no fresh SLO budget — and joins the chosen
    /// backend's forming batch.
    ///
    /// This is the hot path: instead of rebuilding a [`BackendLoad`]
    /// snapshot per arrival (the pre-index implementation, retained as
    /// the [`route`] oracle), it probes the event-maintained
    /// [`AdmissionIndex`] — cached bounds, up-backends in cost order,
    /// one bound refresh per backend per virtual timestamp however deep
    /// the arrival burst.  Debug builds rebuild the snapshot anyway and
    /// assert the oracle reproduces the decision exactly.
    fn admit(
        &mut self,
        rider: Rider,
        now_ns: u64,
    ) -> std::result::Result<RouteDecision, ShedReason> {
        let deadline_ns = rider.arrival_ns.saturating_add(self.cfg.slo_ns());
        let decision = self.index.route(now_ns, deadline_ns, self.cfg.queue_cap);
        #[cfg(debug_assertions)]
        self.check_route_oracle(now_ns, deadline_ns, &decision);
        let decision = decision?;
        let b = decision.backend;
        let at = self.at(now_ns);
        self.index.note_admitted(b);
        let st = &mut self.states[b];
        st.admitted += 1;
        st.in_flight += 1;
        let opened_batch = st.batcher.pending_len() == 0;
        match st.batcher.push(rider, at) {
            Some(batch) => {
                // the push emitted (full batch, or zero staleness
                // budget): the batcher is empty again
                self.index.set_flush_deadline(b, None);
                self.dispatch(b, batch, now_ns);
            }
            None if opened_batch => {
                // this rider started the forming batch: its natural
                // staleness deadline is pinned from here until dispatch
                self.index.set_flush_deadline(b, Some(now_ns.saturating_add(self.wait_ns)));
            }
            None => {}
        }
        if self.obs.is_some() {
            let depth = self.states[b].in_flight as u64;
            self.metric_record("serve.queue_depth", depth);
            self.metric_record("serve.route_scanned", decision.scanned as u64);
            if self.tracing() {
                let args = vec![("in_flight".to_string(), Json::Num(depth as f64))];
                self.trace_counter("queue", Self::tid_backend(b), now_ns, args);
            }
        }
        Ok(decision)
    }

    /// Debug-only equivalence proof, run on EVERY admission: rebuild the
    /// full [`BackendLoad`] snapshot exactly the way the pre-index
    /// implementation did, route it through the linear-scan oracle, and
    /// assert the indexed decision (backend, bound, scan count — or the
    /// shed reason) is identical.  Also asserts the index's per-backend
    /// mirrors (`in_flight`, `up`, `busy_until`) against the loop state,
    /// so a missed event surfaces at the first arrival that could
    /// observe it rather than as a silently different schedule.
    #[cfg(debug_assertions)]
    fn check_route_oracle(
        &self,
        now_ns: u64,
        deadline_ns: u64,
        decision: &std::result::Result<RouteDecision, ShedReason>,
    ) {
        let loads: Vec<BackendLoad> = (0..self.states.len())
            .map(|b| {
                let st = &self.states[b];
                let l = BackendLoad {
                    busy_until_ns: st.busy_until_ns,
                    pending: st.batcher.pending_len(),
                    flush_deadline_ns: self
                        .flush_deadline_from_batcher(b)
                        .unwrap_or_else(|| now_ns.saturating_add(self.wait_ns)),
                    in_flight: st.in_flight,
                    up: st.down_until_ns.is_none(),
                    max_service_ns: self.max_service_at(b, now_ns),
                };
                assert_eq!(l.in_flight, self.index.in_flight(b), "index in_flight mirror (b={b})");
                assert_eq!(l.up, self.index.is_up(b), "index up mirror (b={b})");
                assert_eq!(
                    l.busy_until_ns,
                    self.index.busy_until_ns(b),
                    "index busy mirror (b={b})"
                );
                l
            })
            .collect();
        match (route(&loads, now_ns, deadline_ns, self.cfg.queue_cap), decision) {
            (Ok(o), Ok(i)) => assert_eq!(
                (o.backend, o.completion_bound_ns, o.scanned),
                (i.backend, i.completion_bound_ns, i.scanned),
                "indexed admission diverged from the oracle at t={now_ns}"
            ),
            (Err(o), Err(i)) => {
                assert_eq!(o, *i, "indexed shed reason diverged from the oracle at t={now_ns}")
            }
            (o, i) => panic!("oracle {o:?} vs indexed {i:?} at t={now_ns}"),
        }
    }

    /// Re-admit riders orphaned off `source` by a fault: oldest deadline
    /// first, bounded retries, unsalvageable riders shed with exact
    /// attribution so conservation balances.
    fn requeue(&mut self, source: usize, mut riders: Vec<Rider>, now_ns: u64) {
        if riders.is_empty() {
            return;
        }
        riders.sort_by_key(|r| (r.arrival_ns, r.id));
        self.states[source].requeued += riders.len();
        self.stats.requeued += riders.len();
        for mut r in riders {
            r.retries += 1;
            if r.retries as usize > self.cfg.max_retries {
                self.shed_rider(&r, ShedReason::RetryExhausted, now_ns);
                continue;
            }
            match self.admit(r, now_ns) {
                Ok(d) => {
                    self.stats.retried += 1;
                    if self.tracing() {
                        let args = vec![
                            ("id".to_string(), Json::Num(r.id as f64)),
                            ("from".to_string(), Json::Num(source as f64)),
                            ("backend".to_string(), Json::Num(d.backend as f64)),
                            ("retries".to_string(), Json::Num(f64::from(r.retries))),
                        ];
                        self.trace_instant("retry", TID_REQUESTS, now_ns, args);
                    }
                }
                Err(_) => self.shed_rider(&r, ShedReason::Fault, now_ns),
            }
        }
    }

    fn shed_rider(&mut self, r: &Rider, reason: ShedReason, now_ns: u64) {
        self.stats.record_shed(reason);
        self.shed.push(ShedRecord { id: r.id, arrival_ns: r.arrival_ns, reason });
        if self.tracing() {
            let args = vec![
                ("id".to_string(), Json::Num(r.id as f64)),
                ("reason".to_string(), Json::Str(reason.as_str().to_string())),
            ];
            self.trace_instant("shed", TID_REQUESTS, now_ns, args);
        }
    }

    /// Route + admit (or shed) one arrival at `t_ns`.
    fn arrive(&mut self, id: u64, t_ns: u64) -> Result<()> {
        self.process_until(t_ns)?;
        self.stats.submitted += 1;
        if self.tracing() {
            let args = vec![("id".to_string(), Json::Num(id as f64))];
            self.trace_instant("submit", TID_REQUESTS, t_ns, args);
        }
        let rider = Rider { id, arrival_ns: t_ns, retries: 0 };
        match self.admit(rider, t_ns) {
            Ok(d) => {
                self.stats.admitted += 1;
                if self.tracing() {
                    let args = vec![
                        ("id".to_string(), Json::Num(id as f64)),
                        ("backend".to_string(), Json::Num(d.backend as f64)),
                        ("scanned".to_string(), Json::Num(d.scanned as f64)),
                    ];
                    self.trace_instant("admit", TID_REQUESTS, t_ns, args);
                }
            }
            Err(ShedReason::Fault) => {
                // a fresh arrival during a TOTAL outage: counted
                // admitted-then-fault-shed so both conservation
                // equations stay exact (see AdmissionStats::accounted)
                self.stats.admitted += 1;
                self.shed_rider(&rider, ShedReason::Fault, t_ns);
            }
            Err(reason) => self.shed_rider(&rider, reason, t_ns),
        }
        Ok(())
    }

    /// End of stream: run the virtual clock until every forming batch
    /// has flushed and every dispatched batch has retired.  Faults
    /// scheduled past the last piece of work are reported unapplied.
    fn drain(&mut self) -> Result<()> {
        loop {
            let next_flush = (0..self.states.len()).filter_map(|b| self.flush_deadline(b)).min();
            let next_completion = self
                .states
                .iter()
                .filter_map(|st| st.outstanding.front().map(|f| f.completion_ns))
                .min();
            let Some(t) = next_flush.into_iter().chain(next_completion).min() else {
                return Ok(());
            };
            self.process_until(t)?;
        }
    }

    /// The `faults` block (only built when fault injection was enabled).
    fn faults_report(&self, wall_ns: u64) -> FaultsReport {
        let backends = self
            .states
            .iter()
            .map(|st| BackendFaultStats {
                downs: st.downs,
                down_ns: merge_windows(st.down_windows.clone(), wall_ns)
                    .iter()
                    .map(|&(s, e)| e - s)
                    .sum(),
                requeued: st.requeued,
            })
            .collect();
        let degraded = merge_windows(self.degraded_windows.clone(), wall_ns);
        let mut lat: Vec<Duration> = self
            .responses
            .iter()
            .filter(|r| degraded.iter().any(|&(s, e)| r.completion_ns >= s && r.completion_ns <= e))
            .map(|r| Duration::from_nanos(r.latency_ns()))
            .collect();
        lat.sort_unstable();
        let degraded_p99_ms = if lat.is_empty() {
            0.0
        } else {
            let stats = ServeStats {
                completed: lat.len(),
                batches: 0,
                latencies: lat,
                wall: Duration::from_nanos(wall_ns),
            };
            stats.percentile(0.99).as_secs_f64() * 1e3
        };
        FaultsReport {
            timeline: self.schedule.iter().zip(&self.applied).map(|(e, a)| (*e, *a)).collect(),
            backends,
            requeued: self.stats.requeued,
            retried: self.stats.retried,
            degraded_p99_ms,
            renegotiations: self.renegotiations.clone(),
        }
    }
}

/// Explore + deploy the family the serving entry points share: across
/// every board of the cluster spec when [`FleetConfig::cluster`] is
/// set, on one shared board when [`FleetConfig::partition`] is set, one
/// board per member otherwise.
fn build_fleet(cfg: &FleetConfig) -> Result<Fleet> {
    if let Some(spec) = &cfg.cluster {
        return crate::cluster::build_fleet(cfg, spec);
    }
    let mut ecfg = dse::ExploreConfig::new(cfg.model.clone(), cfg.hw.clone());
    ecfg.sample_budget = cfg.explore_budget;
    ecfg.seed = cfg.seed;
    ecfg.slo_ms = Some(cfg.slo_ms);
    let explored = dse::explore(&ecfg)?;
    if cfg.partition {
        Fleet::select_partitioned_in(
            &cfg.model,
            &cfg.hw,
            &explored,
            cfg.max_backends,
            cfg.max_batch,
            Some(cfg.slo_ms),
            cfg.links.as_ref(),
            cfg.link_mode(),
        )
    } else {
        Fleet::select(&cfg.model, &cfg.hw, &explored, cfg.max_backends, cfg.max_batch)
    }
}

/// What [`run`] serves with and over — the consolidated serve session:
/// an optional pre-built fleet (`None` = explore + deploy from the
/// config), an optional explicit arrival stream (`None` = the seeded
/// Poisson stream), and an optional observability sink (`None` = the
/// provably zero-cost path).  Mirrors `dse::explore_obs`'s optional-sink
/// shape; the six historical `serve_fleet*` entry points are thin
/// wrappers over one `(cfg, Session)` call.
#[derive(Default)]
pub struct Session<'a> {
    fleet: Option<&'a Fleet>,
    arrivals: Option<&'a [u64]>,
    obs: Option<&'a mut Obs>,
}

impl<'a> Session<'a> {
    pub fn new() -> Session<'a> {
        Session::default()
    }

    /// Serve over an already-built fleet instead of exploring one from
    /// the config (tests and benches pin hand-built families this way).
    pub fn on(mut self, fleet: &'a Fleet) -> Session<'a> {
        self.fleet = Some(fleet);
        self
    }

    /// Serve an **explicit** arrival pattern (sorted virtual ns)
    /// instead of the seeded Poisson stream — bursty or adversarial
    /// streams ride the identical routing/admission/batching path.
    /// Request ids are the arrival positions; `cfg.n_requests`/`cfg.rps`
    /// only label the report.
    pub fn stream(mut self, arrivals: &'a [u64]) -> Session<'a> {
        self.arrivals = Some(arrivals);
        self
    }

    /// Attach an observability sink.  The emitted [`FleetReport`] stays
    /// byte-identical — the trace and registry are pure observers of
    /// the identical event sequence (pinned by `obs_properties.rs`).
    pub fn observe(mut self, obs: &'a mut Obs) -> Session<'a> {
        self.obs = Some(obs);
        self
    }
}

/// THE serving entry point: resolve the session's fleet and arrivals
/// (building whatever was left unset from the config) and drive the
/// virtual-clock loop.  `run(cfg, Session::new())` is the full
/// explore → deploy → serve pipeline; every `serve_fleet*` name
/// delegates here byte-identically.
pub fn run(cfg: &FleetConfig, session: Session<'_>) -> Result<FleetReport> {
    let Session { fleet, arrivals, obs } = session;
    let built;
    let fleet = match fleet {
        Some(f) => f,
        None => {
            built = build_fleet(cfg)?;
            &built
        }
    };
    let generated;
    let arrivals = match arrivals {
        Some(a) => a,
        None => {
            generated = TrafficGen::poisson(cfg.seed, cfg.rps, cfg.n_requests);
            &generated
        }
    };
    run_stream(cfg, fleet, arrivals, obs)
}

/// Derive a frontier for the pair, deploy the family — across the
/// cluster with [`FleetConfig::cluster`], on one shared board with
/// [`FleetConfig::partition`], one board per member otherwise — and
/// serve the synthetic stream across it.
pub fn serve_fleet(cfg: &FleetConfig) -> Result<FleetReport> {
    run(cfg, Session::new())
}

/// [`serve_fleet`] with observability attached.  Create the [`Obs`]
/// *before* calling so its global-counter baseline brackets the
/// exploration and deployment phases too (that is where the stage-sim
/// cache and `par_map` actually work).
pub fn serve_fleet_obs(cfg: &FleetConfig, obs: &mut Obs) -> Result<FleetReport> {
    run(cfg, Session::new().observe(obs))
}

/// Drive the virtual-clock serving loop over an already-built fleet.
pub fn serve_fleet_on(cfg: &FleetConfig, fleet: &Fleet) -> Result<FleetReport> {
    run(cfg, Session::new().on(fleet))
}

/// [`serve_fleet_on`] with observability attached.
pub fn serve_fleet_on_obs(cfg: &FleetConfig, fleet: &Fleet, obs: &mut Obs) -> Result<FleetReport> {
    run(cfg, Session::new().on(fleet).observe(obs))
}

/// The serving loop over an already-built fleet and an explicit arrival
/// pattern (see [`Session::stream`]).
pub fn serve_fleet_stream(
    cfg: &FleetConfig,
    fleet: &Fleet,
    arrivals: &[u64],
) -> Result<FleetReport> {
    run(cfg, Session::new().on(fleet).stream(arrivals))
}

/// [`serve_fleet_stream`] with an optional observability sink.
pub fn serve_fleet_stream_obs(
    cfg: &FleetConfig,
    fleet: &Fleet,
    arrivals: &[u64],
    obs: Option<&mut Obs>,
) -> Result<FleetReport> {
    let mut session = Session::new().on(fleet).stream(arrivals);
    if let Some(o) = obs {
        session = session.observe(o);
    }
    run(cfg, session)
}

/// The loop itself — every public entry point funnels here through
/// [`run`].
fn run_stream(
    cfg: &FleetConfig,
    fleet: &Fleet,
    arrivals: &[u64],
    mut obs: Option<&mut Obs>,
) -> Result<FleetReport> {
    debug_assert!(arrivals.windows(2).all(|w| w[0] <= w[1]), "arrivals must be sorted");
    let has_links =
        fleet.budget.as_ref().is_some_and(|b| b.links.is_some()) || fleet.cluster.is_some();
    let n_boards = fleet.cluster.as_ref().map(|c| c.boards.len());
    let schedule: Vec<FaultEvent> = match &cfg.faults {
        None => Vec::new(),
        Some(FaultPolicy::Schedule(s)) => {
            s.validate(fleet.len(), has_links, n_boards)?;
            match &fleet.cluster {
                // a board crash is N member crashes — expand before the
                // loop so routing/draining/recovery see ordinary events
                Some(cb) => faults::expand_boards(&s.events, &cb.member_boards()),
                None => s.events.clone(),
            }
        }
        Some(FaultPolicy::Random { mtbf_s, mttr_s }) => {
            if !(mtbf_s.is_finite() && *mtbf_s > 0.0 && mttr_s.is_finite() && *mttr_s > 0.0) {
                return Err(anyhow!(
                    "--mtbf-s/--mttr-s must be positive and finite, got {mtbf_s}/{mttr_s}"
                ));
            }
            // the horizon is the arrival span: faults beyond the last
            // arrival could only ever hit drain-phase stragglers, and an
            // empty stream faults nothing.  The seed is derived from the
            // traffic seed so one `--seed` pins the whole experiment.
            let horizon_ns = arrivals.last().copied().unwrap_or(0);
            FaultSchedule::random(cfg.seed ^ 0xFA17, *mtbf_s, *mttr_s, fleet.len(), horizon_ns)
                .events
        }
    };
    let faults_enabled = cfg.faults.is_some();
    if let Some(t) = obs.as_deref_mut().and_then(|o| o.trace.as_mut()) {
        t.process_name(PID_SERVE, "cat serve (virtual clock)");
        t.thread_name(PID_SERVE, TID_REQUESTS, "requests");
        for b in 0..fleet.len() {
            t.thread_name(PID_SERVE, b as u32 + 1, &format!("backend {b}"));
        }
        if faults_enabled {
            t.thread_name(PID_SERVE, fleet.len() as u32 + 1, "faults");
        }
    }
    let mut lp = ServeLoop::new(cfg, fleet, schedule, faults_enabled, obs);
    for (id, &t_ns) in arrivals.iter().enumerate() {
        lp.arrive(id as u64, t_ns)?;
    }
    // end of stream: flushes, retirements, and in-horizon faults all
    // keep firing at their own virtual deadlines until the work drains
    lp.drain()?;
    // detach the sink: the metrics fill below reads the finished report
    // while `lp`'s fields are still being consumed
    let obs_after = lp.obs.take();
    let mut stats = lp.stats;
    stats.completed = lp.responses.len();
    let shed = std::mem::take(&mut lp.shed);

    let slo_ns = cfg.slo_ns();
    let wall_ns = lp
        .responses
        .iter()
        .map(|r| r.completion_ns)
        .chain(arrivals.last().copied())
        .max()
        .unwrap_or(0);
    let slo_violations = lp.responses.iter().filter(|r| r.latency_ns() > slo_ns).count();
    let faults_report = if faults_enabled { Some(lp.faults_report(wall_ns)) } else { None };

    // Energy accounting: each member's `power_w` includes the board's
    // static floor.  With one board per member (PR 3 semantics) that is
    // the right per-member charge; a partition-built fleet
    // (`fleet.budget` present) co-resides on ONE physical board, so its
    // static power is charged once — over the experiment wall, since an
    // always-on board burns it through idle gaps too — and members
    // contribute only their dynamic power on top.  Keyed off the fleet
    // itself, so the accounting can never disagree with how the
    // backends were deployed.
    let shared_board = fleet.budget.is_some();
    let static_w = cfg.hw.power.static_w;
    let mut total_ops = 0u64;
    let mut energy_ns_w = if let Some(cb) = &fleet.cluster {
        // a cluster is N always-on boards: each burns its own static
        // floor over the wall, members add dynamic power on top
        cb.boards.iter().map(|bl| bl.hw.power.static_w).sum::<f64>() * wall_ns as f64
    } else if shared_board {
        static_w * wall_ns as f64
    } else {
        0.0
    };
    let backends: Vec<BackendSummary> = lp
        .states
        .iter_mut()
        .zip(&fleet.backends)
        .map(|(st, be)| {
            total_ops += st.ops;
            let member_w = if let Some(cb) = &fleet.cluster {
                (be.power_w() - cb.boards[cb.members[be.id].board].hw.power.static_w).max(0.0)
            } else if shared_board {
                (be.power_w() - static_w).max(0.0)
            } else {
                be.power_w()
            };
            energy_ns_w += member_w * st.busy_ns as f64;
            let mut lat = std::mem::take(&mut st.latencies);
            lat.sort_unstable();
            BackendSummary {
                id: be.id,
                point: be.point.clone(),
                admitted: st.admitted,
                busy_ns: st.busy_ns,
                ops: st.ops,
                stats: ServeStats {
                    completed: lat.len(),
                    batches: st.batches,
                    latencies: lat,
                    wall: Duration::from_nanos(wall_ns),
                },
            }
        })
        .collect();

    let fleet_stats = ServeStats {
        completed: lp.responses.len(),
        batches: backends.iter().map(|b| b.stats.batches).sum(),
        latencies: {
            let mut v: Vec<Duration> = lp
                .responses
                .iter()
                .map(|r| Duration::from_nanos(r.latency_ns()))
                .collect();
            v.sort_unstable();
            v
        },
        wall: Duration::from_nanos(wall_ns),
    };

    let mut responses = lp.responses;
    responses.sort_by_key(|r| r.id);
    let report = FleetReport {
        model: cfg.model.name.clone(),
        hw: match &fleet.cluster {
            Some(c) => c.name.clone(),
            None => cfg.hw.name.clone(),
        },
        rps: cfg.rps,
        slo_ms: cfg.slo_ms,
        seed: cfg.seed,
        n_backends: fleet.len(),
        admission: stats,
        responses,
        shed,
        backends,
        fleet_stats,
        wall_ns,
        fleet_gops_per_w: if energy_ns_w > 0.0 { total_ops as f64 / energy_ns_w } else { 0.0 },
        slo_violations,
        board: fleet.budget.clone(),
        faults: faults_report,
        cluster: fleet.cluster.clone(),
    };
    if let Some(o) = obs_after {
        fill_serve_metrics(o, &report);
    }
    Ok(report)
}

/// Fill the registry from the finished report: the admission split,
/// fleet aggregates, per-backend gauges, and the global-counter deltas
/// (stage-sim cache, DES fast-forward coverage, `par_map` occupancy)
/// bracketed by `Obs::new`.
fn fill_serve_metrics(o: &mut Obs, r: &FleetReport) {
    if let Some(m) = o.metrics.as_mut() {
        r.admission.export_metrics(m);
        m.set_gauge("serve.shed_rate", r.admission.shed_rate());
        m.set_gauge("serve.wall_ms", r.wall_ns as f64 / 1e6);
        m.set_gauge("serve.fleet_gops_per_w", r.fleet_gops_per_w);
        m.add("serve.slo_violations", r.slo_violations as u64);
        let wall = r.wall_ns.max(1) as f64;
        for b in &r.backends {
            m.set_gauge(&format!("serve.backend{}.utilization", b.id), b.busy_ns as f64 / wall);
            m.add(&format!("serve.backend{}.batches", b.id), b.stats.batches as u64);
            m.add(&format!("serve.backend{}.completed", b.id), b.stats.completed as u64);
        }
    }
    o.record_global_deltas();
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base_cfg() -> FleetConfig {
        FleetConfig::new(ModelConfig::bert_base(), HardwareConfig::vck5000())
    }

    fn cluster_spec() -> crate::cluster::ClusterSpec {
        crate::cluster::ClusterSpec {
            boards: vec![HardwareConfig::vck5000(), HardwareConfig::vck5000_limited(64)],
            net: SharedLinkModel { dram_gbps: 25.0, pcie_gbps: 12.5 },
        }
    }

    /// THE flag→schema map, pinned exhaustively: 2^4 combinations of
    /// (cluster, faults, partition, links) → schema.  Any precedence
    /// change must rewrite this table consciously.
    #[test]
    fn schema_table_pins_full_combination_map() {
        let table = [
            // (cluster, faults, partition, links) -> schema
            ((false, false, false, false), "cat-serve-v1"),
            ((false, false, false, true), "cat-serve-v1"),
            ((false, false, true, false), "cat-serve-v2"),
            ((false, false, true, true), "cat-serve-v3"),
            ((false, true, false, false), "cat-serve-v4"),
            ((false, true, false, true), "cat-serve-v4"),
            ((false, true, true, false), "cat-serve-v4"),
            ((false, true, true, true), "cat-serve-v4"),
            ((true, false, false, false), "cat-serve-v5"),
            ((true, false, false, true), "cat-serve-v5"),
            ((true, false, true, false), "cat-serve-v5"),
            ((true, false, true, true), "cat-serve-v5"),
            ((true, true, false, false), "cat-serve-v5"),
            ((true, true, false, true), "cat-serve-v5"),
            ((true, true, true, false), "cat-serve-v5"),
            ((true, true, true, true), "cat-serve-v5"),
        ];
        for ((cluster, faults, partition, links), want) in table {
            let mut cfg = base_cfg();
            cfg.cluster = cluster.then(cluster_spec);
            cfg.faults = faults.then(|| FaultPolicy::Schedule(FaultSchedule::default()));
            cfg.partition = partition;
            cfg.links = if links { Some(cfg.hw.links()) } else { None };
            assert_eq!(
                cfg.schema(),
                want,
                "schema for cluster={cluster} faults={faults} partition={partition} \
                 links={links}"
            );
        }
    }

    #[test]
    fn from_args_defaults_match_new() {
        let cfg = FleetConfig::from_args(&ServeArgs::default()).unwrap();
        assert_eq!(cfg.model.name, "bert-base");
        assert_eq!(cfg.hw.name, "vck5000");
        assert_eq!(cfg.rps, 1000.0);
        assert_eq!(cfg.schema(), "cat-serve-v1");
        assert!(cfg.links.is_some() && cfg.cluster.is_none() && cfg.faults.is_none());
    }

    #[test]
    fn from_args_rejects_bad_numbers_and_zeros() {
        let err = |a: ServeArgs| FleetConfig::from_args(&a).unwrap_err().to_string();
        let rps = ServeArgs { rps: Some("abc".into()), ..Default::default() };
        assert!(err(rps).contains("--rps expects a number"));
        let neg = ServeArgs { rps: Some("-5".into()), ..Default::default() };
        assert!(err(neg).contains("--rps must be positive"));
        let slo = ServeArgs { slo_ms: Some("0".into()), ..Default::default() };
        assert!(err(slo).contains("--slo-ms must be positive"));
        let be = ServeArgs { backends: Some("0".into()), ..Default::default() };
        assert!(err(be).contains("--backends must be positive"));
        let q = ServeArgs { queue_cap: Some("0".into()), ..Default::default() };
        assert!(err(q).contains("--queue-cap must be positive"));
        let budget = ServeArgs { budget: Some("zero".into()), ..Default::default() };
        assert!(err(budget).contains("--budget expects a positive integer or 'all'"));
    }

    #[test]
    fn from_args_link_flags_require_partition() {
        let err = |a: ServeArgs| FleetConfig::from_args(&a).unwrap_err().to_string();
        for a in [
            ServeArgs { no_links: true, ..Default::default() },
            ServeArgs { links_fixed_point: true, ..Default::default() },
            ServeArgs { dram_gbps: Some("10".into()), ..Default::default() },
            ServeArgs { pcie_gbps: Some("10".into()), ..Default::default() },
        ] {
            assert!(err(a).contains("require --partition"));
        }
        let both = ServeArgs {
            partition: true,
            no_links: true,
            links_fixed_point: true,
            ..Default::default()
        };
        assert!(err(both).contains("no contention model to refine"));
        let pools = ServeArgs {
            partition: true,
            no_links: true,
            dram_gbps: Some("10".into()),
            ..Default::default()
        };
        assert!(err(pools).contains("no pools to override"));
        let bad = ServeArgs {
            partition: true,
            dram_gbps: Some("-1".into()),
            ..Default::default()
        };
        assert!(err(bad).contains("--dram-gbps expects a positive number"));
    }

    #[test]
    fn from_args_fault_flag_rules() {
        let err = |a: ServeArgs| FleetConfig::from_args(&a).unwrap_err().to_string();
        // exclusivity fires before the spec file is read: no file needed
        let both = ServeArgs {
            faults: Some("nonexistent.json".into()),
            mtbf_s: Some("10".into()),
            ..Default::default()
        };
        assert!(err(both).contains("mutually exclusive"));
        let half = ServeArgs { mtbf_s: Some("10".into()), ..Default::default() };
        assert!(err(half).contains("must be given together"));
        let bad = ServeArgs {
            mtbf_s: Some("10".into()),
            mttr_s: Some("-1".into()),
            ..Default::default()
        };
        assert!(err(bad).contains("--mttr-s expects a positive number of seconds"));
        let ok = ServeArgs {
            mtbf_s: Some("10".into()),
            mttr_s: Some("0.5".into()),
            ..Default::default()
        };
        let cfg = FleetConfig::from_args(&ok).unwrap();
        assert_eq!(cfg.schema(), "cat-serve-v4");
    }

    #[test]
    fn from_args_cluster_conflicts_fire_before_spec_load() {
        // the path is bogus on purpose: conflicts must not read disk
        let err = |a: ServeArgs| FleetConfig::from_args(&a).unwrap_err().to_string();
        let base = ServeArgs { cluster: Some("/no/such/spec.json".into()), ..Default::default() };
        let hw = ServeArgs { hw: Some("vck190".into()), ..base.clone() };
        assert!(err(hw).contains("--hw conflicts with --cluster"));
        let part = ServeArgs { partition: true, ..base.clone() };
        assert!(err(part).contains("--cluster conflicts with --partition"));
        for a in [
            ServeArgs { no_links: true, ..base.clone() },
            ServeArgs { dram_gbps: Some("10".into()), ..base.clone() },
            ServeArgs { pcie_gbps: Some("10".into()), ..base.clone() },
        ] {
            assert!(err(a).contains("conflict with --cluster"));
        }
        assert!(err(base).contains("reading cluster spec"));
    }

    #[test]
    fn from_args_loads_cluster_spec_and_allows_fixed_point() {
        let path = std::env::temp_dir()
            .join(format!("cat_cluster_spec_{}.json", std::process::id()));
        std::fs::write(&path, r#"{"boards": ["vck5000", "vck5000-limited-64"]}"#).unwrap();
        let a = ServeArgs {
            cluster: Some(path.to_str().unwrap().into()),
            links_fixed_point: true,
            backends: Some("2".into()),
            ..Default::default()
        };
        let cfg = FleetConfig::from_args(&a).unwrap();
        std::fs::remove_file(&path).ok();
        let spec = cfg.cluster.as_ref().unwrap();
        assert_eq!(spec.boards.len(), 2);
        assert_eq!(cfg.hw.name, spec.boards[0].name);
        assert!(cfg.links_fixed_point && !cfg.partition);
        assert_eq!(cfg.schema(), "cat-serve-v5");
        assert_eq!(cfg.link_mode(), links::NegotiationMode::FixedPoint);
    }
}
