//! SLO-aware fleet serving (`cat serve --rps ...`): route a live request
//! stream across an explore-derived accelerator family.
//!
//! The paper derives a *family* of customized accelerators (§IV, Table
//! VI); this module puts the family to work at runtime.  A fleet of
//! logical backends — one per selected [`dse`](crate::dse) frontier point,
//! re-derived via [`dse::deploy_plan`](crate::dse::deploy_plan) and
//! pre-simulated into a per-batch-size service profile ([`fleet`]) — is
//! driven by a **virtual-clock** serving loop:
//!
//! * a seeded open-loop Poisson generator ([`admission::TrafficGen`])
//!   produces arrivals at `--rps`;
//! * each arrival is routed ([`router`]) to the **cheapest** backend whose
//!   worst-case completion bound fits `--slo-ms`, or shed
//!   ([`admission`]) when no bounded queue can make the deadline;
//! * per-backend continuous batching reuses the coordinator's
//!   [`Batcher`] (staleness flushes fire at their exact virtual
//!   deadlines, not on a polling grid);
//! * batch service times come from the explorer's own
//!   [`run_multi_edpu`](crate::sched::run_multi_edpu) machinery via the
//!   stage-sim cache, so the serving loop itself never runs the DES.
//!
//! Everything is integer virtual nanoseconds from a fixed epoch — the
//! loop is deterministic for a fixed seed and closed-form checkable
//! (`rust/tests/serve_properties.rs` asserts request conservation,
//! per-request latency lower bounds, and SLO compliance).
//!
//! **Partitioned mode** (`--partition`): instead of granting every
//! member its own board, [`Fleet::select_partitioned`] picks the best
//! frontier subset that **co-resides on one physical board** — joint
//! `Σ cores ≤ Total_AIE` and Table V PL pool bounds, the Vis-TOP-style
//! overlay scenario — scored on each candidate's pre-simulated
//! worst-case service bound (the router's own admission inequality),
//! and re-derives every member under its granted [`FleetBudget`] share.
//! The **shared memory path** is modeled too ([`links`]): members'
//! DRAM/PCIe demands are negotiated against the board's pools and
//! oversubscribed slices are throttled proportionally, re-simulating
//! their profiles under contention.  The routing/admission path is
//! identical; only the deployments (and hence each member's re-simulated
//! worst-case service bound) change, and the report carries the board
//! ledger under schema `cat-serve-v3` (`cat-serve-v2` when the link
//! model is disabled).

mod admission;
mod fleet;
pub mod links;
mod router;

pub use admission::{AdmissionStats, ShedReason, TrafficGen};
pub use fleet::{Backend, Fleet, FleetBudget};
pub use links::{LinkDemand, LinkLedger, MemberLink};
pub use router::{route, BackendLoad, RouteDecision};

use std::collections::{BTreeMap, VecDeque};
use std::time::{Duration, Instant};

use crate::config::{HardwareConfig, ModelConfig, SharedLinkModel};
use crate::coordinator::{Batcher, BatcherConfig, ServeStats};
use crate::dse;
use crate::util::json::Json;
use anyhow::Result;

/// One fleet-serving experiment.
#[derive(Debug, Clone)]
pub struct FleetConfig {
    pub model: ModelConfig,
    pub hw: HardwareConfig,
    /// Offered open-loop load (requests/second).
    pub rps: f64,
    /// Per-request completion SLO, arrival → response (ms).
    pub slo_ms: f64,
    /// Synthetic requests to generate.
    pub n_requests: usize,
    /// Fleet size cap (fewer deploy when the frontier is small).
    pub max_backends: usize,
    /// Per-backend serving batch cap.
    pub max_batch: usize,
    /// Admission bound: requests admitted but not yet completed, per
    /// backend (forming batch + dispatched backlog).
    pub queue_cap: usize,
    /// How long a forming batch may wait for more requests before the
    /// staleness flush dispatches it (`None` = SLO/8).
    pub batch_wait: Option<Duration>,
    /// Seed for the Poisson arrivals (and the in-process exploration).
    pub seed: u64,
    /// `cat explore` sampling budget for the in-process frontier
    /// derivation (`None` = exhaustive).
    pub explore_budget: Option<usize>,
    /// Deploy the fleet as **co-resident partitions of one board**
    /// (`Σ cores ≤ Total_AIE`, joint Table V PL estimate within the
    /// pools) instead of one board per member; the report gains the
    /// `board` ledger and switches to schema `cat-serve-v3`
    /// (`cat-serve-v2` when [`FleetConfig::links`] is `None`).
    pub partition: bool,
    /// Shared memory-path pools for partitioned deployments (`--partition`):
    /// the board's DRAM bandwidth and PCIe link that co-resident members
    /// negotiate over ([`links`]).  Defaults to the board's own pools;
    /// `None` disables the contention model (PR 4 free-pool semantics,
    /// schema `cat-serve-v2`).  Ignored without `partition` — a
    /// one-board-per-member fleet owns its links outright.
    pub links: Option<SharedLinkModel>,
}

impl FleetConfig {
    pub fn new(model: ModelConfig, hw: HardwareConfig) -> FleetConfig {
        let links = Some(hw.links());
        FleetConfig {
            model,
            hw,
            rps: 1000.0,
            slo_ms: 50.0,
            n_requests: 512,
            max_backends: 3,
            max_batch: 8,
            queue_cap: 64,
            batch_wait: None,
            seed: 0xCA7,
            explore_budget: Some(128),
            partition: false,
            links,
        }
    }

    /// Staleness budget for forming batches: explicit, or SLO/8 so
    /// batching consumes a bounded slice of the deadline.  A
    /// non-positive/NaN SLO degrades to a zero wait (every batch
    /// dispatches immediately) instead of panicking in `Duration`.
    pub fn resolved_batch_wait(&self) -> Duration {
        self.batch_wait.unwrap_or_else(|| {
            let w = self.slo_ms / 8.0 / 1e3;
            Duration::from_secs_f64(if w.is_finite() && w > 0.0 { w } else { 0.0 })
        })
    }

    pub fn slo_ns(&self) -> u64 {
        (self.slo_ms * 1e6).round() as u64
    }
}

/// One completed request (virtual-clock record).
#[derive(Debug, Clone, Copy)]
pub struct FleetResponse {
    pub id: u64,
    /// Fleet position of the backend that served it.
    pub backend: usize,
    pub arrival_ns: u64,
    pub completion_ns: u64,
    /// Size of the batch this request rode in.
    pub batch_size: usize,
    /// Simulated service time of that batch on its backend.
    pub batch_service_ns: u64,
}

impl FleetResponse {
    pub fn latency_ns(&self) -> u64 {
        self.completion_ns - self.arrival_ns
    }
}

/// One shed request.
#[derive(Debug, Clone, Copy)]
pub struct ShedRecord {
    pub id: u64,
    pub arrival_ns: u64,
    pub reason: ShedReason,
}

/// Per-backend serving summary.
#[derive(Debug, Clone)]
pub struct BackendSummary {
    pub id: usize,
    pub point: dse::DesignPoint,
    pub admitted: usize,
    pub busy_ns: u64,
    /// Useful MM ops executed across every batch served.
    pub ops: u64,
    /// Completed/batches/latency percentiles (virtual durations).
    pub stats: ServeStats,
}

impl BackendSummary {
    /// Fraction of the experiment wall the backend spent serving.
    pub fn utilization(&self, wall_ns: u64) -> f64 {
        if wall_ns == 0 {
            return 0.0;
        }
        self.busy_ns as f64 / wall_ns as f64
    }
}

/// The fleet-serving experiment outcome (schema `cat-serve-v1`;
/// `cat-serve-v2` when a partitioned deployment carries its board
/// ledger; `cat-serve-v3` when the board ledger additionally carries
/// the shared memory-path `links` block).
#[derive(Debug, Clone)]
pub struct FleetReport {
    pub model: String,
    pub hw: String,
    pub rps: f64,
    pub slo_ms: f64,
    pub seed: u64,
    pub n_backends: usize,
    pub admission: AdmissionStats,
    pub responses: Vec<FleetResponse>,
    pub shed: Vec<ShedRecord>,
    pub backends: Vec<BackendSummary>,
    /// Fleet-wide latency stats (virtual durations; wall = stream span).
    pub fleet_stats: ServeStats,
    /// Virtual end of the experiment (last completion or arrival).
    pub wall_ns: u64,
    /// Energy-weighted fleet efficiency: total useful ops over total
    /// energy (Σ power·busy), i.e. busy-time-weighted GOPS/W.
    pub fleet_gops_per_w: f64,
    /// Completed requests whose latency exceeded the SLO — zero by
    /// construction (admission bounds completion; see [`router`]).
    pub slo_violations: usize,
    /// One-board resource ledger when the fleet was deployed with
    /// `--partition` (`None` = PR 3 semantics, one board per member).
    pub board: Option<FleetBudget>,
}

impl FleetReport {
    pub fn to_json(&self) -> Json {
        let ms = |d: Duration| d.as_secs_f64() * 1e3;
        let mut m = BTreeMap::new();
        let schema = match &self.board {
            Some(b) if b.links.is_some() => "cat-serve-v3",
            Some(_) => "cat-serve-v2",
            None => "cat-serve-v1",
        };
        m.insert("schema".into(), Json::Str(schema.into()));
        if let Some(b) = &self.board {
            m.insert("board".into(), b.to_json());
        }
        m.insert("model".into(), Json::Str(self.model.clone()));
        m.insert("hw".into(), Json::Str(self.hw.clone()));
        m.insert("rps".into(), Json::Num(self.rps));
        m.insert("slo_ms".into(), Json::Num(self.slo_ms));
        m.insert("seed".into(), Json::Num(self.seed as f64));

        let a = &self.admission;
        let mut adm = BTreeMap::new();
        adm.insert("submitted".into(), Json::Num(a.submitted as f64));
        adm.insert("admitted".into(), Json::Num(a.admitted as f64));
        adm.insert("completed".into(), Json::Num(a.completed as f64));
        adm.insert("shed_slo".into(), Json::Num(a.shed_slo as f64));
        adm.insert("shed_capacity".into(), Json::Num(a.shed_capacity as f64));
        adm.insert("shed_rate".into(), Json::Num(a.shed_rate()));
        m.insert("admission".into(), Json::Obj(adm));

        let s = &self.fleet_stats;
        let mut fl = BTreeMap::new();
        fl.insert("backends".into(), Json::Num(self.n_backends as f64));
        fl.insert("p50_ms".into(), Json::Num(ms(s.percentile(0.50))));
        fl.insert("p95_ms".into(), Json::Num(ms(s.percentile(0.95))));
        fl.insert("p99_ms".into(), Json::Num(ms(s.percentile(0.99))));
        fl.insert("throughput_rps".into(), Json::Num(s.throughput_rps()));
        fl.insert("wall_ms".into(), Json::Num(self.wall_ns as f64 / 1e6));
        fl.insert("gops_per_w".into(), Json::Num(self.fleet_gops_per_w));
        fl.insert("slo_violations".into(), Json::Num(self.slo_violations as f64));
        m.insert("fleet".into(), Json::Obj(fl));

        let wall_ns = self.wall_ns;
        m.insert(
            "backends".into(),
            Json::Arr(
                self.backends
                    .iter()
                    .map(|b| {
                        let mut bm = BTreeMap::new();
                        bm.insert("id".into(), Json::Num(b.id as f64));
                        bm.insert("design".into(), b.point.to_json());
                        bm.insert("admitted".into(), Json::Num(b.admitted as f64));
                        bm.insert("completed".into(), Json::Num(b.stats.completed as f64));
                        bm.insert("batches".into(), Json::Num(b.stats.batches as f64));
                        bm.insert("mean_batch".into(), Json::Num(b.stats.mean_batch()));
                        bm.insert("utilization".into(), Json::Num(b.utilization(wall_ns)));
                        bm.insert("busy_ms".into(), Json::Num(b.busy_ns as f64 / 1e6));
                        bm.insert("p50_ms".into(), Json::Num(ms(b.stats.percentile(0.50))));
                        bm.insert("p99_ms".into(), Json::Num(ms(b.stats.percentile(0.99))));
                        Json::Obj(bm)
                    })
                    .collect(),
            ),
        );
        Json::Obj(m)
    }
}

/// Per-backend mutable serving state (virtual clock).
struct BackendState {
    batcher: Batcher<u64>,
    /// Completion time of everything dispatched so far.
    busy_until_ns: u64,
    /// Dispatched batches not yet past their completion time.
    outstanding: VecDeque<(u64, usize)>,
    in_flight: usize,
    admitted: usize,
    batches: usize,
    busy_ns: u64,
    ops: u64,
    latencies: Vec<Duration>,
}

/// The virtual-clock serving loop over an already-built fleet.
struct ServeLoop<'a> {
    cfg: &'a FleetConfig,
    fleet: &'a Fleet,
    /// Fixed epoch mapping virtual ns ↔ the `Instant`s [`Batcher`] wants.
    epoch: Instant,
    wait_ns: u64,
    /// Last processed virtual time — pending flush deadlines are always
    /// in the future relative to it, so staleness math never saturates.
    cursor_ns: u64,
    states: Vec<BackendState>,
    responses: Vec<FleetResponse>,
}

impl<'a> ServeLoop<'a> {
    fn new(cfg: &'a FleetConfig, fleet: &'a Fleet) -> ServeLoop<'a> {
        let wait = cfg.resolved_batch_wait();
        // never emit a batch the service profiles can't price
        let max_batch = cfg.max_batch.clamp(1, fleet.max_batch());
        let states = fleet
            .backends
            .iter()
            .map(|_| BackendState {
                batcher: Batcher::new(BatcherConfig { max_batch, timeout: wait }),
                busy_until_ns: 0,
                outstanding: VecDeque::new(),
                in_flight: 0,
                admitted: 0,
                batches: 0,
                busy_ns: 0,
                ops: 0,
                latencies: Vec::new(),
            })
            .collect();
        ServeLoop {
            cfg,
            fleet,
            epoch: Instant::now(),
            wait_ns: wait.as_nanos() as u64,
            cursor_ns: 0,
            states,
            responses: Vec::new(),
        }
    }

    fn at(&self, ns: u64) -> Instant {
        self.epoch + Duration::from_nanos(ns)
    }

    /// Absolute flush deadline of backend `b`'s forming batch (`None`
    /// when empty).  Evaluated at the cursor, where deadlines are exact.
    fn flush_deadline(&self, b: usize) -> Option<u64> {
        self.states[b]
            .batcher
            .time_until_stale(self.at(self.cursor_ns))
            .map(|d| self.cursor_ns + d.as_nanos() as u64)
    }

    /// Fire every staleness flush due at or before `t_ns`, each at its
    /// own virtual deadline, in (deadline, backend) order.
    fn flush_stale_up_to(&mut self, t_ns: u64) {
        loop {
            let next = (0..self.states.len())
                .filter_map(|b| self.flush_deadline(b).map(|d| (d, b)))
                .min();
            match next {
                Some((deadline, b)) if deadline <= t_ns => {
                    self.cursor_ns = deadline;
                    if let Some(batch) = self.states[b].batcher.flush() {
                        self.dispatch(b, batch, deadline);
                    }
                }
                _ => break,
            }
        }
        self.cursor_ns = self.cursor_ns.max(t_ns.min(u64::MAX / 2));
    }

    /// Commit one batch to backend `b` at virtual time `now_ns`.
    fn dispatch(&mut self, b: usize, batch: Vec<(u64, Instant)>, now_ns: u64) {
        let size = batch.len();
        let backend = &self.fleet.backends[b];
        let service = backend.service_ns(size);
        let st = &mut self.states[b];
        let start = st.busy_until_ns.max(now_ns);
        let completion = start + service;
        st.busy_until_ns = completion;
        st.busy_ns += service;
        st.batches += 1;
        st.ops += backend.ops(size);
        st.outstanding.push_back((completion, size));
        for (id, enq) in batch {
            let arrival_ns = enq.duration_since(self.epoch).as_nanos() as u64;
            st.latencies.push(Duration::from_nanos(completion - arrival_ns));
            self.responses.push(FleetResponse {
                id,
                backend: b,
                arrival_ns,
                completion_ns: completion,
                batch_size: size,
                batch_service_ns: service,
            });
        }
    }

    /// Retire batches whose completion time has passed (frees queue room).
    fn advance(&mut self, now_ns: u64) {
        for st in &mut self.states {
            while st.outstanding.front().is_some_and(|&(c, _)| c <= now_ns) {
                let (_, n) = st.outstanding.pop_front().unwrap();
                st.in_flight -= n;
            }
        }
    }

    /// Route + admit (or shed) one arrival at `t_ns`.
    fn arrive(&mut self, id: u64, t_ns: u64) -> Result<RouteDecision, ShedReason> {
        self.flush_stale_up_to(t_ns);
        self.advance(t_ns);
        let loads: Vec<BackendLoad> = (0..self.states.len())
            .map(|b| {
                let st = &self.states[b];
                BackendLoad {
                    busy_until_ns: st.busy_until_ns,
                    pending: st.batcher.pending_len(),
                    flush_deadline_ns: self.flush_deadline(b).unwrap_or(t_ns + self.wait_ns),
                    in_flight: st.in_flight,
                }
            })
            .collect();
        let decision = route(
            &self.fleet.backends,
            &loads,
            t_ns,
            self.cfg.slo_ns(),
            self.cfg.queue_cap,
        )?;
        let b = decision.backend;
        let at = self.at(t_ns);
        let st = &mut self.states[b];
        st.admitted += 1;
        st.in_flight += 1;
        if let Some(batch) = st.batcher.push(id, at) {
            self.dispatch(b, batch, t_ns);
        }
        Ok(decision)
    }
}

/// Derive a frontier for the pair, deploy the family — on one shared
/// board when [`FleetConfig::partition`] is set, one board per member
/// otherwise — and serve the synthetic stream across it.
pub fn serve_fleet(cfg: &FleetConfig) -> Result<FleetReport> {
    let mut ecfg = dse::ExploreConfig::new(cfg.model.clone(), cfg.hw.clone());
    ecfg.sample_budget = cfg.explore_budget;
    ecfg.seed = cfg.seed;
    ecfg.slo_ms = Some(cfg.slo_ms);
    let explored = dse::explore(&ecfg)?;
    let fleet = if cfg.partition {
        Fleet::select_partitioned(
            &cfg.model,
            &cfg.hw,
            &explored,
            cfg.max_backends,
            cfg.max_batch,
            Some(cfg.slo_ms),
            cfg.links.as_ref(),
        )?
    } else {
        Fleet::select(&cfg.model, &cfg.hw, &explored, cfg.max_backends, cfg.max_batch)?
    };
    serve_fleet_on(cfg, &fleet)
}

/// Drive the virtual-clock serving loop over an already-built fleet
/// (exposed so tests and benches can pin a hand-built family).
pub fn serve_fleet_on(cfg: &FleetConfig, fleet: &Fleet) -> Result<FleetReport> {
    let arrivals = TrafficGen::poisson(cfg.seed, cfg.rps, cfg.n_requests);
    serve_fleet_stream(cfg, fleet, &arrivals)
}

/// The serving loop over an **explicit** arrival pattern (sorted virtual
/// timestamps, ns) — lets tests drive bursty or adversarial streams
/// through the identical routing/admission/batching path.  Request ids
/// are the arrival positions; `cfg.n_requests`/`cfg.rps` only label the
/// report here, the stream is `arrivals`.
pub fn serve_fleet_stream(
    cfg: &FleetConfig,
    fleet: &Fleet,
    arrivals: &[u64],
) -> Result<FleetReport> {
    debug_assert!(arrivals.windows(2).all(|w| w[0] <= w[1]), "arrivals must be sorted");
    let mut lp = ServeLoop::new(cfg, fleet);
    let mut stats = AdmissionStats::default();
    let mut shed = Vec::new();
    for (id, &t_ns) in arrivals.iter().enumerate() {
        stats.submitted += 1;
        match lp.arrive(id as u64, t_ns) {
            Ok(_) => stats.admitted += 1,
            Err(reason) => {
                stats.record_shed(reason);
                shed.push(ShedRecord { id: id as u64, arrival_ns: t_ns, reason });
            }
        }
    }
    // end of stream: every forming batch still flushes at its own deadline
    lp.flush_stale_up_to(u64::MAX);
    stats.completed = lp.responses.len();

    let slo_ns = cfg.slo_ns();
    let wall_ns = lp
        .responses
        .iter()
        .map(|r| r.completion_ns)
        .chain(arrivals.last().copied())
        .max()
        .unwrap_or(0);
    let slo_violations = lp.responses.iter().filter(|r| r.latency_ns() > slo_ns).count();

    // Energy accounting: each member's `power_w` includes the board's
    // static floor.  With one board per member (PR 3 semantics) that is
    // the right per-member charge; a partition-built fleet
    // (`fleet.budget` present) co-resides on ONE physical board, so its
    // static power is charged once — over the experiment wall, since an
    // always-on board burns it through idle gaps too — and members
    // contribute only their dynamic power on top.  Keyed off the fleet
    // itself, so the accounting can never disagree with how the
    // backends were deployed.
    let shared_board = fleet.budget.is_some();
    let static_w = cfg.hw.power.static_w;
    let mut total_ops = 0u64;
    let mut energy_ns_w = if shared_board { static_w * wall_ns as f64 } else { 0.0 };
    let backends: Vec<BackendSummary> = lp
        .states
        .iter_mut()
        .zip(&fleet.backends)
        .map(|(st, be)| {
            total_ops += st.ops;
            let member_w = if shared_board {
                (be.power_w() - static_w).max(0.0)
            } else {
                be.power_w()
            };
            energy_ns_w += member_w * st.busy_ns as f64;
            let mut lat = std::mem::take(&mut st.latencies);
            lat.sort_unstable();
            BackendSummary {
                id: be.id,
                point: be.point.clone(),
                admitted: st.admitted,
                busy_ns: st.busy_ns,
                ops: st.ops,
                stats: ServeStats {
                    completed: lat.len(),
                    batches: st.batches,
                    latencies: lat,
                    wall: Duration::from_nanos(wall_ns),
                },
            }
        })
        .collect();

    let fleet_stats = ServeStats {
        completed: lp.responses.len(),
        batches: backends.iter().map(|b| b.stats.batches).sum(),
        latencies: {
            let mut v: Vec<Duration> = lp
                .responses
                .iter()
                .map(|r| Duration::from_nanos(r.latency_ns()))
                .collect();
            v.sort_unstable();
            v
        },
        wall: Duration::from_nanos(wall_ns),
    };

    let mut responses = lp.responses;
    responses.sort_by_key(|r| r.id);
    Ok(FleetReport {
        model: cfg.model.name.clone(),
        hw: cfg.hw.name.clone(),
        rps: cfg.rps,
        slo_ms: cfg.slo_ms,
        seed: cfg.seed,
        n_backends: fleet.len(),
        admission: stats,
        responses,
        shed,
        backends,
        fleet_stats,
        wall_ns,
        fleet_gops_per_w: if energy_ns_w > 0.0 { total_ops as f64 / energy_ns_w } else { 0.0 },
        slo_violations,
        board: fleet.budget.clone(),
    })
}
