//! Shared memory-path contention for partitioned fleets.
//!
//! PR 4's `--partition` negotiated co-resident members against one
//! board's `Total_AIE` and Table V PL pools, but every member still drew
//! the board's full DRAM bandwidth and PCIe link for free — exactly the
//! overlay pitfall Vis-TOP warns about.  This module closes that gap:
//!
//! 1. each selected member's **demand** on the two shared pools is
//!    derived from its own *uncontended* service profile at the serving
//!    batch cap (bytes the deployment streams per virtual ns — weights +
//!    activations for DRAM, host I/O for PCIe);
//! 2. [`negotiate`] grants each member a **proportional share** of every
//!    oversubscribed pool (`granted_i = pool · demand_i / Σ demand`) and
//!    derives the member's service-time **stretch** — the ratio of its
//!    solo-link rate (`min(demand, pool)`: a member alone on the link is
//!    the PR 4 baseline, whatever its appetite) to its granted rate;
//! 3. the fleet redeploys every stretched member on a slice whose
//!    `mem_throttle = 1/stretch`, so the contended profile is
//!    **re-simulated** through the same DES the explorer used — the
//!    router's admission bounds then price contention automatically.
//!
//! The model is a single-pass proportional split, deliberately not a
//! fixed point (throttled members demand less, which would relax the
//! split; charging the un-relaxed share keeps the bound conservative and
//! the arithmetic deterministic).  A 1-member partition is bit-identical
//! to the uncontended deployment by construction: its solo rate *is* its
//! baseline, so its stretch is exactly 1.

use std::collections::BTreeMap;

use crate::config::{ModelConfig, SharedLinkModel};
use crate::util::json::Json;

/// One member's bandwidth appetite on the two shared pools (GB/s ==
/// bytes per virtual ns).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinkDemand {
    pub dram_gbps: f64,
    pub pcie_gbps: f64,
}

/// One member's negotiated outcome.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MemberLink {
    /// Uncontended appetite.
    pub demand: LinkDemand,
    /// Proportional share actually granted.
    pub granted: LinkDemand,
    /// Service-time stretch = solo-link rate / granted rate, ≥ 1.  The
    /// member's slice carries `mem_throttle = 1/stretch`.
    pub stretch: f64,
}

/// The board-level link ledger: pools, per-member grants, and the
/// aggregate demand — the `board.links` block of `cat-serve-v3`.
#[derive(Debug, Clone, PartialEq)]
pub struct LinkLedger {
    pub pools: SharedLinkModel,
    /// `members[i]` belongs to fleet position `i` (cost order).
    pub members: Vec<MemberLink>,
}

impl LinkLedger {
    /// Σ demanded bandwidth per pool.
    pub fn demanded(&self) -> LinkDemand {
        LinkDemand {
            dram_gbps: self.members.iter().map(|m| m.demand.dram_gbps).sum(),
            pcie_gbps: self.members.iter().map(|m| m.demand.pcie_gbps).sum(),
        }
    }

    /// Σ granted bandwidth per pool (never exceeds the pools).
    pub fn granted(&self) -> LinkDemand {
        LinkDemand {
            dram_gbps: self.members.iter().map(|m| m.granted.dram_gbps).sum(),
            pcie_gbps: self.members.iter().map(|m| m.granted.pcie_gbps).sum(),
        }
    }

    /// True when any member runs slower than it would alone.
    pub fn throttled(&self) -> bool {
        self.members.iter().any(|m| m.stretch > 1.0)
    }

    /// The `board.links` block: per-pool demanded vs granted bandwidth
    /// and the throttle factor per member.
    pub fn to_json(&self) -> Json {
        let demanded = self.demanded();
        let granted = self.granted();
        let pool = |total: f64, dem: f64, grant: f64| {
            let mut p = BTreeMap::new();
            p.insert("pool_gbps".into(), Json::Num(total));
            p.insert("demanded_gbps".into(), Json::Num(dem));
            p.insert("granted_gbps".into(), Json::Num(grant));
            p.insert(
                "oversubscription".into(),
                Json::Num(if total > 0.0 { dem / total } else { 0.0 }),
            );
            Json::Obj(p)
        };
        let mut m = BTreeMap::new();
        m.insert(
            "dram".into(),
            pool(self.pools.dram_gbps, demanded.dram_gbps, granted.dram_gbps),
        );
        m.insert(
            "pcie".into(),
            pool(self.pools.pcie_gbps, demanded.pcie_gbps, granted.pcie_gbps),
        );
        m.insert("throttled".into(), Json::Bool(self.throttled()));
        m.insert(
            "members".into(),
            Json::Arr(
                self.members
                    .iter()
                    .enumerate()
                    .map(|(i, ml)| {
                        let mut mm = BTreeMap::new();
                        mm.insert("backend".into(), Json::Num(i as f64));
                        mm.insert("dram_demand_gbps".into(), Json::Num(ml.demand.dram_gbps));
                        mm.insert("dram_granted_gbps".into(), Json::Num(ml.granted.dram_gbps));
                        mm.insert("pcie_demand_gbps".into(), Json::Num(ml.demand.pcie_gbps));
                        mm.insert("pcie_granted_gbps".into(), Json::Num(ml.granted.pcie_gbps));
                        mm.insert("stretch".into(), Json::Num(ml.stretch));
                        mm.insert("throttle".into(), Json::Num(1.0 / ml.stretch));
                        Json::Obj(mm)
                    })
                    .collect(),
            ),
        );
        Json::Obj(m)
    }
}

/// Weight bytes one whole-model pass streams from DRAM: every layer's
/// QKV + Proj + FFN parameters ([`ModelConfig::layer_weight_bytes`], so
/// the model's own element width is honored).  BERT-Base int8: ~85 MB —
/// far beyond the 23.9 MB on-chip SRAM, so weights re-stream every
/// batch.
pub fn model_weight_bytes(m: &ModelConfig) -> u64 {
    m.layer_weight_bytes() as u64 * m.layers as u64
}

/// DRAM bytes one batch of `k` items moves: the streamed weights plus
/// activations in and out.
pub fn dram_bytes_per_batch(m: &ModelConfig, k: usize) -> u64 {
    model_weight_bytes(m) + pcie_bytes_per_batch(m, k)
}

/// PCIe bytes one batch of `k` items moves: input and output activations
/// crossing the host link at the model's element width (weights are
/// board-resident in DRAM after the one-time load, so they don't transit
/// PCIe per batch).
pub fn pcie_bytes_per_batch(m: &ModelConfig, k: usize) -> u64 {
    2 * (k * m.seq_len * m.embed_dim * m.bytes_per_elem()) as u64
}

/// One member's pool appetite from its uncontended service time for a
/// batch of `k` (`service_ns` from the member's profile): bytes per
/// virtual ns, i.e. GB/s.
pub fn demand_at(model: &ModelConfig, service_ns: u64, k: usize) -> LinkDemand {
    let t = service_ns.max(1) as f64;
    LinkDemand {
        dram_gbps: dram_bytes_per_batch(model, k) as f64 / t,
        pcie_gbps: pcie_bytes_per_batch(model, k) as f64 / t,
    }
}

/// Proportional share of one pool: `(granted, stretch)`.  Under-
/// subscribed pools grant every demand in full (stretch 1); an
/// oversubscribed pool splits proportionally, and the stretch compares
/// the grant against the member's *solo-link* rate (`min(demand, pool)`)
/// — a lone member owns the whole pool, so sharing is the only thing
/// this model ever charges for.
fn pool_share(demand: f64, total_demand: f64, pool: f64) -> (f64, f64) {
    if demand <= 0.0 || total_demand <= pool {
        return (demand, 1.0);
    }
    if pool <= 0.0 {
        // a demanded pool of zero width grants nothing; an infinite
        // stretch (not the NaN that 0/0 would give) makes the broken
        // configuration loud — the deploy path rejects a zero throttle
        // rather than silently serving at rate zero
        return (0.0, f64::INFINITY);
    }
    let granted = pool * demand / total_demand;
    let solo = demand.min(pool);
    (granted, (solo / granted).max(1.0))
}

/// Negotiate every member's grant against the shared pools.  The
/// member's overall stretch is the worst across pools — its slice is
/// throttled to the tightest link it transits.
pub fn negotiate(pools: &SharedLinkModel, demands: &[LinkDemand]) -> LinkLedger {
    let tot_dram: f64 = demands.iter().map(|d| d.dram_gbps).sum();
    let tot_pcie: f64 = demands.iter().map(|d| d.pcie_gbps).sum();
    let members = demands
        .iter()
        .map(|d| {
            let (g_dram, s_dram) = pool_share(d.dram_gbps, tot_dram, pools.dram_gbps);
            let (g_pcie, s_pcie) = pool_share(d.pcie_gbps, tot_pcie, pools.pcie_gbps);
            MemberLink {
                demand: *d,
                granted: LinkDemand { dram_gbps: g_dram, pcie_gbps: g_pcie },
                stretch: s_dram.max(s_pcie),
            }
        })
        .collect();
    LinkLedger { pools: *pools, members }
}

/// [`negotiate`] over the `up` subset of a partition: down members stop
/// demanding bandwidth, so the survivors split the pools among
/// themselves — the failover path's graceful-degradation step.  Returns
/// one entry per original position (`None` for down members), so fleet
/// indices stay stable across the fault window.  With every member up
/// this is exactly [`negotiate`]; with one survivor it degenerates to
/// the PR 4 single-member case (stretch 1 whatever its appetite).
pub fn negotiate_masked(
    pools: &SharedLinkModel,
    demands: &[LinkDemand],
    up: &[bool],
) -> Vec<Option<MemberLink>> {
    assert_eq!(demands.len(), up.len());
    let live: Vec<LinkDemand> =
        demands.iter().zip(up).filter(|(_, u)| **u).map(|(d, _)| *d).collect();
    let ledger = negotiate(pools, &live);
    let mut granted = ledger.members.into_iter();
    up.iter().map(|u| if *u { granted.next() } else { None }).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pools(dram: f64, pcie: f64) -> SharedLinkModel {
        SharedLinkModel { dram_gbps: dram, pcie_gbps: pcie }
    }

    fn d(dram: f64, pcie: f64) -> LinkDemand {
        LinkDemand { dram_gbps: dram, pcie_gbps: pcie }
    }

    #[test]
    fn undersubscribed_pools_grant_in_full() {
        let l = negotiate(&pools(100.0, 16.0), &[d(40.0, 4.0), d(50.0, 6.0)]);
        assert!(!l.throttled());
        for m in &l.members {
            assert_eq!(m.granted, m.demand);
            assert_eq!(m.stretch, 1.0);
        }
    }

    #[test]
    fn single_member_never_throttles_whatever_its_appetite() {
        // the PR 4 degeneracy: a lone member owns the whole link — even
        // when its demand exceeds the pool, its solo rate IS its
        // baseline, so the stretch is exactly 1
        let l = negotiate(&pools(100.0, 16.0), &[d(250.0, 40.0)]);
        assert_eq!(l.members[0].stretch, 1.0);
        assert!(!l.throttled());
    }

    #[test]
    fn oversubscription_splits_proportionally_and_stretches() {
        // 150 demanded vs a 100 pool: grants 2:1, both stretched 1.5x
        let l = negotiate(&pools(100.0, 1e9), &[d(100.0, 0.0), d(50.0, 0.0)]);
        assert!(l.throttled());
        let (a, b) = (&l.members[0], &l.members[1]);
        assert!((a.granted.dram_gbps - 100.0 * 100.0 / 150.0).abs() < 1e-9);
        assert!((b.granted.dram_gbps - 100.0 * 50.0 / 150.0).abs() < 1e-9);
        assert!((a.stretch - 1.5).abs() < 1e-9);
        assert!((b.stretch - 1.5).abs() < 1e-9);
        // Σ granted saturates the pool exactly
        assert!((l.granted().dram_gbps - 100.0).abs() < 1e-9);
    }

    #[test]
    fn stretch_is_monotone_in_oversubscription() {
        let demands = [d(80.0, 0.0), d(80.0, 0.0)];
        let mut last = 0.0;
        for pool in [200.0, 120.0, 80.0, 40.0, 10.0] {
            let l = negotiate(&pools(pool, 1e9), &demands);
            let s = l.members[0].stretch;
            assert!(s >= last, "pool {pool}: stretch {s} < {last}");
            assert!(s >= 1.0);
            last = s;
        }
    }

    #[test]
    fn worst_pool_wins_the_stretch() {
        // DRAM is fine but PCIe is 4x oversubscribed — the member's
        // slice must throttle to the PCIe stretch
        let l = negotiate(&pools(1000.0, 8.0), &[d(10.0, 16.0), d(10.0, 16.0)]);
        for m in &l.members {
            assert!((m.stretch - 2.0).abs() < 1e-9, "stretch {}", m.stretch);
            assert_eq!(m.granted.dram_gbps, m.demand.dram_gbps);
        }
    }

    #[test]
    fn demand_scales_with_traffic_and_inversely_with_service_time() {
        let m = ModelConfig::bert_base();
        let fast = demand_at(&m, 1_000_000, 8);
        let slow = demand_at(&m, 2_000_000, 8);
        assert!((fast.dram_gbps - 2.0 * slow.dram_gbps).abs() < 1e-9);
        assert!(fast.dram_gbps > fast.pcie_gbps, "weights dominate DRAM traffic");
        // BERT-Base weights ~= 85 MB int8
        let wb = model_weight_bytes(&m) as f64 / (1024.0 * 1024.0);
        assert!((70.0..100.0).contains(&wb), "{wb} MB");
    }

    #[test]
    fn zero_width_demanded_pool_is_loud_not_silently_uncontended() {
        // pool 0 with positive demand must NOT round-trip to a NaN that
        // masks as "stretch 1.0"; it grants nothing and stretches
        // infinitely, which the deploy path rejects as throttle 0
        let l = negotiate(&pools(0.0, 16.0), &[d(10.0, 1.0), d(10.0, 1.0)]);
        for m in &l.members {
            assert_eq!(m.granted.dram_gbps, 0.0);
            assert!(m.stretch.is_infinite());
        }
        assert!(l.throttled());
    }

    #[test]
    fn masked_negotiation_relaxes_survivors() {
        // both up: 150 vs the 100 pool stretches both 1.5x; kill the
        // heavy member and the survivor (demand 50 < pool 100) runs
        // uncontended — stretch drops to exactly 1
        let demands = [d(100.0, 0.0), d(50.0, 0.0)];
        let p = pools(100.0, 1e9);
        let both = negotiate_masked(&p, &demands, &[true, true]);
        assert!(both.iter().all(Option::is_some));
        assert!((both[1].unwrap().stretch - 1.5).abs() < 1e-9);
        // all-up masked == plain negotiate
        let plain = negotiate(&p, &demands);
        assert_eq!(both[0].unwrap(), plain.members[0]);
        let after = negotiate_masked(&p, &demands, &[false, true]);
        assert!(after[0].is_none(), "down member gets no grant");
        let survivor = after[1].unwrap();
        assert_eq!(survivor.stretch, 1.0);
        assert_eq!(survivor.granted, survivor.demand);
        // monotone: losing a contender never worsens a survivor's stretch
        assert!(survivor.stretch <= both[1].unwrap().stretch);
    }

    #[test]
    fn masked_negotiation_single_survivor_matches_single_member_degeneracy() {
        // survivor demand above the pool: solo rate is its baseline, so
        // masked negotiation must preserve the PR 4 lone-member rule
        let after = negotiate_masked(&pools(100.0, 16.0), &[d(1.0, 1.0), d(250.0, 40.0)], &[
            false, true,
        ]);
        assert_eq!(after[1].unwrap().stretch, 1.0);
    }

    #[test]
    fn ledger_json_carries_pools_members_and_throttle() {
        let l = negotiate(&pools(100.0, 16.0), &[d(100.0, 1.0), d(50.0, 1.0)]);
        let j = l.to_json();
        let dram = j.get("dram").unwrap();
        assert_eq!(dram.get("pool_gbps").unwrap().as_f64(), Some(100.0));
        assert_eq!(dram.get("demanded_gbps").unwrap().as_f64(), Some(150.0));
        assert!(j.get("throttled").unwrap().as_bool() == Some(true));
        let members = j.get("members").unwrap().as_arr().unwrap();
        assert_eq!(members.len(), 2);
        let t = members[0].get("throttle").unwrap().as_f64().unwrap();
        assert!((t - 1.0 / 1.5).abs() < 1e-9);
    }
}
