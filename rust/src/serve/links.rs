//! Shared memory-path contention for partitioned fleets.
//!
//! PR 4's `--partition` negotiated co-resident members against one
//! board's `Total_AIE` and Table V PL pools, but every member still drew
//! the board's full DRAM bandwidth and PCIe link for free — exactly the
//! overlay pitfall Vis-TOP warns about.  This module closes that gap:
//!
//! 1. each selected member's **demand** on the two shared pools is
//!    derived from its own *uncontended* service profile at the serving
//!    batch cap (bytes the deployment streams per virtual ns — weights +
//!    activations for DRAM, host I/O for PCIe);
//! 2. [`negotiate`] grants each member a **proportional share** of every
//!    oversubscribed pool (`granted_i = pool · demand_i / Σ demand`) and
//!    derives the member's service-time **stretch** — the ratio of its
//!    solo-link rate (`min(demand, pool)`: a member alone on the link is
//!    the PR 4 baseline, whatever its appetite) to its granted rate;
//! 3. the fleet redeploys every stretched member on a slice whose
//!    `mem_throttle = 1/stretch`, so the contended profile is
//!    **re-simulated** through the same DES the explorer used — the
//!    router's admission bounds then price contention automatically.
//!
//! Two negotiation modes share that machinery ([`NegotiationMode`]):
//!
//! * **Single-pass** (the default, PR 5 semantics): grants are computed
//!   from *uncontended* demand.  A member stretched by one pool keeps
//!   being charged for appetite it can no longer offer on the other
//!   pool, so the bound is conservative — never an under-throttle, but
//!   systematically pessimistic whenever the two pools couple.
//! * **Fixed point** (`--links-fixed-point`): iterate `demand → grant →
//!   stretch → re-derived demand`.  A throttled member's bytes-per-ns
//!   appetite shrinks by exactly its stretch, so the *offered* load on
//!   every pool is monotone non-increasing in the stretch vector; the
//!   freed bandwidth relaxes the split for the members that stay
//!   backlogged on that pool.
//!
//! # Convergence proof (fixed-point mode)
//!
//! Let `d_i^p` be member `i`'s demand on pool `p`, `s_i^p` its
//! single-pass per-pool stretch, and `S_i = max_p s_i^p` its overall
//! stretch.  One relaxation sweep re-derives member `i`'s pool-`p`
//! stretch from the split of *offered* loads: contender `j` offers
//! `d_j^p · min(1, s_j^p / S_j)` — its appetite shrunk by exactly the
//! stretch *in excess* of what pool `p` itself imposes (crediting a
//! pool for its own throttle would spiral into an under-throttle;
//! crediting only cross-pool excess returns exactly the bandwidth a
//! stretched member physically cannot offer).  Member `i`'s own
//! entitlement stays at its full appetite (its bytes still have to
//! move), and the new overall stretch is clamped:
//! `S_i ← min(S_i, max(1, max_p s'_i^p))`.
//!
//! The sweep map is antitone: lowering any `S_j` can only *raise* the
//! offered totals, hence raise every re-derived stretch.  Therefore the
//! clamped sequence is monotone non-increasing and bounded below by 1,
//! so it converges; concretely, sweep 1 applies the full relaxation
//! (credits computed at the single-pass vector) and sweep 2's
//! re-derived stretches can only come back *up* against the clamp, so
//! the iteration is stationary after exactly **two sweeps**.  The hard
//! cap [`FIXED_POINT_MAX_SWEEPS`] and the [`FIXED_POINT_EPS`]
//! convergence assertion guard that invariant rather than the
//! arithmetic.  By the clamp, `1 ≤ stretch_fixed_point ≤
//! stretch_single_pass` member-wise: the two modes bracket the true
//! arbitrated rate (fixed point from the optimistic side, single pass
//! from the conservative side — `rust/tests/link_calibration.rs`
//! replays a beat-level arbitration trace to check the bracket).
//! `granted` stays the single-pass proportional split in both modes —
//! it is a *feasible allocation* (Σ granted ≤ pool); the fixed point
//! relaxes the time-stretch bound, not the allocation.
//!
//! A 1-member partition is bit-identical to the uncontended deployment
//! by construction in both modes: its solo rate *is* its baseline, so
//! its stretch is exactly 1 and there is no contender to relax.

use std::collections::BTreeMap;

use crate::config::{ModelConfig, SharedLinkModel};
use crate::util::json::Json;

/// How stretches are derived from an oversubscribed split: the
/// conservative single pass (default) or the relaxed fixed point
/// (`--links-fixed-point`).  See the module docs for the bracket the
/// two modes form around the true arbitrated rate.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NegotiationMode {
    SinglePass,
    FixedPoint,
}

impl NegotiationMode {
    /// Stable wire name, used by the renegotiation trace args and the
    /// fixed-point ledger JSON.
    pub fn wire_name(self) -> &'static str {
        match self {
            NegotiationMode::SinglePass => "single_pass",
            NegotiationMode::FixedPoint => "fixed_point",
        }
    }
}

/// Hard cap on fixed-point relaxation sweeps.  The module-doc proof
/// shows the clamped iteration is stationary after two sweeps; the cap
/// exists so a violated invariant fails loudly instead of spinning.
pub const FIXED_POINT_MAX_SWEEPS: usize = 32;

/// Convergence epsilon for the fixed-point iteration: a sweep that
/// moves no member's stretch by more than this is stationary.
pub const FIXED_POINT_EPS: f64 = 1e-9;

/// One member's bandwidth appetite on the two shared pools (GB/s ==
/// bytes per virtual ns).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinkDemand {
    pub dram_gbps: f64,
    pub pcie_gbps: f64,
}

/// One member's negotiated outcome.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MemberLink {
    /// Uncontended appetite.
    pub demand: LinkDemand,
    /// Proportional share actually granted (always the single-pass
    /// split — a feasible allocation in both modes).
    pub granted: LinkDemand,
    /// Service-time stretch = solo-link rate / granted rate, ≥ 1.  The
    /// member's slice carries `mem_throttle = 1/stretch`.  In
    /// fixed-point mode this is the relaxed bound.
    pub stretch: f64,
    /// The conservative single-pass bound, kept alongside whatever
    /// `stretch` carries so the report can surface both ends of the
    /// bracket.  Equal to `stretch` in single-pass mode.
    pub stretch_single_pass: f64,
}

/// The board-level link ledger: pools, per-member grants, and the
/// aggregate demand — the `board.links` block of `cat-serve-v3`.
#[derive(Debug, Clone, PartialEq)]
pub struct LinkLedger {
    pub pools: SharedLinkModel,
    /// `members[i]` belongs to fleet position `i` (cost order).
    pub members: Vec<MemberLink>,
    /// Which negotiation derived the members' `stretch`.  Gates the
    /// dual-bound fields in [`LinkLedger::to_json`] so default output
    /// stays byte-identical to `cat-serve-v3`/`v4`.
    pub mode: NegotiationMode,
}

impl LinkLedger {
    /// Σ demanded bandwidth per pool.
    pub fn demanded(&self) -> LinkDemand {
        LinkDemand {
            dram_gbps: self.members.iter().map(|m| m.demand.dram_gbps).sum(),
            pcie_gbps: self.members.iter().map(|m| m.demand.pcie_gbps).sum(),
        }
    }

    /// Σ granted bandwidth per pool (never exceeds the pools).
    pub fn granted(&self) -> LinkDemand {
        LinkDemand {
            dram_gbps: self.members.iter().map(|m| m.granted.dram_gbps).sum(),
            pcie_gbps: self.members.iter().map(|m| m.granted.pcie_gbps).sum(),
        }
    }

    /// True when any member runs slower than it would alone.
    pub fn throttled(&self) -> bool {
        self.members.iter().any(|m| m.stretch > 1.0)
    }

    /// Board-level pessimism of the single-pass bound: the worst
    /// member-wise ratio `stretch_single_pass / stretch_fixed_point`,
    /// ≥ 1 by the clamp.  1.0 exactly when the two bounds coincide
    /// (no cross-pool coupling to relax) or the partition is empty;
    /// members whose bounds are both infinite (a demanded zero-width
    /// pool) contribute the neutral 1.0 — the breakage is already loud
    /// in their stretch.
    pub fn pessimism(&self) -> f64 {
        self.members
            .iter()
            .map(|m| {
                if m.stretch_single_pass == m.stretch {
                    1.0
                } else {
                    m.stretch_single_pass / m.stretch
                }
            })
            .fold(1.0, f64::max)
    }

    /// The `board.links` block: per-pool demanded vs granted bandwidth
    /// and the throttle factor per member.
    pub fn to_json(&self) -> Json {
        let demanded = self.demanded();
        let granted = self.granted();
        let pool = |total: f64, dem: f64, grant: f64| {
            let mut p = BTreeMap::new();
            p.insert("pool_gbps".into(), Json::Num(total));
            p.insert("demanded_gbps".into(), Json::Num(dem));
            p.insert("granted_gbps".into(), Json::Num(grant));
            // a zero-width pool with positive demand is infinitely
            // oversubscribed — report that (the serializer renders
            // non-finite as null), never a healthy-looking 0.0
            p.insert(
                "oversubscription".into(),
                Json::Num(if total > 0.0 {
                    dem / total
                } else if dem > 0.0 {
                    f64::INFINITY
                } else {
                    0.0
                }),
            );
            Json::Obj(p)
        };
        let mut m = BTreeMap::new();
        m.insert(
            "dram".into(),
            pool(self.pools.dram_gbps, demanded.dram_gbps, granted.dram_gbps),
        );
        m.insert(
            "pcie".into(),
            pool(self.pools.pcie_gbps, demanded.pcie_gbps, granted.pcie_gbps),
        );
        m.insert("throttled".into(), Json::Bool(self.throttled()));
        let fixed_point = self.mode == NegotiationMode::FixedPoint;
        if fixed_point {
            m.insert("mode".into(), Json::Str(self.mode.wire_name().into()));
            m.insert("pessimism".into(), Json::Num(self.pessimism()));
        }
        m.insert(
            "members".into(),
            Json::Arr(
                self.members
                    .iter()
                    .enumerate()
                    .map(|(i, ml)| {
                        let mut mm = BTreeMap::new();
                        mm.insert("backend".into(), Json::Num(i as f64));
                        mm.insert("dram_demand_gbps".into(), Json::Num(ml.demand.dram_gbps));
                        mm.insert("dram_granted_gbps".into(), Json::Num(ml.granted.dram_gbps));
                        mm.insert("pcie_demand_gbps".into(), Json::Num(ml.demand.pcie_gbps));
                        mm.insert("pcie_granted_gbps".into(), Json::Num(ml.granted.pcie_gbps));
                        mm.insert("stretch".into(), Json::Num(ml.stretch));
                        mm.insert("throttle".into(), Json::Num(1.0 / ml.stretch));
                        if fixed_point {
                            mm.insert(
                                "stretch_single_pass".into(),
                                Json::Num(ml.stretch_single_pass),
                            );
                            mm.insert("stretch_fixed_point".into(), Json::Num(ml.stretch));
                        }
                        Json::Obj(mm)
                    })
                    .collect(),
            ),
        );
        Json::Obj(m)
    }
}

/// Weight bytes one whole-model pass streams from DRAM: every layer's
/// QKV + Proj + FFN parameters ([`ModelConfig::layer_weight_bytes`], so
/// the model's own element width is honored).  BERT-Base int8: ~85 MB —
/// far beyond the 23.9 MB on-chip SRAM, so weights re-stream every
/// batch.
pub fn model_weight_bytes(m: &ModelConfig) -> u64 {
    m.layer_weight_bytes() as u64 * m.layers as u64
}

/// DRAM bytes one batch of `k` items moves: the streamed weights plus
/// activations in and out.
pub fn dram_bytes_per_batch(m: &ModelConfig, k: usize) -> u64 {
    model_weight_bytes(m) + pcie_bytes_per_batch(m, k)
}

/// PCIe bytes one batch of `k` items moves: input and output activations
/// crossing the host link at the model's element width (weights are
/// board-resident in DRAM after the one-time load, so they don't transit
/// PCIe per batch).
pub fn pcie_bytes_per_batch(m: &ModelConfig, k: usize) -> u64 {
    2 * (k * m.seq_len * m.embed_dim * m.bytes_per_elem()) as u64
}

/// One member's pool appetite from its uncontended service time for a
/// batch of `k` (`service_ns` from the member's profile): bytes per
/// virtual ns, i.e. GB/s.
pub fn demand_at(model: &ModelConfig, service_ns: u64, k: usize) -> LinkDemand {
    let t = service_ns.max(1) as f64;
    LinkDemand {
        dram_gbps: dram_bytes_per_batch(model, k) as f64 / t,
        pcie_gbps: pcie_bytes_per_batch(model, k) as f64 / t,
    }
}

/// Proportional share of one pool: `(granted, stretch)`.  Under-
/// subscribed pools grant every demand in full (stretch 1); an
/// oversubscribed pool splits proportionally, and the stretch compares
/// the grant against the member's *solo-link* rate (`min(demand, pool)`)
/// — a lone member owns the whole pool, so sharing is the only thing
/// this model ever charges for.
fn pool_share(demand: f64, total_demand: f64, pool: f64) -> (f64, f64) {
    if demand <= 0.0 || total_demand <= pool {
        return (demand, 1.0);
    }
    if pool <= 0.0 {
        // a demanded pool of zero width grants nothing; an infinite
        // stretch (not the NaN that 0/0 would give) makes the broken
        // configuration loud — the deploy path rejects a zero throttle
        // rather than silently serving at rate zero
        return (0.0, f64::INFINITY);
    }
    let granted = pool * demand / total_demand;
    let solo = demand.min(pool);
    (granted, (solo / granted).max(1.0))
}

/// Negotiate every member's grant against the shared pools.  The
/// member's overall stretch is the worst across pools — its slice is
/// throttled to the tightest link it transits.  Single-pass mode; the
/// fixed-point refinement is [`negotiate_fixed_point`], and
/// [`negotiate_in`] dispatches on [`NegotiationMode`].
pub fn negotiate(pools: &SharedLinkModel, demands: &[LinkDemand]) -> LinkLedger {
    let tot_dram: f64 = demands.iter().map(|d| d.dram_gbps).sum();
    let tot_pcie: f64 = demands.iter().map(|d| d.pcie_gbps).sum();
    let members = demands
        .iter()
        .map(|d| {
            let (g_dram, s_dram) = pool_share(d.dram_gbps, tot_dram, pools.dram_gbps);
            let (g_pcie, s_pcie) = pool_share(d.pcie_gbps, tot_pcie, pools.pcie_gbps);
            let stretch = s_dram.max(s_pcie);
            MemberLink {
                demand: *d,
                granted: LinkDemand { dram_gbps: g_dram, pcie_gbps: g_pcie },
                stretch,
                stretch_single_pass: stretch,
            }
        })
        .collect();
    LinkLedger { pools: *pools, members, mode: NegotiationMode::SinglePass }
}

/// [`negotiate`] or [`negotiate_fixed_point`] by mode.
pub fn negotiate_in(
    pools: &SharedLinkModel,
    demands: &[LinkDemand],
    mode: NegotiationMode,
) -> LinkLedger {
    match mode {
        NegotiationMode::SinglePass => negotiate(pools, demands),
        NegotiationMode::FixedPoint => negotiate_fixed_point(pools, demands),
    }
}

/// The fixed-point refinement of [`negotiate`]: iterate `demand →
/// grant → stretch → re-derived demand` with the clamped relaxation
/// sweep proved convergent in the module docs.  Grants stay the
/// single-pass split (a feasible allocation); only the stretch bound
/// relaxes, and `1 ≤ stretch ≤ stretch_single_pass` member-wise.
pub fn negotiate_fixed_point(pools: &SharedLinkModel, demands: &[LinkDemand]) -> LinkLedger {
    let mut ledger = negotiate(pools, demands);
    let n = demands.len();
    let tot_dram: f64 = demands.iter().map(|d| d.dram_gbps).sum();
    let tot_pcie: f64 = demands.iter().map(|d| d.pcie_gbps).sum();
    // single-pass per-pool stretches: the credits are frozen at this
    // vector (see the proof — crediting a pool for its own throttle
    // would spiral into an under-throttle)
    let per_pool: Vec<(f64, f64)> = demands
        .iter()
        .map(|d| {
            (
                pool_share(d.dram_gbps, tot_dram, pools.dram_gbps).1,
                pool_share(d.pcie_gbps, tot_pcie, pools.pcie_gbps).1,
            )
        })
        .collect();
    let mut overall: Vec<f64> = ledger.members.iter().map(|m| m.stretch).collect();
    // contender j's offered load on a pool: its appetite shrunk by
    // exactly the stretch in excess of what the pool itself imposes
    let offered = |d: f64, s_pool: f64, s_all: f64| {
        if s_pool.is_infinite() && s_all.is_infinite() {
            d
        } else {
            d * (s_pool / s_all).min(1.0)
        }
    };
    let mut sweeps = 0usize;
    loop {
        sweeps += 1;
        assert!(
            sweeps <= FIXED_POINT_MAX_SWEEPS,
            "fixed-point negotiation failed to converge in {FIXED_POINT_MAX_SWEEPS} sweeps"
        );
        let mut next = overall.clone();
        let mut changed = false;
        for i in 0..n {
            let (mut rel_dram, mut rel_pcie) = (demands[i].dram_gbps, demands[i].pcie_gbps);
            for j in 0..n {
                if j == i {
                    continue;
                }
                rel_dram += offered(demands[j].dram_gbps, per_pool[j].0, overall[j]);
                rel_pcie += offered(demands[j].pcie_gbps, per_pool[j].1, overall[j]);
            }
            let s_dram = pool_share(demands[i].dram_gbps, rel_dram, pools.dram_gbps).1;
            let s_pcie = pool_share(demands[i].pcie_gbps, rel_pcie, pools.pcie_gbps).1;
            let cand = s_dram.max(s_pcie).max(1.0).min(overall[i]);
            if overall[i] - cand > FIXED_POINT_EPS {
                changed = true;
            }
            next[i] = cand;
        }
        overall = next;
        if !changed {
            break;
        }
    }
    for (m, s) in ledger.members.iter_mut().zip(overall) {
        m.stretch = s;
    }
    ledger.mode = NegotiationMode::FixedPoint;
    ledger
}

/// [`negotiate_in`] over the `up` subset of a partition: down members
/// stop demanding bandwidth, so the survivors split the pools among
/// themselves — the failover path's graceful-degradation step.  Returns
/// one entry per original position (`None` for down members), so fleet
/// indices stay stable across the fault window.  With every member up
/// this is exactly [`negotiate_in`]; with one survivor it degenerates
/// to the PR 4 single-member case (stretch 1 whatever its appetite).
/// Every down/up renegotiation must pass the same mode the fleet was
/// selected under, so the fault path relaxes (or conserves) exactly
/// like the initial deployment did.
pub fn negotiate_masked(
    pools: &SharedLinkModel,
    demands: &[LinkDemand],
    up: &[bool],
    mode: NegotiationMode,
) -> Vec<Option<MemberLink>> {
    assert_eq!(demands.len(), up.len());
    let live: Vec<LinkDemand> =
        demands.iter().zip(up).filter(|(_, u)| **u).map(|(d, _)| *d).collect();
    let ledger = negotiate_in(pools, &live, mode);
    let mut granted = ledger.members.into_iter();
    up.iter().map(|u| if *u { granted.next() } else { None }).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pools(dram: f64, pcie: f64) -> SharedLinkModel {
        SharedLinkModel { dram_gbps: dram, pcie_gbps: pcie }
    }

    fn d(dram: f64, pcie: f64) -> LinkDemand {
        LinkDemand { dram_gbps: dram, pcie_gbps: pcie }
    }

    #[test]
    fn undersubscribed_pools_grant_in_full() {
        let l = negotiate(&pools(100.0, 16.0), &[d(40.0, 4.0), d(50.0, 6.0)]);
        assert!(!l.throttled());
        for m in &l.members {
            assert_eq!(m.granted, m.demand);
            assert_eq!(m.stretch, 1.0);
        }
    }

    #[test]
    fn single_member_never_throttles_whatever_its_appetite() {
        // the PR 4 degeneracy: a lone member owns the whole link — even
        // when its demand exceeds the pool, its solo rate IS its
        // baseline, so the stretch is exactly 1
        let l = negotiate(&pools(100.0, 16.0), &[d(250.0, 40.0)]);
        assert_eq!(l.members[0].stretch, 1.0);
        assert!(!l.throttled());
    }

    #[test]
    fn oversubscription_splits_proportionally_and_stretches() {
        // 150 demanded vs a 100 pool: grants 2:1, both stretched 1.5x
        let l = negotiate(&pools(100.0, 1e9), &[d(100.0, 0.0), d(50.0, 0.0)]);
        assert!(l.throttled());
        let (a, b) = (&l.members[0], &l.members[1]);
        assert!((a.granted.dram_gbps - 100.0 * 100.0 / 150.0).abs() < 1e-9);
        assert!((b.granted.dram_gbps - 100.0 * 50.0 / 150.0).abs() < 1e-9);
        assert!((a.stretch - 1.5).abs() < 1e-9);
        assert!((b.stretch - 1.5).abs() < 1e-9);
        // Σ granted saturates the pool exactly
        assert!((l.granted().dram_gbps - 100.0).abs() < 1e-9);
    }

    #[test]
    fn stretch_is_monotone_in_oversubscription() {
        let demands = [d(80.0, 0.0), d(80.0, 0.0)];
        let mut last = 0.0;
        for pool in [200.0, 120.0, 80.0, 40.0, 10.0] {
            let l = negotiate(&pools(pool, 1e9), &demands);
            let s = l.members[0].stretch;
            assert!(s >= last, "pool {pool}: stretch {s} < {last}");
            assert!(s >= 1.0);
            last = s;
        }
    }

    #[test]
    fn worst_pool_wins_the_stretch() {
        // DRAM is fine but PCIe is 4x oversubscribed — the member's
        // slice must throttle to the PCIe stretch
        let l = negotiate(&pools(1000.0, 8.0), &[d(10.0, 16.0), d(10.0, 16.0)]);
        for m in &l.members {
            assert!((m.stretch - 2.0).abs() < 1e-9, "stretch {}", m.stretch);
            assert_eq!(m.granted.dram_gbps, m.demand.dram_gbps);
        }
    }

    #[test]
    fn demand_scales_with_traffic_and_inversely_with_service_time() {
        let m = ModelConfig::bert_base();
        let fast = demand_at(&m, 1_000_000, 8);
        let slow = demand_at(&m, 2_000_000, 8);
        assert!((fast.dram_gbps - 2.0 * slow.dram_gbps).abs() < 1e-9);
        assert!(fast.dram_gbps > fast.pcie_gbps, "weights dominate DRAM traffic");
        // BERT-Base weights ~= 85 MB int8
        let wb = model_weight_bytes(&m) as f64 / (1024.0 * 1024.0);
        assert!((70.0..100.0).contains(&wb), "{wb} MB");
    }

    #[test]
    fn zero_width_demanded_pool_is_loud_not_silently_uncontended() {
        // pool 0 with positive demand must NOT round-trip to a NaN that
        // masks as "stretch 1.0"; it grants nothing and stretches
        // infinitely, which the deploy path rejects as throttle 0
        let l = negotiate(&pools(0.0, 16.0), &[d(10.0, 1.0), d(10.0, 1.0)]);
        for m in &l.members {
            assert_eq!(m.granted.dram_gbps, 0.0);
            assert!(m.stretch.is_infinite());
        }
        assert!(l.throttled());
    }

    #[test]
    fn masked_negotiation_relaxes_survivors() {
        // both up: 150 vs the 100 pool stretches both 1.5x; kill the
        // heavy member and the survivor (demand 50 < pool 100) runs
        // uncontended — stretch drops to exactly 1
        let demands = [d(100.0, 0.0), d(50.0, 0.0)];
        let p = pools(100.0, 1e9);
        let both = negotiate_masked(&p, &demands, &[true, true], NegotiationMode::SinglePass);
        assert!(both.iter().all(Option::is_some));
        assert!((both[1].unwrap().stretch - 1.5).abs() < 1e-9);
        // all-up masked == plain negotiate
        let plain = negotiate(&p, &demands);
        assert_eq!(both[0].unwrap(), plain.members[0]);
        let after = negotiate_masked(&p, &demands, &[false, true], NegotiationMode::SinglePass);
        assert!(after[0].is_none(), "down member gets no grant");
        let survivor = after[1].unwrap();
        assert_eq!(survivor.stretch, 1.0);
        assert_eq!(survivor.granted, survivor.demand);
        // monotone: losing a contender never worsens a survivor's stretch
        assert!(survivor.stretch <= both[1].unwrap().stretch);
    }

    #[test]
    fn masked_negotiation_single_survivor_matches_single_member_degeneracy() {
        // survivor demand above the pool: solo rate is its baseline, so
        // masked negotiation must preserve the PR 4 lone-member rule
        let after = negotiate_masked(
            &pools(100.0, 16.0),
            &[d(1.0, 1.0), d(250.0, 40.0)],
            &[false, true],
            NegotiationMode::SinglePass,
        );
        assert_eq!(after[1].unwrap().stretch, 1.0);
    }

    #[test]
    fn fixed_point_never_exceeds_single_pass_and_never_dips_below_one() {
        let scenarios: [(SharedLinkModel, Vec<LinkDemand>); 4] = [
            (pools(100.0, 4.0), vec![d(40.0, 6.0), d(80.0, 1.0)]),
            (pools(100.0, 8.0), vec![d(80.0, 6.0), d(80.0, 10.0)]),
            (pools(100.0, 1e9), vec![d(100.0, 0.0), d(50.0, 0.0)]),
            (pools(50.0, 2.0), vec![d(30.0, 1.5), d(30.0, 0.2), d(15.0, 0.9)]),
        ];
        for (p, ds) in &scenarios {
            let sp = negotiate(p, ds);
            let fp = negotiate_fixed_point(p, ds);
            assert_eq!(fp.mode, NegotiationMode::FixedPoint);
            for (a, b) in fp.members.iter().zip(&sp.members) {
                assert!(a.stretch >= 1.0, "fp stretch {} < 1", a.stretch);
                assert!(a.stretch <= b.stretch + 1e-12, "fp {} > sp {}", a.stretch, b.stretch);
                assert_eq!(a.stretch_single_pass, b.stretch, "sp bound must be carried");
                assert_eq!(a.granted, b.granted, "grants stay the single-pass split");
            }
            assert!(fp.pessimism() >= 1.0);
        }
    }

    #[test]
    fn fixed_point_strictly_relaxes_a_cross_pool_coupled_partition() {
        // A is PCIe-bound (stretch 1.75 > its DRAM share's 1.2), so its
        // DRAM appetite shrinks by the excess and B's DRAM split
        // relaxes strictly; symmetrically B's PCIe excess relaxes A
        let p = pools(100.0, 4.0);
        let ds = [d(40.0, 6.0), d(80.0, 1.0)];
        let sp = negotiate(&p, &ds);
        let fp = negotiate_fixed_point(&p, &ds);
        assert!(sp.throttled() && fp.throttled());
        for (a, b) in fp.members.iter().zip(&sp.members) {
            assert!(
                a.stretch < b.stretch - 1e-6,
                "expected strict relaxation, fp {} vs sp {}",
                a.stretch,
                b.stretch
            );
        }
        assert!(fp.pessimism() > 1.0 + 1e-6);
    }

    #[test]
    fn fixed_point_matches_single_pass_without_cross_pool_coupling() {
        // pure single-pool contention: every member's binding pool is
        // its own, no excess stretch to credit, the bounds coincide
        let p = pools(100.0, 1e9);
        let ds = [d(80.0, 0.0), d(80.0, 0.0)];
        let sp = negotiate(&p, &ds);
        let fp = negotiate_fixed_point(&p, &ds);
        for (a, b) in fp.members.iter().zip(&sp.members) {
            assert!((a.stretch - b.stretch).abs() < 1e-12);
        }
        assert!((fp.pessimism() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn fixed_point_single_member_stays_stretch_one() {
        let fp = negotiate_fixed_point(&pools(100.0, 16.0), &[d(250.0, 40.0)]);
        assert_eq!(fp.members[0].stretch, 1.0);
        assert_eq!(fp.members[0].stretch_single_pass, 1.0);
        assert!((fp.pessimism() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn fixed_point_zero_width_pool_stays_loud() {
        let fp = negotiate_fixed_point(&pools(0.0, 16.0), &[d(10.0, 1.0), d(10.0, 1.0)]);
        for m in &fp.members {
            assert!(m.stretch.is_infinite());
            assert!(m.stretch_single_pass.is_infinite());
        }
        assert!((fp.pessimism() - 1.0).abs() < 1e-12, "inf/inf bounds are neutral");
    }

    #[test]
    fn masked_fixed_point_uses_the_same_relaxation() {
        let p = pools(100.0, 4.0);
        let ds = [d(40.0, 6.0), d(80.0, 1.0)];
        let all_up = negotiate_masked(&p, &ds, &[true, true], NegotiationMode::FixedPoint);
        let plain = negotiate_fixed_point(&p, &ds);
        assert_eq!(all_up[0].unwrap(), plain.members[0]);
        assert_eq!(all_up[1].unwrap(), plain.members[1]);
        // a lone survivor owns the links in either mode
        let after = negotiate_masked(&p, &ds, &[false, true], NegotiationMode::FixedPoint);
        assert!(after[0].is_none());
        assert_eq!(after[1].unwrap().stretch, 1.0);
    }

    #[test]
    fn zero_width_pool_oversubscription_reports_the_true_signal() {
        // the bug: pool 0 with positive demand used to serialize
        // oversubscription 0.0 — healthy-looking JSON around members
        // carrying infinite stretch
        let l = negotiate(&pools(0.0, 16.0), &[d(10.0, 1.0), d(10.0, 1.0)]);
        let j = l.to_json();
        let over = j.get("dram").unwrap().get("oversubscription").unwrap();
        assert_eq!(over.as_f64(), Some(f64::INFINITY));
        let s = j.to_string();
        assert!(
            s.contains("\"oversubscription\":null"),
            "non-finite oversubscription must serialize as null: {s}"
        );
        assert!(!s.contains("inf"), "bare inf is invalid JSON: {s}");
        // idle zero-width pool (no demand) is genuinely 0.0
        let idle = negotiate(&pools(0.0, 16.0), &[d(0.0, 1.0)]);
        let j = idle.to_json();
        assert_eq!(
            j.get("dram").unwrap().get("oversubscription").unwrap().as_f64(),
            Some(0.0)
        );
    }

    #[test]
    fn fixed_point_ledger_json_carries_both_bounds_and_pessimism() {
        let fp = negotiate_fixed_point(&pools(100.0, 4.0), &[d(40.0, 6.0), d(80.0, 1.0)]);
        let j = fp.to_json();
        assert_eq!(j.get("mode").unwrap().as_str(), Some("fixed_point"));
        let pess = j.get("pessimism").unwrap().as_f64().unwrap();
        assert!(pess > 1.0);
        let members = j.get("members").unwrap().as_arr().unwrap();
        for m in members {
            let sp = m.get("stretch_single_pass").unwrap().as_f64().unwrap();
            let fpv = m.get("stretch_fixed_point").unwrap().as_f64().unwrap();
            assert!(fpv <= sp);
            assert_eq!(m.get("stretch").unwrap().as_f64(), Some(fpv));
        }
        // the default ledger stays free of every dual-bound field, so
        // cat-serve-v3/v4 output is byte-identical with the flag off
        let sp = negotiate(&pools(100.0, 4.0), &[d(40.0, 6.0), d(80.0, 1.0)]);
        let s = sp.to_json().to_string();
        assert!(!s.contains("stretch_single_pass"));
        assert!(!s.contains("pessimism"));
        assert!(!s.contains("\"mode\""));
    }

    #[test]
    fn ledger_json_carries_pools_members_and_throttle() {
        let l = negotiate(&pools(100.0, 16.0), &[d(100.0, 1.0), d(50.0, 1.0)]);
        let j = l.to_json();
        let dram = j.get("dram").unwrap();
        assert_eq!(dram.get("pool_gbps").unwrap().as_f64(), Some(100.0));
        assert_eq!(dram.get("demanded_gbps").unwrap().as_f64(), Some(150.0));
        assert!(j.get("throttled").unwrap().as_bool() == Some(true));
        let members = j.get("members").unwrap().as_arr().unwrap();
        assert_eq!(members.len(), 2);
        let t = members[0].get("throttle").unwrap().as_f64().unwrap();
        assert!((t - 1.0 / 1.5).abs() < 1e-9);
    }
}
