//! Deterministic fault injection for the fleet-serving loop.
//!
//! A [`FaultSchedule`] is a sorted list of virtual-clock events the
//! serving loop applies while it drives the request stream:
//!
//! * **crash** — the backend dies at `at`: its forming batch and every
//!   in-flight batch are orphaned and re-admitted on the survivors, and
//!   the backend is excluded from admission until `at + down`
//!   (omitting `down_ms` means it never comes back);
//! * **stall** — the backend freezes for the window: nothing is lost,
//!   every queued completion shifts by the window, and batches whose
//!   riders can no longer meet their deadlines are orphaned instead of
//!   served late;
//! * **slowdown** — the backend stays up but batches *dispatched* inside
//!   the window take `factor`× their simulated service time (admission
//!   prices the stretched worst case, so completed requests still meet
//!   the SLO);
//! * **link_degrade** — the shared link pools scale down from `at` on:
//!   the board's DRAM/PCIe pools for a partitioned fleet with the link
//!   model, the rack's switch/NIC pools for a cluster (the spec may use
//!   either vocabulary — `dram_scale`/`pcie_scale` or the rack aliases
//!   `switch_scale`/`nic_scale`); the loop re-negotiates every member's
//!   grant against the shrunken pools and redeploys changed members;
//! * **board_crash** — every backend on one cluster board dies at once
//!   (`--cluster` only): expanded into per-member crashes before the
//!   loop ([`expand_boards`]), so drain/re-admit/renegotiate handle a
//!   whole-board outage exactly like N simultaneous backend crashes.
//!
//! Schedules come from a `--faults <spec.json>` file or are generated
//! from `--mtbf-s`/`--mttr-s` by [`FaultSchedule::random`] — seeded and
//! virtual-clock, so every fault run is exactly reproducible.

use std::collections::BTreeMap;

use crate::util::json::Json;
use crate::util::prng::Prng;
use anyhow::{anyhow, Result};

/// Down-time cap (virtual ns): far beyond any experiment horizon, but
/// low enough that `busy_until + service` arithmetic can never overflow
/// (the serving loop clamps its cursor to `u64::MAX / 2`).
pub const DOWN_CAP_NS: u64 = u64::MAX / 4;

/// What a fault event does to the fleet.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultKind {
    /// The backend dies: queued/in-flight work is orphaned and
    /// re-admitted on survivors; down for `down_ns` (saturating —
    /// `DOWN_CAP_NS` means it never recovers).
    Crash { backend: usize, down_ns: u64 },
    /// The backend freezes for `down_ns`: nothing is lost, completions
    /// shift by the window, deadline-violating batches are orphaned.
    Stall { backend: usize, down_ns: u64 },
    /// Batches dispatched during the window serve `factor`× slower.
    Slowdown { backend: usize, down_ns: u64, factor: f64 },
    /// The shared link pools scale to `dram_scale`/`pcie_scale` of their
    /// current width from this point on.  Needs pools to exist: a
    /// partitioned fleet with the link model (board DRAM/PCIe), or a
    /// cluster — where the scales bite the rack's net pools instead
    /// (`dram_scale` scales the switch pool, `pcie_scale` the NIC pool;
    /// specs may write `switch_scale`/`nic_scale` directly) and every
    /// board redeploys through the masked renegotiation path.
    LinkDegrade { dram_scale: f64, pcie_scale: f64 },
    /// Every backend on cluster board `board` crashes at once
    /// (`--cluster` only).  Never reaches the serving loop: it is
    /// expanded into per-member [`FaultKind::Crash`] events first
    /// ([`expand_boards`]).
    BoardCrash { board: usize, down_ns: u64 },
}

impl FaultKind {
    pub fn name(&self) -> &'static str {
        match self {
            FaultKind::Crash { .. } => "crash",
            FaultKind::Stall { .. } => "stall",
            FaultKind::Slowdown { .. } => "slowdown",
            FaultKind::LinkDegrade { .. } => "link_degrade",
            FaultKind::BoardCrash { .. } => "board_crash",
        }
    }

    /// The backend a fault targets (`None` for fleet-wide events).
    pub fn backend(&self) -> Option<usize> {
        match self {
            FaultKind::Crash { backend, .. }
            | FaultKind::Stall { backend, .. }
            | FaultKind::Slowdown { backend, .. } => Some(*backend),
            FaultKind::LinkDegrade { .. } | FaultKind::BoardCrash { .. } => None,
        }
    }

    /// Args for the fault's trace instant — the same fields
    /// [`FaultEvent::to_json`] reports, minus `applied` (a traced fault
    /// was applied by construction).
    pub fn trace_args(&self) -> Vec<(String, Json)> {
        let mut args = Vec::new();
        if let Some(b) = self.backend() {
            args.push(("backend".to_string(), Json::Num(b as f64)));
        }
        match *self {
            FaultKind::Crash { down_ns, .. } | FaultKind::Stall { down_ns, .. } => {
                let ms = down_ns.min(DOWN_CAP_NS) as f64 / 1e6;
                args.push(("down_ms".to_string(), Json::Num(ms)));
            }
            FaultKind::Slowdown { down_ns, factor, .. } => {
                args.push(("down_ms".to_string(), Json::Num(down_ns as f64 / 1e6)));
                args.push(("factor".to_string(), Json::Num(factor)));
            }
            FaultKind::LinkDegrade { dram_scale, pcie_scale } => {
                args.push(("dram_scale".to_string(), Json::Num(dram_scale)));
                args.push(("pcie_scale".to_string(), Json::Num(pcie_scale)));
            }
            FaultKind::BoardCrash { board, down_ns } => {
                args.push(("board".to_string(), Json::Num(board as f64)));
                let ms = down_ns.min(DOWN_CAP_NS) as f64 / 1e6;
                args.push(("down_ms".to_string(), Json::Num(ms)));
            }
        }
        args
    }
}

/// One scheduled fault at a virtual timestamp.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultEvent {
    pub at_ns: u64,
    pub kind: FaultKind,
}

impl FaultEvent {
    /// One `faults.timeline` entry (`applied` = whether the loop reached
    /// this event before the stream drained).
    pub fn to_json(&self, applied: bool) -> Json {
        let mut m = BTreeMap::new();
        m.insert("at_ms".into(), Json::Num(self.at_ns as f64 / 1e6));
        m.insert("kind".into(), Json::Str(self.kind.name().into()));
        if let Some(b) = self.kind.backend() {
            m.insert("backend".into(), Json::Num(b as f64));
        }
        match self.kind {
            FaultKind::Crash { down_ns, .. } | FaultKind::Stall { down_ns, .. } => {
                m.insert("down_ms".into(), Json::Num(down_ns.min(DOWN_CAP_NS) as f64 / 1e6));
            }
            FaultKind::Slowdown { down_ns, factor, .. } => {
                m.insert("down_ms".into(), Json::Num(down_ns as f64 / 1e6));
                m.insert("factor".into(), Json::Num(factor));
            }
            FaultKind::LinkDegrade { dram_scale, pcie_scale } => {
                m.insert("dram_scale".into(), Json::Num(dram_scale));
                m.insert("pcie_scale".into(), Json::Num(pcie_scale));
            }
            FaultKind::BoardCrash { board, down_ns } => {
                m.insert("board".into(), Json::Num(board as f64));
                m.insert("down_ms".into(), Json::Num(down_ns.min(DOWN_CAP_NS) as f64 / 1e6));
            }
        }
        m.insert("applied".into(), Json::Bool(applied));
        Json::Obj(m)
    }
}

/// A sorted, validated fault timeline.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultSchedule {
    pub events: Vec<FaultEvent>,
}

/// How the serving loop obtains its fault timeline.
#[derive(Debug, Clone, PartialEq)]
pub enum FaultPolicy {
    /// An explicit schedule (`--faults <spec.json>`).
    Schedule(FaultSchedule),
    /// Seeded random faults (`--mtbf-s`/`--mttr-s`): exponential
    /// inter-fault gaps with mean `mtbf_s` and repair windows with mean
    /// `mttr_s`, resolved into a [`FaultSchedule`] at serve time (the
    /// generator needs the fleet size and the arrival horizon).
    Random { mtbf_s: f64, mttr_s: f64 },
}

fn ns_of_ms(ms: f64) -> u64 {
    (ms * 1e6).round() as u64
}

impl FaultSchedule {
    /// Parse a `--faults` spec: either a bare array of event objects or
    /// `{"events": [...]}`.  Each event carries `at_ms`, `kind`, and the
    /// kind's own fields:
    ///
    /// ```json
    /// {"events": [
    ///   {"at_ms": 40, "kind": "crash", "backend": 0, "down_ms": 200},
    ///   {"at_ms": 60, "kind": "stall", "backend": 1, "down_ms": 5},
    ///   {"at_ms": 80, "kind": "slowdown", "backend": 1, "down_ms": 10, "factor": 1.5},
    ///   {"at_ms": 90, "kind": "link_degrade", "dram_scale": 0.5, "pcie_scale": 1.0},
    ///   {"at_ms": 95, "kind": "link_degrade", "switch_scale": 0.5, "nic_scale": 0.75}
    /// ]}
    /// ```
    ///
    /// A crash without `down_ms` never recovers.  `link_degrade` accepts
    /// rack vocabulary as aliases for its two pool slots —
    /// `switch_scale` for `dram_scale`, `nic_scale` for `pcie_scale` —
    /// so cluster specs read naturally; giving both names of one slot is
    /// an error.  Backend indices are checked against the actual fleet
    /// later ([`FaultSchedule::validate`], the fleet size is unknown at
    /// parse time).
    pub fn from_json(j: &Json) -> Result<FaultSchedule> {
        let arr = j
            .as_arr()
            .or_else(|| j.get("events").and_then(Json::as_arr))
            .ok_or_else(|| {
                anyhow!("fault spec must be an array of events or {{\"events\": [...]}}")
            })?;
        let mut events = Vec::with_capacity(arr.len());
        for (i, e) in arr.iter().enumerate() {
            let ctx = |msg: String| anyhow!("fault event #{i}: {msg}");
            let num = |key: &str| -> Result<f64> {
                e.get(key)
                    .and_then(Json::as_f64)
                    .filter(|v| v.is_finite())
                    .ok_or_else(|| ctx(format!("'{key}' must be a finite number")))
            };
            let at_ms = num("at_ms")?;
            if at_ms < 0.0 {
                return Err(ctx(format!("'at_ms' must be >= 0, got {at_ms}")));
            }
            let backend = || -> Result<usize> {
                e.get("backend")
                    .and_then(Json::as_usize)
                    .ok_or_else(|| ctx("'backend' must be a non-negative integer".into()))
            };
            let down_ns = |required: bool| -> Result<u64> {
                match e.get("down_ms") {
                    None if required => Err(ctx("'down_ms' is required for this kind".into())),
                    None => Ok(DOWN_CAP_NS),
                    Some(_) => {
                        let ms = num("down_ms")?;
                        if ms <= 0.0 {
                            return Err(ctx(format!("'down_ms' must be positive, got {ms}")));
                        }
                        Ok(ns_of_ms(ms).min(DOWN_CAP_NS))
                    }
                }
            };
            let scale = |key: &str| -> Result<f64> {
                let v = num(key)?;
                if !(v > 0.0 && v <= 1.0) {
                    return Err(ctx(format!("'{key}' must be in (0, 1], got {v}")));
                }
                Ok(v)
            };
            let kind = match e.get("kind").and_then(Json::as_str) {
                Some("crash") => FaultKind::Crash { backend: backend()?, down_ns: down_ns(false)? },
                Some("stall") => FaultKind::Stall { backend: backend()?, down_ns: down_ns(true)? },
                Some("slowdown") => {
                    let factor = num("factor")?;
                    if factor < 1.0 {
                        return Err(ctx(format!("'factor' must be >= 1, got {factor}")));
                    }
                    FaultKind::Slowdown { backend: backend()?, down_ns: down_ns(true)?, factor }
                }
                Some("link_degrade") => {
                    // two vocabularies for the same two pool slots: a
                    // partitioned board names its memory path
                    // (dram/pcie); a cluster names the rack fabric the
                    // net pools map onto (switch -> the dram slot,
                    // nic -> the pcie slot).  One name per slot.
                    let aliased = |board: &str, rack: &str| -> Result<f64> {
                        match (e.get(board).is_some(), e.get(rack).is_some()) {
                            (true, true) => Err(ctx(format!(
                                "'{board}' and '{rack}' name the same pool — give exactly one"
                            ))),
                            (false, true) => scale(rack),
                            _ => scale(board),
                        }
                    };
                    FaultKind::LinkDegrade {
                        dram_scale: aliased("dram_scale", "switch_scale")?,
                        pcie_scale: aliased("pcie_scale", "nic_scale")?,
                    }
                }
                Some("board_crash") => {
                    let board = e
                        .get("board")
                        .and_then(Json::as_usize)
                        .ok_or_else(|| ctx("'board' must be a non-negative integer".into()))?;
                    FaultKind::BoardCrash { board, down_ns: down_ns(false)? }
                }
                other => {
                    return Err(ctx(format!(
                        "'kind' must be crash|stall|slowdown|link_degrade|board_crash, got \
                         {other:?}"
                    )))
                }
            };
            events.push(FaultEvent { at_ns: ns_of_ms(at_ms), kind });
        }
        let mut s = FaultSchedule { events };
        s.sort();
        Ok(s)
    }

    /// Generate a seeded random schedule: exponential inter-fault gaps
    /// (mean `mtbf_s` virtual seconds) up to `horizon_ns`, each fault a
    /// uniformly chosen crash/stall/slowdown on a uniformly chosen
    /// backend with an exponential repair window (mean `mttr_s`).  Only
    /// backend faults are generated — link degradation needs the
    /// partitioned link model, which random schedules cannot assume.
    pub fn random(
        seed: u64,
        mtbf_s: f64,
        mttr_s: f64,
        n_backends: usize,
        horizon_ns: u64,
    ) -> FaultSchedule {
        assert!(mtbf_s > 0.0 && mttr_s > 0.0, "MTBF/MTTR must be positive");
        assert!(n_backends > 0, "need a fleet to fault");
        let mut rng = Prng::new(seed);
        let mut exp_ns = |mean_s: f64| -> u64 {
            let gap_s = -(1.0 - rng.f64()).ln() * mean_s;
            (gap_s * 1e9).round().min(DOWN_CAP_NS as f64) as u64
        };
        let mut events = Vec::new();
        let mut t_ns = 0u64;
        loop {
            t_ns = t_ns.saturating_add(exp_ns(mtbf_s));
            if t_ns >= horizon_ns {
                break;
            }
            let backend = rng.below(n_backends as u64) as usize;
            let down_ns = exp_ns(mttr_s).max(1);
            let kind = match rng.below(3) {
                0 => FaultKind::Crash { backend, down_ns },
                1 => FaultKind::Stall { backend, down_ns },
                _ => {
                    // a stretch in [1.25, 2.0): strong enough to perturb
                    // admission, bounded so dispatch pricing stays sane
                    let factor = 1.25 + 0.75 * rng.f64();
                    FaultKind::Slowdown { backend, down_ns, factor }
                }
            };
            events.push(FaultEvent { at_ns: t_ns, kind });
        }
        FaultSchedule { events }
    }

    /// Stable sort by timestamp (equal-time events keep spec order).
    pub fn sort(&mut self) {
        self.events.sort_by_key(|e| e.at_ns);
    }

    /// Validate against the actual fleet: backend indices in range, link
    /// degradation only when the fleet carries a link ledger, and board
    /// crashes only when there IS a board dimension (`n_boards` =
    /// cluster size, `None` outside `--cluster`).
    pub fn validate(
        &self,
        n_backends: usize,
        has_links: bool,
        n_boards: Option<usize>,
    ) -> Result<()> {
        for (i, e) in self.events.iter().enumerate() {
            match e.kind {
                FaultKind::Crash { backend, .. }
                | FaultKind::Stall { backend, .. }
                | FaultKind::Slowdown { backend, .. } => {
                    if backend >= n_backends {
                        return Err(anyhow!(
                            "fault event #{i} targets backend {backend}, but the fleet has \
                             only {n_backends} backend(s)"
                        ));
                    }
                }
                FaultKind::LinkDegrade { .. } => {
                    if !has_links {
                        return Err(anyhow!(
                            "fault event #{i} is a link_degrade, which needs shared link pools: \
                             --partition with the link model enabled (board DRAM/PCIe) or \
                             --cluster (rack NIC/switch) — the pools don't exist otherwise"
                        ));
                    }
                }
                FaultKind::BoardCrash { board, .. } => match n_boards {
                    None => {
                        return Err(anyhow!(
                            "fault event #{i} is a board_crash, which needs --cluster (there \
                             is no board dimension otherwise)"
                        ))
                    }
                    Some(n) if board >= n => {
                        return Err(anyhow!(
                            "fault event #{i} targets board {board}, but the cluster has only \
                             {n} board(s)"
                        ))
                    }
                    Some(_) => {}
                },
            }
        }
        Ok(())
    }
}

/// Expand every board crash into one member crash per backend living on
/// that board (`member_board[m]` = the board of fleet position `m`), at
/// the same instant, in fleet order — so routing, draining, recovery,
/// and the report see only ordinary per-backend events.  Everything else
/// passes through; the result is re-sorted (stable, so equal-time spec
/// order survives).
pub fn expand_boards(events: &[FaultEvent], member_board: &[usize]) -> Vec<FaultEvent> {
    let mut out = Vec::with_capacity(events.len());
    for e in events {
        match e.kind {
            FaultKind::BoardCrash { board, down_ns } => {
                for (m, &bj) in member_board.iter().enumerate() {
                    if bj == board {
                        let kind = FaultKind::Crash { backend: m, down_ns };
                        out.push(FaultEvent { at_ns: e.at_ns, kind });
                    }
                }
            }
            _ => out.push(*e),
        }
    }
    out.sort_by_key(|e| e.at_ns);
    out
}

/// Per-backend fault accounting for the report.
#[derive(Debug, Clone, Copy, Default)]
pub struct BackendFaultStats {
    /// Crash/stall windows that hit this backend.
    pub downs: usize,
    /// Total downtime, clamped to the experiment wall (virtual ns).
    pub down_ns: u64,
    /// Riders orphaned off this backend (drained for re-admission).
    pub requeued: usize,
}

/// The `faults` block of the `cat-serve-v4` schema.
#[derive(Debug, Clone, Default)]
pub struct FaultsReport {
    /// Every scheduled event, with whether the loop applied it (events
    /// past the end of all serving work are reported but not applied).
    pub timeline: Vec<(FaultEvent, bool)>,
    /// `backends[i]` belongs to fleet position `i`.
    pub backends: Vec<BackendFaultStats>,
    /// Riders orphaned by faults (forming + in-flight drains).
    pub requeued: usize,
    /// Orphaned riders successfully re-admitted on a survivor.
    pub retried: usize,
    /// p99 latency over responses completing inside an applied fault
    /// window (crash/stall/slowdown), ms; 0 when no response did.
    pub degraded_p99_ms: f64,
    /// Link re-negotiations: `(at_ns, stretch per member)` — `None` for
    /// members that were down at that point.
    pub renegotiations: Vec<(u64, Vec<Option<f64>>)>,
}

impl FaultsReport {
    pub fn to_json(&self, wall_ns: u64) -> Json {
        let mut m = BTreeMap::new();
        m.insert(
            "timeline".into(),
            Json::Arr(self.timeline.iter().map(|(e, ap)| e.to_json(*ap)).collect()),
        );
        m.insert(
            "injected".into(),
            Json::Num(self.timeline.iter().filter(|(_, ap)| *ap).count() as f64),
        );
        m.insert(
            "backends".into(),
            Json::Arr(
                self.backends
                    .iter()
                    .enumerate()
                    .map(|(i, b)| {
                        let mut bm = BTreeMap::new();
                        bm.insert("id".into(), Json::Num(i as f64));
                        bm.insert("downs".into(), Json::Num(b.downs as f64));
                        bm.insert("down_ms".into(), Json::Num(b.down_ns as f64 / 1e6));
                        let avail = if wall_ns == 0 {
                            1.0
                        } else {
                            (wall_ns - b.down_ns) as f64 / wall_ns as f64
                        };
                        bm.insert("availability".into(), Json::Num(avail));
                        bm.insert("requeued".into(), Json::Num(b.requeued as f64));
                        Json::Obj(bm)
                    })
                    .collect(),
            ),
        );
        m.insert("requeued".into(), Json::Num(self.requeued as f64));
        m.insert("retried".into(), Json::Num(self.retried as f64));
        m.insert("degraded_p99_ms".into(), Json::Num(self.degraded_p99_ms));
        m.insert(
            "link_renegotiations".into(),
            Json::Arr(
                self.renegotiations
                    .iter()
                    .map(|(at, stretches)| {
                        let mut rm = BTreeMap::new();
                        rm.insert("at_ms".into(), Json::Num(*at as f64 / 1e6));
                        rm.insert(
                            "stretches".into(),
                            Json::Arr(
                                stretches
                                    .iter()
                                    .map(|s| s.map(Json::Num).unwrap_or(Json::Null))
                                    .collect(),
                            ),
                        );
                        Json::Obj(rm)
                    })
                    .collect(),
            ),
        );
        Json::Obj(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(src: &str) -> Result<FaultSchedule> {
        FaultSchedule::from_json(&Json::parse(src).unwrap())
    }

    #[test]
    fn parses_every_kind_and_sorts_by_time() {
        let s = parse(
            r#"{"events": [
                {"at_ms": 80, "kind": "slowdown", "backend": 1, "down_ms": 10, "factor": 1.5},
                {"at_ms": 40, "kind": "crash", "backend": 0, "down_ms": 200},
                {"at_ms": 60, "kind": "stall", "backend": 1, "down_ms": 5},
                {"at_ms": 90, "kind": "link_degrade", "dram_scale": 0.5, "pcie_scale": 1.0}
            ]}"#,
        )
        .unwrap();
        assert_eq!(s.events.len(), 4);
        assert!(s.events.windows(2).all(|w| w[0].at_ns <= w[1].at_ns));
        assert_eq!(s.events[0].kind, FaultKind::Crash { backend: 0, down_ns: 200_000_000 });
        assert_eq!(s.events[0].at_ns, 40_000_000);
        match s.events[3].kind {
            FaultKind::LinkDegrade { dram_scale, pcie_scale } => {
                assert_eq!(dram_scale, 0.5);
                assert_eq!(pcie_scale, 1.0);
            }
            other => panic!("expected link_degrade, got {other:?}"),
        }
        // a bare array parses identically
        let bare = parse(r#"[{"at_ms": 1, "kind": "crash", "backend": 2}]"#).unwrap();
        assert_eq!(bare.events[0].kind, FaultKind::Crash { backend: 2, down_ns: DOWN_CAP_NS });
    }

    #[test]
    fn rejects_malformed_events() {
        assert!(parse(r#"{"no_events": 1}"#).is_err());
        assert!(parse(r#"[{"kind": "crash", "backend": 0}]"#).is_err(), "missing at_ms");
        assert!(parse(r#"[{"at_ms": -1, "kind": "crash", "backend": 0}]"#).is_err());
        assert!(parse(r#"[{"at_ms": 1, "kind": "meteor", "backend": 0}]"#).is_err());
        assert!(parse(r#"[{"at_ms": 1, "kind": "crash"}]"#).is_err(), "missing backend");
        assert!(
            parse(r#"[{"at_ms": 1, "kind": "stall", "backend": 0}]"#).is_err(),
            "stall requires down_ms"
        );
        assert!(
            parse(r#"[{"at_ms": 1, "kind": "stall", "backend": 0, "down_ms": 0}]"#).is_err(),
            "down_ms must be positive"
        );
        assert!(
            parse(
                r#"[{"at_ms": 1, "kind": "slowdown", "backend": 0, "down_ms": 1, "factor": 0.5}]"#
            )
            .is_err(),
            "factor < 1 would be a speedup"
        );
        assert!(
            parse(r#"[{"at_ms": 1, "kind": "link_degrade", "dram_scale": 0, "pcie_scale": 1}]"#)
                .is_err(),
            "zero-width pool"
        );
        assert!(
            parse(r#"[{"at_ms": 1, "kind": "link_degrade", "dram_scale": 2, "pcie_scale": 1}]"#)
                .is_err(),
            "degradation cannot widen a pool"
        );
    }

    #[test]
    fn rack_aliases_name_the_same_link_pools() {
        // switch_scale aliases the dram slot, nic_scale the pcie slot —
        // a cluster spec written in rack vocabulary parses to the exact
        // same FaultKind a board spec would
        let rack = parse(
            r#"[{"at_ms": 1, "kind": "link_degrade", "switch_scale": 0.5, "nic_scale": 0.75}]"#,
        )
        .unwrap();
        assert_eq!(
            rack.events[0].kind,
            FaultKind::LinkDegrade { dram_scale: 0.5, pcie_scale: 0.75 }
        );
        // vocabularies may mix per slot (one name per slot is the rule)
        let mixed =
            parse(r#"[{"at_ms": 1, "kind": "link_degrade", "dram_scale": 0.5, "nic_scale": 1}]"#)
                .unwrap();
        assert_eq!(
            mixed.events[0].kind,
            FaultKind::LinkDegrade { dram_scale: 0.5, pcie_scale: 1.0 }
        );
        // both names of one slot is ambiguous, not a merge
        let both = parse(
            r#"[{"at_ms": 1, "kind": "link_degrade",
                 "dram_scale": 0.5, "switch_scale": 0.5, "nic_scale": 1}]"#,
        );
        assert!(both.is_err(), "dram_scale and switch_scale name the same pool");
        // the (0, 1] range check applies through the aliases too
        assert!(
            parse(r#"[{"at_ms": 1, "kind": "link_degrade", "switch_scale": 2, "nic_scale": 1}]"#)
                .is_err(),
            "degradation cannot widen the switch pool"
        );
        assert!(
            parse(r#"[{"at_ms": 1, "kind": "link_degrade", "switch_scale": 1, "nic_scale": 0}]"#)
                .is_err(),
            "zero-width NIC pool"
        );
        // a cluster fleet has rack pools: validate accepts the event
        // under the cluster shape (and still rejects a pool-less fleet)
        assert!(rack.validate(2, true, Some(2)).is_ok());
        assert!(rack.validate(2, false, None).is_err(), "no pools to degrade");
    }

    #[test]
    fn validate_checks_fleet_shape() {
        let s = parse(r#"[{"at_ms": 1, "kind": "crash", "backend": 2}]"#).unwrap();
        assert!(s.validate(3, false, None).is_ok());
        assert!(s.validate(2, false, None).is_err(), "backend 2 of a 2-backend fleet");
        let l =
            parse(r#"[{"at_ms": 1, "kind": "link_degrade", "dram_scale": 0.5, "pcie_scale": 1}]"#)
                .unwrap();
        assert!(l.validate(2, true, None).is_ok());
        assert!(l.validate(2, false, None).is_err(), "link_degrade without the link model");
    }

    #[test]
    fn board_crash_parses_validates_and_expands() {
        let s = parse(r#"[{"at_ms": 40, "kind": "board_crash", "board": 0, "down_ms": 200}]"#)
            .unwrap();
        assert_eq!(s.events[0].kind, FaultKind::BoardCrash { board: 0, down_ns: 200_000_000 });
        assert_eq!(s.events[0].kind.backend(), None, "a board crash is not one backend's");
        // like a crash, omitting down_ms means the board never recovers
        let forever = parse(r#"[{"at_ms": 1, "kind": "board_crash", "board": 1}]"#).unwrap();
        assert_eq!(
            forever.events[0].kind,
            FaultKind::BoardCrash { board: 1, down_ns: DOWN_CAP_NS }
        );
        assert!(parse(r#"[{"at_ms": 1, "kind": "board_crash"}]"#).is_err(), "missing board");
        // needs --cluster, and the board must exist
        assert!(s.validate(8, false, None).is_err());
        assert!(s.validate(8, false, Some(1)).is_ok());
        assert!(forever.validate(8, false, Some(1)).is_err(), "board 1 of a 1-board cluster");
        // expansion: members 0 and 2 live on board 0, member 1 on board 1
        let out = expand_boards(&s.events, &[0, 1, 0]);
        let crash = |backend: usize| FaultKind::Crash { backend, down_ns: 200_000_000 };
        assert_eq!(
            out,
            vec![
                FaultEvent { at_ns: 40_000_000, kind: crash(0) },
                FaultEvent { at_ns: 40_000_000, kind: crash(2) },
            ]
        );
        // non-board events pass through untouched, and order stays sorted
        let mixed = parse(
            r#"[{"at_ms": 50, "kind": "stall", "backend": 1, "down_ms": 5},
                {"at_ms": 40, "kind": "board_crash", "board": 1, "down_ms": 200}]"#,
        )
        .unwrap();
        let out = expand_boards(&mixed.events, &[0, 1, 0]);
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].kind, FaultKind::Crash { backend: 1, down_ns: 200_000_000 });
        assert_eq!(out[1].kind, FaultKind::Stall { backend: 1, down_ns: 5_000_000 });
        // the report json carries the board, not a backend
        let j = s.events[0].to_json(true);
        assert_eq!(j.get("kind").unwrap().as_str(), Some("board_crash"));
        assert_eq!(j.get("board").unwrap().as_usize(), Some(0));
        assert_eq!(j.get("down_ms").unwrap().as_f64(), Some(200.0));
        assert!(j.get("backend").is_none());
    }

    #[test]
    fn random_schedules_are_seeded_sorted_and_in_horizon() {
        let horizon = 30_000_000_000; // 30 virtual seconds
        let a = FaultSchedule::random(7, 2.0, 0.5, 3, horizon);
        let b = FaultSchedule::random(7, 2.0, 0.5, 3, horizon);
        assert_eq!(a, b, "same seed, same schedule");
        assert_ne!(a, FaultSchedule::random(8, 2.0, 0.5, 3, horizon));
        assert!(!a.events.is_empty(), "30s horizon at 2s MTBF must fault");
        assert!(a.events.windows(2).all(|w| w[0].at_ns <= w[1].at_ns));
        for e in &a.events {
            assert!(e.at_ns < horizon);
            let b = e.kind.backend().expect("random schedules only emit backend faults");
            assert!(b < 3);
            match e.kind {
                FaultKind::Crash { down_ns, .. } | FaultKind::Stall { down_ns, .. } => {
                    assert!(down_ns >= 1)
                }
                FaultKind::Slowdown { down_ns, factor, .. } => {
                    assert!(down_ns >= 1);
                    assert!((1.25..2.0).contains(&factor));
                }
                FaultKind::LinkDegrade { .. } | FaultKind::BoardCrash { .. } => unreachable!(),
            }
        }
        // validates against any fleet of >= 3 backends, link model or not
        assert!(a.validate(3, false, None).is_ok());
    }

    #[test]
    fn timeline_json_carries_kind_fields_and_applied() {
        let e = FaultEvent {
            at_ns: 40_000_000,
            kind: FaultKind::Slowdown { backend: 1, down_ns: 10_000_000, factor: 1.5 },
        };
        let j = e.to_json(true);
        assert_eq!(j.get("at_ms").unwrap().as_f64(), Some(40.0));
        assert_eq!(j.get("kind").unwrap().as_str(), Some("slowdown"));
        assert_eq!(j.get("backend").unwrap().as_usize(), Some(1));
        assert_eq!(j.get("factor").unwrap().as_f64(), Some(1.5));
        assert_eq!(j.get("applied").unwrap().as_bool(), Some(true));
        let d = FaultEvent {
            at_ns: 0,
            kind: FaultKind::LinkDegrade { dram_scale: 0.5, pcie_scale: 0.75 },
        };
        let dj = d.to_json(false);
        assert!(dj.get("backend").is_none());
        assert_eq!(dj.get("dram_scale").unwrap().as_f64(), Some(0.5));
        assert_eq!(dj.get("applied").unwrap().as_bool(), Some(false));
    }
}
