//! Multi-board cluster serving: one admission plane over N boards of
//! mixed SKUs (`cat serve --cluster <boards.json>`).
//!
//! The explore-derived frontier *family* maps across the cluster the
//! same way a partitioned fleet maps across one board, one level up:
//!
//! * **selection** — every board runs its own exploration and
//!   [`Fleet::select_partitioned_in`] under its own AIE/PL budgets and
//!   DRAM/PCIe pools (the same feasibility checks `dse::prune` applies
//!   per point), so a VCK5000 and a Limited-AIE board each deploy the
//!   members their silicon can actually hold;
//! * **network** — the inter-board host NIC and switch fabric are
//!   priced by the PR 5 [`SharedLinkModel`] machinery verbatim: each
//!   board's joint host-I/O appetite becomes one [`LinkDemand`] against
//!   the cluster pools (`pcie_gbps` slot = NIC, `dram_gbps` slot =
//!   switch), proportional grants stretch the oversubscribed boards,
//!   and `--links-fixed-point` relaxes the split to the clamped fixed
//!   point exactly as it does on-board;
//! * **health** — a whole-board crash expands to one simultaneous
//!   backend crash per member on that board (see
//!   [`crate::serve::faults::expand_boards`]), so the PR 6 drain /
//!   re-admit-against-original-deadlines / masked-renegotiation /
//!   five-term-conservation machinery handles board outages with no new
//!   code paths.
//!
//! The serving loop itself never learns about boards: it routes over
//! the flattened member list (power-ascending, the router's
//! cheapest-first contract) through the same event-driven
//! `serve::AdmissionIndex` every fleet shape rides — the flat re-ranked
//! order IS cost order, so the index's up-list interleaves boards'
//! members by cost with no cluster-specific routing code — and only
//! consults the [`ClusterBudget`] ledger when a fault forces link
//! renegotiation (where a rack-vocabulary `link_degrade` bites the
//! NIC/switch pools) or the report prints per-board
//! utilization/availability/energy (schema `cat-serve-v5`).

use std::collections::BTreeMap;

use crate::config::{HardwareConfig, SharedLinkModel};
use crate::dse;
use crate::serve::links::{negotiate_in, LinkDemand, LinkLedger, NegotiationMode};
use crate::serve::{Backend, Fleet, FleetConfig, FleetReport};
use crate::util::json::Json;
use anyhow::{anyhow, Result};

/// A parsed `--cluster` spec: which boards the rack holds and how wide
/// the shared network pools are.
///
/// JSON shape: `{"boards": ["vck5000", "vck5000-limited-64", {...}],
/// "nic_gbps": 12.5, "switch_gbps": 25.0}` — board entries are preset
/// names / `.json` paths (resolved like `--hw`) or inline hardware
/// objects; the pool keys default to a 100 GbE NIC (12.5 GB/s) and a
/// 200 GbE switch port (25 GB/s).
#[derive(Debug, Clone)]
pub struct ClusterSpec {
    pub boards: Vec<HardwareConfig>,
    /// Inter-board pools, reusing [`SharedLinkModel`] with the switch
    /// fabric in the `dram_gbps` slot and the host NIC in `pcie_gbps`.
    pub net: SharedLinkModel,
}

impl ClusterSpec {
    pub fn from_json(j: &Json) -> Result<ClusterSpec> {
        let arr = j
            .get("boards")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("cluster spec must carry a 'boards' array"))?;
        if arr.is_empty() {
            return Err(anyhow!("cluster spec 'boards' must name at least one board"));
        }
        let mut boards = Vec::with_capacity(arr.len());
        for (i, b) in arr.iter().enumerate() {
            let hw = match b {
                Json::Str(name) => HardwareConfig::resolve(name),
                Json::Obj(_) => HardwareConfig::from_json(b),
                _ => Err(anyhow!("board entries must be preset names or inline hardware objects")),
            }
            .map_err(|e| anyhow!("cluster board #{i}: {e}"))?;
            boards.push(hw);
        }
        let pool = |key: &str, default: f64| -> Result<f64> {
            match j.get(key) {
                None => Ok(default),
                Some(v) => v
                    .as_f64()
                    .filter(|x| x.is_finite() && *x > 0.0)
                    .ok_or_else(|| anyhow!("cluster '{key}' must be a positive number")),
            }
        };
        let nic = pool("nic_gbps", 12.5)?;
        let switch = pool("switch_gbps", 25.0)?;
        Ok(ClusterSpec { boards, net: SharedLinkModel { dram_gbps: switch, pcie_gbps: nic } })
    }

    /// Joined SKU names, e.g. `vck5000+vck5000-limited-64` — stands in
    /// for the single-board `hw` tag in cluster reports.
    pub fn name(&self) -> String {
        self.boards.iter().map(|b| b.name.as_str()).collect::<Vec<_>>().join("+")
    }
}

/// Where one fleet position lives in the cluster.
#[derive(Debug, Clone, Copy)]
pub struct MemberSlot {
    /// Board index (into [`ClusterBudget::boards`]).
    pub board: usize,
    /// Slot within that board's own partition (index into the board
    /// ledger's shares and intra-board link members).
    pub slot: usize,
    /// Deployed memory throttle: `1 / (intra-board stretch × the
    /// board's net stretch)`.
    pub throttle: f64,
}

/// One board's slice of the cluster: its hardware, its own partition
/// ledger (AIE/PL budgets, shares, intra-board links), and which global
/// fleet positions deploy on it (ascending = the board's slot order).
#[derive(Debug, Clone)]
pub struct BoardLedger {
    pub hw: HardwareConfig,
    pub budget: crate::serve::FleetBudget,
    pub members: Vec<usize>,
}

/// The cluster-level resource ledger a `--cluster` fleet carries:
/// per-board partitions, the negotiated NIC/switch ledger (one member
/// per **board**), and the flattened member placement.
#[derive(Debug, Clone)]
pub struct ClusterBudget {
    /// Joined SKU names (the report's `hw` tag in cluster mode).
    pub name: String,
    pub boards: Vec<BoardLedger>,
    /// Inter-board network ledger; `members[j]` is board `j`.
    pub net: LinkLedger,
    /// `members[g]` places global fleet position `g`.
    pub members: Vec<MemberSlot>,
}

/// Per-board runtime rollup derived from a finished report — the
/// numbers the cluster ledger prints beside its static budgets.
#[derive(Debug, Clone, Copy)]
pub struct BoardUsage {
    pub admitted: usize,
    pub completed: usize,
    pub busy_ns: u64,
    /// Mean member utilization: `Σ busy / (wall × members)`.
    pub utilization: f64,
    /// Mean member availability: `1 − Σ down / (wall × members)` (1.0
    /// on fault-free runs).
    pub availability: f64,
    /// Board energy over the wall: static once + dynamic per member.
    pub energy_j: f64,
}

impl ClusterBudget {
    /// `member_boards()[g]` = the board of fleet position `g` (the
    /// shape [`crate::serve::faults::expand_boards`] consumes).
    pub fn member_boards(&self) -> Vec<usize> {
        self.members.iter().map(|m| m.board).collect()
    }

    /// Roll the finished report up per board.
    pub fn board_usage(&self, r: &FleetReport) -> Vec<BoardUsage> {
        let wall = r.wall_ns;
        self.boards
            .iter()
            .map(|bl| {
                let mut u = BoardUsage {
                    admitted: 0,
                    completed: 0,
                    busy_ns: 0,
                    utilization: 0.0,
                    availability: 1.0,
                    energy_j: 0.0,
                };
                let mut down_ns = 0u64;
                let mut dynamic_ns_w = 0.0;
                for &g in &bl.members {
                    let b = &r.backends[g];
                    u.admitted += b.admitted;
                    u.completed += b.stats.completed;
                    u.busy_ns += b.busy_ns;
                    dynamic_ns_w +=
                        (b.point.power_w - bl.hw.power.static_w).max(0.0) * b.busy_ns as f64;
                    if let Some(f) = &r.faults {
                        down_ns += f.backends[g].down_ns;
                    }
                }
                let denom = wall as f64 * bl.members.len().max(1) as f64;
                if wall > 0 {
                    u.utilization = u.busy_ns as f64 / denom;
                    u.availability = 1.0 - down_ns as f64 / denom;
                }
                u.energy_j = (bl.hw.power.static_w * wall as f64 + dynamic_ns_w) / 1e9;
                u
            })
            .collect()
    }

    /// The report's `cluster` block (schema `cat-serve-v5`).
    pub fn to_json(&self, r: &FleetReport) -> Json {
        let usage = self.board_usage(r);
        let mut m = BTreeMap::new();
        m.insert("name".to_string(), Json::Str(self.name.clone()));
        m.insert("n_boards".to_string(), Json::Num(self.boards.len() as f64));
        let boards = self
            .boards
            .iter()
            .zip(&usage)
            .enumerate()
            .map(|(j, (bl, u))| {
                let mut bm = BTreeMap::new();
                bm.insert("id".to_string(), Json::Num(j as f64));
                bm.insert("hw".to_string(), Json::Str(bl.hw.name.clone()));
                bm.insert(
                    "members".to_string(),
                    Json::Arr(bl.members.iter().map(|&g| Json::Num(g as f64)).collect()),
                );
                bm.insert("net_stretch".to_string(), Json::Num(self.net.members[j].stretch));
                bm.insert("admitted".to_string(), Json::Num(u.admitted as f64));
                bm.insert("completed".to_string(), Json::Num(u.completed as f64));
                bm.insert("busy_ms".to_string(), Json::Num(u.busy_ns as f64 / 1e6));
                bm.insert("utilization".to_string(), Json::Num(u.utilization));
                bm.insert("availability".to_string(), Json::Num(u.availability));
                bm.insert("energy_j".to_string(), Json::Num(u.energy_j));
                bm.insert("board".to_string(), bl.budget.to_json());
                Json::Obj(bm)
            })
            .collect();
        m.insert("boards".to_string(), Json::Arr(boards));
        m.insert("net".to_string(), self.net_json());
        let members = self
            .members
            .iter()
            .enumerate()
            .map(|(g, ms)| {
                let mut mm = BTreeMap::new();
                mm.insert("backend".to_string(), Json::Num(g as f64));
                mm.insert("board".to_string(), Json::Num(ms.board as f64));
                mm.insert("slot".to_string(), Json::Num(ms.slot as f64));
                mm.insert("throttle".to_string(), Json::Num(ms.throttle));
                Json::Obj(mm)
            })
            .collect();
        m.insert("members".to_string(), Json::Arr(members));
        m.insert(
            "energy_j".to_string(),
            Json::Num(usage.iter().map(|u| u.energy_j).sum::<f64>()),
        );
        Json::Obj(m)
    }

    /// [`LinkLedger::to_json`] speaks DRAM/PCIe; the cluster net reuses
    /// that machinery with the switch fabric in the DRAM slot and the
    /// host NIC in the PCIe slot, so this re-keys the block to say what
    /// it means (and one member per board, not per backend).
    fn net_json(&self) -> Json {
        let demanded = self.net.demanded();
        let granted = self.net.granted();
        let pool = |total: f64, dem: f64, grant: f64| {
            let mut p = BTreeMap::new();
            p.insert("pool_gbps".to_string(), Json::Num(total));
            p.insert("demanded_gbps".to_string(), Json::Num(dem));
            p.insert("granted_gbps".to_string(), Json::Num(grant));
            p.insert(
                "oversubscription".to_string(),
                Json::Num(if total > 0.0 { dem / total } else { 0.0 }),
            );
            Json::Obj(p)
        };
        let mut m = BTreeMap::new();
        m.insert(
            "switch".to_string(),
            pool(self.net.pools.dram_gbps, demanded.dram_gbps, granted.dram_gbps),
        );
        m.insert(
            "nic".to_string(),
            pool(self.net.pools.pcie_gbps, demanded.pcie_gbps, granted.pcie_gbps),
        );
        m.insert("throttled".to_string(), Json::Bool(self.net.throttled()));
        let fixed_point = self.net.mode == NegotiationMode::FixedPoint;
        if fixed_point {
            m.insert("mode".to_string(), Json::Str(self.net.mode.wire_name().to_string()));
            m.insert("pessimism".to_string(), Json::Num(self.net.pessimism()));
        }
        let boards = self
            .net
            .members
            .iter()
            .enumerate()
            .map(|(j, ml)| {
                let mut bm = BTreeMap::new();
                bm.insert("board".to_string(), Json::Num(j as f64));
                // NIC and switch demands are the same host-I/O figure,
                // so one demand/grant pair per board suffices
                bm.insert("demand_gbps".to_string(), Json::Num(ml.demand.pcie_gbps));
                bm.insert("granted_gbps".to_string(), Json::Num(ml.granted.pcie_gbps));
                bm.insert("stretch".to_string(), Json::Num(ml.stretch));
                bm.insert("throttle".to_string(), Json::Num(1.0 / ml.stretch));
                if fixed_point {
                    bm.insert(
                        "stretch_single_pass".to_string(),
                        Json::Num(ml.stretch_single_pass),
                    );
                    bm.insert("stretch_fixed_point".to_string(), Json::Num(ml.stretch));
                }
                Json::Obj(bm)
            })
            .collect();
        m.insert("boards".to_string(), Json::Arr(boards));
        Json::Obj(m)
    }
}

/// One selected member before global flattening.
struct Placed {
    power_w: f64,
    board: usize,
    slot: usize,
    be: Backend,
    throttle: f64,
}

/// Map the serving config across the cluster: per-board exploration +
/// partition, then the inter-board NIC/switch negotiation, then the
/// flattened power-ranked fleet the admission plane routes over.
pub fn build_fleet(cfg: &FleetConfig, spec: &ClusterSpec) -> Result<Fleet> {
    let n_boards = spec.boards.len();
    if n_boards == 0 {
        return Err(anyhow!("cluster spec has no boards"));
    }
    if !spec.net.is_positive_finite() {
        return Err(anyhow!(
            "cluster NIC/switch pools must be positive and finite, got switch {} GB/s / NIC {} \
             GB/s",
            spec.net.dram_gbps,
            spec.net.pcie_gbps
        ));
    }
    if cfg.max_backends < n_boards {
        return Err(anyhow!(
            "--cluster with {n_boards} board(s) needs --backends >= {n_boards} (at least one \
             member per board), got {}",
            cfg.max_backends
        ));
    }
    // Near-even slot split; earlier boards absorb the remainder.
    let base = cfg.max_backends / n_boards;
    let extra = cfg.max_backends % n_boards;
    // Per-board selection: each SKU explores its own frontier and
    // partitions it under its own budgets and link pools — mixed racks
    // deploy genuinely different designs per board.
    let mut per_board = Vec::with_capacity(n_boards);
    for (j, board) in spec.boards.iter().enumerate() {
        let slots = base + usize::from(j < extra);
        let mut ecfg = dse::ExploreConfig::new(cfg.model.clone(), board.clone());
        ecfg.sample_budget = cfg.explore_budget;
        ecfg.seed = cfg.seed;
        ecfg.slo_ms = Some(cfg.slo_ms);
        let explored =
            dse::explore(&ecfg).map_err(|e| anyhow!("cluster board #{j} ({}): {e}", board.name))?;
        let f = Fleet::select_partitioned_in(
            &cfg.model,
            board,
            &explored,
            slots,
            cfg.max_batch,
            Some(cfg.slo_ms),
            Some(&board.links()),
            cfg.link_mode(),
        )
        .map_err(|e| anyhow!("cluster board #{j} ({}): {e}", board.name))?;
        if f.backends.is_empty() {
            return Err(anyhow!(
                "cluster board #{j} ({}) contributed no feasible members",
                board.name
            ));
        }
        let budget = f.budget.clone().expect("partitioned fleets carry their budget");
        per_board.push((budget, f.backends));
    }
    // Inter-board negotiation: a board's demand on the host NIC and the
    // switch fabric is its members' joint host-I/O appetite (activations
    // in and out transit both), priced by the same proportional-grant
    // machinery the intra-board pools use.
    let board_demands: Vec<LinkDemand> = per_board
        .iter()
        .map(|(budget, _)| {
            let ledger = budget.links.as_ref().expect("cluster boards carry link ledgers");
            let host: f64 = ledger.members.iter().map(|m| m.demand.pcie_gbps).sum();
            LinkDemand { dram_gbps: host, pcie_gbps: host }
        })
        .collect();
    let net = negotiate_in(&spec.net, &board_demands, cfg.link_mode());
    // Combined throttle = intra-board stretch × the board's net stretch.
    // A board whose net stretch is exactly 1 keeps its already-deployed
    // members untouched (this is what makes a 1-board cluster
    // byte-identical to the equivalent --partition run); a stretched
    // board redeploys each member on the narrower effective slice.
    let mut flat = Vec::with_capacity(cfg.max_backends);
    for (j, (budget, backends)) in per_board.iter_mut().enumerate() {
        let s_net = net.members[j].stretch;
        let intra: Vec<f64> = budget
            .links
            .as_ref()
            .expect("cluster boards carry link ledgers")
            .members
            .iter()
            .map(|m| m.stretch)
            .collect();
        for (slot, be) in backends.drain(..).enumerate() {
            let throttle = 1.0 / (intra[slot] * s_net);
            let be = if s_net > 1.0 {
                let mut nb = Backend::deploy_in_share(
                    &cfg.model,
                    &spec.boards[j],
                    &be.point,
                    cfg.max_batch,
                    &budget.shares[slot],
                    throttle,
                )
                .map_err(|e| {
                    anyhow!(
                        "deploying cluster member (board #{j} slot {slot}) at throttle \
                         {throttle:.4}: {e}"
                    )
                })?;
                nb.id = be.id;
                nb
            } else {
                be
            };
            flat.push(Placed { power_w: be.power_w(), board: j, slot, be, throttle });
        }
    }
    // Global fleet order: power ascending (the router's cheapest-first
    // contract), ties broken by (board, slot) for determinism.
    flat.sort_by(|a, b| {
        a.power_w.total_cmp(&b.power_w).then(a.board.cmp(&b.board)).then(a.slot.cmp(&b.slot))
    });
    let mut backends = Vec::with_capacity(flat.len());
    let mut members = Vec::with_capacity(flat.len());
    let mut board_members: Vec<Vec<usize>> = vec![Vec::new(); n_boards];
    for (gid, p) in flat.into_iter().enumerate() {
        let mut be = p.be;
        be.id = gid;
        board_members[p.board].push(gid);
        members.push(MemberSlot { board: p.board, slot: p.slot, throttle: p.throttle });
        backends.push(be);
    }
    let boards = per_board
        .into_iter()
        .zip(spec.boards.iter())
        .zip(board_members)
        .map(|(((budget, _), hw), members)| BoardLedger { hw: hw.clone(), budget, members })
        .collect();
    let cluster = ClusterBudget { name: spec.name(), boards, net, members };
    Ok(Fleet { backends, budget: None, cluster: Some(cluster) })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;

    fn parse(src: &str) -> Json {
        Json::parse(src).unwrap()
    }

    fn two_board_spec() -> ClusterSpec {
        let j = parse(r#"{"boards": ["vck5000", "vck5000-limited-64"]}"#);
        ClusterSpec::from_json(&j).unwrap()
    }

    #[test]
    fn spec_parses_presets_defaults_and_rejects_bad_pools() {
        let s = two_board_spec();
        assert_eq!(s.boards.len(), 2);
        assert_eq!(s.boards[1].total_aie, 64);
        assert_eq!(s.net.pcie_gbps, 12.5, "NIC defaults to 100 GbE");
        assert_eq!(s.net.dram_gbps, 25.0, "switch defaults to 200 GbE");
        assert_eq!(s.name(), "vck5000+vck5000-limited-64");

        let s = ClusterSpec::from_json(&parse(
            r#"{"boards": ["vck5000"], "nic_gbps": 4.0, "switch_gbps": 8.0}"#,
        ))
        .unwrap();
        assert_eq!(s.net.pcie_gbps, 4.0);
        assert_eq!(s.net.dram_gbps, 8.0);

        for bad in [
            r#"{}"#,
            r#"{"boards": []}"#,
            r#"{"boards": ["no-such-board"]}"#,
            r#"{"boards": [7]}"#,
            r#"{"boards": ["vck5000"], "nic_gbps": 0}"#,
            r#"{"boards": ["vck5000"], "switch_gbps": -1}"#,
        ] {
            assert!(ClusterSpec::from_json(&parse(bad)).is_err(), "accepted {bad}");
        }
    }

    #[test]
    fn build_splits_slots_and_flattens_power_ascending() {
        let model = ModelConfig::bert_base();
        let spec = two_board_spec();
        let mut cfg = FleetConfig::new(model, spec.boards[0].clone());
        cfg.max_backends = 3;
        cfg.explore_budget = Some(64);
        cfg.slo_ms = 80.0;
        cfg.seed = 7;
        cfg.cluster = Some(spec);
        let fleet = build_fleet(&cfg, cfg.cluster.as_ref().unwrap()).unwrap();
        let cb = fleet.cluster.as_ref().expect("cluster fleets carry the ledger");
        assert_eq!(cb.boards.len(), 2);
        // 3 members over 2 boards: the first board is asked for the
        // remainder (each board may degrade to fewer if its own silicon
        // can't hold the request, but never to zero)
        assert_eq!(cb.boards[0].budget.stats.requested, 2);
        assert_eq!(cb.boards[1].budget.stats.requested, 1);
        assert!(!cb.boards[0].members.is_empty());
        assert!(!cb.boards[1].members.is_empty());
        assert_eq!(
            cb.boards.iter().map(|b| b.members.len()).sum::<usize>(),
            fleet.len(),
            "every member lives on exactly one board"
        );
        assert_eq!(cb.members.len(), fleet.len());
        assert_eq!(cb.net.members.len(), 2, "one net member per board");
        // ids are positions, power ascending, and placement is a bijection
        let mut seen = vec![false; fleet.len()];
        for (g, be) in fleet.backends.iter().enumerate() {
            assert_eq!(be.id, g);
            assert!(cb.boards[cb.members[g].board].members.contains(&g));
            seen[g] = true;
            if g > 0 {
                assert!(
                    fleet.backends[g - 1].power_w() <= be.power_w(),
                    "fleet must stay power-ascending for cheapest-first routing"
                );
            }
        }
        assert!(seen.iter().all(|&s| s));
        // every member's deployed throttle folds intra × net stretch
        for (g, ms) in cb.members.iter().enumerate() {
            let intra = cb.boards[ms.board].budget.links.as_ref().unwrap().members[ms.slot]
                .stretch;
            let s_net = cb.net.members[ms.board].stretch;
            assert!(
                (ms.throttle * intra * s_net - 1.0).abs() < 1e-9,
                "member {g}: throttle {} vs intra {intra} × net {s_net}",
                ms.throttle
            );
        }
    }

    #[test]
    fn one_board_needs_one_backend_and_tiny_fleets_error() {
        let model = ModelConfig::bert_base();
        let spec = two_board_spec();
        let mut cfg = FleetConfig::new(model, spec.boards[0].clone());
        cfg.max_backends = 1;
        let err = build_fleet(&cfg, &spec).unwrap_err().to_string();
        assert!(err.contains("needs --backends >= 2"), "got: {err}");
    }
}
