//! `cat` — the CAT framework CLI (leader entrypoint).
//!
//! ```text
//! cat customize --model bert-base --hw vck5000 [--json]
//! cat simulate  --model bert-base --hw vck5000 --batch 16
//! cat table 2|5|6|7     reproduce the paper tables
//! cat fig5              reproduce Figure 5
//! cat obs1              reproduce Observation 1
//! cat verify            numerics: pallas-tiled == fused == stage-composed
//! cat serve  --requests 32 --batch 8 --layers 2 --workers 1
//! ```

use anyhow::{anyhow, Result};
use cat::experiments;
use cat::config::{HardwareConfig, ModelConfig};
use cat::coordinator::{synthetic_request, Host, HostConfig};
use cat::customize::{customize, CustomizeOptions};
use cat::metrics::summarize;
use cat::report;
use cat::runtime::{EncoderWeights, Runtime};
use cat::sched::run_edpu;
use cat::util::cli;

const VALUED: &[&str] = &[
    "model", "hw", "batch", "requests", "layers", "workers", "variant", "artifacts", "seed",
    "max-cores", "slo-ms", "budget", "rps", "backends", "queue-cap", "dram-gbps", "pcie-gbps",
    "faults", "mtbf-s", "mttr-s", "max-retries", "cluster", "trace", "metrics",
];

fn main() {
    let args = cli::parse(std::env::args().skip(1), VALUED);
    let result = match args.subcommand.as_deref() {
        Some("customize") => cmd_customize(&args),
        Some("explore") => cmd_explore(&args),
        Some("simulate") => cmd_simulate(&args),
        Some("table") => cmd_table(&args),
        Some("fig5") => cmd_fig5(&args),
        Some("obs1") => cmd_obs1(),
        Some("verify") => cmd_verify(&args),
        Some("serve") => cmd_serve(&args),
        Some("codegen") => cmd_codegen(&args),
        Some("help") | None => {
            print!("{}", HELP);
            Ok(())
        }
        Some(other) => Err(anyhow!("unknown subcommand '{other}'\n{HELP}")),
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

const HELP: &str = "\
cat — Customized Transformer Accelerator framework (Versal ACAP, simulated)

subcommands:
  customize --model <m> --hw <h> [--json]   derive an accelerator plan
  explore   --model <m> --hw <h> [--max-cores N] [--slo-ms X]
            [--budget K|all] [--seed S] [--json]
            [--trace <f>] [--metrics <f>]
                                            sweep the joint customization x
                                            deployment space and report the
                                            Pareto-optimal accelerator family;
                                            --trace writes the DSE phase
                                            timeline as Chrome trace-event
                                            JSON (load in Perfetto),
                                            --metrics a cat-obs-v1
                                            counters/histograms document
  simulate  --model <m> --hw <h> [--batch N]  run the EDPU simulator
  table <2|5|6|7>                           reproduce a paper table
  fig5                                      reproduce Figure 5
  obs1                                      reproduce Observation 1
  verify [--artifacts <dir>]                check PJRT numerics end to end
  serve [--requests N] [--batch B] [--layers L] [--workers W]
                                            serve batched requests (PJRT)
  serve --rps <r> --slo-ms <x> [--model <m>] [--hw <h>] [--backends K]
        [--requests N] [--batch B] [--queue-cap Q] [--budget K]
        [--seed S] [--partition] [--dram-gbps G] [--pcie-gbps G]
        [--no-links] [--links-fixed-point]
        [--faults <spec.json> | --mtbf-s <s> --mttr-s <s>]
        [--max-retries R] [--cluster <boards.json>] [--trace <f>]
        [--metrics <f>] [--json]            SLO-aware fleet serving across
                                            an explore-derived accelerator
                                            family (virtual clock);
                                            --partition co-locates the
                                            backends on ONE board (joint
                                            Total_AIE + PL budgets AND the
                                            shared DRAM/PCIe pools, schema
                                            cat-serve-v3; oversubscribed
                                            links throttle members
                                            proportionally);
                                            --dram-gbps / --pcie-gbps
                                            override the board's link
                                            pools, --no-links disables the
                                            contention model (schema
                                            cat-serve-v2);
                                            --links-fixed-point relaxes
                                            the throttle to the proved
                                            fixed point of demand->grant->
                                            stretch (default stays the
                                            conservative single pass); the
                                            links block then reports both
                                            bounds per member plus the
                                            board-level pessimism ratio;
                                            --faults injects a scripted
                                            crash/stall/slowdown/
                                            link_degrade schedule,
                                            --mtbf-s/--mttr-s a seeded
                                            random one (virtual seconds):
                                            failed backends are excluded
                                            from admission, their work is
                                            re-admitted on survivors
                                            (bounded by --max-retries,
                                            default 3), and the report
                                            switches to schema
                                            cat-serve-v4 with a faults
                                            block;
                                            --cluster spreads the family
                                            across EVERY board of a
                                            multi-board spec (preset
                                            names or inline hardware
                                            objects, plus nic_gbps /
                                            switch_gbps pools) behind one
                                            admission plane: each board
                                            is partitioned internally,
                                            inter-board NIC/switch
                                            bandwidth is negotiated like
                                            the on-board links, fault
                                            specs gain a board_crash
                                            kind, and the report
                                            switches to schema
                                            cat-serve-v5 with a cluster
                                            ledger (conflicts with --hw,
                                            --partition, and the link
                                            pool flags; --backends must
                                            be >= the board count);
                                            --trace writes the request
                                            lifecycle on the virtual clock
                                            as Chrome trace-event JSON
                                            (load in Perfetto), --metrics
                                            a cat-obs-v1 document with
                                            counters + deterministic
                                            histograms; neither flag
                                            perturbs the report
  codegen --model <m> --hw <h> [--json]     emit the AIE graph design
models: bert-base | vit-base | <path>.json
hardware: vck5000 | vck190 | vck5000-limited-<n> | <path>.json
";

fn model_of(args: &cli::Args) -> Result<ModelConfig> {
    ModelConfig::resolve(args.opt_or("model", "bert-base"))
}

fn hw_of(args: &cli::Args) -> Result<HardwareConfig> {
    HardwareConfig::resolve(args.opt_or("hw", "vck5000"))
}

fn cmd_customize(args: &cli::Args) -> Result<()> {
    let model = model_of(args)?;
    let hw = hw_of(args)?;
    let plan = customize(&model, &hw, &CustomizeOptions::default())?;
    if args.flag("json") {
        println!("{}", plan.to_json());
        return Ok(());
    }
    println!("== CAT customization: {} on {} ==", model.name, hw.name);
    println!("  MMSZ_AIE (Eq.3)         = {}", plan.mmsz);
    println!("  PLIO_AIE (Eq.4)         = {}", plan.plio_aie);
    println!("  independent linear      = {}", plan.independent_linear);
    println!("  P_ATB (Eq.7/8)          = {}", plan.p_atb);
    println!(
        "  MHA mode (Eq.5)         = {} (Factor1 {:.2}, Factor2 {:.4} MiB)",
        plan.mha.mode,
        plan.factor1_mha,
        plan.factor2_mha_bytes as f64 / (1024.0 * 1024.0)
    );
    println!(
        "  FFN mode (Eq.6)         = {} (Factor1 {:.2}, Factor2 {:.4} MiB)",
        plan.ffn.mode,
        plan.factor1_ffn,
        plan.factor2_ffn_bytes as f64 / (1024.0 * 1024.0)
    );
    println!(
        "  AIE deployed            = {} / {} ({:.0}%)",
        plan.cores_deployed(),
        hw.total_aie,
        plan.deployment_rate() * 100.0
    );
    for (name, stage) in [("MHA", &plan.mha), ("FFN", &plan.ffn)] {
        println!("  {name} PRGs:");
        for prg in &stage.prgs {
            println!(
                "    {:?}[atb{}] <- {:?} ({} cores)",
                prg.kind, prg.atb_index, prg.pus, prg.cores()
            );
        }
    }
    Ok(())
}

fn cmd_explore(args: &cli::Args) -> Result<()> {
    let model = model_of(args)?;
    let hw = hw_of(args)?;
    let mut cfg = cat::dse::ExploreConfig::new(model, hw);
    if let Some(s) = args.opt("max-cores") {
        cfg.max_cores =
            Some(s.parse().map_err(|_| anyhow!("--max-cores expects an integer, got '{s}'"))?);
    }
    if let Some(s) = args.opt("slo-ms") {
        cfg.slo_ms =
            Some(s.parse().map_err(|_| anyhow!("--slo-ms expects a number, got '{s}'"))?);
    }
    if let Some(s) = args.opt("budget") {
        cfg.sample_budget = if s == "all" {
            None
        } else {
            match s.parse() {
                Ok(k) if k > 0 => Some(k),
                _ => {
                    return Err(anyhow!(
                        "--budget expects a positive integer or 'all', got '{s}'"
                    ))
                }
            }
        };
    }
    if let Some(s) = args.opt("seed") {
        cfg.seed = s.parse().map_err(|_| anyhow!("--seed expects an integer, got '{s}'"))?;
    }
    let trace_on = args.opt("trace").is_some();
    let metrics_on = args.opt("metrics").is_some();
    if trace_on || metrics_on {
        let mut obs = cat::obs::Obs::new(trace_on, metrics_on);
        let res = cat::dse::explore_obs(&cfg, Some(&mut obs))?;
        write_obs_outputs(args, &obs)?;
        if args.flag("json") {
            println!("{}", res.to_json());
        } else {
            print!("{}", report::explore(&res));
            if let Some(m) = &obs.metrics {
                print!("{}", report::obs_footer(m));
            }
        }
        return Ok(());
    }
    let res = cat::dse::explore(&cfg)?;
    if args.flag("json") {
        println!("{}", res.to_json());
    } else {
        print!("{}", report::explore(&res));
    }
    Ok(())
}

/// Write the `--trace` / `--metrics` files from a finished observability
/// capture.  Only the sides that were enabled (and given a path) land on
/// disk; both documents end with a trailing newline for clean `cat`/`cmp`.
fn write_obs_outputs(args: &cli::Args, obs: &cat::obs::Obs) -> Result<()> {
    if let (Some(path), Some(t)) = (args.opt("trace"), obs.trace.as_ref()) {
        std::fs::write(path, format!("{}\n", t.to_json()))
            .map_err(|e| anyhow!("writing trace '{path}': {e}"))?;
    }
    if let (Some(path), Some(m)) = (args.opt("metrics"), obs.metrics.as_ref()) {
        std::fs::write(path, format!("{}\n", m.to_json()))
            .map_err(|e| anyhow!("writing metrics '{path}': {e}"))?;
    }
    Ok(())
}

fn cmd_simulate(args: &cli::Args) -> Result<()> {
    let model = model_of(args)?;
    let hw = hw_of(args)?;
    let batch = args.opt_usize("batch", 16);
    let plan = customize(&model, &hw, &CustomizeOptions::default())?;
    let r = run_edpu(&plan, batch)?;
    let s = summarize(&plan, &r);
    println!("{}", report::table6(&[s]));
    Ok(())
}

fn cmd_table(args: &cli::Args) -> Result<()> {
    match args.positional.first().map(String::as_str) {
        Some("2") => {
            println!("{}", report::table2(&experiments::table2_rows()?));
        }
        Some("5") => {
            let plans = experiments::table5_plans()?;
            let refs: Vec<(&str, &cat::arch::AcceleratorPlan)> =
                plans.iter().map(|(n, p)| (*n, p)).collect();
            println!("{}", report::table5(&refs));
        }
        Some("6") => {
            println!("{}", report::table6(&experiments::table6_rows()?));
        }
        Some("7") => {
            let d = experiments::table7_data()?;
            println!(
                "{}",
                report::table7_group(
                    "peak",
                    &d.cat_peak,
                    &[
                        ("CHARM-style (sim)", d.charm_style),
                        ("SSR-style (sim)", d.ssr_style)
                    ]
                )
            );
            println!("{}", report::table7_group("vit", &d.cat_vit, &[]));
            println!("{}", report::table7_group("bert", &d.cat_bert, &[]));
        }
        other => return Err(anyhow!("usage: cat table <2|5|6|7> (got {other:?})")),
    }
    Ok(())
}

fn cmd_fig5(_args: &cli::Args) -> Result<()> {
    for (label, m, hw) in experiments::three_accelerators() {
        let pts = experiments::fig5_series(&m, &hw)?;
        println!("{}", report::fig5(label, &pts));
    }
    Ok(())
}

fn cmd_obs1() -> Result<()> {
    let (serial, pipe) = experiments::obs1_times()?;
    println!("Observation 1 — PL-side send/compute/receive organization");
    println!("  serial    : {serial:>10.1} ns  (paper: 1.10x baseline)");
    println!("  pipelined : {pipe:>10.1} ns  (paper: 0.71x)");
    println!("  speedup   : {:.2}x        (paper: 1.41x)", serial / pipe);
    Ok(())
}

fn cmd_verify(args: &cli::Args) -> Result<()> {
    let dir = args.opt_or("artifacts", "artifacts");
    let model = ModelConfig::bert_base();
    let mut rt = Runtime::open(dir)?;
    println!("PJRT platform: {}", rt.platform());
    let req = synthetic_request(&model, rt.manifest().mmsz, 0, 42);
    let w = EncoderWeights::synthetic(&model, 7);

    println!("running encoder_layer_fused ...");
    let (f_fused, q_fused, s_fused) =
        rt.encoder_layer("encoder_layer_fused", &req.x_q, req.x_scale, &w)?;
    println!("running encoder_layer_pallas (EDPU-tiled) ...");
    let (f_pal, q_pal, s_pal) =
        rt.encoder_layer("encoder_layer_pallas", &req.x_q, req.x_scale, &w)?;

    let a = f_fused.as_f32()?;
    let b = f_pal.as_f32()?;
    let max_diff = a
        .iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f32, f32::max);
    println!("  pallas-tiled vs fused: max |diff| = {max_diff:.2e}");
    if max_diff > 1e-4 {
        return Err(anyhow!("EDPU tiling changed the numerics (diff {max_diff})"));
    }
    if q_fused.as_i8()? != q_pal.as_i8()? {
        return Err(anyhow!("quantized outputs differ"));
    }
    println!("  quantized outputs identical; scales {s_fused:.6} vs {s_pal:.6}");

    // stage composition: ffn(mha(x)) == layer(x)
    println!("running mha_stage + ffn_stage composition ...");
    let mut mha_in = vec![req.x_q.clone(), cat::runtime::Tensor::scalar_f32(req.x_scale)];
    mha_in.extend([
        w.wqkv.clone(),
        cat::runtime::Tensor::scalar_f32(w.sqkv),
        w.bqkv.clone(),
        w.wproj.clone(),
        cat::runtime::Tensor::scalar_f32(w.sproj),
        w.bproj.clone(),
        w.ln1_g.clone(),
        w.ln1_b.clone(),
    ]);
    let h1 = rt.run("mha_stage", &mha_in)?.remove(0);
    let mut ffn_in = vec![h1];
    ffn_in.extend([
        w.w1.clone(),
        cat::runtime::Tensor::scalar_f32(w.s1),
        w.b1.clone(),
        w.w2.clone(),
        cat::runtime::Tensor::scalar_f32(w.s2),
        w.b2.clone(),
        w.ln2_g.clone(),
        w.ln2_b.clone(),
    ]);
    let composed = rt.run("ffn_stage", &ffn_in)?.remove(0);
    let c = composed.as_f32()?;
    let max_diff2 = a
        .iter()
        .zip(c)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f32, f32::max);
    println!("  stage-composed vs full layer: max |diff| = {max_diff2:.2e}");
    if max_diff2 > 1e-4 {
        return Err(anyhow!("stage composition diverged ({max_diff2})"));
    }
    println!("verify OK — the EDPU decomposition is arithmetically exact");
    Ok(())
}

fn cmd_serve(args: &cli::Args) -> Result<()> {
    // --rps selects the fleet path (virtual-clock, frontier-backed);
    // without it `serve` keeps its original single-host PJRT meaning.
    if args.opt("rps").is_some() {
        return cmd_serve_fleet(args);
    }
    if args.opt("trace").is_some() || args.opt("metrics").is_some() {
        return Err(anyhow!(
            "--trace/--metrics require the fleet path (`cat serve --rps ...`): the \
             single-host PJRT loop runs on the wall clock, not the virtual clock"
        ));
    }
    let model = model_of(args)?;
    let hw = hw_of(args)?;
    let n_requests = args.opt_usize("requests", 16);
    let mut cfg = HostConfig::new(model.clone());
    cfg.artifact_dir = args.opt_or("artifacts", "artifacts").to_string();
    cfg.variant = args.opt_or("variant", "encoder_layer_fused").to_string();
    cfg.layers = args.opt_usize("layers", 2);
    cfg.workers = args.opt_usize("workers", 1);
    cfg.max_batch = args.opt_usize("batch", 8);
    cfg.plan = customize(&model, &hw, &CustomizeOptions::default()).ok();
    let mmsz = cfg.plan.as_ref().map(|p| p.mmsz).unwrap_or(64);

    println!(
        "serving {n_requests} requests of {} through {} worker(s), max_batch {} ...",
        model.name, cfg.workers, cfg.max_batch
    );
    let mut host = Host::start(cfg)?;
    for i in 0..n_requests {
        host.submit(synthetic_request(&model, mmsz, i as u64, 1000 + i as u64));
    }
    let (responses, stats) = host.drain()?;
    println!("  completed     : {}", stats.completed);
    println!("  wall time     : {:.2?}", stats.wall);
    println!(
        "  throughput    : {:.2} req/s (host CPU, interpret-mode XLA)",
        stats.throughput_rps()
    );
    println!("  p50 latency   : {:.2?}", stats.percentile(0.5));
    println!("  p99 latency   : {:.2?}", stats.percentile(0.99));
    if let Some(sim) = responses.first().and_then(|r| r.simulated_batch_ns) {
        println!(
            "  simulated VCK5000 batch latency: {:.3} ms ({} layers)",
            sim / 1e6,
            args.opt_usize("layers", 2)
        );
    }
    Ok(())
}

/// Lift the raw CLI surface into the typed [`cat::serve::ServeArgs`]
/// bundle.  No parsing or cross-flag rules here —
/// [`cat::serve::FleetConfig::from_args`] owns all of that, so the CLI
/// and tests validate identically.
fn serve_args_of(args: &cli::Args) -> cat::serve::ServeArgs {
    let s = |k: &str| args.opt(k).map(str::to_string);
    cat::serve::ServeArgs {
        model: s("model"),
        hw: s("hw"),
        rps: s("rps"),
        slo_ms: s("slo-ms"),
        requests: s("requests"),
        backends: s("backends"),
        batch: s("batch"),
        queue_cap: s("queue-cap"),
        seed: s("seed"),
        budget: s("budget"),
        partition: args.flag("partition"),
        no_links: args.flag("no-links"),
        links_fixed_point: args.flag("links-fixed-point"),
        dram_gbps: s("dram-gbps"),
        pcie_gbps: s("pcie-gbps"),
        cluster: s("cluster"),
        faults: s("faults"),
        mtbf_s: s("mtbf-s"),
        mttr_s: s("mttr-s"),
        max_retries: s("max-retries"),
    }
}

fn cmd_serve_fleet(args: &cli::Args) -> Result<()> {
    let cfg = cat::serve::FleetConfig::from_args(&serve_args_of(args))?;
    let trace_on = args.opt("trace").is_some();
    let metrics_on = args.opt("metrics").is_some();
    if trace_on || metrics_on {
        let mut obs = cat::obs::Obs::new(trace_on, metrics_on);
        let r = experiments::serve_fleet_obs(&cfg, &mut obs)?;
        write_obs_outputs(args, &obs)?;
        if args.flag("json") {
            println!("{}", r.to_json());
        } else {
            print!("{}", report::serve_fleet(&r));
            if let Some(m) = &obs.metrics {
                print!("{}", report::obs_footer(m));
            }
        }
        return Ok(());
    }
    let r = experiments::serve_fleet(&cfg)?;
    if args.flag("json") {
        println!("{}", r.to_json());
    } else {
        print!("{}", report::serve_fleet(&r));
    }
    Ok(())
}

fn cmd_codegen(args: &cli::Args) -> Result<()> {
    let model = model_of(args)?;
    let hw = hw_of(args)?;
    let plan = customize(&model, &hw, &CustomizeOptions::default())?;
    let design = cat::codegen::generate(&plan);
    design
        .validate(plan.plio_aie)
        .map_err(|e| anyhow!("generated design invalid: {e}"))?;
    if args.flag("json") {
        println!("{}", design.to_json());
    } else {
        println!(
            "// {} PUs, {} AIE cores, {} array columns\n",
            design.pus.len(),
            design.total_cores(),
            design.cols_used
        );
        print!("{}", design.render_graph_source());
    }
    Ok(())
}
