//! Versal ACAP board descriptors (paper Table III "intrinsic hardware
//! parameters" + §V.A hardware setup).

use std::collections::BTreeMap;

use crate::util::json::Json;
use anyhow::{anyhow, Result};

/// The board's **shared memory-path pools**: the off-chip DRAM bandwidth
/// and the host PCIe link that every co-resident accelerator on one
/// physical part draws from.  A single deployment owns both outright —
/// its simulated profile already reflects whatever rate it achieves —
/// but a *partitioned* fleet (`cat serve --partition`) shares them, and
/// the serving layer negotiates per-member bandwidth grants against
/// these pools (see `serve::links`), throttling slices proportionally
/// when the joint demand oversubscribes a pool.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SharedLinkModel {
    /// Off-chip DRAM bandwidth pool (GB/s).
    pub dram_gbps: f64,
    /// Host PCIe link bandwidth pool (GB/s), full duplex aggregate.
    pub pcie_gbps: f64,
}

impl SharedLinkModel {
    /// The pools of one physical board.
    pub fn of(hw: &HardwareConfig) -> SharedLinkModel {
        SharedLinkModel { dram_gbps: hw.dram_bw_gbps, pcie_gbps: hw.pcie_bw_gbps }
    }

    /// True when both pools are usable widths: positive and finite.
    /// `Fleet::select_partitioned` rejects anything else up front —
    /// a zero-width pool negotiates to an infinite stretch, which the
    /// ledger reports loudly (null oversubscription/stretch in JSON)
    /// but the deploy path refuses to serve on.
    pub fn is_positive_finite(&self) -> bool {
        let ok = |v: f64| v.is_finite() && v > 0.0;
        ok(self.dram_gbps) && ok(self.pcie_gbps)
    }

    /// The pools after a degradation event: each scaled by a factor in
    /// `(0, 1]` (fault injection narrows links, it never widens them —
    /// the same direction `mem_throttle` is validated to).
    pub fn scaled(&self, dram_scale: f64, pcie_scale: f64) -> SharedLinkModel {
        debug_assert!(dram_scale > 0.0 && dram_scale <= 1.0, "dram_scale {dram_scale}");
        debug_assert!(pcie_scale > 0.0 && pcie_scale <= 1.0, "pcie_scale {pcie_scale}");
        SharedLinkModel {
            dram_gbps: self.dram_gbps * dram_scale,
            pcie_gbps: self.pcie_gbps * pcie_scale,
        }
    }
}

/// Calibrated power-model coefficients (see `sim::power`).
#[derive(Debug, Clone, PartialEq)]
pub struct PowerModelParams {
    /// Board static power (W): NoC, DDR controllers, shell.
    pub static_w: f64,
    /// Per *running* AIE core (W) at full MM duty.
    pub aie_active_w: f64,
    /// Per *deployed but idle* AIE core (W): clocked, waiting.
    pub aie_idle_w: f64,
    /// PL dynamic power per 100K LUTs at 300 MHz (W).
    pub pl_per_100k_lut_w: f64,
    /// DRAM I/O power per GB/s of achieved bandwidth (W).
    pub dram_per_gbps_w: f64,
}

/// One Versal ACAP part + board.
#[derive(Debug, Clone, PartialEq)]
pub struct HardwareConfig {
    pub name: String,
    /// Total AIE tiles on the device (`Total_AIE`).
    pub total_aie: usize,
    /// AIE array clock (GHz). Paper Table VII: 1.25 GHz on VCK5000.
    pub aie_freq_ghz: f64,
    /// PL fabric clock (MHz). Paper: 300 MHz.
    pub pl_freq_mhz: f64,
    /// AIE data ("window") memory per tile, bytes (`M_Window`). 32 KiB.
    pub window_bytes: usize,
    /// int8 MACs/cycle one AIE core sustains on the MM inner loop.
    /// Calibrated: paper's 64-core MM-only throughput is 10 TOPS
    /// -> 156 GOPS/core / (2 * 1.25 GHz) ~= 64 MACs/cycle sustained.
    pub aie_macs_per_cycle: usize,
    /// PLIO stream width (bits) at PL clock.
    pub plio_bits: usize,
    /// Total PL on-chip SRAM (bytes) — `Total_Buffer` (23.9 MB on VCK5000).
    pub onchip_sram_bytes: usize,
    /// Off-chip DRAM bandwidth (GB/s).
    pub dram_bw_gbps: f64,
    /// Host PCIe link bandwidth (GB/s).  VCK5000: Gen3 x16, ~16 GB/s.
    pub pcie_bw_gbps: f64,
    /// Off-chip DRAM capacity (bytes).
    pub dram_bytes: usize,
    /// PL resource pools (for the Table V estimator).
    pub pl_luts: usize,
    pub pl_ffs: usize,
    pub pl_brams: usize,
    pub pl_urams: usize,
    /// Max pipeline depth a PRG chain may reach before the fully-pipelined
    /// mode stops paying off (`PRG_MAX_Pipeline_Depth`, paper §V.B: 4).
    pub prg_max_pipeline_depth: usize,
    /// Shared memory-path throttle on this part's stream movers
    /// (fraction of the nominal rate; `1.0` = uncontended, the invariant
    /// for every whole physical board).  Board *slices* handed to
    /// co-resident partition members carry their negotiated
    /// proportional-share factor here (`serve::links`); the scheduler's
    /// PU timing stretches the send/receive phases by `1/mem_throttle`
    /// while compute is unaffected, so contention flows through the DES
    /// — and both engines (`sim::run` / `sim::run_exact`) — identically.
    pub mem_throttle: f64,
    pub power: PowerModelParams,
}

impl HardwareConfig {
    /// The VCK5000 development card (paper's platform): 400 usable AIE
    /// cores, 145 TOPS Int8 peak, 23.9 MB on-chip SRAM @ 23.5 TB/s,
    /// 16 GB DDR @ 102.4 GB/s.
    pub fn vck5000() -> Self {
        HardwareConfig {
            name: "vck5000".into(),
            total_aie: 400,
            aie_freq_ghz: 1.25,
            pl_freq_mhz: 300.0,
            window_bytes: 32 * 1024,
            aie_macs_per_cycle: 64,
            plio_bits: 128,
            onchip_sram_bytes: (23.9 * 1024.0 * 1024.0) as usize,
            dram_bw_gbps: 102.4,
            pcie_bw_gbps: 16.0,
            dram_bytes: 16 << 30,
            pl_luts: 899_840,
            pl_ffs: 1_799_680,
            pl_brams: 967,
            pl_urams: 463,
            prg_max_pipeline_depth: 4,
            mem_throttle: 1.0,
            power: PowerModelParams {
                // calibrated against Table VI: (352 running-avg AIE, 67.6 W),
                // (352, 61.5 W ViT), (64, 16.2 W limited)
                static_w: 4.5,
                aie_active_w: 0.165,
                aie_idle_w: 0.055,
                pl_per_100k_lut_w: 2.2,
                dram_per_gbps_w: 0.035,
            },
        }
    }

    /// The VCK190 evaluation board (CHARM / SSR's platform).
    pub fn vck190() -> Self {
        HardwareConfig {
            name: "vck190".into(),
            total_aie: 400,
            aie_freq_ghz: 1.0,
            pl_freq_mhz: 230.0,
            ..Self::vck5000()
        }
    }

    /// The paper's BERT-Base(Limited AIE) setup: only 64 AIEs allowed,
    /// simulating a smaller Versal part.
    pub fn vck5000_limited(aies: usize) -> Self {
        let mut hw = Self::vck5000();
        hw.name = format!("vck5000-limited-{aies}");
        hw.total_aie = aies;
        hw
    }

    /// AIE single-core iteration time `T_Calc` for an `mmsz^3` tile (ns).
    pub fn t_calc_ns(&self, mmsz: usize) -> f64 {
        let macs = (mmsz * mmsz * mmsz) as f64;
        macs / self.aie_macs_per_cycle as f64 / self.aie_freq_ghz
    }

    /// PLIO time to move one `mmsz^2` int8 window `T_Window` (ns).
    pub fn t_window_ns(&self, mmsz: usize, bytes_per_elem: usize) -> f64 {
        let bytes = (mmsz * mmsz * bytes_per_elem) as f64;
        let bytes_per_ns = self.plio_bits as f64 / 8.0 * self.pl_freq_mhz * 1e-3;
        bytes / bytes_per_ns
    }

    /// The board's shared memory-path pools (DRAM + PCIe).
    pub fn links(&self) -> SharedLinkModel {
        SharedLinkModel::of(self)
    }

    /// Peak int8 throughput of the whole AIE array (TOPS).
    pub fn peak_tops(&self) -> f64 {
        2.0 * self.total_aie as f64 * self.aie_macs_per_cycle as f64 * self.aie_freq_ghz
            / 1e3
    }

    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("name".into(), Json::Str(self.name.clone()));
        let nums: &[(&str, f64)] = &[
            ("total_aie", self.total_aie as f64),
            ("aie_freq_ghz", self.aie_freq_ghz),
            ("pl_freq_mhz", self.pl_freq_mhz),
            ("window_bytes", self.window_bytes as f64),
            ("aie_macs_per_cycle", self.aie_macs_per_cycle as f64),
            ("plio_bits", self.plio_bits as f64),
            ("onchip_sram_bytes", self.onchip_sram_bytes as f64),
            ("dram_bw_gbps", self.dram_bw_gbps),
            ("pcie_bw_gbps", self.pcie_bw_gbps),
            ("dram_bytes", self.dram_bytes as f64),
            ("pl_luts", self.pl_luts as f64),
            ("pl_ffs", self.pl_ffs as f64),
            ("pl_brams", self.pl_brams as f64),
            ("pl_urams", self.pl_urams as f64),
            ("prg_max_pipeline_depth", self.prg_max_pipeline_depth as f64),
            ("mem_throttle", self.mem_throttle),
            ("power_static_w", self.power.static_w),
            ("power_aie_active_w", self.power.aie_active_w),
            ("power_aie_idle_w", self.power.aie_idle_w),
            ("power_pl_per_100k_lut_w", self.power.pl_per_100k_lut_w),
            ("power_dram_per_gbps_w", self.power.dram_per_gbps_w),
        ];
        for (k, v) in nums {
            m.insert(k.to_string(), Json::Num(*v));
        }
        Json::Obj(m)
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let f = |k: &str| -> Result<f64> {
            j.get(k)
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow!("hardware config missing '{k}'"))
        };
        let u = |k: &str| -> Result<usize> { Ok(f(k)? as usize) };
        // optional fields (absent in pre-link-model hardware files)
        let opt = |k: &str, default: f64| f(k).unwrap_or(default);
        let pcie_bw_gbps = opt("pcie_bw_gbps", 16.0);
        if !(pcie_bw_gbps.is_finite() && pcie_bw_gbps > 0.0) {
            return Err(anyhow!("hardware 'pcie_bw_gbps' must be positive, got {pcie_bw_gbps}"));
        }
        // a *file* always describes a whole part, and a whole part is
        // never pre-throttled — the (0, 1] range mirrors
        // deploy_plan_in_share's grant validation, and anything < 1
        // would silently slow every simulation of this board
        let mem_throttle = opt("mem_throttle", 1.0);
        if !(mem_throttle > 0.0 && mem_throttle <= 1.0) {
            return Err(anyhow!(
                "hardware 'mem_throttle' must be in (0, 1], got {mem_throttle}"
            ));
        }
        Ok(HardwareConfig {
            name: j
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("hardware config missing 'name'"))?
                .to_string(),
            total_aie: u("total_aie")?,
            aie_freq_ghz: f("aie_freq_ghz")?,
            pl_freq_mhz: f("pl_freq_mhz")?,
            window_bytes: u("window_bytes")?,
            aie_macs_per_cycle: u("aie_macs_per_cycle")?,
            plio_bits: u("plio_bits")?,
            onchip_sram_bytes: u("onchip_sram_bytes")?,
            dram_bw_gbps: f("dram_bw_gbps")?,
            pcie_bw_gbps,
            dram_bytes: u("dram_bytes")?,
            pl_luts: u("pl_luts")?,
            pl_ffs: u("pl_ffs")?,
            pl_brams: u("pl_brams")?,
            pl_urams: u("pl_urams")?,
            prg_max_pipeline_depth: u("prg_max_pipeline_depth")?,
            mem_throttle,
            power: PowerModelParams {
                static_w: f("power_static_w")?,
                aie_active_w: f("power_aie_active_w")?,
                aie_idle_w: f("power_aie_idle_w")?,
                pl_per_100k_lut_w: f("power_pl_per_100k_lut_w")?,
                dram_per_gbps_w: f("power_dram_per_gbps_w")?,
            },
        })
    }

    /// Resolve a named preset or a JSON file path.
    pub fn resolve(spec: &str) -> Result<Self> {
        match spec {
            "vck5000" => Ok(Self::vck5000()),
            "vck190" => Ok(Self::vck190()),
            s if s.starts_with("vck5000-limited-") => {
                let n: usize = s["vck5000-limited-".len()..]
                    .parse()
                    .map_err(|_| anyhow!("bad limited-AIE count in '{s}'"))?;
                Ok(Self::vck5000_limited(n))
            }
            path if path.ends_with(".json") => {
                Self::from_json(&super::load_json(path)?)
            }
            other => Err(anyhow!(
                "unknown hardware '{other}' (try vck5000, vck190, \
                 vck5000-limited-<n>, or a .json path)"
            )),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vck5000_peak_matches_datasheet_order() {
        let hw = HardwareConfig::vck5000();
        // 2 * 400 * 64 * 1.25 = 64 TOPS sustained-MM peak; the datasheet's
        // 145 TOPS is the marketing peak (int8 vector peak), our model peak
        // is the *sustained* MM roofline the paper's 150 GOPS/AIE implies.
        assert!((hw.peak_tops() - 64.0).abs() < 1e-9);
    }

    #[test]
    fn t_calc_t_window_ratio_near_4() {
        // Eq. 4 cross-check: T_Calc / T_Window ~= 4 on VCK5000 (the paper
        // reaches PLIO_AIE = 4; double buffering absorbs the ~4% shortfall
        // — see customize::eq4_plio_aie).
        let hw = HardwareConfig::vck5000();
        let ratio = hw.t_calc_ns(64) / hw.t_window_ns(64, 1);
        assert!((3.5..=4.5).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn json_roundtrip() {
        let hw = HardwareConfig::vck5000();
        let j = hw.to_json();
        let back = HardwareConfig::from_json(&j).unwrap();
        assert_eq!(hw, back);
    }

    #[test]
    fn resolve_presets() {
        assert_eq!(HardwareConfig::resolve("vck5000").unwrap().total_aie, 400);
        assert_eq!(
            HardwareConfig::resolve("vck5000-limited-64").unwrap().total_aie,
            64
        );
        assert!(HardwareConfig::resolve("nope").is_err());
    }

    #[test]
    fn limited_keeps_other_params() {
        let hw = HardwareConfig::vck5000_limited(64);
        assert_eq!(hw.total_aie, 64);
        assert_eq!(hw.aie_freq_ghz, 1.25);
    }

    #[test]
    fn boards_are_uncontended_and_expose_link_pools() {
        for hw in [HardwareConfig::vck5000(), HardwareConfig::vck190()] {
            assert_eq!(hw.mem_throttle, 1.0, "{}: whole boards never throttle", hw.name);
            let links = hw.links();
            assert_eq!(links.dram_gbps, hw.dram_bw_gbps);
            assert_eq!(links.pcie_gbps, hw.pcie_bw_gbps);
            assert!(links.pcie_gbps > 0.0 && links.pcie_gbps < links.dram_gbps);
        }
    }

    #[test]
    fn pre_link_model_json_defaults_the_new_fields() {
        // hardware files written before the link model lack pcie_bw_gbps
        // and mem_throttle — loading them must not error
        let mut j = HardwareConfig::vck5000().to_json();
        if let Json::Obj(m) = &mut j {
            m.remove("pcie_bw_gbps");
            m.remove("mem_throttle");
        }
        let hw = HardwareConfig::from_json(&j).unwrap();
        assert_eq!(hw.pcie_bw_gbps, 16.0);
        assert_eq!(hw.mem_throttle, 1.0);
    }

    #[test]
    fn out_of_range_link_fields_are_rejected_on_load() {
        let set = |key: &str, v: f64| {
            let mut j = HardwareConfig::vck5000().to_json();
            if let Json::Obj(m) = &mut j {
                m.insert(key.into(), Json::Num(v));
            }
            HardwareConfig::from_json(&j)
        };
        assert!(set("pcie_bw_gbps", 0.0).is_err());
        assert!(set("pcie_bw_gbps", -4.0).is_err());
        assert!(set("mem_throttle", 0.0).is_err(), "zero throttle = infinite stream times");
        assert!(set("mem_throttle", 1.5).is_err(), "a file cannot widen the memory path");
        assert!(set("mem_throttle", 0.5).is_ok(), "in-range values still load");
    }
}
