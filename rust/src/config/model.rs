//! Transformer model configuration information (paper Table IV).

use std::collections::BTreeMap;

use crate::util::json::Json;
use anyhow::{anyhow, Result};

/// One Transformer encoder model, as CAT sees it.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ModelConfig {
    pub name: String,
    /// `Head` — number of attention heads.
    pub heads: usize,
    /// `Embed_dim`.
    pub embed_dim: usize,
    /// `Dff` — FFN hidden dimension.
    pub dff: usize,
    /// `L` — input sequence length (logical, pre-padding).
    pub seq_len: usize,
    /// Encoder layer count.
    pub layers: usize,
    /// Data width in bits (8 = the paper's Int8 models).
    pub bits: usize,
}

impl ModelConfig {
    /// BERT-Base with L fixed to 256 (paper §V.A benchmark 1).
    pub fn bert_base() -> Self {
        ModelConfig {
            name: "bert-base".into(),
            heads: 12,
            embed_dim: 768,
            dff: 3072,
            seq_len: 256,
            layers: 12,
            bits: 8,
        }
    }

    /// ViT-Base, L = 197 (196 patches + CLS; paper §V.A benchmark 2).
    pub fn vit_base() -> Self {
        ModelConfig {
            name: "vit-base".into(),
            heads: 12,
            embed_dim: 768,
            dff: 3072,
            seq_len: 197,
            layers: 12,
            bits: 8,
        }
    }

    pub fn head_dim(&self) -> usize {
        self.embed_dim / self.heads
    }

    /// L padded to a multiple of the AIE tile edge (the paper pads ViT's
    /// 197 -> 256 because `MMSZ_AIE = 64`).
    pub fn padded_seq_len(&self, mmsz: usize) -> usize {
        self.seq_len.div_ceil(mmsz) * mmsz
    }

    /// Fraction of padded MHA work that is useful (ViT pays a padding tax —
    /// §V.D "a part of the throughput is occupied by the padded data").
    pub fn useful_fraction(&self, mmsz: usize) -> f64 {
        self.seq_len as f64 / self.padded_seq_len(mmsz) as f64
    }

    pub fn bytes_per_elem(&self) -> usize {
        self.bits.div_ceil(8)
    }

    /// int8 parameter bytes of one encoder layer (weights only).
    pub fn layer_weight_bytes(&self) -> usize {
        let e = self.embed_dim;
        let d = self.dff;
        (3 * e * e + e * e + e * d + d * e) * self.bytes_per_elem()
    }

    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("name".into(), Json::Str(self.name.clone()));
        for (k, v) in [
            ("heads", self.heads),
            ("embed_dim", self.embed_dim),
            ("dff", self.dff),
            ("seq_len", self.seq_len),
            ("layers", self.layers),
            ("bits", self.bits),
        ] {
            m.insert(k.to_string(), Json::Num(v as f64));
        }
        Json::Obj(m)
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let u = |k: &str| -> Result<usize> {
            j.get(k)
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow!("model config missing '{k}'"))
        };
        let cfg = ModelConfig {
            name: j
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("model config missing 'name'"))?
                .to_string(),
            heads: u("heads")?,
            embed_dim: u("embed_dim")?,
            dff: u("dff")?,
            seq_len: u("seq_len")?,
            layers: u("layers")?,
            bits: u("bits")?,
        };
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn validate(&self) -> Result<()> {
        if self.heads == 0 || self.embed_dim == 0 || self.dff == 0 {
            return Err(anyhow!("model dims must be positive"));
        }
        if self.embed_dim % self.heads != 0 {
            return Err(anyhow!(
                "embed_dim {} not divisible by heads {}",
                self.embed_dim,
                self.heads
            ));
        }
        if self.seq_len == 0 || self.layers == 0 {
            return Err(anyhow!("seq_len and layers must be positive"));
        }
        if !matches!(self.bits, 8 | 16 | 32) {
            return Err(anyhow!("bits must be 8, 16 or 32"));
        }
        Ok(())
    }

    /// Resolve a named preset or a JSON file path.
    pub fn resolve(spec: &str) -> Result<Self> {
        match spec {
            "bert-base" | "bert" => Ok(Self::bert_base()),
            "vit-base" | "vit" => Ok(Self::vit_base()),
            path if path.ends_with(".json") => {
                Self::from_json(&super::load_json(path)?)
            }
            other => Err(anyhow!(
                "unknown model '{other}' (try bert-base, vit-base, or a .json path)"
            )),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_match_table_iv() {
        let b = ModelConfig::bert_base();
        assert_eq!((b.heads, b.embed_dim, b.dff, b.seq_len, b.layers), (12, 768, 3072, 256, 12));
        let v = ModelConfig::vit_base();
        assert_eq!(v.seq_len, 197);
        assert_eq!(v.head_dim(), 64);
    }

    #[test]
    fn vit_pads_to_256() {
        let v = ModelConfig::vit_base();
        assert_eq!(v.padded_seq_len(64), 256);
        assert!((v.useful_fraction(64) - 197.0 / 256.0).abs() < 1e-12);
        let b = ModelConfig::bert_base();
        assert_eq!(b.padded_seq_len(64), 256);
        assert_eq!(b.useful_fraction(64), 1.0);
    }

    #[test]
    fn weight_bytes() {
        let b = ModelConfig::bert_base();
        // 3*768^2 + 768^2 + 2*768*3072 = 7_077_888 int8 bytes / layer
        assert_eq!(b.layer_weight_bytes(), 7_077_888);
    }

    #[test]
    fn json_roundtrip() {
        let b = ModelConfig::bert_base();
        assert_eq!(ModelConfig::from_json(&b.to_json()).unwrap(), b);
    }

    #[test]
    fn validate_rejects_bad() {
        let mut b = ModelConfig::bert_base();
        b.heads = 7; // 768 % 7 != 0
        assert!(b.validate().is_err());
        let mut c = ModelConfig::bert_base();
        c.bits = 12;
        assert!(c.validate().is_err());
    }
}
