//! Hardware and model configuration — the two inputs to the CAT framework.
//!
//! `HardwareConfig` describes a Versal ACAP part the way the paper's
//! Table III "intrinsic hardware parameters" does; `ModelConfig` is the
//! Transformer configuration information (Heads, Embed_dim, Dff, L).
//! Presets mirror the paper's experimental setup (Table IV + §V.A).

mod hardware;
mod model;

pub use hardware::{HardwareConfig, PowerModelParams, SharedLinkModel};
pub use model::ModelConfig;

use crate::util::json::Json;
use anyhow::{anyhow, Result};

/// Load either kind of config from a JSON file produced by `to_json`.
pub fn load_json(path: &str) -> Result<Json> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow!("reading {path}: {e}"))?;
    Json::parse(&text).map_err(|e| anyhow!("parsing {path}: {e}"))
}
