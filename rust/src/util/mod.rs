//! Small self-contained substrates (offline build: no clap/serde/criterion/
//! proptest available, so the repo carries its own minimal equivalents).

pub mod bench;
pub mod check;
pub mod cli;
pub mod json;
pub mod par;
pub mod prng;
pub mod table;
