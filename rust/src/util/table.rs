//! ASCII table rendering for the paper-table reproductions.

/// A simple left-aligned-text / right-aligned-number table.
#[derive(Debug, Clone, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.headers.len(),
            "row width != header width in table '{}'",
            self.title
        );
        self.rows.push(cells.to_vec());
        self
    }

    pub fn row_strs(&mut self, cells: &[&str]) -> &mut Self {
        let owned: Vec<String> = cells.iter().map(|s| s.to_string()).collect();
        self.row(&owned)
    }

    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.headers.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                w[i] = w[i].max(c.chars().count());
            }
        }
        w
    }

    /// Render to a boxed ASCII string.
    pub fn render(&self) -> String {
        let w = self.widths();
        let sep: String = {
            let mut s = String::from("+");
            for wi in &w {
                s.push_str(&"-".repeat(wi + 2));
                s.push('+');
            }
            s
        };
        let fmt_row = |cells: &[String]| {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                let pad = w[i] - c.chars().count();
                // right-align numeric-looking cells
                let numeric = c
                    .chars()
                    .all(|ch| ch.is_ascii_digit() || ".-+x%()/e".contains(ch))
                    && c.chars().any(|ch| ch.is_ascii_digit());
                if numeric {
                    s.push_str(&format!(" {}{} |", " ".repeat(pad), c));
                } else {
                    s.push_str(&format!(" {}{} |", c, " ".repeat(pad)));
                }
            }
            s
        };
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("{}\n", self.title));
        }
        out.push_str(&sep);
        out.push('\n');
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out.push_str(&sep);
        out.push('\n');
        out
    }
}

/// Format a float with engineering-friendly precision.
pub fn fmt_f(v: f64, digits: usize) -> String {
    format!("{v:.digits$}")
}

/// Format a ratio like the paper's "2.41x".
pub fn fmt_ratio(v: f64) -> String {
    format!("{v:.2}x")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_box() {
        let mut t = Table::new("T", &["name", "val"]);
        t.row_strs(&["a", "1.5"]);
        t.row_strs(&["bb", "20"]);
        let s = t.render();
        assert!(s.contains("| name | val |") || s.contains("| name |  val |"), "{s}");
        assert!(s.lines().count() >= 6);
        // all body lines equal width
        let widths: Vec<usize> = s.lines().skip(1).map(|l| l.chars().count()).collect();
        assert!(widths.windows(2).all(|w| w[0] == w[1]), "{s}");
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn rejects_ragged_rows() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row_strs(&["only-one"]);
    }

    #[test]
    fn ratio_format() {
        assert_eq!(fmt_ratio(2.414), "2.41x");
        assert_eq!(fmt_f(1.0 / 3.0, 3), "0.333");
    }
}
