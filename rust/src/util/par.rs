//! Minimal data-parallel map over scoped threads (rayon is not vendored
//! for offline builds).
//!
//! [`par_map`] fans a work list out over `min(len, parallelism)` scoped
//! worker threads with an atomic work-stealing cursor, preserving input
//! order in the output.  Design points:
//!
//! * results land in per-slot mutexes, each touched exactly once — no
//!   `unsafe`, no result reordering, no contention on the hot path;
//! * a panic inside `f` propagates out of the scope (so test assertions
//!   behave exactly as they would serially);
//! * `CAT_THREADS=<n>` caps the pool (set `CAT_THREADS=1` to force serial
//!   execution, e.g. when profiling a single design point).

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Mutex, OnceLock};

// OnceLock is only used for the process-wide thread budget; result slots
// use Mutex so `par_map` needs no `Sync` bound on outputs.

// Occupancy counters for the observability layer (`cat-obs-v1`
// `par.*` counters): coarse per-call atomics, three relaxed adds per
// fan-out — negligible next to spawning even one thread.
static PAR_CALLS: AtomicU64 = AtomicU64::new(0);
static PAR_ITEMS: AtomicU64 = AtomicU64::new(0);
static PAR_WORKER_LAUNCHES: AtomicU64 = AtomicU64::new(0);

/// `(calls, items, worker launches)` since process start.  The serial
/// fallback counts as one worker, so `launches / calls` is the average
/// occupancy a fan-out actually achieved.
pub fn par_stats() -> (u64, u64, u64) {
    (
        PAR_CALLS.load(Ordering::Relaxed),
        PAR_ITEMS.load(Ordering::Relaxed),
        PAR_WORKER_LAUNCHES.load(Ordering::Relaxed),
    )
}

/// Test hook: zero the occupancy counters.
pub fn reset_par_stats() {
    PAR_CALLS.store(0, Ordering::Relaxed);
    PAR_ITEMS.store(0, Ordering::Relaxed);
    PAR_WORKER_LAUNCHES.store(0, Ordering::Relaxed);
}

/// Worker-thread budget: `CAT_THREADS` if set, else the machine's
/// available parallelism.
pub fn thread_budget() -> usize {
    static BUDGET: OnceLock<usize> = OnceLock::new();
    *BUDGET.get_or_init(|| {
        if let Ok(v) = std::env::var("CAT_THREADS") {
            if let Ok(n) = v.parse::<usize>() {
                return n.max(1);
            }
        }
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    })
}

/// Apply `f` to every item, possibly in parallel, preserving order.
pub fn par_map<T, U, F>(items: Vec<T>, f: F) -> Vec<U>
where
    T: Send,
    U: Send,
    F: Fn(T) -> U + Sync,
{
    let n = items.len();
    let workers = thread_budget().min(n);
    let serial = n <= 1 || workers <= 1;
    PAR_CALLS.fetch_add(1, Ordering::Relaxed);
    PAR_ITEMS.fetch_add(n as u64, Ordering::Relaxed);
    PAR_WORKER_LAUNCHES.fetch_add(if serial { 1 } else { workers as u64 }, Ordering::Relaxed);
    if serial {
        return items.into_iter().map(f).collect();
    }
    // Items move into worker threads one at a time through per-slot
    // mutexes; each slot is touched exactly once (the cursor hands out
    // unique indices), so the locks are uncontended.  Mutex rather than
    // OnceLock for the results too: `Mutex<T>: Sync` needs only
    // `T: Send`, which keeps the bounds minimal.
    let slots: Vec<Mutex<Option<T>>> =
        items.into_iter().map(|t| Mutex::new(Some(t))).collect();
    let out: Vec<Mutex<Option<U>>> = (0..n).map(|_| Mutex::new(None)).collect();
    let cursor = AtomicUsize::new(0);
    let f = &f;
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let item = slots[i].lock().unwrap().take().expect("slot taken twice");
                let r = f(item);
                *out[i].lock().unwrap() = Some(r);
            });
        }
    });
    out.into_iter()
        .map(|cell| {
            cell.into_inner()
                .expect("result mutex poisoned")
                .expect("worker left a slot empty")
        })
        .collect()
}

/// [`par_map`] over a fallible `f`: stops delivering the first `Err` in
/// input order (all items still run; short-circuiting across threads is
/// not worth the coordination for our list sizes).
pub fn try_par_map<T, U, E, F>(items: Vec<T>, f: F) -> Result<Vec<U>, E>
where
    T: Send,
    U: Send,
    E: Send,
    F: Fn(T) -> Result<U, E> + Sync,
{
    par_map(items, f).into_iter().collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let v: Vec<usize> = (0..257).collect();
        let out = par_map(v, |x| x * 2);
        assert_eq!(out.len(), 257);
        for (i, x) in out.iter().enumerate() {
            assert_eq!(*x, i * 2);
        }
    }

    #[test]
    fn empty_and_single() {
        assert_eq!(par_map(Vec::<u32>::new(), |x| x), Vec::<u32>::new());
        assert_eq!(par_map(vec![41], |x| x + 1), vec![42]);
    }

    #[test]
    fn moves_non_clone_items() {
        struct NoClone(String);
        let items = vec![NoClone("a".into()), NoClone("b".into())];
        let out = par_map(items, |x| x.0);
        assert_eq!(out, vec!["a", "b"]);
    }

    #[test]
    fn try_variant_surfaces_first_error() {
        let r: Result<Vec<u32>, String> = try_par_map((0..16).collect(), |x| {
            if x == 5 {
                Err(format!("bad {x}"))
            } else {
                Ok(x)
            }
        });
        assert_eq!(r.unwrap_err(), "bad 5");
    }

    #[test]
    fn occupancy_counters_advance() {
        // other tests fan out concurrently, so assert deltas are at
        // least what this call contributes — never exact totals.
        let (calls0, items0, workers0) = par_stats();
        let _ = par_map((0..64).collect::<Vec<u64>>(), |x| x);
        let (calls1, items1, workers1) = par_stats();
        assert!(calls1 >= calls0 + 1);
        assert!(items1 >= items0 + 64);
        assert!(workers1 >= workers0 + 1);
    }

    #[test]
    fn actually_runs_on_many_threads_without_loss() {
        // 1000 trivial items: whatever the scheduling, every result lands.
        let out = par_map((0..1000).collect::<Vec<u64>>(), |x| x);
        let sum: u64 = out.iter().sum();
        assert_eq!(sum, 999 * 1000 / 2);
    }
}
