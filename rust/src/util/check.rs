//! Minimal property-based testing harness (proptest is not vendored for
//! offline builds).
//!
//! A property is a closure over a seeded [`Prng`](super::prng::Prng); the
//! harness runs it for N random cases and, on failure, reports the seed so
//! the case can be replayed deterministically:
//!
//! ```no_run
//! use cat::util::check::property;
//! property("addition commutes", 256, |rng| {
//!     let (a, b) = (rng.range(0, 100), rng.range(0, 100));
//!     if a + b == b + a { Ok(()) } else { Err(format!("{a} {b}")) }
//! });
//! ```
//! (`no_run`: doctest binaries bypass the crate's rpath config and cannot
//! load libxla_extension.so at run time.)

use super::prng::Prng;

/// Run `f` for `cases` random seeds; panic with the failing seed on error.
///
/// Set `CAT_CHECK_SEED=<n>` to replay a single failing case.
pub fn property<F>(name: &str, cases: u64, mut f: F)
where
    F: FnMut(&mut Prng) -> Result<(), String>,
{
    if let Ok(seed) = std::env::var("CAT_CHECK_SEED") {
        let seed: u64 = seed.parse().expect("CAT_CHECK_SEED must be a u64");
        let mut rng = Prng::new(seed);
        if let Err(msg) = f(&mut rng) {
            panic!("property '{name}' failed (replayed seed {seed}): {msg}");
        }
        return;
    }
    // Base seed derived from the property name so distinct properties
    // explore distinct corners, but runs stay reproducible.
    let base: u64 = name.bytes().fold(0xcbf29ce484222325u64, |h, b| {
        (h ^ b as u64).wrapping_mul(0x100000001b3)
    });
    for i in 0..cases {
        let seed = base.wrapping_add(i);
        let mut rng = Prng::new(seed);
        if let Err(msg) = f(&mut rng) {
            panic!(
                "property '{name}' failed on case {i}/{cases} \
                 (replay with CAT_CHECK_SEED={seed}): {msg}"
            );
        }
    }
}

/// Assert two floats agree to a relative tolerance (helper for properties).
pub fn close(a: f64, b: f64, rtol: f64) -> Result<(), String> {
    let denom = a.abs().max(b.abs()).max(1e-12);
    if (a - b).abs() / denom <= rtol {
        Ok(())
    } else {
        Err(format!("{a} !~ {b} (rtol {rtol})"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0;
        property("trivial", 50, |_| {
            n += 1;
            Ok(())
        });
        assert_eq!(n, 50);
    }

    #[test]
    #[should_panic(expected = "replay with CAT_CHECK_SEED=")]
    fn failing_property_reports_seed() {
        property("always-fails", 10, |_| Err("boom".into()));
    }

    #[test]
    fn close_tolerances() {
        assert!(close(1.0, 1.0000001, 1e-6).is_ok());
        assert!(close(1.0, 1.1, 1e-6).is_err());
        assert!(close(0.0, 0.0, 1e-9).is_ok());
    }
}
