//! Minimal timing harness for the `harness = false` benches (criterion is
//! not vendored for offline builds).  Median-of-N with warmup; prints one
//! line per benchmark in a stable, grep-able format.

use std::time::{Duration, Instant};

/// Timing statistics over the measured iterations.
#[derive(Debug, Clone, Copy)]
pub struct Stats {
    pub iters: u32,
    pub median: Duration,
    pub mean: Duration,
    pub min: Duration,
    pub max: Duration,
}

impl Stats {
    pub fn median_ns(&self) -> f64 {
        self.median.as_nanos() as f64
    }
}

/// Time `f` with `warmup` unmeasured and `iters` measured runs.
pub fn time<F: FnMut()>(warmup: u32, iters: u32, mut f: F) -> Stats {
    assert!(iters > 0);
    for _ in 0..warmup {
        f();
    }
    let mut samples: Vec<Duration> = Vec::with_capacity(iters as usize);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed());
    }
    samples.sort();
    let median = samples[samples.len() / 2];
    let mean = samples.iter().sum::<Duration>() / iters;
    Stats {
        iters,
        median,
        mean,
        min: samples[0],
        max: *samples.last().unwrap(),
    }
}

/// Time and report one benchmark row: `bench <name> ... median <t>`.
pub fn bench<F: FnMut()>(name: &str, warmup: u32, iters: u32, f: F) -> Stats {
    let s = time(warmup, iters, f);
    println!(
        "bench {name:<44} median {:>12} mean {:>12} min {:>12} (n={})",
        fmt_dur(s.median),
        fmt_dur(s.mean),
        fmt_dur(s.min),
        s.iters
    );
    s
}

/// Human duration: ns / µs / ms / s with 3 significant places.
pub fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos() as f64;
    if ns < 1e3 {
        format!("{ns:.0}ns")
    } else if ns < 1e6 {
        format!("{:.2}µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2}ms", ns / 1e6)
    } else {
        format!("{:.2}s", ns / 1e9)
    }
}

/// Prevent the optimizer from discarding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_ordering() {
        let s = time(1, 16, || {
            black_box((0..1000).sum::<u64>());
        });
        assert!(s.min <= s.median && s.median <= s.max);
        assert_eq!(s.iters, 16);
    }

    #[test]
    fn fmt_ranges() {
        assert_eq!(fmt_dur(Duration::from_nanos(500)), "500ns");
        assert!(fmt_dur(Duration::from_micros(1500)).ends_with("ms"));
        assert!(fmt_dur(Duration::from_secs(2)).ends_with('s'));
    }
}
