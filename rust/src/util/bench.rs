//! Minimal timing harness for the `harness = false` benches (criterion is
//! not vendored for offline builds).  Median-of-N with warmup; prints one
//! line per benchmark in a stable, grep-able format, and serializes to
//! the `BENCH_*.json` trajectory format via [`Stats::to_json`] /
//! [`write_json`] so perf regressions are machine-checkable.

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

use crate::util::json::Json;

/// Timing statistics over the measured iterations.
#[derive(Debug, Clone, Copy)]
pub struct Stats {
    pub iters: u32,
    pub median: Duration,
    pub mean: Duration,
    pub min: Duration,
    pub max: Duration,
}

impl Stats {
    pub fn median_ns(&self) -> f64 {
        self.median.as_nanos() as f64
    }

    /// Serialize as a `BENCH_*.json` row object.
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("iters".into(), Json::Num(self.iters as f64));
        m.insert("median_ns".into(), Json::Num(self.median.as_nanos() as f64));
        m.insert("mean_ns".into(), Json::Num(self.mean.as_nanos() as f64));
        m.insert("min_ns".into(), Json::Num(self.min.as_nanos() as f64));
        m.insert("max_ns".into(), Json::Num(self.max.as_nanos() as f64));
        Json::Obj(m)
    }
}

/// Assemble the standard `BENCH_*.json` document: named rows plus free-form
/// derived metrics (speedups, parity deviations, provenance notes).
pub fn bench_doc(
    bench_name: &str,
    rows: &[(String, Stats)],
    derived: BTreeMap<String, Json>,
) -> Json {
    let mut root = BTreeMap::new();
    root.insert("schema".into(), Json::Str("cat-bench-v1".into()));
    root.insert("bench".into(), Json::Str(bench_name.into()));
    let mut rowmap = BTreeMap::new();
    for (name, s) in rows {
        rowmap.insert(name.clone(), s.to_json());
    }
    root.insert("rows".into(), Json::Obj(rowmap));
    root.insert("derived".into(), Json::Obj(derived));
    Json::Obj(root)
}

/// Write a JSON document to disk (one line, trailing newline).
pub fn write_json(path: &str, doc: &Json) -> std::io::Result<()> {
    std::fs::write(path, format!("{doc}\n"))
}

/// Time `f` with `warmup` unmeasured and `iters` measured runs.
pub fn time<F: FnMut()>(warmup: u32, iters: u32, mut f: F) -> Stats {
    assert!(iters > 0);
    for _ in 0..warmup {
        f();
    }
    let mut samples: Vec<Duration> = Vec::with_capacity(iters as usize);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed());
    }
    samples.sort();
    let median = samples[samples.len() / 2];
    let mean = samples.iter().sum::<Duration>() / iters;
    Stats {
        iters,
        median,
        mean,
        min: samples[0],
        max: *samples.last().unwrap(),
    }
}

/// Time and report one benchmark row: `bench <name> ... median <t>`.
pub fn bench<F: FnMut()>(name: &str, warmup: u32, iters: u32, f: F) -> Stats {
    let s = time(warmup, iters, f);
    println!(
        "bench {name:<44} median {:>12} mean {:>12} min {:>12} (n={})",
        fmt_dur(s.median),
        fmt_dur(s.mean),
        fmt_dur(s.min),
        s.iters
    );
    s
}

/// Human duration: ns / µs / ms / s with 3 significant places.
pub fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos() as f64;
    if ns < 1e3 {
        format!("{ns:.0}ns")
    } else if ns < 1e6 {
        format!("{:.2}µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2}ms", ns / 1e6)
    } else {
        format!("{:.2}s", ns / 1e9)
    }
}

/// Prevent the optimizer from discarding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_ordering() {
        let s = time(1, 16, || {
            black_box((0..1000).sum::<u64>());
        });
        assert!(s.min <= s.median && s.median <= s.max);
        assert_eq!(s.iters, 16);
    }

    #[test]
    fn fmt_ranges() {
        assert_eq!(fmt_dur(Duration::from_nanos(500)), "500ns");
        assert!(fmt_dur(Duration::from_micros(1500)).ends_with("ms"));
        assert!(fmt_dur(Duration::from_secs(2)).ends_with('s'));
    }

    #[test]
    fn bench_doc_roundtrips() {
        let s = time(0, 4, || {
            black_box((0..100).sum::<u64>());
        });
        let mut derived = BTreeMap::new();
        derived.insert("speedup".to_string(), Json::Num(5.5));
        let doc = bench_doc("hotpath", &[("sim/x".to_string(), s)], derived);
        let parsed = Json::parse(&doc.to_string()).unwrap();
        assert_eq!(parsed.get("schema").unwrap().as_str(), Some("cat-bench-v1"));
        assert_eq!(
            parsed.path(&["rows", "sim/x", "iters"]).unwrap().as_usize(),
            Some(4)
        );
        assert_eq!(parsed.path(&["derived", "speedup"]).unwrap().as_f64(), Some(5.5));
    }
}
