//! Tiny CLI argument parser (clap is not vendored for offline builds).
//!
//! Grammar: `cat <subcommand> [--flag] [--key value] [positional...]`.

use std::collections::BTreeMap;

/// Parsed command-line arguments.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

/// Option names that take a value; everything else starting `--` is a flag.
pub fn parse(raw: impl IntoIterator<Item = String>, valued: &[&str]) -> Args {
    let mut args = Args::default();
    let mut it = raw.into_iter().peekable();
    while let Some(a) = it.next() {
        if let Some(name) = a.strip_prefix("--") {
            if let Some((k, v)) = name.split_once('=') {
                args.options.insert(k.to_string(), v.to_string());
            } else if valued.contains(&name) {
                match it.next() {
                    Some(v) => {
                        args.options.insert(name.to_string(), v);
                    }
                    None => {
                        args.flags.push(name.to_string());
                    }
                }
            } else {
                args.flags.push(name.to_string());
            }
        } else if args.subcommand.is_none() && args.positional.is_empty() {
            args.subcommand = Some(a);
        } else {
            args.positional.push(a);
        }
    }
    args
}

impl Args {
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn opt_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.opt(name).unwrap_or(default)
    }

    pub fn opt_usize(&self, name: &str, default: usize) -> usize {
        self.opt(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} expects an integer, got '{v}'")))
            .unwrap_or(default)
    }

    pub fn opt_f64(&self, name: &str, default: f64) -> f64 {
        self.opt(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} expects a number, got '{v}'")))
            .unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse_strs(s: &[&str], valued: &[&str]) -> Args {
        parse(s.iter().map(|x| x.to_string()), valued)
    }

    #[test]
    fn subcommand_and_positionals() {
        let a = parse_strs(&["simulate", "bert", "extra"], &[]);
        assert_eq!(a.subcommand.as_deref(), Some("simulate"));
        assert_eq!(a.positional, vec!["bert", "extra"]);
    }

    #[test]
    fn options_and_flags() {
        let a = parse_strs(
            &["run", "--batch", "16", "--verbose", "--hw=vck5000"],
            &["batch"],
        );
        assert_eq!(a.opt("batch"), Some("16"));
        assert_eq!(a.opt("hw"), Some("vck5000"));
        assert!(a.flag("verbose"));
        assert_eq!(a.opt_usize("batch", 1), 16);
        assert_eq!(a.opt_usize("missing", 4), 4);
    }

    #[test]
    fn equals_form_needs_no_valued_list() {
        let a = parse_strs(&["x", "--k=v"], &[]);
        assert_eq!(a.opt("k"), Some("v"));
    }

    #[test]
    fn unknown_valued_flag_keeps_its_argument_positional() {
        // "--mystery" is not in the valued list: it parses as a boolean
        // flag and "payload" stays a positional, not a swallowed value.
        let a = parse_strs(&["run", "--mystery", "payload"], &[]);
        assert!(a.flag("mystery"));
        assert_eq!(a.opt("mystery"), None);
        assert_eq!(a.positional, vec!["payload"]);
    }

    #[test]
    fn equals_and_space_forms_agree_for_valued_options() {
        let valued = &["model"];
        let a = parse_strs(&["x", "--model", "bert-base"], valued);
        let b = parse_strs(&["x", "--model=bert-base"], valued);
        assert_eq!(a.opt("model"), Some("bert-base"));
        assert_eq!(a.opt("model"), b.opt("model"));
        assert_eq!(a.positional, b.positional);
    }

    #[test]
    fn repeated_options_last_wins_and_flags_accumulate() {
        let a = parse_strs(
            &["x", "--batch", "8", "--batch", "16", "--v", "--v"],
            &["batch"],
        );
        assert_eq!(a.opt_usize("batch", 0), 16);
        assert!(a.flag("v"));
        assert_eq!(a.flags.iter().filter(|f| f.as_str() == "v").count(), 2);
        // equals form also overrides an earlier space form
        let b = parse_strs(&["x", "--hw", "vck190", "--hw=vck5000"], &["hw"]);
        assert_eq!(b.opt("hw"), Some("vck5000"));
    }

    #[test]
    fn trailing_valued_flag_without_value_degrades_to_flag() {
        let a = parse_strs(&["x", "--model"], &["model"]);
        assert!(a.flag("model"));
        assert_eq!(a.opt("model"), None);
    }

    #[test]
    fn serve_fleet_style_flag_mix() {
        // the `cat serve --rps` surface: fleet flags + the legacy serve
        // flags must coexist (--rps is the dispatch discriminator)
        let valued = &[
            "model", "hw", "batch", "requests", "seed", "slo-ms", "budget", "rps", "backends",
            "queue-cap",
        ];
        let a = parse_strs(
            &[
                "serve", "--rps", "1500", "--slo-ms=20", "--backends", "3", "--queue-cap",
                "32", "--requests", "256", "--batch", "8", "--seed", "7", "--json",
            ],
            valued,
        );
        assert_eq!(a.subcommand.as_deref(), Some("serve"));
        assert!((a.opt_f64("rps", 0.0) - 1500.0).abs() < 1e-12);
        assert!((a.opt_f64("slo-ms", 0.0) - 20.0).abs() < 1e-12);
        assert_eq!(a.opt_usize("backends", 0), 3);
        assert_eq!(a.opt_usize("queue-cap", 0), 32);
        assert_eq!(a.opt_usize("requests", 0), 256);
        assert_eq!(a.opt_usize("batch", 0), 8);
        assert_eq!(a.opt("seed"), Some("7"));
        assert!(a.flag("json"));
        assert!(a.positional.is_empty());
        // without --rps the same parse drives the legacy PJRT serve path
        let legacy = parse_strs(&["serve", "--requests", "32"], valued);
        assert_eq!(legacy.opt("rps"), None);
    }

    #[test]
    fn explore_style_flag_mix() {
        // the `cat explore` surface: several new valued flags + --json
        let a = parse_strs(
            &[
                "explore", "--model", "bert-base", "--max-cores", "64",
                "--slo-ms", "0.5", "--budget=128", "--json",
            ],
            &["model", "hw", "max-cores", "slo-ms", "budget"],
        );
        assert_eq!(a.subcommand.as_deref(), Some("explore"));
        assert_eq!(a.opt("model"), Some("bert-base"));
        assert_eq!(a.opt_usize("max-cores", 0), 64);
        assert!((a.opt_f64("slo-ms", 0.0) - 0.5).abs() < 1e-12);
        assert_eq!(a.opt_usize("budget", 0), 128);
        assert!(a.flag("json"));
        assert!(a.positional.is_empty());
    }
}
