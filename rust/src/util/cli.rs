//! Tiny CLI argument parser (clap is not vendored for offline builds).
//!
//! Grammar: `cat <subcommand> [--flag] [--key value] [positional...]`.

use std::collections::BTreeMap;

/// Parsed command-line arguments.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

/// Option names that take a value; everything else starting `--` is a flag.
pub fn parse(raw: impl IntoIterator<Item = String>, valued: &[&str]) -> Args {
    let mut args = Args::default();
    let mut it = raw.into_iter().peekable();
    while let Some(a) = it.next() {
        if let Some(name) = a.strip_prefix("--") {
            if let Some((k, v)) = name.split_once('=') {
                args.options.insert(k.to_string(), v.to_string());
            } else if valued.contains(&name) {
                match it.next() {
                    Some(v) => {
                        args.options.insert(name.to_string(), v);
                    }
                    None => {
                        args.flags.push(name.to_string());
                    }
                }
            } else {
                args.flags.push(name.to_string());
            }
        } else if args.subcommand.is_none() && args.positional.is_empty() {
            args.subcommand = Some(a);
        } else {
            args.positional.push(a);
        }
    }
    args
}

impl Args {
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn opt_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.opt(name).unwrap_or(default)
    }

    pub fn opt_usize(&self, name: &str, default: usize) -> usize {
        self.opt(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} expects an integer, got '{v}'")))
            .unwrap_or(default)
    }

    pub fn opt_f64(&self, name: &str, default: f64) -> f64 {
        self.opt(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} expects a number, got '{v}'")))
            .unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse_strs(s: &[&str], valued: &[&str]) -> Args {
        parse(s.iter().map(|x| x.to_string()), valued)
    }

    #[test]
    fn subcommand_and_positionals() {
        let a = parse_strs(&["simulate", "bert", "extra"], &[]);
        assert_eq!(a.subcommand.as_deref(), Some("simulate"));
        assert_eq!(a.positional, vec!["bert", "extra"]);
    }

    #[test]
    fn options_and_flags() {
        let a = parse_strs(
            &["run", "--batch", "16", "--verbose", "--hw=vck5000"],
            &["batch"],
        );
        assert_eq!(a.opt("batch"), Some("16"));
        assert_eq!(a.opt("hw"), Some("vck5000"));
        assert!(a.flag("verbose"));
        assert_eq!(a.opt_usize("batch", 1), 16);
        assert_eq!(a.opt_usize("missing", 4), 4);
    }

    #[test]
    fn equals_form_needs_no_valued_list() {
        let a = parse_strs(&["x", "--k=v"], &[]);
        assert_eq!(a.opt("k"), Some("v"));
    }
}
