//! Deterministic PRNG (xoshiro256**) — rand is not vendored offline.
//!
//! Used by workload generators, synthetic weight creation, and the
//! property-test harness. Seeded explicitly everywhere for reproducibility.

/// xoshiro256** by Blackman & Vigna (public domain reference algorithm).
#[derive(Debug, Clone)]
pub struct Prng {
    s: [u64; 4],
}

impl Prng {
    /// Seed via splitmix64 so any u64 (including 0) gives a good state.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Prng { s: [next(), next(), next(), next()] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[lo, hi)`.
    pub fn f32_range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f64() as f32
    }

    /// Uniform in `[0, n)`; unbiased via rejection.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        let zone = u64::MAX - u64::MAX % n;
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    /// Uniform usize in `[lo, hi]` inclusive.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as usize
    }

    /// Random int8 in `[-127, 127]` (the quantized-tensor domain).
    pub fn i8(&mut self) -> i8 {
        (self.below(255) as i64 - 127) as i8
    }

    /// Standard normal via Box-Muller (good enough for synthetic weights).
    pub fn gaussian(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }

    pub fn bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Prng::new(42);
        let mut b = Prng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(Prng::new(1).next_u64(), Prng::new(2).next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut p = Prng::new(7);
        for _ in 0..10_000 {
            let v = p.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut p = Prng::new(3);
        let mut seen = [false; 5];
        for _ in 0..1000 {
            seen[p.below(5) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn range_inclusive() {
        let mut p = Prng::new(9);
        let mut lo_seen = false;
        let mut hi_seen = false;
        for _ in 0..2000 {
            let v = p.range(3, 6);
            assert!((3..=6).contains(&v));
            lo_seen |= v == 3;
            hi_seen |= v == 6;
        }
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn gaussian_moments() {
        let mut p = Prng::new(11);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| p.gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.1, "var={var}");
    }

    #[test]
    fn i8_range() {
        let mut p = Prng::new(5);
        for _ in 0..1000 {
            let v = p.i8();
            assert!((-127..=127).contains(&v));
        }
    }
}
