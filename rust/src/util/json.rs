//! Minimal JSON parser/printer (serde is not vendored for offline builds).
//!
//! Supports the full JSON grammar minus exotic number forms; used for the
//! artifact manifest, hardware/model config files, and plan export.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(src: &str) -> Result<Json, JsonError> {
        let mut p = Parser { src: src.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.src.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(a) => a.get(i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().filter(|n| *n >= 0.0 && n.fract() == 0.0).map(|n| n as u64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Convenience: `v.path(&["a", "b"])` == `v.get("a")?.get("b")`.
    pub fn path(&self, keys: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in keys {
            cur = cur.get(k)?;
        }
        Some(cur)
    }
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    src: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.src.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.src[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return Err(self.err("expected ',' or '}'"));
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut arr = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(arr));
        }
        loop {
            self.skip_ws();
            arr.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(arr)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return Err(self.err("expected ',' or ']'"));
                }
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000C}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let d = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (d as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => out.push(c as char),
                Some(c) => {
                    // re-assemble UTF-8 multibyte sequences
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    let start = self.pos - 1;
                    for _ in 1..len {
                        self.bump();
                    }
                    let s = std::str::from_utf8(&self.src[start..self.pos])
                        .map_err(|_| self.err("bad utf-8"))?;
                    out.push_str(s);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.src[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

impl fmt::Display for Json {
    /// Compact JSON output (stable key order via BTreeMap).
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if !n.is_finite() {
                    // bare `inf`/`NaN` is invalid JSON (and this
                    // parser rejects it); non-finite values degrade to
                    // null so every emitted document stays parseable
                    write!(f, "null")
                } else if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\t' => write!(f, "\\t")?,
                        '\r' => write!(f, "\\r")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{v}", Json::Str(k.clone()))?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.path(&["a"]).unwrap().idx(1).unwrap().as_f64(), Some(2.0));
        assert_eq!(
            v.get("a").unwrap().idx(2).unwrap().get("b").unwrap().as_str(),
            Some("c")
        );
        assert_eq!(v.get("d"), Some(&Json::Null));
    }

    #[test]
    fn parse_unicode_escape() {
        assert_eq!(Json::parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn parse_utf8_passthrough() {
        assert_eq!(Json::parse("\"héllo\"").unwrap(), Json::Str("héllo".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"a":[1,2.5,"x"],"b":{"c":true,"d":null}}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn non_finite_nums_serialize_as_null_and_round_trip() {
        // bare `inf` is invalid JSON and this parser rejects it; the
        // serializer must never emit it
        for v in [f64::INFINITY, f64::NEG_INFINITY, f64::NAN] {
            assert_eq!(Json::Num(v).to_string(), "null");
        }
        let mut m = std::collections::BTreeMap::new();
        m.insert("stretch".to_string(), Json::Num(f64::INFINITY));
        m.insert("throttle".to_string(), Json::Num(0.0));
        let s = Json::Obj(m).to_string();
        assert_eq!(s, r#"{"stretch":null,"throttle":0}"#);
        let back = Json::parse(&s).unwrap();
        assert_eq!(back.get("stretch"), Some(&Json::Null));
        // finite values are untouched — the byte-identity contract
        assert_eq!(Json::Num(1.5).to_string(), "1.5");
        assert_eq!(Json::Num(3.0).to_string(), "3");
    }

    #[test]
    fn as_usize_rejects_fractions() {
        assert_eq!(Json::parse("2.5").unwrap().as_usize(), None);
        assert_eq!(Json::parse("-3").unwrap().as_u64(), None);
        assert_eq!(Json::parse("7").unwrap().as_usize(), Some(7));
    }
}
