//! Board power model (substitute for AMD Power Design Manager).
//!
//! `P = static + n_running·p_active + (n_deployed − n_running)·p_idle
//!      + PL activity + DRAM I/O`
//!
//! Coefficients live in [`PowerModelParams`](crate::config::PowerModelParams)
//! and are calibrated against the paper's three operating points
//! (Table VI): BERT-Base 67.56 W, ViT-Base 61.46 W, Limited-AIE 16.17 W.

use crate::arch::PlResources;
use crate::config::HardwareConfig;

/// Inputs to one power evaluation.
#[derive(Debug, Clone, Copy)]
pub struct PowerBreakdownInput {
    /// AIE cores deployed (clocked).
    pub aie_deployed: usize,
    /// Average running AIE cores over the measurement window.
    pub aie_running_avg: f64,
    /// PL resources in use (Table V overall row).
    pub pl: PlResources,
    /// Average DRAM bandwidth achieved (GB/s).
    pub dram_gbps: f64,
}

/// Itemized power result (W).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PowerBreakdown {
    pub static_w: f64,
    pub aie_active_w: f64,
    pub aie_idle_w: f64,
    pub pl_w: f64,
    pub dram_w: f64,
}

impl PowerBreakdown {
    pub fn total_w(&self) -> f64 {
        self.static_w + self.aie_active_w + self.aie_idle_w + self.pl_w + self.dram_w
    }
}

/// Evaluate the calibrated model.
pub fn power(hw: &HardwareConfig, input: &PowerBreakdownInput) -> PowerBreakdown {
    let p = &hw.power;
    let running = input.aie_running_avg.min(input.aie_deployed as f64);
    let idle = input.aie_deployed as f64 - running;
    PowerBreakdown {
        static_w: p.static_w,
        aie_active_w: running * p.aie_active_w,
        aie_idle_w: idle * p.aie_idle_w,
        pl_w: input.pl.luts as f64 / 100_000.0 * p.pl_per_100k_lut_w,
        dram_w: input.dram_gbps * p.dram_per_gbps_w,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bert_like() -> PowerBreakdownInput {
        PowerBreakdownInput {
            aie_deployed: 352,
            aie_running_avg: 0.87 * 352.0,
            pl: PlResources { luts: 232_300, ffs: 290_500, brams: 940, urams: 360 },
            dram_gbps: 12.0,
        }
    }

    #[test]
    fn bert_operating_point_near_paper() {
        let hw = HardwareConfig::vck5000();
        let p = power(&hw, &bert_like()).total_w();
        // paper Table VI: 67.555 W — calibrate within 15%
        assert!((p - 67.555).abs() / 67.555 < 0.15, "P = {p}");
    }

    #[test]
    fn limited_aie_operating_point_near_paper() {
        let hw = HardwareConfig::vck5000();
        let input = PowerBreakdownInput {
            aie_deployed: 64,
            aie_running_avg: 64.0,
            pl: PlResources { luts: 48_400, ffs: 73_100, brams: 320, urams: 0 },
            dram_gbps: 6.0,
        };
        let p = power(&hw, &input).total_w();
        // paper Table VI: 16.168 W
        assert!((p - 16.168).abs() / 16.168 < 0.20, "P = {p}");
    }

    #[test]
    fn more_running_cores_cost_more() {
        let hw = HardwareConfig::vck5000();
        let mut a = bert_like();
        let mut b = bert_like();
        a.aie_running_avg = 100.0;
        b.aie_running_avg = 300.0;
        assert!(power(&hw, &b).total_w() > power(&hw, &a).total_w());
    }

    #[test]
    fn running_clamped_to_deployed() {
        let hw = HardwareConfig::vck5000();
        let mut i = bert_like();
        i.aie_running_avg = 10_000.0;
        let p = power(&hw, &i);
        assert!(p.aie_idle_w.abs() < 1e-9);
    }
}
