//! Dataflow scenario description consumed by the simulator engine.
//!
//! A scenario is a DAG of processor **nodes** (PRGs holding AIE MM PU
//! instances, or PL operator modules) connected by finite **buffer edges**
//! (on-chip streams/caches).  The scheduler (`crate::sched`) builds one
//! scenario per EDPU stage from an `AcceleratorPlan`; Table II ablations
//! build variants directly.

/// Time unit used throughout the simulator: nanoseconds as f64 at the API,
/// picoseconds as u64 inside the engine (exact heap ordering).
pub const PS_PER_NS: u64 = 1_000;

/// One PU instance inside a node: per-invocation phase times.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PuTiming {
    /// PLIO send of the operand windows into AIE local memory (ns).
    pub t_send_ns: f64,
    /// AIE array compute time for one invocation (ns).
    pub t_calc_ns: f64,
    /// PLIO receive of the result windows (ns).
    pub t_recv_ns: f64,
}

impl PuTiming {
    /// Steady-state initiation interval: pipelined PL organization
    /// overlaps the three phases (double buffering), serial sums them
    /// (paper Observation 1).
    pub fn beat_ns(&self, pipelined: bool) -> f64 {
        if pipelined {
            self.t_send_ns.max(self.t_calc_ns).max(self.t_recv_ns)
        } else {
            self.t_send_ns + self.t_calc_ns + self.t_recv_ns
        }
    }

    /// First-invocation latency (pipeline fill).
    pub fn fill_ns(&self) -> f64 {
        self.t_send_ns + self.t_calc_ns + self.t_recv_ns
    }
}

/// A node port: which edge it connects to and how many bytes one
/// invocation consumes from (or produces into) that edge.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PortSpec {
    pub edge: usize,
    pub bytes_per_inv: u64,
}

/// A processor node (a PRG, or a PL pipeline module).
#[derive(Debug, Clone, PartialEq)]
pub struct NodeSpec {
    pub name: String,
    /// PU instances; each can hold one in-flight invocation (double
    /// buffering is captured by `beat < fill`).
    pub pus: Vec<PuTiming>,
    /// Internal send/compute/receive organization (Observation 1).
    pub pipelined: bool,
    /// Total invocations this node must complete.
    pub n_inv: usize,
    /// Cores this node's PUs occupy (for utilization accounting).
    pub cores: usize,
    pub inputs: Vec<PortSpec>,
    pub outputs: Vec<PortSpec>,
}

/// A finite buffer edge, optionally with a PL operator on it.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EdgeSpec {
    /// On-chip buffer capacity in bytes (backpressure bound).
    pub capacity_bytes: u64,
    /// Extra latency a grain suffers crossing this edge (the PL operator
    /// pipeline depth: softmax/LN/GELU/transpose), ns.
    pub latency_ns: f64,
    /// Edge throughput in bytes/ns (PL stream width x clock); f64::INFINITY
    /// for plain wires.
    pub bw_bytes_per_ns: f64,
}

impl EdgeSpec {
    pub fn wire(capacity_bytes: u64) -> EdgeSpec {
        EdgeSpec { capacity_bytes, latency_ns: 0.0, bw_bytes_per_ns: f64::INFINITY }
    }
}

/// The full dataflow to simulate.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Scenario {
    pub nodes: Vec<NodeSpec>,
    pub edges: Vec<EdgeSpec>,
}

impl Scenario {
    /// Pre-size the node/edge vectors (scenario builders know their shape
    /// up front; avoids re-allocation churn on the hot build path).
    pub fn with_capacity(nodes: usize, edges: usize) -> Scenario {
        Scenario {
            nodes: Vec::with_capacity(nodes),
            edges: Vec::with_capacity(edges),
        }
    }

    /// Total invocations across all nodes (fast-path sizing heuristics).
    pub fn total_invocations(&self) -> usize {
        self.nodes.iter().map(|n| n.n_inv).sum()
    }

    pub fn add_edge(&mut self, e: EdgeSpec) -> usize {
        self.edges.push(e);
        self.edges.len() - 1
    }

    pub fn add_node(&mut self, n: NodeSpec) -> usize {
        self.nodes.push(n);
        self.nodes.len() - 1
    }

    /// Sanity-check port wiring (every edge has exactly one producer and
    /// one consumer; byte ratios conserve flow).
    pub fn validate(&self) -> Result<(), String> {
        let mut producers = vec![0usize; self.edges.len()];
        let mut consumers = vec![0usize; self.edges.len()];
        for n in &self.nodes {
            for p in &n.outputs {
                if p.edge >= self.edges.len() {
                    return Err(format!("node '{}' writes missing edge {}", n.name, p.edge));
                }
                producers[p.edge] += 1;
            }
            for p in &n.inputs {
                if p.edge >= self.edges.len() {
                    return Err(format!("node '{}' reads missing edge {}", n.name, p.edge));
                }
                consumers[p.edge] += 1;
            }
            if n.pus.is_empty() {
                return Err(format!("node '{}' has no PUs", n.name));
            }
            if n.n_inv == 0 {
                return Err(format!("node '{}' has zero invocations", n.name));
            }
        }
        for (i, (&p, &c)) in producers.iter().zip(&consumers).enumerate() {
            if p != 1 || c != 1 {
                return Err(format!(
                    "edge {i} must have exactly 1 producer and 1 consumer (got {p}/{c})"
                ));
            }
        }
        // flow conservation: producer total bytes == consumer total bytes
        for (i, _) in self.edges.iter().enumerate() {
            let produced: u64 = self
                .nodes
                .iter()
                .flat_map(|n| n.outputs.iter().map(move |p| (n, p)))
                .filter(|(_, p)| p.edge == i)
                .map(|(n, p)| n.n_inv as u64 * p.bytes_per_inv)
                .sum();
            let consumed: u64 = self
                .nodes
                .iter()
                .flat_map(|n| n.inputs.iter().map(move |p| (n, p)))
                .filter(|(_, p)| p.edge == i)
                .map(|(n, p)| n.n_inv as u64 * p.bytes_per_inv)
                .sum();
            if produced != consumed {
                return Err(format!(
                    "edge {i}: flow not conserved (produced {produced} != consumed {consumed})"
                ));
            }
        }
        // capacity must fit at least one consumer grain, else deadlock
        for (i, e) in self.edges.iter().enumerate() {
            let max_grain = self
                .nodes
                .iter()
                .flat_map(|n| n.inputs.iter())
                .filter(|p| p.edge == i)
                .map(|p| p.bytes_per_inv)
                .chain(
                    self.nodes
                        .iter()
                        .flat_map(|n| n.outputs.iter())
                        .filter(|p| p.edge == i)
                        .map(|p| p.bytes_per_inv),
                )
                .max()
                .unwrap_or(0);
            if e.capacity_bytes < max_grain {
                return Err(format!(
                    "edge {i}: capacity {} < largest grain {max_grain} (deadlock)",
                    e.capacity_bytes
                ));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pu(ns: f64) -> PuTiming {
        PuTiming { t_send_ns: ns * 0.2, t_calc_ns: ns, t_recv_ns: ns * 0.2 }
    }

    #[test]
    fn beat_serial_vs_pipelined() {
        let t = pu(10.0);
        assert!((t.beat_ns(true) - 10.0).abs() < 1e-9);
        assert!((t.beat_ns(false) - 14.0).abs() < 1e-9);
        assert!((t.fill_ns() - 14.0).abs() < 1e-9);
    }

    #[test]
    fn validate_catches_flow_mismatch() {
        let mut s = Scenario::default();
        let e = s.add_edge(EdgeSpec::wire(1024));
        s.add_node(NodeSpec {
            name: "a".into(),
            pus: vec![pu(1.0)],
            pipelined: true,
            n_inv: 2,
            cores: 1,
            inputs: vec![],
            outputs: vec![PortSpec { edge: e, bytes_per_inv: 100 }],
        });
        s.add_node(NodeSpec {
            name: "b".into(),
            pus: vec![pu(1.0)],
            pipelined: true,
            n_inv: 3, // 3*100 != 2*100
            cores: 1,
            inputs: vec![PortSpec { edge: e, bytes_per_inv: 100 }],
            outputs: vec![],
        });
        assert!(s.validate().unwrap_err().contains("flow not conserved"));
    }

    #[test]
    fn validate_catches_undersized_edge() {
        let mut s = Scenario::default();
        let e = s.add_edge(EdgeSpec::wire(10));
        s.add_node(NodeSpec {
            name: "a".into(),
            pus: vec![pu(1.0)],
            pipelined: true,
            n_inv: 1,
            cores: 1,
            inputs: vec![],
            outputs: vec![PortSpec { edge: e, bytes_per_inv: 100 }],
        });
        s.add_node(NodeSpec {
            name: "b".into(),
            pus: vec![pu(1.0)],
            pipelined: true,
            n_inv: 1,
            cores: 1,
            inputs: vec![PortSpec { edge: e, bytes_per_inv: 100 }],
            outputs: vec![],
        });
        assert!(s.validate().unwrap_err().contains("deadlock"));
    }

    #[test]
    fn validate_ok_graph() {
        let mut s = Scenario::default();
        let e = s.add_edge(EdgeSpec::wire(1 << 20));
        s.add_node(NodeSpec {
            name: "src".into(),
            pus: vec![pu(5.0)],
            pipelined: true,
            n_inv: 4,
            cores: 4,
            inputs: vec![],
            outputs: vec![PortSpec { edge: e, bytes_per_inv: 256 }],
        });
        s.add_node(NodeSpec {
            name: "dst".into(),
            pus: vec![pu(5.0)],
            pipelined: true,
            n_inv: 2,
            cores: 4,
            inputs: vec![PortSpec { edge: e, bytes_per_inv: 512 }],
            outputs: vec![],
        });
        s.validate().unwrap();
    }
}
