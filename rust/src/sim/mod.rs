//! Versal ACAP simulator substrate.
//!
//! The paper measures a physical VCK5000; we reproduce the *schedule
//! behaviour* with a discrete-event, cycle-approximate simulator whose
//! timing parameters come from the paper's own numbers (DESIGN.md §7):
//!
//! * [`scenario`] — the dataflow description: PRG-like processor nodes
//!   holding AIE MM PU instances, buffer edges with PL-operator latency,
//!   internal (send/compute/receive) pipelining flags;
//! * [`engine`] — the event-driven executor with backpressure (finite
//!   buffers block producers — this is what makes the paper's Lab 3
//!   "serial ATB blocks the linear layer" observable);
//! * [`power`] — the calibrated board power model.

pub mod engine;
pub mod power;
pub mod scenario;

pub use engine::{run, run_exact, run_with, EngineConfig, NodeStats, SimReport};
pub use scenario::{EdgeSpec, NodeSpec, PortSpec, Scenario};
