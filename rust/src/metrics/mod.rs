//! AIE evaluating indicators (paper §III.C, Eq. 1–2) and derived
//! performance / energy-efficiency metrics (Table VI columns).

use crate::arch::AcceleratorPlan;
use crate::sched::{EdpuReport, MultiEdpuMode, MultiEdpuReport};
use crate::sim::power::{power, PowerBreakdownInput};

/// Eq. 1: `AIE_deployment_rate = deployed / total`.
pub fn deployment_rate(plan: &AcceleratorPlan) -> f64 {
    plan.deployment_rate()
}

/// Eq. 2: `AIE_effective_utilization_rate = running / deployed`.
pub fn effective_utilization_rate(running: usize, deployed: usize) -> f64 {
    if deployed == 0 {
        return 0.0;
    }
    running as f64 / deployed as f64
}

/// One Table VI row-set for a full EDPU execution.
#[derive(Debug, Clone)]
pub struct PerfSummary {
    pub model: String,
    pub batch: usize,
    pub mha_latency_ms: f64,
    pub mha_tops: f64,
    pub mha_gops_per_aie: f64,
    pub ffn_latency_ms: f64,
    pub ffn_tops: f64,
    pub ffn_gops_per_aie: f64,
    pub sys_latency_ms: f64,
    pub sys_tops: f64,
    pub sys_gops_per_aie: f64,
    pub power_w: f64,
    pub gops_per_w: f64,
    pub deployment_rate: f64,
    pub mha_eff_util: f64,
    pub ffn_eff_util: f64,
    pub avg_eff_util: f64,
}

/// Assemble the Table VI metrics from an EDPU run + its plan.
pub fn summarize(plan: &AcceleratorPlan, r: &EdpuReport) -> PerfSummary {
    let pw = power(
        &plan.hw,
        &PowerBreakdownInput {
            aie_deployed: plan.cores_deployed(),
            aie_running_avg: r.running_avg(),
            pl: plan.res_overall,
            dram_gbps: estimate_dram_gbps(plan, r),
        },
    )
    .total_w();
    let sys_gops = r.ops() as f64 / r.makespan_ns();
    PerfSummary {
        model: plan.model.name.clone(),
        batch: r.batch,
        mha_latency_ms: r.mha.latency_per_item_ns() / 1e6,
        mha_tops: r.mha.tops(),
        mha_gops_per_aie: r.mha.gops_per_aie(),
        ffn_latency_ms: r.ffn.latency_per_item_ns() / 1e6,
        ffn_tops: r.ffn.tops(),
        ffn_gops_per_aie: r.ffn.gops_per_aie(),
        sys_latency_ms: r.latency_per_item_ns() / 1e6,
        sys_tops: r.tops(),
        sys_gops_per_aie: r.gops_per_aie(),
        power_w: pw,
        gops_per_w: sys_gops / pw, // ops/ns == GOPS, so this is GOPS/W
        deployment_rate: plan.deployment_rate(),
        mha_eff_util: r.mha.eff_utilization(),
        ffn_eff_util: r.ffn.eff_utilization(),
        avg_eff_util: r.avg_eff_utilization(),
    }
}

/// Board power (W) for a multi-EDPU deployment — the power-model input
/// scaled to `n_edpu` instances: every instance's cores are deployed
/// (clocked), the active average sums the instances that actually run,
/// and the PL logic replicates per instance.  At `n_edpu = 1` this
/// agrees exactly with [`summarize`]'s power (the per-layer activation
/// traffic rate is invariant to running all `layers` layers).
pub fn multi_edpu_power_w(plan: &AcceleratorPlan, r: &MultiEdpuReport) -> f64 {
    let running_avg = match r.mode {
        // independent instances run concurrently: their busy cores add up
        MultiEdpuMode::Parallel => r.per_edpu.iter().map(EdpuReport::running_avg).sum(),
        // each chain stage re-runs the same per-layer profile
        MultiEdpuMode::Pipelined => {
            r.per_edpu.first().map(EdpuReport::running_avg).unwrap_or(0.0)
                * r.n_edpu.min(plan.model.layers) as f64
        }
    };
    let l = plan.model.padded_seq_len(plan.mmsz) as f64;
    let e = plan.model.embed_dim as f64;
    let layer_crossings = plan.model.layers as f64;
    let dram_gbps =
        2.0 * l * e * r.batch as f64 * layer_crossings / r.makespan_ns.max(1e-9);
    power(
        &plan.hw,
        &PowerBreakdownInput {
            aie_deployed: r.n_edpu * plan.cores_deployed(),
            aie_running_avg: running_avg,
            pl: plan.res_overall.scale(r.n_edpu),
            dram_gbps,
        },
    )
    .total_w()
}

/// Activations in/out over PCIe/DRAM during one EDPU run (GB/s estimate).
fn estimate_dram_gbps(plan: &AcceleratorPlan, r: &EdpuReport) -> f64 {
    let l = plan.model.padded_seq_len(plan.mmsz) as f64;
    let e = plan.model.embed_dim as f64;
    // per item: input int8 L*E in, output L*E out
    let bytes = 2.0 * l * e * r.batch as f64;
    bytes / r.makespan_ns() // bytes/ns == GB/s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{HardwareConfig, ModelConfig};
    use crate::customize::{customize, CustomizeOptions};
    use crate::sched::run_edpu;

    #[test]
    fn gops_per_w_units() {
        // ops/ns = GOPS; TOPS = ops/ns/1e3. sanity-check the conversion:
        // 35 TOPS at 67 W should be ~520 GOPS/W.
        let gops: f64 = 35.194e3; // GOPS
        let w: f64 = 67.555;
        assert!((gops / w - 520.97).abs() < 0.5);
    }

    #[test]
    fn eq2_definition() {
        assert!((effective_utilization_rate(256, 352) - 0.727).abs() < 1e-3);
        assert_eq!(effective_utilization_rate(0, 0), 0.0);
    }

    #[test]
    fn multi_power_agrees_with_summarize_at_one_edpu() {
        let plan = customize(
            &ModelConfig::bert_base(),
            &HardwareConfig::vck5000(),
            &CustomizeOptions::default(),
        )
        .unwrap();
        let r1 = run_edpu(&plan, 8).unwrap();
        let s = summarize(&plan, &r1);
        let m = crate::sched::run_multi_edpu(&plan, 1, 8, MultiEdpuMode::Parallel).unwrap();
        let p = multi_edpu_power_w(&plan, &m);
        assert!(
            (p - s.power_w).abs() / s.power_w < 1e-9,
            "{p} vs {}",
            s.power_w
        );
    }

    #[test]
    fn multi_power_grows_with_instances() {
        // the compact 64-core EDPU hosted on the full board
        let mut plan = customize(
            &ModelConfig::bert_base(),
            &HardwareConfig::vck5000_limited(64),
            &CustomizeOptions::default(),
        )
        .unwrap();
        plan.hw = HardwareConfig::vck5000();
        let p1 = multi_edpu_power_w(
            &plan,
            &crate::sched::run_multi_edpu(&plan, 1, 8, MultiEdpuMode::Parallel).unwrap(),
        );
        let p2 = multi_edpu_power_w(
            &plan,
            &crate::sched::run_multi_edpu(&plan, 2, 8, MultiEdpuMode::Parallel).unwrap(),
        );
        assert!(p2 > p1, "{p2} vs {p1}");
        // both stay in a physically plausible band
        assert!(p1 > 5.0 && p2 < 120.0, "{p1} / {p2}");
    }

    #[test]
    fn bert_summary_plausible() {
        let plan = customize(
            &ModelConfig::bert_base(),
            &HardwareConfig::vck5000(),
            &CustomizeOptions::default(),
        )
        .unwrap();
        let r = run_edpu(&plan, 16).unwrap();
        let s = summarize(&plan, &r);
        assert!((s.deployment_rate - 0.88).abs() < 1e-9);
        assert!(s.power_w > 30.0 && s.power_w < 100.0, "{}", s.power_w);
        assert!(s.gops_per_w > 250.0 && s.gops_per_w < 900.0, "{}", s.gops_per_w);
        assert!(s.sys_tops > 20.0, "{}", s.sys_tops);
        assert!((s.avg_eff_util - (1.0 + 256.0 / 352.0) / 2.0).abs() < 1e-9);
    }
}
