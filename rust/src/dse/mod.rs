//! Design-space exploration (the `cat explore` subsystem).
//!
//! The paper's central claim is that CAT *derives a customized
//! accelerator family* by letting "the underlying hardware and the upper
//! model jointly constrain and decide" the customizable attributes.  This
//! module makes that derivation systematic instead of hand-picked:
//!
//! 1. **Enumerate** ([`space`]) the joint space — the §IV knobs
//!    (`independent_linear` × MHA/FFN [`ParallelMode`](crate::arch::ParallelMode)
//!    × `P_ATB`) × batch × per-EDPU AIE budget × HOST deployment
//!    (`n_edpu` × [`MultiEdpuMode`](crate::sched::MultiEdpuMode)) — as a
//!    mixed-radix indexed iterator, with a deterministic seeded sampler
//!    for spaces too large to sweep exhaustively.
//! 2. **Prune** ([`prune`]) infeasible points against board budgets
//!    (AIE cores, Table V PL estimate) before any simulation.
//! 3. **Evaluate** ([`eval`]) survivors in parallel through
//!    `customize → run_multi_edpu`, riding the stage-sim cache and
//!    `util::par` (§Perf).
//! 4. **Select** ([`pareto`]) the multi-objective Pareto frontier over
//!    (TOPS, per-item latency, GOPS/W, AIE cores, PL LUTs), plus
//!    scalarized best-under-constraint queries (max TOPS s.t. latency ≤
//!    SLO / cores ≤ N).
//!
//! Results are deterministic: the sampler is seeded, the simulator is
//! exact, and `par_map` preserves input order, so the same config yields
//! bit-identical frontiers regardless of thread count.

mod eval;
mod pareto;
mod partition;
mod prune;
mod space;

pub use eval::{evaluate, DesignPoint};
pub use pareto::{best_tops_under, dominates, frontier_indices, ParetoResult, Query};
pub use partition::{partition_frontier, Partition, PartitionConfig, PartitionStats, Share};
pub use prune::{check_budgets, PruneStats, Reject};
pub use space::{Candidate, SpaceSpec};

use std::collections::BTreeMap;

use crate::arch::AcceleratorPlan;
use crate::config::{HardwareConfig, ModelConfig};
use crate::customize::customize;
use crate::obs::{Obs, PID_DSE};
use crate::util::json::Json;
use crate::util::par::par_map;
use anyhow::{anyhow, Result};

/// One exploration request.
#[derive(Debug, Clone)]
pub struct ExploreConfig {
    pub model: ModelConfig,
    pub hw: HardwareConfig,
    /// Board-level AIE cap (the paper's Limited-AIE scenario): the whole
    /// search sees a board with `min(total_aie, max_cores)` cores.
    pub max_cores: Option<usize>,
    /// Per-item whole-model latency SLO (ms) for the scalarized query.
    pub slo_ms: Option<f64>,
    /// Max candidates to *consider*; larger spaces are sampled
    /// deterministically with `seed`.  `None` = exhaustive.
    pub sample_budget: Option<usize>,
    pub seed: u64,
    pub space: SpaceSpec,
}

impl ExploreConfig {
    /// Defaults: the full joint space for the pair, sampled down to 256
    /// candidates (seeded), no constraints.
    pub fn new(model: ModelConfig, hw: HardwareConfig) -> Self {
        let space = SpaceSpec::for_model(&model, &hw);
        ExploreConfig {
            model,
            hw,
            max_cores: None,
            slo_ms: None,
            sample_budget: Some(256),
            seed: 0xCA7,
            space,
        }
    }

    /// The board the search actually targets (`max_cores` applied).
    pub fn board(&self) -> HardwareConfig {
        match self.max_cores {
            Some(n) if n < self.hw.total_aie => {
                let mut hw = self.hw.clone();
                hw.total_aie = n;
                hw.name = format!("{}-limited-{n}", self.hw.name);
                hw
            }
            _ => self.hw.clone(),
        }
    }
}

/// One exploration outcome: every surviving design point, the frontier,
/// and the accounting of where the rest of the space went.
#[derive(Debug, Clone)]
pub struct ExploreResult {
    /// Size of the effective joint space (per-EDPU budgets above the
    /// board collapse before enumeration — see [`explore`]).
    pub space_size: usize,
    /// True when the space was subsampled rather than swept.
    pub sampled: bool,
    /// Evaluated points, in candidate-index order.
    pub points: Vec<DesignPoint>,
    /// Indices into `points` of the Pareto frontier.
    pub frontier: Vec<usize>,
    pub dominated: usize,
    pub duplicates: usize,
    pub stats: PruneStats,
    /// The latency SLO the scalarized query ran with (`None` = the query
    /// was a plain TOPS maximum).
    pub slo_ms: Option<f64>,
    /// Index into `points` of the best-TOPS point satisfying the SLO
    /// query (every point already satisfies the board budgets).
    pub best_constrained: Option<usize>,
}

impl ExploreResult {
    pub fn frontier_points(&self) -> impl Iterator<Item = &DesignPoint> {
        self.frontier.iter().map(|&i| &self.points[i])
    }

    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("schema".into(), Json::Str("cat-dse-v1".into()));
        m.insert("space_size".into(), Json::Num(self.space_size as f64));
        m.insert("sampled".into(), Json::Bool(self.sampled));
        let s = &self.stats;
        let mut pruned = BTreeMap::new();
        pruned.insert("considered".into(), Json::Num(s.sampled as f64));
        pruned.insert("customize_rejected".into(), Json::Num(s.customize_rejected as f64));
        pruned.insert("aie_rejected".into(), Json::Num(s.aie_rejected as f64));
        pruned.insert("pl_rejected".into(), Json::Num(s.pl_rejected as f64));
        pruned.insert("sim_failed".into(), Json::Num(s.sim_failed as f64));
        pruned.insert("evaluated".into(), Json::Num(s.evaluated as f64));
        m.insert("pruning".into(), Json::Obj(pruned));
        m.insert("dominated".into(), Json::Num(self.dominated as f64));
        m.insert("duplicates".into(), Json::Num(self.duplicates as f64));
        m.insert(
            "frontier".into(),
            Json::Arr(self.frontier_points().map(DesignPoint::to_json).collect()),
        );
        m.insert(
            "slo_ms".into(),
            match self.slo_ms {
                Some(x) => Json::Num(x),
                None => Json::Null,
            },
        );
        m.insert(
            "best_constrained".into(),
            match self.best_constrained {
                Some(i) => self.points[i].to_json(),
                None => Json::Null,
            },
        );
        Json::Obj(m)
    }
}

/// Frontier → deployable-backend conversion: re-derive the
/// [`AcceleratorPlan`] for one candidate on `board` by customizing
/// against the candidate's per-EDPU AIE budget, then swapping the full
/// board back in (the budget caps the EDPU, the board hosts it — so the
/// multi-EDPU budget check and the power model see the real part).
///
/// [`explore`] runs every candidate through this; the serving fleet
/// ([`crate::serve`]) uses it to turn selected frontier points back into
/// executable plans.
pub fn deploy_plan(
    model: &ModelConfig,
    board: &HardwareConfig,
    cand: &Candidate,
) -> Result<AcceleratorPlan> {
    let mut edpu_hw = board.clone();
    if cand.edpu_budget < edpu_hw.total_aie {
        edpu_hw.total_aie = cand.edpu_budget;
        edpu_hw.name = format!("{}-edpu-{}", board.name, cand.edpu_budget);
    }
    let mut plan = customize(model, &edpu_hw, &cand.opts)?;
    plan.hw = board.clone();
    Ok(plan)
}

/// [`deploy_plan`] for a **co-resident** deployment: re-derive the
/// candidate's plan exactly as the explorer did, then host it on a
/// *slice* of the board — `share.aie` AIE cores and the granted PL pools
/// — instead of the whole part.  The multi-EDPU budget check and every
/// downstream consumer of `plan.hw` then see only this member's share,
/// so a partitioned backend can never quietly spill into a neighbour's
/// allocation.  Clocks and window memory stay the board's own: the
/// partition divides the AIE array and the PL fabric, not time.
///
/// `mem_throttle` is the slice's share of the **shared memory path**
/// (`1.0` = the member's solo-link rate, the PR 4 behavior; `< 1.0` =
/// its negotiated fraction when the co-resident fleet oversubscribes
/// the board's DRAM/PCIe pools — see `serve::links`).  The scheduler
/// stretches the slice's stream phases by `1/mem_throttle`, so profiles
/// re-simulated on this plan price the contention.
///
/// Errors when the re-derived design does not fit the share it was
/// granted (the partitioner allocates shares at the designed footprint,
/// so a mismatch means the caller's share came from somewhere else).
pub fn deploy_plan_in_share(
    model: &ModelConfig,
    board: &HardwareConfig,
    cand: &Candidate,
    share: &Share,
    mem_throttle: f64,
) -> Result<AcceleratorPlan> {
    if !(mem_throttle > 0.0 && mem_throttle <= 1.0) {
        return Err(anyhow!(
            "mem_throttle must be in (0, 1], got {mem_throttle} (a grant can shrink the \
             memory path, never widen it)"
        ));
    }
    let mut plan = deploy_plan(model, board, cand)?;
    let need = cand.n_edpu * plan.cores_deployed();
    if need > share.aie {
        return Err(anyhow!(
            "candidate {} re-derived to {need} AIE cores but was granted a {}-core share",
            cand.index,
            share.aie
        ));
    }
    let pl = plan.res_overall.scale(cand.n_edpu);
    if !pl.fits_within(&share.pl) {
        return Err(anyhow!(
            "candidate {} re-derived to a PL estimate exceeding its granted share \
             (LUT {}/{}, FF {}/{}, BRAM {}/{}, URAM {}/{})",
            cand.index,
            pl.luts,
            share.pl.luts,
            pl.ffs,
            share.pl.ffs,
            pl.brams,
            share.pl.brams,
            pl.urams,
            share.pl.urams
        ));
    }
    let mut slice = board.clone();
    slice.name = format!("{}-share-{}aie", board.name, share.aie);
    slice.total_aie = share.aie;
    slice.pl_luts = share.pl.luts;
    slice.pl_ffs = share.pl.ffs;
    slice.pl_brams = share.pl.brams;
    slice.pl_urams = share.pl.urams;
    slice.mem_throttle = mem_throttle;
    plan.hw = slice;
    Ok(plan)
}

/// Run one exploration: enumerate/sample → customize+prune → simulate in
/// parallel → select the frontier.
pub fn explore(cfg: &ExploreConfig) -> Result<ExploreResult> {
    explore_obs(cfg, None)
}

/// [`explore`] with an optional observability sink: phase timing on a
/// synthetic deterministic timeline (`--trace`) and `dse.*` counters /
/// histograms (`--metrics`).  `None` is the zero-cost path; the
/// returned [`ExploreResult`] is identical either way — the sink is
/// filled from the finished result, never consulted during the search.
pub fn explore_obs(cfg: &ExploreConfig, obs: Option<&mut Obs>) -> Result<ExploreResult> {
    let board = cfg.board();
    // Effective space: per-EDPU budgets above the (possibly `max_cores`-
    // capped) board all clamp to the same board-sized budget, so collapse
    // them before enumeration — otherwise a capped board turns the budget
    // dimension into identical candidates that waste the sample budget.
    let mut space = cfg.space.clone();
    space.edpu_budgets = {
        let mut budgets = Vec::new();
        for b in &space.edpu_budgets {
            let b = (*b).min(board.total_aie);
            if !budgets.contains(&b) {
                budgets.push(b);
            }
        }
        budgets
    };
    let n = space.size();
    let indices: Vec<usize> = match cfg.sample_budget {
        Some(k) if k < n => space.sample_indices(k, cfg.seed),
        _ => (0..n).collect(),
    };
    let sampled = indices.len() < n;
    let mut stats = PruneStats { sampled: indices.len(), ..PruneStats::default() };

    // Stage 1 — customize + budget-prune (cheap: Eq. 3–8 arithmetic and
    // the Table V estimate; no discrete-event simulation).
    let mut survivors: Vec<(Candidate, AcceleratorPlan)> = Vec::new();
    for idx in indices {
        let cand = space.candidate(idx);
        // customize against the per-EDPU budget, deploy on the board
        let plan = match deploy_plan(&cfg.model, &board, &cand) {
            Ok(p) => p,
            Err(_) => {
                stats.customize_rejected += 1;
                continue;
            }
        };
        match check_budgets(&plan, &board, cand.n_edpu) {
            Ok(()) => survivors.push((cand, plan)),
            Err(Reject::Aie) => stats.aie_rejected += 1,
            Err(Reject::Pl) => stats.pl_rejected += 1,
        }
    }

    // Stage 2 — simulate survivors in parallel (stage-sim cache dedups
    // repeated per-share stage runs underneath).
    let evaluated: Vec<Result<DesignPoint>> =
        par_map(survivors, |(cand, plan)| evaluate(&plan, &cand));
    let mut points = Vec::new();
    for r in evaluated {
        match r {
            Ok(p) => points.push(p),
            Err(_) => stats.sim_failed += 1,
        }
    }
    stats.evaluated = points.len();

    // Stage 3 — multi-objective selection + the scalarized query.
    let objs: Vec<Vec<f64>> = points.iter().map(|p| p.objectives().to_vec()).collect();
    let pr = frontier_indices(&objs);
    let query = Query { max_latency_ms: cfg.slo_ms, ..Query::default() };
    let best_constrained = best_tops_under(&points, &query);

    let res = ExploreResult {
        space_size: n,
        sampled,
        points,
        frontier: pr.frontier,
        dominated: pr.dominated,
        duplicates: pr.duplicates,
        stats,
        slo_ms: cfg.slo_ms,
        best_constrained,
    };
    if let Some(o) = obs {
        fill_explore_obs(o, &res);
    }
    Ok(res)
}

/// Fill the observability sink from a finished exploration.
///
/// The DSE has no virtual clock, so the trace timeline is *synthetic*
/// but deterministic: the prune phase spans 1 µs per candidate
/// considered, then every evaluated point is laid end to end on its own
/// track with its simulated per-item latency as the span width (points
/// are in candidate order — `par_map` preserves it — so the layout is
/// thread-count independent), and the selection phase closes the
/// timeline.  Perfetto then shows *where the search spent its modeled
/// time*, which is the quantity the paper's DSE trades off.
fn fill_explore_obs(o: &mut Obs, r: &ExploreResult) {
    if let Some(t) = o.trace.as_mut() {
        t.process_name(PID_DSE, "cat explore (synthetic timeline)");
        t.thread_name(PID_DSE, 0, "phases");
        t.thread_name(PID_DSE, 1, "evaluate");
        let prune_ns = (r.stats.sampled as u64).max(1) * 1_000;
        let prune_args = vec![
            ("considered".to_string(), Json::Num(r.stats.sampled as f64)),
            ("customize_rejected".to_string(), Json::Num(r.stats.customize_rejected as f64)),
            ("aie_rejected".to_string(), Json::Num(r.stats.aie_rejected as f64)),
            ("pl_rejected".to_string(), Json::Num(r.stats.pl_rejected as f64)),
        ];
        t.complete("customize+prune", "dse", PID_DSE, 0, 0, prune_ns, prune_args);
        let mut cursor = prune_ns;
        for p in &r.points {
            let dur = ((p.latency_ms * 1e6) as u64).max(1);
            let name = format!("eval#{}", p.cand.index);
            t.complete(&name, "dse", PID_DSE, 1, cursor, dur, p.trace_args());
            cursor += dur;
        }
        let select_ns = (r.points.len() as u64 + 1) * 1_000;
        let select_args = vec![
            ("frontier".to_string(), Json::Num(r.frontier.len() as f64)),
            ("dominated".to_string(), Json::Num(r.dominated as f64)),
            ("duplicates".to_string(), Json::Num(r.duplicates as f64)),
        ];
        t.complete("pareto+query", "dse", PID_DSE, 0, cursor, select_ns, select_args);
    }
    if let Some(m) = o.metrics.as_mut() {
        m.add("dse.considered", r.stats.sampled as u64);
        m.add("dse.customize_rejected", r.stats.customize_rejected as u64);
        m.add("dse.aie_rejected", r.stats.aie_rejected as u64);
        m.add("dse.pl_rejected", r.stats.pl_rejected as u64);
        m.add("dse.sim_failed", r.stats.sim_failed as u64);
        m.add("dse.evaluated", r.stats.evaluated as u64);
        m.add("dse.frontier", r.frontier.len() as u64);
        m.add("dse.dominated", r.dominated as u64);
        m.add("dse.duplicates", r.duplicates as u64);
        for p in &r.points {
            m.record("dse.point_latency_ns", (p.latency_ms * 1e6) as u64);
            m.record("dse.point_total_cores", p.total_cores as u64);
        }
        if let Some(i) = r.best_constrained {
            m.set_gauge("dse.best_tops", r.points[i].tops);
        }
    }
    o.record_global_deltas();
}
