//! Multi-objective selection: Pareto frontier + scalarized queries.
//!
//! Objectives are maximize-all `f64` vectors (minimized quantities enter
//! negated — see [`DesignPoint::objectives`](super::DesignPoint::objectives)),
//! so one `dominates` predicate serves every caller.

use super::eval::DesignPoint;

/// `a` dominates `b`: at least as good everywhere, strictly better
/// somewhere (maximize-all convention).
pub fn dominates(a: &[f64], b: &[f64]) -> bool {
    debug_assert_eq!(a.len(), b.len());
    let mut strict = false;
    for (x, y) in a.iter().zip(b) {
        if x < y {
            return false;
        }
        if x > y {
            strict = true;
        }
    }
    strict
}

/// Frontier extraction result.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParetoResult {
    /// Indices (into the input slice) of the non-dominated set, in input
    /// order.  Exact-duplicate objective vectors keep only their first
    /// occurrence.
    pub frontier: Vec<usize>,
    /// Points strictly dominated by some other point.
    pub dominated: usize,
    /// Later exact duplicates of a frontier point.
    pub duplicates: usize,
}

/// O(n²) frontier scan — fine for the few thousand survivors a sweep
/// produces (the expensive part is the simulation, not the selection).
pub fn frontier_indices(objs: &[Vec<f64>]) -> ParetoResult {
    let mut frontier = Vec::new();
    let mut dominated = 0usize;
    let mut duplicates = 0usize;
    'outer: for (i, a) in objs.iter().enumerate() {
        for (j, b) in objs.iter().enumerate() {
            if i == j {
                continue;
            }
            if dominates(b, a) {
                dominated += 1;
                continue 'outer;
            }
            if j < i && a == b {
                duplicates += 1;
                continue 'outer;
            }
        }
        frontier.push(i);
    }
    ParetoResult { frontier, dominated, duplicates }
}

/// A scalarized "best under constraint" question: maximize TOPS subject
/// to the stated ceilings.  Unset fields don't constrain.
#[derive(Debug, Clone, Copy, Default)]
pub struct Query {
    /// Per-item end-to-end latency SLO (ms, whole model).
    pub max_latency_ms: Option<f64>,
    /// Total AIE cores across all EDPU instances.
    pub max_total_cores: Option<usize>,
    /// Board power ceiling (W).
    pub max_power_w: Option<f64>,
}

impl Query {
    pub fn admits(&self, p: &DesignPoint) -> bool {
        self.max_latency_ms.map_or(true, |m| p.latency_ms <= m)
            && self.max_total_cores.map_or(true, |m| p.total_cores <= m)
            && self.max_power_w.map_or(true, |m| p.power_w <= m)
    }
}

/// Index of the highest-TOPS point admitted by `q` (`None` when nothing
/// qualifies).
pub fn best_tops_under(points: &[DesignPoint], q: &Query) -> Option<usize> {
    points
        .iter()
        .enumerate()
        .filter(|(_, p)| q.admits(p))
        .max_by(|a, b| a.1.tops.total_cmp(&b.1.tops))
        .map(|(i, _)| i)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dominance_basics() {
        assert!(dominates(&[2.0, 1.0], &[1.0, 1.0]));
        assert!(!dominates(&[1.0, 1.0], &[1.0, 1.0])); // equal: not strict
        assert!(!dominates(&[2.0, 0.5], &[1.0, 1.0])); // trade-off
        assert!(!dominates(&[1.0, 1.0], &[2.0, 1.0]));
    }

    #[test]
    fn frontier_drops_dominated_and_dedupes() {
        let objs = vec![
            vec![1.0, 1.0], // dominated by 2
            vec![3.0, 0.0], // frontier (best x)
            vec![2.0, 2.0], // frontier
            vec![2.0, 2.0], // duplicate of 2
            vec![0.0, 3.0], // frontier (best y)
        ];
        let r = frontier_indices(&objs);
        assert_eq!(r.frontier, vec![1, 2, 4]);
        assert_eq!(r.dominated, 1);
        assert_eq!(r.duplicates, 1);
        // mutual non-domination on the frontier
        for &i in &r.frontier {
            for &j in &r.frontier {
                if i != j {
                    assert!(!dominates(&objs[i], &objs[j]));
                }
            }
        }
    }

    #[test]
    fn empty_frontier() {
        let r = frontier_indices(&[]);
        assert!(r.frontier.is_empty());
        assert_eq!((r.dominated, r.duplicates), (0, 0));
    }
}
