//! Candidate enumeration: the joint customization × deployment space.
//!
//! A point of the space is one complete accelerator-family decision:
//! the three §IV customizable attributes (as [`CustomizeOptions`]
//! overrides), the per-EDPU AIE budget the customization engine is asked
//! to target, the batch size, and the HOST-level deployment (how many
//! EDPU instances, parallel or pipelined).  The space is addressed by a
//! single mixed-radix index so that exhaustive iteration, deterministic
//! sampling, and resume-from-index all share one decoder.

use crate::arch::ParallelMode;
use crate::config::{HardwareConfig, ModelConfig};
use crate::customize::{knob_domains, CustomizeOptions};
use crate::sched::MultiEdpuMode;
use crate::util::prng::Prng;

/// The domains the explorer sweeps (one `Vec` per knob; the space is
/// their Cartesian product).
#[derive(Debug, Clone)]
pub struct SpaceSpec {
    /// Merged-QKV organization on/off.
    pub independent_linear: Vec<bool>,
    /// MHA stage mode override (`None` = Eq. 5 decides).
    pub mha_modes: Vec<Option<ParallelMode>>,
    /// FFN stage mode override (`None` = Eq. 6 decides).
    pub ffn_modes: Vec<Option<ParallelMode>>,
    /// `P_ATB` values.
    pub p_atb: Vec<usize>,
    /// Batch sizes per EDPU execution.
    pub batches: Vec<usize>,
    /// Per-EDPU AIE core budgets handed to `customize` — smaller budgets
    /// yield compact EDPUs that the HOST can replicate (§III.A families).
    pub edpu_budgets: Vec<usize>,
    /// HOST deployments: (EDPU count, organization).  `n_edpu = 1` is
    /// listed once (the organization is irrelevant for a single EDPU).
    pub deployments: Vec<(usize, MultiEdpuMode)>,
}

/// One decoded candidate design point.
#[derive(Debug, Clone, Copy)]
pub struct Candidate {
    /// Mixed-radix index of this point in its [`SpaceSpec`].
    pub index: usize,
    pub opts: CustomizeOptions,
    pub batch: usize,
    pub edpu_budget: usize,
    pub n_edpu: usize,
    pub multi_mode: MultiEdpuMode,
}

impl SpaceSpec {
    /// The default joint space for one model/board pair: the §IV knob
    /// domains ([`knob_domains`]) × batches `{1,4,8,16,32}` × per-EDPU
    /// budgets `{total, total/2, total/4, 64}` × deployments of up to 4
    /// EDPUs in both HOST organizations.
    pub fn for_model(model: &ModelConfig, hw: &HardwareConfig) -> Self {
        let k = knob_domains(model, hw);
        let total = hw.total_aie;
        let mut edpu_budgets = vec![total];
        for b in [total / 2, total / 4, 64] {
            if b >= 4 && !edpu_budgets.contains(&b) {
                edpu_budgets.push(b);
            }
        }
        let mut deployments = vec![(1, MultiEdpuMode::Parallel)];
        for n in 2..=4 {
            deployments.push((n, MultiEdpuMode::Parallel));
            deployments.push((n, MultiEdpuMode::Pipelined));
        }
        SpaceSpec {
            independent_linear: k.independent_linear,
            mha_modes: k.mha_modes,
            ffn_modes: k.ffn_modes,
            p_atb: k.p_atb,
            batches: vec![1, 4, 8, 16, 32],
            edpu_budgets,
            deployments,
        }
    }

    /// The compact 9-point fixture shared by the hotpath bench's
    /// `dse/explore_9pt_space` row, the serve property tests, and the
    /// fleet unit tests: three per-EDPU budgets × up to three parallel
    /// EDPU instances, everything else pinned to the Eq. 3–8 defaults.
    /// One definition keeps bench and tests sweeping the same space.
    pub fn compact_9pt() -> Self {
        SpaceSpec {
            independent_linear: vec![true],
            mha_modes: vec![None],
            ffn_modes: vec![None],
            p_atb: vec![4],
            batches: vec![4],
            edpu_budgets: vec![400, 100, 64],
            deployments: vec![
                (1, MultiEdpuMode::Parallel),
                (2, MultiEdpuMode::Parallel),
                (3, MultiEdpuMode::Parallel),
            ],
        }
    }

    /// Number of points in the space (product of the domain sizes).
    pub fn size(&self) -> usize {
        self.independent_linear.len()
            * self.mha_modes.len()
            * self.ffn_modes.len()
            * self.p_atb.len()
            * self.batches.len()
            * self.edpu_budgets.len()
            * self.deployments.len()
    }

    /// Decode one mixed-radix index into a candidate.  Deployment varies
    /// fastest, `independent_linear` slowest.
    pub fn candidate(&self, index: usize) -> Candidate {
        assert!(index < self.size(), "candidate index out of range");
        let mut rem = index;
        let mut next = |len: usize| {
            let r = rem % len;
            rem /= len;
            r
        };
        let (n_edpu, multi_mode) = self.deployments[next(self.deployments.len())];
        let edpu_budget = self.edpu_budgets[next(self.edpu_budgets.len())];
        let batch = self.batches[next(self.batches.len())];
        let p_atb = self.p_atb[next(self.p_atb.len())];
        let force_ffn_mode = self.ffn_modes[next(self.ffn_modes.len())];
        let force_mha_mode = self.mha_modes[next(self.mha_modes.len())];
        let independent_linear = self.independent_linear[next(self.independent_linear.len())];
        Candidate {
            index,
            opts: CustomizeOptions {
                independent_linear: Some(independent_linear),
                force_mha_mode,
                force_ffn_mode,
                p_atb: Some(p_atb),
            },
            batch,
            edpu_budget,
            n_edpu,
            multi_mode,
        }
    }

    /// All candidates in index order.
    pub fn iter(&self) -> impl Iterator<Item = Candidate> + '_ {
        (0..self.size()).map(move |i| self.candidate(i))
    }

    /// `budget` distinct indices, uniformly without replacement, sorted
    /// ascending — deterministic for a fixed `seed`.  Floyd's sampling
    /// algorithm: O(budget) work and memory however large the space, so
    /// widening the domains never makes drawing a sample expensive.  A
    /// budget covering the whole space degenerates to exhaustive
    /// enumeration.
    pub fn sample_indices(&self, budget: usize, seed: u64) -> Vec<usize> {
        let n = self.size();
        if budget >= n {
            return (0..n).collect();
        }
        let mut rng = Prng::new(seed);
        let mut picked = std::collections::BTreeSet::new();
        for i in (n - budget)..n {
            let t = rng.below(i as u64 + 1) as usize;
            if !picked.insert(t) {
                picked.insert(i);
            }
        }
        picked.into_iter().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> SpaceSpec {
        SpaceSpec::for_model(&ModelConfig::bert_base(), &HardwareConfig::vck5000())
    }

    #[test]
    fn default_space_shape() {
        let s = spec();
        // 2 indep × 4 mha × 3 ffn × 6 p_atb × 5 batches × 4 budgets × 7 deployments
        assert_eq!(s.size(), 2 * 4 * 3 * 6 * 5 * 4 * 7);
        assert!(s.p_atb.contains(&4)); // the Eq. 7 value for BERT-Base
        assert_eq!(s.edpu_budgets, vec![400, 200, 100, 64]);
        assert_eq!(s.deployments.len(), 7);
    }

    #[test]
    fn decode_roundtrip_covers_every_knob() {
        let s = spec();
        // first point: all domains at position 0
        let c0 = s.candidate(0);
        assert_eq!(c0.index, 0);
        assert_eq!(c0.opts.independent_linear, Some(true));
        assert_eq!(c0.opts.force_mha_mode, None);
        assert_eq!(c0.n_edpu, 1);
        // last point: all domains at their final position
        let last = s.candidate(s.size() - 1);
        assert_eq!(last.opts.independent_linear, Some(false));
        assert_eq!(last.opts.p_atb, Some(12));
        assert_eq!(last.batch, 32);
        assert_eq!(last.edpu_budget, 64);
        assert_eq!((last.n_edpu, last.multi_mode), (4, MultiEdpuMode::Pipelined));
        // every index decodes, and indices are distinct along the walk
        let mut seen_batches = std::collections::BTreeSet::new();
        for c in s.iter().take(1000) {
            seen_batches.insert(c.batch);
        }
        assert!(seen_batches.len() > 1);
    }

    #[test]
    fn sampling_is_deterministic_sorted_and_unique() {
        let s = spec();
        let a = s.sample_indices(16, 7);
        let b = s.sample_indices(16, 7);
        assert_eq!(a, b);
        assert_eq!(a.len(), 16);
        assert!(a.windows(2).all(|w| w[0] < w[1]));
        assert!(a.iter().all(|&i| i < s.size()));
        // budget >= size degenerates to exhaustive
        let tiny = SpaceSpec {
            independent_linear: vec![true],
            mha_modes: vec![None],
            ffn_modes: vec![None],
            p_atb: vec![4],
            batches: vec![8],
            edpu_budgets: vec![64],
            deployments: vec![(1, MultiEdpuMode::Parallel), (2, MultiEdpuMode::Parallel)],
        };
        assert_eq!(tiny.sample_indices(99, 3), vec![0, 1]);
    }
}
