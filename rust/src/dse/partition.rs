//! Single-board fleet partitioning: pick a frontier *subset* whose joint
//! footprint fits one physical board.
//!
//! PR 3's fleet treated every deployed family member as its own VCK5000;
//! this module closes the gap to the paper's core constraint — every
//! Eq. 3–8 customization is negotiated against one board's `Total_AIE`
//! and Table V PL pools.  A partition grants each selected member a
//! [`Share`] — an AIE core allocation plus a slice of the LUT/FF/BRAM/
//! URAM pools — such that, jointly,
//!
//! ```text
//! Σ total_cores ≤ Total_AIE      and      Σ PL estimate ≤ board pools
//! ```
//!
//! — the same per-point checks [`check_budgets`](super::check_budgets)
//! applies during exploration, lifted to a co-residency constraint (the
//! Vis-TOP-style overlay scenario).
//!
//! **Selection.**  The best feasible `k`-subset by a scalarized
//! serving objective: maximize Σ TOPS over members that can actually
//! **admit** a request under the SLO — gated on the same inequality the
//! router enforces (`worst_case_service ≤ SLO`, over every batch size
//! the serving batcher can emit), evaluated on pre-simulated service
//! profiles the caller supplies per candidate (cheap through the
//! stage-sim cache).  Members failing the bound would shed 100% of
//! their traffic, so they contribute nothing.  Subsets are enumerated
//! exhaustively while `C(n, k)` stays under [`PartitionConfig::enum_cap`]
//! (frontiers are small); beyond that a deterministic two-pass greedy
//! (objective density for quality, smallest footprint for
//! reachability) takes over — a heuristic, so past the cap an
//! adversarially-shaped feasible subset can in principle be missed.
//! When no `k`-subset is found — or every larger subset scores a zero
//! objective while a smaller one can actually serve — the request
//! degrades to the best smaller size (every frontier point individually
//! passed the board budgets, so a 1-member partition always exists) and
//! the drop is recorded in [`PartitionStats`].
//!
//! Everything is deterministic: lexicographic subset order, total-order
//! tie-breaks, no randomness.

use super::eval::DesignPoint;
use crate::arch::PlResources;
use crate::config::HardwareConfig;
use anyhow::{anyhow, Result};

/// One member's slice of the board: the AIE cores and PL estimate its
/// deployment may consume.  Shares are allocated at the member's designed
/// footprint (its `total_cores` and replicated Table V estimate), so a
/// re-derivation under the share reproduces the frontier design exactly.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Share {
    pub aie: usize,
    pub pl: PlResources,
}

/// One partitioning request.
#[derive(Debug, Clone, Copy)]
pub struct PartitionConfig {
    /// Requested co-resident backends (degrades when infeasible).
    pub backends: usize,
    /// Per-item latency SLO for the throughput objective (`None` = every
    /// member contributes its TOPS).
    pub slo_ms: Option<f64>,
    /// Max subsets to enumerate per size before falling back to the
    /// greedy pass.
    pub enum_cap: usize,
}

impl PartitionConfig {
    pub fn new(backends: usize) -> PartitionConfig {
        PartitionConfig { backends, slo_ms: None, enum_cap: 100_000 }
    }
}

/// Where every considered subset went — the partition-level analogue of
/// [`PruneStats`](super::PruneStats):
/// `subsets_considered == aie_infeasible + pl_infeasible + feasible`.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PartitionStats {
    /// Deduped frontier points the search ran over.
    pub candidates: usize,
    /// Backends the caller asked for.
    pub requested: usize,
    /// Backends the best feasible subset actually holds.
    pub selected: usize,
    /// Subsets whose joint footprint was checked.
    pub subsets_considered: usize,
    /// Subsets rejected by `Σ cores ≤ Total_AIE`.
    pub aie_infeasible: usize,
    /// Subsets rejected by the PL pools.
    pub pl_infeasible: usize,
    /// Subsets satisfying both board budgets.
    pub feasible: usize,
    /// True when `enum_cap` forced the greedy pass for some size.
    pub greedy: bool,
}

/// One feasible co-resident deployment: the chosen members (indices into
/// the slice handed to [`partition_frontier`], ascending) and their
/// shares, plus the board-level accounting.
#[derive(Debug, Clone)]
pub struct Partition {
    pub members: Vec<usize>,
    /// `shares[i]` belongs to `members[i]`.
    pub shares: Vec<Share>,
    pub aie_used: usize,
    pub pl_used: PlResources,
    /// Σ SLO-feasible member TOPS — the scalarized objective achieved.
    pub objective_tops: f64,
    pub stats: PartitionStats,
}

fn footprint(p: &DesignPoint) -> Share {
    Share {
        aie: p.total_cores,
        pl: PlResources { luts: p.pl_luts, ffs: p.pl_ffs, brams: p.pl_brams, urams: p.pl_urams },
    }
}

/// The admitted-throughput objective: a member's TOPS when its
/// **worst-case service bound** — `max service_ns` over every batch
/// size the serving batcher can emit, pre-simulated by the caller from
/// the candidate's deployment profile — fits the SLO, else 0.  This is
/// the *same* inequality `serve::route` enforces per request (admission
/// requires `completion_bound ≤ SLO`, and `worst_case_service` is its
/// irreducible term), so selection and admission can no longer disagree:
/// a member scoring positive here can admit traffic, and a member
/// scoring zero never will.  (The previous proxy gated on the
/// explore-time per-item latency at the candidate's *own* batch, which
/// diverges from the serving-batch bound in both directions — subsets
/// could be picked whose members never admit a request, or serviceable
/// subsets scored zero and dropped; `rust/tests/partition_properties.rs`
/// pins both directions.)
fn admitted_tops(p: &DesignPoint, worst_service_ns: u64, slo_ms: Option<f64>) -> f64 {
    match slo_ms {
        Some(slo) if worst_service_ns as f64 > slo * 1e6 => 0.0,
        _ => p.tops,
    }
}

fn fits(board: &HardwareConfig, aie: usize, pl: &PlResources) -> Result<(), super::Reject> {
    if aie > board.total_aie {
        return Err(super::Reject::Aie);
    }
    if !pl.fits_within(&PlResources::pools_of(board)) {
        return Err(super::Reject::Pl);
    }
    Ok(())
}

/// `C(n, k)` saturating at `usize::MAX` (only compared against
/// `enum_cap`, so saturation is harmless).
fn n_choose_k(n: usize, k: usize) -> usize {
    if k > n {
        return 0;
    }
    let mut c: u128 = 1;
    for i in 0..k.min(n - k) {
        c = c.saturating_mul((n - i) as u128) / (i + 1) as u128;
        if c > usize::MAX as u128 {
            return usize::MAX;
        }
    }
    c as usize
}

/// Evaluate one subset against the board; returns `(objective, Σ aie)`
/// when feasible and records the outcome in `stats`.  The members are
/// NOT cloned here — the caller copies them only when the subset beats
/// the incumbent, so the exhaustive scan stays allocation-free.
fn evaluate_subset(
    points: &[&DesignPoint],
    bounds: &[u64],
    subset: &[usize],
    board: &HardwareConfig,
    slo_ms: Option<f64>,
    stats: &mut PartitionStats,
) -> Option<(f64, usize)> {
    stats.subsets_considered += 1;
    let mut aie = 0usize;
    let mut pl = PlResources::default();
    let mut objective = 0.0f64;
    for &i in subset {
        let s = footprint(points[i]);
        aie += s.aie;
        pl = pl.add(&s.pl);
        objective += admitted_tops(points[i], bounds[i], slo_ms);
    }
    match fits(board, aie, &pl) {
        Err(super::Reject::Aie) => {
            stats.aie_infeasible += 1;
            None
        }
        Err(super::Reject::Pl) => {
            stats.pl_infeasible += 1;
            None
        }
        Ok(()) => {
            stats.feasible += 1;
            Some((objective, aie))
        }
    }
}

/// A candidate beats the incumbent on (higher objective, then fewer AIE
/// cores, then lexicographically earlier members) — a total order, so
/// the search is deterministic.
fn better(
    objective: f64,
    aie: usize,
    members: &[usize],
    best: &Option<(f64, usize, Vec<usize>)>,
) -> bool {
    match best {
        None => true,
        Some(b) => match objective.total_cmp(&b.0) {
            std::cmp::Ordering::Greater => true,
            std::cmp::Ordering::Less => false,
            std::cmp::Ordering::Equal => (aie, members) < (b.1, b.2.as_slice()),
        },
    }
}

/// Exhaustive best-of-size-`k` search (lexicographic subset order).
fn best_of_size_exhaustive(
    points: &[&DesignPoint],
    bounds: &[u64],
    k: usize,
    board: &HardwareConfig,
    slo_ms: Option<f64>,
    stats: &mut PartitionStats,
) -> Option<(f64, usize, Vec<usize>)> {
    let n = points.len();
    let mut best: Option<(f64, usize, Vec<usize>)> = None;
    let mut idx: Vec<usize> = (0..k).collect();
    loop {
        if let Some((objective, aie)) =
            evaluate_subset(points, bounds, &idx, board, slo_ms, stats)
        {
            if better(objective, aie, &idx, &best) {
                best = Some((objective, aie, idx.clone()));
            }
        }
        // advance to the next k-combination of 0..n (lexicographic)
        let mut i = k;
        loop {
            if i == 0 {
                return best;
            }
            i -= 1;
            if idx[i] != i + n - k {
                idx[i] += 1;
                for j in i + 1..k {
                    idx[j] = idx[j - 1] + 1;
                }
                break;
            }
        }
    }
}

/// One greedy pass: walk `order`, keep every point that still fits,
/// stop at `k` members.  Returns the sorted picks only when `k` was
/// reached (no accounting — the caller evaluates distinct picks once).
fn greedy_picks(
    points: &[&DesignPoint],
    order: &[usize],
    k: usize,
    board: &HardwareConfig,
) -> Option<Vec<usize>> {
    let mut picked = Vec::new();
    let mut aie = 0usize;
    let mut pl = PlResources::default();
    for &i in order {
        let s = footprint(points[i]);
        if fits(board, aie + s.aie, &pl.add(&s.pl)).is_ok() {
            aie += s.aie;
            pl = pl.add(&s.pl);
            picked.push(i);
            if picked.len() == k {
                break;
            }
        }
    }
    if picked.len() < k {
        return None;
    }
    picked.sort_unstable();
    Some(picked)
}

/// Greedy fallback for sizes beyond `enum_cap`.  Two deterministic
/// passes: objective density (best value per AIE core) for quality, and
/// smallest-footprint-first for *reachability* — the k cheapest points
/// fit whenever any k-subset fits the AIE dimension, so a feasible
/// request is not declared infeasible just because the dense pass
/// filled the board early.  (Beyond the cap this stays a heuristic:
/// with adversarial PL shapes a feasible k-subset can still be missed —
/// the enumeration cap is exactly the budget bounding that exactness.)
fn best_of_size_greedy(
    points: &[&DesignPoint],
    bounds: &[u64],
    k: usize,
    board: &HardwareConfig,
    slo_ms: Option<f64>,
    stats: &mut PartitionStats,
) -> Option<(f64, usize, Vec<usize>)> {
    stats.greedy = true;
    let mut by_density: Vec<usize> = (0..points.len()).collect();
    by_density.sort_by(|&a, &b| {
        let da = admitted_tops(points[a], bounds[a], slo_ms) / points[a].total_cores.max(1) as f64;
        let db = admitted_tops(points[b], bounds[b], slo_ms) / points[b].total_cores.max(1) as f64;
        db.total_cmp(&da)
            .then(points[a].total_cores.cmp(&points[b].total_cores))
            .then(a.cmp(&b))
    });
    let mut by_footprint: Vec<usize> = (0..points.len()).collect();
    by_footprint.sort_by(|&a, &b| {
        let fa = footprint(points[a]);
        let fb = footprint(points[b]);
        (fa.aie, fa.pl.luts, a).cmp(&(fb.aie, fb.pl.luts, b))
    });
    let mut best: Option<(f64, usize, Vec<usize>)> = None;
    let mut evaluated: Option<Vec<usize>> = None;
    for order in [&by_density, &by_footprint] {
        let picks = match greedy_picks(points, order, k, board) {
            Some(p) => p,
            None => continue,
        };
        if evaluated.as_ref() == Some(&picks) {
            continue; // both orders converged on the same subset
        }
        if let Some((objective, aie)) =
            evaluate_subset(points, bounds, &picks, board, slo_ms, stats)
        {
            if better(objective, aie, &picks, &best) {
                best = Some((objective, aie, picks.clone()));
            }
        }
        evaluated = Some(picks);
    }
    best
}

/// Find the best feasible co-resident subset of `points` (a ranked,
/// deduped frontier) on `board`.  `bounds[i]` is point `i`'s worst-case
/// service bound at the serving batch cap (ns) — `Backend::max_service_ns`
/// from a pre-simulated deployment profile, the exact quantity the
/// router's admission inequality uses; the SLO objective gates on it.
/// Requests larger than the frontier or infeasible at their requested
/// size degrade to the largest feasible size, with the drop visible as
/// `stats.selected < stats.requested`.
pub fn partition_frontier(
    points: &[&DesignPoint],
    bounds: &[u64],
    board: &HardwareConfig,
    cfg: &PartitionConfig,
) -> Result<Partition> {
    if points.is_empty() {
        return Err(anyhow!("cannot partition an empty frontier"));
    }
    if points.len() != bounds.len() {
        return Err(anyhow!(
            "{} candidates but {} service bounds — every partition candidate needs its \
             pre-simulated worst-case service bound",
            points.len(),
            bounds.len()
        ));
    }
    if cfg.backends == 0 {
        return Err(anyhow!("a partition needs at least one backend"));
    }
    let mut stats = PartitionStats {
        candidates: points.len(),
        requested: cfg.backends,
        ..PartitionStats::default()
    };
    let finish = |objective: f64, aie_used: usize, members: Vec<usize>, mut stats: PartitionStats| {
        stats.selected = members.len();
        let shares: Vec<Share> = members.iter().map(|&i| footprint(points[i])).collect();
        let pl_used = shares.iter().fold(PlResources::default(), |acc, s| acc.add(&s.pl));
        Partition { members, shares, aie_used, pl_used, objective_tops: objective, stats }
    };
    let k_max = cfg.backends.min(points.len());
    // Largest size first, but a zero-objective subset must not shadow a
    // smaller one that can actually serve: a feasible k-subset whose
    // every member misses the SLO scores 0, and deploying it would shed
    // 100% of traffic while e.g. a lone SLO-feasible member exists.  So
    // a zero-objective winner is only a fallback, returned when every
    // smaller size scores zero too.
    let mut zero_fallback: Option<(f64, usize, Vec<usize>)> = None;
    for k in (1..=k_max).rev() {
        let best = if n_choose_k(points.len(), k) > cfg.enum_cap {
            best_of_size_greedy(points, bounds, k, board, cfg.slo_ms, &mut stats)
        } else {
            best_of_size_exhaustive(points, bounds, k, board, cfg.slo_ms, &mut stats)
        };
        if let Some((objective, aie_used, members)) = best {
            if objective > 0.0 {
                return Ok(finish(objective, aie_used, members, stats));
            }
            if zero_fallback.is_none() {
                zero_fallback = Some((objective, aie_used, members));
            }
        }
    }
    if let Some((objective, aie_used, members)) = zero_fallback {
        return Ok(finish(objective, aie_used, members, stats));
    }
    // unreachable in practice: every frontier point passed check_budgets
    // individually, so every 1-subset is feasible
    Err(anyhow!("no feasible partition of any size on {}", board.name))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::customize::CustomizeOptions;
    use crate::dse::Candidate;
    use crate::sched::MultiEdpuMode;

    fn point(index: usize, cores: usize, luts: usize, tops: f64, latency_ms: f64) -> DesignPoint {
        DesignPoint {
            cand: Candidate {
                index,
                opts: CustomizeOptions::default(),
                batch: 4,
                edpu_budget: cores,
                n_edpu: 1,
                multi_mode: MultiEdpuMode::Parallel,
            },
            mmsz: 64,
            plio_aie: 8,
            independent_linear: true,
            p_atb: 4,
            mha_mode: crate::arch::ParallelMode::Serial,
            ffn_mode: crate::arch::ParallelMode::Serial,
            cores_per_edpu: cores,
            total_cores: cores,
            pl_luts: luts,
            pl_ffs: luts,
            pl_brams: 10,
            pl_urams: 0,
            tops,
            latency_ms,
            gops_per_aie: 1.0,
            power_w: 10.0,
            gops_per_w: 1.0,
        }
    }

    fn board() -> HardwareConfig {
        crate::config::HardwareConfig::vck5000()
    }

    /// Worst-case service bounds in ms (the serving-batch-cap profile
    /// maxima a caller pre-simulates), as integer ns.
    fn bounds_ms(ms: &[f64]) -> Vec<u64> {
        ms.iter().map(|x| (x * 1e6) as u64).collect()
    }

    #[test]
    fn picks_the_best_feasible_pair_and_accounts_every_subset() {
        // 400-AIE board: {350, 150, 100} — the only feasible pair is
        // {150, 100} (both pairs touching the 350 blow the array).
        let pts = [
            point(0, 350, 1000, 10.0, 1.0),
            point(1, 150, 1000, 6.0, 1.0),
            point(2, 100, 1000, 5.0, 1.0),
        ];
        let refs: Vec<&DesignPoint> = pts.iter().collect();
        let bounds = bounds_ms(&[1.0, 1.0, 1.0]);
        let part = partition_frontier(&refs, &bounds, &board(), &PartitionConfig::new(2)).unwrap();
        assert_eq!(part.members, vec![1, 2]);
        assert_eq!(part.aie_used, 250);
        assert!((part.objective_tops - 11.0).abs() < 1e-12);
        let s = part.stats;
        assert_eq!((s.requested, s.selected, s.candidates), (2, 2, 3));
        assert_eq!(s.subsets_considered, 3); // C(3,2)
        assert_eq!(s.subsets_considered, s.aie_infeasible + s.pl_infeasible + s.feasible);
        assert_eq!(s.aie_infeasible, 2); // {350,150}, {350,100}
        assert!(!s.greedy);
        // shares are exactly the members' footprints
        for (&m, sh) in part.members.iter().zip(&part.shares) {
            assert_eq!(sh.aie, pts[m].total_cores);
            assert_eq!(sh.pl.luts, pts[m].pl_luts);
        }
    }

    #[test]
    fn slo_gates_the_objective_not_the_feasibility() {
        // same footprints; the point whose worst-case service bound
        // misses the SLO contributes 0 TOPS, so the pair {fast, slow}
        // loses to {fast, medium}
        let pts = [
            point(0, 100, 1000, 9.0, 100.0), // admission-infeasible but roomy
            point(1, 100, 1000, 5.0, 1.0),
            point(2, 100, 1000, 4.0, 1.0),
        ];
        let refs: Vec<&DesignPoint> = pts.iter().collect();
        let bounds = bounds_ms(&[100.0, 1.0, 1.0]);
        let mut cfg = PartitionConfig::new(2);
        cfg.slo_ms = Some(10.0);
        let part = partition_frontier(&refs, &bounds, &board(), &cfg).unwrap();
        assert_eq!(part.members, vec![1, 2]);
        assert!((part.objective_tops - 9.0).abs() < 1e-12);
        // without the SLO the 9-TOPS point wins a slot
        let part =
            partition_frontier(&refs, &bounds, &board(), &PartitionConfig::new(2)).unwrap();
        assert_eq!(part.members, vec![0, 1]);
    }

    #[test]
    fn gates_on_the_admission_bound_not_the_explore_latency() {
        // The PR 4 proxy gated on explore-time latency_ms, which diverges
        // from the router's serving-batch bound in both directions:
        //   A looks fast at explore time (1 ms) but its worst-case
        //     serving bound blows the SLO (200 ms) — it would never admit
        //     a request;
        //   B looks slow at explore time (90 ms, its own large batch) but
        //     its serving-cap bound fits easily (5 ms).
        // The fixed partitioner must score A zero and B positive — the
        // old proxy did exactly the opposite.
        let pts = [
            point(0, 100, 1000, 9.0, 1.0),  // A: explore-fast, admission-hopeless
            point(1, 100, 1000, 4.0, 90.0), // B: explore-slow, admission-fine
        ];
        let refs: Vec<&DesignPoint> = pts.iter().collect();
        let bounds = bounds_ms(&[200.0, 5.0]);
        let mut cfg = PartitionConfig::new(1);
        cfg.slo_ms = Some(50.0);
        let part = partition_frontier(&refs, &bounds, &board(), &cfg).unwrap();
        assert_eq!(part.members, vec![1], "must pick the member that can admit traffic");
        assert!((part.objective_tops - 4.0).abs() < 1e-12);
        // a pair keeps B's contribution and zeroes A's
        let mut cfg2 = PartitionConfig::new(2);
        cfg2.slo_ms = Some(50.0);
        let pair = partition_frontier(&refs, &bounds, &board(), &cfg2).unwrap();
        assert!((pair.objective_tops - 4.0).abs() < 1e-12);
    }

    #[test]
    fn mismatched_bounds_length_errors() {
        let p = point(0, 100, 100, 1.0, 1.0);
        let refs = [&p];
        let err =
            partition_frontier(&refs, &[], &board(), &PartitionConfig::new(1)).unwrap_err();
        assert!(format!("{err}").contains("service bound"), "{err}");
    }

    #[test]
    fn zero_objective_subset_does_not_shadow_a_serving_singleton() {
        // {B,C} is the only feasible pair but neither member meets the
        // SLO (objective 0); the lone SLO-feasible A must win even
        // though it means fewer backends than requested
        let pts = [
            point(0, 300, 1000, 10.0, 1.0),   // A: serves, too big to pair
            point(1, 150, 1000, 8.0, 200.0),  // B: fits, misses SLO
            point(2, 150, 1000, 7.0, 200.0),  // C: fits, misses SLO
        ];
        let refs: Vec<&DesignPoint> = pts.iter().collect();
        let bounds = bounds_ms(&[1.0, 200.0, 200.0]);
        let mut cfg = PartitionConfig::new(2);
        cfg.slo_ms = Some(10.0);
        let part = partition_frontier(&refs, &bounds, &board(), &cfg).unwrap();
        assert_eq!(part.members, vec![0], "the serving singleton must win");
        assert!((part.objective_tops - 10.0).abs() < 1e-12);
        assert_eq!((part.stats.requested, part.stats.selected), (2, 1));
        // without an SLO the same request keeps both members ({B,C})
        let part =
            partition_frontier(&refs, &bounds, &board(), &PartitionConfig::new(2)).unwrap();
        assert_eq!(part.members, vec![1, 2]);
    }

    #[test]
    fn infeasible_request_degrades_to_largest_feasible_size() {
        let pts = [point(0, 300, 1000, 10.0, 1.0), point(1, 200, 1000, 8.0, 1.0)];
        let refs: Vec<&DesignPoint> = pts.iter().collect();
        let bounds = bounds_ms(&[1.0, 1.0]);
        let part =
            partition_frontier(&refs, &bounds, &board(), &PartitionConfig::new(2)).unwrap();
        assert_eq!(part.stats.requested, 2);
        assert_eq!(part.stats.selected, 1);
        assert_eq!(part.members, vec![0]); // best singleton by TOPS
        // requests beyond the frontier size clamp the same way
        let part =
            partition_frontier(&refs, &bounds, &board(), &PartitionConfig::new(64)).unwrap();
        assert!(part.stats.selected <= 2);
    }

    #[test]
    fn pl_pools_reject_independently_of_aie() {
        let mut hw = board();
        hw.pl_luts = 1500;
        let pts = [point(0, 50, 1000, 5.0, 1.0), point(1, 50, 1000, 4.0, 1.0)];
        let refs: Vec<&DesignPoint> = pts.iter().collect();
        let bounds = bounds_ms(&[1.0, 1.0]);
        let part = partition_frontier(&refs, &bounds, &hw, &PartitionConfig::new(2)).unwrap();
        assert_eq!(part.stats.pl_infeasible, 1); // the pair: 2000 LUTs > 1500
        assert_eq!(part.stats.selected, 1);
        assert!(part.pl_used.luts <= hw.pl_luts);
    }

    #[test]
    fn greedy_path_engages_past_the_enum_cap_and_stays_feasible() {
        let pts: Vec<DesignPoint> =
            (0..12).map(|i| point(i, 30 + i, 100, 1.0 + i as f64, 1.0)).collect();
        let refs: Vec<&DesignPoint> = pts.iter().collect();
        let bounds = vec![1_000_000u64; refs.len()];
        let mut cfg = PartitionConfig::new(6);
        cfg.enum_cap = 10; // C(12,6) = 924 >> 10
        let part = partition_frontier(&refs, &bounds, &board(), &cfg).unwrap();
        assert!(part.stats.greedy);
        assert_eq!(part.stats.selected, 6);
        assert!(part.aie_used <= board().total_aie);
        assert!(part.members.windows(2).all(|w| w[0] < w[1]));
        // deterministic
        let again = partition_frontier(&refs, &bounds, &board(), &cfg).unwrap();
        assert_eq!(part.members, again.members);
    }

    #[test]
    fn greedy_density_dead_end_still_reaches_a_feasible_k() {
        // density order picks {200, 150} first and then nothing fits —
        // a single dense pass would stall at 3 members and falsely
        // degrade; the footprint pass must still find a 5-subset
        // (five 50-core points, 250 ≤ 400)
        let mut pts = vec![point(0, 200, 100, 400.0, 1.0), point(1, 150, 100, 225.0, 1.0)];
        for i in 2..12 {
            pts.push(point(i, 50, 100, 25.0, 1.0));
        }
        let refs: Vec<&DesignPoint> = pts.iter().collect();
        let bounds = vec![1_000_000u64; refs.len()];
        let mut cfg = PartitionConfig::new(5);
        cfg.enum_cap = 10; // C(12,5) = 792 >> 10
        let part = partition_frontier(&refs, &bounds, &board(), &cfg).unwrap();
        assert!(part.stats.greedy);
        assert_eq!(part.stats.selected, 5, "feasible k=5 must not degrade");
        assert!(part.aie_used <= board().total_aie);
    }

    #[test]
    fn n_choose_k_saturates_not_overflows() {
        assert_eq!(n_choose_k(3, 2), 3);
        assert_eq!(n_choose_k(12, 6), 924);
        assert_eq!(n_choose_k(2, 5), 0);
        assert_eq!(n_choose_k(200, 100), usize::MAX);
    }

    #[test]
    fn degenerate_inputs_error() {
        assert!(partition_frontier(&[], &[], &board(), &PartitionConfig::new(1)).is_err());
        let p = point(0, 100, 100, 1.0, 1.0);
        let refs = [&p];
        assert!(
            partition_frontier(&refs, &[1_000_000], &board(), &PartitionConfig::new(0)).is_err()
        );
    }
}
