//! Candidate evaluation: simulate one pruned survivor end to end and
//! reduce it to the multi-objective vector the frontier is selected on.
//!
//! Every candidate — including single-EDPU ones — goes through
//! [`run_multi_edpu`](crate::sched::run_multi_edpu) so throughput and
//! latency are whole-model (all encoder layers) and comparable across
//! deployment shapes.  Power comes from the calibrated
//! [`sim::power`](crate::sim::power) model via
//! [`metrics::multi_edpu_power_w`](crate::metrics::multi_edpu_power_w).

use std::collections::BTreeMap;

use super::space::Candidate;
use crate::arch::{AcceleratorPlan, ParallelMode};
use crate::metrics::multi_edpu_power_w;
use crate::sched::run_multi_edpu;
use crate::util::json::Json;
use anyhow::Result;

/// One evaluated design point: the candidate, the plan summary, and the
/// measured (simulated) metrics.
#[derive(Debug, Clone)]
pub struct DesignPoint {
    pub cand: Candidate,
    // -- derived plan summary --
    pub mmsz: usize,
    pub plio_aie: usize,
    pub independent_linear: bool,
    pub p_atb: usize,
    pub mha_mode: ParallelMode,
    pub ffn_mode: ParallelMode,
    pub cores_per_edpu: usize,
    /// AIE cores across all EDPU instances.
    pub total_cores: usize,
    /// PL resources across all EDPU instances (Table V estimate).
    pub pl_luts: usize,
    pub pl_ffs: usize,
    pub pl_brams: usize,
    pub pl_urams: usize,
    // -- simulated metrics --
    pub tops: f64,
    /// Per-item end-to-end latency, whole model (ms), at the candidate's
    /// **own** `cand.batch`.  This is an explore-time ranking metric, NOT
    /// a serving guarantee: the router admits on the worst-case service
    /// bound over every *serving* batch size (`Backend::max_service_ns`),
    /// and the partitioner's SLO gate uses that same bound — the two
    /// diverge from this number in both directions when `cand.batch`
    /// differs from the serving cap (see `dse::partition`).
    pub latency_ms: f64,
    pub gops_per_aie: f64,
    pub power_w: f64,
    pub gops_per_w: f64,
}

impl DesignPoint {
    /// Maximize-all objective vector for the Pareto selection:
    /// `(TOPS, −latency_ms, GOPS/W, −AIE cores, −PL LUTs)`.
    pub fn objectives(&self) -> [f64; 5] {
        [
            self.tops,
            -self.latency_ms,
            self.gops_per_w,
            -(self.total_cores as f64),
            -(self.pl_luts as f64),
        ]
    }

    pub fn to_json(&self) -> Json {
        let mode = |m: Option<ParallelMode>| match m {
            None => Json::Str("auto".into()),
            Some(m) => Json::Str(m.to_string()),
        };
        let mut m = BTreeMap::new();
        m.insert("index".into(), Json::Num(self.cand.index as f64));
        m.insert("independent_linear".into(), Json::Bool(self.independent_linear));
        m.insert("forced_mha_mode".into(), mode(self.cand.opts.force_mha_mode));
        m.insert("forced_ffn_mode".into(), mode(self.cand.opts.force_ffn_mode));
        m.insert("mha_mode".into(), Json::Str(self.mha_mode.to_string()));
        m.insert("ffn_mode".into(), Json::Str(self.ffn_mode.to_string()));
        m.insert("p_atb".into(), Json::Num(self.p_atb as f64));
        m.insert("batch".into(), Json::Num(self.cand.batch as f64));
        m.insert("edpu_budget".into(), Json::Num(self.cand.edpu_budget as f64));
        m.insert("n_edpu".into(), Json::Num(self.cand.n_edpu as f64));
        m.insert(
            "multi_mode".into(),
            Json::Str(format!("{:?}", self.cand.multi_mode).to_lowercase()),
        );
        m.insert("mmsz".into(), Json::Num(self.mmsz as f64));
        m.insert("plio_aie".into(), Json::Num(self.plio_aie as f64));
        m.insert("cores_per_edpu".into(), Json::Num(self.cores_per_edpu as f64));
        m.insert("total_cores".into(), Json::Num(self.total_cores as f64));
        m.insert("pl_luts".into(), Json::Num(self.pl_luts as f64));
        m.insert("pl_ffs".into(), Json::Num(self.pl_ffs as f64));
        m.insert("pl_brams".into(), Json::Num(self.pl_brams as f64));
        m.insert("pl_urams".into(), Json::Num(self.pl_urams as f64));
        m.insert("tops".into(), Json::Num(self.tops));
        m.insert("latency_ms".into(), Json::Num(self.latency_ms));
        m.insert("gops_per_aie".into(), Json::Num(self.gops_per_aie));
        m.insert("power_w".into(), Json::Num(self.power_w));
        m.insert("gops_per_w".into(), Json::Num(self.gops_per_w));
        Json::Obj(m)
    }

    /// Compact args for this point's evaluate-span in the DSE trace —
    /// the subset of [`DesignPoint::to_json`] worth reading in Perfetto.
    pub fn trace_args(&self) -> Vec<(String, Json)> {
        vec![
            ("index".to_string(), Json::Num(self.cand.index as f64)),
            ("tops".to_string(), Json::Num(self.tops)),
            ("latency_ms".to_string(), Json::Num(self.latency_ms)),
            ("total_cores".to_string(), Json::Num(self.total_cores as f64)),
            ("gops_per_w".to_string(), Json::Num(self.gops_per_w)),
        ]
    }
}

/// Simulate one pruned survivor.  `plan.hw` must already be the
/// deployment board (the caller swaps it in after customizing against
/// the per-EDPU budget), so the multi-EDPU budget check and the power
/// model both see the real part.
pub fn evaluate(plan: &AcceleratorPlan, cand: &Candidate) -> Result<DesignPoint> {
    let r = run_multi_edpu(plan, cand.n_edpu, cand.batch, cand.multi_mode)?;
    let power_w = multi_edpu_power_w(plan, &r);
    let total_cores = cand.n_edpu * plan.cores_deployed();
    let pl = plan.res_overall.scale(cand.n_edpu);
    let gops = r.ops as f64 / r.makespan_ns; // ops/ns == GOPS
    Ok(DesignPoint {
        cand: *cand,
        mmsz: plan.mmsz,
        plio_aie: plan.plio_aie,
        independent_linear: plan.independent_linear,
        p_atb: plan.p_atb,
        mha_mode: plan.mha.mode,
        ffn_mode: plan.ffn.mode,
        cores_per_edpu: plan.cores_deployed(),
        total_cores,
        pl_luts: pl.luts,
        pl_ffs: pl.ffs,
        pl_brams: pl.brams,
        pl_urams: pl.urams,
        tops: r.tops(),
        latency_ms: r.latency_ns / 1e6,
        gops_per_aie: gops / total_cores.max(1) as f64,
        power_w,
        gops_per_w: gops / power_w,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{HardwareConfig, ModelConfig};
    use crate::customize::{customize, CustomizeOptions};
    use crate::sched::MultiEdpuMode;

    #[test]
    fn evaluate_matches_the_underlying_multi_edpu_run() {
        let plan = customize(
            &ModelConfig::bert_base(),
            &HardwareConfig::vck5000(),
            &CustomizeOptions::default(),
        )
        .unwrap();
        let cand = Candidate {
            index: 0,
            opts: CustomizeOptions::default(),
            batch: 8,
            edpu_budget: 400,
            n_edpu: 1,
            multi_mode: MultiEdpuMode::Parallel,
        };
        let p = evaluate(&plan, &cand).unwrap();
        let r = run_multi_edpu(&plan, 1, 8, MultiEdpuMode::Parallel).unwrap();
        assert!((p.tops - r.tops()).abs() < 1e-12);
        assert!((p.latency_ms - r.latency_ns / 1e6).abs() < 1e-12);
        assert_eq!(p.total_cores, plan.cores_deployed());
        assert_eq!(p.pl_luts, plan.res_overall.luts);
        assert_eq!(p.pl_ffs, plan.res_overall.ffs);
        assert!(p.power_w > 0.0 && p.gops_per_w > 0.0);
        // objective vector orientation: better TOPS -> larger objective,
        // more cores -> smaller objective
        let o = p.objectives();
        assert_eq!(o[0], p.tops);
        assert_eq!(o[3], -(p.total_cores as f64));
        // JSON carries the headline numbers
        let j = p.to_json();
        assert_eq!(j.get("total_cores").unwrap().as_usize(), Some(352));
        assert!(j.get("tops").unwrap().as_f64().unwrap() > 0.0);
    }
}
