//! Early feasibility pruning — everything that can reject a candidate
//! *before* any discrete-event simulation is spent on it.
//!
//! Order (cheapest first, see ROADMAP §Design-space exploration):
//! 1. `customize` itself (Eq. 3–8 + the PRG allocation invariants) — a
//!    forced mode the board cannot host errors out here;
//! 2. AIE budget: `n_edpu * cores_deployed() <= Total_AIE`, the same
//!    check [`run_multi_edpu`](crate::sched::run_multi_edpu) enforces;
//! 3. PL budget: the Table V estimate, replicated per EDPU instance,
//!    must fit the board's LUT/FF/BRAM/URAM pools.

use crate::arch::{AcceleratorPlan, PlResources};
use crate::config::HardwareConfig;

/// Why a candidate was rejected without simulation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Reject {
    /// AIE cores: the EDPU replicas do not fit the array.
    Aie,
    /// PL resources: the replicated movers/operators/buffers do not fit.
    Pl,
}

/// Exploration accounting: where every *considered* point went
/// (`sampled = customize_rejected + aie_rejected + pl_rejected +
/// sim_failed + evaluated`; the space size itself lives on
/// [`ExploreResult::space_size`](super::ExploreResult::space_size)).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PruneStats {
    /// Points actually considered (== the space size unless sampled).
    pub sampled: usize,
    /// `customize` returned an error (infeasible forced attributes).
    pub customize_rejected: usize,
    /// Rejected by the AIE core budget.
    pub aie_rejected: usize,
    /// Rejected by the PL resource budget.
    pub pl_rejected: usize,
    /// Survived pruning but the simulator errored (should be rare; the
    /// budgets above are pre-checked).
    pub sim_failed: usize,
    /// Points that produced a design point (simulated successfully).
    pub evaluated: usize,
}

/// Check the post-`customize` budgets for an `n_edpu`-instance deployment
/// of `plan` on `board`.
pub fn check_budgets(
    plan: &AcceleratorPlan,
    board: &HardwareConfig,
    n_edpu: usize,
) -> Result<(), Reject> {
    if n_edpu == 0 || n_edpu * plan.cores_deployed() > board.total_aie {
        return Err(Reject::Aie);
    }
    let pl = plan.res_overall.scale(n_edpu);
    if !pl.fits_within(&PlResources::pools_of(board)) {
        return Err(Reject::Pl);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::customize::{customize, CustomizeOptions};

    #[test]
    fn budgets_reject_oversized_deployments() {
        let hw = HardwareConfig::vck5000();
        let plan = customize(
            &ModelConfig::bert_base(),
            &hw,
            &CustomizeOptions::default(),
        )
        .unwrap();
        // 352-core EDPU: one fits, two exceed the 400-AIE array
        assert_eq!(check_budgets(&plan, &hw, 1), Ok(()));
        assert_eq!(check_budgets(&plan, &hw, 2), Err(Reject::Aie));
        assert_eq!(check_budgets(&plan, &hw, 0), Err(Reject::Aie));
    }

    #[test]
    fn pl_budget_rejects_before_aie_runs_out() {
        let hw = HardwareConfig::vck5000();
        // the compact 64-core serial EDPU: AIE-wise 6 fit (384 <= 400),
        // but its replicated PL estimate runs out of BRAM first.
        let mut plan = customize(
            &ModelConfig::bert_base(),
            &HardwareConfig::vck5000_limited(64),
            &CustomizeOptions::default(),
        )
        .unwrap();
        plan.hw = hw.clone();
        assert_eq!(check_budgets(&plan, &hw, 3), Ok(()));
        let per = plan.res_overall;
        let aie_max = hw.total_aie / plan.cores_deployed();
        let bram_max = hw.pl_brams / per.brams.max(1);
        assert!(bram_max < aie_max, "fixture drifted: {bram_max} vs {aie_max}");
        assert_eq!(check_budgets(&plan, &hw, bram_max + 1), Err(Reject::Pl));
        assert_eq!(check_budgets(&plan, &hw, aie_max + 1), Err(Reject::Aie));
    }
}
