//! AIE Graph Code Generator (paper §IV.E, third optimization strategy):
//! "we generate compilable AIE engineering code of AIE MM PU in the
//! calculation engine with one click by importing configuration files".
//!
//! Without the Vitis toolchain the *output* of that generator is the
//! artifact that matters: a complete, machine-checkable description of
//! every AIE MM PU instance — core grid placement, per-core kernel
//! configuration, PLIO channel assignment with packet-switch splits, and
//! window/double-buffer settings — plus an `aiecompiler`-style graph
//! source rendering.  The simulator consumes the same structures, so the
//! generated graph and the simulated timing can never drift apart.

use std::fmt::Write as _;

use crate::arch::{AcceleratorPlan, PuClass, PuSpec};
use crate::util::json::Json;
use std::collections::BTreeMap;

/// Placement of one AIE core inside the array (col, row).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CorePlacement {
    pub col: usize,
    pub row: usize,
    /// Which (m, n, k) tile of the PU's block this core computes.
    pub tile: (usize, usize, usize),
}

/// One generated AIE MM PU instance.
#[derive(Debug, Clone)]
pub struct PuGraph {
    /// Unique instance name, e.g. `mha_qlb_large0`.
    pub name: String,
    pub class: PuClass,
    /// MMSZ^3 tile kernel configuration.
    pub mmsz: usize,
    pub cores: Vec<CorePlacement>,
    /// Input PLIO channels; each lists the operand *windows* it streams
    /// in packet-switch rotation (paper Eq. 4: at most PLIO_AIE windows
    /// per channel — a window is broadcast to every core sharing that
    /// tile, so channels are loaded by unique windows, not by cores).
    /// A-operand windows are ids `0..tiles_m*tiles_k`, B-operand windows
    /// follow.
    pub in_plio: Vec<Vec<usize>>,
    /// Output PLIO channels; result windows (`tiles_m*tiles_n`) drained
    /// per channel.
    pub out_plio: Vec<Vec<usize>>,
    /// Window bytes per operand buffer (double-buffered).
    pub window_bytes: usize,
}

/// The full generated design.
#[derive(Debug, Clone)]
pub struct AieDesign {
    pub pus: Vec<PuGraph>,
    /// Array columns used (VCK5000: 50 cols x 8 rows).
    pub cols_used: usize,
}

/// Array geometry of the AIE region we place into.
const ARRAY_ROWS: usize = 8;

/// Generate the AIE design for a customized plan.
///
/// Placement is columns-first within each PU (the AIE cascade runs along
/// rows, so the K-chain of a PU occupies consecutive cores in a column —
/// same rule CHARM/EA4RCA use), PUs packed left to right.
pub fn generate(plan: &AcceleratorPlan) -> AieDesign {
    let mut pus = Vec::new();
    let mut next_col = 0usize;

    let emit = |name: String, class: PuClass, next_col: &mut usize| {
        let spec = PuSpec::by_class(class);
        let total = spec.cores();
        let mut cores = Vec::with_capacity(total);
        let mut col = *next_col;
        let mut row = 0usize;
        for tm in 0..spec.tiles_m {
            for tn in 0..spec.tiles_n {
                for tk in 0..spec.tiles_k {
                    cores.push(CorePlacement { col, row, tile: (tm, tn, tk) });
                    row += 1;
                    if row == ARRAY_ROWS {
                        row = 0;
                        col += 1;
                    }
                }
            }
        }
        if row != 0 {
            col += 1;
        }
        *next_col = col;

        // Packet-switch assignment by unique operand windows: the A
        // operand has tiles_m*tiles_k distinct windows (each broadcast
        // along the N direction), B has tiles_n*tiles_k (broadcast along
        // M); results have tiles_m*tiles_n. Round-robin windows over the
        // channels — this is what keeps every channel at <= PLIO_AIE
        // windows (Eq. 4) even on the 64-core Large PU.
        let assign = |n_windows: usize, channels: &mut Vec<Vec<usize>>, offset: usize| {
            let n_ch = channels.len().max(1);
            for w in 0..n_windows {
                channels[w % n_ch].push(offset + w);
            }
        };
        let a_windows = spec.tiles_m * spec.tiles_k;
        let b_windows = spec.tiles_n * spec.tiles_k;
        let out_windows = spec.tiles_m * spec.tiles_n;
        let a_ch = (spec.in_plio / 2).max(1);
        let mut in_plio = vec![Vec::new(); spec.in_plio.max(1)];
        {
            let (a_part, b_part) = in_plio.split_at_mut(a_ch.min(spec.in_plio.max(1)));
            let mut a_vec = a_part.to_vec();
            assign(a_windows, &mut a_vec, 0);
            a_part.clone_from_slice(&a_vec);
            if !b_part.is_empty() {
                let mut b_vec = b_part.to_vec();
                assign(b_windows, &mut b_vec, a_windows);
                b_part.clone_from_slice(&b_vec);
            } else {
                // single input channel carries both operands' windows
                let mut both = a_part.to_vec();
                assign(b_windows, &mut both, a_windows);
                a_part.clone_from_slice(&both);
            }
        }
        let mut out_plio = vec![Vec::new(); spec.out_plio.max(1)];
        assign(out_windows, &mut out_plio, 0);
        PuGraph {
            name,
            class,
            mmsz: plan.mmsz,
            in_plio,
            out_plio,
            cores,
            window_bytes: plan.mmsz * plan.mmsz * plan.model.bytes_per_elem() * 2,
        }
    };

    for (stage_name, stage) in [("mha", &plan.mha), ("ffn", &plan.ffn)] {
        if matches!(stage.mode, crate::arch::ParallelMode::FullyPipelined) {
            // pipelined: every PRG owns disjoint PU instances
            for prg in &stage.prgs {
                for (class, n) in &prg.pus {
                    for i in 0..*n {
                        let name = format!(
                            "{stage_name}_{:?}{}_{class}{i}",
                            prg.kind, prg.atb_index
                        )
                        .to_lowercase();
                        pus.push(emit(name, *class, &mut next_col));
                    }
                }
            }
        } else {
            // serial modes: all PRGs share one pool — place it once
            // (the largest PRG allocation).
            if let Some(prg) = stage.prgs.iter().max_by_key(|p| p.cores()) {
                for (class, n) in &prg.pus {
                    for i in 0..*n {
                        let name =
                            format!("{stage_name}_shared_{class}{i}").to_lowercase();
                        pus.push(emit(name, *class, &mut next_col));
                    }
                }
            }
        }
        // the FFN stage reuses the MHA stage's Large PUs (hardware
        // sharing): do not place them twice.
        if stage_name == "mha"
            && plan
                .ffn
                .prgs
                .iter()
                .all(|p| p.pus.iter().all(|(c, _)| *c == PuClass::Large))
        {
            break;
        }
    }

    AieDesign { pus, cols_used: next_col }
}

impl AieDesign {
    pub fn total_cores(&self) -> usize {
        self.pus.iter().map(|p| p.cores.len()).sum()
    }

    /// Every core must satisfy Eq. 4: its PLIO channel feeds at most
    /// `plio_aie` cores in packet-switch mode.
    pub fn validate(&self, plio_aie: usize) -> Result<(), String> {
        let mut seen = std::collections::BTreeSet::new();
        for pu in &self.pus {
            for ch in pu.in_plio.iter().chain(&pu.out_plio) {
                if ch.len() > plio_aie {
                    return Err(format!(
                        "PU '{}' channel feeds {} cores > PLIO_AIE {}",
                        pu.name,
                        ch.len(),
                        plio_aie
                    ));
                }
            }
            for c in &pu.cores {
                if !seen.insert((c.col, c.row)) {
                    return Err(format!(
                        "PU '{}' overlaps another PU at ({}, {})",
                        pu.name, c.col, c.row
                    ));
                }
                if c.row >= ARRAY_ROWS {
                    return Err(format!("row {} out of range", c.row));
                }
            }
            // window must fit AIE local memory (32 KiB), double buffered
            if pu.window_bytes * 4 > 32 * 1024 {
                return Err(format!(
                    "PU '{}' window {}B x4 exceeds 32 KiB",
                    pu.name, pu.window_bytes
                ));
            }
        }
        Ok(())
    }

    /// Render an `aiecompiler`-style graph source (what the paper's
    /// generator emits "with one click").
    pub fn render_graph_source(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "// generated by cat::codegen — do not edit");
        let _ = writeln!(s, "#include <adf.h>");
        let _ = writeln!(s, "using namespace adf;\n");
        for pu in &self.pus {
            let _ = writeln!(s, "class {} : public graph {{", pu.name);
            let _ = writeln!(s, "  kernel mm[{}];", pu.cores.len());
            let _ = writeln!(
                s,
                "  input_plio in[{}]; output_plio out[{}];",
                pu.in_plio.len(),
                pu.out_plio.len()
            );
            let _ = writeln!(s, "public:\n  {}() {{", pu.name);
            for (i, c) in pu.cores.iter().enumerate() {
                let _ = writeln!(
                    s,
                    "    mm[{i}] = kernel::create(mm_int8_{sz});  // tile {:?}",
                    c.tile,
                    sz = pu.mmsz
                );
                let _ = writeln!(
                    s,
                    "    location<kernel>(mm[{i}]) = tile({}, {});",
                    c.col, c.row
                );
            }
            for (ci, windows) in pu.in_plio.iter().enumerate() {
                for w in windows {
                    let _ = writeln!(
                        s,
                        "    connect<window<{wb}>>(in[{ci}].out[0], opbuf[{w}]);  // pktswitch",
                        wb = pu.window_bytes
                    );
                }
            }
            for (ci, windows) in pu.out_plio.iter().enumerate() {
                for w in windows {
                    let _ = writeln!(
                        s,
                        "    connect<window<{wb}>>(resbuf[{w}], out[{ci}].in[0]);",
                        wb = pu.window_bytes
                    );
                }
            }
            let _ = writeln!(s, "  }}\n}};\n");
        }
        s
    }

    /// Export as JSON (the generator's "configuration file" interface).
    pub fn to_json(&self) -> Json {
        let pus: Vec<Json> = self
            .pus
            .iter()
            .map(|p| {
                let mut m = BTreeMap::new();
                m.insert("name".into(), Json::Str(p.name.clone()));
                m.insert("class".into(), Json::Str(p.class.to_string()));
                m.insert("mmsz".into(), Json::Num(p.mmsz as f64));
                m.insert("cores".into(), Json::Num(p.cores.len() as f64));
                m.insert("window_bytes".into(), Json::Num(p.window_bytes as f64));
                m.insert("in_plio".into(), Json::Num(p.in_plio.len() as f64));
                m.insert("out_plio".into(), Json::Num(p.out_plio.len() as f64));
                Json::Obj(m)
            })
            .collect();
        let mut m = BTreeMap::new();
        m.insert("pus".into(), Json::Arr(pus));
        m.insert("total_cores".into(), Json::Num(self.total_cores() as f64));
        m.insert("cols_used".into(), Json::Num(self.cols_used as f64));
        Json::Obj(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{HardwareConfig, ModelConfig};
    use crate::customize::{customize, CustomizeOptions};

    fn bert_design() -> (AcceleratorPlan, AieDesign) {
        let plan = customize(
            &ModelConfig::bert_base(),
            &HardwareConfig::vck5000(),
            &CustomizeOptions::default(),
        )
        .unwrap();
        let design = generate(&plan);
        (plan, design)
    }

    #[test]
    fn generates_352_core_design() {
        let (plan, design) = bert_design();
        assert_eq!(design.total_cores(), plan.cores_deployed());
        assert_eq!(design.total_cores(), 352);
        design.validate(plan.plio_aie).unwrap();
    }

    #[test]
    fn packet_switch_respects_eq4() {
        let (plan, design) = bert_design();
        for pu in &design.pus {
            for ch in pu.in_plio.iter().chain(&pu.out_plio) {
                assert!(ch.len() <= plan.plio_aie, "{}: {}", pu.name, ch.len());
            }
        }
    }

    #[test]
    fn no_core_overlap_and_fits_array() {
        let (_, design) = bert_design();
        // VCK5000 AIE array: 50 columns x 8 rows = 400 cores
        assert!(design.cols_used <= 50, "{} cols", design.cols_used);
    }

    #[test]
    fn windows_fit_local_memory() {
        let (_, design) = bert_design();
        for pu in &design.pus {
            // Eq. 3: double-buffered operand pairs fill <= the 32 KiB window
            assert!(pu.window_bytes * 4 <= 32 * 1024, "{}", pu.window_bytes);
        }
    }

    #[test]
    fn graph_source_renders() {
        let (_, design) = bert_design();
        let src = design.render_graph_source();
        assert!(src.contains("#include <adf.h>"));
        assert!(src.contains("mm_int8_64"));
        assert!(src.contains("pktswitch"));
        // one class per PU instance
        assert_eq!(src.matches("public graph").count(), design.pus.len());
    }

    #[test]
    fn json_export_consistent() {
        let (_, design) = bert_design();
        let j = design.to_json();
        assert_eq!(j.get("total_cores").unwrap().as_usize(), Some(352));
        let text = j.to_string();
        assert!(crate::util::json::Json::parse(&text).is_ok());
    }

    #[test]
    fn limited_serial_design_generates_too() {
        let plan = customize(
            &ModelConfig::bert_base(),
            &HardwareConfig::vck5000_limited(64),
            &CustomizeOptions::default(),
        )
        .unwrap();
        let design = generate(&plan);
        design.validate(plan.plio_aie).unwrap();
        assert_eq!(design.total_cores(), 64);
    }
}
