//! EDPU execution scheduling (paper Algorithm 1) over the ACAP simulator.
//!
//! Builds a [`Scenario`](crate::sim::Scenario) per EDPU stage from an
//! [`AcceleratorPlan`](crate::arch::AcceleratorPlan) and runs it:
//!
//! * **fully-pipelined** — one dataflow graph: LB PRGs stream into the
//!   `P_ATB` parallel ATBs (through the PL transpose/softmax branches)
//!   into the Proj LB; everything overlaps;
//! * **serial-hybrid** — QKV LBs run serially on the whole engine, then
//!   the ATBs in parallel, then Proj (paper mode (2));
//! * **serial** — every PRG in turn on the shared pool (Limited-AIE).
//!
//! Batch handling: `n_inv` scales with `batch_size`; pipeline fill
//! amortizes exactly like the paper's Figure 5.

pub mod cache;
pub mod multi;

pub use cache::{reset_stage_cache, stage_cache_len, stage_cache_stats};
pub use multi::{edpu_count_sweep, max_deployable, run_multi_edpu, MultiEdpuMode, MultiEdpuReport};

use crate::arch::{AcceleratorPlan, ParallelMode, Prg, PrgKind, PuSpec};
use crate::config::HardwareConfig;
use crate::sim::scenario::{EdgeSpec, NodeSpec, PortSpec, PuTiming, Scenario};
use crate::sim::{run, SimReport};
use crate::workload::{layer_workload, MmSite, Workload};
use anyhow::{anyhow, Result};

/// Which EDPU stage.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Stage {
    Mha,
    Ffn,
}

/// Result of executing one stage for `batch` items.
#[derive(Debug, Clone)]
pub struct StageReport {
    pub stage: Stage,
    pub batch: usize,
    /// Simulated wall time for the whole batch (ns).
    pub makespan_ns: f64,
    /// Useful MM ops executed (MAC*2), for all batch items.
    pub ops: u64,
    /// Cores this stage has deployed (its PU allocation).
    pub cores_deployed: usize,
    /// Cores that actually participate (Eq. 2 numerator).
    pub cores_running: usize,
    /// Temporal PU busy fraction from the DES.
    pub temporal_utilization: f64,
    pub sim: SimReport,
}

impl StageReport {
    /// Achieved throughput in TOPS.
    pub fn tops(&self) -> f64 {
        self.ops as f64 / self.makespan_ns / 1e3
    }

    /// GOPS per *deployed* AIE (the paper's GOPS/AIE column divides by the
    /// cores the stage actually engages).
    pub fn gops_per_aie(&self) -> f64 {
        self.ops as f64 / self.makespan_ns / self.cores_running.max(1) as f64
    }

    /// Eq. 2 at stage granularity.
    pub fn eff_utilization(&self) -> f64 {
        self.cores_running as f64 / self.cores_deployed.max(1) as f64
    }

    /// Per-item latency once the pipeline is warm.
    pub fn latency_per_item_ns(&self) -> f64 {
        self.makespan_ns / self.batch as f64
    }
}

/// Result of a full EDPU execution (MHA then FFN, serial — Algorithm 1).
#[derive(Debug, Clone)]
pub struct EdpuReport {
    pub mha: StageReport,
    pub ffn: StageReport,
    pub batch: usize,
}

impl EdpuReport {
    pub fn makespan_ns(&self) -> f64 {
        self.mha.makespan_ns + self.ffn.makespan_ns
    }

    pub fn latency_per_item_ns(&self) -> f64 {
        self.makespan_ns() / self.batch as f64
    }

    pub fn ops(&self) -> u64 {
        self.mha.ops + self.ffn.ops
    }

    pub fn tops(&self) -> f64 {
        self.ops() as f64 / self.makespan_ns() / 1e3
    }

    /// System GOPS/AIE over the union of engaged cores.
    pub fn gops_per_aie(&self) -> f64 {
        let cores = self.mha.cores_running.max(self.ffn.cores_running).max(1);
        self.ops() as f64 / self.makespan_ns() / cores as f64
    }

    /// Paper Table V "overall" row: simple average of the stage rates.
    pub fn avg_eff_utilization(&self) -> f64 {
        (self.mha.eff_utilization() + self.ffn.eff_utilization()) / 2.0
    }

    /// Average running cores over the EDPU execution (power-model input).
    pub fn running_avg(&self) -> f64 {
        (self.mha.cores_running as f64 * self.mha.makespan_ns
            + self.ffn.cores_running as f64 * self.ffn.makespan_ns)
            / self.makespan_ns()
    }
}

// ---------------------------------------------------------------------------
// PU timing + invocation counting
// ---------------------------------------------------------------------------

/// PLIO payload bandwidth, bytes/ns — scaled by the part's shared
/// memory-path throttle.  A whole board streams at the nominal PLIO rate
/// (`mem_throttle == 1.0`, multiplication is exact identity); a board
/// *slice* granted a proportional share of a contended DRAM/PCIe pool
/// (`serve::links`) feeds its stream movers correspondingly slower, so
/// send/receive phases stretch by `1/mem_throttle` while compute
/// (`t_calc`) is untouched.  Design-time customization (Eq. 3–8 via
/// `HardwareConfig::t_window_ns`) deliberately ignores the throttle: the
/// deployed design is fixed; contention is a runtime effect.
fn plio_bytes_per_ns(hw: &HardwareConfig) -> f64 {
    hw.plio_bits as f64 / 8.0 * hw.pl_freq_mhz * 1e-3 * hw.mem_throttle
}

/// Per-invocation phase times of one PU (see DESIGN.md §7: the rigid
/// spec-shaped operand streaming keeps send ≈ calc — the paper's
/// `T_PU ≈ T_Calc` design point).
pub fn pu_timing(
    spec: &PuSpec,
    hw: &HardwareConfig,
    mmsz: usize,
    out_elem_bytes: usize,
) -> PuTiming {
    let bw = plio_bytes_per_ns(hw);
    let (m, n, _) = spec.invocation_shape(mmsz);
    let t_send = spec.in_bytes(mmsz) as f64 / (spec.in_plio as f64 * bw);
    let t_recv = (m * n * out_elem_bytes) as f64 / (spec.out_plio as f64 * bw);
    PuTiming {
        t_send_ns: t_send,
        t_calc_ns: hw.t_calc_ns(mmsz),
        t_recv_ns: t_recv,
    }
}

/// Invocations for a PU *group* to cover `count` matmuls of `[m,k]x[k,n]`.
///
/// Tiles are **packed across the `count` small matmuls** (the paper's
/// "extract and aggregate the small QKV calculations ... into a whole" —
/// this is what lets the Limited-AIE serial design reach ~150 GOPS/AIE
/// instead of wasting cores on under-full invocations).  The result is
/// the *total* invocation count; the engine spreads it over the group's
/// PU instances, so beats = n_inv / instances.
fn invocations(
    pus: &[(crate::arch::PuClass, usize)],
    mmsz: usize,
    count: usize,
    m: usize,
    n: usize,
    k: usize,
) -> usize {
    invocations_opt(pus, mmsz, count, m, n, k, true)
}

/// Like [`invocations`] with the aggregation toggle exposed: without
/// independent-linear, each of the `count` small matmuls runs alone and
/// pays its own partially-filled invocation (the Table II Lab 1/2/4
/// organization).
fn invocations_opt(
    pus: &[(crate::arch::PuClass, usize)],
    mmsz: usize,
    count: usize,
    m: usize,
    n: usize,
    k: usize,
    packed: bool,
) -> usize {
    let cores: usize = pus
        .iter()
        .map(|(c, n_)| PuSpec::by_class(*c).cores() * n_)
        .sum();
    let instances: usize = pus.iter().map(|(_, n_)| n_).sum();
    let tiles = m.div_ceil(mmsz) * n.div_ceil(mmsz) * k.div_ceil(mmsz);
    if packed {
        (count * tiles).div_ceil(cores.max(1)) * instances.max(1)
    } else {
        count * tiles.div_ceil(cores.max(1)) * instances.max(1)
    }
}

/// All PU instances of a PRG as individual `PuTiming`s (one per instance).
fn prg_pu_timings(
    prg: &Prg,
    hw: &HardwareConfig,
    mmsz: usize,
    out_elem_bytes: usize,
) -> Vec<PuTiming> {
    let mut v = Vec::new();
    for (class, n) in &prg.pus {
        let spec = PuSpec::by_class(*class);
        for _ in 0..*n {
            v.push(pu_timing(&spec, hw, mmsz, out_elem_bytes));
        }
    }
    v
}

// ---------------------------------------------------------------------------
// Scenario construction
// ---------------------------------------------------------------------------

/// Connect producer -> consumer moving ~`total_bytes`, conserving flow
/// exactly by scaling both ports to a common unit.
fn connect(
    sc: &mut Scenario,
    edge: EdgeSpec,
    total_bytes: u64,
    prod_inv: usize,
    cons_inv: usize,
) -> (usize, PortSpec, PortSpec) {
    let unit = (total_bytes / (prod_inv as u64 * cons_inv as u64)).max(1);
    let mut e = edge;
    // Deadlock-freedom: a consumer grain can span several producer
    // grains, and consumption leaves residues when the grains are not
    // multiples of each other — the buffer must always have room for one
    // more producer grain until a full consumer grain has accumulated.
    // capacity >= cons + prod guarantees that for any residue.
    let cons_grain = unit * prod_inv as u64;
    let prod_grain = unit * cons_inv as u64;
    let min_cap = cons_grain + prod_grain;
    if e.capacity_bytes < min_cap {
        e.capacity_bytes = min_cap;
    }
    let id = sc.add_edge(e);
    // conservation: prod_inv * (unit*cons_inv) == cons_inv * (unit*prod_inv)
    let cons_port = PortSpec { edge: id, bytes_per_inv: unit * prod_inv as u64 };
    let prod_port = PortSpec { edge: id, bytes_per_inv: unit * cons_inv as u64 };
    (id, cons_port, prod_port)
}

/// PL operator edge: latency = module pipeline depth, infinite rate.
///
/// The paper's Observation 1/2: PL operator modules are "inserted into the
/// backbone data flow [and] will not affect the overall delay, but will
/// only increase the depth of the pipeline" — i.e. they are rate-matched
/// to the streams they sit on, contributing latency, not throughput loss.
fn pl_edge(hw: &HardwareConfig, capacity: u64, depth_rows: f64) -> EdgeSpec {
    EdgeSpec {
        capacity_bytes: capacity,
        latency_ns: depth_rows / (hw.pl_freq_mhz * 1e-3), // depth cycles
        bw_bytes_per_ns: f64::INFINITY,
    }
}

/// Build the fully-pipelined MHA scenario (Fig. 3 dataflow).
pub fn build_mha_pipelined(
    plan: &AcceleratorPlan,
    wl: &Workload,
    batch: usize,
    atb_pipelined: bool,
) -> Result<Scenario> {
    let hw = &plan.hw;
    let mmsz = plan.mmsz;
    let p_atb = plan.p_atb;
    // 3 LBs + pre/post per ATB + Proj; 5 edges per ATB.
    let mut sc = Scenario::with_capacity(4 + 2 * p_atb, 5 * p_atb);

    let qkv = wl
        .mms_at(MmSite::QkvLb)
        .ok_or_else(|| anyhow!("workload missing QKV"))?;
    let pre = wl.mms_at(MmSite::AtbPre).unwrap();
    let post = wl.mms_at(MmSite::AtbPost).unwrap();
    let proj = wl.mms_at(MmSite::ProjLb).unwrap();

    // --- LB nodes (Q, K, V) ---
    let lb_kinds = [PrgKind::QLb, PrgKind::KLb, PrgKind::VLb];
    let lb_prgs: Vec<&Prg> = lb_kinds
        .iter()
        .filter_map(|k| plan.mha.prgs_of(*k).next())
        .collect();
    if lb_prgs.len() != 3 {
        return Err(anyhow!("pipelined MHA needs Q/K/V LB PRGs"));
    }
    // per-LB matmul: with independent linear each LB computes one
    // [L,E]x[E,E]; per-head it computes `heads` small [L,dh] projections.
    // with independent linear the QKV tiles aggregate into full PU loads;
    // per-head linears each pay their own (partially filled) invocations.
    let (lb_count, lb_m, lb_n, lb_k) = (qkv.count / 3, qkv.m, qkv.n, qkv.k);
    let lb_inv: Vec<usize> = lb_prgs
        .iter()
        .map(|p| {
            batch
                * invocations_opt(
                    &p.pus,
                    mmsz,
                    lb_count,
                    lb_m,
                    lb_n,
                    lb_k,
                    plan.independent_linear,
                )
        })
        .collect();

    // --- ATB nodes ---
    let atb_pre_prgs: Vec<&Prg> = plan.mha.prgs_of(PrgKind::AtbPre).collect();
    let atb_post_prgs: Vec<&Prg> = plan.mha.prgs_of(PrgKind::AtbPost).collect();
    if atb_pre_prgs.len() != p_atb || atb_post_prgs.len() != p_atb {
        return Err(anyhow!("expected {p_atb} ATB pre/post PRGs"));
    }
    let heads_per_atb = wl.model.heads.div_ceil(p_atb);
    let pre_inv: Vec<usize> = atb_pre_prgs
        .iter()
        .map(|p| batch * invocations(&p.pus, mmsz, heads_per_atb, pre.m, pre.n, pre.k))
        .collect();
    let post_inv: Vec<usize> = atb_post_prgs
        .iter()
        .map(|p| batch * invocations(&p.pus, mmsz, heads_per_atb, post.m, post.n, post.k))
        .collect();

    // --- Proj node ---
    let proj_prg = plan
        .mha
        .prgs_of(PrgKind::ProjLb)
        .next()
        .ok_or_else(|| anyhow!("missing Proj PRG"))?;
    let proj_inv = batch * invocations(&proj_prg.pus, mmsz, proj.count, proj.m, proj.n, proj.k);

    // Byte volumes (per whole batch)
    let l = wl.model.padded_seq_len(mmsz) as u64;
    let e_dim = wl.model.embed_dim as u64;
    let dh = wl.model.head_dim() as u64;
    let b = batch as u64;
    let q_bytes_per_atb = b * l * dh * heads_per_atb as u64; // int8
    let scores_bytes = b * heads_per_atb as u64 * l * l * 4; // int32 scores
    let ctx_bytes_per_atb = b * l * dh * heads_per_atb as u64;

    // node indices
    let mut nodes: Vec<NodeSpec> = Vec::new();

    // Q/K/V LB -> per-ATB edges. Q and K feed pre; V feeds post.
    // Edge capacities from the §V.B buffer accounting.
    let qkv_out_cap = (l * (plan.plio_aie * mmsz) as u64) / p_atb as u64;

    // build LB nodes first (ports filled below)
    for (i, prg) in lb_prgs.iter().enumerate() {
        nodes.push(NodeSpec {
            name: format!("{:?}", lb_kinds[i]),
            pus: prg_pu_timings(prg, hw, mmsz, 1),
            pipelined: true,
            n_inv: lb_inv[i],
            cores: prg.cores(),
            inputs: vec![],
            outputs: vec![],
        });
    }
    let (qi, ki, vi) = (0usize, 1usize, 2usize);

    // ATB + proj nodes
    let mut pre_ids = Vec::new();
    let mut post_ids = Vec::new();
    for a in 0..p_atb {
        // score elements leave the PU as int32 (dequantized on PL after)
        nodes.push(NodeSpec {
            name: format!("AtbPre{a}"),
            pus: prg_pu_timings(atb_pre_prgs[a], hw, mmsz, 4),
            pipelined: atb_pipelined,
            n_inv: pre_inv[a],
            cores: atb_pre_prgs[a].cores(),
            inputs: vec![],
            outputs: vec![],
        });
        pre_ids.push(nodes.len() - 1);
        nodes.push(NodeSpec {
            name: format!("AtbPost{a}"),
            pus: prg_pu_timings(atb_post_prgs[a], hw, mmsz, 1),
            pipelined: atb_pipelined,
            n_inv: post_inv[a],
            cores: atb_post_prgs[a].cores(),
            inputs: vec![],
            outputs: vec![],
        });
        post_ids.push(nodes.len() - 1);
    }
    nodes.push(NodeSpec {
        name: "ProjLb".into(),
        pus: prg_pu_timings(proj_prg, hw, mmsz, 1),
        pipelined: true,
        n_inv: proj_inv,
        cores: proj_prg.cores(),
        inputs: vec![],
        outputs: vec![],
    });
    let proj_id = nodes.len() - 1;

    for n in nodes {
        sc.add_node(n);
    }

    // wire edges
    for a in 0..p_atb {
        // Q -> pre (plain wire buffer). The Q LB emits a slice to every
        // ATB's edge each invocation.
        let (_eq, cq, pq) = connect(
            &mut sc,
            EdgeSpec::wire(qkv_out_cap.max(1)),
            q_bytes_per_atb,
            lb_inv[qi],
            pre_inv[a],
        );
        sc.nodes[qi].outputs.push(pq);
        sc.nodes[pre_ids[a]].inputs.push(cq);

        // K -> pre through the PL transpose module
        let (_ek, ckk, pk) = connect(
            &mut sc,
            pl_edge(hw, qkv_out_cap.max(1), 64.0),
            q_bytes_per_atb,
            lb_inv[ki],
            pre_inv[a],
        );
        sc.nodes[ki].outputs.push(pk);
        sc.nodes[pre_ids[a]].inputs.push(ckk);

        // V -> post (buffered until attention ready)
        let (_ev, cv, pv) = connect(
            &mut sc,
            EdgeSpec::wire((l * dh * 4).max(1)),
            ctx_bytes_per_atb,
            lb_inv[vi],
            post_inv[a],
        );
        sc.nodes[vi].outputs.push(pv);
        sc.nodes[post_ids[a]].inputs.push(cv);

        // pre -> post through the PL softmax module (attention cache)
        let attn_cap = (l * l / 2).max(1);
        let (_es, cs, ps) = connect(
            &mut sc,
            pl_edge(hw, attn_cap, 128.0),
            scores_bytes,
            pre_inv[a],
            post_inv[a],
        );
        sc.nodes[pre_ids[a]].outputs.push(ps);
        sc.nodes[post_ids[a]].inputs.push(cs);

        // post -> proj
        let (_ep, cp, pp) = connect(
            &mut sc,
            EdgeSpec::wire((l * e_dim).max(1)),
            ctx_bytes_per_atb,
            post_inv[a],
            proj_inv,
        );
        sc.nodes[post_ids[a]].outputs.push(pp);
        sc.nodes[proj_id].inputs.push(cp);
    }

    // drop the dangling first-connect edges (created before wiring fix):
    // rebuild scenario cleanly instead.
    let sc = rebuild_without_orphans(sc);
    Ok(sc)
}

/// Remove edges that ended up with no producer or consumer (construction
/// artifacts), remapping port indices.  No-ops (and keeps the original
/// allocations) when every edge is fully wired — the common case.
fn rebuild_without_orphans(sc: Scenario) -> Scenario {
    let mut used = vec![false; sc.edges.len()];
    for n in &sc.nodes {
        for p in &n.inputs {
            used[p.edge] = true;
        }
    }
    let mut also_out = vec![false; sc.edges.len()];
    for n in &sc.nodes {
        for p in &n.outputs {
            also_out[p.edge] = true;
        }
    }
    let keep: Vec<bool> = used
        .iter()
        .zip(&also_out)
        .map(|(a, b)| *a && *b)
        .collect();
    if keep.iter().all(|k| *k) {
        return sc;
    }
    let mut remap = vec![usize::MAX; sc.edges.len()];
    let mut new_edges = Vec::new();
    for (i, k) in keep.iter().enumerate() {
        if *k {
            remap[i] = new_edges.len();
            new_edges.push(sc.edges[i]);
        }
    }
    let mut new_nodes = sc.nodes;
    for n in &mut new_nodes {
        n.inputs.retain(|p| keep[p.edge]);
        n.outputs.retain(|p| keep[p.edge]);
        for p in n.inputs.iter_mut().chain(n.outputs.iter_mut()) {
            p.edge = remap[p.edge];
        }
    }
    Scenario { nodes: new_nodes, edges: new_edges }
}

/// Build the fully-pipelined FFN scenario: FFN1 -> GELU (PL) -> FFN2.
pub fn build_ffn_pipelined(
    plan: &AcceleratorPlan,
    wl: &Workload,
    batch: usize,
) -> Result<Scenario> {
    let hw = &plan.hw;
    let mmsz = plan.mmsz;
    let mut sc = Scenario::with_capacity(2, 1);
    let f1 = wl.mms_at(MmSite::Ffn1Lb).unwrap();
    let f2 = wl.mms_at(MmSite::Ffn2Lb).unwrap();
    let p1 = plan
        .ffn
        .prgs_of(PrgKind::Ffn1Lb)
        .next()
        .ok_or_else(|| anyhow!("missing FFN1 PRG"))?;
    let p2 = plan
        .ffn
        .prgs_of(PrgKind::Ffn2Lb)
        .next()
        .ok_or_else(|| anyhow!("missing FFN2 PRG"))?;
    let inv1 = batch * invocations(&p1.pus, mmsz, f1.count, f1.m, f1.n, f1.k);
    let inv2 = batch * invocations(&p2.pus, mmsz, f2.count, f2.m, f2.n, f2.k);

    let n1 = sc.add_node(NodeSpec {
        name: "Ffn1Lb".into(),
        pus: prg_pu_timings(p1, hw, mmsz, 1),
        pipelined: true,
        n_inv: inv1,
        cores: p1.cores(),
        inputs: vec![],
        outputs: vec![],
    });
    let n2 = sc.add_node(NodeSpec {
        name: "Ffn2Lb".into(),
        pus: prg_pu_timings(p2, hw, mmsz, 1),
        pipelined: true,
        n_inv: inv2,
        cores: p2.cores(),
        inputs: vec![],
        outputs: vec![],
    });

    let l = wl.model.padded_seq_len(mmsz) as u64;
    let d = wl.model.dff as u64;
    let hidden_bytes = batch as u64 * l * d; // int8 through GELU
    let (_e, c, p) = connect(
        &mut sc,
        pl_edge(hw, l * d, 64.0),
        hidden_bytes,
        inv1,
        inv2,
    );
    sc.nodes[n1].outputs.push(p);
    sc.nodes[n2].inputs.push(c);
    Ok(sc)
}

/// Serial execution: each step's PRGs run to completion before the next
/// step starts (paper mode (2) steps / Limited-AIE full serial).
/// Returns total makespan + merged stats.
fn run_serial_steps(steps: Vec<Scenario>) -> Result<(f64, Vec<SimReport>)> {
    let mut total = 0.0;
    let mut reports = Vec::new();
    for sc in steps {
        let r = run(&sc).map_err(|e| anyhow!("sim: {e}"))?;
        total += r.makespan_ns;
        reports.push(r);
    }
    Ok((total, reports))
}

/// One single-node scenario: `prg` grinding through `count` matmuls.
fn mono_scenario(
    name: &str,
    prg: &Prg,
    hw: &HardwareConfig,
    mmsz: usize,
    count: usize,
    m: usize,
    n: usize,
    k: usize,
    out_elem: usize,
    pipelined: bool,
) -> Scenario {
    let mut sc = Scenario::default();
    sc.add_node(NodeSpec {
        name: name.into(),
        pus: prg_pu_timings(prg, hw, mmsz, out_elem),
        pipelined,
        n_inv: invocations(&prg.pus, mmsz, count, m, n, k),
        cores: prg.cores(),
        inputs: vec![],
        outputs: vec![],
    });
    sc
}

/// Execute one stage for `batch` items per the plan's parallel mode.
pub fn run_stage(plan: &AcceleratorPlan, stage: Stage, batch: usize) -> Result<StageReport> {
    run_stage_opts(plan, stage, batch, true)
}

/// Like [`run_stage`] but exposing the ATB internal-pipelining toggle
/// (Table II ablation).
pub fn run_stage_opts(
    plan: &AcceleratorPlan,
    stage: Stage,
    batch: usize,
    atb_pipelined: bool,
) -> Result<StageReport> {
    if batch == 0 {
        return Err(anyhow!("batch must be positive"));
    }
    // Stage-sim memoization: the simulator is deterministic, so the report
    // is a pure function of (plan, stage, batch, atb_pipelined).
    let key = cache::enabled().then(|| cache::StageKey {
        plan_fp: plan.fingerprint(),
        stage,
        batch,
        atb_pipelined,
    });
    if let Some(k) = &key {
        if let Some(cached) = cache::lookup(k) {
            // cache hits still count as a stage run for observability:
            // the cached report keeps its fast-forward coverage
            crate::obs::record_stage_run(cached.sim.fast_forwarded);
            return Ok(cached);
        }
    }
    let wl = layer_workload(&plan.model, plan.mmsz, plan.independent_linear);
    let useful = plan.model.useful_fraction(plan.mmsz);
    let (mode, plan_stage) = match stage {
        Stage::Mha => (plan.mha.mode, &plan.mha),
        Stage::Ffn => (plan.ffn.mode, &plan.ffn),
    };
    let hw = &plan.hw;
    let mmsz = plan.mmsz;

    let (makespan, sims, cores_running) = match (stage, mode) {
        (Stage::Mha, ParallelMode::FullyPipelined) => {
            let sc = build_mha_pipelined(plan, &wl, batch, atb_pipelined)?;
            let r = run(&sc).map_err(|e| anyhow!("sim: {e}"))?;
            let running = plan_stage.cores_deployed();
            (r.makespan_ns, vec![r], running)
        }
        (Stage::Ffn, ParallelMode::FullyPipelined) => {
            let sc = build_ffn_pipelined(plan, &wl, batch)?;
            let r = run(&sc).map_err(|e| anyhow!("sim: {e}"))?;
            let running = plan_stage.cores_deployed();
            (r.makespan_ns, vec![r], running)
        }
        (Stage::Mha, ParallelMode::SerialHybrid) => {
            // LBs serial on the whole pool, ATBs parallel, Proj serial.
            let mut steps = Vec::new();
            for prg in plan_stage.prgs.iter().filter(|p| {
                matches!(p.kind, PrgKind::QkvLb | PrgKind::QLb | PrgKind::KLb | PrgKind::VLb)
            }) {
                let mm = wl.mms_at(MmSite::QkvLb).unwrap();
                let per_prg = if plan.independent_linear { mm.count } else { mm.count / 3 };
                steps.push(mono_scenario(
                    &format!("{:?}", prg.kind),
                    prg,
                    hw,
                    mmsz,
                    per_prg * batch,
                    mm.m,
                    mm.n,
                    mm.k,
                    1,
                    true,
                ));
            }
            // parallel ATBs: one scenario with p_atb independent chains
            let mut atb_sc = Scenario::default();
            let pre = wl.mms_at(MmSite::AtbPre).unwrap();
            let post = wl.mms_at(MmSite::AtbPost).unwrap();
            let heads_per_atb = plan.model.heads.div_ceil(plan.p_atb);
            for prg in plan_stage.prgs.iter().filter(|p| p.kind.is_atb()) {
                let (mm, heads) = if prg.kind == PrgKind::AtbPre {
                    (pre, heads_per_atb)
                } else {
                    (post, heads_per_atb)
                };
                atb_sc.add_node(NodeSpec {
                    name: format!("{:?}{}", prg.kind, prg.atb_index),
                    pus: prg_pu_timings(
                        prg,
                        hw,
                        mmsz,
                        if prg.kind == PrgKind::AtbPre { 4 } else { 1 },
                    ),
                    pipelined: atb_pipelined,
                    n_inv: batch * invocations(&prg.pus, mmsz, heads, mm.m, mm.n, mm.k),
                    cores: prg.cores(),
                    inputs: vec![],
                    outputs: vec![],
                });
            }
            steps.push(atb_sc);
            let proj = wl.mms_at(MmSite::ProjLb).unwrap();
            if let Some(prg) = plan_stage.prgs_of(PrgKind::ProjLb).next() {
                steps.push(mono_scenario(
                    "ProjLb", prg, hw, mmsz, proj.count * batch, proj.m, proj.n, proj.k, 1, true,
                ));
            }
            let (t, rs) = run_serial_steps(steps)?;
            let running = plan_stage.cores_deployed();
            (t, rs, running)
        }
        (Stage::Ffn, ParallelMode::SerialHybrid) | (Stage::Ffn, ParallelMode::Serial) => {
            let f1 = wl.mms_at(MmSite::Ffn1Lb).unwrap();
            let f2 = wl.mms_at(MmSite::Ffn2Lb).unwrap();
            let mut steps = Vec::new();
            for (mm, kind) in [(f1, PrgKind::Ffn1Lb), (f2, PrgKind::Ffn2Lb)] {
                let prg = plan_stage
                    .prgs_of(kind)
                    .next()
                    .ok_or_else(|| anyhow!("missing {kind:?}"))?;
                steps.push(mono_scenario(
                    &format!("{kind:?}"),
                    prg,
                    hw,
                    mmsz,
                    mm.count * batch,
                    mm.m,
                    mm.n,
                    mm.k,
                    1,
                    true,
                ));
            }
            let (t, rs) = run_serial_steps(steps)?;
            let running = plan_stage.cores_deployed();
            (t, rs, running)
        }
        (Stage::Mha, ParallelMode::Serial) => {
            // every PRG in turn on the shared pool
            let mut steps = Vec::new();
            for prg in &plan_stage.prgs {
                let (mm, count) = match prg.kind {
                    PrgKind::QkvLb => {
                        let m = wl.mms_at(MmSite::QkvLb).unwrap();
                        (m, m.count)
                    }
                    PrgKind::QLb | PrgKind::KLb | PrgKind::VLb => {
                        let m = wl.mms_at(MmSite::QkvLb).unwrap();
                        (m, m.count / 3)
                    }
                    PrgKind::AtbPre => {
                        let m = wl.mms_at(MmSite::AtbPre).unwrap();
                        (m, m.count)
                    }
                    PrgKind::AtbPost => {
                        let m = wl.mms_at(MmSite::AtbPost).unwrap();
                        (m, m.count)
                    }
                    PrgKind::ProjLb => {
                        let m = wl.mms_at(MmSite::ProjLb).unwrap();
                        (m, m.count)
                    }
                    _ => continue,
                };
                let out_elem = if prg.kind == PrgKind::AtbPre { 4 } else { 1 };
                steps.push(mono_scenario(
                    &format!("{:?}", prg.kind),
                    prg,
                    hw,
                    mmsz,
                    count * batch,
                    mm.m,
                    mm.n,
                    mm.k,
                    out_elem,
                    atb_pipelined || !prg.kind.is_atb(),
                ));
            }
            let (t, rs) = run_serial_steps(steps)?;
            let running = plan_stage.cores_deployed();
            (t, rs, running)
        }
    };

    let raw_ops = match stage {
        Stage::Mha => wl.mha_ops(),
        Stage::Ffn => wl.ffn_ops(),
    };
    // MHA padding tax: ViT pays for padded rows (useful ops only).
    let ops = match stage {
        Stage::Mha => (raw_ops as f64 * useful) as u64 * batch as u64,
        Stage::Ffn => (raw_ops as f64 * useful) as u64 * batch as u64,
    };

    let temporal = sims
        .iter()
        .map(|r| r.avg_utilization())
        .sum::<f64>()
        / sims.len().max(1) as f64;

    // merge sim reports (keep the largest for inspection)
    let sim = sims
        .into_iter()
        .max_by(|a, b| a.makespan_ns.total_cmp(&b.makespan_ns))
        .unwrap();

    let report = StageReport {
        stage,
        batch,
        makespan_ns: makespan,
        ops,
        cores_deployed: plan.cores_deployed(),
        cores_running,
        temporal_utilization: temporal,
        sim,
    };
    if let Some(k) = key {
        cache::insert(k, &report);
    }
    crate::obs::record_stage_run(report.sim.fast_forwarded);
    Ok(report)
}

/// Algorithm 1: MHA Stage then FFN Stage, serial, sharing hardware.
pub fn run_edpu(plan: &AcceleratorPlan, batch: usize) -> Result<EdpuReport> {
    let mha = run_stage(plan, Stage::Mha, batch)?;
    let ffn = run_stage(plan, Stage::Ffn, batch)?;
    Ok(EdpuReport { mha, ffn, batch })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{HardwareConfig, ModelConfig};
    use crate::customize::{customize, CustomizeOptions};

    fn bert_plan() -> AcceleratorPlan {
        customize(
            &ModelConfig::bert_base(),
            &HardwareConfig::vck5000(),
            &CustomizeOptions::default(),
        )
        .unwrap()
    }

    #[test]
    fn pu_timing_balanced_on_vck5000() {
        // DESIGN.md §7: Large PU send ~= calc ~= recv(int8) ~= 3.3-3.4 µs
        let hw = HardwareConfig::vck5000();
        let t = pu_timing(&PuSpec::by_class(crate::arch::PuClass::Large), &hw, 64, 1);
        assert!((t.t_send_ns - 3413.0).abs() < 5.0, "{t:?}");
        assert!((t.t_calc_ns - 3276.8).abs() < 1.0, "{t:?}");
        assert!((t.t_recv_ns - 3413.0).abs() < 5.0, "{t:?}");
    }

    #[test]
    fn bert_mha_latency_near_paper() {
        // paper Table VI: MHA 0.037 ms — "the delay of one iteration" with
        // the pipeline warm, i.e. the steady-state initiation interval.
        // Measure per-item latency at batch 8; accept +-40% (calibrated
        // simulator, not the board).
        let plan = bert_plan();
        let r = run_stage(&plan, Stage::Mha, 8).unwrap();
        let ms = r.latency_per_item_ns() / 1e6;
        assert!(ms > 0.022 && ms < 0.055, "MHA {ms} ms/item");
        // cold-start (batch 1) additionally pays the full pipeline drain
        let cold = run_stage(&plan, Stage::Mha, 1).unwrap();
        assert!(cold.makespan_ns > r.latency_per_item_ns());
        assert!(cold.makespan_ns / 1e6 < 0.10, "{}", cold.makespan_ns / 1e6);
    }

    #[test]
    fn bert_ffn_latency_near_paper() {
        // paper Table VI: FFN 0.081 ms at batch 1.
        let plan = bert_plan();
        let r = run_stage(&plan, Stage::Ffn, 1).unwrap();
        let ms = r.makespan_ns / 1e6;
        assert!(ms > 0.050 && ms < 0.120, "FFN {ms} ms");
    }

    #[test]
    fn bert_edpu_tops_near_paper() {
        // paper: 35.2 TOPS peak; batch 16 is near-peak (Fig. 5).
        let plan = bert_plan();
        let r = run_edpu(&plan, 16).unwrap();
        let tops = r.tops();
        assert!(tops > 22.0 && tops < 50.0, "EDPU {tops} TOPS");
    }

    #[test]
    fn ffn_eff_utilization_is_73pct() {
        let plan = bert_plan();
        let r = run_stage(&plan, Stage::Ffn, 1).unwrap();
        // 256 running / 352 deployed (Table V)
        assert!((r.eff_utilization() - 256.0 / 352.0).abs() < 1e-9);
    }

    #[test]
    fn batch_amortizes_fill() {
        let plan = bert_plan();
        let t1 = run_edpu(&plan, 1).unwrap();
        let t16 = run_edpu(&plan, 16).unwrap();
        // throughput must grow with batch and saturate (Fig. 5)
        assert!(t16.tops() > t1.tops());
        let t32 = run_edpu(&plan, 32).unwrap();
        let growth = t32.tops() / t16.tops();
        assert!(growth < 1.15, "not saturating: {growth}");
    }

    #[test]
    fn limited_aie_serial_runs() {
        let plan = customize(
            &ModelConfig::bert_base(),
            &HardwareConfig::vck5000_limited(64),
            &CustomizeOptions::default(),
        )
        .unwrap();
        let r = run_edpu(&plan, 1).unwrap();
        // paper: 0.398 ms; accept 0.2..0.8
        let ms = r.makespan_ns() / 1e6;
        assert!(ms > 0.2 && ms < 0.8, "{ms} ms");
        // GOPS/AIE should be HIGH (paper: ~150 GOPS/AIE)
        let g = r.gops_per_aie();
        assert!(g > 100.0 && g < 170.0, "{g} GOPS/AIE");
    }

    #[test]
    fn vit_mha_slower_than_bert_per_useful_op() {
        // padding tax: ViT MHA TOPS < BERT MHA TOPS (paper: 30.5 vs 40.2)
        let bert = bert_plan();
        let vit = customize(
            &ModelConfig::vit_base(),
            &HardwareConfig::vck5000(),
            &CustomizeOptions::default(),
        )
        .unwrap();
        let rb = run_stage(&bert, Stage::Mha, 8).unwrap();
        let rv = run_stage(&vit, Stage::Mha, 8).unwrap();
        assert!(rv.tops() < rb.tops());
    }

    #[test]
    fn atb_pipelining_matters() {
        // Table II Lab 4 vs Lab 3 direction: pipelined ATB beats serial ATB
        let plan = bert_plan();
        let pipe = run_stage_opts(&plan, Stage::Mha, 4, true).unwrap();
        let serial = run_stage_opts(&plan, Stage::Mha, 4, false).unwrap();
        assert!(serial.makespan_ns > pipe.makespan_ns);
    }

    #[test]
    fn zero_batch_rejected() {
        let plan = bert_plan();
        assert!(run_stage(&plan, Stage::Mha, 0).is_err());
    }

    #[test]
    fn mem_throttle_stretches_streaming_not_compute() {
        let hw = HardwareConfig::vck5000();
        let mut half = hw.clone();
        half.mem_throttle = 0.5;
        let spec = PuSpec::by_class(crate::arch::PuClass::Large);
        let full_t = pu_timing(&spec, &hw, 64, 1);
        let half_t = pu_timing(&spec, &half, 64, 1);
        assert!((half_t.t_send_ns - 2.0 * full_t.t_send_ns).abs() < 1e-9);
        assert!((half_t.t_recv_ns - 2.0 * full_t.t_recv_ns).abs() < 1e-9);
        assert_eq!(half_t.t_calc_ns, full_t.t_calc_ns);
        // identity at 1.0: bit-exact, so uncontended paths are unchanged
        let mut one = hw.clone();
        one.mem_throttle = 1.0;
        assert_eq!(pu_timing(&spec, &one, 64, 1), full_t);
    }

    #[test]
    fn throttled_slice_strictly_slows_the_edpu() {
        // contended per-item latency ≥ uncontended, monotone in the
        // over-subscription (smaller throttle = slower), and the stage
        // cache keys the throttle via the plan fingerprint so the two
        // plans never alias
        let model = ModelConfig::bert_base();
        let mut last = 0.0f64;
        for throttle in [1.0, 0.5, 0.25] {
            let mut hw = HardwareConfig::vck5000();
            hw.mem_throttle = throttle;
            let plan = customize(&model, &hw, &CustomizeOptions::default()).unwrap();
            let r = run_edpu(&plan, 4).unwrap();
            assert!(
                r.makespan_ns() > last,
                "throttle {throttle}: {} not slower than {last}",
                r.makespan_ns()
            );
            last = r.makespan_ns();
        }
    }
}
