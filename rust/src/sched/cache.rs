//! Stage-simulation memoization (§Perf).
//!
//! Every experiment driver, bench, multi-EDPU organizer, and coordinator
//! worker funnels through [`run_stage_opts`](super::run_stage_opts), and
//! most of them re-simulate *identical* stage scenarios: the same plan,
//! stage, batch size, and ATB-pipelining toggle.  The simulator is fully
//! deterministic, so the [`StageReport`] is a pure function of that
//! tuple — memoizing it is semantically invisible.
//!
//! The key is `(plan fingerprint, stage, batch, atb_pipelined)` where the
//! fingerprint hashes the **complete** plan (model dims, hardware timing
//! parameters, PRG/PU allocation — see
//! [`AcceleratorPlan::fingerprint`](crate::arch::AcceleratorPlan::fingerprint)),
//! so two plans that differ anywhere that could affect the schedule can
//! never collide on purpose.  Invalidation is therefore structural: a new
//! plan hashes to a new key; the cache itself never needs flushing for
//! correctness, only for memory (a simple clear-at-capacity bound) and
//! for benchmarking (see [`reset_stage_cache`]).
//!
//! Set `CAT_SIM_CACHE=0` to disable the cache process-wide (used by the
//! hotpath bench to time the engine itself).

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};

use super::{Stage, StageReport};

/// Cache key: everything that determines a stage simulation's outcome.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub(crate) struct StageKey {
    pub plan_fp: u64,
    pub stage: Stage,
    pub batch: usize,
    pub atb_pipelined: bool,
}

/// Bound on retained entries; at capacity the map is cleared (simple and
/// deterministic — the workloads that matter re-populate in one sweep).
const MAX_ENTRIES: usize = 256;

static CACHE: OnceLock<Mutex<HashMap<StageKey, StageReport>>> = OnceLock::new();
static HITS: AtomicU64 = AtomicU64::new(0);
static MISSES: AtomicU64 = AtomicU64::new(0);

fn cache() -> &'static Mutex<HashMap<StageKey, StageReport>> {
    CACHE.get_or_init(|| Mutex::new(HashMap::new()))
}

pub(crate) fn enabled() -> bool {
    static ON: OnceLock<bool> = OnceLock::new();
    *ON.get_or_init(|| std::env::var("CAT_SIM_CACHE").map(|v| v != "0").unwrap_or(true))
}

pub(crate) fn lookup(key: &StageKey) -> Option<StageReport> {
    if !enabled() {
        return None;
    }
    let hit = cache().lock().unwrap().get(key).cloned();
    match &hit {
        Some(_) => HITS.fetch_add(1, Ordering::Relaxed),
        None => MISSES.fetch_add(1, Ordering::Relaxed),
    };
    hit
}

pub(crate) fn insert(key: StageKey, report: &StageReport) {
    if !enabled() {
        return;
    }
    let mut map = cache().lock().unwrap();
    if map.len() >= MAX_ENTRIES {
        map.clear();
    }
    map.insert(key, report.clone());
}

/// `(hits, misses)` since process start (or the last
/// [`reset_stage_cache`]).
pub fn stage_cache_stats() -> (u64, u64) {
    (HITS.load(Ordering::Relaxed), MISSES.load(Ordering::Relaxed))
}

/// Number of currently cached stage reports.
pub fn stage_cache_len() -> usize {
    cache().lock().unwrap().len()
}

/// Drop every cached entry and zero the hit/miss counters (benchmarks do
/// this between iterations to time the engine rather than the cache).
pub fn reset_stage_cache() {
    cache().lock().unwrap().clear();
    HITS.store(0, Ordering::Relaxed);
    MISSES.store(0, Ordering::Relaxed);
}
