//! Multi-EDPU deployment (paper §III.A): "the framework supports the
//! deployment of multiple EDPUs ... Different EDPUs can be used to
//! jointly accelerate one upper level task in a pipelined manner, or
//! multiple upper level tasks can be executed in parallel without
//! interfering with each other."
//!
//! Both HOST-level organizations over the single-EDPU simulator:
//!
//! * **Parallel** — `n` independent EDPUs each run a share of the batch;
//!   makespan = slowest share (plus nothing: they do not interfere).
//! * **Pipelined** — the model's layers are partitioned round-robin over
//!   the EDPUs; batch items stream through the EDPU chain, so steady-
//!   state throughput is set by the slowest EDPU while latency still
//!   pays every layer.

use super::{run_edpu, EdpuReport};
use crate::arch::AcceleratorPlan;
use anyhow::{anyhow, Result};

/// How the HOST organizes several EDPUs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MultiEdpuMode {
    /// Independent tasks, one per EDPU (no interference).
    Parallel,
    /// One task, layers partitioned across the EDPU chain.
    Pipelined,
}

/// Result of a multi-EDPU execution of a whole model (all layers).
#[derive(Debug, Clone)]
pub struct MultiEdpuReport {
    pub mode: MultiEdpuMode,
    pub n_edpu: usize,
    pub batch: usize,
    /// Wall time to finish the whole batch through all layers (ns).
    pub makespan_ns: f64,
    /// Per-item end-to-end latency (ns).
    pub latency_ns: f64,
    pub ops: u64,
    pub per_edpu: Vec<EdpuReport>,
}

impl MultiEdpuReport {
    pub fn tops(&self) -> f64 {
        self.ops as f64 / self.makespan_ns / 1e3
    }

    /// Wall time from batch admission to batch completion when the
    /// deployment serves batches back-to-back with no cross-batch overlap
    /// (the serving fleet's conservative service model): Parallel EDPUs
    /// finish when the slowest share does (the makespan); a Pipelined
    /// chain must push the whole batch through every layer (the latency),
    /// not just one steady-state window.
    pub fn service_ns(&self) -> f64 {
        match self.mode {
            MultiEdpuMode::Parallel => self.makespan_ns,
            MultiEdpuMode::Pipelined => self.latency_ns.max(self.makespan_ns),
        }
    }
}

/// Execute `plan.model.layers` encoder layers for `batch` items on
/// `n_edpu` EDPU instances.
///
/// Resource note: each EDPU instance needs its own AIE allocation; the
/// caller is responsible for `n_edpu * plan.cores_deployed() <=` the
/// board budget (checked here).
///
/// Contention note: when `plan.hw` is a board *slice* carrying a
/// negotiated `mem_throttle < 1.0` (a co-resident partition member whose
/// shared DRAM/PCIe pools are oversubscribed — see `serve::links`), the
/// per-PU stream phases are already stretched by the scheduler's timing
/// layer, so every report this function produces — and therefore every
/// serving profile built on it — reflects the contended memory path.
pub fn run_multi_edpu(
    plan: &AcceleratorPlan,
    n_edpu: usize,
    batch: usize,
    mode: MultiEdpuMode,
) -> Result<MultiEdpuReport> {
    if n_edpu == 0 {
        return Err(anyhow!("need at least one EDPU"));
    }
    if n_edpu * plan.cores_deployed() > plan.hw.total_aie {
        return Err(anyhow!(
            "{n_edpu} EDPUs x {} cores exceed the {}-AIE budget",
            plan.cores_deployed(),
            plan.hw.total_aie
        ));
    }
    let layers = plan.model.layers;
    match mode {
        MultiEdpuMode::Parallel => {
            // split the batch as evenly as possible; EDPUs don't interfere
            let mut per_edpu = Vec::new();
            let mut makespan: f64 = 0.0;
            let mut ops = 0u64;
            for i in 0..n_edpu {
                let share = batch / n_edpu + usize::from(i < batch % n_edpu);
                if share == 0 {
                    continue;
                }
                let r = run_edpu(plan, share)?;
                makespan = makespan.max(r.makespan_ns() * layers as f64);
                ops += r.ops() * layers as u64;
                per_edpu.push(r);
            }
            let latency = makespan / batch.div_ceil(n_edpu).max(1) as f64;
            Ok(MultiEdpuReport {
                mode,
                n_edpu,
                batch,
                makespan_ns: makespan,
                latency_ns: latency,
                ops,
                per_edpu,
            })
        }
        MultiEdpuMode::Pipelined => {
            // Layers partitioned round-robin: EDPU i runs ~layers/n of
            // the model; batches stream through the EDPU chain.  The
            // chain's steady-state initiation interval is the slowest
            // stage's time — that is the effective makespan charged per
            // batch window once warm.  A single batch's end-to-end
            // latency still crosses every layer.
            let r = run_edpu(plan, batch)?;
            let per_layer = r.makespan_ns(); // batch makespan for one layer
            let stage_layers = layers.div_ceil(n_edpu);
            let stage_time = per_layer * stage_layers as f64;
            let latency = per_layer * layers as f64;
            let ops = r.ops() * layers as u64;
            Ok(MultiEdpuReport {
                mode,
                n_edpu,
                batch,
                makespan_ns: stage_time,
                latency_ns: latency,
                ops,
                per_edpu: vec![r],
            })
        }
    }
}

/// How many instances of this plan's EDPU the board's AIE array can host
/// (always at least 1 so a sweep has a starting point; the budget check
/// in [`run_multi_edpu`] still rejects a plan that doesn't fit even once).
pub fn max_deployable(plan: &AcceleratorPlan) -> usize {
    (plan.hw.total_aie / plan.cores_deployed().max(1)).max(1)
}

/// Sweep EDPU counts for a fixed total budget: how many EDPUs should the
/// HOST deploy? (the "adjusted freely according to hardware resources
/// and acceleration requirements" knob).  The counts are independent
/// design points, so they evaluate in parallel; the stage-sim cache
/// dedups the many repeated per-share simulations underneath (§Perf).
pub fn edpu_count_sweep(
    plan: &AcceleratorPlan,
    batch: usize,
    mode: MultiEdpuMode,
) -> Result<Vec<MultiEdpuReport>> {
    crate::util::par::try_par_map((1..=max_deployable(plan)).collect(), |n| {
        run_multi_edpu(plan, n, batch, mode)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{HardwareConfig, ModelConfig};
    use crate::customize::{customize, CustomizeOptions};

    fn small_plan() -> AcceleratorPlan {
        // a compact 64-core EDPU (the Limited-AIE serial design) hosted
        // on the full 400-AIE board, so several instances fit — the
        // §III.A "number of EDPUs can be adjusted freely" scenario.
        let mut plan = customize(
            &ModelConfig::bert_base(),
            &HardwareConfig::vck5000_limited(64),
            &CustomizeOptions::default(),
        )
        .unwrap();
        plan.hw = HardwareConfig::vck5000();
        plan
    }

    #[test]
    fn budget_enforced() {
        let plan = customize(
            &ModelConfig::bert_base(),
            &HardwareConfig::vck5000(),
            &CustomizeOptions::default(),
        )
        .unwrap();
        // 352-core EDPU: two do not fit in 400
        assert!(run_multi_edpu(&plan, 2, 8, MultiEdpuMode::Parallel).is_err());
        assert!(run_multi_edpu(&plan, 1, 8, MultiEdpuMode::Parallel).is_ok());
        assert!(run_multi_edpu(&plan, 0, 8, MultiEdpuMode::Parallel).is_err());
    }

    #[test]
    fn parallel_edpus_scale_throughput() {
        let plan = small_plan();
        let one = run_multi_edpu(&plan, 1, 8, MultiEdpuMode::Parallel).unwrap();
        let deployable = plan.hw.total_aie / plan.cores_deployed();
        assert!(deployable >= 2, "plan too big: {}", plan.cores_deployed());
        let two = run_multi_edpu(&plan, 2, 8, MultiEdpuMode::Parallel).unwrap();
        // two EDPUs on half the batch each: close to half the makespan
        assert!(two.makespan_ns < one.makespan_ns * 0.7,
                "{} vs {}", two.makespan_ns, one.makespan_ns);
        assert_eq!(one.ops, two.ops);
        assert!(two.tops() > one.tops() * 1.4);
    }

    #[test]
    fn pipelined_edpus_improve_initiation_not_latency() {
        let plan = small_plan();
        let one = run_multi_edpu(&plan, 1, 4, MultiEdpuMode::Pipelined).unwrap();
        let three = run_multi_edpu(&plan, 3, 4, MultiEdpuMode::Pipelined).unwrap();
        // latency (all layers) identical; makespan per batch window shrinks
        assert!((three.latency_ns - one.latency_ns).abs() / one.latency_ns < 1e-9);
        assert!(three.makespan_ns <= one.makespan_ns);
    }

    #[test]
    fn sweep_covers_budget() {
        let plan = small_plan();
        let sweep = edpu_count_sweep(&plan, 8, MultiEdpuMode::Parallel).unwrap();
        let max_n = plan.hw.total_aie / plan.cores_deployed();
        assert_eq!(sweep.len(), max_n);
        // throughput non-decreasing in EDPU count (monotone resource law)
        for w in sweep.windows(2) {
            assert!(w[1].tops() >= w[0].tops() * 0.99);
        }
    }

    #[test]
    fn uneven_batch_split_completes_all_items() {
        let plan = small_plan();
        let r = run_multi_edpu(&plan, 3, 7, MultiEdpuMode::Parallel).unwrap();
        let total: usize = r.per_edpu.iter().map(|e| e.batch).sum();
        assert_eq!(total, 7);
    }

    #[test]
    fn parallel_makespan_is_exactly_the_slowest_share() {
        // Invariant: non-interfering EDPUs finish when the largest batch
        // share finishes — recompute the shares independently and demand
        // exact agreement with the reported makespan.
        let plan = small_plan();
        let layers = plan.model.layers as f64;
        for (n, batch) in [(2usize, 8usize), (3, 7), (4, 4)] {
            let r = run_multi_edpu(&plan, n, batch, MultiEdpuMode::Parallel).unwrap();
            let slowest = (0..n)
                .map(|i| batch / n + usize::from(i < batch % n))
                .filter(|s| *s > 0)
                .map(|s| run_edpu(&plan, s).unwrap().makespan_ns() * layers)
                .fold(0.0f64, f64::max);
            assert!(
                (r.makespan_ns - slowest).abs() <= 1e-9 * slowest,
                "n={n} batch={batch}: {} vs {slowest}",
                r.makespan_ns
            );
        }
    }

    #[test]
    fn parallel_never_beats_perfect_scaling() {
        // Splitting a batch over n EDPUs can at best divide the wall time
        // by n: the largest share is ceil(batch/n) items, and a share's
        // invocation count is at least a 1/n-th of the whole batch's
        // (ceil arithmetic can only round *up* per share).
        let plan = small_plan();
        let batch = 8;
        let one = run_multi_edpu(&plan, 1, batch, MultiEdpuMode::Parallel).unwrap();
        for n in 2..=4usize {
            let r = run_multi_edpu(&plan, n, batch, MultiEdpuMode::Parallel).unwrap();
            let bound = one.makespan_ns / n as f64;
            assert!(
                r.makespan_ns >= bound * (1.0 - 1e-9),
                "n={n}: {} beats perfect scaling {bound}",
                r.makespan_ns
            );
            // ops are conserved, so throughput gains are bounded too
            assert_eq!(r.ops, one.ops);
            assert!(r.tops() <= one.tops() * n as f64 * (1.0 + 1e-9));
        }
    }

    #[test]
    fn pipelined_latency_pays_every_layer() {
        // Invariant: the chain improves the initiation interval, never
        // the single-batch end-to-end latency — a batch still crosses
        // every encoder layer; the steady-state window is bounded below
        // by the slowest EDPU's per-layer time.
        let plan = small_plan();
        let layers = plan.model.layers;
        let per_layer = run_edpu(&plan, 4).unwrap().makespan_ns();
        for n in [1usize, 2, 3, 5] {
            let r = run_multi_edpu(&plan, n, 4, MultiEdpuMode::Pipelined).unwrap();
            let full = per_layer * layers as f64;
            assert!(
                (r.latency_ns - full).abs() <= 1e-9 * full,
                "n={n}: latency {} != {}",
                r.latency_ns,
                full
            );
            let window = per_layer * layers.div_ceil(n) as f64;
            assert!(
                (r.makespan_ns - window).abs() <= 1e-9 * window,
                "n={n}: window {} != {window}",
                r.makespan_ns
            );
            assert!(r.makespan_ns >= per_layer * (1.0 - 1e-9));
        }
    }

    #[test]
    fn service_time_covers_batch_completion_in_both_modes() {
        // Parallel: a batch is done when the slowest share is (makespan);
        // Pipelined: a batch still crosses every layer, so its service
        // time is the full latency even though the steady-state window
        // (makespan) is shorter.
        let plan = small_plan();
        let par = run_multi_edpu(&plan, 2, 8, MultiEdpuMode::Parallel).unwrap();
        assert_eq!(par.service_ns(), par.makespan_ns);
        let pipe = run_multi_edpu(&plan, 3, 8, MultiEdpuMode::Pipelined).unwrap();
        assert_eq!(pipe.service_ns(), pipe.latency_ns);
        assert!(pipe.service_ns() >= pipe.makespan_ns);
    }

    #[test]
    fn budget_rejection_is_clean_and_matches_max_deployable() {
        let big = customize(
            &ModelConfig::bert_base(),
            &HardwareConfig::vck5000(),
            &CustomizeOptions::default(),
        )
        .unwrap();
        assert_eq!(max_deployable(&big), 1);
        let err = run_multi_edpu(&big, 2, 8, MultiEdpuMode::Parallel).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("exceed"), "unexpected error text: {msg}");

        let small = small_plan();
        let max_n = max_deployable(&small);
        assert_eq!(max_n, small.hw.total_aie / small.cores_deployed());
        assert!(run_multi_edpu(&small, max_n, 4, MultiEdpuMode::Parallel).is_ok());
        assert!(run_multi_edpu(&small, max_n + 1, 4, MultiEdpuMode::Parallel).is_err());
    }
}
