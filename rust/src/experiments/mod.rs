//! Experiment drivers: one function per paper artifact (tables, figure,
//! observation), shared by the CLI (`cat table ...`) and the bench
//! targets (`cargo bench`).  Each returns structured data; the
//! [`report`](crate::report) module renders it.

use crate::arch::ParallelMode;
use crate::baselines;
use crate::config::{HardwareConfig, ModelConfig};
use crate::customize::{customize, CustomizeOptions};
use crate::metrics::{summarize, PerfSummary};
use crate::report::{AblationRow, BatchPoint, CatRow};
use crate::sched::{run_edpu, run_stage_opts, Stage};
use crate::sim::scenario::{NodeSpec, PuTiming, Scenario};
use crate::util::par::try_par_map;
use anyhow::Result;

/// EXP-T2 — Table II: the five ablation labs.  Same PU specifications in
/// every lab ("to ensure fairness ... the same scale AIE MM PU"),
/// toggling only the three customization attributes.  The labs are
/// independent design points, so they simulate in parallel (§Perf).
pub fn table2_rows() -> Result<Vec<AblationRow>> {
    let model = ModelConfig::vit_base();
    let hw = HardwareConfig::vck5000();
    let labs: Vec<(&'static str, bool, &'static str, usize, bool)> = vec![
        ("Lab 1", false, "N/A", 1, false),
        ("Lab 2", false, "Pipeline Parallel", 1, true),
        ("Lab 3", true, "N/A", 4, false),
        ("Lab 4", false, "Pipeline Parallel", 4, true),
        ("Lab 5", true, "Pipeline Parallel", 4, true),
    ];
    try_par_map(labs, |(lab, indep, mode_name, p_atb, atb_pipelined)| {
        let opts = CustomizeOptions {
            independent_linear: Some(indep),
            p_atb: Some(p_atb),
            force_mha_mode: Some(ParallelMode::FullyPipelined),
            force_ffn_mode: None,
        };
        let plan = customize(&model, &hw, &opts)?;
        let r = run_stage_opts(&plan, Stage::Mha, 8, atb_pipelined)?;
        Ok(AblationRow {
            lab,
            independent_linear: indep,
            atb_parallel_mode: mode_name,
            atb_parallelism: p_atb,
            makespan_ns: r.makespan_ns,
        })
    })
}

/// The paper's three accelerators (Table IV configurations).
pub fn three_accelerators() -> Vec<(&'static str, ModelConfig, HardwareConfig)> {
    vec![
        ("BERT-Base", ModelConfig::bert_base(), HardwareConfig::vck5000()),
        ("ViT-Base", ModelConfig::vit_base(), HardwareConfig::vck5000()),
        (
            "BERT-Base (Limited AIE)",
            ModelConfig::bert_base(),
            HardwareConfig::vck5000_limited(64),
        ),
    ]
}

/// EXP-T5 — Table V: the three customized plans (resource estimates live
/// on the plans themselves), derived in parallel.
pub fn table5_plans() -> Result<Vec<(&'static str, crate::arch::AcceleratorPlan)>> {
    try_par_map(three_accelerators(), |(name, m, hw)| {
        Ok((name, customize(&m, &hw, &CustomizeOptions::default())?))
    })
}

/// EXP-T6 — Table VI: peak performance + energy for the three
/// accelerators (batch 16 = saturation per Fig. 5), simulated in
/// parallel — they are independent design points.
pub fn table6_rows() -> Result<Vec<PerfSummary>> {
    try_par_map(three_accelerators(), |(name, m, hw)| {
        let plan = customize(&m, &hw, &CustomizeOptions::default())?;
        let r = run_edpu(&plan, 16)?;
        let mut s = summarize(&plan, &r);
        s.model = name.to_string();
        Ok(s)
    })
}

/// EXP-T7 — Table VII: CAT's measured rows plus the scheduling-style
/// baselines simulated on the same board.
pub struct Table7Data {
    pub cat_peak: CatRow,
    pub cat_vit: CatRow,
    pub cat_bert: CatRow,
    pub charm_style: baselines::BaselineResult,
    pub ssr_style: baselines::BaselineResult,
}

pub fn table7_data() -> Result<Table7Data> {
    let hw = HardwareConfig::vck5000();
    let bert = customize(&ModelConfig::bert_base(), &hw, &CustomizeOptions::default())?;
    let vit = customize(&ModelConfig::vit_base(), &hw, &CustomizeOptions::default())?;
    let sb = summarize(&bert, &run_edpu(&bert, 16)?);
    let sv = summarize(&vit, &run_edpu(&vit, 16)?);
    Ok(Table7Data {
        cat_peak: CatRow { tops: sb.sys_tops, gops_per_w: sb.gops_per_w },
        cat_vit: CatRow { tops: sv.sys_tops, gops_per_w: sv.gops_per_w },
        cat_bert: CatRow { tops: sb.sys_tops, gops_per_w: sb.gops_per_w },
        charm_style: baselines::charm_style(&ModelConfig::bert_base(), &hw),
        ssr_style: baselines::ssr_style(&ModelConfig::bert_base(), &hw),
    })
}

/// EXP-F5 — Figure 5: the batch sweep for one accelerator.  Batch sizes
/// are independent design points, so they simulate in parallel (§Perf).
pub fn fig5_series(model: &ModelConfig, hw: &HardwareConfig) -> Result<Vec<BatchPoint>> {
    let plan = customize(model, hw, &CustomizeOptions::default())?;
    let plan = &plan;
    try_par_map(vec![1usize, 2, 4, 8, 16, 32], |batch| {
        let r = run_edpu(plan, batch)?;
        Ok(BatchPoint {
            batch,
            mha_tops: r.mha.tops(),
            ffn_tops: r.ffn.tops(),
            sys_tops: r.tops(),
        })
    })
}

/// EXP-DSE — the `cat explore` driver: derive the Pareto-optimal
/// accelerator family for one model/board pair over the default joint
/// space (see [`dse`](crate::dse)).  `budget` caps how many candidates
/// are simulated (`None` = exhaustive — only sensible on reduced
/// spaces); `max_cores`/`slo_ms` pose the constrained variants.
pub fn explore(
    model: &ModelConfig,
    hw: &HardwareConfig,
    budget: Option<usize>,
    seed: u64,
    max_cores: Option<usize>,
    slo_ms: Option<f64>,
) -> Result<crate::dse::ExploreResult> {
    let mut cfg = crate::dse::ExploreConfig::new(model.clone(), hw.clone());
    cfg.sample_budget = budget;
    cfg.seed = seed;
    cfg.max_cores = max_cores;
    cfg.slo_ms = slo_ms;
    crate::dse::explore(&cfg)
}

/// EXP-SERVE — the `cat serve --rps` driver: derive a Pareto frontier
/// for the pair in-process, deploy up to `cfg.max_backends` family
/// members — co-resident partitions of one board when `cfg.partition`
/// is set (schema `cat-serve-v3` with the board ledger incl. the shared
/// DRAM/PCIe link negotiation, or `cat-serve-v2` when `cfg.links` is
/// `None`), one board per member otherwise — and route `cfg.n_requests`
/// seeded Poisson
/// arrivals across them with SLO-aware admission
/// ([`serve`](crate::serve)).  When `cfg.faults` is set, a deterministic
/// fault schedule (scripted or seeded random) is injected along the way:
/// failed backends drop out of admission, their work is re-admitted on
/// the survivors, partitioned fleets re-negotiate the shared links over
/// the survivors, and the report switches to schema `cat-serve-v4` with
/// a `faults` block.  When `cfg.cluster` is set, the family spreads
/// across EVERY board of the multi-board spec behind the same admission
/// plane (schema `cat-serve-v5` with a `cluster` ledger,
/// [`cluster`](crate::cluster)).  Fully deterministic for a fixed
/// `cfg.seed` — the report's JSON is byte-identical across runs and
/// thread counts, with or without faults.  Delegates to
/// [`serve::run`](crate::serve::run), the consolidated serve entry
/// point.
pub fn serve_fleet(cfg: &crate::serve::FleetConfig) -> Result<crate::serve::FleetReport> {
    crate::serve::serve_fleet(cfg)
}

/// [`serve_fleet`] with the observability layer attached: the run's
/// request lifecycle lands in `obs.trace` and its counters/histograms in
/// `obs.metrics` (whichever sides are enabled).  The report itself is
/// byte-identical to the plain entry point — observation never perturbs
/// the virtual clock.
pub fn serve_fleet_obs(
    cfg: &crate::serve::FleetConfig,
    obs: &mut crate::obs::Obs,
) -> Result<crate::serve::FleetReport> {
    crate::serve::serve_fleet_obs(cfg, obs)
}

/// EXP-O1 — Observation 1: serial vs pipelined send/compute/receive on
/// the PL side.  Returns (serial_ns, pipelined_ns).
pub fn obs1_times() -> Result<(f64, f64)> {
    let t = PuTiming { t_send_ns: 683.0, t_calc_ns: 3277.0, t_recv_ns: 683.0 };
    let mk = |pipelined: bool| {
        let mut sc = Scenario::default();
        sc.add_node(NodeSpec {
            name: if pipelined { "pipelined" } else { "serial" }.into(),
            pus: vec![t],
            pipelined,
            n_inv: 100,
            cores: 64,
            inputs: vec![],
            outputs: vec![],
        });
        sc
    };
    let serial = crate::sim::run(&mk(false)).map_err(anyhow::Error::msg)?;
    let pipe = crate::sim::run(&mk(true)).map_err(anyhow::Error::msg)?;
    Ok((serial.makespan_ns, pipe.makespan_ns))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_ordering_matches_paper() {
        // paper: 1.0x < 3.8x < 5.3x < 14.6x < 20.1x — strict monotone
        let rows = table2_rows().unwrap();
        assert_eq!(rows.len(), 5);
        for w in rows.windows(2) {
            assert!(
                w[1].makespan_ns < w[0].makespan_ns,
                "{} ({}) not faster than {} ({})",
                w[1].lab,
                w[1].makespan_ns,
                w[0].lab,
                w[0].makespan_ns
            );
        }
        // Lab 5 should be several times faster than Lab 1
        let speedup = rows[0].makespan_ns / rows[4].makespan_ns;
        assert!(speedup > 4.0, "Lab5 speedup only {speedup}");
    }

    #[test]
    fn table6_shapes() {
        let rows = table6_rows().unwrap();
        assert_eq!(rows.len(), 3);
        // BERT faster than ViT (padding); limited far below both
        assert!(rows[0].sys_tops > rows[1].sys_tops);
        assert!(rows[2].sys_tops < rows[1].sys_tops / 2.0);
        // limited has the best GOPS/AIE (paper: 150 vs ~100)
        assert!(rows[2].sys_gops_per_aie > rows[0].sys_gops_per_aie);
    }

    #[test]
    fn table7_cat_is_sota() {
        let d = table7_data().unwrap();
        // paper: CAT > SSR (1.31x peak throughput)
        assert!(d.cat_peak.tops > 26.7);
        assert!(d.cat_peak.tops > d.ssr_style.tops);
        assert!(d.ssr_style.tops > d.charm_style.tops);
        // energy efficiency also ahead of published SSR
        assert!(d.cat_peak.gops_per_w > 453.0);
    }

    #[test]
    fn fig5_saturates() {
        let pts =
            fig5_series(&ModelConfig::bert_base(), &HardwareConfig::vck5000()).unwrap();
        assert_eq!(pts.len(), 6);
        // monotone non-decreasing system TOPS, saturating by 16
        for w in pts.windows(2) {
            assert!(w[1].sys_tops >= w[0].sys_tops * 0.98);
        }
        let b16 = pts.iter().find(|p| p.batch == 16).unwrap();
        let b32 = pts.iter().find(|p| p.batch == 32).unwrap();
        assert!(b32.sys_tops / b16.sys_tops < 1.1, "not saturating");
        // paper: >= 22 TOPS even at small batch for BERT
        assert!(pts[0].sys_tops > 10.0);
    }

    #[test]
    fn obs1_speedup_1_4x() {
        let (serial, pipe) = obs1_times().unwrap();
        let speedup = serial / pipe;
        assert!((speedup - 1.41).abs() < 0.05, "{speedup}");
    }
}
