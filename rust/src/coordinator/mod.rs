//! HOST-side coordination (paper Fig. 2): top-level resource scheduling
//! and execution timing control of the EDPUs.
//!
//! The HOST "is only responsible for the scheduling work between EDPUs,
//! and cannot interfere with the internal operation of EDPUs" — here:
//!
//! * a **batcher** groups incoming requests up to `max_batch` (or a
//!   timeout), exactly the batch loop of Algorithm 1;
//! * an **EDPU pool** of worker threads, each owning its own PJRT
//!   [`Runtime`](crate::runtime::Runtime) (one compiled executable per
//!   model variant), pulls batches from a shared queue — "multiple upper
//!   level tasks can be executed in parallel without interfering";
//! * serving statistics (latency percentiles, throughput) and, when a
//!   plan is attached, the *simulated board* latency for each batch.

mod batcher;
mod pool;

pub use batcher::{Batcher, BatcherConfig};
pub use pool::{Executor, ExecutorFactory, WorkerPool};

use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::arch::AcceleratorPlan;
use crate::config::ModelConfig;
use crate::runtime::{EncoderWeights, Runtime, Tensor};
use crate::sched;
use anyhow::{anyhow, Result};

/// One inference request: a quantized `[L, E]` activation.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub x_q: Tensor,
    pub x_scale: f32,
}

/// One completed inference.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    /// Final encoder output (fp32 `[L, E]`).
    pub output: Tensor,
    /// Host wall-clock latency (enqueue -> completion).
    pub latency: Duration,
    /// Which batch this request rode in.
    pub batch_size: usize,
    /// Simulated VCK5000 latency for that batch, if a plan was attached.
    pub simulated_batch_ns: Option<f64>,
}

/// Serving statistics.
#[derive(Debug, Clone, Default)]
pub struct ServeStats {
    pub completed: usize,
    pub batches: usize,
    /// Completed-request latencies, **sorted ascending** — sorted once at
    /// snapshot time ([`Host::drain`]) so [`ServeStats::percentile`] can
    /// index directly instead of cloning and re-sorting per call.
    pub latencies: Vec<Duration>,
    pub wall: Duration,
}

impl ServeStats {
    pub fn throughput_rps(&self) -> f64 {
        if self.wall.is_zero() {
            return 0.0;
        }
        self.completed as f64 / self.wall.as_secs_f64()
    }

    /// Latency percentile with linear interpolation between adjacent
    /// ranks; `p` is clamped to `[0, 1]`.  `latencies` is sorted at
    /// snapshot time, so this is a pure index (no clone, no sort).
    pub fn percentile(&self, p: f64) -> Duration {
        debug_assert!(
            self.latencies.windows(2).all(|w| w[0] <= w[1]),
            "ServeStats.latencies must be sorted"
        );
        if self.latencies.is_empty() {
            return Duration::ZERO;
        }
        let v = &self.latencies;
        let pos = (v.len() - 1) as f64 * p.clamp(0.0, 1.0);
        let lo = pos.floor() as usize;
        let hi = pos.ceil() as usize;
        if lo == hi {
            return v[lo];
        }
        let frac = pos - lo as f64;
        let a = v[lo].as_nanos() as f64;
        let b = v[hi].as_nanos() as f64;
        Duration::from_nanos((a + (b - a) * frac).round() as u64)
    }

    pub fn mean_batch(&self) -> f64 {
        if self.batches == 0 {
            return 0.0;
        }
        self.completed as f64 / self.batches as f64
    }
}

/// Pool configuration.
#[derive(Debug, Clone)]
pub struct HostConfig {
    pub artifact_dir: String,
    /// `encoder_layer_fused` (fast) or `encoder_layer_pallas` (tiled proof).
    pub variant: String,
    pub model: ModelConfig,
    /// Encoder layers to run per request (can be < model.layers for demos).
    pub layers: usize,
    pub workers: usize,
    pub max_batch: usize,
    pub batch_timeout: Duration,
    /// Attach to report simulated-board latency alongside wall clock.
    pub plan: Option<AcceleratorPlan>,
    pub weight_seed: u64,
}

impl HostConfig {
    pub fn new(model: ModelConfig) -> HostConfig {
        HostConfig {
            artifact_dir: "artifacts".into(),
            variant: "encoder_layer_fused".into(),
            model,
            layers: 2,
            workers: 1,
            max_batch: 8,
            batch_timeout: Duration::from_millis(5),
            plan: None,
            weight_seed: 0xCA7,
        }
    }
}

/// One queued unit of EDPU work: the batch and its size.
type BatchJob = (Vec<(Request, Instant)>, usize);

/// The HOST: accepts requests, batches them, runs them on the EDPU pool.
///
/// The thread/queue/shutdown machinery lives in the generic
/// [`WorkerPool`]; `Host` contributes the PJRT executor (one runtime +
/// pre-compiled variant + synthetic weights per worker) and the batching
/// front end.
pub struct Host {
    cfg: HostConfig,
    pool: WorkerPool<BatchJob, Response>,
    batcher: Batcher<Request>,
    submitted: u64,
    batches_dispatched: usize,
    started: Instant,
}

impl Host {
    /// Start the worker pool. Each worker opens its own PJRT runtime and
    /// pre-compiles the model variant, so serving latency excludes
    /// compilation.
    pub fn start(cfg: HostConfig) -> Result<Host> {
        let wcfg = cfg.clone();
        let factory: ExecutorFactory<BatchJob, Response> = Arc::new(move |_wid| {
            let cfg = wcfg.clone();
            let mut rt =
                Runtime::open(&cfg.artifact_dir).map_err(|e| anyhow!("runtime open: {e}"))?;
            rt.compile(&cfg.variant).map_err(|e| anyhow!("compile: {e}"))?;
            let weights: Vec<EncoderWeights> = (0..cfg.layers)
                .map(|i| {
                    EncoderWeights::synthetic(&cfg.model, cfg.weight_seed.wrapping_add(i as u64))
                })
                .collect();
            Ok(Box::new(move |(batch, batch_size): BatchJob| {
                // simulated board latency for this batch (once per batch;
                // the stage-sim cache makes repeats of the same batch
                // size free)
                let sim_ns = cfg
                    .plan
                    .as_ref()
                    .and_then(|p| sched::run_edpu(p, batch_size).ok())
                    .map(|r| r.makespan_ns() * cfg.layers as f64);
                let mut out = Vec::with_capacity(batch.len());
                for (req, enq) in batch {
                    let output = rt
                        .encoder_forward(&cfg.variant, req.x_q.clone(), req.x_scale, &weights)
                        .map_err(|e| anyhow!("req {}: {e}", req.id))?;
                    out.push(Response {
                        id: req.id,
                        output,
                        latency: enq.elapsed(),
                        batch_size,
                        simulated_batch_ns: sim_ns,
                    });
                }
                Ok(out)
            }) as Executor<BatchJob, Response>)
        });
        let pool = WorkerPool::start("edpu", cfg.workers.max(1), factory)?;
        let batcher = Batcher::new(BatcherConfig {
            max_batch: cfg.max_batch,
            timeout: cfg.batch_timeout,
        });
        Ok(Host {
            cfg,
            pool,
            batcher,
            submitted: 0,
            batches_dispatched: 0,
            started: Instant::now(),
        })
    }

    /// Enqueue a request (non-blocking). The batcher may hold it until
    /// `max_batch` requests accumulate or the timeout passes.
    pub fn submit(&mut self, req: Request) {
        self.submitted += 1;
        if let Some(batch) = self.batcher.push(req, Instant::now()) {
            self.dispatch(batch);
        }
    }

    /// Flush the batcher (end of request stream).
    pub fn flush(&mut self) {
        if let Some(batch) = self.batcher.flush() {
            self.dispatch(batch);
        }
    }

    /// Dispatch the pending batch if its oldest request has exceeded the
    /// batch timeout.  Callers with request gaps longer than the timeout
    /// should tick this so partially filled batches don't sit waiting for
    /// the next submit.
    pub fn poll(&mut self) {
        if self.batcher.is_stale(Instant::now()) {
            if let Some(batch) = self.batcher.flush() {
                self.dispatch(batch);
            }
        }
    }

    /// How long a serving loop may sleep before the next [`Host::poll`]
    /// tick is due (`None`: nothing pending, sleep on request arrival).
    pub fn time_until_stale(&self) -> Option<Duration> {
        self.batcher.time_until_stale(Instant::now())
    }

    /// Requests accumulated in the batcher but not yet dispatched.
    pub fn pending_len(&self) -> usize {
        self.batcher.pending_len()
    }

    fn dispatch(&mut self, batch: Vec<(Request, Instant)>) {
        let n = batch.len();
        self.batches_dispatched += 1;
        self.pool.submit((batch, n));
    }

    /// Wait until every submitted request has completed; returns all
    /// responses (sorted by id) and the serving stats.
    ///
    /// §Perf: completion is condvar-driven ([`WorkerPool::wait_for_results`]),
    /// not a 1 ms sleep-poll.  The initial `flush()` empties the batcher
    /// and `drain` consumes the host, so no batch can go stale during the
    /// wait — timeout-driven flushing on a live request stream is
    /// [`Host::poll`]'s job (its wait budget comes from
    /// [`Batcher::time_until_stale`]).
    pub fn drain(mut self) -> Result<(Vec<Response>, ServeStats)> {
        self.flush();
        self.pool.wait_for_results(self.submitted as usize);
        let batches = self.batches_dispatched;
        let wall = self.started.elapsed();
        let mut out = self.pool.shutdown()?;
        out.sort_by_key(|r| r.id);
        let stats = ServeStats {
            completed: out.len(),
            batches,
            latencies: {
                // sorted once here so every percentile() call is O(1)
                let mut v: Vec<Duration> = out.iter().map(|r| r.latency).collect();
                v.sort_unstable();
                v
            },
            wall,
        };
        Ok((out, stats))
    }

    pub fn config(&self) -> &HostConfig {
        &self.cfg
    }
}

/// Generate a random request for demos/tests.
pub fn synthetic_request(model: &ModelConfig, mmsz: usize, id: u64, seed: u64) -> Request {
    use crate::util::prng::Prng;
    let mut rng = Prng::new(seed);
    let l = model.padded_seq_len(mmsz);
    let e = model.embed_dim;
    let x: Vec<f32> = (0..l * e).map(|_| rng.gaussian() as f32).collect();
    let (x_q, x_scale) = crate::runtime::quantize_activation(&x, &[l, e]);
    Request { id, x_q, x_scale }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_percentiles() {
        let stats = ServeStats {
            completed: 4,
            batches: 2,
            latencies: vec![
                Duration::from_millis(1),
                Duration::from_millis(2),
                Duration::from_millis(3),
                Duration::from_millis(100),
            ],
            wall: Duration::from_secs(1),
        };
        assert_eq!(stats.percentile(0.0), Duration::from_millis(1));
        assert_eq!(stats.percentile(1.0), Duration::from_millis(100));
        assert_eq!(stats.throughput_rps(), 4.0);
        assert_eq!(stats.mean_batch(), 2.0);
    }

    #[test]
    fn percentile_edge_cases_and_interpolation() {
        let with = |lat: Vec<Duration>| ServeStats {
            completed: lat.len(),
            batches: 1,
            latencies: lat,
            wall: Duration::from_secs(1),
        };
        // empty: every percentile is zero
        let empty = with(vec![]);
        for p in [0.0, 0.5, 1.0] {
            assert_eq!(empty.percentile(p), Duration::ZERO);
        }
        // one element: every percentile is that element, and out-of-range
        // p clamps instead of indexing out of bounds
        let one = with(vec![Duration::from_millis(7)]);
        for p in [-0.5, 0.0, 0.37, 1.0, 2.0] {
            assert_eq!(one.percentile(p), Duration::from_millis(7));
        }
        // interpolation edge: p50 of [0ms, 10ms] sits exactly between
        let two = with(vec![Duration::ZERO, Duration::from_millis(10)]);
        assert_eq!(two.percentile(0.5), Duration::from_millis(5));
        assert_eq!(two.percentile(0.25), Duration::from_micros(2500));
        assert_eq!(two.percentile(0.0), Duration::ZERO);
        assert_eq!(two.percentile(1.0), Duration::from_millis(10));
        // exact-rank positions need no interpolation
        let three = with(vec![
            Duration::from_millis(1),
            Duration::from_millis(2),
            Duration::from_millis(9),
        ]);
        assert_eq!(three.percentile(0.5), Duration::from_millis(2));
        // and an interpolated rank between the 2nd and 3rd samples:
        // pos = 2 * 0.75 = 1.5 -> (2 + 9) / 2 = 5.5 ms
        assert_eq!(three.percentile(0.75), Duration::from_micros(5500));
    }

    #[test]
    fn synthetic_request_shape() {
        let m = ModelConfig::bert_base();
        let r = synthetic_request(&m, 64, 3, 42);
        assert_eq!(r.x_q.shape(), &[256, 768]);
        assert!(r.x_scale > 0.0);
        assert_eq!(r.id, 3);
    }

    #[test]
    fn poll_flushes_stale_partial_batch() {
        // Host-side batching needs no runtime: workers fail to open the
        // bogus artifact dir and exit, which is irrelevant here — poll()
        // operates on the batcher/queue only.
        let m = ModelConfig::bert_base();
        let mut cfg = HostConfig::new(m.clone());
        cfg.artifact_dir = "nonexistent-artifacts".into();
        cfg.max_batch = 100;
        cfg.batch_timeout = Duration::from_millis(1);
        cfg.workers = 1;
        let mut host = Host::start(cfg).unwrap();
        host.submit(synthetic_request(&m, 64, 0, 7));
        assert_eq!(host.pending_len(), 1);
        assert!(host.time_until_stale().is_some());
        std::thread::sleep(Duration::from_millis(5));
        host.poll();
        assert_eq!(host.pending_len(), 0, "stale partial batch must dispatch");
        assert_eq!(host.time_until_stale(), None);
    }

    // end-to-end host tests live in rust/tests/ (they need artifacts)
}
