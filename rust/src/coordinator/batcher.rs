//! Dynamic batcher: groups requests up to `max_batch` or until the oldest
//! pending request has waited `timeout` (the host-side analogue of the
//! EDPU batch loop — larger batches amortize pipeline fill, Fig. 5).
//!
//! Generic over the request type so the same staleness/flush logic serves
//! both the PJRT [`Host`](super::Host) (`Batcher<Request>`) and the fleet
//! coordinator's lightweight virtual-clock requests ([`crate::serve`]).

use std::time::{Duration, Instant};

#[derive(Debug, Clone, Copy)]
pub struct BatcherConfig {
    pub max_batch: usize,
    pub timeout: Duration,
}

/// Accumulates requests; emits a batch when full or stale.
pub struct Batcher<T> {
    cfg: BatcherConfig,
    pending: Vec<(T, Instant)>,
}

impl<T> Batcher<T> {
    pub fn new(cfg: BatcherConfig) -> Batcher<T> {
        assert!(cfg.max_batch > 0, "max_batch must be positive");
        Batcher { cfg, pending: Vec::new() }
    }

    /// Add a request; returns a full batch if one is ready.
    pub fn push(&mut self, req: T, now: Instant) -> Option<Vec<(T, Instant)>> {
        self.pending.push((req, now));
        if self.pending.len() >= self.cfg.max_batch {
            return Some(std::mem::take(&mut self.pending));
        }
        if self.is_stale(now) {
            return Some(std::mem::take(&mut self.pending));
        }
        None
    }

    /// True if the oldest pending request has exceeded the timeout.
    pub fn is_stale(&self, now: Instant) -> bool {
        self.pending
            .first()
            .map(|(_, t)| now.duration_since(*t) >= self.cfg.timeout)
            .unwrap_or(false)
    }

    /// How long until the oldest pending request goes stale (`None` when
    /// nothing is pending; `Some(ZERO)` when already stale).  Drives the
    /// host's condvar wait so timeout flushes fire promptly instead of on
    /// a fixed polling grid.
    pub fn time_until_stale(&self, now: Instant) -> Option<Duration> {
        self.pending
            .first()
            .map(|(_, t)| self.cfg.timeout.saturating_sub(now.duration_since(*t)))
    }

    /// Emit whatever is pending (stream end / timer tick).
    pub fn flush(&mut self) -> Option<Vec<(T, Instant)>> {
        if self.pending.is_empty() {
            None
        } else {
            Some(std::mem::take(&mut self.pending))
        }
    }

    pub fn pending_len(&self) -> usize {
        self.pending.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Request;
    use crate::runtime::Tensor;

    fn req(id: u64) -> Request {
        Request {
            id,
            x_q: Tensor::I8 { data: vec![0; 4], shape: vec![2, 2] },
            x_scale: 1.0,
        }
    }

    #[test]
    fn emits_full_batches() {
        let mut b = Batcher::new(BatcherConfig {
            max_batch: 3,
            timeout: Duration::from_secs(10),
        });
        let t = Instant::now();
        assert!(b.push(req(1), t).is_none());
        assert!(b.push(req(2), t).is_none());
        let batch = b.push(req(3), t).unwrap();
        assert_eq!(batch.len(), 3);
        assert_eq!(b.pending_len(), 0);
    }

    #[test]
    fn timeout_forces_emission() {
        let mut b = Batcher::new(BatcherConfig {
            max_batch: 100,
            timeout: Duration::from_millis(1),
        });
        let t0 = Instant::now();
        assert!(b.push(req(1), t0).is_none());
        let later = t0 + Duration::from_millis(5);
        let batch = b.push(req(2), later).unwrap();
        assert_eq!(batch.len(), 2);
    }

    #[test]
    fn flush_drains() {
        let mut b = Batcher::new(BatcherConfig {
            max_batch: 8,
            timeout: Duration::from_secs(1),
        });
        assert!(b.flush().is_none());
        b.push(req(1), Instant::now());
        assert_eq!(b.flush().unwrap().len(), 1);
        assert!(b.flush().is_none());
    }

    #[test]
    #[should_panic(expected = "max_batch")]
    fn zero_batch_rejected() {
        Batcher::<Request>::new(BatcherConfig { max_batch: 0, timeout: Duration::ZERO });
    }

    #[test]
    fn time_until_stale_counts_down() {
        let mut b = Batcher::new(BatcherConfig {
            max_batch: 10,
            timeout: Duration::from_millis(8),
        });
        let t0 = Instant::now();
        assert_eq!(b.time_until_stale(t0), None);
        b.push(req(1), t0);
        assert_eq!(b.time_until_stale(t0), Some(Duration::from_millis(8)));
        assert_eq!(
            b.time_until_stale(t0 + Duration::from_millis(5)),
            Some(Duration::from_millis(3))
        );
        // past the deadline: saturates at zero and reads as stale
        let late = t0 + Duration::from_millis(20);
        assert_eq!(b.time_until_stale(late), Some(Duration::ZERO));
        assert!(b.is_stale(late));
    }
}
