//! Generic HOST worker-pool machinery, extracted from [`Host`](super::Host)
//! so the fleet coordinator can multi-instantiate pools (or bypass threads
//! entirely) without paying for a PJRT runtime per logical backend.
//!
//! The pool owns the concurrency-sensitive pieces the PR 1 rework tuned:
//!
//! * idle workers park on a condvar (no polling cadence) with a long
//!   belt-and-braces re-check timeout;
//! * the stop flag is raised **under the queue lock**, so the shutdown
//!   notify can never slip between a worker's stop check and its wait
//!   (the missed-wakeup race);
//! * workers drain the queue before honoring stop, so every job submitted
//!   before [`WorkerPool::shutdown`] still completes;
//! * result completion is signaled on a second condvar so
//!   [`WorkerPool::wait_for_results`] wakes immediately instead of
//!   sleep-polling.
//!
//! What runs inside a worker is the caller's business: an
//! [`ExecutorFactory`] builds one [`Executor`] per worker thread, and the
//! expensive per-worker state (a PJRT runtime, pre-compiled executables,
//! synthetic weights) lives in that closure.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use anyhow::{anyhow, Result};

/// Per-worker job executor: consumes one job and returns the results it
/// produced (a job may yield several — e.g. one response per request of a
/// batch).  An error poisons the pool: the worker records it and exits,
/// and [`WorkerPool::shutdown`] surfaces it.
pub type Executor<J, R> = Box<dyn FnMut(J) -> Result<Vec<R>> + Send>;

/// Builds one [`Executor`] per worker thread (the worker index is passed
/// for naming/sharding).  Returning an error marks the pool failed
/// without panicking the thread.
pub type ExecutorFactory<J, R> = Arc<dyn Fn(usize) -> Result<Executor<J, R>> + Send + Sync>;

struct Shared<J, R> {
    queue: Mutex<VecDeque<J>>,
    available: Condvar,
    done: Mutex<Vec<R>>,
    /// Signaled (paired with `done`) whenever a worker completes a job or
    /// records an error, so waiters wake immediately.
    completed: Condvar,
    stop: AtomicBool,
    errors: Mutex<Vec<String>>,
}

/// A fixed set of worker threads pulling jobs from a shared queue.
pub struct WorkerPool<J: Send + 'static, R: Send + 'static> {
    shared: Arc<Shared<J, R>>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl<J: Send + 'static, R: Send + 'static> WorkerPool<J, R> {
    /// Spawn `workers.max(1)` threads named `{name}-{i}`, each running the
    /// executor its factory call builds.
    pub fn start(name: &str, workers: usize, factory: ExecutorFactory<J, R>) -> Result<Self> {
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            available: Condvar::new(),
            done: Mutex::new(Vec::new()),
            completed: Condvar::new(),
            stop: AtomicBool::new(false),
            errors: Mutex::new(Vec::new()),
        });
        let mut handles = Vec::new();
        for wid in 0..workers.max(1) {
            let sh = Arc::clone(&shared);
            let factory = Arc::clone(&factory);
            handles.push(
                std::thread::Builder::new()
                    .name(format!("{name}-{wid}"))
                    .spawn(move || worker_loop(wid, factory, sh))
                    .map_err(|e| anyhow!("spawning worker: {e}"))?,
            );
        }
        Ok(WorkerPool { shared, workers: handles })
    }

    /// Enqueue one job (non-blocking) and wake an idle worker.
    pub fn submit(&self, job: J) {
        let mut q = self.shared.queue.lock().unwrap();
        q.push_back(job);
        drop(q);
        self.shared.available.notify_one();
    }

    /// Pull every not-yet-started job back out of the queue (in-flight
    /// jobs are untouched — a worker that already popped its job will
    /// still complete it).  This is the thread-pool analogue of the
    /// serving loop's fault-time drain: on a backend failure the
    /// coordinator reclaims the queued work and re-submits it elsewhere
    /// instead of letting it die with the pool.
    pub fn drain_queued(&self) -> Vec<J> {
        let mut q = self.shared.queue.lock().unwrap();
        q.drain(..).collect()
    }

    /// Results collected so far.
    pub fn results_len(&self) -> usize {
        self.shared.done.lock().unwrap().len()
    }

    /// True once any worker has recorded an error.
    pub fn has_errors(&self) -> bool {
        !self.shared.errors.lock().unwrap().is_empty()
    }

    /// Block until at least `n` results exist or a worker errored.
    ///
    /// §Perf: condvar-driven (workers signal `completed`), not a sleep
    /// poll; the wait timeout is only a backstop for the error path's
    /// separate mutex.
    pub fn wait_for_results(&self, n: usize) {
        let mut done = self.shared.done.lock().unwrap();
        loop {
            if done.len() >= n {
                return;
            }
            // On a worker error, return (not hang): the caller's shutdown
            // still joins the surviving workers and reports the error.
            if self.has_errors() {
                return;
            }
            done = self
                .shared
                .completed
                .wait_timeout(done, Duration::from_millis(50))
                .unwrap()
                .0;
        }
    }

    /// Stop the pool: raise the stop flag (under the queue lock — see the
    /// module docs), join every worker, and return all results.  Jobs
    /// already queued are completed first; worker errors surface as `Err`.
    pub fn shutdown(mut self) -> Result<Vec<R>> {
        {
            let _q = self.shared.queue.lock().unwrap();
            self.shared.stop.store(true, Ordering::SeqCst);
        }
        self.shared.available.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        let out = std::mem::take(&mut *self.shared.done.lock().unwrap());
        let errs = self.shared.errors.lock().unwrap();
        if !errs.is_empty() {
            return Err(anyhow!("worker error: {}", errs.join("; ")));
        }
        Ok(out)
    }
}

fn worker_loop<J: Send, R: Send>(
    wid: usize,
    factory: ExecutorFactory<J, R>,
    sh: Arc<Shared<J, R>>,
) {
    let fail = |sh: &Shared<J, R>, msg: String| {
        sh.errors.lock().unwrap().push(msg);
        // wake any waiter so the error surfaces immediately
        sh.completed.notify_all();
    };
    let mut exec = match factory(wid) {
        Ok(e) => e,
        Err(e) => {
            fail(&sh, format!("{e}"));
            return;
        }
    };
    loop {
        // Idle workers park on `available` until a job is queued or stop
        // is raised (raised under this same lock, so the notify cannot be
        // missed).  Jobs are drained before stop is honored.
        let job = {
            let mut q = sh.queue.lock().unwrap();
            loop {
                if let Some(j) = q.pop_front() {
                    break Some(j);
                }
                if sh.stop.load(Ordering::SeqCst) {
                    break None;
                }
                q = sh.available.wait_timeout(q, Duration::from_millis(500)).unwrap().0;
            }
        };
        let Some(job) = job else { return };
        match exec(job) {
            Ok(results) => {
                let mut done = sh.done.lock().unwrap();
                done.extend(results);
                drop(done);
                sh.completed.notify_all();
            }
            Err(e) => {
                fail(&sh, format!("{e}"));
                return;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn echo_factory() -> ExecutorFactory<u64, u64> {
        Arc::new(|_wid| Ok(Box::new(|j: u64| Ok(vec![j])) as Executor<u64, u64>))
    }

    #[test]
    fn completes_all_jobs_and_returns_them() {
        let pool = WorkerPool::start("t", 3, echo_factory()).unwrap();
        for j in 0..50u64 {
            pool.submit(j);
        }
        pool.wait_for_results(50);
        let mut out = pool.shutdown().unwrap();
        out.sort_unstable();
        assert_eq!(out, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn shutdown_without_jobs_is_prompt() {
        let pool = WorkerPool::<u64, u64>::start("t", 4, echo_factory()).unwrap();
        assert_eq!(pool.results_len(), 0);
        assert!(pool.shutdown().unwrap().is_empty());
    }

    #[test]
    fn queued_jobs_survive_immediate_shutdown() {
        // stop is only honored once the queue is empty, so jobs submitted
        // before shutdown all complete even with no wait_for_results.
        let pool = WorkerPool::start("t", 2, echo_factory()).unwrap();
        for j in 0..20u64 {
            pool.submit(j);
        }
        let mut out = pool.shutdown().unwrap();
        out.sort_unstable();
        assert_eq!(out.len(), 20);
    }

    #[test]
    fn drain_queued_reclaims_unstarted_jobs() {
        // a stalled pool (executor blocks on a gate) accumulates a queue;
        // drain_queued hands the backlog back for re-submission while any
        // in-flight job still completes on shutdown — the conservation the
        // serving loop's fault-time drain relies on
        let gate = Arc::new((Mutex::new(false), Condvar::new()));
        let g = Arc::clone(&gate);
        let factory: ExecutorFactory<u64, u64> = Arc::new(move |_wid| {
            let g = Arc::clone(&g);
            Ok(Box::new(move |j: u64| {
                let (lock, cv) = &*g;
                let mut open = lock.lock().unwrap();
                while !*open {
                    open = cv.wait(open).unwrap();
                }
                Ok(vec![j])
            }) as Executor<u64, u64>)
        });
        let pool = WorkerPool::start("t", 1, factory).unwrap();
        for j in 0..10u64 {
            pool.submit(j);
        }
        // the single worker holds at most one popped job at the gate; the
        // rest come back out, in submission order
        let reclaimed = pool.drain_queued();
        assert!(reclaimed.len() >= 9, "at most one job can be in flight");
        assert!(reclaimed.windows(2).all(|w| w[0] < w[1]), "submission order");
        assert!(pool.drain_queued().is_empty(), "drain empties the queue");
        let (lock, cv) = &*gate;
        *lock.lock().unwrap() = true;
        cv.notify_all();
        let done = pool.shutdown().unwrap();
        assert_eq!(done.len() + reclaimed.len(), 10, "every job reclaimed or completed");
    }

    #[test]
    fn executor_error_poisons_the_pool() {
        let factory: ExecutorFactory<u64, u64> = Arc::new(|_wid| {
            Ok(Box::new(|j: u64| {
                if j == 3 {
                    Err(anyhow!("boom on {j}"))
                } else {
                    Ok(vec![j])
                }
            }) as Executor<u64, u64>)
        });
        let pool = WorkerPool::start("t", 1, factory).unwrap();
        for j in 0..5u64 {
            pool.submit(j);
        }
        pool.wait_for_results(5); // returns early on the error
        let err = pool.shutdown().unwrap_err();
        assert!(format!("{err}").contains("worker error"), "{err}");
    }

    #[test]
    fn factory_error_poisons_the_pool() {
        let factory: ExecutorFactory<u64, u64> =
            Arc::new(|wid| Err(anyhow!("init failed on {wid}")));
        let pool = WorkerPool::start("t", 2, factory).unwrap();
        pool.submit(1);
        pool.wait_for_results(1);
        assert!(pool.has_errors());
        assert!(pool.shutdown().is_err());
    }
}
