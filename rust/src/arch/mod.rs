//! Abstract accelerator architecture (paper §III).
//!
//! Types that describe a derived accelerator *before* it runs: PU
//! specifications (Fig. 4), PRGs (minimum scheduling units), ATB / LB
//! blocks, the two EDPU stages and their parallel modes, and the complete
//! `AcceleratorPlan` the customization engine emits.

use std::collections::BTreeMap;
use std::fmt;

use crate::config::{HardwareConfig, ModelConfig};
use crate::util::json::Json;

/// AIE MM PU size class (Fig. 4).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum PuClass {
    Large,
    Standard,
    Small,
}

impl fmt::Display for PuClass {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            PuClass::Large => "large",
            PuClass::Standard => "standard",
            PuClass::Small => "small",
        };
        write!(f, "{s}")
    }
}

/// One AIE MM PU specification: a `tiles_m x tiles_n x tiles_k` grid of
/// AIE cores, each holding an `MMSZ^3` tile, with PLIO channel counts.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PuSpec {
    pub class: PuClass,
    pub tiles_m: usize,
    pub tiles_n: usize,
    pub tiles_k: usize,
    pub in_plio: usize,
    pub out_plio: usize,
}

impl PuSpec {
    /// The paper's VCK5000 catalog (Fig. 4, `PLIO_AIE = 4`).
    pub fn catalog() -> Vec<PuSpec> {
        vec![
            PuSpec {
                class: PuClass::Large,
                tiles_m: 4,
                tiles_n: 4,
                tiles_k: 4,
                in_plio: 8,
                out_plio: 4,
            },
            PuSpec {
                class: PuClass::Standard,
                tiles_m: 2,
                tiles_n: 2,
                tiles_k: 4,
                in_plio: 4,
                out_plio: 1,
            },
            PuSpec {
                class: PuClass::Small,
                tiles_m: 1,
                tiles_n: 1,
                tiles_k: 4,
                in_plio: 2,
                out_plio: 1,
            },
        ]
    }

    pub fn by_class(class: PuClass) -> PuSpec {
        Self::catalog().into_iter().find(|p| p.class == class).unwrap()
    }

    /// AIE cores consumed by one PU instance.
    pub fn cores(&self) -> usize {
        self.tiles_m * self.tiles_n * self.tiles_k
    }

    /// (M, N, K) one invocation computes, in elements.
    pub fn invocation_shape(&self, mmsz: usize) -> (usize, usize, usize) {
        (self.tiles_m * mmsz, self.tiles_n * mmsz, self.tiles_k * mmsz)
    }

    /// int8 bytes streamed in per invocation (A and B operand tiles).
    pub fn in_bytes(&self, mmsz: usize) -> u64 {
        let (m, n, k) = self.invocation_shape(mmsz);
        (m * k + k * n) as u64
    }

    /// int32 bytes streamed out per invocation.
    pub fn out_bytes(&self, mmsz: usize) -> u64 {
        let (m, n, _) = self.invocation_shape(mmsz);
        (m * n * 4) as u64
    }

    /// MAC*2 ops per invocation.
    pub fn ops(&self, mmsz: usize) -> u64 {
        let (m, n, k) = self.invocation_shape(mmsz);
        2 * (m * n * k) as u64
    }

    /// Invocations needed to cover an `[M,K]x[K,N]` matmul.
    pub fn invocations_for(&self, mmsz: usize, m: usize, n: usize, k: usize) -> usize {
        let (pm, pn, pk) = self.invocation_shape(mmsz);
        m.div_ceil(pm) * n.div_ceil(pn) * k.div_ceil(pk)
    }
}

/// Stage-level parallel mode (paper §IV.C).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ParallelMode {
    /// Mode (1): all PRGs launched in parallel, each owning a slice of the
    /// computing engine; the stage forms one deep pipeline.
    FullyPipelined,
    /// Mode (2): LBs run serially (each with ALL engine resources); the
    /// `P_ATB` ATBs run in parallel between them.
    SerialHybrid,
    /// Pure serial (only when every MM exceeds the whole engine at once —
    /// "extremely rare", kept for the Limited-AIE configuration).
    Serial,
}

impl fmt::Display for ParallelMode {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            ParallelMode::FullyPipelined => "fully-pipelined",
            ParallelMode::SerialHybrid => "serial-hybrid",
            ParallelMode::Serial => "serial",
        };
        write!(f, "{s}")
    }
}

/// What a PRG does — its place in the EDPU dataflow (Fig. 3).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PrgKind {
    QLb,
    KLb,
    VLb,
    /// Merged QKV LB (independent-linear organization).
    QkvLb,
    /// ATB pre-stage (Q·K^T + transpose + softmax branch).
    AtbPre,
    /// ATB post-stage (A·V).
    AtbPost,
    ProjLb,
    Ffn1Lb,
    Ffn2Lb,
}

impl PrgKind {
    pub fn in_mha(&self) -> bool {
        !matches!(self, PrgKind::Ffn1Lb | PrgKind::Ffn2Lb)
    }

    pub fn is_atb(&self) -> bool {
        matches!(self, PrgKind::AtbPre | PrgKind::AtbPost)
    }
}

/// A Parallel Region — the minimum scheduling unit of the EDPU. Internally
/// a fixed pipeline (send → compute → receive + PL branches); externally
/// combined by the stage's parallel mode.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Prg {
    pub kind: PrgKind,
    /// Which ATB instance this PRG belongs to (0 for LBs).
    pub atb_index: usize,
    /// PU instances allocated to this PRG (class, how many).
    pub pus: Vec<(PuClass, usize)>,
}

impl Prg {
    pub fn cores(&self) -> usize {
        self.pus
            .iter()
            .map(|(c, n)| PuSpec::by_class(*c).cores() * n)
            .sum()
    }
}

/// One stage of the EDPU (MHA or FFN) after customization.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct StagePlan {
    pub mode: ParallelMode,
    pub prgs: Vec<Prg>,
}

impl StagePlan {
    /// Distinct AIE cores this stage touches.
    ///
    /// * Fully pipelined: every PRG owns disjoint PUs — sum.
    /// * Serial-hybrid: LBs reuse one pool (max); the `P_ATB` parallel ATBs
    ///   stack, but each ATB's pre/post PRGs run serially and share their
    ///   ATB's PUs (per-ATB max, summed across ATBs).
    /// * Serial: everything shares one pool — max.
    pub fn cores_deployed(&self) -> usize {
        match self.mode {
            ParallelMode::FullyPipelined => self.prgs.iter().map(Prg::cores).sum(),
            ParallelMode::Serial => {
                self.prgs.iter().map(Prg::cores).max().unwrap_or(0)
            }
            ParallelMode::SerialHybrid => {
                let lb_max = self
                    .prgs
                    .iter()
                    .filter(|p| !p.kind.is_atb())
                    .map(Prg::cores)
                    .max()
                    .unwrap_or(0);
                let mut per_atb: std::collections::BTreeMap<usize, usize> =
                    std::collections::BTreeMap::new();
                for p in self.prgs.iter().filter(|p| p.kind.is_atb()) {
                    let e = per_atb.entry(p.atb_index).or_insert(0);
                    *e = (*e).max(p.cores());
                }
                let atb_sum: usize = per_atb.values().sum();
                lb_max.max(atb_sum)
            }
        }
    }

    pub fn prgs_of(&self, kind: PrgKind) -> impl Iterator<Item = &Prg> {
        self.prgs.iter().filter(move |p| p.kind == kind)
    }
}

/// The PL resource estimate for one hardware module (Table V rows).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct PlResources {
    pub luts: usize,
    pub ffs: usize,
    pub brams: usize,
    pub urams: usize,
}

impl PlResources {
    pub fn add(&self, o: &PlResources) -> PlResources {
        PlResources {
            luts: self.luts + o.luts,
            ffs: self.ffs + o.ffs,
            brams: self.brams + o.brams,
            urams: self.urams + o.urams,
        }
    }

    /// The board's PL pools as one resource vector (the Table V
    /// denominators) — the shape every budget check compares against.
    pub fn pools_of(hw: &HardwareConfig) -> PlResources {
        PlResources { luts: hw.pl_luts, ffs: hw.pl_ffs, brams: hw.pl_brams, urams: hw.pl_urams }
    }

    /// Component-wise fit: this estimate stays inside `pool` on every
    /// resource class.  The single predicate behind the explorer's PL
    /// pruning, the partitioner's joint-footprint check, and the
    /// share-grant validation — one definition, no drift.
    pub fn fits_within(&self, pool: &PlResources) -> bool {
        self.luts <= pool.luts
            && self.ffs <= pool.ffs
            && self.brams <= pool.brams
            && self.urams <= pool.urams
    }

    /// Resources for `n` independent replicas (multi-EDPU deployment:
    /// each EDPU instance carries its own movers, operators and buffers).
    pub fn scale(&self, n: usize) -> PlResources {
        PlResources {
            luts: self.luts * n,
            ffs: self.ffs * n,
            brams: self.brams * n,
            urams: self.urams * n,
        }
    }

    /// Shared-resource union (two stages sharing hardware: the overall
    /// consumption is less than the sum — paper Table V discussion).
    pub fn union_shared(&self, o: &PlResources, shared_fraction: f64) -> PlResources {
        let f = 1.0 - shared_fraction;
        PlResources {
            luts: self.luts.max(o.luts) + (self.luts.min(o.luts) as f64 * f) as usize,
            ffs: self.ffs.max(o.ffs) + (self.ffs.min(o.ffs) as f64 * f) as usize,
            brams: self.brams.max(o.brams) + (self.brams.min(o.brams) as f64 * f) as usize,
            urams: self.urams.max(o.urams) + (self.urams.min(o.urams) as f64 * f) as usize,
        }
    }
}

/// The complete customized accelerator the CAT engine emits.
#[derive(Debug, Clone, PartialEq)]
pub struct AcceleratorPlan {
    pub model: ModelConfig,
    /// The part this plan deploys on.  Usually a whole board; the
    /// serving layer swaps in board *slices* here (a share of the AIE
    /// array and PL pools, and — for co-resident partition members on a
    /// contended memory path — a `mem_throttle < 1.0` that stretches the
    /// scheduler's stream timings).  Because [`Self::fingerprint`]
    /// hashes the full plan including this field, every distinct slice
    /// keys its own stage-sim cache entries.
    pub hw: HardwareConfig,
    /// Eq. 3 decision.
    pub mmsz: usize,
    /// Eq. 4 decision.
    pub plio_aie: usize,
    /// Whether the QKV linears are merged (independent-linear, §III.B).
    pub independent_linear: bool,
    /// Eq. 7/8 decision.
    pub p_atb: usize,
    pub mha: StagePlan,
    pub ffn: StagePlan,
    /// Eq. 5/6 intermediate values, kept for reporting.
    pub factor1_mha: f64,
    pub factor2_mha_bytes: u64,
    pub factor1_ffn: f64,
    pub factor2_ffn_bytes: u64,
    /// Table V estimates.
    pub res_mha: PlResources,
    pub res_ffn: PlResources,
    pub res_overall: PlResources,
}

impl AcceleratorPlan {
    /// `AIE_Deployment_number` — max over stages (stages share hardware).
    pub fn cores_deployed(&self) -> usize {
        self.mha.cores_deployed().max(self.ffn.cores_deployed())
    }

    /// Semantic fingerprint of everything that can influence a simulation
    /// of this plan (model dims, hardware timing parameters, and the full
    /// PRG/PU allocation).  Keyed on the complete `Debug` rendering so a
    /// new plan field can never silently escape the key; used by the
    /// scheduler's stage-simulation cache.  Stable within a process run,
    /// which is all an in-memory cache needs.
    ///
    /// Recomputed per call (not memoized on the plan): tests mutate plans
    /// in place after `customize` (e.g. swapping `hw`), and a stale
    /// stored fingerprint would alias two different plans in the cache.
    /// The formatter streams straight into the hasher, so the cost is one
    /// Debug-format pass with no allocation — trivial next to even a
    /// cache-hit's clone.
    pub fn fingerprint(&self) -> u64 {
        use std::collections::hash_map::DefaultHasher;
        use std::hash::Hasher;

        struct HashWriter(DefaultHasher);
        impl std::fmt::Write for HashWriter {
            fn write_str(&mut self, s: &str) -> std::fmt::Result {
                self.0.write(s.as_bytes());
                Ok(())
            }
        }
        let mut w = HashWriter(DefaultHasher::new());
        let _ = std::fmt::write(&mut w, format_args!("{self:?}"));
        w.0.finish()
    }

    /// Eq. 1: deployed / total.
    pub fn deployment_rate(&self) -> f64 {
        self.cores_deployed() as f64 / self.hw.total_aie as f64
    }

    pub fn to_json(&self) -> Json {
        let stage = |s: &StagePlan| {
            let prgs: Vec<Json> = s
                .prgs
                .iter()
                .map(|p| {
                    let mut m = BTreeMap::new();
                    m.insert("kind".into(), Json::Str(format!("{:?}", p.kind)));
                    m.insert("atb_index".into(), Json::Num(p.atb_index as f64));
                    m.insert(
                        "pus".into(),
                        Json::Arr(
                            p.pus
                                .iter()
                                .map(|(c, n)| {
                                    Json::Arr(vec![
                                        Json::Str(c.to_string()),
                                        Json::Num(*n as f64),
                                    ])
                                })
                                .collect(),
                        ),
                    );
                    m.insert("cores".into(), Json::Num(p.cores() as f64));
                    Json::Obj(m)
                })
                .collect();
            let mut m = BTreeMap::new();
            m.insert("mode".into(), Json::Str(s.mode.to_string()));
            m.insert("prgs".into(), Json::Arr(prgs));
            m.insert("cores".into(), Json::Num(s.cores_deployed() as f64));
            Json::Obj(m)
        };
        let mut m = BTreeMap::new();
        m.insert("model".into(), self.model.to_json());
        m.insert("hardware".into(), Json::Str(self.hw.name.clone()));
        m.insert("mmsz".into(), Json::Num(self.mmsz as f64));
        m.insert("plio_aie".into(), Json::Num(self.plio_aie as f64));
        m.insert("independent_linear".into(), Json::Bool(self.independent_linear));
        m.insert("p_atb".into(), Json::Num(self.p_atb as f64));
        m.insert("mha_stage".into(), stage(&self.mha));
        m.insert("ffn_stage".into(), stage(&self.ffn));
        m.insert("factor1_mha".into(), Json::Num(self.factor1_mha));
        m.insert("factor2_mha_bytes".into(), Json::Num(self.factor2_mha_bytes as f64));
        m.insert("factor1_ffn".into(), Json::Num(self.factor1_ffn));
        m.insert("factor2_ffn_bytes".into(), Json::Num(self.factor2_ffn_bytes as f64));
        m.insert("aie_deployed".into(), Json::Num(self.cores_deployed() as f64));
        m.insert("aie_deployment_rate".into(), Json::Num(self.deployment_rate()));
        Json::Obj(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn catalog_matches_fig4() {
        let large = PuSpec::by_class(PuClass::Large);
        assert_eq!(large.cores(), 64);
        assert_eq!(large.invocation_shape(64), (256, 256, 256));
        assert_eq!((large.in_plio, large.out_plio), (8, 4));

        let std_ = PuSpec::by_class(PuClass::Standard);
        assert_eq!(std_.cores(), 16);
        assert_eq!(std_.invocation_shape(64), (128, 128, 256));

        let small = PuSpec::by_class(PuClass::Small);
        assert_eq!(small.cores(), 4);
        assert_eq!(small.invocation_shape(64), (64, 64, 256));
    }

    #[test]
    fn invocations_cover_design_case() {
        let large = PuSpec::by_class(PuClass::Large);
        // 256x768x768 on a Large PU (256^3 per shot): 1*3*3 = 9 invocations
        assert_eq!(large.invocations_for(64, 256, 768, 768), 9);
        let small = PuSpec::by_class(PuClass::Small);
        // QK^T 256x256x64 on Small (64x64x256): 4*4*1
        assert_eq!(small.invocations_for(64, 256, 256, 64), 16);
    }

    #[test]
    fn stage_core_accounting() {
        // §V.C: 4 Large to LBs + per-ATB (2 Small + 1 Standard) x 4 = 352
        let lb = |kind| Prg { kind, atb_index: 0, pus: vec![(PuClass::Large, 1)] };
        let mut prgs =
            vec![lb(PrgKind::QkvLb), lb(PrgKind::QLb), lb(PrgKind::KLb), lb(PrgKind::ProjLb)];
        for i in 0..4 {
            prgs.push(Prg { kind: PrgKind::AtbPre, atb_index: i, pus: vec![(PuClass::Small, 2)] });
            prgs.push(Prg {
                kind: PrgKind::AtbPost,
                atb_index: i,
                pus: vec![(PuClass::Standard, 1)],
            });
        }
        let stage = StagePlan { mode: ParallelMode::FullyPipelined, prgs };
        assert_eq!(stage.cores_deployed(), 4 * 64 + 4 * (2 * 4 + 16));
        assert_eq!(stage.cores_deployed(), 352);
    }

    #[test]
    fn serial_mode_shares_pool() {
        let prgs = vec![
            Prg { kind: PrgKind::Ffn1Lb, atb_index: 0, pus: vec![(PuClass::Large, 4)] },
            Prg { kind: PrgKind::Ffn2Lb, atb_index: 0, pus: vec![(PuClass::Large, 4)] },
        ];
        let stage = StagePlan { mode: ParallelMode::Serial, prgs };
        assert_eq!(stage.cores_deployed(), 256); // shared, not 512
    }

    #[test]
    fn pu_bytes() {
        let small = PuSpec::by_class(PuClass::Small);
        // 64x64x256: A 64x256 + B 256x64 = 32 KiB in, 64x64x4 = 16 KiB out
        assert_eq!(small.in_bytes(64), 32 * 1024);
        assert_eq!(small.out_bytes(64), 16 * 1024);
    }

    #[test]
    fn scale_replicates_every_pool() {
        let a = PlResources { luts: 100, ffs: 200, brams: 10, urams: 4 };
        let s = a.scale(3);
        assert_eq!((s.luts, s.ffs, s.brams, s.urams), (300, 600, 30, 12));
        let id = a.scale(1);
        assert_eq!(id, a);
    }

    #[test]
    fn shared_union_less_than_sum() {
        let a = PlResources { luts: 100, ffs: 200, brams: 10, urams: 4 };
        let b = PlResources { luts: 60, ffs: 100, brams: 8, urams: 2 };
        let u = a.union_shared(&b, 0.8);
        assert!(u.luts < a.luts + b.luts);
        assert!(u.luts >= a.luts);
    }
}
