//! # CAT: Customized Transformer Accelerator Framework on Versal ACAP
//!
//! Full-system reproduction of Zhang, Liu & Bao (2024).  The crate derives
//! customized Transformer accelerators for a (simulated) Versal ACAP part:
//!
//! * [`config`] — hardware + model descriptors (paper Tables III/IV);
//! * [`workload`] — Transformer load analysis (§IV.A);
//! * [`arch`] — the abstract accelerator architecture: PU specs, PRGs,
//!   ATB/LB, EDPU stages (§III);
//! * [`customize`] — the Eq. 3–8 customization strategy (§IV);
//! * [`dse`] — design-space exploration: Pareto-optimal accelerator
//!   families over the joint customization × deployment space;
//! * [`sim`] — discrete-event Versal ACAP substrate (AIE/PLIO/PL/power);
//! * [`sched`] — Algorithm 1: EDPU stage execution over the simulator;
//! * [`metrics`] — AIE utilization rates (Eq. 1–2), TOPS, GOPS/W;
//! * [`baselines`] — CHARM/SSR-style and published GPU/FPGA comparators;
//! * [`runtime`] — PJRT execution of the AOT-compiled JAX/Pallas encoder;
//! * [`coordinator`] — HOST-side request batching over an EDPU pool;
//! * [`serve`] — SLO-aware fleet serving across an explore-derived
//!   accelerator family (virtual-clock routing + admission control);
//! * [`cluster`] — multi-board cluster serving: the family spread over a
//!   rack of mixed SKUs behind one admission plane, with the inter-board
//!   NIC/switch pools negotiated like on-board links;
//! * [`obs`] — zero-cost-when-off observability: virtual-clock traces
//!   (Chrome trace-event JSON for Perfetto) + `cat-obs-v1` metrics;
//! * [`report`] — renderers for every paper table/figure.
//!
//! See DESIGN.md for the substitution map (real board → simulator) and
//! EXPERIMENTS.md for paper-vs-measured results.

pub mod arch;
pub mod baselines;
pub mod cluster;
pub mod codegen;
pub mod config;
pub mod experiments;
pub mod coordinator;
pub mod customize;
pub mod dse;
pub mod metrics;
pub mod obs;
pub mod report;
pub mod runtime;
pub mod sched;
pub mod serve;
pub mod sim;
pub mod util;
pub mod workload;
