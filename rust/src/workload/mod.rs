//! Transformer load analysis (paper §IV.A).
//!
//! Enumerates the exact MM / nonlinear-operator load of one encoder layer
//! under either linear-layer organization:
//!
//! * **per-head linear** — the naive `5·Head + 3` matmuls;
//! * **independent linear** — the paper's extraction/aggregation of the
//!   QKV projections of all heads into one large PU matmul (§III.B),
//!   which collapses the LB count to 4 but keeps `2·Head` ATB matmuls.

use crate::config::ModelConfig;

/// Where in the EDPU dataflow an MM lives (decides which PRG runs it).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum MmSite {
    /// Merged QKV linear (independent-linear mode), or one of Q/K/V.
    QkvLb,
    /// ATB pre-stage `Q·K^T` (per head).
    AtbPre,
    /// ATB post-stage `A·V` (per head).
    AtbPost,
    /// Output projection LB.
    ProjLb,
    /// FFN first linear.
    Ffn1Lb,
    /// FFN second linear.
    Ffn2Lb,
}

impl MmSite {
    pub fn in_mha(&self) -> bool {
        !matches!(self, MmSite::Ffn1Lb | MmSite::Ffn2Lb)
    }
}

/// `count` matmuls of shape `[m, k] x [k, n]`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MmOp {
    pub site: MmSite,
    pub count: usize,
    pub m: usize,
    pub n: usize,
    pub k: usize,
}

impl MmOp {
    /// MAC*2 ops for all `count` instances.
    pub fn ops(&self) -> u64 {
        2 * self.count as u64 * self.m as u64 * self.n as u64 * self.k as u64
    }

    /// int8 input bytes streamed for one instance (A + B operands).
    pub fn in_bytes(&self) -> u64 {
        (self.m * self.k + self.k * self.n) as u64
    }

    /// int32 output bytes for one instance.
    pub fn out_bytes(&self) -> u64 {
        (self.m * self.n * 4) as u64
    }
}

/// Nonlinear / data-movement operators that run on the PL branch.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PlSite {
    Softmax,
    Transpose,
    Gelu,
    LayerNormAdd,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PlOp {
    pub site: PlSite,
    pub count: usize,
    /// rows x cols processed per instance.
    pub rows: usize,
    pub cols: usize,
}

impl PlOp {
    pub fn bytes(&self) -> u64 {
        (self.count * self.rows * self.cols * 4) as u64
    }
}

/// The full one-layer load.
#[derive(Debug, Clone, PartialEq)]
pub struct Workload {
    pub model: ModelConfig,
    pub mmsz: usize,
    pub independent_linear: bool,
    pub mms: Vec<MmOp>,
    pub pls: Vec<PlOp>,
}

/// Enumerate one encoder layer's load (paper §IV.A and the §V.B design
/// case), padded to `mmsz`.
pub fn layer_workload(
    model: &ModelConfig,
    mmsz: usize,
    independent_linear: bool,
) -> Workload {
    let l = model.padded_seq_len(mmsz);
    let e = model.embed_dim;
    let d = model.dff;
    let h = model.heads;
    let dh = model.head_dim().max(mmsz); // pad tiny head_dim up to a tile

    let mut mms = Vec::new();
    if independent_linear {
        // merged QKV: one [L,E]x[E,3E] — accounted as 3 L x E x E plus the
        // projection, i.e. the paper's "4 times 256x768x768".
        mms.push(MmOp { site: MmSite::QkvLb, count: 3, m: l, n: e, k: e });
    } else {
        // per-head Q, K, V linears: 3·Head small matmuls [L,E]x[E,dh]
        mms.push(MmOp { site: MmSite::QkvLb, count: 3 * h, m: l, n: dh, k: e });
    }
    mms.push(MmOp { site: MmSite::AtbPre, count: h, m: l, n: l, k: dh });
    mms.push(MmOp { site: MmSite::AtbPost, count: h, m: l, n: dh, k: l });
    mms.push(MmOp { site: MmSite::ProjLb, count: 1, m: l, n: e, k: e });
    mms.push(MmOp { site: MmSite::Ffn1Lb, count: 1, m: l, n: d, k: e });
    mms.push(MmOp { site: MmSite::Ffn2Lb, count: 1, m: l, n: e, k: d });

    let pls = vec![
        PlOp { site: PlSite::Softmax, count: h, rows: l, cols: l },
        PlOp { site: PlSite::Transpose, count: h, rows: l, cols: dh },
        PlOp { site: PlSite::LayerNormAdd, count: 2, rows: l, cols: e },
        PlOp { site: PlSite::Gelu, count: 1, rows: l, cols: d },
    ];

    Workload {
        model: model.clone(),
        mmsz,
        independent_linear,
        mms,
        pls,
    }
}

impl Workload {
    /// Total matmul instances. Per-head linear: `5·Head + 3` (§IV.A);
    /// independent linear: `2·Head + 6`.
    pub fn mm_count(&self) -> usize {
        self.mms.iter().map(|m| m.count).sum()
    }

    /// MAC*2 ops of the layer (what the paper's TOPS figures count).
    pub fn total_ops(&self) -> u64 {
        self.mms.iter().map(MmOp::ops).sum()
    }

    pub fn mha_ops(&self) -> u64 {
        self.mms.iter().filter(|m| m.site.in_mha()).map(MmOp::ops).sum()
    }

    pub fn ffn_ops(&self) -> u64 {
        self.mms.iter().filter(|m| !m.site.in_mha()).map(MmOp::ops).sum()
    }

    /// Fraction of MM ops vs everything (the paper: "more than 90%").
    pub fn mm_op_fraction(&self) -> f64 {
        // count PL ops as ~10 flops/element (exp/div/mean/var etc.)
        let pl: u64 = self.pls.iter().map(|p| p.bytes() / 4 * 10).sum();
        let mm = self.total_ops();
        mm as f64 / (mm + pl) as f64
    }

    pub fn mms_at(&self, site: MmSite) -> Option<&MmOp> {
        self.mms.iter().find(|m| m.site == site)
    }

    /// Weight bytes that must be resident (the §V.B weight cache term).
    pub fn weight_cache_bytes(&self) -> u64 {
        let e = self.model.embed_dim as u64;
        let d = self.model.dff as u64;
        // paper counts 768*768*4 (QKV merged + proj) + 768*3072*2 = 6.75 MB
        4 * e * e + 2 * e * d
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;

    #[test]
    fn bert_design_case_counts() {
        // §V.B: 4x 256x768x768, 12x 256x64x256 (pre: n=l? see below),
        // 12x 256x256x64, 2 FFN matmuls.
        let wl = layer_workload(&ModelConfig::bert_base(), 64, true);
        let qkv = wl.mms_at(MmSite::QkvLb).unwrap();
        assert_eq!((qkv.count, qkv.m, qkv.n, qkv.k), (3, 256, 768, 768));
        let proj = wl.mms_at(MmSite::ProjLb).unwrap();
        assert_eq!((proj.count, proj.m, proj.n, proj.k), (1, 256, 768, 768));
        let pre = wl.mms_at(MmSite::AtbPre).unwrap();
        assert_eq!((pre.count, pre.m, pre.n, pre.k), (12, 256, 256, 64));
        let post = wl.mms_at(MmSite::AtbPost).unwrap();
        assert_eq!((post.count, post.m, post.n, post.k), (12, 256, 64, 256));
        assert_eq!(wl.mms_at(MmSite::Ffn1Lb).unwrap().n, 3072);
    }

    #[test]
    fn mm_count_rule() {
        let m = ModelConfig::bert_base();
        assert_eq!(layer_workload(&m, 64, false).mm_count(), 5 * 12 + 3);
        assert_eq!(layer_workload(&m, 64, true).mm_count(), 2 * 12 + 6);
    }

    #[test]
    fn ops_match_paper_table_vi() {
        let wl = layer_workload(&ModelConfig::bert_base(), 64, true);
        // FFN = 2.416 GOP, MHA = 1.409 GOP (paper Table VI cross-check)
        assert!((wl.ffn_ops() as f64 - 2.416e9).abs() / 2.416e9 < 0.01);
        assert!((wl.mha_ops() as f64 - 1.409e9).abs() / 1.409e9 < 0.01);
    }

    #[test]
    fn mm_dominates_compute() {
        let wl = layer_workload(&ModelConfig::bert_base(), 64, true);
        assert!(wl.mm_op_fraction() > 0.90, "{}", wl.mm_op_fraction());
    }

    #[test]
    fn vit_pads_attention() {
        let wl = layer_workload(&ModelConfig::vit_base(), 64, true);
        let pre = wl.mms_at(MmSite::AtbPre).unwrap();
        assert_eq!((pre.m, pre.n), (256, 256)); // padded from 197
    }

    #[test]
    fn weight_cache_is_6_75_mb() {
        let wl = layer_workload(&ModelConfig::bert_base(), 64, true);
        // 768*768*4 + 768*3072*2 = 7_077_888 bytes = 6.75 MiB (paper §V.B)
        assert_eq!(wl.weight_cache_bytes(), 7_077_888);
        assert!((wl.weight_cache_bytes() as f64 / (1024.0 * 1024.0) - 6.75).abs() < 1e-9);
    }

    #[test]
    fn independent_vs_perhead_same_total_lb_ops() {
        // merging QKV must not change total LB compute
        let m = ModelConfig::bert_base();
        let a = layer_workload(&m, 64, true);
        let b = layer_workload(&m, 64, false);
        assert_eq!(a.total_ops(), b.total_ops());
    }
}
