//! Synthetic int8-quantized encoder weights, generated host-side.
//!
//! The paper deploys "already quantified Int8 models"; lacking the
//! original checkpoints we generate Xavier-style random weights with the
//! same quantization scheme as `python/compile/model.py::init_params`
//! (symmetric per-tensor scales), deterministic per seed.

use super::Tensor;
use crate::config::ModelConfig;
use crate::util::prng::Prng;

/// One encoder layer's parameters, in the manifest's canonical order:
/// wqkv, sqkv, bqkv, wproj, sproj, bproj, w1, s1, b1, w2, s2, b2,
/// ln1_g, ln1_b, ln2_g, ln2_b.
#[derive(Debug, Clone)]
pub struct EncoderWeights {
    pub wqkv: Tensor,
    pub sqkv: f32,
    pub bqkv: Tensor,
    pub wproj: Tensor,
    pub sproj: f32,
    pub bproj: Tensor,
    pub w1: Tensor,
    pub s1: f32,
    pub b1: Tensor,
    pub w2: Tensor,
    pub s2: f32,
    pub b2: Tensor,
    pub ln1_g: Tensor,
    pub ln1_b: Tensor,
    pub ln2_g: Tensor,
    pub ln2_b: Tensor,
}

/// Quantize an fp32 weight matrix to (int8, scale) with a calibrated
/// symmetric per-tensor scale.
fn quantize_weight(w: &[f32]) -> (Vec<i8>, f32) {
    let max = w.iter().fold(0.0f32, |m, v| m.max(v.abs())).max(1e-8);
    let scale = max / 127.0;
    let q = w
        .iter()
        .map(|v| (v / scale).round().clamp(-127.0, 127.0) as i8)
        .collect();
    (q, scale)
}

/// Quantize an activation with a dynamic per-tensor scale (matches
/// `model.dyn_quant`). Returns (int8 tensor, scale).
pub fn quantize_activation(x: &[f32], shape: &[usize]) -> (Tensor, f32) {
    let max = x.iter().fold(0.0f32, |m, v| m.max(v.abs())).max(1e-8);
    let scale = max / 127.0;
    let q: Vec<i8> = x
        .iter()
        .map(|v| (v / scale).round().clamp(-127.0, 127.0) as i8)
        .collect();
    (Tensor::I8 { data: q, shape: shape.to_vec() }, scale)
}

fn xavier(rng: &mut Prng, rows: usize, cols: usize) -> Vec<f32> {
    let std = 1.0 / (rows as f64).sqrt();
    (0..rows * cols)
        .map(|_| (rng.gaussian() * std) as f32)
        .collect()
}

impl EncoderWeights {
    /// Deterministic synthetic weights for one layer.
    pub fn synthetic(model: &ModelConfig, seed: u64) -> EncoderWeights {
        let mut rng = Prng::new(seed);
        let e = model.embed_dim;
        let d = model.dff;
        let (wqkv, sqkv) = quantize_weight(&xavier(&mut rng, e, 3 * e));
        let (wproj, sproj) = quantize_weight(&xavier(&mut rng, e, e));
        let (w1, s1) = quantize_weight(&xavier(&mut rng, e, d));
        let (w2, s2) = quantize_weight(&xavier(&mut rng, d, e));
        let zeros = |n: usize| Tensor::F32 { data: vec![0.0; n], shape: vec![n] };
        let ones = |n: usize| Tensor::F32 { data: vec![1.0; n], shape: vec![n] };
        EncoderWeights {
            wqkv: Tensor::I8 { data: wqkv, shape: vec![e, 3 * e] },
            sqkv,
            bqkv: zeros(3 * e),
            wproj: Tensor::I8 { data: wproj, shape: vec![e, e] },
            sproj,
            bproj: zeros(e),
            w1: Tensor::I8 { data: w1, shape: vec![e, d] },
            s1,
            b1: zeros(d),
            w2: Tensor::I8 { data: w2, shape: vec![d, e] },
            s2,
            b2: zeros(e),
            ln1_g: ones(e),
            ln1_b: zeros(e),
            ln2_g: ones(e),
            ln2_b: zeros(e),
        }
    }

    /// Weights for a whole model (one entry per layer).
    pub fn model_stack(model: &ModelConfig, seed: u64) -> Vec<EncoderWeights> {
        (0..model.layers)
            .map(|i| Self::synthetic(model, seed.wrapping_add(i as u64)))
            .collect()
    }

    /// Flatten in the manifest's canonical parameter order.
    pub fn tensors(&self) -> Vec<Tensor> {
        vec![
            self.wqkv.clone(),
            Tensor::scalar_f32(self.sqkv),
            self.bqkv.clone(),
            self.wproj.clone(),
            Tensor::scalar_f32(self.sproj),
            self.bproj.clone(),
            self.w1.clone(),
            Tensor::scalar_f32(self.s1),
            self.b1.clone(),
            self.w2.clone(),
            Tensor::scalar_f32(self.s2),
            self.b2.clone(),
            self.ln1_g.clone(),
            self.ln1_b.clone(),
            self.ln2_g.clone(),
            self.ln2_b.clone(),
        ]
    }

    /// Total int8 weight bytes (for DRAM/buffer accounting).
    pub fn weight_bytes(&self) -> usize {
        [&self.wqkv, &self.wproj, &self.w1, &self.w2]
            .iter()
            .map(|t| t.len())
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> ModelConfig {
        ModelConfig {
            name: "tiny".into(),
            heads: 4,
            embed_dim: 64,
            dff: 128,
            seq_len: 32,
            layers: 2,
            bits: 8,
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let a = EncoderWeights::synthetic(&tiny(), 7);
        let b = EncoderWeights::synthetic(&tiny(), 7);
        assert_eq!(a.wqkv, b.wqkv);
        assert_eq!(a.sqkv, b.sqkv);
        let c = EncoderWeights::synthetic(&tiny(), 8);
        assert_ne!(a.wqkv, c.wqkv);
    }

    #[test]
    fn shapes_match_model() {
        let w = EncoderWeights::synthetic(&tiny(), 1);
        assert_eq!(w.wqkv.shape(), &[64, 192]);
        assert_eq!(w.w1.shape(), &[64, 128]);
        assert_eq!(w.w2.shape(), &[128, 64]);
        assert_eq!(w.tensors().len(), 16);
        assert_eq!(w.weight_bytes(), 64 * 192 + 64 * 64 + 2 * 64 * 128);
    }

    #[test]
    fn quantization_in_range() {
        let w = EncoderWeights::synthetic(&tiny(), 3);
        let q = w.wqkv.as_i8().unwrap();
        assert!(q.iter().any(|v| *v != 0));
        assert!(q.iter().all(|v| (-127..=127).contains(v)));
        assert!(w.sqkv > 0.0);
    }

    #[test]
    fn activation_quantization_roundtrip() {
        let x = vec![-2.0f32, 0.0, 1.0, 2.0];
        let (t, s) = quantize_activation(&x, &[2, 2]);
        let q = t.as_i8().unwrap();
        assert_eq!(q[0], -127);
        assert_eq!(q[3], 127);
        for (orig, qv) in x.iter().zip(q) {
            assert!((orig - *qv as f32 * s).abs() <= s * 0.5 + 1e-6);
        }
    }

    #[test]
    fn stack_has_layer_count() {
        let ws = EncoderWeights::model_stack(&tiny(), 42);
        assert_eq!(ws.len(), 2);
        assert_ne!(
            ws[0].wqkv.as_i8().unwrap()[..32],
            ws[1].wqkv.as_i8().unwrap()[..32]
        );
    }
}
