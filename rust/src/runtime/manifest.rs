//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! rust runtime (parameter order, shapes, dtypes of every HLO artifact).

use std::collections::BTreeMap;

use crate::util::json::Json;
use anyhow::{anyhow, Result};

/// One artifact parameter.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParamInfo {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

/// One AOT artifact.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArtifactInfo {
    pub name: String,
    pub file: String,
    pub params: Vec<ParamInfo>,
    pub output_shapes: Vec<Vec<usize>>,
    pub output_dtypes: Vec<String>,
}

/// A model entry in the manifest (logical vs padded sequence length).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ModelEntry {
    pub name: String,
    pub heads: usize,
    pub embed_dim: usize,
    pub dff: usize,
    pub seq_len: usize,
    pub padded_seq_len: usize,
    pub layers: usize,
}

/// Parsed `artifacts/manifest.json`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Manifest {
    pub mmsz: usize,
    pub artifacts: Vec<ArtifactInfo>,
    pub models: Vec<ModelEntry>,
}

fn parse_shape(j: &Json) -> Result<Vec<usize>> {
    j.as_arr()
        .ok_or_else(|| anyhow!("shape not an array"))?
        .iter()
        .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim")))
        .collect()
}

impl Manifest {
    pub fn load(path: impl AsRef<std::path::Path>) -> Result<Manifest> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("reading {}: {e} (run `make artifacts`)", path.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("parsing manifest: {e}"))?;
        Self::from_json(&j)
    }

    pub fn from_json(j: &Json) -> Result<Manifest> {
        let mmsz = j
            .get("mmsz")
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow!("manifest missing mmsz"))?;
        let mut artifacts = Vec::new();
        for a in j
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing artifacts"))?
        {
            let name = a
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("artifact missing name"))?
                .to_string();
            let file = a
                .get("file")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("artifact missing file"))?
                .to_string();
            let mut params = Vec::new();
            for p in a.get("params").and_then(Json::as_arr).unwrap_or(&[]) {
                params.push(ParamInfo {
                    name: p
                        .get("name")
                        .and_then(Json::as_str)
                        .ok_or_else(|| anyhow!("param missing name"))?
                        .to_string(),
                    shape: parse_shape(
                        p.get("shape").ok_or_else(|| anyhow!("param missing shape"))?,
                    )?,
                    dtype: p
                        .get("dtype")
                        .and_then(Json::as_str)
                        .ok_or_else(|| anyhow!("param missing dtype"))?
                        .to_string(),
                });
            }
            let mut output_shapes = Vec::new();
            let mut output_dtypes = Vec::new();
            for o in a.get("outputs").and_then(Json::as_arr).unwrap_or(&[]) {
                output_shapes.push(parse_shape(
                    o.get("shape").ok_or_else(|| anyhow!("output missing shape"))?,
                )?);
                output_dtypes.push(
                    o.get("dtype")
                        .and_then(Json::as_str)
                        .ok_or_else(|| anyhow!("output missing dtype"))?
                        .to_string(),
                );
            }
            artifacts.push(ArtifactInfo { name, file, params, output_shapes, output_dtypes });
        }
        let mut models = Vec::new();
        if let Some(m) = j.get("models").and_then(Json::as_obj) {
            for (name, v) in m {
                let u = |k: &str| -> Result<usize> {
                    v.get(k)
                        .and_then(Json::as_usize)
                        .ok_or_else(|| anyhow!("model '{name}' missing '{k}'"))
                };
                models.push(ModelEntry {
                    name: name.clone(),
                    heads: u("heads")?,
                    embed_dim: u("embed_dim")?,
                    dff: u("dff")?,
                    seq_len: u("seq_len")?,
                    padded_seq_len: u("padded_seq_len")?,
                    layers: u("layers")?,
                });
            }
        }
        Ok(Manifest { mmsz, artifacts, models })
    }

    /// Serialize back to the `manifest.json` schema.  [`Manifest::from_json`]
    /// is the inverse: `from_json(&m.to_json()) == m` (models come back in
    /// name order — the JSON object is sorted — so a manifest that
    /// round-trips once is a fixed point).
    pub fn to_json(&self) -> Json {
        let shape = |s: &[usize]| Json::Arr(s.iter().map(|d| Json::Num(*d as f64)).collect());
        let mut root = BTreeMap::new();
        root.insert("mmsz".into(), Json::Num(self.mmsz as f64));
        let mut models = BTreeMap::new();
        for m in &self.models {
            let mut e = BTreeMap::new();
            e.insert("heads".into(), Json::Num(m.heads as f64));
            e.insert("embed_dim".into(), Json::Num(m.embed_dim as f64));
            e.insert("dff".into(), Json::Num(m.dff as f64));
            e.insert("seq_len".into(), Json::Num(m.seq_len as f64));
            e.insert("padded_seq_len".into(), Json::Num(m.padded_seq_len as f64));
            e.insert("layers".into(), Json::Num(m.layers as f64));
            models.insert(m.name.clone(), Json::Obj(e));
        }
        root.insert("models".into(), Json::Obj(models));
        let artifacts = self
            .artifacts
            .iter()
            .map(|a| {
                let mut e = BTreeMap::new();
                e.insert("name".into(), Json::Str(a.name.clone()));
                e.insert("file".into(), Json::Str(a.file.clone()));
                e.insert(
                    "params".into(),
                    Json::Arr(
                        a.params
                            .iter()
                            .map(|p| {
                                let mut pm = BTreeMap::new();
                                pm.insert("name".into(), Json::Str(p.name.clone()));
                                pm.insert("shape".into(), shape(&p.shape));
                                pm.insert("dtype".into(), Json::Str(p.dtype.clone()));
                                Json::Obj(pm)
                            })
                            .collect(),
                    ),
                );
                e.insert(
                    "outputs".into(),
                    Json::Arr(
                        a.output_shapes
                            .iter()
                            .zip(&a.output_dtypes)
                            .map(|(s, d)| {
                                let mut om = BTreeMap::new();
                                om.insert("shape".into(), shape(s));
                                om.insert("dtype".into(), Json::Str(d.clone()));
                                Json::Obj(om)
                            })
                            .collect(),
                    ),
                );
                Json::Obj(e)
            })
            .collect();
        root.insert("artifacts".into(), Json::Arr(artifacts));
        Json::Obj(root)
    }

    pub fn artifact(&self, name: &str) -> Option<&ArtifactInfo> {
        self.artifacts.iter().find(|a| a.name == name)
    }

    pub fn model(&self, name: &str) -> Option<&ModelEntry> {
        self.models.iter().find(|m| m.name == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
        "mmsz": 64,
        "models": {"bert-base": {"heads":12,"embed_dim":768,"dff":3072,
                   "seq_len":256,"padded_seq_len":256,"layers":12}},
        "artifacts": [{
            "name": "mm_tile", "file": "mm_tile.hlo.txt",
            "params": [
                {"name":"a","shape":[64,64],"dtype":"int8"},
                {"name":"b","shape":[64,64],"dtype":"int8"}],
            "outputs": [{"shape":[64,64],"dtype":"int32"}],
            "meta": {"mmsz": 64}
        }]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::from_json(&Json::parse(SAMPLE).unwrap()).unwrap();
        assert_eq!(m.mmsz, 64);
        let a = m.artifact("mm_tile").unwrap();
        assert_eq!(a.params[0].shape, vec![64, 64]);
        assert_eq!(a.output_dtypes, vec!["int32"]);
        assert_eq!(m.model("bert-base").unwrap().layers, 12);
        assert!(m.artifact("nope").is_none());
    }

    #[test]
    fn rejects_incomplete() {
        let j = Json::parse(r#"{"artifacts": []}"#).unwrap();
        assert!(Manifest::from_json(&j).is_err());
    }

    #[test]
    fn roundtrips_through_json() {
        let m = Manifest::from_json(&Json::parse(SAMPLE).unwrap()).unwrap();
        // serialize -> parse -> equal (the whole structure, not a spot check)
        let back = Manifest::from_json(&m.to_json()).unwrap();
        assert_eq!(m, back);
        // and the JSON text itself is a fixed point after one round trip
        assert_eq!(m.to_json().to_string(), back.to_json().to_string());
        // a parse of the printed text also round-trips (printer emits
        // valid JSON in the manifest schema)
        let reparsed =
            Manifest::from_json(&Json::parse(&m.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(m, reparsed);
    }

    #[test]
    fn roundtrip_preserves_empty_params_and_multiple_models() {
        let m = Manifest {
            mmsz: 32,
            artifacts: vec![ArtifactInfo {
                name: "softmax".into(),
                file: "softmax.hlo.txt".into(),
                params: vec![],
                output_shapes: vec![vec![8, 8], vec![1]],
                output_dtypes: vec!["f32".into(), "f32".into()],
            }],
            models: vec![
                ModelEntry {
                    name: "a".into(),
                    heads: 2,
                    embed_dim: 16,
                    dff: 64,
                    seq_len: 10,
                    padded_seq_len: 32,
                    layers: 1,
                },
                ModelEntry {
                    name: "b".into(),
                    heads: 4,
                    embed_dim: 32,
                    dff: 128,
                    seq_len: 20,
                    padded_seq_len: 32,
                    layers: 2,
                },
            ],
        };
        let back = Manifest::from_json(&m.to_json()).unwrap();
        assert_eq!(m, back);
        assert_eq!(back.artifact("softmax").unwrap().output_shapes.len(), 2);
    }

    #[test]
    fn malformed_manifests_name_the_missing_piece() {
        let err = |src: &str| {
            format!("{}", Manifest::from_json(&Json::parse(src).unwrap()).unwrap_err())
        };
        assert!(err(r#"{"artifacts": []}"#).contains("missing mmsz"));
        assert!(err(r#"{"mmsz": 64}"#).contains("missing artifacts"));
        assert!(err(r#"{"mmsz": 64, "artifacts": [{"file": "x"}]}"#).contains("missing name"));
        assert!(
            err(r#"{"mmsz": 64, "artifacts": [{"name": "x"}]}"#).contains("missing file")
        );
        // a bad shape dimension points at the dim, not a generic failure
        let bad_dim = err(
            r#"{"mmsz": 64, "artifacts": [{"name":"x","file":"f",
                "params":[{"name":"a","shape":[64,-1],"dtype":"int8"}]}]}"#,
        );
        assert!(bad_dim.contains("bad dim"), "{bad_dim}");
        // model entries name the model and the missing key
        let bad_model = err(
            r#"{"mmsz": 64, "artifacts": [],
                "models": {"tiny": {"heads": 2}}}"#,
        );
        assert!(bad_model.contains("'tiny'") && bad_model.contains("embed_dim"), "{bad_model}");
    }

    #[test]
    fn load_error_points_at_make_artifacts() {
        let err = Manifest::load("definitely/not/a/manifest.json").unwrap_err();
        assert!(format!("{err}").contains("make artifacts"), "{err}");
    }

    #[test]
    fn loads_real_manifest_if_present() {
        if std::path::Path::new("artifacts/manifest.json").exists() {
            let m = Manifest::load("artifacts/manifest.json").unwrap();
            assert!(m.artifact("encoder_layer_fused").is_some());
            assert!(m.artifact("encoder_layer_pallas").is_some());
            let enc = m.artifact("encoder_layer_fused").unwrap();
            assert_eq!(enc.params.len(), 18); // x_q, x_scale + 16 weights
        }
    }
}
