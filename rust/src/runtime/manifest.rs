//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! rust runtime (parameter order, shapes, dtypes of every HLO artifact).

use crate::util::json::Json;
use anyhow::{anyhow, Result};

/// One artifact parameter.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParamInfo {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

/// One AOT artifact.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArtifactInfo {
    pub name: String,
    pub file: String,
    pub params: Vec<ParamInfo>,
    pub output_shapes: Vec<Vec<usize>>,
    pub output_dtypes: Vec<String>,
}

/// A model entry in the manifest (logical vs padded sequence length).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ModelEntry {
    pub name: String,
    pub heads: usize,
    pub embed_dim: usize,
    pub dff: usize,
    pub seq_len: usize,
    pub padded_seq_len: usize,
    pub layers: usize,
}

/// Parsed `artifacts/manifest.json`.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub mmsz: usize,
    pub artifacts: Vec<ArtifactInfo>,
    pub models: Vec<ModelEntry>,
}

fn parse_shape(j: &Json) -> Result<Vec<usize>> {
    j.as_arr()
        .ok_or_else(|| anyhow!("shape not an array"))?
        .iter()
        .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim")))
        .collect()
}

impl Manifest {
    pub fn load(path: impl AsRef<std::path::Path>) -> Result<Manifest> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("reading {}: {e} (run `make artifacts`)", path.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("parsing manifest: {e}"))?;
        Self::from_json(&j)
    }

    pub fn from_json(j: &Json) -> Result<Manifest> {
        let mmsz = j
            .get("mmsz")
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow!("manifest missing mmsz"))?;
        let mut artifacts = Vec::new();
        for a in j
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing artifacts"))?
        {
            let name = a
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("artifact missing name"))?
                .to_string();
            let file = a
                .get("file")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("artifact missing file"))?
                .to_string();
            let mut params = Vec::new();
            for p in a.get("params").and_then(Json::as_arr).unwrap_or(&[]) {
                params.push(ParamInfo {
                    name: p
                        .get("name")
                        .and_then(Json::as_str)
                        .ok_or_else(|| anyhow!("param missing name"))?
                        .to_string(),
                    shape: parse_shape(p.get("shape").ok_or_else(|| anyhow!("param missing shape"))?)?,
                    dtype: p
                        .get("dtype")
                        .and_then(Json::as_str)
                        .ok_or_else(|| anyhow!("param missing dtype"))?
                        .to_string(),
                });
            }
            let mut output_shapes = Vec::new();
            let mut output_dtypes = Vec::new();
            for o in a.get("outputs").and_then(Json::as_arr).unwrap_or(&[]) {
                output_shapes.push(parse_shape(
                    o.get("shape").ok_or_else(|| anyhow!("output missing shape"))?,
                )?);
                output_dtypes.push(
                    o.get("dtype")
                        .and_then(Json::as_str)
                        .ok_or_else(|| anyhow!("output missing dtype"))?
                        .to_string(),
                );
            }
            artifacts.push(ArtifactInfo { name, file, params, output_shapes, output_dtypes });
        }
        let mut models = Vec::new();
        if let Some(m) = j.get("models").and_then(Json::as_obj) {
            for (name, v) in m {
                let u = |k: &str| -> Result<usize> {
                    v.get(k)
                        .and_then(Json::as_usize)
                        .ok_or_else(|| anyhow!("model '{name}' missing '{k}'"))
                };
                models.push(ModelEntry {
                    name: name.clone(),
                    heads: u("heads")?,
                    embed_dim: u("embed_dim")?,
                    dff: u("dff")?,
                    seq_len: u("seq_len")?,
                    padded_seq_len: u("padded_seq_len")?,
                    layers: u("layers")?,
                });
            }
        }
        Ok(Manifest { mmsz, artifacts, models })
    }

    pub fn artifact(&self, name: &str) -> Option<&ArtifactInfo> {
        self.artifacts.iter().find(|a| a.name == name)
    }

    pub fn model(&self, name: &str) -> Option<&ModelEntry> {
        self.models.iter().find(|m| m.name == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
        "mmsz": 64,
        "models": {"bert-base": {"heads":12,"embed_dim":768,"dff":3072,
                   "seq_len":256,"padded_seq_len":256,"layers":12}},
        "artifacts": [{
            "name": "mm_tile", "file": "mm_tile.hlo.txt",
            "params": [
                {"name":"a","shape":[64,64],"dtype":"int8"},
                {"name":"b","shape":[64,64],"dtype":"int8"}],
            "outputs": [{"shape":[64,64],"dtype":"int32"}],
            "meta": {"mmsz": 64}
        }]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::from_json(&Json::parse(SAMPLE).unwrap()).unwrap();
        assert_eq!(m.mmsz, 64);
        let a = m.artifact("mm_tile").unwrap();
        assert_eq!(a.params[0].shape, vec![64, 64]);
        assert_eq!(a.output_dtypes, vec!["int32"]);
        assert_eq!(m.model("bert-base").unwrap().layers, 12);
        assert!(m.artifact("nope").is_none());
    }

    #[test]
    fn rejects_incomplete() {
        let j = Json::parse(r#"{"artifacts": []}"#).unwrap();
        assert!(Manifest::from_json(&j).is_err());
    }

    #[test]
    fn loads_real_manifest_if_present() {
        if std::path::Path::new("artifacts/manifest.json").exists() {
            let m = Manifest::load("artifacts/manifest.json").unwrap();
            assert!(m.artifact("encoder_layer_fused").is_some());
            assert!(m.artifact("encoder_layer_pallas").is_some());
            let enc = m.artifact("encoder_layer_fused").unwrap();
            assert_eq!(enc.params.len(), 18); // x_q, x_scale + 16 weights
        }
    }
}
