//! PJRT runtime: load the AOT-compiled HLO artifacts and execute them.
//!
//! This is the XRT-substitute host path: python/jax lowered every L1/L2
//! computation to HLO **text** at `make artifacts` time; here the rust
//! coordinator compiles them once on the PJRT CPU client and executes
//! them on the request path — python never runs at serving time.

mod manifest;
mod weights;

pub use manifest::{ArtifactInfo, Manifest, ParamInfo};
pub use weights::{quantize_activation, EncoderWeights};

use std::collections::HashMap;

use anyhow::{anyhow, Context, Result};

/// A host tensor, convertible to/from `xla::Literal`.
#[derive(Debug, Clone, PartialEq)]
pub enum Tensor {
    I8 { data: Vec<i8>, shape: Vec<usize> },
    I32 { data: Vec<i32>, shape: Vec<usize> },
    F32 { data: Vec<f32>, shape: Vec<usize> },
}

impl Tensor {
    pub fn shape(&self) -> &[usize] {
        match self {
            Tensor::I8 { shape, .. } | Tensor::I32 { shape, .. } | Tensor::F32 { shape, .. } => {
                shape
            }
        }
    }

    pub fn len(&self) -> usize {
        self.shape().iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn scalar_f32(v: f32) -> Tensor {
        Tensor::F32 { data: vec![v], shape: vec![] }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            Tensor::F32 { data, .. } => Ok(data),
            _ => Err(anyhow!("tensor is not f32")),
        }
    }

    pub fn as_i8(&self) -> Result<&[i8]> {
        match self {
            Tensor::I8 { data, .. } => Ok(data),
            _ => Err(anyhow!("tensor is not i8")),
        }
    }

    pub fn dtype_name(&self) -> &'static str {
        match self {
            Tensor::I8 { .. } => "int8",
            Tensor::I32 { .. } => "int32",
            Tensor::F32 { .. } => "float32",
        }
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        let (ty, bytes): (xla::ElementType, Vec<u8>) = match self {
            Tensor::I8 { data, .. } => (
                xla::ElementType::S8,
                data.iter().map(|v| *v as u8).collect(),
            ),
            Tensor::I32 { data, .. } => (
                xla::ElementType::S32,
                data.iter().flat_map(|v| v.to_le_bytes()).collect(),
            ),
            Tensor::F32 { data, .. } => (
                xla::ElementType::F32,
                data.iter().flat_map(|v| v.to_le_bytes()).collect(),
            ),
        };
        xla::Literal::create_from_shape_and_untyped_data(ty, self.shape(), &bytes)
            .map_err(|e| anyhow!("literal creation: {e}"))
    }

    fn from_literal(lit: &xla::Literal) -> Result<Tensor> {
        let shape = lit.shape().map_err(|e| anyhow!("literal shape: {e}"))?;
        let (dims, ty) = match &shape {
            xla::Shape::Array(a) => (
                a.dims().iter().map(|d| *d as usize).collect::<Vec<_>>(),
                a.ty(),
            ),
            _ => return Err(anyhow!("tuple literal where array expected")),
        };
        match ty {
            xla::ElementType::S8 => Ok(Tensor::I8 {
                data: lit.to_vec::<i8>().map_err(|e| anyhow!("{e}"))?,
                shape: dims,
            }),
            xla::ElementType::S32 => Ok(Tensor::I32 {
                data: lit.to_vec::<i32>().map_err(|e| anyhow!("{e}"))?,
                shape: dims,
            }),
            xla::ElementType::F32 => Ok(Tensor::F32 {
                data: lit.to_vec::<f32>().map_err(|e| anyhow!("{e}"))?,
                shape: dims,
            }),
            other => Err(anyhow!("unsupported output element type {other:?}")),
        }
    }
}

/// The PJRT runtime: a CPU client plus compiled executables, keyed by
/// artifact name.  Compilation happens once (lazily); execution is the
/// hot path.
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    dir: std::path::PathBuf,
    compiled: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Runtime {
    /// Open the artifact directory (built by `make artifacts`).
    pub fn open(dir: impl AsRef<std::path::Path>) -> Result<Runtime> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(dir.join("manifest.json"))?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT client: {e}"))?;
        Ok(Runtime { client, manifest, dir, compiled: HashMap::new() })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (memoized) an artifact by name.
    pub fn compile(&mut self, name: &str) -> Result<()> {
        if self.compiled.contains_key(name) {
            return Ok(());
        }
        let info = self
            .manifest
            .artifact(name)
            .ok_or_else(|| anyhow!("unknown artifact '{name}'"))?;
        let path = self.dir.join(&info.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parsing {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {name}: {e}"))?;
        self.compiled.insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute an artifact.  Inputs are validated against the manifest.
    /// All artifacts are lowered with `return_tuple=True`, so the result
    /// is always the decomposed tuple.
    pub fn run(&mut self, name: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let info = self
            .manifest
            .artifact(name)
            .ok_or_else(|| anyhow!("unknown artifact '{name}'"))?
            .clone();
        if inputs.len() != info.params.len() {
            return Err(anyhow!(
                "artifact '{name}' expects {} inputs, got {}",
                info.params.len(),
                inputs.len()
            ));
        }
        for (t, p) in inputs.iter().zip(&info.params) {
            if t.shape() != p.shape.as_slice() {
                return Err(anyhow!(
                    "param '{}' shape mismatch: expected {:?}, got {:?}",
                    p.name,
                    p.shape,
                    t.shape()
                ));
            }
            if t.dtype_name() != p.dtype {
                return Err(anyhow!(
                    "param '{}' dtype mismatch: expected {}, got {}",
                    p.name,
                    p.dtype,
                    t.dtype_name()
                ));
            }
        }
        self.compile(name)?;
        let exe = self.compiled.get(name).unwrap();
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(Tensor::to_literal)
            .collect::<Result<_>>()?;
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("executing {name}: {e}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching result of {name}: {e}"))?;
        let parts = result
            .to_tuple()
            .map_err(|e| anyhow!("decomposing result tuple of {name}: {e}"))?;
        parts
            .iter()
            .map(Tensor::from_literal)
            .collect::<Result<Vec<_>>>()
            .with_context(|| format!("converting outputs of {name}"))
    }

    /// Run one encoder layer (fused fast path by default).
    /// Returns `(out_f32, out_q, out_scale)` for layer chaining.
    pub fn encoder_layer(
        &mut self,
        variant: &str,
        x_q: &Tensor,
        x_scale: f32,
        w: &EncoderWeights,
    ) -> Result<(Tensor, Tensor, f32)> {
        let mut inputs = vec![x_q.clone(), Tensor::scalar_f32(x_scale)];
        inputs.extend(w.tensors());
        let mut out = self.run(variant, &inputs)?;
        if out.len() != 3 {
            return Err(anyhow!("encoder artifact returned {} outputs", out.len()));
        }
        let scale = out[2].as_f32()?[0];
        let q = out.remove(1);
        let f = out.remove(0);
        Ok((f, q, scale))
    }

    /// Chain `weights.len()` encoder layers on the int8 path (the EDPU
    /// loop: each call's `(q, scale)` feeds the next).
    pub fn encoder_forward(
        &mut self,
        variant: &str,
        x_q: Tensor,
        x_scale: f32,
        weights: &[EncoderWeights],
    ) -> Result<Tensor> {
        let mut q = x_q;
        let mut s = x_scale;
        let mut last_f = None;
        for w in weights {
            let (f, q2, s2) = self.encoder_layer(variant, &q, s, w)?;
            q = q2;
            s = s2;
            last_f = Some(f);
        }
        last_f.ok_or_else(|| anyhow!("no layers given"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_available() -> bool {
        std::path::Path::new("artifacts/manifest.json").exists()
    }

    #[test]
    fn tensor_roundtrip_f32() {
        let t = Tensor::F32 { data: vec![1.0, -2.5, 3.25, 0.0], shape: vec![2, 2] };
        let lit = t.to_literal().unwrap();
        let back = Tensor::from_literal(&lit).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn tensor_roundtrip_i8() {
        let t = Tensor::I8 { data: vec![-127, 0, 5, 127, 1, -1], shape: vec![3, 2] };
        let lit = t.to_literal().unwrap();
        let back = Tensor::from_literal(&lit).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn runtime_rejects_bad_shapes() {
        if !artifacts_available() {
            return;
        }
        let mut rt = Runtime::open("artifacts").unwrap();
        let bad = vec![Tensor::I8 { data: vec![0; 4], shape: vec![2, 2] }; 2];
        assert!(rt.run("mm_tile", &bad).is_err());
    }

    #[test]
    fn mm_tile_executes_correctly() {
        if !artifacts_available() {
            return;
        }
        let mut rt = Runtime::open("artifacts").unwrap();
        let n = 64;
        // identity x constant: a = I, b = ramp -> out == b
        let mut a = vec![0i8; n * n];
        for i in 0..n {
            a[i * n + i] = 1;
        }
        let b: Vec<i8> = (0..n * n).map(|i| (i % 127) as i8).collect();
        let out = rt
            .run(
                "mm_tile",
                &[
                    Tensor::I8 { data: a, shape: vec![n, n] },
                    Tensor::I8 { data: b.clone(), shape: vec![n, n] },
                ],
            )
            .unwrap();
        match &out[0] {
            Tensor::I32 { data, .. } => {
                assert!(data.iter().zip(&b).all(|(x, y)| *x == *y as i32));
            }
            other => panic!("unexpected output {other:?}"),
        }
    }
}
