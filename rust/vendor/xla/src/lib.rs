//! In-memory stub of the `xla-rs` PJRT bindings.
//!
//! The real crate links `libxla_extension.so`, which cannot be vendored
//! for offline builds.  This stub keeps the **host-side** pieces fully
//! functional — [`Literal`] construction, shape queries, and typed
//! readback, which the tensor round-trip tests exercise — while every
//! PJRT entry point ([`PjRtClient::cpu`], compilation, execution) returns
//! a descriptive error.  All call sites that need a live PJRT runtime are
//! gated on the presence of an `artifacts/` directory and skip cleanly,
//! so `cargo test` passes with this stub and upgrades transparently when
//! the real bindings are swapped back in.

use std::fmt;

/// Error type matching the surface the codebase uses (`Display` only).
#[derive(Debug, Clone)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn no_runtime<T>() -> Result<T> {
    Err(Error(
        "vendored `xla` stub has no PJRT runtime (rebuild against real \
         xla-rs and run `make artifacts` to enable the PJRT path)"
            .to_string(),
    ))
}

/// XLA element types (subset + padding so downstream `other =>` match arms
/// stay reachable).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    Pred,
    S8,
    S16,
    S32,
    S64,
    U8,
    U16,
    U32,
    U64,
    F16,
    Bf16,
    F32,
    F64,
}

impl ElementType {
    fn byte_size(self) -> usize {
        match self {
            ElementType::Pred | ElementType::S8 | ElementType::U8 => 1,
            ElementType::S16 | ElementType::U16 | ElementType::F16 | ElementType::Bf16 => 2,
            ElementType::S32 | ElementType::U32 | ElementType::F32 => 4,
            ElementType::S64 | ElementType::U64 | ElementType::F64 => 8,
        }
    }
}

/// Array shape: dims + element type.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArrayShape {
    dims: Vec<i64>,
    ty: ElementType,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn ty(&self) -> ElementType {
        self.ty
    }
}

/// A shape is either an array or a tuple of shapes.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Shape {
    Array(ArrayShape),
    Tuple(Vec<Shape>),
}

/// Element types readable out of a [`Literal`] via [`Literal::to_vec`].
pub trait NativeType: Sized {
    const TY: ElementType;
    const SIZE: usize;
    fn from_le(bytes: &[u8]) -> Self;
}

impl NativeType for i8 {
    const TY: ElementType = ElementType::S8;
    const SIZE: usize = 1;
    fn from_le(bytes: &[u8]) -> Self {
        bytes[0] as i8
    }
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;
    const SIZE: usize = 4;
    fn from_le(bytes: &[u8]) -> Self {
        i32::from_le_bytes([bytes[0], bytes[1], bytes[2], bytes[3]])
    }
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;
    const SIZE: usize = 4;
    fn from_le(bytes: &[u8]) -> Self {
        f32::from_le_bytes([bytes[0], bytes[1], bytes[2], bytes[3]])
    }
}

impl NativeType for i64 {
    const TY: ElementType = ElementType::S64;
    const SIZE: usize = 8;
    fn from_le(bytes: &[u8]) -> Self {
        i64::from_le_bytes(bytes[..8].try_into().unwrap())
    }
}

impl NativeType for f64 {
    const TY: ElementType = ElementType::F64;
    const SIZE: usize = 8;
    fn from_le(bytes: &[u8]) -> Self {
        f64::from_le_bytes(bytes[..8].try_into().unwrap())
    }
}

/// A host literal: untyped bytes plus shape metadata (or a tuple).
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    ty: ElementType,
    dims: Vec<i64>,
    bytes: Vec<u8>,
    tuple: Option<Vec<Literal>>,
}

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        dims: &[usize],
        data: &[u8],
    ) -> Result<Literal> {
        let n: usize = dims.iter().product();
        if data.len() != n * ty.byte_size() {
            return Err(Error(format!(
                "literal data length {} does not match shape {:?} of {:?} ({} bytes expected)",
                data.len(),
                dims,
                ty,
                n * ty.byte_size()
            )));
        }
        Ok(Literal {
            ty,
            dims: dims.iter().map(|d| *d as i64).collect(),
            bytes: data.to_vec(),
            tuple: None,
        })
    }

    pub fn tuple(parts: Vec<Literal>) -> Literal {
        Literal { ty: ElementType::Pred, dims: vec![], bytes: vec![], tuple: Some(parts) }
    }

    pub fn shape(&self) -> Result<Shape> {
        match &self.tuple {
            Some(parts) => Ok(Shape::Tuple(
                parts
                    .iter()
                    .map(|p| p.shape())
                    .collect::<Result<Vec<_>>>()?,
            )),
            None => Ok(Shape::Array(ArrayShape { dims: self.dims.clone(), ty: self.ty })),
        }
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        if self.tuple.is_some() {
            return Err(Error("to_vec on a tuple literal".to_string()));
        }
        if self.ty != T::TY {
            return Err(Error(format!(
                "to_vec element type mismatch: literal is {:?}, requested {:?}",
                self.ty,
                T::TY
            )));
        }
        Ok(self.bytes.chunks_exact(T::SIZE).map(T::from_le).collect())
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        match &self.tuple {
            Some(parts) => Ok(parts.clone()),
            None => Err(Error("literal is not a tuple".to_string())),
        }
    }
}

/// Parsed HLO module (opaque; parsing requires the real bindings).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        no_runtime()
    }
}

/// An XLA computation handle.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// PJRT client stub — construction fails with a descriptive error.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        no_runtime()
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        no_runtime()
    }
}

/// Compiled executable stub.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        no_runtime()
    }
}

/// Device buffer stub.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        no_runtime()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let data: Vec<u8> = [1.0f32, -2.5, 0.0, 3.25]
            .iter()
            .flat_map(|v| v.to_le_bytes())
            .collect();
        let lit =
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[2, 2], &data).unwrap();
        assert_eq!(lit.to_vec::<f32>().unwrap(), vec![1.0, -2.5, 0.0, 3.25]);
        match lit.shape().unwrap() {
            Shape::Array(a) => {
                assert_eq!(a.dims(), &[2, 2]);
                assert_eq!(a.ty(), ElementType::F32);
            }
            other => panic!("unexpected shape {other:?}"),
        }
    }

    #[test]
    fn literal_rejects_bad_length() {
        assert!(
            Literal::create_from_shape_and_untyped_data(ElementType::S32, &[3], &[0u8; 4]).is_err()
        );
    }

    #[test]
    fn tuple_literals() {
        let a = Literal::create_from_shape_and_untyped_data(ElementType::S8, &[2], &[1, 2]).unwrap();
        let t = Literal::tuple(vec![a.clone()]);
        assert_eq!(t.to_tuple().unwrap(), vec![a]);
        assert!(t.to_vec::<i8>().is_err());
    }

    #[test]
    fn runtime_paths_fail_cleanly() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x").is_err());
    }
}
