//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The repo builds with no network access, so instead of pulling the real
//! `anyhow` from crates.io we vendor the small API slice the codebase
//! actually uses: [`Error`], [`Result`], [`anyhow!`], [`ensure!`],
//! [`bail!`], and the [`Context`] extension trait.  `Error` is a plain
//! message-carrying type (the `source()` chain of a wrapped error is
//! flattened into the message at conversion time), which is all the CLI
//! and tests need.
//!
//! Mirroring real `anyhow`, `Error` deliberately does **not** implement
//! `std::error::Error`; that is what makes the blanket
//! `impl From<E: std::error::Error>` coherent, so `?` works on any
//! std-error type.

use std::fmt;

/// A message-carrying error type (stand-in for `anyhow::Error`).
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable (`anyhow::Error::msg`).
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // `{:#}` in real anyhow prints the whole cause chain; ours is
        // already flattened, so both forms print the same string.
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        let mut msg = e.to_string();
        let mut src = e.source();
        while let Some(cause) = src {
            msg.push_str(": ");
            msg.push_str(&cause.to_string());
            src = cause.source();
        }
        Error { msg }
    }
}

/// `anyhow::Result`: defaults the error type to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding context to errors (`anyhow::Context`).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| Error { msg: format!("{c}: {e}") })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error { msg: format!("{}: {e}", f()) })
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error { msg: c.to_string() })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error { msg: f().to_string() })
    }
}

/// Construct an [`Error`] from a format string (`anyhow::anyhow!`).
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an error (`anyhow::bail!`).
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless a condition holds (`anyhow::ensure!`).
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow!(concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        Err(anyhow!("bad {}", 42))
    }

    #[test]
    fn macro_formats() {
        assert_eq!(fails().unwrap_err().to_string(), "bad 42");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse() -> Result<i32> {
            let v: i32 = "notanumber".parse()?;
            Ok(v)
        }
        assert!(parse().is_err());
    }

    #[test]
    fn context_prepends() {
        let r: std::result::Result<(), String> = Err("inner".into());
        let e = r.context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer: inner");
    }

    #[test]
    fn ensure_bare_and_formatted() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x > 0);
            ensure!(x < 10, "x too big: {x}");
            Ok(x)
        }
        assert!(f(5).is_ok());
        assert!(f(-1).unwrap_err().to_string().contains("condition failed"));
        assert_eq!(f(12).unwrap_err().to_string(), "x too big: 12");
    }
}
