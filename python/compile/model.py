"""L2 — the Transformer encoder layer as the EDPU executes it.

This is the compute graph the paper's EDPU implements: one call = one
Encoder layer = MHA Stage then FFN Stage (Algorithm 1), with

* every MM on the AIE MM PU int8 path (:func:`kernels.mm_pu.mm_pu` /
  :func:`bmm_pu`),
* every nonlinear operator (softmax / LayerNorm / GELU) on the PL branch
  (:mod:`kernels.plops`),
* int8 symmetric quantization: static per-tensor scales for weights,
  dynamic per-tensor scales for activations (computed in-graph, so the
  lowered HLO is self-contained).

Two implementations of the same arithmetic:

* ``encoder_layer`` — Pallas-kernelized (the decomposition proof; this is
  what validates that the EDPU tiling computes the right numbers);
* ``encoder_layer_fused`` — plain jnp (identical math, no grids; the fast
  serving path the rust coordinator uses on CPU PJRT).

Both are AOT-lowered by :mod:`compile.aot`; the rust runtime cross-checks
them against each other and against the fp32 reference.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

from .kernels import mm_pu as mmk
from .kernels import plops
from .kernels import ref


# ---------------------------------------------------------------------------
# Model configuration (Table IV of the paper)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Transformer configuration information (paper Table III/IV)."""

    name: str
    heads: int
    embed_dim: int
    dff: int
    seq_len: int       # logical L
    layers: int
    mmsz: int = mmk.MMSZ_AIE

    @property
    def head_dim(self) -> int:
        return self.embed_dim // self.heads

    @property
    def padded_seq_len(self) -> int:
        """L padded up to a multiple of MMSZ (the paper pads ViT 197->256)."""
        m = self.mmsz
        return ((self.seq_len + m - 1) // m) * m


BERT_BASE = ModelConfig("bert-base", 12, 768, 3072, 256, 12)
VIT_BASE = ModelConfig("vit-base", 12, 768, 3072, 197, 12)

# Canonical parameter order for one encoder layer.  aot.py records this in
# the artifact manifest; the rust runtime feeds literals in this order.
PARAM_ORDER = (
    "wqkv", "sqkv", "bqkv",
    "wproj", "sproj", "bproj",
    "w1", "s1", "b1",
    "w2", "s2", "b2",
    "ln1_g", "ln1_b", "ln2_g", "ln2_b",
)


def param_shapes(cfg: ModelConfig) -> dict:
    """name -> (shape, dtype) for one encoder layer's parameters."""
    e, d = cfg.embed_dim, cfg.dff
    i8, f32 = "int8", "float32"
    return {
        "wqkv": ((e, 3 * e), i8), "sqkv": ((), f32), "bqkv": ((3 * e,), f32),
        "wproj": ((e, e), i8), "sproj": ((), f32), "bproj": ((e,), f32),
        "w1": ((e, d), i8), "s1": ((), f32), "b1": ((d,), f32),
        "w2": ((d, e), i8), "s2": ((), f32), "b2": ((e,), f32),
        "ln1_g": ((e,), f32), "ln1_b": ((e,), f32),
        "ln2_g": ((e,), f32), "ln2_b": ((e,), f32),
    }


def init_params(key: jax.Array, cfg: ModelConfig) -> dict:
    """Random fp32 weights, int8-quantized with calibrated scales."""
    e, d = cfg.embed_dim, cfg.dff
    ks = jax.random.split(key, 4)

    def qw(k, shape, fan_in):
        w = jax.random.normal(k, shape, jnp.float32) / math.sqrt(fan_in)
        s = jnp.maximum(jnp.max(jnp.abs(w)), 1e-8) / 127.0
        return ref.quantize(w, s), s

    wqkv, sqkv = qw(ks[0], (e, 3 * e), e)
    wproj, sproj = qw(ks[1], (e, e), e)
    w1, s1 = qw(ks[2], (e, d), e)
    w2, s2 = qw(ks[3], (d, e), d)
    z = jnp.zeros
    return {
        "wqkv": wqkv, "sqkv": sqkv, "bqkv": z((3 * e,), jnp.float32),
        "wproj": wproj, "sproj": sproj, "bproj": z((e,), jnp.float32),
        "w1": w1, "s1": s1, "b1": z((d,), jnp.float32),
        "w2": w2, "s2": s2, "b2": z((e,), jnp.float32),
        "ln1_g": jnp.ones((e,), jnp.float32), "ln1_b": z((e,), jnp.float32),
        "ln2_g": jnp.ones((e,), jnp.float32), "ln2_b": z((e,), jnp.float32),
    }


# ---------------------------------------------------------------------------
# Quantization plumbing
# ---------------------------------------------------------------------------


def dyn_quant(x: jax.Array):
    """Dynamic symmetric int8 quantization: returns (q, scale)."""
    s = jnp.maximum(jnp.max(jnp.abs(x)), 1e-8) / 127.0
    return ref.quantize(x, s), s


# Softmax output lives in [0, 1]; its scale is fixed at deploy time.
ATTN_SCALE = 1.0 / 127.0


# ---------------------------------------------------------------------------
# Kernelized (Pallas / EDPU-tiled) encoder layer
# ---------------------------------------------------------------------------


def _split_heads(x: jax.Array, heads: int) -> jax.Array:
    """[L, E] -> [H, L, dh] — the head-splitting after the merged QKV LB."""
    l, e = x.shape
    return x.reshape(l, heads, e // heads).transpose(1, 0, 2)


def _merge_heads(x: jax.Array) -> jax.Array:
    h, l, dh = x.shape
    return x.transpose(1, 0, 2).reshape(l, h * dh)


def mha_stage(x_q, x_scale, p, cfg: ModelConfig, *, kernels=True):
    """MHA Stage: merged-QKV LB -> ATB (QK^T, softmax, AV) -> Proj LB -> LN.

    ``x_q`` int8 [Lp, E]; returns fp32 [Lp, E] (post add&norm).
    """
    heads, dh = cfg.heads, cfg.head_dim
    mm = (lambda a, b: mmk.mm_pu(a, b, mmsz=cfg.mmsz)) if kernels else ref.mm_ref
    bmm = (lambda a, b: mmk.bmm_pu(a, b, mmsz=cfg.mmsz)) if kernels else ref.bmm_ref
    softmax = plops.softmax_pl if kernels else ref.softmax_ref
    layernorm = plops.layernorm_pl if kernels else ref.layernorm_ref

    # --- QKV LB (independent-linear: the three QKV projections of all heads
    # aggregated into one large PU matmul, §III.B) ---
    qkv = ref.dequantize(mm(x_q, p["wqkv"]), x_scale * p["sqkv"]) + p["bqkv"]
    e = cfg.embed_dim
    q = _split_heads(qkv[:, :e], heads)
    k = _split_heads(qkv[:, e:2 * e], heads)
    v = _split_heads(qkv[:, 2 * e:], heads)

    # --- ATB pre-stage: QK^T on Small PUs ---
    q_q, q_s = dyn_quant(q)
    k_q, k_s = dyn_quant(k)
    kt = jnp.transpose(k_q, (0, 2, 1))  # the PL matrix-transpose module
    scores = ref.dequantize(bmm(q_q, kt), q_s * k_s)

    # --- PL softmax branch ---
    attn = softmax(scores, scale=1.0 / math.sqrt(dh))

    # --- ATB post-stage: AV on Standard PUs ---
    a_q = ref.quantize(attn, ATTN_SCALE)
    v_q, v_s = dyn_quant(v)
    ctx = ref.dequantize(bmm(a_q, v_q), ATTN_SCALE * v_s)

    # --- Proj LB ---
    c_q, c_s = dyn_quant(_merge_heads(ctx))
    proj = ref.dequantize(mm(c_q, p["wproj"]), c_s * p["sproj"]) + p["bproj"]

    # --- Add & LayerNorm (PL) ---
    x_f = ref.dequantize(x_q, x_scale)
    if kernels:
        return layernorm(x_f + proj, p["ln1_g"], p["ln1_b"])
    return ref.layernorm_ref(x_f + proj, p["ln1_g"], p["ln1_b"])


def ffn_stage(h1, p, cfg: ModelConfig, *, kernels=True):
    """FFN Stage: FFN1 LB -> GELU (PL) -> FFN2 LB -> Add & LayerNorm."""
    mm = (lambda a, b: mmk.mm_pu(a, b, mmsz=cfg.mmsz)) if kernels else ref.mm_ref
    gelu = plops.gelu_pl if kernels else ref.gelu_ref
    layernorm = plops.layernorm_pl if kernels else ref.layernorm_ref

    h_q, h_s = dyn_quant(h1)
    f1 = ref.dequantize(mm(h_q, p["w1"]), h_s * p["s1"]) + p["b1"]
    g = gelu(f1)
    g_q, g_s = dyn_quant(g)
    f2 = ref.dequantize(mm(g_q, p["w2"]), g_s * p["s2"]) + p["b2"]
    return layernorm(h1 + f2, p["ln2_g"], p["ln2_b"])


def encoder_layer(x_q, x_scale, p, cfg: ModelConfig, *, kernels=True):
    """One EDPU call: MHA Stage then FFN Stage (serial, Algorithm 1).

    Returns ``(out_f32, out_q, out_scale)`` so successive layers chain on
    the int8 path without host-side float math.
    """
    h1 = mha_stage(x_q, x_scale, p, cfg, kernels=kernels)
    out = ffn_stage(h1, p, cfg, kernels=kernels)
    out_q, out_s = dyn_quant(out)
    return out, out_q, out_s


def encoder_layer_fused(x_q, x_scale, p, cfg: ModelConfig):
    """Identical arithmetic, plain jnp (the fast CPU serving path)."""
    return encoder_layer(x_q, x_scale, p, cfg, kernels=False)


# ---------------------------------------------------------------------------
# fp32 reference (no quantization) — for quantization-error sanity only
# ---------------------------------------------------------------------------


def encoder_layer_fp32(x, pf, cfg: ModelConfig):
    """pf holds fp32 weights (same keys, de-quantized)."""
    heads, dh = cfg.heads, cfg.head_dim
    qkv = x @ pf["wqkv"] + pf["bqkv"]
    e = cfg.embed_dim
    q = _split_heads(qkv[:, :e], heads)
    k = _split_heads(qkv[:, e:2 * e], heads)
    v = _split_heads(qkv[:, 2 * e:], heads)
    scores = jnp.einsum("hld,hmd->hlm", q, k) / math.sqrt(dh)
    attn = jax.nn.softmax(scores, axis=-1)
    ctx = _merge_heads(jnp.einsum("hlm,hmd->hld", attn, v))
    proj = ctx @ pf["wproj"] + pf["bproj"]
    h1 = ref.layernorm_ref(x + proj, pf["ln1_g"], pf["ln1_b"])
    f1 = ref.gelu_ref(h1 @ pf["w1"] + pf["b1"])
    out = f1 @ pf["w2"] + pf["b2"]
    return ref.layernorm_ref(h1 + out, pf["ln2_g"], pf["ln2_b"])


def dequant_params(p: dict) -> dict:
    """int8 params -> fp32 params for the fp32 reference."""
    out = dict(p)
    for w, s in (("wqkv", "sqkv"), ("wproj", "sproj"), ("w1", "s1"), ("w2", "s2")):
        out[w] = ref.dequantize(p[w], p[s])
    return out


# ---------------------------------------------------------------------------
# Workload accounting (paper §IV.A) — used to cross-check the rust side
# ---------------------------------------------------------------------------


def mm_workload(cfg: ModelConfig) -> list:
    """The (count, M, N, K) MM load of one layer, independent-linear mode.

    Matches the paper's §V.B design case for BERT-Base: 4x 256x768x768,
    12x 256x256x64 pre / 12x 256x64x256 post, 2x 256x768x3072-shaped FFN.
    """
    l, e, d, h = cfg.padded_seq_len, cfg.embed_dim, cfg.dff, cfg.heads
    dh = cfg.head_dim
    return [
        # merged QKV (3x [E,E]) + Proj = 4 LB matmuls of L x E x E
        (4, l, e, e),
        # ATB pre-stage QK^T: per head L x L x dh
        (h, l, l, dh),
        # ATB post-stage AV: per head L x dh x L
        (h, l, dh, l),
        # FFN1 + FFN2
        (1, l, d, e),
        (1, l, e, d),
    ]


def total_ops(cfg: ModelConfig) -> int:
    """MAC*2 ops of one encoder layer (MM only, as the paper counts TOPS)."""
    return sum(2 * c * m * n * k for (c, m, n, k) in mm_workload(cfg))
