"""Pure-jnp correctness oracles for every L1 kernel and for the L2 model.

These implement the *same arithmetic* as the Pallas kernels with plain
jax.numpy — no tiling, no grids — so any disagreement is a kernel bug,
not a quantization choice.  Integer paths must match exactly; float paths
to ~1e-5.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def mm_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """int8 x int8 -> int32 matmul oracle."""
    return jnp.dot(a, b, preferred_element_type=jnp.int32)


def bmm_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """Batched int8 matmul oracle: [H,M,K] x [H,K,N] -> int32 [H,M,N]."""
    return jax.lax.dot_general(
        a,
        b,
        dimension_numbers=(((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.int32,
    )


def softmax_ref(x: jax.Array, *, scale: float = 1.0) -> jax.Array:
    v = x.astype(jnp.float32) * scale
    return jax.nn.softmax(v, axis=-1)


def layernorm_ref(
    x: jax.Array, gamma: jax.Array, beta: jax.Array, *, eps: float = 1e-5
) -> jax.Array:
    v = x.astype(jnp.float32)
    mu = jnp.mean(v, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(v - mu), axis=-1, keepdims=True)
    return (v - mu) * jax.lax.rsqrt(var + eps) * gamma + beta


def gelu_ref(x: jax.Array) -> jax.Array:
    v = x.astype(jnp.float32)
    c = 0.7978845608028654
    return 0.5 * v * (1.0 + jnp.tanh(c * (v + 0.044715 * v * v * v)))


# ---------------------------------------------------------------------------
# Quantization helpers shared by model and oracle (int8 symmetric,
# per-tensor scale — the "already quantified Int8 model" of the paper).
# ---------------------------------------------------------------------------


def quantize(x: jax.Array, scale) -> jax.Array:
    """fp32 -> int8 with symmetric per-tensor scale."""
    q = jnp.round(x / scale)
    return jnp.clip(q, -127, 127).astype(jnp.int8)


def dequantize(q: jax.Array, scale) -> jax.Array:
    return q.astype(jnp.float32) * scale


def calibrate_scale(x) -> float:
    """Pick the per-tensor scale a deploy-time calibrator would pick."""
    import numpy as np

    return float(max(abs(np.asarray(x, dtype=np.float64)).max(), 1e-8) / 127.0)
