"""L1 — AIE MM PU tile kernel (Pallas).

The paper's compute hot-spot is the AIE MM PU: a 2-D group of AIE cores,
each computing an ``MMSZ_AIE^3`` int8 matrix-multiply out of its 32 KB
window memory, fed by PLIO streams with double buffering (Eq. 3-4 of the
paper).  On the TPU-style Pallas machine the same schedule is expressed as:

* window tile (<= 1/4 of window memory per operand)  ->  ``BlockSpec``
  ``(MMSZ, MMSZ)`` blocks resident in VMEM;
* the PLIO / DMA HBM->window streaming order             ->  the Pallas grid
  ``(M/MMSZ, N/MMSZ, K/MMSZ)`` with K innermost (the PU's accumulation
  iteration);
* the AIE vector processor's int8 MAC array          ->  the MXU via
  ``jnp.dot(..., preferred_element_type=int32)``.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers the same schedule to portable HLO,
which is what the rust runtime loads.

Correctness oracle: :mod:`compile.kernels.ref`.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile edge.  Satisfies Eq. 3 on both machines: 64*64 int8 = 4 KiB
# <= M_Window/4 (32 KiB AIE window) and 64x64 is an MXU-native tile.
MMSZ_AIE = 64


def _mm_kernel(a_ref, b_ref, o_ref):
    """One AIE-core step: multiply the resident window tiles, accumulate."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.int32
    )


@functools.partial(jax.jit, static_argnames=("mmsz",))
def mm_pu(a: jax.Array, b: jax.Array, *, mmsz: int = MMSZ_AIE) -> jax.Array:
    """int8 x int8 -> int32 blocked matmul with the AIE MM PU schedule.

    ``a``: int8 ``[M, K]``; ``b``: int8 ``[K, N]``.  ``M, K, N`` must be
    multiples of ``mmsz`` (the paper pads — e.g. ViT's L=197 -> 256).
    """
    m, ka = a.shape
    kb, n = b.shape
    assert ka == kb, f"inner dims differ: {ka} vs {kb}"
    assert m % mmsz == 0 and n % mmsz == 0 and ka % mmsz == 0, (
        f"shapes ({m},{ka})x({kb},{n}) not multiples of MMSZ={mmsz}"
    )
    grid = (m // mmsz, n // mmsz, ka // mmsz)
    return pl.pallas_call(
        _mm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((mmsz, mmsz), lambda i, j, k: (i, k)),
            pl.BlockSpec((mmsz, mmsz), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((mmsz, mmsz), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=True,
    )(a, b)


def _bmm_kernel(a_ref, b_ref, o_ref):
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[0], b_ref[0], preferred_element_type=jnp.int32
    )[None]


@functools.partial(jax.jit, static_argnames=("mmsz",))
def bmm_pu(a: jax.Array, b: jax.Array, *, mmsz: int = MMSZ_AIE) -> jax.Array:
    """Batched (per attention head) int8 PU matmul.

    ``a``: int8 ``[H, M, K]``; ``b``: int8 ``[H, K, N]`` -> int32
    ``[H, M, N]``.  This is the ATB data path: the head dimension is folded
    into the grid, exactly as the paper folds heads onto parallel ATBs.
    """
    h, m, ka = a.shape
    hb, kb, n = b.shape
    assert h == hb and ka == kb
    assert m % mmsz == 0 and n % mmsz == 0 and ka % mmsz == 0
    grid = (h, m // mmsz, n // mmsz, ka // mmsz)
    return pl.pallas_call(
        _bmm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, mmsz, mmsz), lambda b_, i, j, k: (b_, i, k)),
            pl.BlockSpec((1, mmsz, mmsz), lambda b_, i, j, k: (b_, k, j)),
        ],
        out_specs=pl.BlockSpec((1, mmsz, mmsz), lambda b_, i, j, k: (b_, i, j)),
        out_shape=jax.ShapeDtypeStruct((h, m, n), jnp.int32),
        interpret=True,
    )(a, b)


# PU specification shapes (Fig. 4 of the paper): one PU invocation computes
# this many MMSZ tiles per dimension.  Used by aot.py to emit one artifact
# per PU spec so the rust tile-emulation path can drive them directly.
PU_SPECS = {
    # name: (tiles_m, tiles_n, tiles_k, cores, in_plio, out_plio)
    "large": (4, 4, 4, 64, 8, 4),
    "standard": (2, 2, 4, 16, 4, 1),
    "small": (1, 1, 4, 4, 2, 1),
}


def pu_invocation_shape(spec: str, mmsz: int = MMSZ_AIE):
    """(M, N, K) handled by one invocation of the named PU spec."""
    tm, tn, tk, _, _, _ = PU_SPECS[spec]
    return (tm * mmsz, tn * mmsz, tk * mmsz)
