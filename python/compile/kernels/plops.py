"""L1 — PL-side operator kernels (Pallas).

In the paper the memory-bound nonlinear operators (SoftMax, LayerNorm,
GELU) run on the PL fabric as pipeline branches inserted into the MM
backbone data flow (Observation 1/2).  Here each is a row-tiled Pallas
kernel so it lowers into the same HLO module as the MM PU kernels — the
software analogue of "inserted into the backbone pipeline".

All operate in fp32 (the PL branch de-quantizes the AIE int32 results).
``interpret=True`` for CPU-PJRT portability.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Rows per PL-module pipeline beat.  8 rows x 4 KiB-ish row is a BRAM-sized
# burst; on TPU it is simply a VMEM-friendly block.
ROW_BLOCK = 8


def _pick_row_block(rows: int) -> int:
    rb = ROW_BLOCK
    while rows % rb:
        rb //= 2
    return max(rb, 1)


def _softmax_kernel(x_ref, o_ref, *, scale: float):
    v = x_ref[...].astype(jnp.float32) * scale
    m = jnp.max(v, axis=-1, keepdims=True)
    e = jnp.exp(v - m)
    o_ref[...] = e / jnp.sum(e, axis=-1, keepdims=True)


@functools.partial(jax.jit, static_argnames=("scale",))
def softmax_pl(x: jax.Array, *, scale: float = 1.0) -> jax.Array:
    """Row softmax of ``scale * x`` over the last axis.

    ``x``: fp32 ``[..., R, C]`` flattened internally to ``[rows, C]``.
    ``scale`` is the attention 1/sqrt(d_head) factor, static at trace time
    (the PL module is configured per accelerator, not per request).
    """
    shape = x.shape
    x2 = x.reshape((-1, shape[-1]))
    rows, cols = x2.shape
    rb = _pick_row_block(rows)
    out = pl.pallas_call(
        functools.partial(_softmax_kernel, scale=scale),
        grid=(rows // rb,),
        in_specs=[pl.BlockSpec((rb, cols), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((rb, cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, cols), jnp.float32),
        interpret=True,
    )(x2)
    return out.reshape(shape)


def _layernorm_kernel(x_ref, g_ref, b_ref, o_ref, *, eps: float):
    v = x_ref[...].astype(jnp.float32)
    mu = jnp.mean(v, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(v - mu), axis=-1, keepdims=True)
    o_ref[...] = (v - mu) * jax.lax.rsqrt(var + eps) * g_ref[...] + b_ref[...]


@functools.partial(jax.jit, static_argnames=("eps",))
def layernorm_pl(
    x: jax.Array, gamma: jax.Array, beta: jax.Array, *, eps: float = 1e-5
) -> jax.Array:
    """Row LayerNorm.  ``x``: fp32 ``[R, C]``; ``gamma``/``beta``: ``[C]``."""
    rows, cols = x.shape
    rb = _pick_row_block(rows)
    return pl.pallas_call(
        functools.partial(_layernorm_kernel, eps=eps),
        grid=(rows // rb,),
        in_specs=[
            pl.BlockSpec((rb, cols), lambda i: (i, 0)),
            pl.BlockSpec((cols,), lambda i: (0,)),
            pl.BlockSpec((cols,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((rb, cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, cols), jnp.float32),
        interpret=True,
    )(x, gamma, beta)


_SQRT_2_OVER_PI = 0.7978845608028654


def _gelu_kernel(x_ref, o_ref):
    v = x_ref[...].astype(jnp.float32)
    inner = _SQRT_2_OVER_PI * (v + 0.044715 * v * v * v)
    o_ref[...] = 0.5 * v * (1.0 + jnp.tanh(inner))


@jax.jit
def gelu_pl(x: jax.Array) -> jax.Array:
    """tanh-approximation GELU (the form FPGA/PL implementations use)."""
    rows, cols = x.shape
    rb = _pick_row_block(rows)
    return pl.pallas_call(
        _gelu_kernel,
        grid=(rows // rb,),
        in_specs=[pl.BlockSpec((rb, cols), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((rb, cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, cols), jnp.float32),
        interpret=True,
    )(x)
